//! Ternary spanning tree over process ranks.
//!
//! The paper modifies Mattern's star-topology time algorithm to "a version
//! using a spanning tree and we have implemented a version using a ternary
//! tree" (§4.3). Rank 0 is the root; rank `r`'s children are
//! `3r+1, 3r+2, 3r+3` (when < P) and its parent is `(r−1)/3`.

/// Position of one rank in the ternary spanning tree.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanningTree {
    rank: usize,
    size: usize,
    arity: usize,
}

impl SpanningTree {
    /// The paper's ternary tree.
    pub fn ternary(rank: usize, size: usize) -> Self {
        Self::with_arity(rank, size, 3)
    }

    /// General `k`-ary tree (used by the ablation bench).
    pub fn with_arity(rank: usize, size: usize, arity: usize) -> Self {
        assert!(arity >= 1);
        assert!(rank < size);
        SpanningTree { rank, size, arity }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn is_root(&self) -> bool {
        self.rank == 0
    }

    /// Parent rank (`None` for the root).
    pub fn parent(&self) -> Option<usize> {
        if self.rank == 0 {
            None
        } else {
            Some((self.rank - 1) / self.arity)
        }
    }

    /// Child ranks present in a world of `size` processes.
    pub fn children(&self) -> Vec<usize> {
        (1..=self.arity)
            .map(|k| self.rank * self.arity + k)
            .filter(|&c| c < self.size)
            .collect()
    }

    /// Depth of this rank (root = 0). O(log₃ P).
    pub fn depth(&self) -> usize {
        let mut d = 0;
        let mut r = self.rank;
        while r != 0 {
            r = (r - 1) / self.arity;
            d += 1;
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn parent_child_inverse() {
        forall("child's parent is self", 32, |rng| {
            let size = 1 + rng.index(2000);
            let rank = rng.index(size);
            let t = SpanningTree::ternary(rank, size);
            for c in t.children() {
                let ct = SpanningTree::ternary(c, size);
                if ct.parent() != Some(rank) {
                    return Err(format!("size={size} rank={rank} child={c}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn every_nonroot_has_smaller_parent() {
        let size = 1200;
        for rank in 1..size {
            let t = SpanningTree::ternary(rank, size);
            let p = t.parent().unwrap();
            assert!(p < rank);
        }
    }

    #[test]
    fn tree_spans_all_ranks() {
        // Walking down from the root reaches every rank exactly once.
        let size = 1200;
        let mut seen = vec![false; size];
        let mut stack = vec![0usize];
        while let Some(r) = stack.pop() {
            assert!(!seen[r], "rank {r} reached twice");
            seen[r] = true;
            stack.extend(SpanningTree::ternary(r, size).children());
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn ternary_depth_logarithmic() {
        assert_eq!(SpanningTree::ternary(0, 1200).depth(), 0);
        // depth of the last rank in a 1200-node ternary tree is ~log3(1200)≈6.5
        let d = SpanningTree::ternary(1199, 1200).depth();
        assert!((6..=8).contains(&d), "depth {d}");
    }

    #[test]
    fn root_has_no_parent() {
        assert_eq!(SpanningTree::ternary(0, 7).parent(), None);
        assert!(SpanningTree::ternary(0, 7).is_root());
        assert!(!SpanningTree::ternary(3, 7).is_root());
    }
}
