//! The distributed parallel miner (paper §4) — the system contribution.
//!
//! [`worker::Worker`] is the Fig. 5 `ParallelDFS` state machine: stack-based
//! DFS, lifeline work stealing, Mattern termination detection, and the
//! piggybacked λ protocol, written against the abstract [`crate::fabric::Mailbox`]
//! so the *identical protocol code* runs under all three engines:
//!
//! - [`engine_thread`] — real OS threads (the paper's single-node MPI runs);
//! - [`engine_sim`] — the deterministic discrete-event simulation used for
//!   the P ≤ 1,200 scaling studies (Figs. 6–7; TSUBAME substitution);
//! - [`engine_process`] — one OS process per rank over the stream-socket
//!   fabric (Unix-domain on one host, TCP across hosts — DESIGN.md §11),
//!   with every message serialized through [`crate::wire`]
//!   (distributed memory for real; DESIGN.md §7).
//!
//! The *naive baseline* of Table 2 is this same machinery with stealing
//! disabled (`steal: false`): the depth-1 static partition plus the λ
//! broadcast, exactly as §5.4 describes.

pub mod breakdown;
pub mod engine_process;
pub mod engine_sim;
pub mod engine_thread;
pub mod worker;

pub use breakdown::Breakdown;
pub use crate::fabric::process::DataPlane;
pub use crate::net::fault::{NetFaultKind, NetFaultPlan};
pub use crate::util::fault::{FaultPlan, FAULT_EXIT_CODE};
pub use engine_process::{
    run_process, run_process_with, AbortHandle, FleetError, PendingFleet, ProcessConfig,
    ProcessFleet,
};
pub use engine_sim::{run_sim, SimConfig};
pub use engine_thread::{run_threads, run_threads_with, ThreadConfig};
pub use worker::{Poll, RunMode, Worker, WorkerConfig};

use crate::db::Database;
use crate::lamp::{phase3_extract, LampResult, SupportIncreaseRule};
use crate::lcm::SupportHist;
use crate::obs::trace::RankTrace;

/// Aggregate outcome of one parallel run (one phase).
#[derive(Clone, Debug)]
pub struct ParRunResult {
    /// Final λ (phase 1) or the fixed minimum support (count mode).
    pub lambda_final: u32,
    /// `λ_final − 1` (phase-1 mode).
    pub min_sup: u32,
    /// Exact global closed-set histogram (merged from all workers at the
    /// phase boundary).
    pub hist: SupportHist,
    /// Total closed itemsets visited.
    pub closed_total: u64,
    /// Wall-clock (thread engine) or virtual (sim engine) makespan.
    pub makespan_s: f64,
    /// Per-process time breakdown (Fig. 7).
    pub breakdowns: Vec<Breakdown>,
    /// Aggregated communication counters.
    pub comm: crate::fabric::CommStats,
    /// Total expansion work units across processes: word-op equivalents
    /// including conditional-database reduction work (DESIGN.md §8).
    pub work_units: u64,
    /// Per-rank event timelines, clock-aligned onto the hub (empty unless
    /// the run was traced — DESIGN.md §14). In-process engines share one
    /// clock, so their offsets are 0.
    pub traces: Vec<RankTrace>,
}

impl ParRunResult {
    /// Finalize a phase-1 run: compute the exact λ from the merged
    /// histogram (the root's in-flight λ may lag; the merged histogram is
    /// exact, so this equals the serial result — see DESIGN.md §4).
    ///
    /// Public so callers composing the phases manually (instead of going
    /// through [`crate::coordinator`]) can recover λ* the same way the
    /// coordinator and the `lamp_parallel_*` wrappers do.
    pub fn finalize_phase1(&mut self, rule: &SupportIncreaseRule) {
        self.lambda_final = rule.advance(1, |l| self.hist.cs_ge(l));
        self.min_sup = self.lambda_final.saturating_sub(1).max(1);
    }
}

/// Full three-phase LAMP through the DES engine (phases 1–2 distributed,
/// phase 3 serial — the paper measures it at ~10 ms and omits it).
///
/// Convenience wrapper with the paper-default GLB parameters; the
/// [`crate::coordinator`] is the full-featured orchestration path.
pub fn lamp_parallel_sim(
    db: &Database,
    alpha: f64,
    cfg: &SimConfig,
) -> (LampResult, ParRunResult, ParRunResult) {
    let rule = SupportIncreaseRule::new(db.marginals(), alpha);
    let mut p1 = run_sim(db, RunMode::Phase1 { alpha }, cfg);
    p1.finalize_phase1(&rule);
    // Decorrelate the counting phase's steal randomness from phase 1, as
    // the thread wrapper and the coordinator both do (results are
    // seed-invariant; only comm/timing statistics are affected).
    let p2_cfg = SimConfig { seed: cfg.seed.wrapping_add(1), ..cfg.clone() };
    let p2 = run_sim(db, RunMode::Count { min_sup: p1.min_sup }, &p2_cfg);
    let k = p2.closed_total.max(1);
    let significant = phase3_extract(db, p1.min_sup, k, alpha);
    let result = LampResult {
        alpha,
        lambda_final: p1.lambda_final,
        min_sup: p1.min_sup,
        correction_factor: k,
        adjusted_level: alpha / k as f64,
        significant,
        phase1_closed: p1.closed_total,
        phase2_closed: p2.closed_total,
    };
    (result, p1, p2)
}

/// Full three-phase LAMP through the thread engine.
pub fn lamp_parallel_threads(
    db: &Database,
    alpha: f64,
    p: usize,
    steal: bool,
    seed: u64,
) -> (LampResult, ParRunResult, ParRunResult) {
    let rule = SupportIncreaseRule::new(db.marginals(), alpha);
    let mut p1 = run_threads(db, RunMode::Phase1 { alpha }, p, steal, seed);
    p1.finalize_phase1(&rule);
    let mode2 = RunMode::Count { min_sup: p1.min_sup };
    let p2 = run_threads(db, mode2, p, steal, seed.wrapping_add(1));
    let k = p2.closed_total.max(1);
    let significant = phase3_extract(db, p1.min_sup, k, alpha);
    let result = LampResult {
        alpha,
        lambda_final: p1.lambda_final,
        min_sup: p1.min_sup,
        correction_factor: k,
        adjusted_level: alpha / k as f64,
        significant,
        phase1_closed: p1.closed_total,
        phase2_closed: p2.closed_total,
    };
    (result, p1, p2)
}
