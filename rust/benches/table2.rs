//! Table 2: (left) GLB vs the naive static partition at P ∈ {12, 48};
//! (right) our bitmap phase 1 vs the LAMP2-style occurrence-deliver
//! baseline, single core (paper §5.4–5.5).
//!
//! Run: `cargo bench --bench table2 [-- --quick]`

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::lamp::{lamp2::lamp2_serial, lamp_serial};
use parlamp::par::{run_sim, RunMode, SimConfig};
use parlamp::util::bench_harness::{quick_mode, time_once, BenchSet};
use parlamp::util::fmt_secs;

fn main() {
    let quick = quick_mode();
    let alpha = parlamp::DEFAULT_ALPHA;
    let mut set = BenchSet::new(
        "Table 2 — vs naive approach and vs LAMP2 (phase-1 times)",
        &["name", "t1", "t12", "t48", "n12", "n48", "t_LAMP2"],
    );
    for sc in all_scenarios(quick) {
        let db = sc.build();
        let cal = calibrate_lamp(&db, alpha);
        let (t1, serial) = time_once(|| lamp_serial(&db, alpha));
        let (t_lamp2, l2) = time_once(|| lamp2_serial(&db, alpha));
        assert_eq!(serial.lambda_final, l2.lambda_final, "{}", sc.name);

        let mut times = Vec::new();
        for (p, steal) in [(12usize, true), (48, true), (12, false), (48, false)] {
            let cfg = SimConfig { p, steal, ..SimConfig::calibrated(p, &cal) };
            let out = run_sim(&db, RunMode::Phase1 { alpha }, &cfg);
            times.push(out.makespan_s);
        }
        set.row(vec![
            sc.name.to_string(),
            fmt_secs(t1),
            fmt_secs(times[0]),
            fmt_secs(times[1]),
            fmt_secs(times[2]),
            fmt_secs(times[3]),
            fmt_secs(t_lamp2),
        ]);
    }
    set.finish();
    println!(
        "expected shape (paper §5.4–5.5): n12 ≥ t12 and n48 ≥ t48 everywhere;\n\
         LAMP2 wins single-core on the sparse many-transaction problem (mcf7),\n\
         loses on the large dense GWAS problems."
    );
}
