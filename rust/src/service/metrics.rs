//! Daemon-side counters behind the STATS frame (DESIGN.md §13).
//!
//! Everything here is plain data mutated under the server's existing
//! `Shared` mutex — no atomics, no extra locks. `snapshot()` folds the
//! counters together with queue depths and cache/store gauges into the
//! wire-level [`ServiceStats`] report that `parlamp stats` renders.
//!
//! The daemon's deadline arithmetic also lives on this struct's clock:
//! [`Metrics::now_ms`] is milliseconds since daemon start on a monotonic
//! clock, the same timebase the fair queue's absolute deadlines use.

use std::collections::BTreeMap;
use std::time::Instant;

use crate::wire::service::{ClientStats, FleetStats, ServiceStats};

use super::queue::ClientDepth;

/// Number of log₂ buckets in a latency histogram: bucket `i` counts
/// samples in `[2^i, 2^(i+1))` ms (bucket 0 also takes 0 ms), bucket 19
/// takes everything ≥ ~8.7 minutes.
pub const HIST_BUCKETS: usize = 20;

/// Fixed-size log₂ histogram of millisecond durations.
#[derive(Clone, Debug, Default)]
pub struct LatencyHist {
    buckets: [u64; HIST_BUCKETS],
}

impl LatencyHist {
    pub fn record(&mut self, ms: u64) {
        let idx = if ms == 0 { 0 } else { (63 - ms.leading_zeros()) as usize };
        self.buckets[idx.min(HIST_BUCKETS - 1)] += 1;
    }

    pub fn to_vec(&self) -> Vec<u64> {
        self.buckets.to_vec()
    }
}

/// Per-fleet work accounting, indexed by fleet id.
#[derive(Clone, Debug, Default)]
pub struct FleetCounters {
    pub jobs_mined: u64,
    /// Wall-clock spent inside `mine()` — utilization = busy/uptime.
    pub busy_ms: u64,
    /// Worker ranks respawned in place mid-phase (PR-7 recovery).
    pub respawns: u64,
    /// Whole-fleet rebuilds after a poisoned run.
    pub rebuilds: u64,
}

/// All daemon counters; lives inside the server's `Inner` state.
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub jobs_submitted: u64,
    pub jobs_mined: u64,
    pub jobs_failed: u64,
    pub jobs_rejected_busy: u64,
    pub jobs_expired: u64,
    pub jobs_cancelled: u64,
    pub store_appends: u64,
    /// LRU misses answered from the persistent store.
    pub store_hits: u64,
    /// Terminal job records dropped by the bounded history (was silent
    /// before this PR — see `Inner::finish`).
    pub evicted_records: u64,
    /// Jobs submitted per client, over the daemon's lifetime.
    pub submitted_by_client: BTreeMap<String, u64>,
    pub fleets: Vec<FleetCounters>,
    /// Submit → dispatch.
    pub queue_wait: LatencyHist,
    /// Submit → terminal state.
    pub latency: LatencyHist,
}

impl Metrics {
    pub fn new(n_fleets: usize) -> Metrics {
        Metrics {
            started: Instant::now(),
            jobs_submitted: 0,
            jobs_mined: 0,
            jobs_failed: 0,
            jobs_rejected_busy: 0,
            jobs_expired: 0,
            jobs_cancelled: 0,
            store_appends: 0,
            store_hits: 0,
            evicted_records: 0,
            submitted_by_client: BTreeMap::new(),
            fleets: vec![FleetCounters::default(); n_fleets],
            queue_wait: LatencyHist::default(),
            latency: LatencyHist::default(),
        }
    }

    /// Milliseconds since daemon start (monotonic). The timebase for job
    /// deadlines and all recorded durations.
    pub fn now_ms(&self) -> u64 {
        self.started.elapsed().as_millis() as u64
    }

    /// Fold counters + live gauges into the wire report.
    pub fn snapshot(
        &self,
        cache: (u64, u64, usize),
        store_entries: usize,
        depths: &[ClientDepth],
    ) -> ServiceStats {
        let (cache_hits, cache_misses, cache_entries) = cache;
        let clients = depths
            .iter()
            .map(|d| ClientStats {
                client: d.client.clone(),
                queued: d.queued as u64,
                active: d.active as u64,
                submitted: self.submitted_by_client.get(&d.client).copied().unwrap_or(0),
            })
            .collect();
        ServiceStats {
            uptime_ms: self.now_ms(),
            jobs_submitted: self.jobs_submitted,
            jobs_mined: self.jobs_mined,
            jobs_failed: self.jobs_failed,
            jobs_rejected_busy: self.jobs_rejected_busy,
            jobs_expired: self.jobs_expired,
            jobs_cancelled: self.jobs_cancelled,
            cache_hits,
            cache_misses,
            cache_entries: cache_entries as u64,
            store_entries: store_entries as u64,
            store_appends: self.store_appends,
            store_hits: self.store_hits,
            evicted_records: self.evicted_records,
            fleets: self
                .fleets
                .iter()
                .map(|f| FleetStats {
                    jobs_mined: f.jobs_mined,
                    busy_ms: f.busy_ms,
                    respawns: f.respawns,
                    rebuilds: f.rebuilds,
                })
                .collect(),
            clients,
            queue_wait_ms: self.queue_wait.to_vec(),
            latency_ms: self.latency.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_by_log2() {
        let mut h = LatencyHist::default();
        for ms in [0, 1, 2, 3, 4, 7, 8, 1 << 19, u64::MAX] {
            h.record(ms);
        }
        let v = h.to_vec();
        assert_eq!(v[0], 2, "0 and 1 share bucket 0");
        assert_eq!(v[1], 2, "2 and 3");
        assert_eq!(v[2], 2, "4 and 7");
        assert_eq!(v[3], 1, "8");
        assert_eq!(v[19], 2, "2^19 and the overflow clamp");
        assert_eq!(v.iter().sum::<u64>(), 9);
    }

    #[test]
    fn snapshot_carries_depths_and_per_client_counts() {
        let mut m = Metrics::new(2);
        m.jobs_submitted = 3;
        m.submitted_by_client.insert("a".into(), 3);
        m.fleets[1].jobs_mined = 2;
        let depths = vec![ClientDepth { client: "a".into(), queued: 1, active: 1 }];
        let s = m.snapshot((5, 7, 4), 9, &depths);
        assert_eq!(s.cache_hits, 5);
        assert_eq!(s.cache_misses, 7);
        assert_eq!(s.cache_entries, 4);
        assert_eq!(s.store_entries, 9);
        assert_eq!(s.fleets.len(), 2);
        assert_eq!(s.fleets[1].jobs_mined, 2);
        assert_eq!(s.clients.len(), 1);
        assert_eq!(s.clients[0].submitted, 3);
        assert_eq!(s.queue_wait_ms.len(), HIST_BUCKETS);
    }
}
