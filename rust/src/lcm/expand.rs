//! PPC extension — the `ProcessNode` of the paper's Fig. 5.
//!
//! Expanding a closed itemset `P` with core `e` generates, for every item
//! `i > e` with `i ∉ P` and `sup(P ∪ i) ≥ min_sup`, the closure
//! `Q = clo(P ∪ i)`; the extension is *prefix-preserving* iff
//! `Q ∩ [0, i) = P ∩ [0, i)`. Each frequent closed itemset other than the
//! root is produced by exactly one `(P, i)` pair, so no duplicate detection
//! is needed — the property that makes the search a tree and therefore
//! amenable to stack-based distribution.
//!
//! Since PR 3 the expansion runs on a **reduced conditional database**
//! ([`ConditionalDb`], DESIGN.md §8) rebuilt per node: the candidate range
//! is projected onto `occ(P)` once (infrequent items pruned, identical
//! rows merged, dense or sparse encoding by density), and every support,
//! PPC, and closure check then runs at the projection's width instead of
//! over full-width columns. Only two full-width touches remain per child:
//! the prefix PPC scan over items ≤ core (early-exit, as before) and the
//! child's occurrence bitmap materialization.

use crate::bits::BitVec;
use crate::db::{ConditionalDb, Database, Item, ProjectScratch};

use super::node::SearchNode;

/// Reusable scratch buffers (child bitmap, closure list, projection
/// intermediates) so the per-node loop allocates only for the projection
/// outputs and the children it actually emits.
#[derive(Default)]
pub struct ExpandScratch {
    child_occ: Option<BitVec>,
    closure: Vec<Item>,
    project: ProjectScratch,
}

/// Work accounting for one expansion, used both for perf reporting and as
/// the discrete-event simulator's virtual-time cost model (DESIGN.md §8).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExpandStats {
    /// Items scanned in the candidate range (`i > core`, `i ∉ P`, inside
    /// the `keep` partition), whether or not they survived the frequency
    /// pruning.
    pub candidates: u64,
    /// Frequent candidates that reached the PPC/closure pass.
    pub closure_checks: u64,
    /// Children emitted.
    pub children: u64,
    /// Approximate `u64`-word operations in the candidate loop:
    /// reduced-width containment checks, full-width prefix scans, and
    /// child bitmap materialization.
    pub word_ops: u64,
    /// Word-op equivalents spent building the conditional database
    /// (projection, row merging, re-encoding) and reconstructing stripped
    /// occurrence bitmaps.
    pub reduce_ops: u64,
}

impl ExpandStats {
    pub fn add(&mut self, o: &ExpandStats) {
        self.candidates += o.candidates;
        self.closure_checks += o.closure_checks;
        self.children += o.children;
        self.word_ops += o.word_ops;
        self.reduce_ops += o.reduce_ops;
    }

    /// Total expansion work in word-op equivalents — the unit the DES
    /// charges virtual time for (`units × ns_per_unit`) and the quantity
    /// `bench::calibrate*` divides measured wall-clock by. Reduction work
    /// is included so calibration stays meaningful on the reduced hot
    /// path.
    #[inline]
    pub fn units(&self) -> u64 {
        self.word_ops + self.reduce_ops
    }
}

/// Expand `node`, pushing each PPC child onto `out` in **reverse item
/// order** so that popping from a stack visits children in ascending order
/// (depth-first order identical to the recursive formulation — paper §4.1).
///
/// `min_sup` is the current frequency threshold (the LAMP `λ`); children
/// below it are not generated.
pub fn expand(
    db: &Database,
    node: &mut SearchNode,
    min_sup: u32,
    scratch: &mut ExpandScratch,
    out: &mut Vec<SearchNode>,
) -> ExpandStats {
    expand_filtered(db, node, min_sup, scratch, out, |_| true)
}

/// [`expand`] restricted to generating items accepted by `keep`.
///
/// Used by the depth-1 preprocess partition (paper §4.5): process `r` of
/// `P` expands the root only for items `i` with `i mod P = r`, which seeds
/// every stack without any communication. Only the `keep` slice is
/// projected into the conditional database (each rank pays `O(m/P)`
/// extraction work, not `O(m)`); filtered-out items still participate in
/// PPC and closure checks through full-width early-exit scans, exactly as
/// in the pre-reduction expansion, so the emitted children are identical
/// to the unfiltered expansion's `keep`-satisfying subset.
pub fn expand_filtered(
    db: &Database,
    node: &mut SearchNode,
    min_sup: u32,
    scratch: &mut ExpandScratch,
    out: &mut Vec<SearchNode>,
    keep: impl Fn(Item) -> bool,
) -> ExpandStats {
    let mut stats = ExpandStats::default();
    let words = crate::bits::words_for(db.n_trans()) as u64;
    let first = out.len();

    // Ensure the occurrence bitmap exists (may have been stripped in
    // transit); charge its reconstruction as reduction work.
    if node.occ.is_none() {
        stats.reduce_ops += words * node.items.len() as u64;
    }
    let occ = node.occurrence(db).clone();

    // Build this node's conditional database: the `keep` slice of the
    // candidate range projected onto occ(P), infrequent items pruned,
    // identical rows merged, encoding chosen by density. Per-candidate
    // checks against projected items run on this reduced view; items
    // outside `keep` (none, for a plain `expand`) are handled full-width
    // below.
    let cond = ConditionalDb::project_where_with(
        db,
        &occ,
        &node.items,
        node.core,
        min_sup,
        &keep,
        &mut scratch.project,
    );
    stats.candidates += cond.scanned();
    stats.reduce_ops += cond.build_ops();

    let start: Item = (node.core + 1) as Item; // NO_CORE = -1 -> 0
    let n_items = db.n_items() as Item;
    // Membership mask of P for O(1) "i ∈ P" checks. P is sorted and small.
    let in_p = |i: Item| node.items.binary_search(&i).is_ok();
    // Did `keep` exclude anything from the projection? (Plain `expand`
    // never does; the preprocess partition does.) When nothing was
    // excluded the full-width fallback pass below is skipped wholesale.
    let members_in_range = node.items.len() - node.items.partition_point(|&m| m < start);
    let keep_excluded =
        cond.scanned() < (n_items as usize - start as usize - members_in_range) as u64;

    let child_occ = scratch.child_occ.get_or_insert_with(|| BitVec::zeros(db.n_trans()));
    let closure = &mut scratch.closure;

    // Candidates iterate in ascending-support order (deterministic; the
    // per-candidate cost is independent of this order — the saving comes
    // from the support-cut walk inside ppc_closure).
    'cand: for k in cond.candidates() {
        let (i, sup) = cond.item(k);
        stats.closure_checks += 1;
        closure.clear();

        // Suffix PPC + closure completion in one frequency-ordered pass
        // over the reduced columns. Items pruned from the projection
        // cannot contain the child (containment would lift their
        // projected support past min_sup), so they are never touched.
        if !cond.ppc_closure(k, closure, &mut stats.word_ops) {
            continue;
        }

        // Prefix PPC over items ≤ core outside P, against full-width
        // columns (the projection only covers the candidate range). The
        // child occurrence is materialized once, here, and reused as the
        // emitted child's cache. Early-exit scans are ~1 word on average.
        occ.and_assign_into(db.col(i), child_occ);
        stats.word_ops += words;
        for j in 0..start {
            if in_p(j) {
                continue;
            }
            stats.word_ops += 1;
            if child_occ.is_subset_of(db.col(j)) {
                continue 'cand;
            }
        }
        // Candidate-range items excluded from the projection by `keep`:
        // same full-width early-exit containment checks the seed used.
        // Skipped entirely by plain `expand`, where `keep` excludes
        // nothing; `keep` is tested first so included items cost one call.
        if keep_excluded {
            for j in start..n_items {
                if keep(j) || in_p(j) || j == i {
                    continue;
                }
                stats.word_ops += 1;
                if child_occ.is_subset_of(db.col(j)) {
                    if j < i {
                        continue 'cand; // PPC violation from another partition
                    }
                    closure.push(j);
                }
            }
        }

        let mut items = Vec::with_capacity(node.items.len() + 1 + closure.len());
        items.extend_from_slice(&node.items);
        items.push(i);
        items.extend_from_slice(closure);
        items.sort_unstable();

        out.push(SearchNode {
            items,
            core: i as i64,
            support: sup,
            occ: Some(child_occ.clone()),
        });
        stats.children += 1;
    }

    // The frequency-ordered generation above is re-sorted so stack pops
    // see ascending core order (true DFS order, as before the reduction).
    out[first..].sort_unstable_by(|a, b| b.core.cmp(&a.core));
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lcm::node::NO_CORE;

    fn db() -> Database {
        // The classic 4-item example; transactions chosen so several
        // closures are non-trivial.
        let trans = vec![
            vec![0, 1, 2],
            vec![0, 1],
            vec![1, 2, 3],
            vec![0, 3],
            vec![1, 2],
        ];
        Database::from_transactions(4, &trans, &[true, true, false, false, false])
    }

    #[test]
    fn children_have_correct_support_and_closure() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut out = Vec::new();
        let mut scratch = ExpandScratch::default();
        let st = expand(&d, &mut root, 1, &mut scratch, &mut out);
        assert_eq!(st.children as usize, out.len());
        for c in &out {
            // support matches db
            assert_eq!(d.support(&c.items), c.support, "items {:?}", c.items);
            // closed: no item outside adds nothing
            let occ = d.occurrence(&c.items);
            for j in 0..d.n_items() as Item {
                if !c.items.contains(&j) {
                    assert!(
                        !occ.is_subset_of(d.col(j)),
                        "items {:?} not closed wrt {j}",
                        c.items
                    );
                }
            }
            assert!(c.core > NO_CORE);
            // the occurrence cache is the full-width bitmap
            assert_eq!(c.occ.as_ref().unwrap(), &occ);
        }
    }

    #[test]
    fn min_sup_prunes() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut scratch = ExpandScratch::default();
        let mut all = Vec::new();
        expand(&d, &mut root.clone(), 1, &mut scratch, &mut all);
        let mut frequent = Vec::new();
        expand(&d, &mut root, 3, &mut scratch, &mut frequent);
        assert!(frequent.len() < all.len());
        for c in &frequent {
            assert!(c.support >= 3);
        }
    }

    #[test]
    fn children_pushed_in_reverse_core_order() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut out = Vec::new();
        expand(&d, &mut root, 1, &mut ExpandScratch::default(), &mut out);
        for w in out.windows(2) {
            assert!(w[0].core > w[1].core, "stack order must be reverse");
        }
    }

    #[test]
    fn filtered_expansion_partitions_children() {
        // keep-filtered expansions must produce exactly the children of
        // the unfiltered expansion whose core satisfies the predicate,
        // with identical closures (checks stay keep-agnostic).
        let d = db();
        let mut all = Vec::new();
        expand(&d, &mut SearchNode::root(&d), 1, &mut ExpandScratch::default(), &mut all);
        let p = 2u32;
        let mut parts = Vec::new();
        for r in 0..p {
            let mut out = Vec::new();
            expand_filtered(
                &d,
                &mut SearchNode::root(&d),
                1,
                &mut ExpandScratch::default(),
                &mut out,
                |i| i % p == r,
            );
            parts.extend(out);
        }
        let key = |n: &SearchNode| (n.core, n.items.clone(), n.support);
        let mut a: Vec<_> = all.iter().map(key).collect();
        let mut b: Vec<_> = parts.iter().map(key).collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn stats_accumulate_and_units_sum() {
        let mut a = ExpandStats {
            candidates: 1,
            closure_checks: 2,
            children: 3,
            word_ops: 4,
            reduce_ops: 5,
        };
        let b = a;
        a.add(&b);
        assert_eq!(
            a,
            ExpandStats {
                candidates: 2,
                closure_checks: 4,
                children: 6,
                word_ops: 8,
                reduce_ops: 10,
            }
        );
        assert_eq!(a.units(), 18);
    }

    #[test]
    fn expansion_charges_reduction_work() {
        let d = db();
        let mut out = Vec::new();
        let st = expand(&d, &mut SearchNode::root(&d), 1, &mut ExpandScratch::default(), &mut out);
        assert!(st.reduce_ops > 0, "projection build must be accounted");
        assert!(st.word_ops > 0);
        assert!(st.units() >= st.word_ops.max(st.reduce_ops));
    }
}
