//! Command-line driver (no clap in the offline registry — a small
//! hand-rolled parser).
//!
//! ```text
//! parlamp lamp     --data t.dat --labels t.lab
//!                  [--engine serial|lamp2|threads|sim|process]
//!                  [--data-plane hub|mesh] [--transport unix|tcp]
//!                  [--hosts h1:p,h2:p,..] [--trace trace.json]
//! parlamp mine     --data t.dat [--min-sup K]
//! parlamp sim      --scenario hapmap-dom-20 --procs 96 [--naive] [--ethernet]
//! parlamp bench    [--quick] [--engines a,b,..] [--scenarios x,y|all]
//!                  [--transport unix|tcp] [--out BENCH_pr9.json]
//!                  | --check FILE | --compare A.json,B.json
//! parlamp trace    summary trace.json
//! parlamp gendata  --scenario alz-dom-5 --out dir/
//! parlamp scenarios
//! parlamp serve    --endpoint unix:/run/parlamp.sock --procs 8
//!                  [--fleets 2] [--cache 32] [--store results.plst]
//!                  [--queue-depth N] [--client-depth N] [--client-slots N]
//! parlamp submit   --endpoint tcp:127.0.0.1:7878 --data t.dat --labels t.lab
//!                  [--priority P] [--deadline-ms MS] [--client NAME]
//! parlamp status   --endpoint tcp:127.0.0.1:7878 --job 1
//! parlamp results  --endpoint tcp:127.0.0.1:7878 --job 1
//! parlamp cancel   --endpoint tcp:127.0.0.1:7878 --job 1
//! parlamp stats    --endpoint tcp:127.0.0.1:7878
//! parlamp shutdown --endpoint tcp:127.0.0.1:7878
//! ```
//!
//! `--socket PATH` stays accepted everywhere as a deprecated alias for
//! `--endpoint unix:PATH` (a bare path parses as a Unix endpoint).

mod args;
mod commands;

pub use args::Args;

/// Binary entry point.
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&argv);
    std::process::exit(code);
}

/// Dispatch; returns the process exit code (testable).
pub fn run(argv: &[String]) -> i32 {
    // Dump the last-N structured log lines if anything panics, in every
    // command (workers re-install the same hook after fork — idempotent).
    crate::obs::log::install_panic_hook();
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return 2;
    };
    // `trace` is the one verb with positional operands (`trace summary
    // FILE`), which the flag parser would reject — dispatch it first.
    if cmd == "trace" {
        return match commands::cmd_trace(rest) {
            Ok(()) => 0,
            Err(e) => {
                eprintln!("error: {e:#}");
                1
            }
        };
    }
    let args = match Args::parse(rest) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    let result = match cmd.as_str() {
        "lamp" => commands::cmd_lamp(&args),
        "mine" => commands::cmd_mine(&args),
        "sim" => commands::cmd_sim(&args),
        "bench" => commands::cmd_bench(&args),
        "gendata" => commands::cmd_gendata(&args),
        "scenarios" => commands::cmd_scenarios(&args),
        "serve" => commands::cmd_serve(&args),
        "submit" => commands::cmd_submit(&args),
        "status" => commands::cmd_status(&args),
        "results" => commands::cmd_results(&args),
        "cancel" => commands::cmd_cancel(&args),
        "stats" => commands::cmd_stats(&args),
        "shutdown" => commands::cmd_shutdown(&args),
        // Hidden: the process-fabric child entry point. The parent engine
        // re-executes this binary as `parlamp __worker --connect ENDPOINT
        // --token T --worker-rank R` for each rank, and `--hosts` launcher
        // mode prints the same command for humans to run on other machines
        // (see par::engine_process).
        "__worker" => crate::par::engine_process::worker_main(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

pub fn usage() -> String {
    "parlamp — distributed significant pattern mining (LCM + LAMP + lifeline GLB)

USAGE:
  parlamp lamp      --data FILE --labels FILE [--alpha A]
                    [--engine serial|lamp2|threads|sim|process]
                    [--procs P | -n P] [--naive] [--data-plane hub|mesh]
                    [--transport unix|tcp] [--hosts H1:P,H2:P,..]
                    [--endpoint EP] [--screen native|xla|auto] [--seed S]
                    [--fault-inject rank=R,phase=P,after=N]
                    [--net-fault rank=R,kind=K,phase=P,after=N]
                    [--lease-timeout SECS] [--trace FILE]
                    [--probe-budget UNITS]
  parlamp mine      --data FILE [--min-sup K]
  parlamp sim       --scenario NAME [--procs P] [--naive] [--ethernet]
                    [--no-preprocess] [--alpha A] [--seed S]
  parlamp bench     [--quick] [--engines E1,E2,..] [--scenarios S1,S2|all]
                    [--procs P] [--alpha A] [--seed S] [--label L]
                    [--out FILE] [--data-plane hub|mesh] [--transport unix|tcp]
                    [--trace FILE]
  parlamp bench     --check FILE
  parlamp bench     --compare A.json,B.json  (or --compare A.json --with B.json)
  parlamp trace     summary FILE
  parlamp gendata   --scenario NAME --out DIR [--quick]
  parlamp scenarios [--quick]
  parlamp serve     --endpoint EP [--procs P] [--fleets N] [--cache N]
                    [--store FILE] [--queue-depth N] [--client-depth N]
                    [--client-slots N]
                    [--data-plane hub|mesh] [--transport unix|tcp]
                    [--hosts H1:P,..] [--fleet-listen EP]
                    [--fault-inject rank=R,phase=P,after=N]
                    [--net-fault rank=R,kind=K,phase=P,after=N]
                    [--lease-timeout SECS] [--job-watchdog-secs SECS]
                    [--trace FILE]
  parlamp submit    --endpoint EP --data FILE --labels FILE [--alpha A]
                    [--naive] [--no-preprocess] [--screen native|xla|auto]
                    [--seed S] [--priority P] [--deadline-ms MS]
                    [--client NAME]
  parlamp status    --endpoint EP --job ID
  parlamp results   --endpoint EP --job ID
  parlamp cancel    --endpoint EP --job ID
  parlamp stats     --endpoint EP [--format human|prom]
  parlamp shutdown  --endpoint EP

Endpoints (EP) are typed: `unix:<path>` or `tcp:<host>:<port>` (DESIGN.md
§11). `--socket PATH` is a deprecated alias for `--endpoint unix:PATH` and
stays accepted on serve/submit/status/results/shutdown; a bare path with
no scheme parses as a Unix endpoint.

`bench` runs the Table-1 scenarios across engines (default: all five) and
writes the schema-stable perf-trajectory JSON (BENCH_<label>.json; the
label defaults to pr9 and is stamped into the document header);
`--quick` shrinks the data and defaults to the single mcf7 scenario;
`--check` validates an existing file against the parlamp-bench/4 schema;
`--compare` diffs two reports per (scenario, engine) — wall-clock,
work-unit, and phase-breakdown deltas — and errors if result fields
disagree.

Observability (DESIGN.md §14): `--trace FILE` on `lamp`, `bench`, and
`serve` records a fixed-capacity ring of timestamped events per rank
(phase spans, expand batches, steal REQUEST/GIVE/REJECT, DTD waves,
checkpoints, respawns) and writes a Chrome/Perfetto trace-event JSON —
one track per rank plus a hub track, with flow arrows linking each steal
request to the give that answered it; load it at ui.perfetto.dev.
`parlamp trace summary FILE` prints the same trace as terminal numbers:
a per-rank Fig.-7 breakdown, the who-stole-from-whom matrix, and DTD
wave arrival spreads. `parlamp stats --format prom` renders the daemon's
STATS frame as the Prometheus text format. `PARLAMP_LOG=level[,target=
level]` (error|warn|info|debug|trace, default info) filters the
structured rank/fleet/job-tagged log on stderr. `--probe-budget UNITS`
(lamp, distributed engines) shrinks the work quantum between mailbox
polls below the 4M-unit paper default, so short traced runs still
exercise the steal protocol.

Engines `threads`, `sim`, and `process` run the full three-phase procedure
through the coordinator (phases 1-2 distributed, phase 3 via the configured
screen). `process` spawns one worker OS process per rank, connected over a
pluggable stream transport (`--transport`, DESIGN.md §11) speaking the
DESIGN.md §7 wire protocol — `unix` (default) for single-host distributed
memory, `tcp` for cross-host fleets. Its data plane is selectable
(`--data-plane`, DESIGN.md §10): `mesh` (default) lets workers exchange
steal traffic and DTD waves over direct worker-to-worker sockets with zero
hub hops; `hub` relays everything through the parent (the centralized
ablation baseline). `--hosts` switches the process engine into launcher
mode: the hub binds (at `--endpoint`, default tcp:127.0.0.1:0), prints one
`JOIN[rank]: parlamp __worker …` command per listed host, and waits for
those externally-started workers to attach instead of spawning local
children. Scenario names mirror Table 1: hapmap-dom-10, hapmap-dom-20,
alz-dom-5, alz-dom-10, alz-rec-30, mcf7.

A process fleet survives worker death (DESIGN.md §12): a rank lost
mid-phase is respawned in place and the phase replayed under a fresh
epoch, with results bit-identical to an undisturbed run. `--fault-inject
rank=R,phase=P,after=N` (lamp --engine process, serve) arms one
deterministic worker death for chaos testing — rank R exits with code 86
once phase epoch P has cost it N work units.

Liveness beyond crash detection (DESIGN.md §15): the hub pings workers
mid-phase and tracks a per-rank heartbeat lease; a rank silent past
`--lease-timeout SECS` (default 60) is force-killed and respawned through
the same replay path, so stalls and network partitions — not just deaths —
are survived. `--net-fault rank=R,kind=stall|drop|corrupt|partition,
phase=P,after=N` (lamp --engine process, serve) arms one deterministic
network fault under rank R's fabric stream, scripted by data-frame count
N within phase epoch P. `serve --job-watchdog-secs SECS` (default 1800;
0 disables) bounds each job's wall-clock: a fleet that exceeds it is
force-killed, the job fails with a typed error, and the fleet is rebuilt
for the next job.

`serve` starts the long-running mining daemon (DESIGN.md §9 and §13): a
pool of `--fleets` warm worker fleets mines queued jobs concurrently, a
weighted-fair queue with per-client accounting picks what runs next
(priorities, optional deadlines, typed `busy` rejections past
`--queue-depth`/`--client-depth`), and repeat submissions are answered
from a bounded result cache keyed by (database digest, alpha, GLB
parameters, screen). `--store FILE` adds a disk-backed persistent result
store behind the cache: results survive daemon restarts and are served
without mining. The daemon listens at `--endpoint` (Unix path or TCP
port); `--transport tcp` (or `--hosts`) puts the fleets' own fabric on
TCP too, and `--fleet-listen` pins the fleet hub's address for off-host
workers. `submit` prints the assigned job id; `results` blocks until the
job finishes and prints the same summary + table as `lamp --engine
serial`; `stats` prints per-fleet utilization, per-client queue depths,
cache/store counters, and latency histograms; `shutdown` (or SIGTERM)
drains the queue, BYEs every fleet, and unlinks a Unix socket (TCP
listeners leave nothing behind)."
        .to_string()
}
