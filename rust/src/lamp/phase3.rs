//! Phase 3 — significant pattern extraction.
//!
//! Walks the frequent closed itemsets once more and reports those whose
//! one-sided Fisher exact P-value is at or below the adjusted level
//! `δ = α / k`. The paper reports this phase takes ~10 ms; it is also the
//! phase the XLA/PJRT screen accelerates in batch (`runtime::screen`), and
//! the two paths are asserted equivalent in the integration tests.

use crate::db::Database;
use crate::lcm::{mine_closed, Visit};
use crate::stats::FisherTable;

/// A statistically significant pattern.
#[derive(Clone, Debug, PartialEq)]
pub struct SignificantPattern {
    pub items: Vec<crate::db::Item>,
    /// Total frequency `x(I)`.
    pub support: u32,
    /// Positive-class frequency `n(I)`.
    pub pos_support: u32,
    /// Raw (uncorrected) one-sided Fisher P-value.
    pub p_value: f64,
}

/// Extract all significant patterns at the adjusted level `α / k` among
/// closed itemsets with support ≥ `min_sup`, sorted by ascending P-value
/// (ties broken by itemset for determinism).
pub fn phase3_extract(
    db: &Database,
    min_sup: u32,
    correction_factor: u64,
    alpha: f64,
) -> Vec<SignificantPattern> {
    let delta = alpha / correction_factor as f64;
    let fisher = FisherTable::new(db.marginals());
    let log_delta = delta.ln();
    let mut out = Vec::new();
    mine_closed(db, min_sup.max(1), |node, ms| {
        let occ = node.occ.as_ref().expect("serial miner keeps occurrence bitmaps");
        let n_obs = db.pos_support(occ);
        let log_p = fisher.log_p_value(node.support, n_obs);
        if log_p <= log_delta {
            out.push(SignificantPattern {
                items: node.items.clone(),
                support: node.support,
                pos_support: n_obs,
                p_value: log_p.exp(),
            });
        }
        (Visit::Continue, ms)
    });
    out.sort_by(|a, b| {
        a.p_value.partial_cmp(&b.p_value).unwrap().then_with(|| a.items.cmp(&b.items))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::util::rng::Rng;

    /// A database with a planted perfect association: items {0,1} co-occur
    /// exactly in the positive class.
    fn planted() -> Database {
        let n = 40;
        let mut trans: Vec<Vec<Item>> = Vec::new();
        let mut labels = Vec::new();
        let mut rng = Rng::new(99);
        for t in 0..n {
            let pos = t < 12;
            let mut items: Vec<Item> = Vec::new();
            if pos {
                items.extend([0, 1]);
            }
            for i in 2..8 {
                if rng.bernoulli(0.3) {
                    items.push(i);
                }
            }
            trans.push(items);
            labels.push(pos);
        }
        Database::from_transactions(8, &trans, &labels)
    }

    #[test]
    fn finds_planted_association() {
        let db = planted();
        let sig = phase3_extract(&db, 2, 100, 0.05);
        assert!(
            sig.iter().any(|s| s.items.starts_with(&[0, 1]) || s.items == vec![0, 1]),
            "planted pattern {{0,1}} must be significant; got {sig:?}"
        );
        // Sorted by p-value
        for w in sig.windows(2) {
            assert!(w[0].p_value <= w[1].p_value + 1e-15);
        }
    }

    #[test]
    fn stricter_correction_yields_subset() {
        let db = planted();
        let loose = phase3_extract(&db, 2, 10, 0.05);
        let strict = phase3_extract(&db, 2, 100_000, 0.05);
        assert!(strict.len() <= loose.len());
        for s in &strict {
            assert!(loose.contains(s), "strict result must be a subset");
        }
    }

    #[test]
    fn p_values_are_exact() {
        let db = planted();
        let sig = phase3_extract(&db, 2, 1, 0.9999);
        let fisher = FisherTable::new(db.marginals());
        for s in &sig {
            let want = fisher.p_value(s.support, s.pos_support);
            assert!((s.p_value - want).abs() < 1e-12);
            assert_eq!(db.support(&s.items), s.support);
        }
    }
}
