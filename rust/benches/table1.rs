//! Table 1: problem statistics + t₁ (serial, measured), t₁₂ and t₁,₂₀₀
//! (DES, virtual time calibrated against the measured serial run).
//!
//! Run: `cargo bench --bench table1 [-- --quick]`

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::par::{lamp_parallel_sim, SimConfig};
use parlamp::util::bench_harness::{quick_mode, BenchSet};
use parlamp::util::fmt_secs;

fn main() {
    let quick = quick_mode();
    let alpha = parlamp::DEFAULT_ALPHA;
    let columns = [
        "name", "items", "trans.", "density", "N_pos", "lambda", "nu.CS", "t1", "t12", "t1200",
        "speedup1200",
    ];
    let mut set = BenchSet::new(
        "Table 1 — problems and runtimes (t in seconds; t12/t1200 simulated)",
        &columns,
    );
    for sc in all_scenarios(quick) {
        let db = sc.build();
        // t₁ is the measured serial time of the same computation the
        // parallel engines run (phases 1+2); phase 3 is reported in §5.6.
        let cal = calibrate_lamp(&db, alpha);
        let t1 = cal.t1_s;
        let mut row_times = Vec::new();
        for p in [12usize, 1200] {
            let cfg = SimConfig { p, ..SimConfig::calibrated(p, &cal) };
            let (_r, p1, p2) = lamp_parallel_sim(&db, alpha, &cfg);
            row_times.push(p1.makespan_s + p2.makespan_s);
        }
        set.row(vec![
            sc.name.to_string(),
            db.n_items().to_string(),
            db.n_trans().to_string(),
            format!("{:.2}%", db.density() * 100.0),
            db.marginals().n_pos.to_string(),
            cal.min_sup.to_string(),
            cal.correction.to_string(),
            fmt_secs(t1),
            fmt_secs(row_times[0]),
            fmt_secs(row_times[1]),
            format!("{:.0}x", t1 / row_times[1].max(1e-12)),
        ]);
    }
    set.finish();
}
