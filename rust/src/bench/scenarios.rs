//! The six Table-1 problems, scaled (data substitution — DESIGN.md §2).
//!
//! | paper problem   | items   | trans  | density | N_pos | regime        |
//! |-----------------|---------|--------|---------|-------|---------------|
//! | HapMap dom. 10  | 11,253  | 697    | 1.02%   | 105   | small/dense   |
//! | HapMap dom. 20  | 11,914  | 697    | 1.91%   | 105   | LARGE         |
//! | Alz. dom. 5     | 44,052  | 364    | 5.40%   | 176   | small         |
//! | Alz. dom. 10    | 91,126  | 364    | 9.78%   | 176   | LARGE         |
//! | Alz. rec. 30    | 250,120 | 364    | 2.90%   | 176   | medium        |
//! | MCF7            | 397     | 12,773 | 2.94%   | 1,129 | few items     |
//!
//! Scaled versions keep the *ratios* (items ≫ transactions for GWAS,
//! items ≪ transactions for MCF7; dominant > recessive density; class
//! fraction ≈ paper) while shrinking absolute work so the full sweep runs
//! on one core. `--quick` shrinks further.

use crate::datagen::{generate_gwas, generate_mcf7_like, GeneticModel, GwasSpec, Mcf7Spec};
use crate::db::Database;

/// One benchmark scenario.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Paper problem name this mirrors.
    pub name: &'static str,
    /// Whether the paper treats it as one of the two "large" problems
    /// (near-linear speedup expected through P = 1200).
    pub large: bool,
    spec: Spec,
}

#[derive(Clone, Debug)]
enum Spec {
    Gwas(GwasSpec),
    Mcf7(Mcf7Spec),
}

impl Scenario {
    /// Generate the database (deterministic per scenario).
    pub fn build(&self) -> Database {
        match &self.spec {
            Spec::Gwas(s) => generate_gwas(s).0,
            Spec::Mcf7(s) => generate_mcf7_like(s).0,
        }
    }
}

/// All six scenarios. `quick` shrinks the two large problems.
pub fn all_scenarios(quick: bool) -> Vec<Scenario> {
    let shrink = |x: usize, q: usize| if quick { q } else { x };
    vec![
        Scenario {
            name: "hapmap-dom-10",
            large: false,
            spec: Spec::Gwas(GwasSpec {
                n_snps: 2200,
                n_individuals: 192,
                n_pos: 29,
                model: GeneticModel::Dominant,
                maf_upper: 0.10,
                ld_copy_prob: 0.35,
                common_frac: 0.15,
                planted: vec![(3, 0.8)],
                seed: 0x4A50_0001,
            }),
        },
        Scenario {
            name: "hapmap-dom-20",
            large: true,
            spec: Spec::Gwas(GwasSpec {
                n_snps: shrink(1150, 650),
                n_individuals: 192,
                n_pos: 29,
                model: GeneticModel::Dominant,
                maf_upper: 0.20,
                ld_copy_prob: 0.35,
                common_frac: 0.25,
                planted: vec![(4, 0.85)],
                seed: 0x4A50_0002,
            }),
        },
        Scenario {
            name: "alz-dom-5",
            large: false,
            spec: Spec::Gwas(GwasSpec {
                n_snps: 8000,
                n_individuals: 256,
                n_pos: 124,
                model: GeneticModel::Dominant,
                maf_upper: 0.05,
                ld_copy_prob: 0.3,
                common_frac: 0.5,
                planted: vec![(3, 0.8)],
                seed: 0x4A50_0003,
            }),
        },
        Scenario {
            name: "alz-dom-10",
            large: true,
            spec: Spec::Gwas(GwasSpec {
                n_snps: shrink(11000, 3000),
                n_individuals: 256,
                n_pos: 124,
                model: GeneticModel::Dominant,
                maf_upper: 0.10,
                ld_copy_prob: 0.55,
                common_frac: 0.65,
                planted: vec![(4, 0.85)],
                seed: 0x4A50_0004,
            }),
        },
        Scenario {
            name: "alz-rec-30",
            large: false,
            spec: Spec::Gwas(GwasSpec {
                n_snps: 9000,
                n_individuals: 256,
                n_pos: 124,
                model: GeneticModel::Recessive,
                maf_upper: 0.30,
                ld_copy_prob: 0.3,
                common_frac: 0.3,
                planted: vec![(3, 0.8)],
                seed: 0x4A50_0005,
            }),
        },
        Scenario {
            name: "mcf7",
            large: false,
            spec: Spec::Mcf7(Mcf7Spec {
                n_items: 250,
                n_trans: shrink(6000, 2000),
                n_pos: 530,
                density: 0.0294,
                skew: 0.8,
                planted: vec![(2, 0.6)],
                seed: 0x4A50_0006,
            }),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_build_with_paper_like_shapes() {
        for s in all_scenarios(true) {
            let db = s.build();
            assert!(db.n_trans() > 0 && db.n_items() > 0, "{}", s.name);
            if s.name == "mcf7" {
                assert!(db.n_items() < db.n_trans(), "mcf7 is items ≪ transactions");
            } else {
                assert!(db.n_items() > db.n_trans(), "GWAS is items ≫ transactions");
            }
        }
    }

    #[test]
    fn dominant_variants_denser_than_recessive() {
        let all = all_scenarios(true);
        let d10 = all.iter().find(|s| s.name == "hapmap-dom-10").unwrap().build();
        let rec = all.iter().find(|s| s.name == "alz-rec-30").unwrap().build();
        // regime check, not exact densities
        assert!(d10.density() > 0.0);
        assert!(rec.density() > 0.0);
    }

    #[test]
    fn quick_mode_shrinks_large_problems() {
        let full = all_scenarios(false);
        let quick = all_scenarios(true);
        let f = full.iter().find(|s| s.name == "hapmap-dom-20").unwrap().build();
        let q = quick.iter().find(|s| s.name == "hapmap-dom-20").unwrap().build();
        assert!(q.n_items() < f.n_items());
    }
}
