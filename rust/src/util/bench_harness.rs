//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Methodology mirrors criterion's core loop: warm-up iterations, then a
//! fixed number of timed samples, reporting mean ± standard deviation.
//! Benchmarks that reproduce paper tables use [`BenchSet`] to accumulate and
//! render rows; `cargo bench` invokes the `[[bench]]` binaries with
//! `harness = false`, which call into this module.

use std::time::Instant;

use super::{fmt_secs, mean_sd, table::Table};

/// One measured statistic.
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub mean_s: f64,
    pub sd_s: f64,
    pub samples: usize,
}

impl Sample {
    pub fn display(&self) -> String {
        format!("{} ± {}", fmt_secs(self.mean_s), fmt_secs(self.sd_s))
    }
}

/// Benchmark a closure: `warmup` untimed runs then `samples` timed runs.
///
/// The closure's return value is consumed through `std::hint::black_box` so
/// the optimizer cannot elide the work.
pub fn bench<T, F: FnMut() -> T>(warmup: usize, samples: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
    }
    let (mean_s, sd_s) = mean_sd(&times);
    Sample { mean_s, sd_s, samples }
}

/// Quick single-shot wall-clock measurement (for long-running end-to-end
/// benches where repeated sampling is impractical; the paper itself averages
/// ≥10 runs for parallel and ≥4 for serial — callers choose).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (f64, T) {
    let t0 = Instant::now();
    let out = f();
    (t0.elapsed().as_secs_f64(), out)
}

/// A named collection of benchmark rows rendered as a table, matching the
/// row/column layout of the paper artefact each bench binary reproduces.
pub struct BenchSet {
    title: String,
    table: Table,
}

impl BenchSet {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        BenchSet { title: title.to_string(), table: Table::new(columns) }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.table.row(cells);
    }

    /// Render to stdout (and return the rendered string for logging).
    pub fn finish(self) -> String {
        let mut out = format!("\n=== {} ===\n", self.title);
        out.push_str(&self.table.render());
        println!("{out}");
        out
    }
}

/// Parse `--quick` / `PARLAMP_BENCH_QUICK=1` so CI can run abbreviated
/// versions of the paper-scale benches.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
        || std::env::var("PARLAMP_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_requested_samples() {
        let s = bench(1, 5, || 2u64 + 2);
        assert_eq!(s.samples, 5);
        assert!(s.mean_s >= 0.0);
    }

    #[test]
    fn time_once_returns_value() {
        let (dt, v) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(dt >= 0.0);
    }

    #[test]
    fn bench_set_renders_rows() {
        let mut b = BenchSet::new("t", &["a", "b"]);
        b.row(vec!["1".into(), "2".into()]);
        let s = b.finish();
        assert!(s.contains("=== t ==="));
        assert!(s.contains('1'));
    }
}
