//! The daemon's weighted-fair job queue (DESIGN.md §13).
//!
//! PR 4's queue was a global FIFO: one greedy client could bury everyone
//! else's jobs arbitrarily deep, nothing bounded queue growth, and a job
//! had no way to say "useless after t". This queue replaces it with
//! per-client accounting:
//!
//! - **Admission control**: a bounded number of queued jobs per client and
//!   globally. An over-limit `SUBMIT` is rejected with a typed
//!   [`Busy`] reply instead of growing the queue without bound.
//! - **Weighted-fair selection**: each client carries a virtual-time
//!   clock advanced by `SCALE / weight` per dispatched job; the eligible
//!   client with the lowest clock goes next (start-time fair queueing).
//!   A client that was idle has its clock caught up to the busiest
//!   backlog's floor on re-arrival, so sleeping does not bank credit
//!   beyond one scheduling round.
//! - **Slot caps**: a client may hold at most `per_client_active` fleets
//!   at once; its further jobs stay queued while others run, so no client
//!   is starved while another holds more than its cap of the pool.
//! - **Priorities + deadlines**: within one client, higher [`Entry`]
//!   priority dispatches first and equal priorities dispatch in
//!   submission order (FIFO-within-class). A job whose deadline passes
//!   before dispatch is expired with a typed error, never run late.
//!
//! Every operation is a pure function of the queue state and the caller's
//! clock (`now_ms`) — no hidden time reads — which is what lets
//! `tests/scheduler.rs` drive it against a reference model over hundreds
//! of randomized traces.

use std::collections::BTreeMap;

/// Virtual-time units charged per dispatch at weight 1 (`SCALE / weight`
/// for heavier clients, so double weight = half the charge = twice the
/// dispatch share).
const SCALE: u64 = 1 << 20;

/// Admission-control bounds. Defaults suit a small pool; the CLI exposes
/// them as `serve --queue-depth / --client-depth / --client-slots`.
#[derive(Clone, Copy, Debug)]
pub struct QueueLimits {
    /// Max queued (not yet running) jobs per client.
    pub per_client_queued: usize,
    /// Max queued jobs across all clients.
    pub global_queued: usize,
    /// Max concurrently *running* jobs per client (fairness slot cap).
    pub per_client_active: usize,
}

impl Default for QueueLimits {
    fn default() -> QueueLimits {
        QueueLimits { per_client_queued: 64, global_queued: 256, per_client_active: 1 }
    }
}

/// Typed admission rejection: which bound was hit and where it stands.
/// Carried to the client as a `JobState::Busy` STATUS payload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Busy {
    /// The submitting client's own queue is full.
    Client { queued: usize, cap: usize },
    /// The daemon-wide queue is full.
    Global { queued: usize, cap: usize },
}

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Busy::Client { queued, cap } => {
                write!(f, "client queue full ({queued}/{cap} jobs queued)")
            }
            Busy::Global { queued, cap } => {
                write!(f, "daemon queue full ({queued}/{cap} jobs queued)")
            }
        }
    }
}

/// One queued job: id plus everything selection needs.
#[derive(Clone, Debug)]
struct Entry {
    id: u64,
    priority: u8,
    /// Absolute expiry instant on the caller's `now_ms` clock; `None` =
    /// no deadline.
    deadline_at_ms: Option<u64>,
    /// Global submission sequence — the FIFO-within-class tie-breaker.
    seq: u64,
}

#[derive(Debug, Default)]
struct ClientState {
    /// Pending entries in submission order (`seq` ascending).
    pending: Vec<Entry>,
    /// Jobs currently dispatched to fleets.
    active: usize,
    /// Weighted-fair virtual clock (SCALE units).
    vtime: u64,
    /// Dispatch share weight (≥ 1); charged `SCALE / weight` per pop.
    weight: u32,
}

impl ClientState {
    fn is_idle(&self) -> bool {
        self.pending.is_empty() && self.active == 0
    }
}

/// Per-client queue depths, for STATS.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientDepth {
    pub client: String,
    pub queued: usize,
    pub active: usize,
}

/// The weighted-fair queue of pending job ids.
#[derive(Debug)]
pub struct FairQueue {
    limits: QueueLimits,
    clients: BTreeMap<String, ClientState>,
    seq: u64,
    total_pending: usize,
}

impl Default for FairQueue {
    fn default() -> FairQueue {
        FairQueue::new(QueueLimits::default())
    }
}

impl FairQueue {
    pub fn new(limits: QueueLimits) -> FairQueue {
        FairQueue { limits, clients: BTreeMap::new(), seq: 0, total_pending: 0 }
    }

    /// Set a client's dispatch weight (default 1). Heavier clients are
    /// charged less virtual time per job and so win a proportionally
    /// larger share of pops under contention.
    pub fn set_weight(&mut self, client: &str, weight: u32) {
        self.clients.entry(client.to_string()).or_default().weight = weight.max(1);
    }

    /// Enqueue a job, or reject it with a typed [`Busy`] when an
    /// admission bound is hit. `deadline_ms` is relative (0 = none);
    /// `now_ms` is the caller's monotonic clock.
    pub fn push(
        &mut self,
        client: &str,
        id: u64,
        priority: u8,
        deadline_ms: u64,
        now_ms: u64,
    ) -> Result<(), Busy> {
        if self.total_pending >= self.limits.global_queued {
            return Err(Busy::Global {
                queued: self.total_pending,
                cap: self.limits.global_queued,
            });
        }
        let queued = self.clients.get(client).map_or(0, |c| c.pending.len());
        if queued >= self.limits.per_client_queued {
            return Err(Busy::Client { queued, cap: self.limits.per_client_queued });
        }
        // A returning idle client catches its virtual clock up to the
        // floor of the currently-busy clients, so idling never banks more
        // than one round of credit. Computed before the borrow below.
        let floor = self
            .clients
            .iter()
            .filter(|(name, c)| name.as_str() != client && !c.is_idle())
            .map(|(_, c)| c.vtime)
            .min();
        let state = self.clients.entry(client.to_string()).or_default();
        if state.is_idle() {
            if let Some(floor) = floor {
                state.vtime = state.vtime.max(floor);
            }
        }
        let seq = self.seq;
        self.seq += 1;
        state.pending.push(Entry {
            id,
            priority,
            deadline_at_ms: (deadline_ms > 0).then(|| now_ms.saturating_add(deadline_ms)),
            seq,
        });
        self.total_pending += 1;
        Ok(())
    }

    /// Remove and return every pending job whose deadline has passed
    /// (`now_ms` strictly beyond `deadline_at`). Call before [`pop`]
    /// so an expired job is never dispatched.
    ///
    /// [`pop`]: FairQueue::pop
    pub fn expire(&mut self, now_ms: u64) -> Vec<u64> {
        let mut expired = Vec::new();
        for state in self.clients.values_mut() {
            state.pending.retain(|e| {
                let dead = e.deadline_at_ms.is_some_and(|at| now_ms > at);
                if dead {
                    expired.push(e.id);
                }
                e.deadline_at_ms.is_none() || !dead
            });
        }
        self.total_pending -= expired.len();
        // Ids in global submission order so the report is deterministic.
        expired.sort_unstable();
        expired
    }

    /// Dispatch the next job: among clients with pending work and a free
    /// slot, the lowest virtual clock wins (client name breaks ties);
    /// within the winner, highest priority first, submission order within
    /// a priority class. Returns `None` when no client is eligible —
    /// which can happen with jobs still pending, if every backlogged
    /// client is at its slot cap.
    pub fn pop(&mut self) -> Option<u64> {
        let winner = self
            .clients
            .iter()
            .filter(|(_, c)| !c.pending.is_empty() && c.active < self.limits.per_client_active)
            .min_by_key(|(name, c)| (c.vtime, name.as_str()))
            .map(|(name, _)| name.clone())?;
        let state = self.clients.get_mut(&winner).expect("winner exists");
        let best = state
            .pending
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| (std::cmp::Reverse(e.priority), e.seq))
            .map(|(i, _)| i)
            .expect("winner has pending work");
        let entry = state.pending.remove(best);
        state.active += 1;
        state.vtime += SCALE / u64::from(state.weight.max(1));
        self.total_pending -= 1;
        Some(entry.id)
    }

    /// Release a client's slot once its dispatched job reaches a terminal
    /// state (done, failed, or the fleet died under it).
    pub fn complete(&mut self, client: &str) {
        if let Some(state) = self.clients.get_mut(client) {
            state.active = state.active.saturating_sub(1);
        }
    }

    /// Remove a pending job. Returns whether it was present; every other
    /// entry keeps its relative order.
    pub fn cancel(&mut self, id: u64) -> bool {
        for state in self.clients.values_mut() {
            if let Some(i) = state.pending.iter().position(|e| e.id == id) {
                state.pending.remove(i);
                self.total_pending -= 1;
                return true;
            }
        }
        false
    }

    /// Estimated dispatch position (0 = among the next to run): the number
    /// of pending jobs that order before this one by (priority, seq). The
    /// true dispatch order also depends on fairness clocks and slot
    /// releases, so this is a display estimate, not a promise.
    pub fn position(&self, id: u64) -> Option<usize> {
        let target = self
            .clients
            .values()
            .flat_map(|c| c.pending.iter())
            .find(|e| e.id == id)?;
        let ahead = self
            .clients
            .values()
            .flat_map(|c| c.pending.iter())
            .filter(|e| {
                e.priority > target.priority
                    || (e.priority == target.priority && e.seq < target.seq)
            })
            .count();
        Some(ahead)
    }

    /// Total pending (queued, not running) jobs.
    pub fn len(&self) -> usize {
        self.total_pending
    }

    pub fn is_empty(&self) -> bool {
        self.total_pending == 0
    }

    /// Total jobs currently dispatched to fleets.
    pub fn active_total(&self) -> usize {
        self.clients.values().map(|c| c.active).sum()
    }

    /// Per-client depths (clients that ever submitted), name order.
    pub fn depths(&self) -> Vec<ClientDepth> {
        self.clients
            .iter()
            .map(|(client, c)| ClientDepth {
                client: client.clone(),
                queued: c.pending.len(),
                active: c.active,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(per_client_active: usize) -> FairQueue {
        FairQueue::new(QueueLimits {
            per_client_queued: 4,
            global_queued: 8,
            per_client_active,
        })
    }

    #[test]
    fn fifo_within_one_client_and_priority_first() {
        let mut q = q(8);
        q.push("a", 1, 1, 0, 0).unwrap();
        q.push("a", 2, 1, 0, 0).unwrap();
        q.push("a", 3, 2, 0, 0).unwrap(); // higher priority, submitted last
        q.push("a", 4, 1, 0, 0).unwrap();
        assert_eq!(q.pop(), Some(3), "priority beats submission order");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn fair_interleave_across_clients() {
        let mut q = q(8);
        for id in 1..=3 {
            q.push("a", id, 1, 0, 0).unwrap();
        }
        for id in 11..=13 {
            q.push("b", id, 1, 0, 0).unwrap();
        }
        // Equal clocks: the name tie-break starts with a, then strict
        // alternation — neither client gets two pops in a row while the
        // other has work.
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![1, 11, 2, 12, 3, 13]);
    }

    #[test]
    fn weight_doubles_share() {
        let mut q = q(8);
        q.set_weight("heavy", 2);
        for id in 1..=4 {
            q.push("heavy", id, 1, 0, 0).unwrap();
        }
        for id in 11..=12 {
            q.push("light", id, 1, 0, 0).unwrap();
        }
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
        // weight 2 charges half per pop: heavy dispatches twice per light
        // dispatch (ties by name: "heavy" < "light").
        assert_eq!(order, vec![1, 2, 11, 3, 4, 12]);
    }

    #[test]
    fn slot_cap_blocks_until_complete() {
        let mut q = q(1);
        q.push("a", 1, 1, 0, 0).unwrap();
        q.push("a", 2, 1, 0, 0).unwrap();
        q.push("b", 3, 1, 0, 0).unwrap();
        assert_eq!(q.pop(), Some(1));
        // a is at its cap: b runs next even though a submitted first.
        assert_eq!(q.pop(), Some(3));
        // Both at cap now: job 2 must wait for a slot release.
        assert_eq!(q.pop(), None);
        assert_eq!(q.len(), 1);
        q.complete("a");
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn admission_caps_reject_with_typed_busy() {
        let mut q = FairQueue::new(QueueLimits {
            per_client_queued: 2,
            global_queued: 3,
            per_client_active: 1,
        });
        q.push("a", 1, 1, 0, 0).unwrap();
        q.push("a", 2, 1, 0, 0).unwrap();
        assert_eq!(q.push("a", 3, 1, 0, 0), Err(Busy::Client { queued: 2, cap: 2 }));
        q.push("b", 4, 1, 0, 0).unwrap();
        assert_eq!(q.push("b", 5, 1, 0, 0), Err(Busy::Global { queued: 3, cap: 3 }));
        // Draining one entry reopens admission.
        assert_eq!(q.pop(), Some(1));
        q.push("b", 5, 1, 0, 0).unwrap();
    }

    #[test]
    fn deadlines_expire_before_dispatch() {
        let mut q = q(8);
        q.push("a", 1, 1, 100, 1000).unwrap(); // expires after t=1100
        q.push("a", 2, 1, 0, 1000).unwrap(); // no deadline
        assert_eq!(q.expire(1100), Vec::<u64>::new(), "deadline instant itself still valid");
        assert_eq!(q.expire(1101), vec![1]);
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn cancel_and_position() {
        let mut q = q(8);
        q.push("a", 1, 1, 0, 0).unwrap();
        q.push("b", 2, 2, 0, 0).unwrap();
        q.push("a", 3, 1, 0, 0).unwrap();
        // Priority-2 job 2 orders before both priority-1 jobs.
        assert_eq!(q.position(2), Some(0));
        assert_eq!(q.position(1), Some(1));
        assert_eq!(q.position(3), Some(2));
        assert!(q.cancel(1));
        assert!(!q.cancel(1), "already removed");
        assert!(!q.cancel(99), "never queued");
        assert_eq!(q.position(3), Some(1));
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn idle_client_does_not_bank_credit() {
        let mut q = q(8);
        // a dispatches 3 jobs while b is absent.
        for id in 1..=3 {
            q.push("a", id, 1, 0, 0).unwrap();
        }
        assert_eq!((q.pop(), q.pop(), q.pop()), (Some(1), Some(2), Some(3)));
        for c in ["a", "a", "a"] {
            q.complete(c);
        }
        // b arrives with a backlog; its clock catches up to a's — it does
        // NOT get 3 consecutive pops of "owed" service.
        q.push("a", 4, 1, 0, 0).unwrap();
        q.push("a", 5, 1, 0, 0).unwrap();
        q.push("b", 11, 1, 0, 0).unwrap();
        q.push("b", 12, 1, 0, 0).unwrap();
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(order, vec![4, 11, 5, 12]);
    }

    #[test]
    fn depths_report_queued_and_active() {
        let mut q = q(2);
        q.push("a", 1, 1, 0, 0).unwrap();
        q.push("a", 2, 1, 0, 0).unwrap();
        q.push("b", 3, 1, 0, 0).unwrap();
        assert_eq!(q.pop(), Some(1));
        let depths = q.depths();
        assert_eq!(
            depths,
            vec![
                ClientDepth { client: "a".into(), queued: 1, active: 1 },
                ClientDepth { client: "b".into(), queued: 1, active: 0 },
            ]
        );
        assert_eq!(q.active_total(), 1);
    }
}
