//! Deterministic *network*-fault injection for the process fabric
//! (DESIGN.md §15).
//!
//! [`crate::util::fault`] can only kill a worker process; this module
//! breaks its **network** instead, which is the failure class heartbeat
//! leases exist to detect — a rank that is hung, partitioned, or silently
//! discarding traffic, while its process stays alive and its sockets stay
//! open (no EOF ever fires). A [`NetFaultPlan`] travels as one CLI/env
//! token,
//!
//! ```text
//! rank=R,kind=K,phase=P,after=N      K := stall|drop|corrupt|partition
//! ```
//!
//! mirroring the `--fault-inject` grammar, and is scripted by **frame
//! counts, not wall time**: the plan arms during phase epoch `phase` and
//! fires at the armed rank's `N`-th data-plane frame send of that epoch
//! (`PEERMSG` on the mesh plane, `RELAY` on the hub plane) — so a chaos
//! run is reproducible bit-for-bit. The four kinds:
//!
//! - `stall`: the worker stops reading *and* writing — the main thread
//!   parks at the send site and the reader thread parks too, so `PING`s
//!   pile up unread. Liveness must come from the hub's lease table.
//! - `partition`: the main thread parks at the send site but the reader
//!   keeps absorbing. The hub link stays open and `PING`s keep arriving —
//!   but `PONG`s are answered by the *main* thread (whole-worker
//!   liveness), so the lease still expires.
//! - `drop`: sever the worker→hub direction only. The worker keeps
//!   mining; every hub-bound frame (checkpoints, the merge, `PONG`s) is
//!   silently discarded.
//! - `corrupt`: flip the tag byte of the next hub-bound frame. The hub's
//!   route thread gets a decode error on an established stream, which
//!   must become that one rank's `Gone` — never a poisoned fleet.
//!
//! The state here is process-global (one armed plan per worker process,
//! set from `__worker`'s argv/environment); the fabric layer consults the
//! decision functions at its frame-write sites and performs the actual
//! parking/logging so this module stays below `wire` in the layer map.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

/// Environment variable consulted by `__worker` when no `--net-fault`
/// argument is present (same `rank=R,kind=K,phase=P,after=N` grammar).
pub const NET_FAULT_ENV: &str = "PARLAMP_NET_FAULT";

/// The four scripted network-fault classes (DESIGN.md §15).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetFaultKind {
    /// Stop reading and writing: the classic hung rank.
    Stall,
    /// Sever worker→hub writes; the worker keeps mining into the void.
    Drop,
    /// Flip the tag byte of the next hub-bound frame.
    Corrupt,
    /// Park the main thread (mesh links dead) while the reader keeps the
    /// hub link warm.
    Partition,
}

impl NetFaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            NetFaultKind::Stall => "stall",
            NetFaultKind::Drop => "drop",
            NetFaultKind::Corrupt => "corrupt",
            NetFaultKind::Partition => "partition",
        }
    }

    fn parse(s: &str) -> Result<NetFaultKind> {
        match s {
            "stall" => Ok(NetFaultKind::Stall),
            "drop" => Ok(NetFaultKind::Drop),
            "corrupt" => Ok(NetFaultKind::Corrupt),
            "partition" => Ok(NetFaultKind::Partition),
            other => bail!("unknown net fault kind '{other}' (stall|drop|corrupt|partition)"),
        }
    }
}

/// One planned network fault: break `rank`'s network per `kind` at its
/// `after`-th data-plane frame send during phase epoch `phase`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct NetFaultPlan {
    /// Worker rank whose network breaks.
    pub rank: usize,
    /// What breaks.
    pub kind: NetFaultKind,
    /// Fleet phase epoch (0-based, hub-assigned) during which the plan
    /// arms; frames sent in any other epoch neither count nor fire.
    pub phase: u64,
    /// Fires at the rank's `after`-th data-plane frame send of that epoch
    /// (1-based; that send is the first affected one).
    pub after: u64,
}

impl NetFaultPlan {
    /// Parse the `rank=R,kind=K,phase=P,after=N` spelling (fields in any
    /// order, all four required).
    pub fn parse(s: &str) -> Result<NetFaultPlan> {
        let (mut rank, mut kind, mut phase, mut after) = (None, None, None, None);
        for field in s.split(',').filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .with_context(|| format!("net fault field '{field}' is not key=value"))?;
            match key.trim() {
                "rank" => {
                    rank = Some(value.trim().parse::<usize>().with_context(|| {
                        format!("net fault rank '{value}' is not an unsigned integer")
                    })?);
                }
                "kind" => kind = Some(NetFaultKind::parse(value.trim())?),
                "phase" => {
                    phase = Some(value.trim().parse::<u64>().with_context(|| {
                        format!("net fault phase '{value}' is not an unsigned integer")
                    })?);
                }
                "after" => {
                    after = Some(value.trim().parse::<u64>().with_context(|| {
                        format!("net fault after '{value}' is not an unsigned integer")
                    })?);
                }
                other => bail!("unknown net fault field '{other}' (rank|kind|phase|after)"),
            }
        }
        let miss = "net fault plan is missing";
        let form = "(rank=R,kind=K,phase=P,after=N)";
        Ok(NetFaultPlan {
            rank: rank.with_context(|| format!("{miss} rank= {form}"))?,
            kind: kind.with_context(|| format!("{miss} kind= {form}"))?,
            phase: phase.with_context(|| format!("{miss} phase= {form}"))?,
            after: after.with_context(|| format!("{miss} after= {form}"))?,
        })
    }
}

impl std::fmt::Display for NetFaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "rank={},kind={},phase={},after={}",
            self.rank,
            self.kind.name(),
            self.phase,
            self.after
        )
    }
}

impl std::str::FromStr for NetFaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<NetFaultPlan> {
        NetFaultPlan::parse(s)
    }
}

// ---------------------------------------------------------------------------
// Armed state (process-global; one worker process arms at most one plan)
// ---------------------------------------------------------------------------

static PLAN: Mutex<Option<NetFaultPlan>> = Mutex::new(None);
/// Fast-path gate so the unarmed case (production) costs one relaxed load.
static ARMED: AtomicBool = AtomicBool::new(false);
/// Data-plane frames sent during the armed epoch.
static FRAMES: AtomicU64 = AtomicU64::new(0);
/// One-shot latch: a plan fires exactly once.
static FIRED: AtomicBool = AtomicBool::new(false);
/// `stall` fired: the reader thread must park too.
static STALLED: AtomicBool = AtomicBool::new(false);
/// `drop` fired: hub-bound frame writes are silently discarded.
static DROP_HUB: AtomicBool = AtomicBool::new(false);
/// `corrupt` fired: the next hub-bound frame write flips its tag byte.
static CORRUPT_NEXT: AtomicBool = AtomicBool::new(false);

/// Arm `plan` for this process. Called once from `__worker` startup, and
/// only when the plan names the worker's own rank (a plan naming another
/// rank is inert, exactly like `--fault-inject`).
pub fn arm(plan: NetFaultPlan) {
    *PLAN.lock().unwrap() = Some(plan);
    ARMED.store(true, Ordering::Release);
}

/// Consulted by the fabric at every data-plane frame send (`PEERMSG` on
/// the mesh plane, `RELAY` on the hub plane) with the sender's current
/// phase epoch. Counts matching-epoch sends; returns the plan exactly
/// once, at the `after`-th such send — the caller logs the firing and
/// performs the kind's action (parking for stall/partition; drop/corrupt
/// latch here and apply at the hub-write sites).
pub fn on_data_frame(epoch: u64) -> Option<NetFaultPlan> {
    if !ARMED.load(Ordering::Acquire) || FIRED.load(Ordering::Acquire) {
        return None;
    }
    let plan = (*PLAN.lock().unwrap())?;
    if epoch != plan.phase {
        return None;
    }
    let sent = FRAMES.fetch_add(1, Ordering::AcqRel) + 1;
    if sent < plan.after.max(1) {
        return None;
    }
    if FIRED.swap(true, Ordering::AcqRel) {
        return None;
    }
    match plan.kind {
        NetFaultKind::Stall => STALLED.store(true, Ordering::Release),
        NetFaultKind::Drop => DROP_HUB.store(true, Ordering::Release),
        NetFaultKind::Corrupt => CORRUPT_NEXT.store(true, Ordering::Release),
        NetFaultKind::Partition => {}
    }
    Some(plan)
}

/// `true` once a `stall` plan fired: the fabric's reader thread parks
/// instead of reading, so the hub's `PING`s stay unread in the socket
/// buffer (they are a few bytes each — they never fill it before the
/// lease expires).
pub fn stalled() -> bool {
    STALLED.load(Ordering::Acquire)
}

/// What the fabric must do with a hub-bound frame write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HubWrite {
    /// No fault (or none that touches this direction): write normally.
    Forward,
    /// A `drop` plan fired: discard the frame, report success.
    Discard,
    /// A `corrupt` plan fired: flip the frame's tag byte, then write.
    /// One-shot — returned exactly once.
    Corrupt,
}

/// Consulted by the fabric before every hub-bound frame write
/// (checkpoints, merges, trace flushes, `PONG`s, hub-plane relays).
pub fn hub_write() -> HubWrite {
    if !ARMED.load(Ordering::Acquire) {
        return HubWrite::Forward;
    }
    if DROP_HUB.load(Ordering::Acquire) {
        return HubWrite::Discard;
    }
    if CORRUPT_NEXT.swap(false, Ordering::AcqRel) {
        return HubWrite::Corrupt;
    }
    HubWrite::Forward
}

/// Corrupt an encoded frame in place by flipping its tag byte (the byte
/// right after the 4-byte little-endian length prefix). Every frame tag
/// lives well below `0x80`, so the flipped value can never collide with a
/// valid tag: the receiver's decode fails deterministically with an
/// "unknown frame tag" error instead of a silently-wrong payload.
pub fn corrupt_frame_bytes(bytes: &mut [u8]) {
    if bytes.len() > 4 {
        bytes[4] ^= 0xFF;
    }
}

/// Park the calling thread forever — the body of a fired `stall` or
/// `partition`. The process stays alive (no EOF anywhere); only the hub's
/// heartbeat lease can notice, which is the point. The force-kill that
/// follows lease expiry is what ends the process.
pub fn park_forever() -> ! {
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

#[cfg(test)]
fn reset() {
    *PLAN.lock().unwrap() = None;
    ARMED.store(false, Ordering::Release);
    FRAMES.store(0, Ordering::Release);
    FIRED.store(false, Ordering::Release);
    STALLED.store(false, Ordering::Release);
    DROP_HUB.store(false, Ordering::Release);
    CORRUPT_NEXT.store(false, Ordering::Release);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for kind in [
            NetFaultKind::Stall,
            NetFaultKind::Drop,
            NetFaultKind::Corrupt,
            NetFaultKind::Partition,
        ] {
            let plan = NetFaultPlan { rank: 2, kind, phase: 1, after: 4096 };
            assert_eq!(NetFaultPlan::parse(&plan.to_string()).unwrap(), plan);
        }
        // Any field order parses; whitespace around fields is tolerated.
        assert_eq!(
            NetFaultPlan::parse("after=7, kind=partition ,rank=2,phase=1").unwrap(),
            NetFaultPlan { rank: 2, kind: NetFaultKind::Partition, phase: 1, after: 7 }
        );
        assert_eq!(
            "rank=0,kind=stall,phase=0,after=0".parse::<NetFaultPlan>().unwrap().after,
            0
        );
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for bad in [
            "",
            "rank=1,phase=0,after=1",              // missing kind
            "rank=1,kind=stall,phase=0",           // missing after
            "rank=1,kind=sever,phase=0,after=1",   // unknown kind
            "rank=x,kind=stall,phase=0,after=1",   // non-numeric
            "rank=1,kind=stall,phase=0,after=1,bogus=2", // unknown field
            "rank,kind=stall,phase=0,after=1",     // not key=value
        ] {
            assert!(NetFaultPlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    /// The armed-state machine, end to end in one test (the state is
    /// process-global, so all its assertions live in one serial body).
    #[test]
    fn armed_plan_counts_frames_and_fires_once() {
        reset();
        // Unarmed: every site is a no-op.
        assert_eq!(on_data_frame(0), None);
        assert_eq!(hub_write(), HubWrite::Forward);
        assert!(!stalled());

        // Drop: fires at the 3rd matching-epoch frame, exactly once.
        let plan = NetFaultPlan { rank: 1, kind: NetFaultKind::Drop, phase: 2, after: 3 };
        arm(plan);
        assert_eq!(on_data_frame(1), None, "wrong epoch must not count");
        assert_eq!(on_data_frame(2), None);
        assert_eq!(on_data_frame(2), None);
        assert_eq!(on_data_frame(2), Some(plan), "third matching frame fires");
        assert_eq!(on_data_frame(2), None, "a plan fires exactly once");
        assert_eq!(hub_write(), HubWrite::Discard);
        assert_eq!(hub_write(), HubWrite::Discard, "drop is sticky");
        assert!(!stalled());

        // Corrupt: one-shot at the hub-write site.
        reset();
        arm(NetFaultPlan { rank: 0, kind: NetFaultKind::Corrupt, phase: 0, after: 1 });
        assert!(on_data_frame(0).is_some());
        assert_eq!(hub_write(), HubWrite::Corrupt);
        assert_eq!(hub_write(), HubWrite::Forward, "corrupt applies to one frame");

        // Stall: flips the reader-park flag; hub writes unaffected (the
        // main thread parks before ever reaching a hub-write site).
        reset();
        arm(NetFaultPlan { rank: 0, kind: NetFaultKind::Stall, phase: 0, after: 1 });
        assert!(on_data_frame(0).is_some());
        assert!(stalled());
        assert_eq!(hub_write(), HubWrite::Forward);
        reset();
    }

    #[test]
    fn corrupt_flips_the_tag_byte_only() {
        let mut bytes = vec![5, 0, 0, 0, 0x0A, 1, 2, 3, 4];
        let orig = bytes.clone();
        corrupt_frame_bytes(&mut bytes);
        assert_eq!(bytes[4], 0x0A ^ 0xFF);
        assert_eq!(bytes[..4], orig[..4], "length prefix untouched");
        assert_eq!(bytes[5..], orig[5..], "payload untouched");
        // Degenerate inputs are left alone rather than panicking.
        let mut short = vec![1, 0, 0, 0];
        corrupt_frame_bytes(&mut short);
        assert_eq!(short, vec![1, 0, 0, 0]);
    }
}
