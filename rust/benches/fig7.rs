//! Fig. 7: breakdown of total CPU time (summed over processes) into
//! preprocess / main / probe / idle, per process count (paper §5.2).
//!
//! Run: `cargo bench --bench fig7 [-- --quick]`

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::par::{breakdown, run_sim, RunMode, SimConfig};
use parlamp::util::bench_harness::{quick_mode, BenchSet};

fn main() {
    let quick = quick_mode();
    let procs: Vec<usize> =
        if quick { vec![1, 12, 96, 600] } else { vec![1, 12, 24, 48, 96, 192, 300, 600, 1200] };
    for sc in all_scenarios(quick) {
        let db = sc.build();
        let cal = calibrate_lamp(&db, parlamp::DEFAULT_ALPHA);
        let mut set = BenchSet::new(
            &format!("Fig 7 — total CPU time breakdown, {} (seconds)", sc.name),
            &["P", "preprocess", "main", "probe", "idle", "total"],
        );
        for &p in &procs {
            let cfg = SimConfig { p, ..SimConfig::calibrated(p, &cal) };
            let out = run_sim(&db, RunMode::Phase1 { alpha: parlamp::DEFAULT_ALPHA }, &cfg);
            let b = breakdown::sum(&out.breakdowns);
            let [pre, main, probe, idle] = b.as_secs();
            set.row(vec![
                p.to_string(),
                format!("{pre:.4}"),
                format!("{main:.4}"),
                format!("{probe:.4}"),
                format!("{idle:.4}"),
                format!("{:.4}", pre + main + probe + idle),
            ]);
        }
        set.finish();
    }
}
