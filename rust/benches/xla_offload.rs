//! XLA screen offload vs native rust phase 3: batched PJRT execution
//! throughput and end-to-end phase-3 comparison (the L1/L2 path on the
//! request side). Skips (cleanly) when artifacts are missing.
//!
//! Run: `make artifacts && cargo bench --bench xla_offload`

use parlamp::bits::BitVec;
use parlamp::datagen::{generate_gwas, GwasSpec};
use parlamp::lamp::{lamp_serial, phase3_extract};
use parlamp::runtime::{
    artifacts_available, artifacts_dir, phase3_extract_xla, ScreenEngine, XlaRuntime,
};
use parlamp::stats::{FisherTable, Marginals};
use parlamp::util::bench_harness::{bench, time_once, BenchSet};
use parlamp::util::rng::Rng;

fn main() {
    if !artifacts_available() {
        println!("SKIP xla_offload: artifacts/ missing — run `make artifacts`");
        return;
    }
    // In default (stub) builds the loader fails even with artifacts
    // present; skip rather than panic (build with `--features xla`).
    let rt = match XlaRuntime::load(&artifacts_dir()) {
        Ok(rt) => rt,
        Err(e) => {
            println!("SKIP xla_offload: {e:#}");
            return;
        }
    };
    let engine = ScreenEngine::new(rt);
    let man = engine.runtime().manifest();
    println!(
        "platform={} artifact: K={} W={} T_MAX={}",
        engine.runtime().platform(),
        man.k,
        man.w,
        man.t_max
    );

    let mut set =
        BenchSet::new("XLA offload — batched significance screen", &["bench", "mean ± sd", "rate"]);
    let n = 500usize;
    let m = Marginals::new(n as u32, 120);
    let mut rng = Rng::new(11);
    let pos = BitVec::from_indices(n, 0..120);
    let rows: Vec<BitVec> = (0..man.k)
        .map(|_| BitVec::from_indices(n, (0..n).filter(|_| rng.bernoulli(0.1))))
        .collect();

    // Full batch through PJRT.
    let s = bench(2, 10, || engine.score(&rows, &pos, m).unwrap().len());
    set.row(vec![
        format!("xla screen batch (K={})", man.k),
        s.display(),
        format!("{:.0} cand/s", man.k as f64 / s.mean_s),
    ]);

    // Native equivalent.
    let fisher = FisherTable::new(m);
    let s2 = bench(2, 10, || {
        let mut acc = 0.0f64;
        for r in &rows {
            let x = r.count();
            let nobs = r.and_count(&pos);
            acc += fisher.log_p_value(x, nobs);
        }
        acc
    });
    set.row(vec![
        format!("native screen batch (K={})", man.k),
        s2.display(),
        format!("{:.0} cand/s", man.k as f64 / s2.mean_s),
    ]);
    set.finish();

    // End-to-end phase 3 on a GWAS-like problem.
    let (db, _) = generate_gwas(&GwasSpec {
        n_snps: 400,
        n_individuals: 180,
        n_pos: 45,
        planted: vec![(3, 0.85)],
        ..GwasSpec::small(5)
    });
    let res = lamp_serial(&db, 0.05);
    let (t_native, native) =
        time_once(|| phase3_extract(&db, res.min_sup, res.correction_factor, 0.05));
    let (t_xla, xla) = time_once(|| {
        phase3_extract_xla(&engine, &db, res.min_sup, res.correction_factor, 0.05).unwrap()
    });
    assert_eq!(native.len(), xla.len(), "paths must agree");
    println!(
        "phase-3 end-to-end: native {:.4}s vs xla {:.4}s ({} significant patterns)",
        t_native,
        t_xla,
        native.len()
    );
}
