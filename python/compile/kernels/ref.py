"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness baseline: simple, obviously-correct jax.numpy
implementations of (a) packed-bitmap popcount support counting and (b) the
one-sided Fisher exact test / Tarone minimum-achievable-P bound. pytest
asserts the Pallas kernels match these (and scipy independently checks the
statistics).
"""

import jax.numpy as jnp


def popcount_u32(v):
    """SWAR population count of a uint32 array (reference form)."""
    v = v.astype(jnp.uint32)
    v = v - ((v >> 1) & jnp.uint32(0x55555555))
    v = (v & jnp.uint32(0x33333333)) + ((v >> 2) & jnp.uint32(0x33333333))
    v = (v + (v >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((v * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def support_counts_ref(occ_words, pos_words):
    """Support and positive-class support of K packed candidate bitmaps.

    occ_words: (K, W) uint32 — occurrence bitmaps, little-endian packing.
    pos_words: (W,) uint32 — positive-class mask.
    Returns (x, n): each (K,) int32.
    """
    x = popcount_u32(occ_words).sum(axis=1, dtype=jnp.int32)
    n = popcount_u32(occ_words & pos_words[None, :]).sum(axis=1, dtype=jnp.int32)
    return x, n


def _log_choose(a, b):
    """ln C(a, b) via lgamma, elementwise; caller guarantees 0 <= b <= a."""
    from jax.scipy.special import gammaln

    return gammaln(a + 1.0) - gammaln(b + 1.0) - gammaln(a - b + 1.0)


def fisher_logp_ref(x, n, n_total, n_pos, t_max):
    """One-sided Fisher exact test, log P-value (f64 reference).

    P = sum_{k=n}^{min(x, n_pos)} C(n_pos,k) C(n_total-n_pos, x-k) / C(n_total, x)

    evaluated as a masked fixed-length (t_max) tail in log space.
    x, n: (K,) arrays; n_total, n_pos: scalars; returns (K,) float64 (<= 0).
    Entries with x == 0 get log P = 0 (P = 1).
    """
    x = x.astype(jnp.float64)
    n = n.astype(jnp.float64)
    N = jnp.float64(n_total)
    Np = jnp.float64(n_pos)
    ks = n[:, None] + jnp.arange(t_max, dtype=jnp.float64)[None, :]  # (K, T)
    hi = jnp.minimum(x, Np)[:, None]
    lo_support = jnp.maximum(x - (N - Np), 0.0)[:, None]
    valid = (ks <= hi) & (ks >= lo_support) & ((x[:, None] - ks) >= 0)
    ks_c = jnp.clip(ks, 0.0, None)
    xk = jnp.clip(x[:, None] - ks_c, 0.0, None)
    log_term = (
        _log_choose(Np, jnp.minimum(ks_c, Np))
        + _log_choose(N - Np, jnp.minimum(xk, N - Np))
        - _log_choose(N, x)[:, None]
    )
    log_term = jnp.where(valid, log_term, -jnp.inf)
    m = jnp.max(log_term, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    logp = jnp.squeeze(m, 1) + jnp.log(jnp.sum(jnp.exp(log_term - m), axis=1))
    # x == 0 (or an empty tail) means P = 1.
    logp = jnp.where(x <= 0, 0.0, logp)
    return jnp.minimum(logp, 0.0)


def tarone_logf_ref(x, n_total, n_pos):
    """Tarone minimum-achievable log P, ln f(x) (f64 reference).

    f(x) = C(n_pos, x)/C(n_total, x) for x <= n_pos, else the
    all-positives-inside bound C(n_total-n_pos, x-n_pos)/C(n_total, x);
    f(0) = 1.
    """
    x = x.astype(jnp.float64)
    N = jnp.float64(n_total)
    Np = jnp.float64(n_pos)
    low = _log_choose(Np, jnp.minimum(x, Np)) - _log_choose(N, x)
    high = _log_choose(N - Np, jnp.clip(x - Np, 0.0, None)) - _log_choose(N, x)
    logf = jnp.where(x <= Np, low, high)
    return jnp.where(x <= 0, 0.0, jnp.minimum(logf, 0.0))
