//! Thread engine: one OS thread per process, real wall-clock time.
//!
//! This is the configuration the paper runs on a single compute node
//! (§5.3, the `t₁₂` column of Table 1): MPI communication degenerates to a
//! memory copy. The container this reproduction runs in has a single
//! physical core, so wall-clock *speedup* is measured with the DES engine;
//! this engine demonstrates protocol correctness under true concurrency
//! and OS-scheduling nondeterminism.

use std::time::{Duration, Instant};

use crate::db::Database;

use super::engine_sim::collect;
use super::worker::{Poll, RunMode, Worker, WorkerConfig};
use super::ParRunResult;

/// Run one phase on `p` OS threads. `steal = false` gives the naive
/// baseline. Blocking waits cap at 200 µs so DTD waves keep flowing.
pub fn run_threads(db: &Database, mode: RunMode, p: usize, steal: bool, seed: u64) -> ParRunResult {
    assert!(p >= 1);
    let boxes = crate::fabric::thread::thread_fabric(p);
    let t0 = Instant::now();
    let workers: Vec<Worker> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (rank, mut mb) in boxes.into_iter().enumerate() {
            let cfg = WorkerConfig {
                ns_per_unit: None, // real time
                steal,
                preprocess: p > 1,
                ..WorkerConfig::paper_defaults(rank, p, mode, seed)
            };
            let mut worker = Worker::new(db, cfg);
            handles.push(scope.spawn(move || {
                let t0 = Instant::now();
                loop {
                    let now_ns = t0.elapsed().as_nanos() as u64;
                    match worker.poll(&mut mb, now_ns) {
                        Poll::Busy { .. } => {}
                        Poll::Idle { wake_at } => {
                            let cap = Duration::from_micros(200);
                            let d = match wake_at {
                                Some(t) => {
                                    Duration::from_nanos(t.saturating_sub(now_ns)).min(cap)
                                }
                                None => cap,
                            };
                            if !d.is_zero() {
                                mb.wait_for_msg(d);
                            }
                        }
                        Poll::Finished => break,
                    }
                }
                worker
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let makespan_ns = t0.elapsed().as_nanos() as u64;
    collect(db, workers, makespan_ns, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lamp::{lamp_serial, SupportIncreaseRule};
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, m: usize, n: usize, density: f64) -> Database {
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t < n / 3).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    #[test]
    fn threads_phase1_matches_serial() {
        let mut rng = Rng::new(21);
        for p in [1usize, 2, 4] {
            let db = random_db(&mut rng, 12, 30, 0.4);
            let serial = lamp_serial(&db, 0.05);
            let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
            let mut got = run_threads(&db, RunMode::Phase1 { alpha: 0.05 }, p, true, 42);
            got.finalize_phase1(&rule);
            assert_eq!(got.lambda_final, serial.lambda_final, "p={p}");
            let p2 = run_threads(&db, RunMode::Count { min_sup: got.min_sup }, p, true, 43);
            assert_eq!(p2.closed_total, serial.correction_factor, "p={p}");
        }
    }

    #[test]
    fn threads_naive_matches_serial_counts() {
        let mut rng = Rng::new(31);
        let db = random_db(&mut rng, 10, 26, 0.5);
        let serial = lamp_serial(&db, 0.05);
        let p2 = run_threads(&db, RunMode::Count { min_sup: serial.min_sup }, 3, false, 7);
        assert_eq!(p2.closed_total, serial.correction_factor);
        assert_eq!(p2.comm.gives, 0);
    }
}
