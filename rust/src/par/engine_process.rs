//! Process engine: one OS process per rank, real wall-clock time.
//!
//! The third engine, and the first with true distributed memory: where
//! [`super::engine_thread`] shares one address space and [`super::engine_sim`]
//! shares one event loop, this engine runs each rank as a separate worker
//! process connected to a parent [`Hub`] over a stream transport — a
//! Unix-domain socket by default, loopback or cross-host TCP when the hub
//! is given a `tcp:` [`Endpoint`] (DESIGN.md §11) — speaking the
//! [`crate::wire`] protocol (DESIGN.md §7). Every steal, DTD wave, and
//! phase-boundary merge of the paper's §4 protocol therefore crosses a real
//! serialization boundary — the configuration the paper's MPI runs assume,
//! minus (on one host) only the physical network.
//!
//! Workers join in one of two ways, decided by
//! [`ProcessConfig::remote_workers`]:
//!
//! - **local spawn** (the default): the parent forks `P` children of the
//!   `parlamp` binary pointed at the hub endpoint;
//! - **remote attach** (`--hosts`): the parent only *binds* — via the
//!   two-phase [`ProcessFleet::bind`] / [`PendingFleet::await_workers`]
//!   API — and prints per-rank join commands
//!   (`parlamp __worker --connect <endpoint> --token <T> …`) for workers
//!   started by hand (or by a launcher) on other machines. The shared
//!   fleet token keeps stray TCP connections out.
//!
//! The central abstraction is the **warm fleet** ([`ProcessFleet`]): spawn
//! the worker processes once, then run any number of phases — and any
//! number of *jobs* — across them. A phase over a database the workers
//! already hold ships only a `RECONFIG` (~60 bytes) instead of the
//! serialized database; [`crate::db::Database::digest`] decides. This is
//! what lets `parlamp serve` (DESIGN.md §9) answer a stream of requests
//! without paying spawn + handshake + data-ship per request, and it also
//! halves the data shipped by a one-shot coordinated run (phase 2 reuses
//! phase 1's database).
//!
//! The parent (this module) is the coordinator side: it spawns and
//! supervises the worker fleet, owns the control plane (and, under
//! `--data-plane hub`, relays the data plane too — under the default mesh
//! plane the workers exchange steal traffic and DTD waves directly,
//! DESIGN.md §10), collects the per-rank merges into a [`ParRunResult`],
//! and tears the fleet down. The
//! child side is [`worker_main`], reached through the hidden `__worker`
//! CLI entry point — worker processes re-execute the `parlamp` binary (or
//! whatever [`ProcessConfig::worker_exe`] / `$PARLAMP_WORKER_EXE` names,
//! for callers that are not the binary).

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::db::Database;
use crate::fabric::process::{connect, DataPlane, Hub, HubEvent};
use crate::fabric::CommStats;
use crate::net::fault::{self as netfault, NetFaultPlan, NET_FAULT_ENV};
use crate::net::{fresh_token, Endpoint};
use crate::obs::clock::{self, estimate_offset, HandshakeSample};
use crate::obs::log::{self, Tags};
use crate::obs::trace::{self as obs_trace, EventKind as TraceEv, RankTrace, TraceEvent, TraceRing};
use crate::util::fault::{FaultPlan, FAULT_ENV, FAULT_EXIT_CODE};
use crate::util::sig;
use crate::wire::trace::TraceChunk;
use crate::wire::{PhaseSpec, RunSpec, WorkerMerge};

use super::breakdown::Breakdown;
use super::worker::{Poll, RunMode, Worker, WorkerConfig};
use super::ParRunResult;

/// Environment variable overriding the worker executable, for callers that
/// are not themselves the `parlamp` binary (e.g. scripts embedding the
/// library). In-process callers should prefer the race-free
/// [`ProcessConfig::worker_exe`] field — the integration tests point it at
/// `CARGO_BIN_EXE_parlamp`.
pub const WORKER_EXE_ENV: &str = "PARLAMP_WORKER_EXE";

/// Knobs for process-engine phases: the [`super::engine_thread::ThreadConfig`]
/// surface plus process-spawn controls.
#[derive(Clone, Debug)]
pub struct ProcessConfig {
    pub p: usize,
    /// Random steal attempts `w` (paper: 1).
    pub w: usize,
    /// Hypercube edge length `l` (paper: 2).
    pub l: usize,
    /// DTD spanning-tree arity (paper: 3).
    pub tree_arity: usize,
    /// `false` = naive baseline (no stealing).
    pub steal: bool,
    /// Depth-1 preprocess partition (§4.5).
    pub preprocess: bool,
    /// Record per-rank event traces and flush them to the hub with each
    /// merge (DESIGN.md §14). Carried to the workers in the `PhaseSpec`;
    /// off by default — tracing must cost nothing when unused.
    pub trace: bool,
    /// Work budget between probes, in expansion cost units (§4.6).
    pub probe_budget_units: u64,
    pub dtd_interval_ns: u64,
    pub seed: u64,
    /// Worker executable; when `None`, `$PARLAMP_WORKER_EXE` is consulted
    /// and then the current executable (correct when the caller *is* the
    /// `parlamp` binary).
    pub worker_exe: Option<PathBuf>,
    /// How long to wait for the whole fleet to spawn and handshake.
    pub spawn_timeout: Duration,
    /// Which topology carries steal traffic and DTD waves: direct
    /// worker-to-worker sockets (`Mesh`, the default) or the parent hub
    /// relay (`Hub`, the centralized baseline). A fleet property — fixed
    /// at [`ProcessFleet::spawn`] for the fleet's whole lifetime.
    pub data_plane: DataPlane,
    /// Where the hub listens. `None` (the default) binds a Unix socket in
    /// a fresh per-fleet temp directory; `Some(tcp:host:0)` asks the OS
    /// for an ephemeral TCP port (resolved in [`Hub::endpoint`]). An
    /// explicit `unix:` endpoint is honored as given — the caller owns the
    /// path's directory.
    pub listen: Option<Endpoint>,
    /// `Some(endpoints)` switches the fleet to **remote attach** mode: no
    /// children are spawned; the fleet instead waits for
    /// `len()` externally-launched `parlamp __worker --connect …` processes
    /// (overriding `p`). Entry `i` is rank `i`'s mesh data-plane listen
    /// endpoint, handed to that worker as `--peer-endpoint` in its join
    /// command.
    pub remote_workers: Option<Vec<Endpoint>>,
    /// Deterministic fault injection (DESIGN.md §12): kill the named rank
    /// at the planned point. Passed to the targeted worker's argv at spawn
    /// (`--fault-inject rank=R,phase=P,after=N`); respawned replacements
    /// never inherit it, so the fault fires exactly once. `None` in
    /// production; the chaos suite and the `--fault-inject` CLI flag set it.
    pub fault: Option<FaultPlan>,
    /// Deterministic *network*-fault injection (DESIGN.md §15): break the
    /// named rank's network (`stall`/`drop`/`corrupt`/`partition`) at a
    /// scripted data-plane frame count, while its process stays alive.
    /// Same propagation rules as `fault`: passed to the targeted worker's
    /// argv at spawn (`--net-fault rank=R,kind=K,phase=P,after=N`), never
    /// inherited by respawned replacements. `None` in production.
    pub net_fault: Option<NetFaultPlan>,
    /// Heartbeat lease window (v8, DESIGN.md §15): a mid-phase rank whose
    /// route thread has read no frame — `PONG` or otherwise — for this
    /// long is declared lost, force-killed, and respawned through the
    /// ordinary recovery path. Generous by default: a healthy worker
    /// answers pings from every blocking wait, so only a genuinely hung,
    /// partitioned, or write-severed rank ever ages this far.
    pub lease_timeout: Duration,
}

impl ProcessConfig {
    pub fn paper_defaults(p: usize, seed: u64) -> Self {
        ProcessConfig {
            p,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: true,
            trace: false,
            probe_budget_units: 4_000_000,
            dtd_interval_ns: 1_000_000,
            seed,
            worker_exe: None,
            spawn_timeout: Duration::from_secs(30),
            data_plane: DataPlane::Mesh,
            listen: None,
            remote_workers: None,
            fault: None,
            net_fault: None,
            lease_timeout: Duration::from_secs(60),
        }
    }

    /// World size: the remote host count in attach mode, `p` otherwise.
    pub fn world_size(&self) -> usize {
        match &self.remote_workers {
            Some(hosts) => hosts.len(),
            None => self.p,
        }
    }

    /// Copy of this config with fault injection disarmed — both the
    /// process-kill plan and the network-fault plan. The serve daemon's
    /// fleet pool arms an injected plan on fleet 0 only — every other
    /// fleet (and every whole-fleet rebuild) spawns from this copy, so a
    /// planned fault fires in exactly one place.
    pub fn without_fault(&self) -> ProcessConfig {
        ProcessConfig { fault: None, net_fault: None, ..self.clone() }
    }
}

/// Run one phase on `p` worker processes with the paper-default knobs.
pub fn run_process(db: &Database, mode: RunMode, p: usize, seed: u64) -> Result<ParRunResult> {
    run_process_with(db, mode, &ProcessConfig::paper_defaults(p, seed))
}

/// Ceiling on mid-phase recoveries before a phase is abandoned: protects
/// against a crash-looping worker binary (every respawn dies again) turning
/// [`ProcessFleet::run_phase`] into an infinite replay loop.
const MAX_PHASE_RECOVERIES: u32 = 8;

/// Typed failure classes of a fleet phase (DESIGN.md §15), carried through
/// `anyhow` so callers that must *react* to a class — the serve daemon
/// converts each into a failed-job reply plus a fleet rebuild — can
/// downcast instead of string-matching.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FleetError {
    /// The fleet never finished assembling: fewer than `p` workers
    /// completed the `HELLO` handshake within the spawn timeout.
    AssembleTimeout { connected: usize, p: usize },
    /// An external watchdog ([`AbortHandle::fire`]) declared this fleet
    /// wedged and aborted it mid-phase.
    WatchdogAbort,
    /// The phase was abandoned after [`MAX_PHASE_RECOVERIES`] mid-phase
    /// recoveries — a crash-looping worker binary, not a one-off death.
    RecoveryExhausted { rank: usize, detail: String },
}

impl std::fmt::Display for FleetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FleetError::AssembleTimeout { connected, p } => {
                write!(f, "timed out assembling worker fleet ({connected}/{p} workers joined)")
            }
            FleetError::WatchdogAbort => {
                write!(f, "fleet aborted by watchdog (phase exceeded its deadline)")
            }
            FleetError::RecoveryExhausted { rank, detail } => write!(
                f,
                "phase abandoned after {MAX_PHASE_RECOVERIES} recoveries; \
                 last death: rank {rank}: {detail}"
            ),
        }
    }
}

impl std::error::Error for FleetError {}

/// Handle a watchdog thread uses to abort a wedged fleet it does not own
/// (the serve daemon's per-job watchdog, DESIGN.md §15). [`AbortHandle::fire`]
/// sets the fleet's abort flag — checked between collection ticks, so the
/// phase surfaces [`FleetError::WatchdogAbort`] instead of respawn-looping —
/// and SIGKILLs the worker pids, which also frees any OS-level wait. The
/// flag is load-bearing: the kills alone would be indistinguishable from
/// crashes, and recovery might respawn every rank and let the phase succeed.
#[derive(Clone, Debug)]
pub struct AbortHandle {
    flag: Arc<AtomicBool>,
    pids: Vec<u32>,
}

impl AbortHandle {
    pub fn fire(&self) {
        self.flag.store(true, Ordering::SeqCst);
        for &pid in &self.pids {
            sig::kill_pid(pid, sig::SIGKILL);
        }
    }
}

/// Send a custody checkpoint to the hub roughly once per this many local
/// work units (DESIGN.md §12). Matches the probe budget's order of
/// magnitude: cheap enough to be off the critical path, frequent enough
/// that a `Gone` report's custody context is current.
const CHECKPOINT_EVERY_UNITS: u64 = 4_000_000;

/// How one phase *attempt* ended (see [`ProcessFleet::try_phase`]).
enum PhaseOutcome {
    /// Every rank's merge arrived; the phase result is final.
    Done(ParRunResult),
    /// A rank disconnected mid-attempt; the attempt is void (its partial
    /// merges carry the aborted epoch and will be fenced off).
    Lost { rank: usize, detail: String },
}

/// Kill-on-drop guard for the worker fleet: a parent error path must never
/// leak orphan miners. Keeps its spawn parameters so a single dead rank
/// can be respawned in place (DESIGN.md §12) without re-resolving the
/// executable through a config that may no longer name it.
struct Fleet {
    children: Vec<Child>,
    reaped: Vec<bool>,
    /// Spawn parameters, retained for [`Fleet::respawn`]. `None` exe =
    /// remote-attach fleet (nothing local to respawn).
    exe: Option<PathBuf>,
    hub: Option<Endpoint>,
    token: String,
}

impl Fleet {
    fn spawn_one(
        exe: &PathBuf,
        hub: &Endpoint,
        token: &str,
        rank: usize,
        fault: Option<&FaultPlan>,
        net_fault: Option<&NetFaultPlan>,
    ) -> Result<Child> {
        let mut cmd = Command::new(exe);
        cmd.arg("__worker")
            .arg("--connect")
            .arg(hub.to_string())
            .arg("--token")
            .arg(token)
            .arg("--worker-rank")
            .arg(rank.to_string())
            .stdin(Stdio::null());
        if let Some(plan) = fault {
            if plan.rank == rank {
                cmd.arg("--fault-inject").arg(plan.to_string());
            }
        }
        if let Some(plan) = net_fault {
            if plan.rank == rank {
                cmd.arg("--net-fault").arg(plan.to_string());
            }
        }
        cmd.spawn()
            .with_context(|| format!("spawn worker rank {rank} ({})", exe.display()))
    }

    fn spawn(
        exe: &PathBuf,
        hub: &Endpoint,
        token: &str,
        p: usize,
        fault: Option<&FaultPlan>,
        net_fault: Option<&NetFaultPlan>,
    ) -> Result<Fleet> {
        let mut children = Vec::with_capacity(p);
        for rank in 0..p {
            children.push(Self::spawn_one(exe, hub, token, rank, fault, net_fault)?);
        }
        Ok(Fleet {
            reaped: vec![false; p],
            children,
            exe: Some(exe.clone()),
            hub: Some(hub.clone()),
            token: token.to_string(),
        })
    }

    /// The remote-attach fleet: no children to supervise — liveness comes
    /// from the workers' hub connections alone.
    fn remote() -> Fleet {
        Fleet {
            reaped: Vec::new(),
            children: Vec::new(),
            exe: None,
            hub: None,
            token: String::new(),
        }
    }

    /// Non-blocking liveness check: a worker that already exited while the
    /// fleet is still being assembled is a fatal fault (nobody will
    /// recover a rank that never joined).
    fn check(&mut self) -> Result<()> {
        for (rank, child) in self.children.iter_mut().enumerate() {
            if self.reaped[rank] {
                continue;
            }
            if let Some(status) = child.try_wait().context("poll worker status")? {
                self.reaped[rank] = true;
                bail!("worker rank {rank} exited mid-run: {status}");
            }
        }
        Ok(())
    }

    /// Kill `rank`'s process outright and reap it. Idempotent. This is the
    /// lease-expiry teardown (DESIGN.md §15): the process may be perfectly
    /// alive — hung, partitioned, or mining into a severed socket — but
    /// its network is dead to the fleet, and the declared loss must become
    /// a real death before the slot is respawned.
    fn force_kill(&mut self, rank: usize) {
        if rank >= self.children.len() || self.reaped[rank] {
            return;
        }
        let _ = self.children[rank].kill();
        let _ = self.children[rank].wait();
        self.reaped[rank] = true;
    }

    /// Pids of the children not yet reaped, for [`AbortHandle`].
    fn pids(&self) -> Vec<u32> {
        self.children
            .iter()
            .enumerate()
            .filter(|(rank, _)| !self.reaped[*rank])
            .map(|(_, c)| c.id())
            .collect()
    }

    /// Replace a dead rank's process with a fresh one (DESIGN.md §12). The
    /// old child is killed-then-reaped first: usually it is already dead
    /// (its death is what triggered the call), but on the corrupt-frame
    /// path the hub severed the *connection* while the process mines on —
    /// a bare `wait` there would wedge forever. The replacement is spawned
    /// *without* any fault plan — an injected fault fires exactly once.
    fn respawn(&mut self, rank: usize) -> Result<()> {
        let exe = self.exe.clone().context("remote-attach fleets cannot respawn locally")?;
        let hub = self.hub.clone().context("fleet spawn endpoint missing")?;
        ensure!(rank < self.children.len(), "respawn of out-of-range rank {rank}");
        self.force_kill(rank);
        let token = self.token.clone();
        self.children[rank] = Self::spawn_one(&exe, &hub, &token, rank, None, None)?;
        self.reaped[rank] = false;
        Ok(())
    }

    /// Reap the whole fleet after `BYE`. A non-zero exit is an error —
    /// except the fault-injection exit code, which marks a death the chaos
    /// harness planned (e.g. a kill scheduled after the fleet's last
    /// phase, when no recovery runs because no phase is active).
    fn wait_all(&mut self) -> Result<()> {
        for (rank, child) in self.children.iter_mut().enumerate() {
            if self.reaped[rank] {
                continue;
            }
            let status = child.wait().context("wait for worker")?;
            self.reaped[rank] = true;
            ensure!(
                status.success() || status.code() == Some(FAULT_EXIT_CODE),
                "worker rank {rank} exited with {status}"
            );
        }
        Ok(())
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for (rank, child) in self.children.iter_mut().enumerate() {
            if !self.reaped[rank] {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// Remove the per-fleet socket directory when the fleet ends, however it
/// ends. This covers the hub socket *and* every worker's own mesh
/// data-plane socket (`hub.sock.r<rank>`, DESIGN.md §10), which the
/// workers bind inside the same directory.
///
/// Only Unix transports have filesystem residue: a fleet whose hub
/// listens on TCP carries no `SockDir` at all (`None`), so teardown and
/// respawn never attempt a bogus unlink of a name that was never a file.
struct SockDir(PathBuf);

impl Drop for SockDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn fresh_sock_endpoint() -> Result<(SockDir, Endpoint)> {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let dir = std::env::temp_dir().join(format!(
        "parlamp-pf-{}-{}",
        std::process::id(),
        COUNTER.fetch_add(1, Ordering::Relaxed)
    ));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("create socket directory {}", dir.display()))?;
    let sock = Endpoint::unix(dir.join("hub.sock"));
    Ok((SockDir(dir), sock))
}

fn worker_exe(cfg: &ProcessConfig) -> Result<PathBuf> {
    if let Some(exe) = &cfg.worker_exe {
        return Ok(exe.clone());
    }
    if let Some(exe) = std::env::var_os(WORKER_EXE_ENV) {
        return Ok(PathBuf::from(exe));
    }
    std::env::current_exe().context("resolve current executable for worker spawn")
}

/// A spawned, handshaken, reusable worker fleet: the warm half of the
/// process engine. One [`ProcessFleet`] serves any number of phases (and
/// jobs); the database ships to the workers only when it differs from the
/// one they already hold (keyed by [`Database::digest`]).
///
/// A worker death no longer poisons the fleet: a rank lost mid-phase is
/// respawned in place and the phase replayed under a fresh epoch
/// (DESIGN.md §12) — [`ProcessFleet::run_phase`] owns that loop. The
/// fleet is *poisoned* only by unrecoverable errors (hub socket failures,
/// repeated respawn failures) — then drop it (children are killed, the
/// socket directory is removed) and spawn a fresh one; the daemon's
/// scheduler does exactly that as its last resort. On the success path,
/// call [`ProcessFleet::shutdown`] for an orderly `BYE` + reap.
pub struct ProcessFleet {
    hub: Hub,
    fleet: Fleet,
    _sock_dir: Option<SockDir>,
    p: usize,
    /// Digest of the database currently resident on every worker.
    resident_db: Option<u64>,
    /// Data plane this fleet was spawned with. Fixed for the fleet
    /// lifetime: the mesh peer map is resolved once at spawn (every
    /// worker's own listen endpoint, learned during the `HELLO`
    /// handshakes), refreshed after a respawn, and redistributed with each
    /// phase frame.
    data_plane: DataPlane,
    /// The resolved mesh peer endpoint map; empty under [`DataPlane::Hub`].
    peers: Vec<Endpoint>,
    /// The next hub-assigned phase epoch: monotonic across phases, jobs,
    /// and replay attempts, so mesh fencing and stale-merge dropping stay
    /// sound for the fleet's whole lifetime.
    next_epoch: u64,
    /// Ranks respawned since their last `CONFIG`: they hold no database,
    /// so the next phase ships them the full `CONFIG` even when the
    /// survivors get a `RECONFIG`.
    fresh: Vec<bool>,
    /// Workers respawned over the fleet lifetime (chaos tests assert
    /// "exactly one").
    respawns: u64,
    /// Hub-side trace events (respawn/fence records) awaiting collection —
    /// drained by [`ProcessFleet::take_hub_trace`] onto the hub track.
    hub_trace: TraceRing,
    /// Ranks that died *after* their merge for the active epoch was
    /// collected (e.g. killed while the owner runs the serial phase-3
    /// screen): their contribution is complete, so the attempt is not
    /// voided — the repair is deferred to the next phase opening.
    deferred_gone: Vec<(usize, String)>,
    spawn_timeout: Duration,
    /// Heartbeat lease window ([`ProcessConfig::lease_timeout`]): enforced
    /// mid-phase against every rank still owing its merge.
    lease_timeout: Duration,
    /// Set by an external watchdog's [`AbortHandle::fire`]: the current
    /// (and any next) phase attempt surfaces [`FleetError::WatchdogAbort`]
    /// instead of recovering.
    abort: Arc<AtomicBool>,
    remote: bool,
}

/// A fleet that has bound its hub but not yet assembled its workers — the
/// first half of the two-phase spawn. The split exists for remote attach
/// mode: the hub endpoint and the fleet token must be *printable* (so the
/// operator can launch `parlamp __worker --connect … --token …` on other
/// machines) before the blocking wait for those workers begins.
pub struct PendingFleet {
    hub: Hub,
    fleet: Fleet,
    _sock_dir: Option<SockDir>,
    p: usize,
    data_plane: DataPlane,
    spawn_timeout: Duration,
    lease_timeout: Duration,
    remote: bool,
}

impl PendingFleet {
    /// The endpoint joining workers must dial (ephemeral TCP ports
    /// resolved).
    pub fn endpoint(&self) -> &Endpoint {
        self.hub.endpoint()
    }

    /// The fleet's shared-secret auth token.
    pub fn token(&self) -> &str {
        self.hub.token()
    }

    /// The join command for rank `rank`, ready to paste on another host.
    /// `peer` is the rank's mesh data-plane listen endpoint
    /// (`--peer-endpoint`); omit it to let the worker pick one itself.
    pub fn join_command(&self, exe: &str, rank: usize, peer: Option<&Endpoint>) -> String {
        let mut cmd = format!(
            "{exe} __worker --connect {} --token {} --worker-rank {rank}",
            self.endpoint(),
            self.token()
        );
        if let Some(p) = peer {
            cmd.push_str(&format!(" --peer-endpoint {p}"));
        }
        cmd
    }

    /// Block until every rank has completed the `HELLO` handshake (or the
    /// spawn timeout passes / a locally-spawned worker dies), then freeze
    /// the mesh peer map and hand over the warm fleet.
    pub fn await_workers(mut self) -> Result<ProcessFleet> {
        let p = self.p;
        let deadline = Instant::now() + self.spawn_timeout;
        while self.hub.connected() < p {
            self.fleet.check().context("while assembling the worker fleet")?;
            if !self.hub.try_accept()? {
                if Instant::now() >= deadline {
                    // Typed (DESIGN.md §15): the serve daemon rebuilds a
                    // fleet that never assembled rather than retrying it.
                    return Err(FleetError::AssembleTimeout {
                        connected: self.hub.connected(),
                        p,
                    }
                    .into());
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        let peers = match self.data_plane {
            DataPlane::Mesh => {
                self.hub.peer_map().context("resolve mesh peer endpoint map")?
            }
            DataPlane::Hub => Vec::new(),
        };
        Ok(ProcessFleet {
            hub: self.hub,
            fleet: self.fleet,
            _sock_dir: self._sock_dir,
            p,
            resident_db: None,
            data_plane: self.data_plane,
            peers,
            next_epoch: 0,
            fresh: vec![false; p],
            respawns: 0,
            hub_trace: TraceRing::with_default_cap(),
            deferred_gone: Vec::new(),
            spawn_timeout: self.spawn_timeout,
            lease_timeout: self.lease_timeout,
            abort: Arc::new(AtomicBool::new(false)),
            remote: self.remote,
        })
    }
}

impl ProcessFleet {
    /// First half of the spawn: bind the hub (at `cfg.listen`, or a fresh
    /// per-fleet Unix socket), mint the fleet token, and either spawn
    /// `cfg.p` local children pointed at it or — in remote attach mode —
    /// spawn nothing and leave the joining to the caller's operators.
    /// Complete with [`PendingFleet::await_workers`].
    pub fn bind(cfg: &ProcessConfig) -> Result<PendingFleet> {
        let p = cfg.world_size();
        ensure!(p >= 1, "world size must be ≥ 1");
        let (sock_dir, listen) = match &cfg.listen {
            Some(ep) => (None, ep.clone()),
            None => {
                let (dir, ep) = fresh_sock_endpoint()?;
                (Some(dir), ep)
            }
        };
        let hub = Hub::bind(&listen, p, fresh_token())?;
        let fleet = if cfg.remote_workers.is_some() {
            Fleet::remote()
        } else {
            let exe = worker_exe(cfg)?;
            Fleet::spawn(
                &exe,
                hub.endpoint(),
                hub.token(),
                p,
                cfg.fault.as_ref(),
                cfg.net_fault.as_ref(),
            )?
        };
        Ok(PendingFleet {
            hub,
            fleet,
            _sock_dir: sock_dir,
            p,
            data_plane: cfg.data_plane,
            spawn_timeout: cfg.spawn_timeout,
            lease_timeout: cfg.lease_timeout,
            remote: cfg.remote_workers.is_some(),
        })
    }

    /// Bind a hub, spawn the workers, and block until every rank has
    /// completed the `HELLO` handshake (or `cfg.spawn_timeout` passes / a
    /// worker dies). [`ProcessFleet::bind`] + [`PendingFleet::await_workers`]
    /// in one call — what every local-spawn caller wants.
    pub fn spawn(cfg: &ProcessConfig) -> Result<ProcessFleet> {
        ProcessFleet::bind(cfg)?.await_workers()
    }

    /// World size.
    pub fn p(&self) -> usize {
        self.p
    }

    /// The data plane this fleet was spawned with.
    pub fn data_plane(&self) -> DataPlane {
        self.data_plane
    }

    /// Workers respawned over this fleet's lifetime.
    pub fn respawns(&self) -> u64 {
        self.respawns
    }

    /// Handle for an external watchdog to abort this fleet from another
    /// thread (the serve daemon's per-job watchdog, DESIGN.md §15). The
    /// pid list is a snapshot — fire the handle once and rebuild the
    /// fleet; a handle held across respawns may miss replacement pids,
    /// which the abort flag still covers.
    pub fn abort_handle(&self) -> AbortHandle {
        AbortHandle { flag: Arc::clone(&self.abort), pids: self.fleet.pids() }
    }

    /// Drain the hub-side trace events (respawns and replay fences) as
    /// `(events, dropped)`. The coordinator merges them onto the hub
    /// track; empty unless tracing is on and a recovery ran.
    pub fn take_hub_trace(&mut self) -> (Vec<TraceEvent>, u64) {
        self.hub_trace.take()
    }

    /// The hub's last custody checkpoint for `rank` (diagnostics).
    pub fn custody(&self, rank: usize) -> crate::fabric::process::Custody {
        self.hub.custody(rank)
    }

    /// Run one phase across the warm fleet and block until every rank's
    /// phase-boundary merge arrived. Ships the database only when its
    /// digest differs from what the workers hold (`CONFIG` vs `RECONFIG`).
    /// The data plane is the fleet's, fixed at spawn — `cfg.data_plane` is
    /// ignored here.
    ///
    /// **Fault tolerance (DESIGN.md §12):** a rank lost mid-phase does not
    /// fail the call. The dead rank is respawned in place (exactly that
    /// one rank — never a fleet restart), the mesh peer map refreshed, and
    /// the whole phase replayed under a fresh hub-assigned epoch; epoch
    /// fencing discards every frame and merge of the aborted attempt, so
    /// the replay — a pure function of the database and the phase spec —
    /// yields results bit-identical to an undisturbed run.
    pub fn run_phase(
        &mut self,
        db: &Database,
        mode: RunMode,
        cfg: &ProcessConfig,
        seed: u64,
    ) -> Result<ParRunResult> {
        let phase = PhaseSpec {
            p: self.p as u32,
            seed,
            w: cfg.w as u32,
            l: cfg.l as u32,
            tree_arity: cfg.tree_arity as u32,
            steal: cfg.steal,
            preprocess: cfg.preprocess && self.p > 1,
            trace: cfg.trace,
            probe_budget_units: cfg.probe_budget_units,
            dtd_interval_ns: cfg.dtd_interval_ns,
            mode,
        };
        let digest = db.digest();
        let mut recoveries = 0u32;
        loop {
            if self.abort.load(Ordering::SeqCst) {
                return Err(FleetError::WatchdogAbort.into());
            }
            // Between-phase deaths (a rank killed after its last merge —
            // during the owner's serial screen, or between two jobs of a
            // warm daemon fleet) surface as queued `Gone` events; repair
            // before opening the phase.
            self.repair()?;
            match self.try_phase(db, &phase, digest, mode) {
                Ok(PhaseOutcome::Done(result)) => return Ok(result),
                Ok(PhaseOutcome::Lost { rank, detail }) => {
                    recoveries += 1;
                    if recoveries > MAX_PHASE_RECOVERIES {
                        return Err(FleetError::RecoveryExhausted { rank, detail }.into());
                    }
                    self.recover_rank(rank, &detail)?;
                }
                Err(e) => {
                    // A send failure can race the death that caused it (a
                    // write to a rank that died a moment ago). If the hub
                    // holds a pending Gone, recover and replay instead of
                    // poisoning the fleet.
                    match self.hub.recv_event(Duration::from_millis(50))? {
                        Some(HubEvent::Gone { rank, detail }) => {
                            recoveries += 1;
                            if recoveries > MAX_PHASE_RECOVERIES {
                                return Err(
                                    FleetError::RecoveryExhausted { rank, detail }.into()
                                );
                            }
                            self.recover_rank(rank, &detail)?;
                        }
                        _ => return Err(e),
                    }
                }
            }
        }
    }

    /// Drain between-phase hub events, recovering any rank that died while
    /// no phase was active. Stale merges of aborted attempts are dropped.
    fn repair(&mut self) -> Result<()> {
        for (rank, detail) in std::mem::take(&mut self.deferred_gone) {
            self.recover_rank(rank, &detail)?;
        }
        while let Some(ev) = self.hub.recv_event(Duration::ZERO)? {
            match ev {
                HubEvent::Gone { rank, detail } => self.recover_rank(rank, &detail)?,
                HubEvent::Merge(_) => {}      // stale merge of an aborted attempt
                HubEvent::Trace { .. } => {}  // stale flush of an aborted attempt
            }
        }
        Ok(())
    }

    /// One phase *attempt* at a fresh epoch: per-rank phase frames (full
    /// `CONFIG` for respawned ranks that hold no database, `RECONFIG` for
    /// survivors), the `START` barrier, then merge collection. A `Gone`
    /// mid-collection aborts the attempt — the caller recovers the rank
    /// and calls again.
    fn try_phase(
        &mut self,
        db: &Database,
        phase: &PhaseSpec,
        digest: u64,
        mode: RunMode,
    ) -> Result<PhaseOutcome> {
        let epoch = self.next_epoch;
        self.next_epoch += 1;
        if self.resident_db == Some(digest) {
            for rank in 0..self.p {
                if self.fresh[rank] {
                    let spec = RunSpec { phase: phase.clone(), db: db.clone() };
                    self.hub.send_config_to(rank, &spec, &self.peers)?;
                } else {
                    self.hub.send_reconfig_to(rank, phase, &self.peers)?;
                }
            }
        } else {
            // Invalidate first: a partial broadcast failure leaves the fleet
            // in a mixed state, and the fleet is poisoned anyway on error.
            self.resident_db = None;
            self.hub
                .broadcast_config(&RunSpec { phase: phase.clone(), db: db.clone() }, &self.peers)?;
            self.resident_db = Some(digest);
        }
        for f in &mut self.fresh {
            *f = false;
        }
        self.hub.start_all(epoch)?;
        // Heartbeat bookkeeping (v8, DESIGN.md §15): leases measure
        // liveness only while a phase runs, so re-seed them now — an idle
        // warm fleet between jobs goes legitimately quiet and its leases
        // would otherwise expire the first rank checked.
        self.hub.reset_leases();
        let ping_every = (self.lease_timeout / 4).max(Duration::from_millis(200));
        let mut last_ping = Instant::now();

        // Collect one merge per rank. Merges echo the epoch they conclude,
        // so stragglers from an aborted attempt are dropped rather than
        // double-counted; a disconnect aborts this attempt only.
        let mut merges: Vec<Option<WorkerMerge>> = vec![None; self.p];
        let mut traces: Vec<Option<(TraceChunk, u64)>> = vec![None; self.p];
        let mut keep_trace = |traces: &mut Vec<Option<(TraceChunk, u64)>>,
                              chunk: TraceChunk,
                              hub_recv_ns: u64| {
            let rank = chunk.rank as usize;
            if chunk.epoch == epoch && rank < traces.len() && traces[rank].is_none() {
                traces[rank] = Some((chunk, hub_recv_ns));
            }
        };
        let mut collected = 0usize;
        while collected < self.p {
            if self.abort.load(Ordering::SeqCst) {
                return Err(FleetError::WatchdogAbort.into());
            }
            if last_ping.elapsed() >= ping_every {
                last_ping = Instant::now();
                self.hub.ping_all();
                if let Some(lost) = self.expire_leases(epoch, &merges) {
                    return Ok(lost);
                }
            }
            match self.hub.recv_event(Duration::from_millis(200))? {
                Some(HubEvent::Merge(m)) => {
                    if m.epoch != epoch {
                        continue; // stale: an aborted attempt's merge
                    }
                    let rank = m.rank as usize;
                    ensure!(rank < self.p, "merge from out-of-range rank {rank}");
                    ensure!(merges[rank].is_none(), "duplicate merge from rank {rank}");
                    // The wire layer validates counts, not value ranges;
                    // check supports here so a corrupt MERGE errors instead
                    // of panicking collect_merges' histogram indexing.
                    let max_sup = db.n_trans() as u32;
                    for &(s, _) in &m.hist {
                        ensure!(
                            s <= max_sup,
                            "merge from rank {rank} reports support {s} > N = {max_sup}"
                        );
                    }
                    merges[rank] = Some(m);
                    collected += 1;
                }
                Some(HubEvent::Trace { chunk, hub_recv_ns }) => {
                    keep_trace(&mut traces, chunk, hub_recv_ns);
                }
                Some(HubEvent::Gone { rank, detail }) => {
                    // A rank that died *after* this epoch's merge arrived
                    // has already contributed everything the phase needs;
                    // voiding the attempt would replay a complete phase.
                    // Defer its repair to the next phase opening instead.
                    if rank < self.p && merges[rank].is_some() {
                        self.deferred_gone.push((rank, detail));
                        continue;
                    }
                    return Ok(PhaseOutcome::Lost { rank, detail });
                }
                None => {} // idle tick; a crashed worker surfaces as Gone (EOF)
            }
        }

        // Each rank's TRACE flush rides its socket right behind its MERGE,
        // so by the time the last merge lands most chunks are queued — but
        // the *last* rank's chunk is still in flight. Wait briefly for the
        // stragglers; the flush is best-effort, so a missing chunk degrades
        // the timeline (logged), never the run.
        if phase.trace {
            let deadline = Instant::now() + Duration::from_secs(5);
            while traces.iter().any(Option::is_none) && Instant::now() < deadline {
                match self.hub.recv_event(Duration::from_millis(50))? {
                    Some(HubEvent::Trace { chunk, hub_recv_ns }) => {
                        keep_trace(&mut traces, chunk, hub_recv_ns);
                    }
                    Some(HubEvent::Gone { rank, detail }) => {
                        // The phase is complete; repair at the next opening.
                        self.deferred_gone.push((rank, detail));
                    }
                    Some(HubEvent::Merge(_)) | None => {}
                }
            }
        }
        let mut rank_traces: Vec<RankTrace> = Vec::new();
        if phase.trace {
            for (rank, slot) in traces.into_iter().enumerate() {
                let Some((chunk, hub_recv_ns)) = slot else {
                    log::warn(
                        "fleet",
                        &Tags::rank(rank),
                        format_args!("no trace chunk from rank {rank} for epoch {epoch}"),
                    );
                    continue;
                };
                // One NTP-style handshake round per phase: hub stamps the
                // START write and the TRACE read; the worker stamps the
                // START read and the flush inside the chunk.
                let off = estimate_offset(&[HandshakeSample {
                    hub_send_ns: self.hub.start_sent_ns(rank),
                    worker_recv_ns: chunk.start_recv_ns,
                    worker_send_ns: chunk.flush_ns,
                    hub_recv_ns,
                }]);
                rank_traces.push(RankTrace {
                    rank: chunk.rank,
                    offset_ns: off.offset_ns,
                    uncertainty_ns: off.uncertainty_ns,
                    dropped: chunk.dropped,
                    events: chunk.events,
                });
            }
        }

        let merges: Vec<WorkerMerge> = merges.into_iter().map(Option::unwrap).collect();
        let mut result = collect_merges(db, &merges, mode);
        result.traces = rank_traces;
        Ok(PhaseOutcome::Done(result))
    }

    /// Heartbeat-lease enforcement (v8, DESIGN.md §15): find a mid-phase
    /// rank whose lease aged past the timeout, force-kill it, and
    /// synthesize the same `Lost` outcome a crash would have produced —
    /// the ordinary respawn + epoch-fenced replay path does the rest.
    /// Ranks whose merge for this epoch already arrived owe nothing
    /// further and are exempt; remote-attach fleets hold no child handle
    /// to kill, so there EOF stays the only liveness signal.
    fn expire_leases(
        &mut self,
        epoch: u64,
        merges: &[Option<WorkerMerge>],
    ) -> Option<PhaseOutcome> {
        if self.remote {
            return None;
        }
        for rank in 0..self.p {
            if merges[rank].is_some() {
                continue;
            }
            // No lease means the slot is vacated (mid-recovery); the Gone
            // path owns that rank, not the lease scan.
            let Some(age) = self.hub.lease_age(rank) else { continue };
            if age < self.lease_timeout {
                continue;
            }
            if obs_trace::enabled() {
                let now = clock::now_ns();
                self.hub_trace.push(now, TraceEv::LeaseMiss { rank: rank as u32, epoch });
                self.hub_trace.push(now, TraceEv::ForceKill { rank: rank as u32, epoch });
            }
            // Order matters: arm the expected-EOF flag *before* the kill,
            // so the route thread's EOF cannot race ahead of it and
            // surface a duplicate `Gone` (which would double-respawn).
            self.hub.mark_expected_eof(rank);
            self.fleet.force_kill(rank);
            let detail = format!(
                "lease expired: no frame from rank {rank} in {age:.1?} \
                 (lease timeout {:.1?}); force-killed",
                self.lease_timeout
            );
            return Some(PhaseOutcome::Lost { rank, detail });
        }
        None
    }

    /// Recover from one rank's death (DESIGN.md §12): vacate its hub slot,
    /// respawn exactly that rank (or, for remote-attach fleets, print the
    /// re-join command and wait), await its `HELLO`, refresh the mesh peer
    /// map, and mark it fresh so the next attempt ships it the database.
    fn recover_rank(&mut self, rank: usize, detail: &str) -> Result<()> {
        // Classify the death for the structured-log scrape (DESIGN.md §15):
        // which detection path declared this rank lost.
        let cause = if detail.contains("lease expired") {
            "lease-expiry"
        } else if detail.starts_with("EOF") {
            "eof"
        } else if detail.contains("unknown frame tag") {
            "corrupt-frame"
        } else {
            "protocol-error"
        };
        log::warn(
            "fleet",
            &Tags::rank(rank).and_cause(cause),
            format_args!("worker rank {rank} lost ({detail}); respawning rank {rank}"),
        );
        if obs_trace::enabled() {
            self.hub_trace.push(
                clock::now_ns(),
                TraceEv::Respawn { rank: rank as u32, epoch: self.next_epoch },
            );
        }
        self.hub.forget_rank(rank);
        if self.remote {
            log::warn(
                "fleet",
                &Tags::rank(rank),
                format_args!(
                    "remote fleet — re-attach rank {rank} with: \
                     parlamp __worker --connect {} --token {} --worker-rank {rank}",
                    self.hub.endpoint(),
                    self.hub.token()
                ),
            );
        } else {
            self.fleet.respawn(rank)?;
        }
        self.respawns += 1;
        let deadline = Instant::now() + self.spawn_timeout;
        while self.hub.connected() < self.p {
            if !self.hub.try_accept()? {
                ensure!(
                    Instant::now() < deadline,
                    "timed out waiting for respawned rank {rank} to re-join the fleet"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        if self.data_plane == DataPlane::Mesh {
            self.peers = self.hub.peer_map().context("refresh mesh peer map after respawn")?;
        }
        self.fresh[rank] = true;
        Ok(())
    }

    /// Orderly teardown: `BYE` the fleet, reap every worker (non-zero exit
    /// is an error), join the route threads, remove the socket directory.
    pub fn shutdown(mut self) -> Result<()> {
        self.hub.broadcast_bye();
        self.fleet.wait_all()?;
        self.hub.join();
        Ok(())
    }
}

/// Run one phase on worker processes with explicit GLB/DTD knobs: spawn a
/// fleet, run, tear down. Kept for one-shot callers and tests; anything
/// running more than one phase should hold a [`ProcessFleet`] (the
/// coordinator and the `parlamp serve` daemon both do).
pub fn run_process_with(db: &Database, mode: RunMode, cfg: &ProcessConfig) -> Result<ParRunResult> {
    let mut fleet = ProcessFleet::spawn(cfg)?;
    match fleet.run_phase(db, mode, cfg, cfg.seed) {
        Ok(result) => {
            fleet.shutdown()?;
            Ok(result)
        }
        // Drop the poisoned fleet: children are killed, nothing leaks.
        Err(e) => Err(e),
    }
}

/// Merge the per-rank wire payloads into a [`ParRunResult`] — the
/// serialization-boundary twin of `engine_sim::collect`.
fn collect_merges(db: &Database, merges: &[WorkerMerge], mode: RunMode) -> ParRunResult {
    let makespan_ns = merges.iter().map(|m| m.makespan_ns).max().unwrap_or(0);
    let mut hist = SupportHist::new(db.n_trans());
    let mut closed_total = 0u64;
    let mut comm = CommStats::default();
    let mut work_units = 0u64;
    let mut breakdowns: Vec<Breakdown> = Vec::with_capacity(merges.len());
    for m in merges {
        for &(s, c) in &m.hist {
            hist.add_count(s, c);
        }
        closed_total += m.closed_count;
        comm.add(&m.comm);
        work_units += m.work_units;
        let mut b = m.breakdown;
        b.close_over_span(makespan_ns);
        breakdowns.push(b);
    }
    let (lambda_final, min_sup) = match mode {
        RunMode::Phase1 { .. } => (0, 0), // finalized by finalize_phase1
        RunMode::Count { min_sup } => (min_sup + 1, min_sup),
    };
    ParRunResult {
        lambda_final,
        min_sup,
        hist,
        closed_total,
        makespan_s: makespan_ns as f64 * 1e-9,
        breakdowns,
        comm,
        work_units,
        traces: Vec::new(), // filled by try_phase when the run was traced
    }
}

/// Child entry point behind the hidden `__worker` CLI command: join the hub
/// at `--connect <endpoint>` (legacy spellings `--endpoint`/`--socket`
/// accepted) as `--worker-rank`, presenting the fleet's `--token`, then
/// serve phases until `BYE` — for each one, run the ordinary Fig. 5 worker
/// loop over the process fabric and ship the merge. The database arrives
/// with the first phase (`CONFIG`) and is retained across `RECONFIG`
/// phases. `--peer-endpoint` pins the mesh data-plane listener (remote
/// attach mode hands each rank its advertised address); without it the
/// worker derives one from the hub endpoint.
pub fn worker_main(args: &crate::cli::Args) -> Result<()> {
    // Terminal Ctrl-C hits the whole foreground process group; a worker
    // that died to it would abort the supervisor's graceful drain. Workers
    // are supervised — they exit on fabric EOF or `BYE` — so SIGINT is
    // ignored here (SIGTERM keeps its default for targeted kills).
    crate::util::sig::ignore_interrupts();
    // A dying worker's stderr should carry its recent history, not just a
    // bare panic line — the hub quotes that tail in its `Gone` detail.
    log::install_panic_hook();
    let hub: Endpoint = args
        .get("connect")
        .or_else(|| args.get("endpoint"))
        .or_else(|| args.get("socket"))
        .context("__worker needs --connect <endpoint> (or legacy --socket PATH)")?
        .parse()
        .context("--connect endpoint")?;
    let token = args.get("token").unwrap_or("").to_string();
    let peer_listen: Option<Endpoint> = match args.get("peer-endpoint") {
        Some(p) => Some(p.parse().context("--peer-endpoint")?),
        None => None,
    };
    let rank: usize = args
        .require("worker-rank")?
        .parse()
        .context("--worker-rank must be a non-negative integer")?;
    // Deterministic fault injection (DESIGN.md §12): `--fault-inject` wins,
    // then the environment variable. A plan naming another rank is inert.
    let fault: Option<FaultPlan> = match args.get("fault-inject") {
        Some(plan) => Some(plan.parse().context("--fault-inject")?),
        None => match std::env::var(FAULT_ENV) {
            Ok(plan) => Some(plan.parse().with_context(|| format!("${FAULT_ENV}"))?),
            Err(_) => None,
        },
    };
    // Network-fault injection (DESIGN.md §15) follows the same precedence.
    // Arming is per-process and latched before the fabric connects so the
    // very first data frame is already counted.
    let net_fault: Option<NetFaultPlan> = match args.get("net-fault") {
        Some(plan) => Some(plan.parse().context("--net-fault")?),
        None => match std::env::var(NET_FAULT_ENV) {
            Ok(plan) => Some(plan.parse().with_context(|| format!("${NET_FAULT_ENV}"))?),
            Err(_) => None,
        },
    };
    if let Some(plan) = net_fault {
        if plan.rank == rank {
            netfault::arm(plan);
        }
    }
    let mut mb = connect(&hub, rank, &token, peer_listen)?;
    let mut resident: Option<Database> = None;

    while let Some(start) = mb.await_phase()? {
        if let Some(db) = start.db {
            resident = Some(db);
        }
        let db = resident
            .as_ref()
            .context("hub opened a RECONFIG phase before ever shipping a database")?;
        let spec = start.phase;
        // The hub decides per phase whether this run is traced; flip the
        // process-global switch before the worker is built so its ring is
        // allocated (or not) accordingly.
        obs_trace::set_enabled(spec.trace);
        let wc = WorkerConfig {
            rank,
            p: spec.p as usize,
            w: spec.w as usize,
            l: spec.l as usize,
            tree_arity: spec.tree_arity as usize,
            steal: spec.steal,
            preprocess: spec.preprocess,
            mode: spec.mode,
            probe_budget_units: spec.probe_budget_units,
            dtd_interval_ns: spec.dtd_interval_ns,
            ns_per_unit: None, // real time
            seed: spec.seed,
        };
        let mut worker = Worker::new(db, wc);
        worker.trace_event(TraceEv::PhaseStart {
            phase: spec.mode.phase_no(),
            epoch: mb.epoch(),
        });

        // The same scheduling loop as the thread engine: blocking waits cap
        // at 200 µs so DTD waves keep flowing. Two fault-tolerance hooks
        // ride along (DESIGN.md §12): a custody checkpoint to the hub every
        // `CHECKPOINT_EVERY_UNITS` of local expansion, and the interrupt
        // check — a phase frame arriving mid-phase means the hub aborted
        // this attempt (a peer died), so the attempt is abandoned without a
        // merge and the stashed frames open the replay.
        let t0 = Instant::now();
        let mut last_checkpoint = 0u64;
        let mut interrupted = false;
        loop {
            if let Some(err) = mb.lost() {
                bail!("rank {rank}: fabric link lost mid-run: {err}");
            }
            if mb.phase_interrupted() {
                interrupted = true;
                break;
            }
            if let Some(plan) = &fault {
                if plan.fires_in_phase(rank, mb.epoch(), worker.work_units()) {
                    fault_exit(rank, plan);
                }
            }
            if worker.work_units() - last_checkpoint >= CHECKPOINT_EVERY_UNITS {
                last_checkpoint = worker.work_units();
                let roots = worker.stack_roots(64);
                worker.trace_event(TraceEv::Checkpoint {
                    units: last_checkpoint,
                    roots: roots.len() as u32,
                });
                mb.send_checkpoint(last_checkpoint, roots);
            }
            let now_ns = t0.elapsed().as_nanos() as u64;
            match worker.poll(&mut mb, now_ns) {
                Poll::Busy { .. } => {}
                Poll::Idle { wake_at } => {
                    let cap = Duration::from_micros(200);
                    let d = match wake_at {
                        Some(t) => Duration::from_nanos(t.saturating_sub(now_ns)).min(cap),
                        None => cap,
                    };
                    if !d.is_zero() {
                        mb.wait_for_msg(d);
                    }
                }
                Poll::Finished => break,
            }
        }
        if interrupted {
            // Abandoned attempt: no merge — the hub has already moved on,
            // and a merge stamped with this epoch would be fenced anyway.
            // The ring is drained so the replay starts a clean trace.
            let _ = worker.take_trace();
            continue;
        }
        let makespan_ns = t0.elapsed().as_nanos() as u64;
        worker.trace_event(TraceEv::PhaseEnd {
            phase: spec.mode.phase_no(),
            epoch: mb.epoch(),
        });

        // Fold the mailbox's per-phase data-plane split into the comm
        // counters so the hub-vs-mesh ablation is observable in the merge.
        let (hub_frames, direct_frames) = mb.plane_counters();
        let mut comm = worker.comm;
        comm.hub_frames = hub_frames;
        comm.direct_frames = direct_frames;

        let hist = worker.hist().sparse();
        let merge = WorkerMerge {
            rank: rank as u32,
            epoch: mb.epoch(),
            hist,
            closed_count: worker.closed_count(),
            work_units: worker.work_units(),
            breakdown: worker.breakdown,
            comm,
            makespan_ns,
        };
        mb.send_merge(&merge)?;
        // The trace flush rides the same socket immediately after the
        // merge (best-effort — a lost chunk degrades the timeline, never
        // the run). `take_trace` is `None` when this phase was untraced.
        if let Some((events, dropped)) = worker.take_trace() {
            mb.send_trace(events, dropped);
        }

        // The post-phase trigger: a plan whose armed epoch completed under
        // its `after` budget fires here, right after the rank's last merge
        // — which is how the chaos suite kills a worker while the owner
        // runs the serial phase-3 screen (no distributed phase active, no
        // recovery needed, and `Fleet::wait_all` tolerates the exit code).
        if let Some(plan) = &fault {
            if plan.fires_after_phase(rank, mb.phases_started()) {
                fault_exit(rank, plan);
            }
        }
    }
    Ok(())
}

/// Die by plan: the injected fault's one observable side effect beyond the
/// exit code is a stderr line the chaos CI job greps for.
fn fault_exit(rank: usize, plan: &FaultPlan) -> ! {
    log::warn(
        "worker",
        &Tags::rank(rank),
        format_args!("fault injection firing ({plan}); exiting {FAULT_EXIT_CODE}"),
    );
    log::dump_recent("fault injection");
    std::process::exit(FAULT_EXIT_CODE);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn merge(rank: u32, hist: Vec<(u32, u64)>, closed: u64, makespan_ns: u64) -> WorkerMerge {
        WorkerMerge {
            rank,
            epoch: 0,
            hist,
            closed_count: closed,
            work_units: closed * 10,
            breakdown: Breakdown { main_ns: 100, ..Default::default() },
            comm: CommStats { sent: rank as u64, ..Default::default() },
            makespan_ns,
        }
    }

    #[test]
    fn collect_merges_mirrors_engine_collect() {
        let trans = vec![vec![0], vec![0, 1], vec![1]];
        let db = Database::from_transactions(2, &trans, &[true, false, false]);
        let merges = vec![
            merge(0, vec![(1, 2), (2, 1)], 3, 500),
            merge(1, vec![(2, 4)], 4, 900),
        ];
        let got = collect_merges(&db, &merges, RunMode::Count { min_sup: 1 });
        assert_eq!(got.closed_total, 7);
        assert_eq!(got.hist.cs_ge(2), 5);
        assert_eq!(got.hist.cs_ge(1), 7);
        assert_eq!(got.min_sup, 1);
        assert_eq!(got.lambda_final, 2);
        assert_eq!(got.comm.sent, 1);
        assert_eq!(got.work_units, 70);
        assert!((got.makespan_s - 900e-9).abs() < 1e-15);
        // idle fills each rank's breakdown to the global makespan
        for b in &got.breakdowns {
            assert_eq!(b.total_ns(), 900);
        }
    }

    #[test]
    fn process_config_defaults_match_thread_engine() {
        let pc = ProcessConfig::paper_defaults(4, 7);
        let tc = super::super::ThreadConfig::paper_defaults(4, 7);
        assert_eq!(pc.w, tc.w);
        assert_eq!(pc.l, tc.l);
        assert_eq!(pc.tree_arity, tc.tree_arity);
        assert_eq!(pc.probe_budget_units, tc.probe_budget_units);
        assert_eq!(pc.dtd_interval_ns, tc.dtd_interval_ns);
        assert!(pc.steal && pc.preprocess);
        assert!(pc.listen.is_none() && pc.remote_workers.is_none());
    }

    #[test]
    fn remote_workers_override_world_size() {
        let mut cfg = ProcessConfig::paper_defaults(4, 7);
        assert_eq!(cfg.world_size(), 4);
        cfg.remote_workers =
            Some(vec![Endpoint::tcp("h1", 7001), Endpoint::tcp("h2", 7001)]);
        assert_eq!(cfg.world_size(), 2);
    }

    #[test]
    fn bind_exposes_endpoint_token_and_join_commands() {
        let mut cfg = ProcessConfig::paper_defaults(2, 1);
        cfg.listen = Some(Endpoint::tcp("127.0.0.1", 0));
        cfg.remote_workers =
            Some(vec![Endpoint::tcp("10.0.0.1", 7001), Endpoint::tcp("10.0.0.2", 7001)]);
        // Remote attach: bind() must return without spawning or waiting for
        // anything, with a printable resolved endpoint and token.
        let pending = ProcessFleet::bind(&cfg).unwrap();
        assert!(matches!(pending.endpoint(), Endpoint::Tcp(_, p) if *p != 0));
        assert_eq!(pending.token().len(), 16);
        let peer = Endpoint::tcp("10.0.0.2", 7001);
        let cmd = pending.join_command("parlamp", 1, Some(&peer));
        assert!(cmd.contains("__worker"), "{cmd}");
        assert!(cmd.contains(&format!("--connect {}", pending.endpoint())), "{cmd}");
        assert!(cmd.contains(&format!("--token {}", pending.token())), "{cmd}");
        assert!(cmd.contains("--worker-rank 1"), "{cmd}");
        assert!(cmd.contains("--peer-endpoint tcp:10.0.0.2:7001"), "{cmd}");
    }
}
