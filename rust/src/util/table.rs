//! Plain-text table rendering for bench reports and CLI output.

/// A simple column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table { header: columns.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
    }

    /// Render with right-aligned numeric-looking cells and a separator rule.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                // right-align numbers, left-align text
                let numeric = c.chars().next().map(|ch| ch.is_ascii_digit()).unwrap_or(false);
                if numeric {
                    line.push_str(&format!("{c:>w$}", w = width[i]));
                } else {
                    line.push_str(&format!("{c:<w$}", w = width[i]));
                }
            }
            line.trim_end().to_string()
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(width.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligns_columns() {
        let mut t = Table::new(&["name", "val"]);
        t.row(vec!["alpha".into(), "1".into()]);
        t.row(vec!["b".into(), "1234".into()]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[2].starts_with("alpha"));
        assert!(lines[3].contains("1234"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
