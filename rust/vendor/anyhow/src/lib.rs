//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the subset of `anyhow`'s API the workspace actually uses, with
//! the same observable behaviour:
//!
//! - [`Error`]: an opaque error carrying a context chain (outermost first).
//!   `Display` prints the outermost message, `{:#}` prints the full chain
//!   joined by `": "`, and `Debug` prints the anyhow-style
//!   `Caused by:` listing.
//! - [`Result<T>`] with `Error` as the default error type.
//! - [`Context`]: `.context(..)` / `.with_context(..)` on `Result<T, E>`
//!   for any `E: std::error::Error`, on `Result<T, Error>`, and on
//!   `Option<T>`.
//! - [`anyhow!`], [`bail!`], [`ensure!`].
//!
//! The blanket-vs-`Error` coherence is resolved exactly the way the real
//! crate does it: one `Context` impl parameterized over a private extension
//! trait that is implemented both for all standard errors and for `Error`
//! itself (sound because `Error` deliberately does not implement
//! `std::error::Error`).

use std::convert::Infallible;
use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` by default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a chain of context messages (outermost first) plus the
/// boxed root cause when one exists.
pub struct Error {
    /// Context messages, outermost first; the last entry is the root
    /// message. Never empty.
    chain: Vec<String>,
    /// The typed root cause, when constructed from a standard error.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()], source: None }
    }

    /// Create an error from a standard error value.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { chain: vec![error.to_string()], source: Some(Box::new(error)) }
    }

    /// Wrap with an outer context message.
    fn wrap<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().expect("chain is never empty")
    }

    /// The typed root cause, when one exists.
    // `.map` cannot drop the box's `Send + Sync` bounds (no coercion through
    // `Option`), so this stays an explicit match.
    #[allow(clippy::manual_map)]
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        match &self.source {
            Some(boxed) => Some(&**boxed),
            None => None,
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

mod ext {
    use super::*;

    /// Private extension trait: "something that can absorb a context
    /// message and become an `Error`". Implemented for every standard
    /// error and for `Error` itself.
    pub trait ErrorExt {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> ErrorExt for E {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            Error::new(self).wrap(context)
        }
    }

    impl ErrorExt for Error {
        fn ext_context<C: fmt::Display>(self, context: C) -> Error {
            self.wrap(context)
        }
    }
}

/// Attach context to a fallible value.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: ext::ErrorExt> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T, Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            $crate::bail!(::std::concat!("condition failed: ", ::std::stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(e.to_string(), "missing file");
        assert!(e.source().is_some());
    }

    #[test]
    fn context_chains_outermost_first() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("open config").unwrap_err();
        assert_eq!(format!("{e}"), "open config");
        assert_eq!(format!("{e:#}"), "open config: missing file");
        assert_eq!(e.chain().collect::<Vec<_>>(), vec!["open config", "missing file"]);
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn context_on_anyhow_result_stacks() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("layer {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "layer 2: inner");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
        assert_eq!(Some(7).context("unused").unwrap(), 7);
        let lazy: Option<u32> = None;
        assert!(lazy.with_context(|| "lazy").is_err());
    }

    #[test]
    fn debug_prints_caused_by() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        let dbg = format!("{e:?}");
        assert!(dbg.starts_with("outer"));
        assert!(dbg.contains("Caused by:"));
        assert!(dbg.contains("missing file"));
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 0 {
                bail!("zero is not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero is not allowed");
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");

        fn g() -> Result<()> {
            ensure!(1 + 1 == 3);
            Ok(())
        }
        assert!(g().unwrap_err().to_string().contains("condition failed"));
    }

    #[test]
    fn anyhow_macro_forms() {
        let a = anyhow!("plain literal");
        assert_eq!(a.to_string(), "plain literal");
        let n = 4;
        let b = anyhow!("formatted {n} {}", "args");
        assert_eq!(b.to_string(), "formatted 4 args");
        let c = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }
}
