//! Process-backed fabric: one OS process per rank, stream sockets as the
//! interconnect (DESIGN.md §7) — Unix-domain on one host or TCP across
//! hosts, behind the pluggable transport of [`crate::net`] (§11).
//!
//! The first fabric backend with real address-space separation: unlike
//! [`super::thread`] and [`super::sim`], nothing can be passed by value, so
//! every protocol message crosses the [`crate::wire`] serialization
//! boundary. The *control plane* is hub-and-spoke: the parent process runs
//! a [`Hub`] that accepts one connection per worker rank and owns the
//! phase lifecycle (HELLO/CONFIG/START/MERGE/BYE, plus liveness via socket
//! EOF *and*, since wire v8, a PING/PONG heartbeat feeding a per-rank
//! lease table — see [`Hub::lease_age`] — so a rank that is hung or
//! partitioned with its socket still open is detected too, DESIGN.md
//! §15). Every HELLO and PEERHELLO carries the fleet's shared-secret
//! token (wire v4); a connection with the wrong token never joins the
//! fabric, so a stray TCP connector cannot poison a run. The *data
//! plane* — every steal REQUEST/GIVE/REJECT frame and every DTD wave —
//! is selectable ([`DataPlane`], DESIGN.md §10):
//!
//! - [`DataPlane::Mesh`] (the default): each worker binds its own
//!   data-plane listener (a `<hub>.r<rank>` Unix socket next to a unix
//!   hub, an ephemeral TCP port on the hub-facing interface otherwise),
//!   the hub distributes the peer endpoint map with each phase frame, and
//!   workers open lazy direct connections on first send — lifeline
//!   neighbors and random-steal victims talk worker-to-worker with zero
//!   hub hops. Mesh frames are epoch-stamped so phases stay fenced
//!   without the hub's socket ordering.
//! - [`DataPlane::Hub`]: the original topology — every `RELAY` frame is
//!   forwarded by the hub. `P` sockets instead of up to `P(P−1)/2`, at the
//!   cost of doubling every data-plane hop and serializing all steal
//!   traffic through one process. Retained as the fallback and as the
//!   ablation baseline for the mesh speedup.
//!
//! The fleet is **warm**: a worker's connection outlives any single phase,
//! so one spawned fleet can serve many phases — and many jobs, which is
//! what `parlamp serve` (DESIGN.md §9) is built on. Lifecycle:
//!
//! 1. the engine ([`crate::par::engine_process`]) binds a hub and spawns
//!    `P` worker processes pointing at its socket; each worker connects and
//!    sends `HELLO { rank }`;
//! 2. per phase, the hub broadcasts `CONFIG` (the [`PhaseSpec`] *plus* the
//!    database) — or `RECONFIG` (the [`PhaseSpec`] alone) when the workers
//!    already hold the right database — and then `START`, the barrier that
//!    guarantees no steal traffic targets a rank that is not in the phase;
//! 3. workers run the ordinary [`crate::par::Worker`] loop against a
//!    [`ProcessMailbox`]; every [`Mailbox::send`] becomes either a `RELAY`
//!    frame the hub forwards (hub plane) or an epoch-stamped `PEERMSG` on
//!    a lazy direct connection (mesh plane — the phase frame carried a
//!    peer socket map);
//! 4. on `Finish` each worker sends its `MERGE` (the phase-boundary
//!    histogram/breakdown/counter payload) and returns to
//!    [`ProcessMailbox::await_phase`];
//! 5. the hub collects `P` merges and either opens the next phase (step 2)
//!    or broadcasts `BYE`, upon which the workers exit cleanly.
//!
//! Between phases the hub plane needs no explicit fencing: a worker sends
//! nothing after its `MERGE` until its next `START`, so once the hub holds
//! all `P` merges, every late relay of the finished phase has already been
//! forwarded — anything a worker receives *before* its next
//! `CONFIG`/`RECONFIG` is stale and dropped, anything after belongs to the
//! new phase and is buffered until `START`. Mesh frames have no such
//! socket ordering against the hub's phase frames, so they carry the
//! sender's phase index instead: the receiver drops frames below its next
//! phase index and buffers the rest exactly like hub-path pre-`START`
//! deliveries (DESIGN.md §10).
//!
//! Failure semantics (DESIGN.md §12): a worker that dies mid-run surfaces
//! as a [`HubEvent::Gone`] whose detail embeds the rank's last delivered
//! epoch, its frame context, and its last custody checkpoint (workers
//! periodically report their unfinished stack roots in `CHECKPOINT`
//! frames; the hub keeps the latest per rank in a [`Custody`] table). The
//! fleet owner ([`crate::par::engine_process::ProcessFleet`]) respawns
//! exactly the dead rank — [`Hub::forget_rank`] vacates the slot and the
//! replacement re-`HELLO`s into it — and replays the interrupted phase
//! under a fresh hub-assigned epoch (`START` carries it); survivors see
//! the replay's `RECONFIG` arrive mid-phase, abandon the aborted attempt
//! without merging ([`ProcessMailbox::phase_interrupted`]), and epoch
//! fencing drops every frame of the aborted attempt on both data planes.
//! A forward to an already-exited worker is silently dropped, mirroring
//! the finished-peer no-op of the thread fabric (MPI-finalize semantics).

use std::collections::VecDeque;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::db::Database;
use crate::net::fault as netfault;
use crate::net::{dial, dial_with_preamble, Endpoint, Listener, RetryPolicy, Stream};
use crate::obs::log::{self, Tags};
use crate::obs::clock;
use crate::obs::trace::TraceEvent;
use crate::wire::trace::TraceChunk;
use crate::wire::{
    encode_config, read_frame, write_frame, Frame, PhaseSpec, RunSpec, WorkerMerge,
    MAX_FRAME_LEN,
};

use super::{BasicKind, Mailbox, Msg, WireTask};

/// How long the hub waits for a connecting worker's `HELLO` before
/// declaring the peer dead.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

/// Which topology carries the data plane (steal traffic + DTD waves) of a
/// process-fabric phase. The control plane (phase lifecycle, merges,
/// liveness) always runs through the hub.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DataPlane {
    /// Direct worker-to-worker stream connections, opened lazily on
    /// first send; the hub forwards zero data-plane frames. The default.
    #[default]
    Mesh,
    /// Every data-plane frame is relayed by the parent hub — the
    /// centralized baseline (two hops per message).
    Hub,
}

impl DataPlane {
    /// CLI name (`--data-plane hub|mesh`).
    pub fn name(&self) -> &'static str {
        match self {
            DataPlane::Mesh => "mesh",
            DataPlane::Hub => "hub",
        }
    }

    /// Parse a `--data-plane` value.
    pub fn parse(s: &str) -> Result<DataPlane> {
        match s {
            "mesh" => Ok(DataPlane::Mesh),
            "hub" => Ok(DataPlane::Hub),
            other => bail!("unknown data plane '{other}' (hub|mesh)"),
        }
    }
}

/// The path of rank `rank`'s own data-plane listener socket, derived from
/// the hub socket path: `<hub>.r<rank>`. Lives in the per-fleet socket
/// directory, so the fleet owner's cleanup removes it with the hub socket.
pub fn peer_sock_path(hub: &Path, rank: usize) -> PathBuf {
    let mut os = hub.as_os_str().to_os_string();
    os.push(format!(".r{rank}"));
    PathBuf::from(os)
}

// ---- worker (child) side ---------------------------------------------------

/// Link status of a worker's hub connection.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Link {
    Open,
    /// Socket error, unexpected EOF, or protocol violation; the run cannot
    /// complete.
    Lost(String),
}

enum ChildEvent {
    /// A hub-relayed data-plane delivery (hub plane only). `epoch` is the
    /// *sender's* phase index, carried through the relay. FIFO order on the
    /// hub socket is NOT a fence once phases can be aborted mid-flight
    /// (the hub's RECONFIG races relays routed by other ranks' route
    /// threads), so hub deliveries are epoch-fenced exactly like mesh ones.
    Deliver { src: usize, epoch: u64, msg: Msg },
    /// A direct mesh delivery. `epoch` is the *sender's* phase index; the
    /// mailbox fences it against its own (see [`ProcessMailbox::await_phase`]).
    PeerDeliver { src: usize, epoch: u64, msg: Msg },
    Config { spec: Box<RunSpec>, peers: Vec<Endpoint> },
    Reconfig { phase: Box<PhaseSpec>, peers: Vec<Endpoint> },
    /// The phase barrier, carrying the hub-assigned phase epoch — the
    /// mailbox adopts it, so a respawned worker inherits the fleet's phase
    /// numbering and a replayed phase fences out its aborted attempt.
    Start(u64),
    /// A heartbeat probe from the hub (v8). Queued by the reader and
    /// answered with `PONG` by the *main* thread ([`ProcessMailbox`]'s
    /// `answer_ping`), so the answer attests whole-worker liveness: a
    /// rank whose reader still drains frames but whose main thread is
    /// hung or partitioned stops answering and misses its lease.
    Ping,
    Bye,
    Lost(String),
}

/// What [`ProcessMailbox::await_phase`] hands the worker: the phase
/// parameters, plus the database when the hub (re-)shipped one (`CONFIG`).
/// `db: None` means "mine the database you already hold" (`RECONFIG`).
pub struct PhaseStart {
    pub phase: PhaseSpec,
    pub db: Option<Database>,
}

/// Typed error for a bounded [`ProcessMailbox::await_phase_deadline`]
/// wait that elapsed: no phase frame (and no EOF) arrived within the
/// bound. Downcastable through `anyhow`, so callers that impose a
/// deadline can tell "the hub is silent" apart from a broken link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseWaitTimeout {
    /// The bound that elapsed.
    pub limit: Duration,
}

impl std::fmt::Display for PhaseWaitTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "no phase frame from the hub within {:.1}s (deadline elapsed)",
            self.limit.as_secs_f64()
        )
    }
}

impl std::error::Error for PhaseWaitTimeout {}

/// The worker-process endpoint of the fabric: the [`Mailbox`] the ordinary
/// [`crate::par::Worker`] state machine drives, plus the phase/merge
/// handshake. Obtain one with [`connect`]; drive phases with
/// [`ProcessMailbox::await_phase`].
pub struct ProcessMailbox {
    rank: usize,
    /// World size of the current phase (set by `await_phase`).
    size: usize,
    writer: Stream,
    rx: Receiver<ChildEvent>,
    /// Messages pulled in by a blocking wait (or buffered between `CONFIG`
    /// and `START`) but not yet consumed by the worker's probe loop.
    pending: VecDeque<(usize, Msg)>,
    link: Link,
    /// Peer endpoint map of the current phase; empty = hub data plane.
    peer_endpoints: Vec<Endpoint>,
    /// Lazily opened direct connections, cached for the fleet lifetime
    /// (warm fleets keep peer links across phases and jobs).
    peer_writers: Vec<Option<Stream>>,
    /// The fleet's shared-secret token, sent in every outgoing `PEERHELLO`.
    token: String,
    /// Hub-assigned index of the current phase (stamped onto every
    /// outgoing delivery, mesh or hub relay; adopted from each `START`).
    epoch: u64,
    /// One past the last adopted epoch.
    phases_started: u64,
    /// Worker-clock time at which the current phase's `START` frame was
    /// read — one half of the clock-alignment handshake shipped in the
    /// `TRACE` flush (DESIGN.md §14).
    start_recv_ns: u64,
    /// Deliveries (either plane) from an epoch *above* the current one,
    /// observed mid-phase: a peer already entered the replay of an aborted
    /// phase. Held for the next `await_phase` (DESIGN.md §12).
    future: VecDeque<(usize, u64, Msg)>,
    /// Phase frames (`CONFIG`/`RECONFIG`/`START`/`BYE`) that arrived
    /// mid-phase: the hub interrupting an aborted attempt. The worker loop
    /// polls [`ProcessMailbox::phase_interrupted`], abandons the attempt
    /// without merging, and `await_phase` replays these events in order.
    interrupt: VecDeque<ChildEvent>,
    /// Per-phase data-plane counters, reset at each `START`.
    hub_frames: u64,
    direct_frames: u64,
    _reader: JoinHandle<()>,
    _peer_listener: JoinHandle<()>,
}

/// Connect to the hub at `hub` as `rank`, authenticating with the fleet
/// `token`: dial the hub, bind this rank's own data-plane listener
/// (*before* `HELLO`, so the endpoint the hub learns is always
/// connectable), send `HELLO`, and hand the hub socket to a background
/// reader thread.
///
/// The data-plane listener binds at `peer_listen` when given (the
/// `--hosts` launcher passes each remote rank its advertised endpoint);
/// otherwise it is derived from the hub endpoint — `<path>.r<rank>` next
/// to a unix hub, or an ephemeral TCP port on whichever local interface
/// the dialed hub connection uses (that interface demonstrably routes to
/// the rest of the fleet's side of the network).
///
/// The worker then blocks in [`ProcessMailbox::await_phase`] until the
/// hub opens a phase — there is deliberately no read timeout, because a
/// warm worker legitimately idles between jobs for as long as the daemon
/// stays up; a dead hub surfaces as EOF, and hub heartbeat `PING`s are
/// answered even while idling, so the worker's lease stays fresh (v8).
pub fn connect(
    hub: &Endpoint,
    rank: usize,
    token: &str,
    peer_listen: Option<Endpoint>,
) -> Result<ProcessMailbox> {
    let mut stream = dial(hub, &RetryPolicy::default())
        .with_context(|| format!("connect to fabric hub at {hub}"))?;
    let listen_at = match (peer_listen, hub) {
        (Some(ep), _) => ep,
        (None, Endpoint::Unix(path)) => Endpoint::Unix(peer_sock_path(path, rank)),
        (None, Endpoint::Tcp(..)) => {
            let ip = stream
                .local_tcp_ip()
                .context("tcp hub connection reports no local address")?;
            Endpoint::Tcp(ip.to_string(), 0)
        }
    };
    if let Endpoint::Unix(path) = &listen_at {
        // A respawned rank reuses its predecessor's deterministic
        // `<hub>.r<rank>` path; the dead process never unlinked it, and a
        // bind over an existing socket file fails. Removing a stale path
        // is safe — the fleet owner only respawns a rank it saw die.
        let _ = std::fs::remove_file(path);
    }
    let peer_listener = Listener::bind(&listen_at)
        .with_context(|| format!("bind peer data-plane listener at {listen_at}"))?;
    let peer_endpoint = peer_listener.local_endpoint()?;
    let (tx, rx) = channel();
    let peer_tx = tx.clone();
    let expect_token = token.to_string();
    let peer_accept =
        std::thread::spawn(move || peer_accept_loop(peer_listener, peer_tx, expect_token));

    let hello =
        Frame::Hello { rank: rank as u32, token: token.to_string(), peer: peer_endpoint };
    write_frame(&mut stream, &hello).context("send HELLO")?;
    let reader_stream = stream.try_clone().context("clone fabric socket")?;
    let reader_tx = tx;
    let reader = std::thread::spawn(move || reader_loop(reader_stream, reader_tx));
    Ok(ProcessMailbox {
        rank,
        size: 0,
        writer: stream,
        rx,
        pending: VecDeque::new(),
        link: Link::Open,
        peer_endpoints: Vec::new(),
        peer_writers: Vec::new(),
        token: token.to_string(),
        epoch: 0,
        phases_started: 0,
        start_recv_ns: 0,
        future: VecDeque::new(),
        interrupt: VecDeque::new(),
        hub_frames: 0,
        direct_frames: 0,
        _reader: reader,
        _peer_listener: peer_accept,
    })
}

fn reader_loop(mut stream: Stream, tx: Sender<ChildEvent>) {
    loop {
        // A fired `stall` net-fault plan means this worker must stop
        // reading too (DESIGN.md §15): park so the hub's PINGs sit unread
        // and only the lease can notice. The process stays alive — the
        // force-kill that follows lease expiry ends it.
        if netfault::stalled() {
            netfault::park_forever();
        }
        let ev = match read_frame(&mut stream) {
            Ok(Some(Frame::Relay { peer, epoch, msg })) => {
                ChildEvent::Deliver { src: peer as usize, epoch, msg }
            }
            Ok(Some(Frame::Config { spec, peers })) => ChildEvent::Config { spec, peers },
            Ok(Some(Frame::Reconfig { phase, peers })) => ChildEvent::Reconfig { phase, peers },
            Ok(Some(Frame::Start { epoch })) => ChildEvent::Start(epoch),
            Ok(Some(Frame::Ping)) => ChildEvent::Ping,
            Ok(Some(Frame::Bye)) => {
                let _ = tx.send(ChildEvent::Bye);
                return;
            }
            Ok(Some(other)) => {
                let _ = tx.send(ChildEvent::Lost(format!(
                    "unexpected {} frame from hub",
                    other.name()
                )));
                return;
            }
            Ok(None) => {
                let _ = tx.send(ChildEvent::Lost("hub closed the connection".into()));
                return;
            }
            Err(e) => {
                let _ = tx.send(ChildEvent::Lost(format!("{e:#}")));
                return;
            }
        };
        if tx.send(ev).is_err() {
            return; // mailbox dropped
        }
    }
}

/// Accept incoming mesh connections for the mailbox lifetime. Each peer
/// opens with a `PEERHELLO`; a dedicated reader thread then feeds its
/// `PEERMSG` frames into the shared event channel. A peer connection that
/// EOFs or misbehaves is simply dropped — the hub link owns liveness, so a
/// dead peer is reported by the hub as `Gone`, never inferred here.
/// Transient `accept` failures (ECONNABORTED from a peer that died
/// mid-connect, EMFILE under descriptor pressure in a long-lived daemon)
/// must not kill the accept loop — a mesh-deaf worker would silently
/// black-hole steal traffic for the rest of the fleet lifetime — so they
/// are retried after a short sleep, mirroring the service listener. The
/// thread lives as long as the worker process (a worker's mailbox does
/// too; the process exits when the hub says `BYE`).
fn peer_accept_loop(listener: Listener, tx: Sender<ChildEvent>, token: String) {
    loop {
        match listener.accept() {
            Ok(stream) => {
                let tx = tx.clone();
                let token = token.clone();
                std::thread::spawn(move || peer_reader_loop(stream, tx, token));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Per-connection mesh reader. The `PEERHELLO` must carry the fleet
/// token — a stray connector (routine on a TCP listener) is dropped
/// before any of its frames reach the mailbox. The claimed source rank is
/// range-checked by the mailbox against the phase's world size (`absorb`
/// / `await_phase`), where that size is known — this thread only pins the
/// connection to one rank and rejects frames that contradict it.
fn peer_reader_loop(mut stream: Stream, tx: Sender<ChildEvent>, token: String) {
    let src = match read_frame(&mut stream) {
        Ok(Some(Frame::PeerHello { rank, token: got })) if got == token => rank as usize,
        // Wrong token, not a PEERHELLO, or malformed: drop the connection
        // without ever joining the mesh.
        _ => return,
    };
    loop {
        match read_frame(&mut stream) {
            Ok(Some(Frame::PeerMsg { src: claimed, epoch, msg }))
                if claimed as usize == src =>
            {
                if tx.send(ChildEvent::PeerDeliver { src, epoch, msg }).is_err() {
                    return; // mailbox dropped
                }
            }
            // EOF, a frame claiming a different source, or any protocol
            // error: the connection is useless; the sender will lazily
            // reconnect if it is still alive.
            _ => return,
        }
    }
}

impl ProcessMailbox {
    /// Block until the hub opens the next phase (`CONFIG`/`RECONFIG`
    /// followed by `START`) or dismisses the fleet (`BYE` → `None`).
    ///
    /// Stale deliveries from the finished phase are dropped; deliveries
    /// that belong to the upcoming phase (a peer that started earlier may
    /// already be stealing) are buffered until `START`. Both planes are
    /// fenced the same way: every delivery — a hub `RELAY` or a direct
    /// mesh frame — carries the epoch its sender stamped, and it is
    /// compared against the hub-assigned epoch the `START` frame carries.
    /// A frame below the opened phase's epoch is stale (it belongs to a
    /// finished phase or to an aborted attempt of this one); a frame *at*
    /// it belongs to the phase being opened (DESIGN.md §10, §12). FIFO
    /// order on the hub socket is deliberately *not* trusted as a fence:
    /// relays toward this rank are written by other ranks' route threads,
    /// which race the owner thread's RECONFIG once a phase can be aborted
    /// mid-flight. Since the hub assigns the epoch, a respawned worker
    /// inherits the fleet's numbering here without any local state.
    pub fn await_phase(&mut self) -> Result<Option<PhaseStart>> {
        self.await_phase_deadline(None)
    }

    /// [`ProcessMailbox::await_phase`] with an optional bound: when
    /// `limit` is `Some`, the whole wait (phase frame through `START`)
    /// must complete within it or a typed [`PhaseWaitTimeout`] error is
    /// returned. `worker_main` passes `None` — a warm serve worker
    /// legitimately idles between jobs for as long as the daemon stays
    /// up, and a dead hub surfaces as EOF — but embedders and tests that
    /// know a phase frame is due can bound the wait instead of wedging.
    pub fn await_phase_deadline(
        &mut self,
        limit: Option<Duration>,
    ) -> Result<Option<PhaseStart>> {
        if let Link::Lost(e) = &self.link {
            bail!("fabric link lost: {e}");
        }
        let deadline = limit.map(|d| (Instant::now() + d, d));
        self.pending.clear();
        // Early traffic for the upcoming phase. Every delivery — hub or
        // mesh — keeps its sender's epoch so it can be fenced once the
        // `START` frame names the phase. Frames already held over from an
        // interrupted attempt (see `absorb`) seed the buffer.
        let mut early: VecDeque<(usize, u64, Msg)> = std::mem::take(&mut self.future);
        // 1. The phase frame (buffering deliveries for the epoch fence).
        let (start, peers) = loop {
            match self.recv_event_until(deadline)? {
                ChildEvent::Config { spec, peers } => {
                    let RunSpec { phase, db } = *spec;
                    break (PhaseStart { phase, db: Some(db) }, peers);
                }
                ChildEvent::Reconfig { phase, peers } => {
                    break (PhaseStart { phase: *phase, db: None }, peers);
                }
                ChildEvent::Deliver { src, epoch, msg }
                | ChildEvent::PeerDeliver { src, epoch, msg } => {
                    early.push_back((src, epoch, msg));
                }
                ChildEvent::Ping => self.answer_ping(),
                ChildEvent::Bye => return Ok(None),
                ChildEvent::Start(_) => bail!("START from hub before CONFIG"),
                ChildEvent::Lost(e) => {
                    self.link = Link::Lost(e.clone());
                    bail!("fabric link lost awaiting phase: {e}");
                }
            }
        };
        ensure!(
            (self.rank as u32) < start.phase.p,
            "rank {} out of range for world size {}",
            self.rank,
            start.phase.p
        );
        self.size = start.phase.p as usize;
        self.set_peers(peers)?;
        // 2. The START barrier (buffering early next-phase traffic).
        let epoch = loop {
            match self.recv_event_until(deadline)? {
                ChildEvent::Start(epoch) => break epoch,
                ChildEvent::Deliver { src, epoch, msg }
                | ChildEvent::PeerDeliver { src, epoch, msg } => {
                    early.push_back((src, epoch, msg));
                }
                ChildEvent::Ping => self.answer_ping(),
                ChildEvent::Bye => bail!("BYE from hub between CONFIG and START"),
                ChildEvent::Config { .. } | ChildEvent::Reconfig { .. } => {
                    bail!("duplicate CONFIG from hub before START")
                }
                ChildEvent::Lost(e) => {
                    self.link = Link::Lost(e.clone());
                    bail!("fabric link lost awaiting START: {e}");
                }
            }
        };
        // Buffered frames were collected before the world size and the
        // phase epoch were known; validate both now, matching the in-phase
        // checks in `absorb`. Frames from an aborted attempt of this phase
        // carry a smaller epoch and are dropped here — that is the fence
        // that keeps a replayed phase's DTD counters clean.
        // Stamp the START receipt on this process's clock: paired with the
        // hub's write stamp it forms the request half of the clock-offset
        // handshake (the TRACE flush forms the reply half, DESIGN.md §14).
        self.start_recv_ns = clock::now_ns();
        early.retain(|(src, e, _)| *src < self.size && *e == epoch);
        self.pending = early.into_iter().map(|(src, _, msg)| (src, msg)).collect();
        self.epoch = epoch;
        self.phases_started = epoch + 1;
        self.hub_frames = 0;
        self.direct_frames = 0;
        Ok(Some(start))
    }

    /// Install the phase's peer endpoint map. Cached direct connections
    /// are kept when the map is unchanged (the warm-fleet case) and
    /// dropped when it differs (a respawned fleet binds fresh listeners).
    fn set_peers(&mut self, peers: Vec<Endpoint>) -> Result<()> {
        ensure!(
            peers.is_empty() || peers.len() == self.size,
            "peer map has {} entries for world size {}",
            peers.len(),
            self.size
        );
        if self.peer_endpoints != peers {
            self.peer_writers = (0..peers.len()).map(|_| None).collect();
            self.peer_endpoints = peers;
        }
        Ok(())
    }

    /// Receive the next phase-wait event, optionally bounded by a
    /// deadline (`(when, original_limit)` — the limit is echoed into the
    /// typed [`PhaseWaitTimeout`] error when the deadline elapses).
    fn recv_event_until(
        &mut self,
        deadline: Option<(Instant, Duration)>,
    ) -> Result<ChildEvent> {
        if let Some(ev) = self.interrupt.pop_front() {
            return Ok(ev);
        }
        match deadline {
            None => self.rx.recv().map_err(|_| anyhow::anyhow!("fabric reader thread exited")),
            Some((when, limit)) => {
                let left = when.saturating_duration_since(Instant::now());
                match self.rx.recv_timeout(left) {
                    Ok(ev) => Ok(ev),
                    Err(RecvTimeoutError::Timeout) => Err(PhaseWaitTimeout { limit }.into()),
                    Err(RecvTimeoutError::Disconnected) => {
                        bail!("fabric reader thread exited")
                    }
                }
            }
        }
    }

    /// Absorb an event mid-phase, when only deliveries are expected.
    fn absorb(&mut self, ev: ChildEvent) -> Option<(usize, Msg)> {
        match ev {
            // Frames from a finished phase (or an aborted attempt of this
            // one) can surface arbitrarily late — mesh deliveries ride
            // independent sockets with independent reader threads, and hub
            // relays written by another rank's route thread can land after
            // this rank's RECONFIG on the same socket — so anything below
            // the current epoch is stale and dropped. A frame *above* it
            // means a peer already entered the replay of a phase the hub
            // aborted while this rank has not seen its RECONFIG yet: hold
            // it for the next `await_phase` (dropping it would unbalance
            // the replay's DTD counters). The source rank is validated
            // against the world size here (the reader thread cannot know
            // it) — the mesh counterpart of the hub's out-of-range HELLO
            // rejection: a stray connector must not be able to poison the
            // DTD counters with unmatched messages.
            ChildEvent::Deliver { src, epoch, msg }
            | ChildEvent::PeerDeliver { src, epoch, msg } => {
                if src >= self.size {
                    return None;
                }
                if epoch == self.epoch {
                    return Some((src, msg));
                }
                if epoch > self.epoch {
                    self.future.push_back((src, epoch, msg));
                }
                None
            }
            ChildEvent::Ping => {
                self.answer_ping();
                None
            }
            ev @ (ChildEvent::Config { .. } | ChildEvent::Reconfig { .. }
            | ChildEvent::Start(_) | ChildEvent::Bye) => {
                // A phase frame mid-phase is the hub interrupting an
                // aborted attempt (a rank died; the owner is replaying the
                // phase — DESIGN.md §12) or dismissing the fleet. Stash it
                // in arrival order: the worker loop polls
                // `phase_interrupted`, abandons the attempt without
                // merging, and `await_phase` replays these events.
                self.interrupt.push_back(ev);
                None
            }
            ChildEvent::Lost(e) => {
                if self.link == Link::Open {
                    self.link = Link::Lost(e);
                }
                None
            }
        }
    }

    /// Did the hub interrupt the current phase (a `CONFIG`/`RECONFIG`/
    /// `START`/`BYE` arrived mid-phase)? The worker loop checks this each
    /// quantum and, when set, abandons the attempt *without* sending a
    /// merge — the hub aborted the phase because a rank died, and the
    /// whole phase is being replayed under a fresh epoch (DESIGN.md §12).
    pub fn phase_interrupted(&self) -> bool {
        !self.interrupt.is_empty()
    }

    /// The hub-assigned epoch of the current phase.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// One past the last adopted epoch (the fleet-wide phase count as of
    /// this rank's latest `START`).
    pub fn phases_started(&self) -> u64 {
        self.phases_started
    }

    /// Report a custody checkpoint to the hub: this rank's work-unit clock
    /// plus up to a handful of bottom-of-stack roots (DESIGN.md §12).
    /// Best-effort diagnostics — a write failure severs nothing here; the
    /// regular send path notices a dead hub soon enough.
    pub fn send_checkpoint(&mut self, work_units: u64, roots: Vec<WireTask>) {
        let frame = Frame::Checkpoint {
            rank: self.rank as u32,
            epoch: self.epoch,
            work_units,
            roots,
        };
        let _ = self.write_hub(&frame);
    }

    /// This phase's data-plane send counters: frames pushed through the
    /// hub relay and frames sent directly to peers. Reset at every
    /// `START`; the worker folds them into its `MERGE` so the hub-vs-mesh
    /// split is observable end to end ([`crate::fabric::CommStats`]).
    pub fn plane_counters(&self) -> (u64, u64) {
        (self.hub_frames, self.direct_frames)
    }

    /// Send `msg` over a lazily opened direct connection to `dst`; `true`
    /// = the frame was written. A write failure on a *cached* stream does
    /// not lose the frame: the stream may merely be stale (the receiver
    /// dropped one connection), so it is discarded and the same frame
    /// retried on a fresh connect (twice, with a short pause, to ride out
    /// transient refusals such as a momentarily full listener backlog).
    ///
    /// Exhausting the retries severs the link: a silently dropped frame to
    /// a live peer would permanently unbalance the Mattern send/receive
    /// counts — no `Gone` fires, termination is never detected, and the
    /// phase hangs forever. Failing loudly instead aborts this worker, the
    /// hub reports it `Gone`, and the fleet owner respawns — exactly the
    /// hub plane's write-failure semantics. (If the *peer* was the dead
    /// one, its own `Gone` had already doomed the phase anyway.)
    fn send_direct(&mut self, dst: usize, msg: Msg) -> bool {
        if dst >= self.peer_writers.len() {
            self.link = Link::Lost(format!("direct send to out-of-range rank {dst}"));
            return false;
        }
        let frame = Frame::PeerMsg { src: self.rank as u32, epoch: self.epoch, msg };
        if let Some(w) = self.peer_writers[dst].as_mut() {
            if write_frame(w, &frame).is_ok() {
                return true;
            }
            self.peer_writers[dst] = None; // stale stream: retry fresh below
        }
        for attempt in 0..2 {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(10));
            }
            if let Ok(mut stream) = self.open_peer(dst) {
                if write_frame(&mut stream, &frame).is_ok() {
                    self.peer_writers[dst] = Some(stream);
                    return true;
                }
            }
        }
        self.link =
            Link::Lost(format!("direct send to rank {dst} failed after reconnect attempts"));
        false
    }

    /// Open a fresh direct connection to `dst`: one dial (the outer
    /// `send_direct` loop owns retries, so the policy is single-attempt)
    /// with the `PEERHELLO` handshake as the preamble.
    fn open_peer(&self, dst: usize) -> Result<Stream> {
        let hello =
            Frame::PeerHello { rank: self.rank as u32, token: self.token.clone() }.encode();
        dial_with_preamble(&self.peer_endpoints[dst], &RetryPolicy::once(), &hello)
    }

    /// The error that severed the hub link, if any. The worker loop checks
    /// this each quantum and aborts the run — without a hub there is no
    /// termination detection, so spinning would hang forever.
    pub fn lost(&self) -> Option<&str> {
        match &self.link {
            Link::Lost(e) => Some(e),
            Link::Open => None,
        }
    }

    /// Block until a message arrives (buffered for the next `try_recv`) or
    /// the timeout elapses — used by idle workers so they wake on incoming
    /// GIVEs without spinning. Returns whether a message arrived.
    pub fn wait_for_msg(&mut self, d: Duration) -> bool {
        if !self.pending.is_empty() {
            return true;
        }
        match self.rx.recv_timeout(d) {
            Ok(ev) => match self.absorb(ev) {
                Some(m) => {
                    self.pending.push_back(m);
                    true
                }
                None => false,
            },
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => false,
        }
    }

    /// Send the phase-boundary merge after the worker saw `Finish`. The
    /// worker must send nothing else until its next phase starts — the
    /// between-phase protocol relies on `MERGE` ending a phase's data
    /// traffic (see the module docs) — with one carve-out: an optional
    /// [`ProcessMailbox::send_trace`] flush immediately after.
    pub fn send_merge(&mut self, merge: &WorkerMerge) -> Result<()> {
        self.write_hub(&Frame::Merge(Box::new(merge.clone()))).context("send MERGE to hub")
    }

    /// Flush the rank's event ring to the hub as a `TRACE` frame (v7),
    /// immediately after [`ProcessMailbox::send_merge`] when the phase ran
    /// with tracing armed. Best-effort, like checkpoints: a lost trace
    /// costs a timeline, never a result. The chunk carries this phase's
    /// `START`-receipt stamp and a flush stamp taken here, both on this
    /// process's clock — the hub pairs them with its own send/receive
    /// stamps to estimate the rank's clock offset (DESIGN.md §14).
    pub fn send_trace(&mut self, events: Vec<TraceEvent>, dropped: u64) {
        let chunk = TraceChunk {
            rank: self.rank as u32,
            epoch: self.epoch,
            start_recv_ns: self.start_recv_ns,
            flush_ns: clock::now_ns(),
            dropped,
            events,
        };
        let _ = self.write_hub(&Frame::Trace(Box::new(chunk)));
    }

    /// Answer a hub heartbeat probe with `PONG`. Called from the *main*
    /// thread only (`absorb` / `await_phase_deadline`), never from the
    /// reader: the answer then attests whole-worker liveness, so a rank
    /// whose reader still drains frames but whose main thread is hung or
    /// partitioned stops answering and misses its lease (DESIGN.md §15).
    fn answer_ping(&mut self) {
        let _ = self.write_hub(&Frame::Pong);
    }

    /// Every hub-bound write funnels through here so the deterministic
    /// net-fault layer ([`crate::net::fault`], DESIGN.md §15) can
    /// interpose: a fired `drop` plan silently discards the frame (the
    /// worker keeps mining while its merges and PONGs vanish — only the
    /// lease can notice), a fired `corrupt` plan flips the next frame's
    /// tag byte (the hub's decoder errors deterministically and declares
    /// this rank `Gone`). With no fault armed this is exactly
    /// [`write_frame`].
    fn write_hub(&mut self, frame: &Frame) -> Result<()> {
        match netfault::hub_write() {
            netfault::HubWrite::Forward => write_frame(&mut self.writer, frame),
            netfault::HubWrite::Discard => Ok(()),
            netfault::HubWrite::Corrupt => {
                let mut bytes = frame.encode();
                netfault::corrupt_frame_bytes(&mut bytes);
                self.writer.write_all(&bytes)?;
                Ok(())
            }
        }
    }
}

impl Mailbox for ProcessMailbox {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        if self.link != Link::Open {
            return; // shutdown race: mirror the dropped-peer no-op
        }
        // Deterministic net-fault trigger (DESIGN.md §15): an armed plan
        // counts this worker's data-plane sends within its target phase,
        // so the injected failure lands at the same frame on every run —
        // scripted by frame counts, never by wall time.
        if let Some(plan) = netfault::on_data_frame(self.epoch) {
            log::warn(
                "worker",
                &Tags::rank(self.rank),
                format_args!("net fault injection firing ({plan})"),
            );
            match plan.kind {
                // Stall/partition: the main thread wedges right here, so
                // PONGs stop and the hub's lease expires. (A stall also
                // parks the reader thread — see `reader_loop`.)
                netfault::NetFaultKind::Stall | netfault::NetFaultKind::Partition => {
                    netfault::park_forever()
                }
                // Drop/corrupt act on the write path (`write_hub`); the
                // worker keeps running.
                netfault::NetFaultKind::Drop | netfault::NetFaultKind::Corrupt => {}
            }
        }
        // The plane counters record frames actually written, so a failed
        // send (which severs the link) never inflates them.
        if !self.peer_endpoints.is_empty() {
            // Mesh data plane: worker-to-worker, zero hub hops.
            if self.send_direct(dst, msg) {
                self.direct_frames += 1;
            }
            return;
        }
        let frame = Frame::Relay { peer: dst as u32, epoch: self.epoch, msg };
        match self.write_hub(&frame) {
            Ok(()) => self.hub_frames += 1,
            Err(e) => self.link = Link::Lost(format!("send to hub failed: {e}")),
        }
    }

    fn try_recv(&mut self) -> Option<(usize, Msg)> {
        if let Some(m) = self.pending.pop_front() {
            return Some(m);
        }
        while let Ok(ev) = self.rx.try_recv() {
            if let Some(m) = self.absorb(ev) {
                return Some(m);
            }
            if self.link != Link::Open {
                return None;
            }
        }
        None
    }
}

// ---- hub (parent) side -----------------------------------------------------

/// What the hub reports to the engine while a phase runs.
#[derive(Debug)]
pub enum HubEvent {
    /// A worker delivered its phase-boundary merge.
    Merge(WorkerMerge),
    /// A worker's connection ended — orderly EOF after the `BYE`, or a
    /// crash/protocol violation. The detail embeds the route thread's
    /// context (the rank's last delivered epoch, how many frames the
    /// connection carried and the name of the last one) plus the rank's
    /// last custody checkpoint, so a chaos-test failure or a production
    /// crash is diagnosable from the error string alone. A `Gone` during
    /// an active phase aborts that *attempt* only: the owner forgets the
    /// rank, respawns it, and replays the phase under a fresh epoch
    /// (DESIGN.md §12); orderly post-`BYE` EOFs arrive only after the
    /// engine has stopped listening.
    Gone { rank: usize, detail: String },
    /// A worker flushed its per-rank event ring (v7): the decoded chunk
    /// plus the hub-clock time the frame was read. Paired with the hub's
    /// `START`-write stamp ([`Hub::start_sent_ns`]) and the chunk's two
    /// worker-clock stamps, this forms one NTP-style handshake sample for
    /// [`crate::obs::clock::estimate_offset`].
    Trace { chunk: TraceChunk, hub_recv_ns: u64 },
}

/// The hub's view of what one rank last reported holding (DESIGN.md §12):
/// refreshed by each `CHECKPOINT` frame, plus a count of the GIVE frames
/// the hub itself relayed *from* the rank (hub data plane only — mesh
/// GIVEs never pass the hub, so there checkpoints are the only custody
/// source). This is diagnostics for crash reports and lost-work estimates;
/// recovery replays the phase from its inputs rather than trusting this
/// necessarily-stale view (§12's DTD reconciliation argument).
#[derive(Clone, Debug, Default)]
pub struct Custody {
    /// Epoch of the last checkpoint observed.
    pub epoch: u64,
    /// The rank's work-unit clock at that checkpoint.
    pub work_units: u64,
    /// The bottom-of-stack roots it reported still holding.
    pub roots: Vec<WireTask>,
    /// GIVE frames the hub has relayed from this rank (hub plane only).
    pub gives_routed: u64,
    /// Tasks shipped in those relayed GIVEs.
    pub tasks_routed: u64,
}

/// Per-rank write halves, shared between the hub and its route threads.
type Writers = Arc<Vec<Mutex<Option<Stream>>>>;

/// Per-rank custody table, shared the same way.
type Custodies = Arc<Vec<Mutex<Custody>>>;

/// Per-rank heartbeat lease table (v8, DESIGN.md §15): `Some(t)` = the
/// rank's route thread last read a frame from it at `t`; `None` = slot
/// vacant. Shared between the hub (pings, expiry checks) and its route
/// threads (touch on every frame).
type Leases = Arc<Vec<Mutex<Option<Instant>>>>;

/// Parent-side fabric endpoint: accepts worker connections, runs one route
/// thread per worker, opens phases, and surfaces merges. Owned and driven
/// by [`crate::par::engine_process::ProcessFleet`].
pub struct Hub {
    listener: Listener,
    /// The endpoint the listener is actually bound at (ephemeral TCP
    /// ports resolved).
    endpoint: Endpoint,
    p: usize,
    /// The fleet's shared-secret token; a `HELLO` carrying anything else
    /// is rejected before the connection touches any per-rank state.
    token: String,
    writers: Writers,
    custody: Custodies,
    /// Heartbeat leases (v8): touched by each rank's route thread on every
    /// frame it reads (`PONG` or otherwise), inspected by the fleet owner
    /// via [`Hub::lease_age`]. DESIGN.md §15.
    leases: Leases,
    /// One-shot per-rank flags armed by [`Hub::mark_expected_eof`] just
    /// before the owner force-kills a lease-expired rank: the kill makes
    /// the route thread read EOF, and without the flag it would report a
    /// second `Gone` for a death the owner already synthesized — which
    /// would double-respawn the rank.
    expect_eof: Arc<Vec<AtomicBool>>,
    events_tx: Sender<HubEvent>,
    events_rx: Receiver<HubEvent>,
    routers: Vec<JoinHandle<()>>,
    connected: usize,
    /// Each rank's own data-plane endpoint, learned from its `HELLO`.
    peer_endpoints: Vec<Option<Endpoint>>,
    /// Hub-clock stamp of each rank's last `START` write — one half of
    /// the clock-alignment handshake (DESIGN.md §14).
    start_sent_ns: Vec<u64>,
}

impl Hub {
    /// Bind the hub listener at `ep` for a world of `p` ranks,
    /// authenticated by `token`.
    pub fn bind(ep: &Endpoint, p: usize, token: String) -> Result<Hub> {
        ensure!(p >= 1, "world size must be ≥ 1");
        let listener =
            Listener::bind(ep).with_context(|| format!("bind fabric hub at {ep}"))?;
        let endpoint = listener.local_endpoint()?;
        listener.set_nonblocking(true).context("set hub listener non-blocking")?;
        let (events_tx, events_rx) = channel();
        Ok(Hub {
            listener,
            endpoint,
            p,
            token,
            writers: Arc::new((0..p).map(|_| Mutex::new(None)).collect()),
            custody: Arc::new((0..p).map(|_| Mutex::new(Custody::default())).collect()),
            leases: Arc::new((0..p).map(|_| Mutex::new(None)).collect()),
            expect_eof: Arc::new((0..p).map(|_| AtomicBool::new(false)).collect()),
            events_tx,
            events_rx,
            routers: Vec::with_capacity(p),
            connected: 0,
            peer_endpoints: vec![None; p],
            start_sent_ns: vec![0; p],
        })
    }

    /// The endpoint workers must dial — the bind endpoint with any
    /// ephemeral TCP port resolved to the one the OS picked.
    pub fn endpoint(&self) -> &Endpoint {
        &self.endpoint
    }

    /// The fleet's shared-secret token: what every joining worker must
    /// present in its `HELLO` (and peers in their `PEERHELLO`s).
    pub fn token(&self) -> &str {
        &self.token
    }

    /// Ranks that have completed the `HELLO` handshake so far.
    pub fn connected(&self) -> usize {
        self.connected
    }

    /// The mesh peer endpoint map: every rank's own data-plane endpoint
    /// in rank order, as reported in the `HELLO` handshakes. Errors until
    /// the whole fleet has connected.
    pub fn peer_map(&self) -> Result<Vec<Endpoint>> {
        self.peer_endpoints
            .iter()
            .enumerate()
            .map(|(rank, p)| {
                p.clone().with_context(|| format!("rank {rank} has not completed HELLO"))
            })
            .collect()
    }

    /// The last custody checkpoint the hub holds for `rank` (the default
    /// empty [`Custody`] before any checkpoint arrived).
    pub fn custody(&self, rank: usize) -> Custody {
        self.custody[rank].lock().expect("custody lock").clone()
    }

    /// Forget a dead rank after a [`HubEvent::Gone`]: clear its writer and
    /// peer endpoint so a replacement worker can `HELLO` into the vacant
    /// slot (see [`Hub::try_accept`] — the duplicate-HELLO rejection only
    /// guards *occupied* slots). The custody entry is kept: it describes
    /// what died. The rank's route thread has already exited by the time
    /// its `Gone` surfaces, so there is nothing to stop here.
    pub fn forget_rank(&mut self, rank: usize) {
        let had = self.writers[rank].lock().expect("writer lock").take().is_some();
        if had {
            self.connected -= 1;
        }
        self.peer_endpoints[rank] = None;
        *self.leases[rank].lock().expect("lease lock") = None;
    }

    /// Accept and handshake at most one pending worker connection. Returns
    /// whether one was accepted. Non-blocking: the engine interleaves this
    /// with liveness checks on the spawned processes. A rank whose slot
    /// was vacated by [`Hub::forget_rank`] re-registers here exactly like
    /// a first connection — that is the respawn path.
    pub fn try_accept(&mut self) -> Result<bool> {
        let mut stream = match self.listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) => return Err(e).context("accept worker connection"),
        };
        stream.set_nonblocking(false).context("set worker socket blocking")?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let frame = read_frame(&mut stream)?.context("worker closed during handshake")?;
        let (rank, token, peer) = match frame {
            Frame::Hello { rank, token, peer } => (rank as usize, token, peer),
            other => bail!("expected HELLO from worker, got {}", other.name()),
        };
        ensure!(
            token == self.token,
            "HELLO with bad auth token (a stray connection, or a worker from another fleet)"
        );
        ensure!(rank < self.p, "HELLO rank {rank} out of range for world size {}", self.p);
        // Post-handshake reads are deliberately unbounded: liveness is
        // owned by socket EOF plus the v8 heartbeat lease (the route
        // thread touches [`Hub::lease_age`]'s table on every frame), not
        // by read timeouts — an idle warm worker is healthy, not dead.
        stream.set_read_timeout(None)?;
        let reader = stream.try_clone().context("clone worker socket")?;
        {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            ensure!(slot.is_none(), "duplicate HELLO for rank {rank}");
            *slot = Some(stream);
        }
        self.peer_endpoints[rank] = Some(peer);
        *self.leases[rank].lock().expect("lease lock") = Some(Instant::now());
        let writers = Arc::clone(&self.writers);
        let custody = Arc::clone(&self.custody);
        let leases = Arc::clone(&self.leases);
        let expect_eof = Arc::clone(&self.expect_eof);
        let tx = self.events_tx.clone();
        let p = self.p;
        self.routers.push(std::thread::spawn(move || {
            route_loop(rank, reader, writers, custody, leases, expect_eof, tx, p)
        }));
        self.connected += 1;
        Ok(true)
    }

    /// Write pre-encoded frame bytes to every registered rank.
    fn broadcast_bytes(&mut self, bytes: &[u8], what: &str) -> Result<()> {
        ensure!(
            self.connected == self.p,
            "cannot {what}: {}/{} workers connected",
            self.connected,
            self.p
        );
        for rank in 0..self.p {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            let w = slot
                .as_mut()
                .with_context(|| format!("rank {rank} disconnected before {what}"))?;
            w.write_all(bytes).with_context(|| format!("{what} to rank {rank}"))?;
        }
        Ok(())
    }

    /// Open a phase by shipping the full run specification — phase
    /// parameters *plus* database — to every rank. `peers` selects the
    /// data plane: the mesh peer endpoint map ([`Hub::peer_map`]) for
    /// direct worker-to-worker traffic, or empty for the hub relay. Use
    /// [`Hub::broadcast_reconfig`] instead when the workers already hold
    /// the database (the warm-fleet fast path).
    pub fn broadcast_config(&mut self, spec: &RunSpec, peers: &[Endpoint]) -> Result<()> {
        let bytes = encode_config_checked(spec, peers)?;
        self.broadcast_bytes(&bytes, "send CONFIG")
    }

    /// Open a phase over the database the workers already hold: ships the
    /// phase parameters (plus the peer map, as in [`Hub::broadcast_config`])
    /// only — a ~60-byte frame instead of the serialized database.
    pub fn broadcast_reconfig(&mut self, phase: &PhaseSpec, peers: &[Endpoint]) -> Result<()> {
        let frame = Frame::Reconfig { phase: Box::new(phase.clone()), peers: peers.to_vec() };
        self.broadcast_bytes(&frame.encode(), "send RECONFIG")
    }

    /// Write pre-encoded frame bytes to one registered rank — the
    /// recovery path's per-rank counterpart of [`Hub::broadcast_bytes`]:
    /// a replayed phase mixes `CONFIG` (to the database-less replacement)
    /// with `RECONFIG` (to the survivors), so a uniform broadcast cannot
    /// express it (DESIGN.md §12).
    fn send_bytes_to(&mut self, rank: usize, bytes: &[u8], what: &str) -> Result<()> {
        let mut slot = self.writers[rank].lock().expect("writer lock");
        let w = slot
            .as_mut()
            .with_context(|| format!("rank {rank} disconnected before {what}"))?;
        w.write_all(bytes).with_context(|| format!("{what} to rank {rank}"))
    }

    /// Ship the full run specification — phase parameters plus database —
    /// to a single rank (a respawned worker holds no database).
    pub fn send_config_to(
        &mut self,
        rank: usize,
        spec: &RunSpec,
        peers: &[Endpoint],
    ) -> Result<()> {
        let bytes = encode_config_checked(spec, peers)?;
        self.send_bytes_to(rank, &bytes, "send CONFIG")
    }

    /// Ship the phase parameters alone to a single rank (a survivor of an
    /// aborted phase already holds the database).
    pub fn send_reconfig_to(
        &mut self,
        rank: usize,
        phase: &PhaseSpec,
        peers: &[Endpoint],
    ) -> Result<()> {
        let frame = Frame::Reconfig { phase: Box::new(phase.clone()), peers: peers.to_vec() };
        self.send_bytes_to(rank, &frame.encode(), "send RECONFIG")
    }

    /// Release the phase barrier: broadcast `START` carrying the
    /// hub-assigned phase `epoch` the workers adopt (monotonic across
    /// jobs, replays, and respawns — the owner owns the counter). Call
    /// only after [`Hub::broadcast_config`] / [`Hub::broadcast_reconfig`]
    /// (or their per-rank variants) for this phase.
    pub fn start_all(&mut self, epoch: u64) -> Result<()> {
        ensure!(
            self.connected == self.p,
            "cannot send START: {}/{} workers connected",
            self.connected,
            self.p
        );
        let bytes = Frame::Start { epoch }.encode();
        for rank in 0..self.p {
            // Stamp the hub clock right before each rank's write: with the
            // worker's receipt stamp (shipped back in its TRACE flush) this
            // is the request half of the clock-offset handshake.
            self.start_sent_ns[rank] = clock::now_ns();
            let mut slot = self.writers[rank].lock().expect("writer lock");
            let w = slot
                .as_mut()
                .with_context(|| format!("rank {rank} disconnected before send START"))?;
            w.write_all(&bytes).with_context(|| format!("send START to rank {rank}"))?;
        }
        Ok(())
    }

    /// Hub-clock stamp of `rank`'s last `START` write (0 before the first
    /// phase). Pairs with the worker-clock stamps in the rank's `TRACE`
    /// flush for [`crate::obs::clock::estimate_offset`].
    pub fn start_sent_ns(&self, rank: usize) -> u64 {
        self.start_sent_ns[rank]
    }

    /// Wait up to `timeout` for the next hub event. `Ok(None)` = timeout.
    pub fn recv_event(&self, timeout: Duration) -> Result<Option<HubEvent>> {
        match self.events_rx.recv_timeout(timeout) {
            Ok(ev) => Ok(Some(ev)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // All route threads gone without the engine collecting P merges.
            Err(RecvTimeoutError::Disconnected) => bail!("all fabric route threads exited"),
        }
    }

    /// Broadcast a heartbeat probe (`PING`, v8) to every connected rank.
    /// Write errors are ignored — a dead rank's EOF is already in flight,
    /// and a stalled one is exactly what the lease exists to catch. PINGs
    /// are tiny (5 bytes encoded), so even a peer that stopped reading
    /// leaves socket-buffer room for every probe a lease window can hold.
    pub fn ping_all(&mut self) {
        let bytes = Frame::Ping.encode();
        for slot in self.writers.iter() {
            if let Some(w) = slot.lock().expect("writer lock").as_mut() {
                let _ = w.write_all(&bytes);
            }
        }
    }

    /// Age of `rank`'s heartbeat lease: time since its route thread last
    /// read *any* frame from it (`None` = slot vacant). The fleet owner
    /// compares this against its lease timeout and force-kills a rank
    /// whose lease expired mid-phase (DESIGN.md §15).
    pub fn lease_age(&self, rank: usize) -> Option<Duration> {
        self.leases[rank].lock().expect("lease lock").map(|t| t.elapsed())
    }

    /// Re-seed every connected rank's lease. The fleet owner calls this at
    /// each phase start: between phases (an idle warm fleet in `parlamp
    /// serve`) no traffic flows and leases go stale legitimately — they
    /// measure liveness only while a phase is running.
    pub fn reset_leases(&mut self) {
        for (rank, lease) in self.leases.iter().enumerate() {
            let connected = self.writers[rank].lock().expect("writer lock").is_some();
            *lease.lock().expect("lease lock") = connected.then(Instant::now);
        }
    }

    /// Arm `rank`'s one-shot expected-EOF flag. Call *before* force-killing
    /// a lease-expired rank: the kill makes its route thread read EOF, and
    /// the flag makes that thread swallow the event instead of reporting a
    /// `Gone` the owner has already synthesized (see `route_loop`).
    pub fn mark_expected_eof(&self, rank: usize) {
        self.expect_eof[rank].store(true, Ordering::SeqCst);
    }

    /// Broadcast `BYE`: no further phases; the fleet exits. Send errors are
    /// ignored: a worker that already exited has nothing left to
    /// acknowledge.
    pub fn broadcast_bye(&mut self) {
        let bytes = Frame::Bye.encode();
        for slot in self.writers.iter() {
            if let Some(w) = slot.lock().expect("writer lock").as_mut() {
                let _ = w.write_all(&bytes);
            }
        }
    }

    /// Join the route threads (they exit at worker-socket EOF). Call after
    /// [`Hub::broadcast_bye`] and after the worker processes were reaped —
    /// never while workers may still be running.
    pub fn join(&mut self) {
        for h in self.routers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Helper for the CONFIG frame-size guard shared by the broadcast and
/// per-rank paths.
fn encode_config_checked(spec: &RunSpec, peers: &[Endpoint]) -> Result<Vec<u8>> {
    let bytes = encode_config(spec, peers);
    ensure!(
        bytes.len() - 4 <= MAX_FRAME_LEN as usize,
        "CONFIG frame ({} bytes) exceeds the {MAX_FRAME_LEN}-byte frame cap; \
         the database is too large for the process fabric's wire format",
        bytes.len() - 4
    );
    Ok(bytes)
}

/// Per-worker route thread: forward `RELAY` frames to their destination
/// rank (stamping the source), record `CHECKPOINT` custody reports,
/// surface `MERGE` and disconnection. Lives for one connection — a
/// respawned rank gets a fresh route thread from its new `HELLO`. The
/// thread keeps connection-scoped context (frames carried, last frame
/// name, last delivered epoch) and folds it plus the rank's last custody
/// checkpoint into the `Gone` detail (DESIGN.md §12): a crash must be
/// diagnosable from the error string alone.
fn route_loop(
    rank: usize,
    mut reader: Stream,
    writers: Writers,
    custody: Custodies,
    leases: Leases,
    expect_eof: Arc<Vec<AtomicBool>>,
    tx: Sender<HubEvent>,
    p: usize,
) {
    let mut frames: u64 = 0;
    let mut last_frame: &'static str = "none";
    let mut last_epoch: u64 = 0;
    let cause: String = loop {
        let frame = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => break "EOF".into(),
            Err(e) => break format!("{e:#}"),
        };
        // Any frame is proof of life: touch the rank's heartbeat lease
        // (v8). PONGs exist for ranks with nothing else to say.
        *leases[rank].lock().expect("lease lock") = Some(Instant::now());
        frames += 1;
        last_frame = frame.name();
        match frame {
            Frame::Relay { peer, epoch, msg } => {
                let dst = peer as usize;
                if dst >= p {
                    break format!("relayed to out-of-range rank {dst}");
                }
                last_epoch = epoch;
                // Custody bookkeeping: a GIVE relayed through the hub
                // moves subtree roots off this rank (hub plane only; mesh
                // GIVEs are counted by the sender's next checkpoint).
                if let Msg::Basic { kind: BasicKind::Give { tasks }, .. } = &msg {
                    let mut c = custody[rank].lock().expect("custody lock");
                    c.gives_routed += 1;
                    c.tasks_routed += tasks.len() as u64;
                }
                let frame = Frame::Relay { peer: rank as u32, epoch, msg };
                let mut slot = writers[dst].lock().expect("writer lock");
                if let Some(w) = slot.as_mut() {
                    // A failed forward means the destination already exited;
                    // drop it like the thread fabric drops sends to a
                    // finished peer.
                    let _ = write_frame(w, &frame);
                }
            }
            Frame::Checkpoint { rank: r, epoch, work_units, roots } => {
                if r as usize != rank {
                    break format!("CHECKPOINT claims rank {r} on rank {rank}'s connection");
                }
                last_epoch = epoch;
                let mut c = custody[rank].lock().expect("custody lock");
                c.epoch = epoch;
                c.work_units = work_units;
                c.roots = roots;
            }
            Frame::Merge(m) => {
                if m.rank as usize != rank {
                    break format!("MERGE claims rank {} on rank {rank}'s connection", m.rank);
                }
                last_epoch = m.epoch;
                if tx.send(HubEvent::Merge(*m)).is_err() {
                    return; // engine gone
                }
                // Keep reading: the next phase's relays and merge arrive on
                // this same connection.
            }
            Frame::Trace(c) => {
                if c.rank as usize != rank {
                    break format!("TRACE claims rank {} on rank {rank}'s connection", c.rank);
                }
                last_epoch = c.epoch;
                // Stamp the read on the hub clock: the reply half of the
                // clock-offset handshake (DESIGN.md §14).
                let ev = HubEvent::Trace { chunk: *c, hub_recv_ns: clock::now_ns() };
                if tx.send(ev).is_err() {
                    return; // engine gone
                }
            }
            // Heartbeat answer (v8): liveness only — the lease touch above
            // is its entire effect. Never forwarded, never counted as a
            // data-plane frame.
            Frame::Pong => {}
            other => break format!("unexpected {} frame", other.name()),
        }
    };
    let (ck_units, ck_roots) = {
        let c = custody[rank].lock().expect("custody lock");
        (c.work_units, c.roots.len())
    };
    let detail = format!(
        "{cause}; last delivered epoch {last_epoch}, {frames} frames on this connection \
         (last: {last_frame}); custody at last checkpoint: {ck_units} work units, \
         {ck_roots} stack roots"
    );
    // A rank the owner just force-killed (lease expiry) lands here via the
    // EOF its kill produced. The owner already synthesized that rank's
    // loss and is respawning it — a second `Gone` would double-respawn.
    if expect_eof[rank].swap(false, Ordering::SeqCst) {
        return;
    }
    let _ = tx.send(HubEvent::Gone { rank, detail });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Database;
    use crate::fabric::BasicKind;
    use crate::par::worker::RunMode;

    fn tiny_phase(p: u32, seed: u64) -> PhaseSpec {
        PhaseSpec {
            p,
            seed,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: false,
            trace: false,
            probe_budget_units: 1000,
            dtd_interval_ns: 1000,
            mode: RunMode::Count { min_sup: 1 },
        }
    }

    fn tiny_spec(p: u32) -> RunSpec {
        let trans = vec![vec![0, 1], vec![1]];
        let db = Database::from_transactions(2, &trans, &[true, false]);
        RunSpec { phase: tiny_phase(p, 1), db }
    }

    fn test_sock(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("parlamp-fabtest-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("hub.sock")
    }

    fn test_ep(tag: &str) -> Endpoint {
        Endpoint::unix(test_sock(tag))
    }

    const TOKEN: &str = "fabtest-fleet-token";

    fn merge_for(rank: u32) -> WorkerMerge {
        WorkerMerge {
            rank,
            epoch: 0,
            hist: vec![(1, 2)],
            closed_count: 2,
            work_units: 10,
            breakdown: Default::default(),
            comm: Default::default(),
            makespan_ns: 5,
        }
    }

    /// Drive `try_accept` until all `want` workers have registered.
    fn accept_all(hub: &mut Hub, want: usize) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while hub.connected() < want {
            if !hub.try_accept().unwrap() {
                assert!(Instant::now() < deadline, "workers never connected");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }

    fn collect_merges(hub: &Hub, want: usize) {
        let mut got = 0;
        while got < want {
            match hub.recv_event(Duration::from_secs(10)).unwrap() {
                Some(HubEvent::Merge(_)) => got += 1,
                Some(HubEvent::Trace { .. }) => {} // optional flush, not counted
                Some(HubEvent::Gone { rank, detail }) => {
                    panic!("rank {rank} gone before merge: {detail}")
                }
                None => panic!("timed out waiting for merges"),
            }
        }
    }

    /// Two in-process "workers" on real sockets, across TWO phases on the
    /// same warm connections: phase 1 opens with `CONFIG` (database
    /// shipped), phase 2 with `RECONFIG` (database reused). Messages are
    /// routed both ways in each phase; `BYE` ends the loop.
    #[test]
    fn warm_hub_runs_two_phases_reusing_the_database() {
        let sock = test_ep("route");
        let mut hub = Hub::bind(&sock, 2, TOKEN.into()).unwrap();

        let spawn_worker = |rank: usize, sock: Endpoint| {
            std::thread::spawn(move || -> Result<()> {
                let mut mb = connect(&sock, rank, TOKEN, None)?;
                let mut phases = 0u32;
                while let Some(start) = mb.await_phase()? {
                    assert_eq!(start.phase.p, 2);
                    assert_eq!(mb.rank(), rank);
                    assert_eq!(mb.size(), 2);
                    match phases {
                        0 => assert!(start.db.is_some(), "first phase must ship the db"),
                        _ => assert!(start.db.is_none(), "reconfig must not re-ship the db"),
                    }
                    assert_eq!(start.phase.seed, u64::from(phases) + 1);
                    let peer = 1 - rank;
                    mb.send(peer, Msg::WaveDown { t: rank as u64, lambda: 7 + phases });
                    // await the peer's message
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        assert!(Instant::now() < deadline, "no message from peer");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    assert_eq!(got.0, peer, "source must be stamped by the hub");
                    assert!(
                        matches!(got.1, Msg::WaveDown { lambda, .. } if lambda == 7 + phases)
                    );
                    mb.send_merge(&merge_for(rank as u32))?;
                    phases += 1;
                }
                assert_eq!(phases, 2, "worker must have served both phases");
                Ok(())
            })
        };
        let w0 = spawn_worker(0, sock.clone());
        let w1 = spawn_worker(1, sock.clone());

        accept_all(&mut hub, 2);
        // Phase 1: full CONFIG.
        hub.broadcast_config(&tiny_spec(2), &[]).unwrap();
        hub.start_all(0).unwrap();
        collect_merges(&hub, 2);
        // Phase 2: RECONFIG over the resident database.
        hub.broadcast_reconfig(&tiny_phase(2, 2), &[]).unwrap();
        hub.start_all(1).unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        w1.join().unwrap().unwrap();
        hub.join();
    }

    /// The same two-phase warm exchange over the MESH data plane: the hub
    /// distributes the peer socket map, workers talk directly, and the
    /// per-phase plane counters show zero hub-relayed frames.
    #[test]
    fn warm_mesh_runs_two_phases_with_direct_peer_traffic() {
        let sock = test_ep("mesh");
        let mut hub = Hub::bind(&sock, 2, TOKEN.into()).unwrap();

        let spawn_worker = |rank: usize, sock: Endpoint| {
            std::thread::spawn(move || -> Result<()> {
                let mut mb = connect(&sock, rank, TOKEN, None)?;
                let mut phases = 0u32;
                while let Some(start) = mb.await_phase()? {
                    assert_eq!(start.phase.p, 2);
                    let peer = 1 - rank;
                    mb.send(peer, Msg::WaveDown { t: rank as u64, lambda: 7 + phases });
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        assert!(Instant::now() < deadline, "no message from peer");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    assert_eq!(got.0, peer, "direct frames must carry the sender rank");
                    assert!(
                        matches!(got.1, Msg::WaveDown { lambda, .. } if lambda == 7 + phases)
                    );
                    let (hub_frames, direct_frames) = mb.plane_counters();
                    assert_eq!(hub_frames, 0, "mesh phase must not relay through the hub");
                    assert_eq!(direct_frames, 1);
                    mb.send_merge(&merge_for(rank as u32))?;
                    phases += 1;
                }
                assert_eq!(phases, 2);
                Ok(())
            })
        };
        let w0 = spawn_worker(0, sock.clone());
        let w1 = spawn_worker(1, sock.clone());

        accept_all(&mut hub, 2);
        let peers = hub.peer_map().unwrap();
        assert_eq!(peers.len(), 2);
        assert!(
            peers[0].to_string().ends_with(".r0") && peers[1].to_string().ends_with(".r1"),
            "{peers:?}"
        );
        assert!(peers.iter().all(Endpoint::is_unix), "unix hub must yield unix peers");
        hub.broadcast_config(&tiny_spec(2), &peers).unwrap();
        hub.start_all(0).unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_reconfig(&tiny_phase(2, 2), &peers).unwrap();
        hub.start_all(1).unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        w1.join().unwrap().unwrap();
        hub.join();
    }

    /// FIFO per (src, dst) on the mesh data plane: two senders each push a
    /// numbered sequence at a common receiver over direct connections; the
    /// receiver must observe every source's sequence in send order
    /// (interleaving across sources is free).
    #[test]
    fn mesh_preserves_fifo_per_src_dst_pair() {
        const N: u64 = 200;
        let sock = test_ep("fifo");
        let mut hub = Hub::bind(&sock, 3, TOKEN.into()).unwrap();

        let sender = |rank: usize, sock: Endpoint| {
            std::thread::spawn(move || -> Result<()> {
                let mut mb = connect(&sock, rank, TOKEN, None)?;
                while let Some(_start) = mb.await_phase()? {
                    for t in 0..N {
                        mb.send(1, Msg::WaveDown { t, lambda: rank as u32 });
                    }
                    mb.send_merge(&merge_for(rank as u32))?;
                }
                Ok(())
            })
        };
        let receiver = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<()> {
                let mut mb = connect(&sock, 1, TOKEN, None)?;
                while let Some(_start) = mb.await_phase()? {
                    let mut next = [0u64; 3]; // per-source expected sequence number
                    let mut got = 0u64;
                    let deadline = Instant::now() + Duration::from_secs(20);
                    while got < 2 * N {
                        let Some((src, msg)) = mb.try_recv() else {
                            ensure!(Instant::now() < deadline, "only {got} of {} msgs", 2 * N);
                            mb.wait_for_msg(Duration::from_millis(10));
                            continue;
                        };
                        let Msg::WaveDown { t, lambda } = msg else {
                            bail!("unexpected message {msg:?}");
                        };
                        ensure!(lambda as usize == src, "stamped source mismatch");
                        ensure!(
                            t == next[src],
                            "src {src}: got seq {t}, expected {} — FIFO violated",
                            next[src]
                        );
                        next[src] += 1;
                        got += 1;
                    }
                    mb.send_merge(&merge_for(1))?;
                }
                Ok(())
            }
        });
        let s0 = sender(0, sock.clone());
        let s2 = sender(2, sock.clone());

        accept_all(&mut hub, 3);
        let peers = hub.peer_map().unwrap();
        hub.broadcast_config(&tiny_spec(3), &peers).unwrap();
        hub.start_all(0).unwrap();
        collect_merges(&hub, 3);
        hub.broadcast_bye();
        s0.join().unwrap().unwrap();
        s2.join().unwrap().unwrap();
        receiver.join().unwrap().unwrap();
        hub.join();
    }

    /// GIVE payloads (serialized SearchNodes) survive the hub round trip.
    #[test]
    fn give_tasks_roundtrip_through_hub() {
        let sock = test_ep("give");
        let mut hub = Hub::bind(&sock, 2, TOKEN.into()).unwrap();
        let tasks = vec![crate::fabric::WireTask { items: vec![3, 9], core: 9, support: 4 }];
        let sent = tasks.clone();
        let w0 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<()> {
                let mut mb = connect(&sock, 0, TOKEN, None)?;
                while let Some(_start) = mb.await_phase()? {
                    mb.send(
                        1,
                        Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks: tasks.clone() } },
                    );
                    mb.send_merge(&merge_for(0))?;
                }
                Ok(())
            }
        });
        let w1 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<(usize, Msg)> {
                let mut mb = connect(&sock, 1, TOKEN, None)?;
                let mut got_msg = None;
                while let Some(_start) = mb.await_phase()? {
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        ensure!(Instant::now() < deadline, "no GIVE arrived");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    got_msg = Some(got);
                    mb.send_merge(&merge_for(1))?;
                }
                got_msg.context("no phase ran")
            }
        });
        accept_all(&mut hub, 2);
        hub.broadcast_config(&tiny_spec(2), &[]).unwrap();
        hub.start_all(0).unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        let (src, msg) = w1.join().unwrap().unwrap();
        assert_eq!(src, 0);
        match msg {
            Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks } } => {
                assert_eq!(tasks, sent);
            }
            other => panic!("expected GIVE, got {other:?}"),
        }
        hub.join();
    }

    /// Drive `try_accept` until it yields a definite accept/reject outcome.
    fn accept_outcome(hub: &mut Hub) -> Result<bool> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match hub.try_accept() {
                Ok(false) => {
                    assert!(Instant::now() < deadline, "no pending connection");
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => return other,
            }
        }
    }

    #[test]
    fn hub_rejects_out_of_range_duplicate_and_bad_token_hellos() {
        let sock = test_ep("badrank");
        let mut hub = Hub::bind(&sock, 2, TOKEN.into()).unwrap();
        let hello = |rank, token: &str| Frame::Hello {
            rank,
            token: token.into(),
            peer: Endpoint::unix(format!("/nowhere.r{rank}")),
        };
        let raw_connect = || dial(&sock, &RetryPolicy::once()).unwrap();
        // out-of-range rank
        let mut s = raw_connect();
        write_frame(&mut s, &hello(9, TOKEN)).unwrap();
        let err = accept_outcome(&mut hub).expect_err("rank 9 must be rejected");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // wrong fleet token: rejected before any rank state is touched
        let mut t = raw_connect();
        write_frame(&mut t, &hello(0, "someone-elses-fleet")).unwrap();
        let err = accept_outcome(&mut hub).expect_err("bad token must be rejected");
        assert!(format!("{err:#}").contains("bad auth token"), "{err:#}");
        assert_eq!(hub.connected(), 0, "a bad-token HELLO must not register a rank");
        // duplicate rank: first registration succeeds, second errors
        let mut a = raw_connect();
        write_frame(&mut a, &hello(0, TOKEN)).unwrap();
        assert!(accept_outcome(&mut hub).unwrap());
        let mut b = raw_connect();
        write_frame(&mut b, &hello(0, TOKEN)).unwrap();
        let err = accept_outcome(&mut hub).expect_err("duplicate rank must be rejected");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
        assert_eq!(hub.connected(), 1);
        // the peer map is incomplete until every rank has connected
        assert!(hub.peer_map().is_err());
        // a phase broadcast with a missing rank fails loudly
        let err = hub.broadcast_config(&tiny_spec(2), &[]).expect_err("incomplete fleet");
        assert!(format!("{err:#}").contains("1/2"), "{err:#}");
    }

    /// The same warm mesh exchange over loopback TCP: the hub binds an
    /// ephemeral port, workers derive their data-plane listeners from the
    /// dialed connection's local interface, the peer map carries tcp
    /// endpoints with real ports, and the plane counters still show zero
    /// hub relays.
    #[test]
    fn tcp_hub_runs_mesh_phase_with_direct_peer_traffic() {
        let mut hub = Hub::bind(&Endpoint::tcp("127.0.0.1", 0), 2, TOKEN.into()).unwrap();
        let ep = hub.endpoint().clone();
        assert!(matches!(&ep, Endpoint::Tcp(_, port) if *port != 0), "{ep}");

        let spawn_worker = |rank: usize, ep: Endpoint| {
            std::thread::spawn(move || -> Result<()> {
                let mut mb = connect(&ep, rank, TOKEN, None)?;
                while let Some(start) = mb.await_phase()? {
                    assert_eq!(start.phase.p, 2);
                    let peer = 1 - rank;
                    mb.send(peer, Msg::WaveDown { t: rank as u64, lambda: 5 });
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        assert!(Instant::now() < deadline, "no message from peer");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    assert_eq!(got.0, peer);
                    assert!(matches!(got.1, Msg::WaveDown { lambda: 5, .. }));
                    let (hub_frames, direct_frames) = mb.plane_counters();
                    assert_eq!(hub_frames, 0, "tcp mesh must not relay through the hub");
                    assert_eq!(direct_frames, 1);
                    mb.send_merge(&merge_for(rank as u32))?;
                }
                Ok(())
            })
        };
        let w0 = spawn_worker(0, ep.clone());
        let w1 = spawn_worker(1, ep.clone());

        accept_all(&mut hub, 2);
        let peers = hub.peer_map().unwrap();
        for p in &peers {
            assert!(
                matches!(p, Endpoint::Tcp(_, port) if *port != 0),
                "tcp hub must yield resolved tcp peer endpoints, got {p}"
            );
        }
        hub.broadcast_config(&tiny_spec(2), &peers).unwrap();
        hub.start_all(0).unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        w1.join().unwrap().unwrap();
        hub.join();
    }

    /// The recovery primitives (DESIGN.md §12), end to end at the fabric
    /// layer: a worker checkpoints custody and dies; the `Gone` detail
    /// carries the diagnosable context in the documented format; the hub
    /// forgets the rank; a replacement `HELLO`s into the vacant slot.
    #[test]
    fn gone_detail_carries_custody_and_respawn_rehellos_into_vacant_slot() {
        let sock = test_ep("respawn");
        let mut hub = Hub::bind(&sock, 2, TOKEN.into()).unwrap();
        let hello = Frame::Hello {
            rank: 0,
            token: TOKEN.into(),
            peer: Endpoint::unix("/nowhere.r0"),
        };
        let mut s = dial(&sock, &RetryPolicy::once()).unwrap();
        write_frame(&mut s, &hello).unwrap();
        accept_all(&mut hub, 1);
        // A custody checkpoint, then death (socket drop → EOF).
        let ck = Frame::Checkpoint {
            rank: 0,
            epoch: 3,
            work_units: 123,
            roots: vec![crate::fabric::WireTask { items: vec![1, 4], core: 4, support: 6 }],
        };
        write_frame(&mut s, &ck).unwrap();
        drop(s);
        let detail = match hub.recv_event(Duration::from_secs(10)).unwrap() {
            Some(HubEvent::Gone { rank: 0, detail }) => detail,
            other => panic!("expected Gone for rank 0, got {other:?}"),
        };
        // The documented detail format (satellite of ISSUE 7): cause, last
        // delivered epoch, frame context, custody at last checkpoint.
        assert!(detail.contains("EOF"), "{detail}");
        assert!(detail.contains("last delivered epoch 3"), "{detail}");
        assert!(detail.contains("1 frames on this connection (last: CHECKPOINT)"), "{detail}");
        assert!(detail.contains("123 work units"), "{detail}");
        assert!(detail.contains("1 stack roots"), "{detail}");
        let c = hub.custody(0);
        assert_eq!((c.epoch, c.work_units, c.roots.len()), (3, 123, 1));
        // Vacate the slot and re-HELLO as the respawned rank 0.
        hub.forget_rank(0);
        assert_eq!(hub.connected(), 0);
        let mut s2 = dial(&sock, &RetryPolicy::once()).unwrap();
        write_frame(&mut s2, &hello).unwrap();
        assert!(accept_outcome(&mut hub).unwrap(), "re-HELLO must be accepted");
        assert_eq!(hub.connected(), 1);
        // The occupied slot still rejects duplicates.
        let mut dup = dial(&sock, &RetryPolicy::once()).unwrap();
        write_frame(&mut dup, &hello).unwrap();
        let err = accept_outcome(&mut hub).expect_err("duplicate HELLO must still fail");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
    }

    /// A mid-phase RECONFIG (the hub aborting a phase attempt after a peer
    /// died) must not sever the survivor's link: it surfaces through
    /// `phase_interrupted`, and the stashed frames open the replay phase
    /// on the next `await_phase`, with the worker adopting the replay's
    /// hub-assigned epoch.
    #[test]
    fn survivor_sees_interrupt_and_joins_replay_epoch() {
        let sock = test_ep("interrupt");
        let mut hub = Hub::bind(&sock, 1, TOKEN.into()).unwrap();
        let worker = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<()> {
                let mut mb = connect(&sock, 0, TOKEN, None)?;
                // Phase attempt at epoch 5: interrupted mid-phase.
                let start = mb.await_phase()?.context("no phase opened")?;
                assert!(start.db.is_some());
                assert_eq!(mb.epoch(), 5);
                let deadline = Instant::now() + Duration::from_secs(10);
                while !mb.phase_interrupted() {
                    ensure!(Instant::now() < deadline, "interrupt never surfaced");
                    mb.wait_for_msg(Duration::from_millis(5));
                    ensure!(mb.lost().is_none(), "interrupt must not sever the link");
                }
                // Abandon without merging; the replay opens at epoch 6.
                let replay = mb.await_phase()?.context("no replay phase")?;
                assert!(replay.db.is_none(), "survivors are reconfigured, not re-shipped");
                assert_eq!(mb.epoch(), 6);
                mb.send_merge(&merge_for(0))?;
                assert!(mb.await_phase()?.is_none(), "expected BYE");
                Ok(())
            }
        });
        accept_all(&mut hub, 1);
        hub.broadcast_config(&tiny_spec(1), &[]).unwrap();
        hub.start_all(5).unwrap();
        // Mid-phase: abort the attempt and open the replay under a fresh
        // epoch (what the fleet owner does after a respawn).
        hub.send_reconfig_to(0, &tiny_phase(1, 1), &[]).unwrap();
        hub.start_all(6).unwrap();
        collect_merges(&hub, 1);
        hub.broadcast_bye();
        worker.join().unwrap().unwrap();
        hub.join();
    }

    /// The heartbeat lease table (v8, DESIGN.md §15) at the fabric layer:
    /// a handshake seeds the lease, `ping_all` probes the worker, a `PONG`
    /// refreshes the lease, `reset_leases` re-seeds it, and an EOF marked
    /// expected by [`Hub::mark_expected_eof`] (the force-kill path) is
    /// swallowed instead of surfacing a duplicate `Gone`.
    #[test]
    fn hub_lease_table_tracks_heartbeats_and_suppresses_expected_eof() {
        let sock = test_ep("lease");
        let mut hub = Hub::bind(&sock, 1, TOKEN.into()).unwrap();
        let hello = Frame::Hello {
            rank: 0,
            token: TOKEN.into(),
            peer: Endpoint::unix("/nowhere.r0"),
        };
        let mut s = dial(&sock, &RetryPolicy::once()).unwrap();
        write_frame(&mut s, &hello).unwrap();
        accept_all(&mut hub, 1);
        // The handshake seeds the lease.
        assert!(hub.lease_age(0).is_some(), "handshake must seed the lease");
        // A PING reaches the fake worker...
        hub.ping_all();
        match read_frame(&mut s).unwrap() {
            Some(Frame::Ping) => {}
            other => panic!("expected PING from hub, got {other:?}"),
        }
        // ...and while it stays silent the lease only ages.
        std::thread::sleep(Duration::from_millis(60));
        assert!(hub.lease_age(0).unwrap() >= Duration::from_millis(40));
        // Its PONG refreshes the lease (the route thread races us: poll).
        write_frame(&mut s, &Frame::Pong).unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while hub.lease_age(0).unwrap() >= Duration::from_millis(40) {
            assert!(Instant::now() < deadline, "PONG never refreshed the lease");
            std::thread::sleep(Duration::from_millis(1));
        }
        // A phase-start reset re-seeds connected slots.
        std::thread::sleep(Duration::from_millis(60));
        hub.reset_leases();
        assert!(hub.lease_age(0).unwrap() < Duration::from_millis(40));
        // An expected EOF (the owner force-killed this rank and already
        // synthesized its loss) must NOT surface as a second Gone.
        hub.mark_expected_eof(0);
        drop(s);
        match hub.recv_event(Duration::from_millis(300)).unwrap() {
            None => {}
            other => panic!("expected-EOF death must be swallowed, got {other:?}"),
        }
        // Vacating the slot clears the lease.
        hub.forget_rank(0);
        assert!(hub.lease_age(0).is_none(), "forgotten rank must hold no lease");
    }

    /// A bounded `await_phase_deadline` on a worker whose hub never opens
    /// a phase fails with the typed [`PhaseWaitTimeout`] — the watchdog
    /// counterpart of the unbounded production wait (DESIGN.md §15).
    #[test]
    fn await_phase_deadline_surfaces_typed_timeout() {
        let sock = test_ep("deadline");
        let mut hub = Hub::bind(&sock, 1, TOKEN.into()).unwrap();
        let worker = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<Duration> {
                let mut mb = connect(&sock, 0, TOKEN, None)?;
                let err = mb
                    .await_phase_deadline(Some(Duration::from_millis(100)))
                    .expect_err("the hub never opened a phase");
                let t = err
                    .source()
                    .and_then(|s| s.downcast_ref::<PhaseWaitTimeout>())
                    .context("error source must downcast to PhaseWaitTimeout")?;
                Ok(t.limit)
            }
        });
        accept_all(&mut hub, 1);
        // Deliberately no CONFIG/START: the worker's bounded wait elapses.
        let limit = worker.join().unwrap().unwrap();
        assert_eq!(limit, Duration::from_millis(100));
    }
}
