"""L1 Pallas kernel: batched one-sided Fisher exact test + Tarone bound.

Transcendental-bound (lgamma) VPU work: each grid step takes a (BK,) tile
of (x, n) pairs and evaluates the hypergeometric upper tail as a masked,
fixed-length (T_MAX) log-sum-exp — shape-static, so it AOT-lowers cleanly.
f64 is used under interpret=True for exactness against the rust oracle; a
real-TPU build would drop to f32 with compensated summation (DESIGN.md §5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_K = 256


def _log_choose(a, b):
    return (
        jax.lax.lgamma(a + 1.0) - jax.lax.lgamma(b + 1.0) - jax.lax.lgamma(a - b + 1.0)
    )


def _fisher_kernel(t_max, x_ref, n_ref, nt_ref, np_ref, logp_ref, logf_ref):
    x = x_ref[...].astype(jnp.float64)
    n = n_ref[...].astype(jnp.float64)
    N = nt_ref[0].astype(jnp.float64)
    Np = np_ref[0].astype(jnp.float64)

    # --- Fisher upper tail via the cumulative-ratio formulation ---
    # The observed cell (x, n) is always inside the hypergeometric support
    # (n ≤ min(x, N_pos) and x − n ≤ N − N_pos hold by construction in the
    # miner), so the first tail term is valid and successive terms follow
    # from term(k+1)/term(k) = (Np−k)(x−k) / ((k+1)(N−Np−x+k+1)): one `log`
    # + a cumulative sum per term instead of six `lgamma`s (§Perf, L1).
    nc = jnp.minimum(n, Np)  # clamp for padded/degenerate rows
    lt0 = (
        _log_choose(Np, nc)
        + _log_choose(N - Np, jnp.clip(x - nc, 0.0, None))
        - _log_choose(N, x)
    )
    j = jnp.arange(t_max - 1, dtype=jnp.float64)[None, :]
    kj = n[:, None] + j
    num = (Np - kj) * (x[:, None] - kj)
    den = (kj + 1.0) * (N - Np - x[:, None] + kj + 1.0)
    log_r = jnp.log(jnp.clip(num, 1e-300, None)) - jnp.log(jnp.clip(den, 1e-300, None))
    log_term = jnp.concatenate(
        [lt0[:, None], lt0[:, None] + jnp.cumsum(log_r, axis=1)], axis=1
    )
    ks = n[:, None] + jnp.arange(t_max, dtype=jnp.float64)[None, :]
    hi = jnp.minimum(x, Np)[:, None]
    log_term = jnp.where(ks <= hi, log_term, -jnp.inf)
    m = jnp.max(log_term, axis=1, keepdims=True)
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    logp = jnp.squeeze(m, 1) + jnp.log(jnp.sum(jnp.exp(log_term - m), axis=1))
    # Observed count at/below the support's lower limit ⇒ the tail covers
    # the whole distribution ⇒ P = 1 (also covers x = 0 padding rows).
    lo_support = jnp.maximum(x - (N - Np), 0.0)
    logp = jnp.where((x <= 0) | (n <= lo_support), 0.0, logp)
    logp_ref[...] = jnp.minimum(logp, 0.0)

    # --- Tarone minimum-achievable log P ---
    low = _log_choose(Np, jnp.minimum(x, Np)) - _log_choose(N, x)
    high = _log_choose(N - Np, jnp.clip(x - Np, 0.0, None)) - _log_choose(N, x)
    logf = jnp.where(x <= Np, low, high)
    logf_ref[...] = jnp.where(x <= 0, 0.0, jnp.minimum(logf, 0.0))


@functools.partial(jax.jit, static_argnames=("t_max", "block_k"))
def fisher_tarone(x, n, n_total, n_pos, *, t_max, block_k=BLOCK_K):
    """Batched (log P, log f) for K (x, n) pairs.

    x, n: (K,) int32 (K divisible by block_k); n_total/n_pos: () f64 scalars
    (shape-(1,) arrays). t_max must be ≥ n_pos + 1 to cover the longest
    possible tail. Returns (logp, logf): (K,) float64 each.
    """
    (k,) = x.shape
    assert k % block_k == 0, f"K={k} must be padded to a multiple of {block_k}"
    grid = (k // block_k,)
    kern = functools.partial(_fisher_kernel, t_max)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_k,), lambda i: (i,)),
            pl.BlockSpec((block_k,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_k,), lambda i: (i,)),
            pl.BlockSpec((block_k,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k,), jnp.float64),
            jax.ShapeDtypeStruct((k,), jnp.float64),
        ],
        interpret=True,
    )(x, n, n_total, n_pos)
