//! Exact-test statistics for significant pattern mining (paper §3).
//!
//! - [`logfact::LogFact`]: cached log-factorial table, the shared substrate.
//! - [`fisher::FisherTable`]: one-sided Fisher's exact test P-values.
//! - [`tarone`]: Tarone's minimum-achievable-P bound `f(x)` (Eq. in §3.2),
//!   the key to the LAMP correction.

pub mod fisher;
pub mod logfact;
pub mod tarone;

pub use fisher::FisherTable;
pub use logfact::LogFact;

/// Marginals of the 2×2 contingency setting: `n` transactions total of
/// which `n_pos` are labelled positive. Shared by Fisher and Tarone code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Marginals {
    /// Total number of transactions `N`.
    pub n: u32,
    /// Number of positive transactions `N_pos` (must be ≤ `n`).
    pub n_pos: u32,
}

impl Marginals {
    pub fn new(n: u32, n_pos: u32) -> Self {
        assert!(n_pos <= n, "n_pos={n_pos} > n={n}");
        Marginals { n, n_pos }
    }
}
