//! Command-line driver (no clap in the offline registry — a small
//! hand-rolled parser).
//!
//! ```text
//! parlamp lamp     --data t.dat --labels t.lab
//!                  [--engine serial|lamp2|threads|sim|process]
//!                  [--data-plane hub|mesh]
//! parlamp mine     --data t.dat [--min-sup K]
//! parlamp sim      --scenario hapmap-dom-20 --procs 96 [--naive] [--ethernet]
//! parlamp bench    [--quick] [--engines a,b,..] [--scenarios x,y|all]
//!                  [--out BENCH_pr5.json] | --check FILE
//!                  | --compare A.json,B.json
//! parlamp gendata  --scenario alz-dom-5 --out dir/
//! parlamp scenarios
//! parlamp serve    --socket /run/parlamp.sock --procs 8 [--cache 32]
//! parlamp submit   --socket /run/parlamp.sock --data t.dat --labels t.lab
//! parlamp status   --socket /run/parlamp.sock --job 1
//! parlamp results  --socket /run/parlamp.sock --job 1
//! parlamp shutdown --socket /run/parlamp.sock
//! ```

mod args;
mod commands;

pub use args::Args;

/// Binary entry point.
pub fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = run(&argv);
    std::process::exit(code);
}

/// Dispatch; returns the process exit code (testable).
pub fn run(argv: &[String]) -> i32 {
    let Some((cmd, rest)) = argv.split_first() else {
        eprintln!("{}", usage());
        return 2;
    };
    let args = match Args::parse(rest) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", usage());
            return 2;
        }
    };
    let result = match cmd.as_str() {
        "lamp" => commands::cmd_lamp(&args),
        "mine" => commands::cmd_mine(&args),
        "sim" => commands::cmd_sim(&args),
        "bench" => commands::cmd_bench(&args),
        "gendata" => commands::cmd_gendata(&args),
        "scenarios" => commands::cmd_scenarios(&args),
        "serve" => commands::cmd_serve(&args),
        "submit" => commands::cmd_submit(&args),
        "status" => commands::cmd_status(&args),
        "results" => commands::cmd_results(&args),
        "shutdown" => commands::cmd_shutdown(&args),
        // Hidden: the process-fabric child entry point. The parent engine
        // re-executes this binary as `parlamp __worker --socket S
        // --worker-rank R` for each rank (see par::engine_process).
        "__worker" => crate::par::engine_process::worker_main(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n\n{}", usage());
            return 2;
        }
    };
    match result {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

pub fn usage() -> String {
    "parlamp — distributed significant pattern mining (LCM + LAMP + lifeline GLB)

USAGE:
  parlamp lamp      --data FILE --labels FILE [--alpha A]
                    [--engine serial|lamp2|threads|sim|process]
                    [--procs P | -n P] [--naive] [--data-plane hub|mesh]
                    [--screen native|xla|auto] [--seed S]
  parlamp mine      --data FILE [--min-sup K]
  parlamp sim       --scenario NAME [--procs P] [--naive] [--ethernet]
                    [--no-preprocess] [--alpha A] [--seed S]
  parlamp bench     [--quick] [--engines E1,E2,..] [--scenarios S1,S2|all]
                    [--procs P] [--alpha A] [--seed S] [--label L]
                    [--out FILE] [--data-plane hub|mesh]
  parlamp bench     --check FILE
  parlamp bench     --compare A.json,B.json  (or --compare A.json --with B.json)
  parlamp gendata   --scenario NAME --out DIR [--quick]
  parlamp scenarios [--quick]
  parlamp serve     --socket PATH [--procs P] [--cache N]
                    [--data-plane hub|mesh]
  parlamp submit    --socket PATH --data FILE --labels FILE [--alpha A]
                    [--naive] [--no-preprocess] [--screen native|xla|auto]
                    [--seed S]
  parlamp status    --socket PATH --job ID
  parlamp results   --socket PATH --job ID
  parlamp shutdown  --socket PATH

`bench` runs the Table-1 scenarios across engines (default: all five) and
writes the schema-stable perf-trajectory JSON (BENCH_<label>.json; the
label defaults to pr5 and is stamped into the document header);
`--quick` shrinks the data and defaults to the single mcf7 scenario;
`--check` validates an existing file against the parlamp-bench/2 schema;
`--compare` diffs two reports per (scenario, engine) — wall-clock and
work-unit deltas — and errors if result fields disagree.

Engines `threads`, `sim`, and `process` run the full three-phase procedure
through the coordinator (phases 1-2 distributed, phase 3 via the configured
screen). `process` spawns one worker OS process per rank, connected over
Unix-domain sockets with the DESIGN.md §7 wire protocol — true distributed
memory on one host. Its data plane is selectable (`--data-plane`,
DESIGN.md §10): `mesh` (default) lets workers exchange steal traffic and
DTD waves over direct worker-to-worker sockets with zero hub hops; `hub`
relays everything through the parent (the centralized ablation baseline).
Scenario names mirror Table 1: hapmap-dom-10, hapmap-dom-20, alz-dom-5,
alz-dom-10, alz-rec-30, mcf7.

`serve` starts the long-running mining daemon (DESIGN.md §9): the worker
fleet spawns once and stays warm, jobs queue FIFO, and repeat submissions
are answered from a bounded result cache keyed by (database digest, alpha,
GLB parameters, screen). `submit` prints the assigned job id; `results`
blocks until the job finishes and prints the same summary + table as
`lamp --engine serial`; `shutdown` (or SIGTERM) drains the queue, BYEs the
fleet, and unlinks the socket."
        .to_string()
}
