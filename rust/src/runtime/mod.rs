//! PJRT runtime — loads and executes the AOT-compiled XLA artifacts.
//!
//! The build-time Python (`make artifacts`) lowers the JAX/Pallas
//! significance screen to HLO text; this module loads it through the
//! `xla` crate (`PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `compile` → `execute`) so the rust coordinator can score closed
//! itemsets in batches with Python nowhere on the path.

pub mod manifest;
pub mod screen;

// The real PJRT loader needs the `xla` crate, which the offline build
// environment cannot provide; without the `xla` cargo feature a stub with
// the identical API is compiled instead, `XlaRuntime::load` fails with an
// explanatory error, and callers (notably `coordinator::ScreenMode::Auto`)
// fall back to the native Fisher screen. See DESIGN.md §5.
#[cfg(feature = "xla")]
pub mod pjrt;
#[cfg(not(feature = "xla"))]
#[path = "pjrt_stub.rs"]
pub mod pjrt;

pub use manifest::Manifest;
pub use pjrt::XlaRuntime;
pub use screen::{phase3_extract_xla, ScreenEngine, ScreenRow};

use std::path::{Path, PathBuf};

/// Default artifacts directory, overridable with `PARLAMP_ARTIFACTS`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PARLAMP_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Do the AOT artifacts exist? (Benches/tests skip XLA paths otherwise;
/// `make artifacts` builds them.)
pub fn artifacts_available() -> bool {
    let d = artifacts_dir();
    has_artifacts(&d)
}

pub fn has_artifacts(dir: &Path) -> bool {
    dir.join("manifest.json").exists() && dir.join("screen.hlo.txt").exists()
}
