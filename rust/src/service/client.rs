//! Client side of the `parlamp serve` protocol: connect, speak frames,
//! surface typed results. Used by the `parlamp submit|status|results|
//! cancel|stats|shutdown` subcommands and by the integration tests.
//!
//! Liveness (DESIGN.md §15): every read is bounded by a deadline — a
//! daemon that accepts the connection and then hangs (or a network that
//! silently eats the reply) surfaces as a timeout error instead of a
//! client parked forever. *Idempotent* requests (status, cancel, stats,
//! shutdown, result) additionally survive one transient failure per
//! call: the client reconnects through the standard [`dial`] retry
//! policy and reissues the frame. `SUBMIT` is never reissued — a retry
//! after an ambiguous failure could enqueue the job twice.

use std::io;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::net::{dial, Endpoint, RetryPolicy, Stream};
use crate::wire::service::{JobOutcome, JobSpec, JobState, ServiceStats};
use crate::wire::{read_frame, write_frame, Frame};

/// Default per-reply read deadline for the quick request kinds.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Per-reply read deadline while waiting on `RESULT` — the daemon blocks
/// that reply until the job is terminal, so a long mine legitimately
/// keeps the socket quiet. On expiry the client probes `STATUS` and keeps
/// waiting while the job is still queued or running.
const RESULT_READ_TIMEOUT: Duration = Duration::from_secs(120);

/// One connection to a running daemon. A connection can carry any number
/// of requests; each request is one frame out, one frame back.
pub struct Client {
    endpoint: Endpoint,
    stream: Stream,
    read_timeout: Duration,
    retry: RetryPolicy,
}

/// Whether an error is a transport-level transient — a timed-out read, a
/// dropped connection, a clean EOF where a reply belonged — as opposed to
/// a protocol error (bad frame, typed rejection). Only transients justify
/// a reconnect-and-reissue.
fn is_transient(e: &anyhow::Error) -> bool {
    match e.source().and_then(|s| s.downcast_ref::<io::Error>()) {
        Some(io_err) => matches!(
            io_err.kind(),
            io::ErrorKind::TimedOut
                | io::ErrorKind::WouldBlock
                | io::ErrorKind::ConnectionReset
                | io::ErrorKind::ConnectionAborted
                | io::ErrorKind::BrokenPipe
                | io::ErrorKind::UnexpectedEof
        ),
        None => false,
    }
}

impl Client {
    /// Connect to the daemon listening at `ep` — Unix path or TCP
    /// host:port, through the one [`dial`] retry/timeout path (DESIGN.md
    /// §11).
    pub fn connect(ep: &Endpoint) -> Result<Client> {
        let retry = RetryPolicy::default();
        let stream = dial(ep, &retry).with_context(|| {
            format!("connect to parlamp daemon at {ep} (is `parlamp serve` running?)")
        })?;
        Ok(Client {
            endpoint: ep.clone(),
            stream,
            read_timeout: READ_TIMEOUT,
            retry,
        })
    }

    /// Override the per-reply read deadline (tests, impatient tooling).
    pub fn with_read_timeout(mut self, timeout: Duration) -> Client {
        self.read_timeout = timeout;
        self
    }

    /// Drop the current stream and dial the daemon again.
    fn reconnect(&mut self) -> Result<()> {
        self.stream = dial(&self.endpoint, &self.retry)
            .with_context(|| format!("reconnect to parlamp daemon at {}", self.endpoint))?;
        Ok(())
    }

    /// One request/reply exchange on the current stream, reply bounded by
    /// `timeout`. A clean EOF where a reply belonged is reported as an
    /// `UnexpectedEof` io error so [`is_transient`] classifies it.
    fn call_once(&mut self, frame: &Frame, timeout: Duration) -> Result<Frame> {
        self.stream
            .set_read_timeout(Some(timeout))
            .context("set reply deadline on the daemon stream")?;
        write_frame(&mut self.stream, frame)
            .with_context(|| format!("send {} to daemon", frame.name()))?;
        read_frame(&mut self.stream)
            .with_context(|| {
                format!(
                    "read {} reply from daemon (deadline {:.0?})",
                    frame.name(),
                    timeout
                )
            })?
            .ok_or_else(|| {
                anyhow::Error::new(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "daemon closed the connection without replying",
                ))
            })
    }

    /// One exchange at the client's standard deadline. When `reissue` is
    /// set (idempotent requests only) a transient failure is retried once
    /// on a fresh connection; a repeat failure — and any protocol error —
    /// surfaces to the caller.
    fn call_with(&mut self, frame: &Frame, reissue: bool) -> Result<Frame> {
        match self.call_once(frame, self.read_timeout) {
            Ok(reply) => Ok(reply),
            Err(e) if reissue && is_transient(&e) => {
                self.reconnect()?;
                self.call_once(frame, self.read_timeout).with_context(|| {
                    format!("{} retry after transient failure ({e:#})", frame.name())
                })
            }
            Err(e) => Err(e),
        }
    }

    /// Submit a job; returns the assigned job id. A daemon at its
    /// admission bounds replies with a `STATUS` carrying
    /// [`JobState::Busy`]; that (and any other rejection, e.g. a deadline
    /// already impossible or a draining daemon) surfaces here as an error
    /// rendering the typed state. Never reissued: after an ambiguous
    /// transport failure the job may or may not be queued, and a blind
    /// retry could run it twice — query `status`/resubmit deliberately.
    pub fn submit(&mut self, spec: JobSpec) -> Result<u64> {
        match self.call_with(&Frame::Submit(Box::new(spec)), false)? {
            Frame::Accepted { job_id } => Ok(job_id),
            Frame::Status { report: Some(state), .. } => {
                bail!("daemon rejected the submission: {state}")
            }
            other => bail!("expected ACCEPTED from daemon, got {}", other.name()),
        }
    }

    /// Query a job's lifecycle state.
    pub fn status(&mut self, job_id: u64) -> Result<JobState> {
        match self.call_with(&Frame::Status { job_id, report: None }, true)? {
            Frame::Status { job_id: got, report: Some(state) } if got == job_id => Ok(state),
            other => bail!("expected STATUS report from daemon, got {}", other.name()),
        }
    }

    /// Fetch a job's outcome. The daemon blocks the reply until the job is
    /// terminal, so this call waits with it — under a long read deadline,
    /// not forever: each expiry (or dropped connection) reconnects and
    /// probes `STATUS`, and the wait continues only while the daemon still
    /// reports the job queued, running, or done. A job that failed, was
    /// cancelled, or is unknown surfaces as an error carrying its state.
    pub fn results(&mut self, job_id: u64) -> Result<JobOutcome> {
        loop {
            let req = Frame::JobResult { job_id, report: None };
            match self.call_once(&req, RESULT_READ_TIMEOUT) {
                Ok(Frame::JobResult { job_id: got, report: Some(outcome) })
                    if got == job_id =>
                {
                    return Ok(*outcome);
                }
                Ok(Frame::Status { report: Some(state), .. }) => {
                    bail!("job {job_id} has no results: {state}")
                }
                Ok(other) => bail!("expected RESULT from daemon, got {}", other.name()),
                Err(e) if is_transient(&e) => {
                    self.reconnect()?;
                    match self.status(job_id)? {
                        // Still on its way (or already terminal-with-output):
                        // reissue RESULT — it is idempotent.
                        JobState::Queued { .. } | JobState::Running | JobState::Done { .. } => {}
                        state => bail!("job {job_id} has no results: {state}"),
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Remove a pending job from the queue; returns the job's state after
    /// the attempt (`Cancelled` iff it was still pending). Idempotent: a
    /// reissued cancel of an already-cancelled job just reports
    /// `Cancelled` again.
    pub fn cancel(&mut self, job_id: u64) -> Result<JobState> {
        match self.call_with(&Frame::Cancel { job_id }, true)? {
            Frame::Status { job_id: got, report: Some(state) } if got == job_id => Ok(state),
            other => bail!("expected STATUS report from daemon, got {}", other.name()),
        }
    }

    /// Fetch the daemon's operational counters: per-fleet utilization,
    /// per-client queue depths, cache/store counters, latency histograms.
    pub fn stats(&mut self) -> Result<ServiceStats> {
        match self.call_with(&Frame::Stats { report: None }, true)? {
            Frame::Stats { report: Some(stats) } => Ok(*stats),
            other => bail!("expected STATS report from daemon, got {}", other.name()),
        }
    }

    /// Ask the daemon to drain its queue and exit. Returns once the daemon
    /// acknowledged (it may still be draining; wait on process exit or
    /// socket removal for full teardown). Idempotent: a reissued SHUTDOWN
    /// to an already-draining daemon is acknowledged again.
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call_with(&Frame::Shutdown, true)? {
            Frame::Shutdown => Ok(()),
            other => bail!("expected SHUTDOWN ack from daemon, got {}", other.name()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::Listener;
    use std::time::Instant;

    fn test_ep(tag: &str) -> Endpoint {
        let dir = std::env::temp_dir()
            .join(format!("parlamp_client_{}_{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        Endpoint::unix(dir.join("svc.sock"))
    }

    /// A daemon that accepts and immediately drops the first connection
    /// forces the client through reconnect + reissue; the second
    /// connection answers, and the idempotent `status` call succeeds.
    #[test]
    fn idempotent_call_survives_one_dropped_connection() {
        let ep = test_ep("retry");
        let listener = Listener::bind(&ep).unwrap();
        let server = std::thread::spawn(move || {
            // First connection: accept, say nothing, hang up.
            drop(listener.accept().unwrap());
            // Second connection: a well-behaved daemon.
            let mut s = listener.accept().unwrap();
            match read_frame(&mut s).unwrap().unwrap() {
                Frame::Status { job_id, .. } => write_frame(
                    &mut s,
                    &Frame::Status { job_id, report: Some(JobState::Running) },
                )
                .unwrap(),
                other => panic!("expected STATUS, got {}", other.name()),
            }
        });
        let mut client = Client::connect(&ep)
            .unwrap()
            .with_read_timeout(Duration::from_secs(5));
        let state = client.status(7).expect("status must survive one dropped connection");
        assert!(matches!(state, JobState::Running));
        server.join().unwrap();
    }

    /// A daemon that accepts the frame and never replies must trip the
    /// read deadline — bounded, and *not* reissued for SUBMIT.
    #[test]
    fn submit_read_deadline_is_bounded_and_not_reissued() {
        let ep = test_ep("deadline");
        let listener = Listener::bind(&ep).unwrap();
        let server = std::thread::spawn(move || {
            let mut s = listener.accept().unwrap();
            // Swallow the request; never answer. Hold the stream open so
            // the client sees silence, not EOF.
            let _ = read_frame(&mut s);
            std::thread::sleep(Duration::from_millis(500));
            // No second accept: a reissue attempt would park the client in
            // dial and fail the elapsed-time assertion below.
        });
        let mut client = Client::connect(&ep)
            .unwrap()
            .with_read_timeout(Duration::from_millis(100));
        let spec = JobSpec {
            alpha: 0.05,
            glb: Default::default(),
            screen: crate::coordinator::ScreenMode::Native,
            seed: 1,
            priority: 1,
            deadline_ms: 0,
            client: String::new(),
            db: crate::db::Database::from_transactions(
                2,
                &[vec![0u32], vec![1u32]],
                &[true, false],
            ),
        };
        let started = Instant::now();
        let err = client.submit(spec).expect_err("silent daemon must time out");
        assert!(
            started.elapsed() < Duration::from_secs(3),
            "submit must fail within the read deadline, took {:?}",
            started.elapsed()
        );
        assert!(is_transient(&err), "timeout must classify as transient: {err:#}");
        server.join().unwrap();
    }

    /// `results` under a transient failure reconnects, probes STATUS, and
    /// keeps or stops waiting according to the reported state — here the
    /// job failed, so the wait ends with the typed reason.
    #[test]
    fn results_probes_status_after_transient_failure() {
        let ep = test_ep("results");
        let listener = Listener::bind(&ep).unwrap();
        let server = std::thread::spawn(move || {
            // First connection: take the RESULT request, hang up mid-wait.
            let mut s = listener.accept().unwrap();
            let _ = read_frame(&mut s);
            drop(s);
            // Second connection: the status probe learns the job failed.
            let mut s = listener.accept().unwrap();
            match read_frame(&mut s).unwrap().unwrap() {
                Frame::Status { job_id, .. } => write_frame(
                    &mut s,
                    &Frame::Status {
                        job_id,
                        report: Some(JobState::Failed { reason: "boom".into() }),
                    },
                )
                .unwrap(),
                other => panic!("expected STATUS probe, got {}", other.name()),
            }
        });
        let mut client = Client::connect(&ep).unwrap();
        let err = client.results(9).expect_err("failed job must end the wait");
        let rendered = format!("{err:#}");
        assert!(
            rendered.contains("no results") && rendered.contains("boom"),
            "error must carry the typed job state: {rendered}"
        );
        server.join().unwrap();
    }
}
