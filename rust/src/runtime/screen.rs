//! Batched phase-3 significance screen through the XLA artifact.
//!
//! Walks the frequent closed itemsets exactly like the native
//! `lamp::phase3_extract`, but accumulates candidate occurrence bitmaps
//! into batches and scores them with one PJRT execution per batch.
//! Integration tests assert the XLA path and the native path produce the
//! same significant set (to f64 tolerance).

use anyhow::Result;

use crate::bits::BitVec;
use crate::db::{Database, Item};
use crate::lamp::phase3::SignificantPattern;
use crate::lcm::{mine_closed, Visit};
use crate::stats::Marginals;

use super::pjrt::XlaRuntime;

/// Re-export of the per-row output type.
pub type ScreenRow = super::pjrt::ScreenOut;

/// Batch accumulator around the runtime.
pub struct ScreenEngine {
    rt: XlaRuntime,
}

impl ScreenEngine {
    pub fn new(rt: XlaRuntime) -> Self {
        ScreenEngine { rt }
    }

    pub fn runtime(&self) -> &XlaRuntime {
        &self.rt
    }

    /// Score a set of candidate bitmaps (splitting into artifact-sized
    /// batches as needed).
    pub fn score(
        &self,
        rows: &[BitVec],
        pos_mask: &BitVec,
        m: Marginals,
    ) -> Result<Vec<ScreenRow>> {
        let k = self.rt.manifest().k;
        let mut out = Vec::with_capacity(rows.len());
        for chunk in rows.chunks(k) {
            let refs: Vec<&BitVec> = chunk.iter().collect();
            out.extend(self.rt.screen_batch_with_pos(&refs, pos_mask, m)?);
        }
        Ok(out)
    }
}

/// Phase 3 through the XLA screen: identical contract to
/// [`crate::lamp::phase3_extract`].
pub fn phase3_extract_xla(
    engine: &ScreenEngine,
    db: &Database,
    min_sup: u32,
    correction_factor: u64,
    alpha: f64,
) -> Result<Vec<SignificantPattern>> {
    let m = db.marginals();
    let delta = alpha / correction_factor as f64;
    let log_delta = delta.ln();
    let batch_cap = engine.rt.manifest().k;

    let mut pending_items: Vec<Vec<Item>> = Vec::new();
    let mut pending_occ: Vec<BitVec> = Vec::new();
    let mut out: Vec<SignificantPattern> = Vec::new();

    let mut flush = |items: &mut Vec<Vec<Item>>, occ: &mut Vec<BitVec>| -> Result<()> {
        if occ.is_empty() {
            return Ok(());
        }
        let rows = engine.score(occ, db.pos_mask(), m)?;
        for (i, row) in rows.iter().enumerate() {
            if row.logp <= log_delta {
                out.push(SignificantPattern {
                    items: items[i].clone(),
                    support: row.x as u32,
                    pos_support: row.n as u32,
                    p_value: row.logp.exp(),
                });
            }
        }
        items.clear();
        occ.clear();
        Ok(())
    };

    let mut err: Option<anyhow::Error> = None;
    mine_closed(db, min_sup.max(1), |node, ms| {
        pending_items.push(node.items.clone());
        pending_occ.push(node.occ.clone().expect("serial miner keeps occ"));
        if pending_occ.len() >= batch_cap {
            if let Err(e) = flush(&mut pending_items, &mut pending_occ) {
                err = Some(e);
                return (Visit::Stop, ms);
            }
        }
        (Visit::Continue, ms)
    });
    if let Some(e) = err {
        return Err(e);
    }
    flush(&mut pending_items, &mut pending_occ)?;

    out.sort_by(|a, b| {
        a.p_value.partial_cmp(&b.p_value).unwrap().then_with(|| a.items.cmp(&b.items))
    });
    Ok(out)
}
