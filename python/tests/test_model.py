"""L2 model composition + AOT lowering smoke tests."""

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from compile import aot, model  # noqa: E402
from compile.kernels import ref  # noqa: E402


def _pack_bits(rows):
    """rows: (K, N) bool -> (K, W) uint32 little-endian packed."""
    k, n = rows.shape
    w = (n + 31) // 32
    out = np.zeros((k, w), dtype=np.uint32)
    for i in range(k):
        for j in range(n):
            if rows[i, j]:
                out[i, j // 32] |= np.uint32(1) << np.uint32(j % 32)
    return out


def test_screen_batch_end_to_end():
    rng = np.random.default_rng(0)
    n, n_pos, k = 100, 30, 256
    occ_bool = rng.random((k, n)) < 0.2
    pos_bool = np.zeros(n, dtype=bool)
    pos_bool[:n_pos] = True
    occ = _pack_bits(occ_bool)
    pos = _pack_bits(pos_bool[None, :])[0]
    t_max = n_pos + 1
    x, nn, logp, logf = model.screen_batch(
        jnp.asarray(occ),
        jnp.asarray(pos),
        jnp.asarray([float(n)]),
        jnp.asarray([float(n_pos)]),
        t_max=t_max,
    )
    # supports straight from the boolean matrix
    np.testing.assert_array_equal(np.asarray(x), occ_bool.sum(axis=1))
    np.testing.assert_array_equal(np.asarray(nn), (occ_bool & pos_bool[None, :]).sum(axis=1))
    # statistics match the reference oracles
    rp = ref.fisher_logp_ref(x, nn, float(n), float(n_pos), t_max)
    rf = ref.tarone_logf_ref(x, float(n), float(n_pos))
    np.testing.assert_allclose(np.asarray(logp), np.asarray(rp), rtol=1e-10)
    np.testing.assert_allclose(np.asarray(logf), np.asarray(rf), rtol=1e-10)
    # padding row convention: all-zero bitmap ⇒ x = 0 ⇒ log P = 0
    assert np.asarray(logp)[np.asarray(x) == 0].max(initial=0.0) == 0.0


def test_aot_lowering_produces_hlo_text():
    lowered = aot.lower_screen(k=256, w=4, t_max=32)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    # all four parameters present with the frozen shapes
    assert "u32[256,4]" in text
    assert "f64[1]" in text
    lowered2 = aot.lower_support(k=256, w=4)
    text2 = aot.to_hlo_text(lowered2)
    assert "HloModule" in text2 and "u32[256,4]" in text2
