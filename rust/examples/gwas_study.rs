//! End-to-end driver (the EXPERIMENTS.md validation run): a full GWAS
//! significant-pattern study exercising every layer of the stack —
//!
//! 1. synthetic GWAS cohort generation (dominant model, MAF filter,
//!    planted multi-SNP association),
//! 2. serial LAMP (reference),
//! 3. a coordinated run ([`parlamp::coordinator`]) on the DES fabric at
//!    P = 96 (phases 1–2) with the λ/DTD protocol, calibrated against the
//!    measured serial run,
//! 4. phase 3 through the AOT-compiled XLA/PJRT screen when artifacts are
//!    present (native fallback otherwise),
//! 5. cross-validation of all three paths + paper §5.6-style reporting.
//!
//! ```bash
//! make artifacts && cargo run --release --example gwas_study
//! ```

use parlamp::bench::calibrate_lamp;
use parlamp::coordinator::{Backend, Coordinator, ScreenKind, ScreenMode};
use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::fabric::sim::NetModel;
use parlamp::lamp::lamp_serial;
use parlamp::par::breakdown;
use parlamp::util::bench_harness::time_once;

fn main() {
    // 1. cohort
    let spec = GwasSpec {
        n_snps: 450,
        n_individuals: 192,
        n_pos: 29,
        model: GeneticModel::Dominant,
        maf_upper: 0.20,
        ld_copy_prob: 0.35,
        common_frac: 0.2,
        planted: vec![(4, 0.85)],
        seed: 0xE2E,
    };
    let (db, planted) = generate_gwas(&spec);
    println!("== cohort ==");
    println!(
        "{} SNP items × {} individuals, density {:.2}%, N_pos={}",
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0,
        db.marginals().n_pos
    );
    println!("planted: {:?}", planted[0]);

    // 2. serial reference
    let (t1, serial) = time_once(|| lamp_serial(&db, 0.05));
    println!("\n== serial LAMP ==\nt1={t1:.3}s  {}", serial.summary());

    // 3. coordinated run (DES backend, P = 96)
    let cal = calibrate_lamp(&db, 0.05);
    let p = 96;
    let coord = Coordinator::new(0.05).with_calibration(cal).with_screen(ScreenMode::Native);
    let backend = Backend::Sim { p, net: NetModel::default(), seed: 0xE2E };
    let run = coord.run(&db, &backend).expect("coordinated run");
    let t_par = run.t_parallel_s();
    println!("\n== distributed (coordinator, DES, P={p}) ==");
    // Speedup baseline: the same computation serially (phases 1+2).
    println!(
        "phase1={:.4}s phase2={:.4}s speedup={:.1}x efficiency={:.0}% (serial 1+2: {:.3}s)",
        run.phase1.makespan_s,
        run.phase2.makespan_s,
        cal.t1_s / t_par,
        100.0 * cal.t1_s / t_par / p as f64,
        cal.t1_s
    );
    let comm = run.comm_total();
    println!(
        "steals: {} gives, {} tasks shipped, {} messages, {} bytes",
        comm.gives, comm.tasks_shipped, comm.sent, comm.bytes_sent
    );
    let b = breakdown::sum(&run.phase1.breakdowns);
    let [pre, main, probe, idle] = b.as_secs();
    println!(
        "phase1 CPU breakdown: preprocess={pre:.3}s main={main:.3}s probe={probe:.3}s \
         idle={idle:.3}s"
    );
    let par_res = &run.result;
    assert_eq!(par_res.lambda_final, serial.lambda_final, "parallel must match serial");
    assert_eq!(par_res.correction_factor, serial.correction_factor);

    // 4. phase 3 through the coordinator's Auto screen policy: the
    // XLA/PJRT artifact when present and loadable, native Fisher otherwise
    // (one policy — the same code path the CLI and tests use).
    println!("\n== phase 3 ==");
    let screen_coord = Coordinator::new(0.05).with_screen(ScreenMode::Auto);
    let (t3, (significant, kind)) = time_once(|| {
        screen_coord.screen(&db, serial.min_sup, serial.correction_factor).expect("phase 3")
    });
    match kind {
        ScreenKind::Xla => println!("screen: XLA artifact (AOT from JAX/Pallas), {t3:.3}s"),
        ScreenKind::Native => println!(
            "screen: native Fisher ({t3:.3}s) — run `make artifacts` for the XLA path"
        ),
    }

    // 5. cross-validate + report
    assert_eq!(significant.len(), serial.significant.len(), "screens must agree");
    println!(
        "\n== findings (paper §5.6 style) ==\n{} significant patterns, max arity {}",
        significant.len(),
        significant.iter().map(|s| s.items.len()).max().unwrap_or(0)
    );
    for (i, s) in significant.iter().take(8).enumerate() {
        println!(
            "  {:>2}. {:?} x={} n={} P={:.3e}",
            i + 1,
            s.items,
            s.support,
            s.pos_support,
            s.p_value
        );
    }
    let found = significant.iter().any(|s| planted[0].iter().all(|i| s.items.contains(i)));
    println!("\nplanted association recovered: {found}");
    assert!(found, "the planted association must be recovered");
    println!("\nOK — all layers agree (serial = coordinated; native = XLA screen).");
}
