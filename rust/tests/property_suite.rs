//! Cross-module property suite (the proptest-style invariants of
//! DESIGN.md §6, on the in-repo propcheck harness).

use parlamp::bits::BitVec;
use parlamp::db::{Database, Item};
use parlamp::fabric::sim::NetModel;
use parlamp::glb::Lifelines;
use parlamp::lamp::{lamp_serial, SupportIncreaseRule};
use parlamp::lcm::{brute_force_closed, mine_closed, Visit};
use parlamp::par::{run_sim, RunMode, SimConfig};
use parlamp::stats::{tarone::TaroneBound, FisherTable, Marginals};
use parlamp::util::propcheck::forall;
use parlamp::util::rng::Rng;

fn random_db(rng: &mut Rng, max_items: usize, max_trans: usize) -> Database {
    let m = 2 + rng.index(max_items - 1);
    let n = 2 + rng.index(max_trans - 1);
    let density = 0.15 + rng.f64() * 0.55;
    let trans: Vec<Vec<Item>> =
        (0..n).map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect()).collect();
    let labels: Vec<bool> = (0..n).map(|t| t < n.div_ceil(3)).collect();
    Database::from_transactions(m, &trans, &labels)
}

#[test]
fn closure_is_idempotent_and_support_preserving() {
    forall("closure idempotence", 100, |rng| {
        let db = random_db(rng, 10, 20);
        let m = db.n_items();
        // random itemset
        let items: Vec<Item> = (0..m as Item).filter(|_| rng.bernoulli(0.3)).collect();
        let occ = db.occurrence(&items);
        if occ.count() == 0 {
            return Ok(());
        }
        let closure: Vec<Item> =
            (0..m as Item).filter(|&j| occ.is_subset_of(db.col(j))).collect();
        // support preserved
        if db.support(&closure) != occ.count() {
            return Err(format!("closure changed support: {items:?} -> {closure:?}"));
        }
        // idempotent
        let occ2 = db.occurrence(&closure);
        let closure2: Vec<Item> =
            (0..m as Item).filter(|&j| occ2.is_subset_of(db.col(j))).collect();
        if closure2 != closure {
            return Err(format!("closure not idempotent: {closure:?} -> {closure2:?}"));
        }
        Ok(())
    });
}

#[test]
fn miner_is_exhaustive_and_duplicate_free() {
    forall("PPC enumeration completeness", 50, |rng| {
        let db = random_db(rng, 9, 16);
        let min_sup = 1 + rng.below(3) as u32;
        let mut got: Vec<(Vec<Item>, u32)> = Vec::new();
        mine_closed(&db, min_sup, |n, ms| {
            got.push((n.items.clone(), n.support));
            (Visit::Continue, ms)
        });
        got.sort();
        let want = brute_force_closed(&db, min_sup);
        if got != want {
            return Err(format!("min_sup={min_sup}: {} vs {}", got.len(), want.len()));
        }
        Ok(())
    });
}

#[test]
fn fisher_tail_properties() {
    forall("fisher: bounds, monotonicity, symmetry limits", 80, |rng| {
        let n = 5 + rng.below(400) as u32;
        let npos = 1 + rng.below(n as u64 - 1) as u32;
        let m = Marginals::new(n, npos);
        let f = FisherTable::new(m);
        let t = TaroneBound::new(m);
        let x = 1 + rng.below(n as u64) as u32;
        let lo = x.saturating_sub(n - npos);
        let hi = x.min(npos);
        // P ∈ [f(x), 1]; P(lo) = 1; monotone non-increasing in n.
        let mut prev = f64::INFINITY;
        for nobs in lo..=hi {
            let p = f.p_value(x, nobs);
            if !(0.0..=1.0 + 1e-12).contains(&p) {
                return Err(format!("P out of range: {p}"));
            }
            if p > prev + 1e-12 {
                return Err("not monotone".into());
            }
            if p + 1e-300 < t.f(x) * (1.0 - 1e-9) {
                return Err(format!("P {p:e} below Tarone bound {:e}", t.f(x)));
            }
            prev = p;
        }
        if (f.p_value(x, lo) - 1.0).abs() > 1e-9 {
            return Err("P at lower support limit must be 1".into());
        }
        Ok(())
    });
}

#[test]
fn support_increase_rule_is_sound() {
    // The rule's final λ must always satisfy: condition 3.1 holds for all
    // levels below, fails at λ (on the histogram it was given).
    forall("rule soundness", 60, |rng| {
        let n = 10 + rng.below(200) as u32;
        let npos = 1 + rng.below(n as u64 / 2) as u32;
        let rule = SupportIncreaseRule::new(Marginals::new(n, npos), 0.05);
        // random decreasing cs_ge
        let mut levels = vec![0u64; n as usize + 2];
        let mut acc = 0u64;
        for s in (1..=n as usize).rev() {
            acc += rng.below(50);
            levels[s] = acc;
        }
        let cs = |l: u32| levels.get(l as usize).copied().unwrap_or(0);
        let lambda = rule.advance(1, cs);
        if lambda > 1 && !rule.exceeded(lambda - 1, cs(lambda - 1)) {
            return Err(format!("λ={lambda} but level {} not exceeded", lambda - 1));
        }
        if lambda <= n && rule.exceeded(lambda, cs(lambda)) {
            return Err(format!("λ={lambda} still exceeded"));
        }
        Ok(())
    });
}

#[test]
fn des_results_independent_of_network_and_seed() {
    // Protocol nondeterminism (steal victims, message timing) must never
    // change the *computed result*, only the timing.
    forall("result invariance", 12, |rng| {
        let db = random_db(rng, 10, 24);
        let serial = lamp_serial(&db, 0.05);
        let p = 2 + rng.index(20);
        for (seed, net) in
            [(1u64, NetModel::default()), (2, NetModel::ethernet()), (3, NetModel::default())]
        {
            let cfg = SimConfig { p, seed, net, ..SimConfig::paper_defaults(p) };
            let out = run_sim(&db, RunMode::Count { min_sup: serial.min_sup }, &cfg);
            if out.closed_total != serial.correction_factor {
                return Err(format!(
                    "p={p} seed={seed}: count {} != serial {}",
                    out.closed_total, serial.correction_factor
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn bitvec_algebra_laws() {
    forall("bitvec boolean-algebra laws", 100, |rng| {
        let len = 1 + rng.index(260);
        let mk = |rng: &mut Rng, d: f64| {
            BitVec::from_indices(len, (0..len).filter(|_| rng.bernoulli(d)))
        };
        let a = mk(rng, 0.5);
        let b = mk(rng, 0.5);
        let c = mk(rng, 0.5);
        // commutativity, associativity, absorption-ish via subset
        if a.and(&b) != b.and(&a) {
            return Err("AND not commutative".into());
        }
        if a.and(&b).and(&c) != a.and(&b.and(&c)) {
            return Err("AND not associative".into());
        }
        if !a.and(&b).is_subset_of(&a) {
            return Err("a∧b ⊄ a".into());
        }
        if a.and(&a) != a {
            return Err("AND not idempotent".into());
        }
        Ok(())
    });
}

#[test]
fn lifeline_graph_strongly_connected_for_all_small_worlds() {
    // Paper §4.2 / DESIGN.md §6: work flows victim→thief along *directed*
    // lifeline edges, and Mattern termination is only deadlock-free if a
    // starving process can eventually be reached from any process that
    // still has work — i.e. the directed lifeline graph must be strongly
    // connected. Exhaustive over every world size the benches use and both
    // hypercube edge lengths of the ablation (P ≤ 256, l ∈ {2, 3}).
    fn reach_count(adj: &[Vec<usize>], start: usize) -> usize {
        let mut seen = vec![false; adj.len()];
        let mut queue = std::collections::VecDeque::from([start]);
        seen[start] = true;
        let mut n = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    n += 1;
                    queue.push_back(v);
                }
            }
        }
        n
    }
    for l in [2usize, 3] {
        for p in 1..=256usize {
            let fwd: Vec<Vec<usize>> =
                (0..p).map(|r| Lifelines::new(r, p, l).neighbors().to_vec()).collect();
            let mut rev: Vec<Vec<usize>> = vec![Vec::new(); p];
            for (u, ns) in fwd.iter().enumerate() {
                for &v in ns {
                    assert!(v < p && v != u, "P={p} l={l}: bad edge {u}->{v}");
                    rev[v].push(u);
                }
            }
            if p >= 2 {
                for (r, ns) in fwd.iter().enumerate() {
                    assert!(
                        !ns.is_empty(),
                        "P={p} l={l}: rank {r} has no outgoing lifeline (would starve)"
                    );
                }
            }
            assert_eq!(reach_count(&fwd, 0), p, "P={p} l={l}: not forward-reachable from 0");
            assert_eq!(reach_count(&rev, 0), p, "P={p} l={l}: rank 0 not reachable from all");
        }
    }
}

#[test]
fn wire_roundtrip_preserves_node_identity() {
    // Shipping a node (dropping its bitmap) then re-expanding must produce
    // the same children as expanding the original.
    forall("steal wire roundtrip", 40, |rng| {
        let db = random_db(rng, 10, 20);
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        let mut scratch = parlamp::lcm::ExpandScratch::default();
        // take some node from a quick mine
        let mut nodes = Vec::new();
        mine_closed(&db, 1, |n, ms| {
            nodes.push(n.clone());
            (if nodes.len() >= 8 { Visit::Stop } else { Visit::Continue }, ms)
        });
        for mut node in nodes {
            let mut shipped = node.clone();
            shipped.strip_for_wire();
            out_a.clear();
            out_b.clear();
            parlamp::lcm::expand(&db, &mut node, 1, &mut scratch, &mut out_a);
            parlamp::lcm::expand(&db, &mut shipped, 1, &mut scratch, &mut out_b);
            if out_a.len() != out_b.len()
                || out_a
                    .iter()
                    .zip(&out_b)
                    .any(|(x, y)| x.items != y.items || x.support != y.support)
            {
                return Err("wire roundtrip changed expansion".into());
            }
        }
        Ok(())
    });
}
