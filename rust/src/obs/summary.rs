//! `parlamp trace summary <file>`: recompute the paper's Fig. 7 view
//! from an exported Chrome trace.
//!
//! Reads the trace-event JSON written by [`crate::obs::chrome::export`]
//! (via the same hand-rolled parser the bench schema uses) and prints
//! three things a timeline viewer shows visually but a terminal wants as
//! numbers: a per-rank breakdown table (phase span seconds, expansion
//! units, steal traffic, ring overflow), a who-stole-from-whom matrix of
//! shipped stack roots, and DTD wave arrival spreads — the latency of
//! each termination-detection wave front across the fleet.

use crate::bench::report::{parse_json, Json};
use crate::util::table::Table;
use anyhow::{Context, Result};
use std::collections::BTreeMap;

#[derive(Default, Clone)]
struct RankAgg {
    phase_s: [f64; 3],
    expand_units: u64,
    steal_requests: u64,
    rejects: u64,
    gives: u64,
    tasks_out: u64,
    tasks_in: u64,
    dropped: u64,
}

/// How many DTD waves the summary lists individually before truncating
/// (with an explicit "+N more" note — never a silent cap).
const MAX_WAVE_ROWS: usize = 16;

/// Summarize a Chrome trace-event JSON document into the terminal report.
pub fn summarize(doc: &str) -> Result<String> {
    let v = parse_json(doc).context("parse trace JSON")?;
    let events = v
        .get("traceEvents")
        .and_then(Json::as_arr)
        .context("missing 'traceEvents' array — not a parlamp trace?")?;

    let mut names: BTreeMap<u64, String> = BTreeMap::new();
    let mut ranks: BTreeMap<u64, RankAgg> = BTreeMap::new();
    // matrix[(victim, thief)] = tasks shipped victim → thief
    let mut matrix: BTreeMap<(u64, u64), u64> = BTreeMap::new();
    // wave (id, up) → arrival timestamps (µs)
    let mut waves: BTreeMap<(u64, bool), Vec<f64>> = BTreeMap::new();

    let num = |e: &Json, k: &str| e.get(k).and_then(Json::as_f64);
    let arg = |e: &Json, k: &str| e.get("args").and_then(|a| a.get(k)).and_then(Json::as_f64);
    let arg_bool = |e: &Json, k: &str| {
        matches!(e.get("args").and_then(|a| a.get(k)), Some(Json::Bool(true)))
    };

    for e in events {
        let ph = e.get("ph").and_then(Json::as_str).unwrap_or("");
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        let tid = num(e, "tid").unwrap_or(0.0) as u64;
        match ph {
            "M" if name == "thread_name" => {
                if let Some(n) = e.get("args").and_then(|a| a.get("name")).and_then(Json::as_str)
                {
                    names.insert(tid, n.to_string());
                }
            }
            "X" => {
                let phase = match name {
                    "phase1" => 0,
                    "phase2" => 1,
                    "phase3" => 2,
                    _ => continue,
                };
                let dur_us = num(e, "dur").unwrap_or(0.0);
                ranks.entry(tid).or_default().phase_s[phase] += dur_us / 1e6;
            }
            "i" => {
                let a = ranks.entry(tid).or_default();
                match name {
                    "expand" => a.expand_units += arg(e, "units").unwrap_or(0.0) as u64,
                    "steal.request" => a.steal_requests += 1,
                    "steal.reject" => a.rejects += 1,
                    "steal.give" => {
                        let tasks = arg(e, "tasks").unwrap_or(0.0) as u64;
                        a.gives += 1;
                        a.tasks_out += tasks;
                        if let Some(thief) = arg(e, "dst") {
                            *matrix.entry((tid, thief as u64)).or_default() += tasks;
                        }
                    }
                    "steal.recv" => a.tasks_in += arg(e, "tasks").unwrap_or(0.0) as u64,
                    "dtd.wave" => {
                        let t = arg(e, "t").unwrap_or(0.0) as u64;
                        let ts = num(e, "ts").unwrap_or(0.0);
                        waves.entry((t, arg_bool(e, "up"))).or_default().push(ts);
                    }
                    "trace.dropped" => a.dropped += arg(e, "dropped").unwrap_or(0.0) as u64,
                    _ => {}
                }
            }
            _ => {}
        }
    }

    let rank_label = |tid: u64| {
        names
            .get(&tid)
            .cloned()
            .unwrap_or_else(|| format!("tid {tid}"))
    };

    let mut out = String::new();

    // -- per-rank breakdown (Fig. 7) -----------------------------------
    out.push_str("per-rank breakdown (paper Fig. 7)\n");
    let mut t = Table::new(&[
        "rank", "phase1 s", "phase2 s", "phase3 s", "expand units", "steal req", "rejects",
        "gives", "tasks out", "tasks in", "dropped",
    ]);
    for (tid, a) in &ranks {
        t.row(vec![
            rank_label(*tid),
            format!("{:.6}", a.phase_s[0]),
            format!("{:.6}", a.phase_s[1]),
            format!("{:.6}", a.phase_s[2]),
            a.expand_units.to_string(),
            a.steal_requests.to_string(),
            a.rejects.to_string(),
            a.gives.to_string(),
            a.tasks_out.to_string(),
            a.tasks_in.to_string(),
            a.dropped.to_string(),
        ]);
    }
    out.push_str(&t.render());

    // -- steal matrix ---------------------------------------------------
    out.push_str("\nsteal matrix (tasks shipped, victim row -> thief column)\n");
    if matrix.is_empty() {
        out.push_str("(no steals recorded)\n");
    } else {
        let mut thieves: Vec<u64> = matrix.keys().map(|&(_, t)| t).collect();
        thieves.sort_unstable();
        thieves.dedup();
        let mut victims: Vec<u64> = matrix.keys().map(|&(v, _)| v).collect();
        victims.sort_unstable();
        victims.dedup();
        let mut header: Vec<String> = vec!["victim".to_string()];
        header.extend(thieves.iter().map(|t| rank_label(*t)));
        let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
        let mut t = Table::new(&header_refs);
        for v in &victims {
            let mut row = vec![rank_label(*v)];
            for th in &thieves {
                row.push(matrix.get(&(*v, *th)).copied().unwrap_or(0).to_string());
            }
            t.row(row);
        }
        out.push_str(&t.render());
    }

    // -- DTD wave latencies --------------------------------------------
    out.push_str("\nDTD waves (arrival spread across ranks)\n");
    if waves.is_empty() {
        out.push_str("(no waves recorded)\n");
    } else {
        let mut t = Table::new(&["wave", "dir", "arrivals", "first us", "last us", "spread us"]);
        let total = waves.len();
        for ((id, up), ts) in waves.iter().take(MAX_WAVE_ROWS) {
            let first = ts.iter().cloned().fold(f64::INFINITY, f64::min);
            let last = ts.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            t.row(vec![
                id.to_string(),
                if *up { "up".to_string() } else { "down".to_string() },
                ts.len().to_string(),
                format!("{first:.1}"),
                format!("{last:.1}"),
                format!("{:.1}", last - first),
            ]);
        }
        out.push_str(&t.render());
        if total > MAX_WAVE_ROWS {
            out.push_str(&format!("(+{} more waves not shown)\n", total - MAX_WAVE_ROWS));
        }
    }

    let dropped: u64 = ranks.values().map(|a| a.dropped).sum();
    if dropped > 0 {
        out.push_str(&format!(
            "\nWARNING: {dropped} events were dropped by full trace rings; \
             totals above undercount.\n"
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::chrome;
    use crate::obs::trace::{EventKind, RankTrace, TraceEvent};

    fn rt(rank: u32, events: Vec<(u64, EventKind)>) -> RankTrace {
        RankTrace {
            rank,
            offset_ns: 0,
            uncertainty_ns: 0,
            dropped: 0,
            events: events
                .into_iter()
                .map(|(t_ns, kind)| TraceEvent { t_ns, kind })
                .collect(),
        }
    }

    #[test]
    fn summary_reports_breakdown_matrix_and_waves() {
        let r0 = rt(
            0,
            vec![
                (0, EventKind::PhaseStart { phase: 1, epoch: 0 }),
                (100, EventKind::ExpandBatch { units: 50 }),
                (200, EventKind::StealRequest { dst: 1, lifeline: true }),
                (900, EventKind::StealRecv { src: 1, tasks: 4 }),
                (1_000, EventKind::WaveArrive { t: 1, up: false }),
                (2_000_000, EventKind::PhaseEnd { phase: 1, epoch: 0 }),
            ],
        );
        let r1 = rt(
            1,
            vec![
                (0, EventKind::PhaseStart { phase: 1, epoch: 0 }),
                (500, EventKind::StealGive { dst: 0, tasks: 4 }),
                (1_500, EventKind::WaveArrive { t: 1, up: false }),
                (2_000_000, EventKind::PhaseEnd { phase: 1, epoch: 0 }),
            ],
        );
        let json = chrome::export(&[r0, r1]);
        let out = summarize(&json).unwrap();
        assert!(out.contains("per-rank breakdown"), "{out}");
        assert!(out.contains("rank 0"), "{out}");
        assert!(out.contains("rank 1"), "{out}");
        assert!(out.contains("0.002000"), "phase span seconds missing:\n{out}");
        assert!(out.contains("steal matrix"), "{out}");
        assert!(out.contains("DTD waves"), "{out}");
        // wave 1 spread: 1.5 µs − 1.0 µs = 0.5 µs
        assert!(out.contains("0.5"), "wave spread missing:\n{out}");
    }

    #[test]
    fn summary_flags_dropped_events() {
        let mut r = rt(0, vec![(10, EventKind::ExpandBatch { units: 1 })]);
        r.dropped = 3;
        let out = summarize(&chrome::export(&[r])).unwrap();
        assert!(out.contains("3 events were dropped"), "{out}");
    }

    #[test]
    fn summary_rejects_non_trace_json() {
        assert!(summarize("{\"a\": 1}").is_err());
        assert!(summarize("not json").is_err());
    }

    #[test]
    fn empty_sections_render_placeholders() {
        let r = rt(
            2,
            vec![
                (0, EventKind::PhaseStart { phase: 2, epoch: 0 }),
                (10, EventKind::PhaseEnd { phase: 2, epoch: 0 }),
            ],
        );
        let out = summarize(&chrome::export(&[r])).unwrap();
        assert!(out.contains("(no steals recorded)"), "{out}");
        assert!(out.contains("(no waves recorded)"), "{out}");
    }
}
