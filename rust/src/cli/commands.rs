//! Subcommand implementations. Every LAMP pipeline — serial or
//! distributed — dispatches its phases through [`crate::coordinator`], so
//! the CLI, the examples, and the benches exercise one orchestration path.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::bench::{all_scenarios, measure_engine, report, BenchRecord, BenchReport, ENGINES};
use crate::coordinator::{
    parse_engine, Backend, Coordinator, CoordinatorRun, EngineSelect, GlbParams, ScreenKind,
    ScreenMode, Transport,
};
use crate::db::{read_labels, read_transactions, Database};
use crate::fabric::sim::NetModel;
use crate::lamp::{lamp2::lamp2_serial, lamp_serial, SignificantPattern};
use crate::lcm::{mine_closed, Visit};
use crate::net::fault::NetFaultPlan;
use crate::net::Endpoint;
use crate::obs::log::{self, Tags};
use crate::obs::trace::RankTrace;
use crate::obs::{chrome, prom, summary, trace as obs_trace};
use crate::par::{DataPlane, ProcessConfig, ProcessFleet};
use crate::service::{print_join_commands, Client, QueueLimits, ServeConfig};
use crate::util::fault::FaultPlan;
use crate::util::table::Table;
use crate::wire::service::{JobSpec, JobState};

use super::args::Args;

fn load_db(args: &Args) -> Result<Database> {
    let data = args.require("data")?;
    let labels_path = args.require("labels")?;
    let (n_items, trans) = read_transactions(Path::new(data))?;
    let labels = read_labels(Path::new(labels_path))?;
    anyhow::ensure!(
        labels.len() == trans.len(),
        "{} labels vs {} transactions",
        labels.len(),
        trans.len()
    );
    Ok(Database::from_transactions(n_items, &trans, &labels))
}

fn scenario_db(args: &Args) -> Result<(String, Database)> {
    let name = args.require("scenario")?;
    let quick = args.flag("quick");
    let sc = all_scenarios(quick)
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown scenario '{name}' (see `parlamp scenarios`)"))?;
    Ok((name.to_string(), sc.build()))
}

fn parse_screen(args: &Args) -> Result<ScreenMode> {
    match args.get("screen").unwrap_or("native") {
        "native" => Ok(ScreenMode::Native),
        "xla" => Ok(ScreenMode::Xla),
        "auto" => Ok(ScreenMode::Auto),
        other => bail!("unknown --screen '{other}' (native|xla|auto)"),
    }
}

/// `--data-plane hub|mesh` (default mesh): which topology carries the
/// process engine's steal traffic and DTD waves (DESIGN.md §10). Ignored
/// by the other engines.
fn data_plane_from_args(args: &Args) -> Result<DataPlane> {
    DataPlane::parse(args.get("data-plane").unwrap_or("mesh")).context("--data-plane")
}

/// `--transport unix|tcp` (default unix): which stream transport carries
/// the process engine's fabric (DESIGN.md §11). Ignored by the other
/// engines.
fn transport_from_args(args: &Args) -> Result<Transport> {
    args.get("transport").unwrap_or("unix").parse().context("--transport")
}

/// `--fault-inject rank=R,phase=P,after=N` (DESIGN.md §12): arm one
/// deterministic worker death for the chaos harness. Only the process
/// backend (and `serve`'s warm fleet) consumes it.
fn fault_from_args(args: &Args) -> Result<Option<FaultPlan>> {
    match args.get("fault-inject") {
        Some(plan) => Ok(Some(plan.parse().context("--fault-inject")?)),
        None => Ok(None),
    }
}

/// `--net-fault rank=R,kind=stall|drop|corrupt|partition,phase=P,after=N`
/// (DESIGN.md §15): arm one deterministic network fault under a rank's
/// fabric stream. Only the process backend (and `serve`'s warm fleet)
/// consumes it.
fn net_fault_from_args(args: &Args) -> Result<Option<NetFaultPlan>> {
    match args.get("net-fault") {
        Some(plan) => Ok(Some(plan.parse().context("--net-fault")?)),
        None => Ok(None),
    }
}

/// `--lease-timeout SECS` (DESIGN.md §15): heartbeat-lease timeout for the
/// process backend's hub. `None` keeps the 60 s default.
fn lease_timeout_from_args(args: &Args) -> Result<Option<Duration>> {
    match args.get("lease-timeout") {
        Some(_) => {
            let secs = args.get_u64("lease-timeout", 0)?;
            anyhow::ensure!(secs > 0, "--lease-timeout must be a positive number of seconds");
            Ok(Some(Duration::from_secs(secs)))
        }
        None => Ok(None),
    }
}

/// The service endpoint: `--endpoint unix:<path>|tcp:<host>:<port>`, with
/// `--socket PATH` kept as a deprecated alias (a bare path parses as a
/// Unix endpoint).
fn endpoint_from_args(args: &Args) -> Result<Endpoint> {
    let raw = args
        .get("endpoint")
        .or_else(|| args.get("socket"))
        .context("missing required --endpoint (unix:<path> | tcp:<host>:<port>)")?;
    raw.parse().context("--endpoint")
}

/// `--hosts h1:p,h2:p,…` → one mesh data-plane endpoint per rank. Bare
/// `host:port` entries are TCP; explicit `unix:`/`tcp:` schemes pass
/// through.
fn hosts_from_args(args: &Args) -> Result<Option<Vec<Endpoint>>> {
    let Some(spec) = args.get("hosts") else {
        return Ok(None);
    };
    let mut out = Vec::new();
    for h in spec.split(',').filter(|s| !s.is_empty()) {
        let ep: Endpoint = if h.starts_with("unix:") || h.starts_with("tcp:") {
            h.parse()
        } else {
            format!("tcp:{h}").parse()
        }
        .with_context(|| format!("--hosts entry '{h}' (want host:port)"))?;
        out.push(ep);
    }
    anyhow::ensure!(!out.is_empty(), "--hosts needs at least one host:port entry");
    Ok(Some(out))
}

/// The `--hosts` launcher path: bind the hub, print one copy-pasteable
/// join command per rank, wait for the remote workers to attach, run the
/// three-phase procedure across them, and dismiss the fleet. The hub
/// listens at `--endpoint` (default `tcp:127.0.0.1:0` — pass
/// `--endpoint tcp:0.0.0.0:<port>` to accept off-host workers).
fn run_lamp_hosts(
    coord: &Coordinator,
    db: &Database,
    args: &Args,
    hosts: &[Endpoint],
    data_plane: DataPlane,
    seed: u64,
) -> Result<CoordinatorRun> {
    let listen = match args.get("endpoint").or_else(|| args.get("socket")) {
        Some(raw) => raw.parse().context("--endpoint")?,
        None => Endpoint::tcp("127.0.0.1", 0),
    };
    let cfg = ProcessConfig {
        data_plane,
        listen: Some(listen),
        remote_workers: Some(hosts.to_vec()),
        ..ProcessConfig::paper_defaults(hosts.len(), seed)
    };
    let pending = ProcessFleet::bind(&cfg)?;
    print_join_commands(&pending, hosts);
    let mut fleet = pending.await_workers()?;
    let run = coord.run_on_fleet(db, &mut fleet, seed)?;
    fleet.shutdown()?;
    Ok(run)
}

fn glb_from_args(args: &Args) -> GlbParams {
    let base = if args.flag("naive") {
        GlbParams::naive()
    } else {
        GlbParams::default()
    };
    GlbParams { preprocess: !args.flag("no-preprocess"), ..base }
}

fn print_significant(significant: &[SignificantPattern]) {
    let mut t = Table::new(&["rank", "items", "x", "n", "p-value"]);
    for (i, s) in significant.iter().take(20).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            format!("{:?}", s.items),
            s.support.to_string(),
            s.pos_support.to_string(),
            format!("{:.3e}", s.p_value),
        ]);
    }
    println!("{}", t.render());
    if significant.len() > 20 {
        println!("… and {} more", significant.len() - 20);
    }
}

/// `parlamp lamp` — full three-phase LAMP on a dataset from disk, on any
/// engine: `serial` (reference), `lamp2` (occurrence-deliver comparator),
/// or a coordinated distributed run on `threads` / `sim` / `process`.
/// Engine-name dispatch goes through [`parse_engine`] — the same resolver
/// (and error message) the bench harness uses.
pub fn cmd_lamp(args: &Args) -> Result<()> {
    let db = load_db(args)?;
    let alpha = args.get_f64("alpha", crate::DEFAULT_ALPHA)?;
    let engine = args.get("engine").unwrap_or("serial");
    let p = args.get_usize("procs", 4)?;
    let seed = args.get_u64("seed", 2015)?;
    let select = parse_engine(engine, p, seed)?;
    let screen = parse_screen(args)?;
    // Validated for every engine so a typo'd flag errors instead of being
    // silently ignored; only the process backend actually consumes them.
    let data_plane = data_plane_from_args(args)?;
    let transport = transport_from_args(args)?;
    let hosts = hosts_from_args(args)?;
    let fault = fault_from_args(args)?;
    let net_fault = net_fault_from_args(args)?;
    let lease_timeout = lease_timeout_from_args(args)?;
    anyhow::ensure!(
        hosts.is_none() || engine == "process",
        "--hosts requires --engine process (got '{engine}')"
    );
    anyhow::ensure!(
        fault.is_none() || engine == "process",
        "--fault-inject requires --engine process (got '{engine}')"
    );
    anyhow::ensure!(
        net_fault.is_none() || engine == "process",
        "--net-fault requires --engine process (got '{engine}')"
    );
    anyhow::ensure!(
        lease_timeout.is_none() || engine == "process",
        "--lease-timeout requires --engine process (got '{engine}')"
    );
    // Tracing needs ranks; the serial pipelines have none (DESIGN.md §14).
    let trace_out = args.get("trace");
    anyhow::ensure!(
        trace_out.is_none() || matches!(select, EngineSelect::Backend(_)),
        "--trace requires a distributed engine (threads|sim|process), got '{engine}'"
    );
    if trace_out.is_some() {
        obs_trace::set_enabled(true);
    }
    println!(
        "N={} items={} density={:.4}% N_pos={}",
        db.n_trans(),
        db.n_items(),
        db.density() * 100.0,
        db.marginals().n_pos
    );

    let significant: Vec<SignificantPattern> = match select {
        EngineSelect::Serial | EngineSelect::Lamp2 => {
            let res = match select {
                EngineSelect::Serial => lamp_serial(&db, alpha),
                _ => lamp2_serial(&db, alpha),
            };
            // The serial pipelines already ran the native phase 3; only
            // re-dispatch through the coordinator's screen policy when a
            // non-native screen was requested (PJRT artifact / auto).
            let (sig, kind) = match screen {
                ScreenMode::Native => (res.significant.clone(), ScreenKind::Native),
                _ => {
                    let coord = Coordinator::new(alpha).with_screen(screen);
                    coord.screen(&db, res.min_sup, res.correction_factor)?
                }
            };
            println!("{} | engine={engine} screen={kind:?}", res.summary());
            sig
        }
        EngineSelect::Backend(backend) => {
            let backend = backend.with_data_plane(data_plane).with_transport(transport);
            let mut coord =
                Coordinator::new(alpha).with_glb(glb_from_args(args)).with_screen(screen);
            if let Some(plan) = fault {
                coord = coord.with_fault_plan(plan);
            }
            if let Some(plan) = net_fault {
                coord = coord.with_net_fault_plan(plan);
            }
            if let Some(t) = lease_timeout {
                coord = coord.with_lease_timeout(t);
            }
            // Smaller quanta = more steal opportunities on short runs;
            // pairs with --trace to make the protocol visible (§14).
            if args.get("probe-budget").is_some() {
                coord = coord.with_probe_budget(args.get_u64("probe-budget", 0)?);
            }
            let run = match &hosts {
                Some(hosts) => run_lamp_hosts(&coord, &db, args, hosts, data_plane, seed)?,
                None => coord.run(&db, &backend)?,
            };
            let world = hosts.as_ref().map_or(p, Vec::len);
            println!("engine={engine} P={world} | {}", run.summary());
            if let Some(path) = trace_out {
                std::fs::write(path, chrome::export(&run.traces()))
                    .with_context(|| format!("write {path}"))?;
                println!("wrote {path} (trace-event JSON; load at ui.perfetto.dev)");
            }
            run.result.significant
        }
    };
    print_significant(&significant);
    Ok(())
}

/// `parlamp mine` — plain frequent closed itemset mining.
pub fn cmd_mine(args: &Args) -> Result<()> {
    let data = args.require("data")?;
    let (n_items, trans) = read_transactions(Path::new(data))?;
    let labels = vec![false; trans.len()];
    let db = Database::from_transactions(n_items, &trans, &labels);
    let min_sup = args.get_usize("min-sup", 1)? as u32;
    let mut count = 0u64;
    let verbose = args.flag("verbose");
    let stats = mine_closed(&db, min_sup, |node, ms| {
        count += 1;
        if verbose {
            println!("{:?} (sup {})", node.items, node.support);
        }
        (Visit::Continue, ms)
    });
    println!(
        "closed itemsets: {count} (scanned {} candidates, {} word-ops + {} reduce-ops)",
        stats.expand.candidates, stats.expand.word_ops, stats.expand.reduce_ops
    );
    Ok(())
}

/// `parlamp sim` — one coordinated DES run with full reporting.
pub fn cmd_sim(args: &Args) -> Result<()> {
    let (name, db) = scenario_db(args)?;
    let p = args.get_usize("procs", 12)?;
    let alpha = args.get_f64("alpha", crate::DEFAULT_ALPHA)?;
    // The speedup baseline is the *same computation* serially: LAMP
    // phases 1+2 with support-increase pruning (not a full enumeration).
    // The measurement doubles as the DES cost-model calibration.
    let cal = crate::bench::calibrate_lamp(&db, alpha);
    let t1 = cal.t1_s;
    let coord = Coordinator::new(alpha)
        .with_glb(glb_from_args(args))
        .with_calibration(cal)
        .with_screen(ScreenMode::Auto);
    let net = if args.flag("ethernet") {
        NetModel::ethernet()
    } else {
        NetModel::default()
    };
    let backend = Backend::Sim { p, net, seed: args.get_u64("seed", 2015)? };
    let run = coord.run(&db, &backend)?;
    println!("scenario {name}: {}", run.result.summary());
    println!(
        "serial t1={:.3}s | P={p} phase1={:.4}s phase2={:.4}s speedup₁={:.1}× screen={:?}",
        t1,
        run.phase1.makespan_s,
        run.phase2.makespan_s,
        t1 / run.t_parallel_s().max(1e-12),
        run.screen,
    );
    let comm = run.comm_total();
    println!(
        "comm: sent={} gives={} tasks={} rejects={} bytes={}",
        comm.sent, comm.gives, comm.tasks_shipped, comm.rejects, comm.bytes_sent,
    );
    let b = crate::par::breakdown::sum(&run.phase1.breakdowns);
    let [pre, main, probe, idle] = b.as_secs();
    println!(
        "phase1 cpu-time: preprocess={pre:.4}s main={main:.4}s probe={probe:.4}s \
         idle={idle:.4}s"
    );
    Ok(())
}

/// `parlamp bench` — the perf-trajectory harness: run the Table-1
/// scenarios across engines, emit a schema-stable `BENCH_*.json`
/// (validated before it is written), or validate an existing file with
/// `--check`.
///
/// Defaults: all six scenarios × all five engines; `--quick` shrinks the
/// datasets *and* narrows the default scenario set to one (`mcf7`) so CI
/// can smoke every engine cheaply. Timings in the file are informative;
/// only the schema is a contract (see README "Benchmarks").
pub fn cmd_bench(args: &Args) -> Result<()> {
    if let Some(path) = args.get("check") {
        let doc = std::fs::read_to_string(path)
            .with_context(|| format!("read {path}"))?;
        let n = report::validate(&doc).with_context(|| format!("validate {path}"))?;
        println!("{path}: valid {} ({n} runs)", crate::bench::SCHEMA_ID);
        return Ok(());
    }
    // `--compare A.json,B.json` (or `--compare A.json --with B.json`):
    // diff two reports per (scenario, engine) — errors on result-field
    // mismatches, so it doubles as a CI regression gate.
    if let Some(spec) = args.get("compare") {
        let (path_a, path_b) = match spec.split_once(',') {
            Some((a, b)) => (a.to_string(), b.to_string()),
            None => (spec.to_string(), args.require("with")?.to_string()),
        };
        let doc_a = std::fs::read_to_string(&path_a)
            .with_context(|| format!("read {path_a}"))?;
        let doc_b = std::fs::read_to_string(&path_b)
            .with_context(|| format!("read {path_b}"))?;
        print!("{}", report::compare(&doc_a, &doc_b)?);
        return Ok(());
    }

    let quick = args.flag("quick");
    let alpha = args.get_f64("alpha", crate::DEFAULT_ALPHA)?;
    let procs = args.get_usize("procs", 4)?;
    let seed = args.get_u64("seed", 2015)?;
    let data_plane = data_plane_from_args(args)?;
    let transport = transport_from_args(args)?;
    // `--trace FILE`: record every distributed run and export the last
    // one's timeline (the bench loop reuses ranks run after run, so one
    // merged file would stack unrelated scenarios on the same tracks).
    let trace_out = args.get("trace");
    if trace_out.is_some() {
        obs_trace::set_enabled(true);
    }
    let mut last_trace: Option<(String, String, Vec<RankTrace>)> = None;
    let label = args.get("label").unwrap_or("pr9");
    let default_out = format!("BENCH_{label}.json");
    let out = args.get("out").unwrap_or(&default_out);
    let default_engines = ENGINES.join(",");
    let engines: Vec<&str> = args
        .get("engines")
        .unwrap_or(&default_engines)
        .split(',')
        .filter(|e| !e.is_empty())
        .collect();
    // Fail on a typo before any measurement runs, not minutes into it.
    for e in &engines {
        anyhow::ensure!(ENGINES.contains(e), "unknown engine '{e}' ({})", ENGINES.join("|"));
    }
    let default_scenarios = if quick { "mcf7" } else { "all" };
    let wanted = args.get("scenarios").unwrap_or(default_scenarios);
    let all = all_scenarios(quick);
    let chosen: Vec<_> = if wanted == "all" {
        all
    } else {
        let names: Vec<&str> = wanted.split(',').filter(|s| !s.is_empty()).collect();
        for n in &names {
            anyhow::ensure!(
                all.iter().any(|s| s.name == *n),
                "unknown scenario '{n}' (see `parlamp scenarios`)"
            );
        }
        all.into_iter().filter(|s| names.contains(&s.name)).collect()
    };
    anyhow::ensure!(!chosen.is_empty(), "no scenarios selected");
    anyhow::ensure!(!engines.is_empty(), "no engines selected");

    let mut rep = BenchReport::new(label, quick, alpha, seed);
    let mut t = Table::new(&["scenario", "engine", "wall", "units", "λ*", "k", "sig"]);
    for sc in &chosen {
        let db = sc.build();
        println!(
            "scenario {}: {} items × {} transactions, density {:.2}%",
            sc.name,
            db.n_items(),
            db.n_trans(),
            db.density() * 100.0
        );
        for &engine in &engines {
            let r = measure_engine(&db, engine, procs, alpha, seed, data_plane, transport)
                .with_context(|| format!("{} on {}", engine, sc.name))?;
            if trace_out.is_some() && !r.traces.is_empty() {
                last_trace = Some((sc.name.to_string(), engine.to_string(), r.traces.clone()));
            }
            t.row(vec![
                sc.name.to_string(),
                engine.to_string(),
                crate::util::fmt_secs(r.wall_s),
                r.work_units.to_string(),
                r.lambda_star.to_string(),
                r.correction_factor.to_string(),
                r.significant.to_string(),
            ]);
            rep.push(BenchRecord {
                scenario: sc.name.to_string(),
                engine: engine.to_string(),
                data_plane: if engine == "process" {
                    data_plane.name().to_string()
                } else {
                    "none".to_string()
                },
                transport: if engine == "process" {
                    transport.name().to_string()
                } else {
                    "none".to_string()
                },
                procs: if matches!(engine, "serial" | "lamp2") { 1 } else { procs },
                n_items: db.n_items(),
                n_trans: db.n_trans(),
                density: db.density(),
                wall_s: r.wall_s,
                t_parallel_s: r.t_parallel_s,
                work_units: r.work_units,
                word_ops: r.word_ops,
                reduce_ops: r.reduce_ops,
                lambda_star: r.lambda_star,
                min_sup: r.min_sup,
                correction_factor: r.correction_factor,
                phase1_closed: r.phase1_closed,
                phase2_closed: r.phase2_closed,
                significant: r.significant,
                hub_frames: r.hub_frames,
                direct_frames: r.direct_frames,
                preprocess_s: r.preprocess_s,
                main_s: r.main_s,
                probe_s: r.probe_s,
                idle_s: r.idle_s,
                steal_sent: r.steal_sent,
                steal_gives: r.steal_gives,
                tasks_shipped: r.tasks_shipped,
            });
        }
    }
    println!("{}", t.render());

    let doc = rep.to_json();
    report::validate(&doc).context("self-check emitted JSON")?;
    std::fs::write(out, &doc).with_context(|| format!("write {out}"))?;
    println!("wrote {out} ({} runs, schema {})", rep.len(), crate::bench::SCHEMA_ID);
    if let Some(path) = trace_out {
        let (sc, engine, traces) = last_trace
            .context("--trace recorded nothing (no distributed engine in the selection)")?;
        std::fs::write(path, chrome::export(&traces))
            .with_context(|| format!("write {path}"))?;
        println!("wrote {path} (trace of the last distributed run: {sc}/{engine})");
    }
    Ok(())
}

/// `parlamp gendata` — write a scenario to FIMI files.
pub fn cmd_gendata(args: &Args) -> Result<()> {
    let (name, db) = scenario_db(args)?;
    let out = PathBuf::from(args.require("out")?);
    std::fs::create_dir_all(&out)?;
    // reconstruct horizontal form
    let mut trans: Vec<Vec<crate::db::Item>> = vec![Vec::new(); db.n_trans()];
    for i in 0..db.n_items() as crate::db::Item {
        for t in db.col(i).iter_ones() {
            trans[t].push(i);
        }
    }
    let labels: Vec<bool> = (0..db.n_trans()).map(|t| db.pos_mask().get(t)).collect();
    crate::db::write_transactions(&out.join(format!("{name}.dat")), &trans)?;
    crate::db::write_labels(&out.join(format!("{name}.labels")), &labels)?;
    println!(
        "wrote {}/{name}.dat ({} items × {} transactions, density {:.3}%)",
        out.display(),
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0
    );
    Ok(())
}

/// `parlamp scenarios` — list the Table-1 mirror problems.
pub fn cmd_scenarios(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let mut t = Table::new(&["name", "items", "trans", "density", "N_pos", "class"]);
    for s in all_scenarios(quick) {
        let db = s.build();
        t.row(vec![
            s.name.to_string(),
            db.n_items().to_string(),
            db.n_trans().to_string(),
            format!("{:.2}%", db.density() * 100.0),
            db.marginals().n_pos.to_string(),
            if s.large { "LARGE".into() } else { "small".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

// ---- service subcommands (DESIGN.md §9) ------------------------------------

/// `parlamp serve` — start the long-running mining daemon: a pool of warm
/// worker fleets (`--fleets`), a weighted-fair job queue with admission
/// control, a bounded in-memory result cache, and an optional disk-backed
/// persistent result store (`--store`). Blocks until `SHUTDOWN` or
/// SIGTERM drains the queue.
pub fn cmd_serve(args: &Args) -> Result<()> {
    let listen = endpoint_from_args(args)?;
    let hosts = hosts_from_args(args)?;
    let procs = match &hosts {
        Some(hosts) => hosts.len(),
        None => args.get_usize("procs", 4)?,
    };
    let mut cfg = ServeConfig::new(listen, procs);
    cfg.fleets = args.get_usize("fleets", 1)?;
    anyhow::ensure!(cfg.fleets >= 1, "--fleets must be ≥ 1");
    anyhow::ensure!(
        cfg.fleets == 1 || hosts.is_none(),
        "--fleets > 1 is incompatible with --hosts (remote attach assembles one fleet)"
    );
    cfg.cache_cap = args.get_usize("cache", 32)?;
    cfg.store = args.get("store").map(PathBuf::from);
    cfg.limits = QueueLimits {
        per_client_queued: args
            .get_usize("client-depth", QueueLimits::default().per_client_queued)?,
        global_queued: args.get_usize("queue-depth", QueueLimits::default().global_queued)?,
        // By default one client may hold every fleet; lower it to reserve
        // capacity for other clients under contention.
        per_client_active: args.get_usize("client-slots", cfg.fleets)?,
    };
    anyhow::ensure!(cfg.limits.per_client_queued >= 1, "--client-depth must be ≥ 1");
    anyhow::ensure!(cfg.limits.global_queued >= 1, "--queue-depth must be ≥ 1");
    anyhow::ensure!(cfg.limits.per_client_active >= 1, "--client-slots must be ≥ 1");
    cfg.data_plane = data_plane_from_args(args)?;
    cfg.fleet_listen = match (args.get("fleet-listen"), transport_from_args(args)?, &hosts) {
        (Some(raw), _, _) => Some(raw.parse::<Endpoint>().context("--fleet-listen")?),
        // --hosts implies a TCP hub even without an explicit --transport:
        // remote workers cannot dial a Unix path on another machine.
        (None, Transport::Tcp, _) | (None, Transport::Unix, Some(_)) => {
            Some(Endpoint::tcp("127.0.0.1", 0))
        }
        (None, Transport::Unix, None) => None,
    };
    cfg.remote_workers = hosts;
    cfg.fault = fault_from_args(args)?;
    cfg.net_fault = net_fault_from_args(args)?;
    cfg.lease_timeout = lease_timeout_from_args(args)?;
    // --job-watchdog-secs 0 disables the per-job watchdog entirely.
    if args.get("job-watchdog-secs").is_some() {
        let secs = args.get_u64("job-watchdog-secs", 0)?;
        cfg.job_watchdog = (secs > 0).then(|| Duration::from_secs(secs));
    }
    cfg.trace = args.get("trace").map(PathBuf::from);
    if cfg.trace.is_some() {
        obs_trace::set_enabled(true);
    }
    anyhow::ensure!(cfg.cache_cap >= 1, "--cache must be ≥ 1");
    crate::service::serve(&cfg)
}

fn connect_client(args: &Args) -> Result<Client> {
    Client::connect(&endpoint_from_args(args)?)
}

fn job_id(args: &Args) -> Result<u64> {
    args.require("job")?.parse().context("--job must be a job id (unsigned integer)")
}

/// `parlamp submit` — submit a dataset to a running daemon; prints the
/// assigned job id. `--priority` (0–255, default 1) orders jobs within
/// one client; `--deadline-ms` expires the job if not dispatched in time;
/// `--client NAME` names the fair-queue account (default `anon`).
pub fn cmd_submit(args: &Args) -> Result<()> {
    let db = load_db(args)?;
    let priority = args.get_u64("priority", 1)?;
    anyhow::ensure!(priority <= u64::from(u8::MAX), "--priority must be ≤ 255");
    let spec = JobSpec {
        alpha: args.get_f64("alpha", crate::DEFAULT_ALPHA)?,
        glb: glb_from_args(args),
        screen: parse_screen(args)?,
        seed: args.get_u64("seed", 2015)?,
        priority: priority as u8,
        deadline_ms: args.get_u64("deadline-ms", 0)?,
        client: args.get("client").unwrap_or("").to_string(),
        db,
    };
    let id = connect_client(args)?.submit(spec)?;
    println!("job {id} accepted");
    Ok(())
}

/// `parlamp status` — one-line lifecycle report for a job.
pub fn cmd_status(args: &Args) -> Result<()> {
    let id = job_id(args)?;
    let state = connect_client(args)?.status(id)?;
    println!("job {id}: {state}");
    anyhow::ensure!(state != JobState::NotFound, "job {id} is unknown to the daemon");
    Ok(())
}

/// `parlamp results` — fetch (blocking until finished) and print a job's
/// outcome. Stdout carries exactly the summary line + significant-pattern
/// table, so it diffs against `parlamp lamp --engine serial` output; the
/// cache-hit note goes to stderr.
pub fn cmd_results(args: &Args) -> Result<()> {
    let id = job_id(args)?;
    let outcome = connect_client(args)?.results(id)?;
    if outcome.from_cache {
        log::info(
            "client",
            &Tags::job(id),
            format_args!("job {id}: served from the result cache"),
        );
    }
    let res = outcome.to_lamp_result();
    println!("{}", res.summary());
    print_significant(&res.significant);
    Ok(())
}

/// `parlamp cancel` — remove a still-pending job from the daemon's queue.
pub fn cmd_cancel(args: &Args) -> Result<()> {
    let id = job_id(args)?;
    let state = connect_client(args)?.cancel(id)?;
    println!("job {id}: {state}");
    anyhow::ensure!(
        state == JobState::Cancelled,
        "job {id} was not pending (nothing to cancel)"
    );
    Ok(())
}

/// `parlamp stats` — print the daemon's operational counters: per-fleet
/// utilization, per-client queue depths, cache/store counters, and job
/// latency histograms. `--format prom` renders the same STATS frame as
/// the Prometheus text exposition format (DESIGN.md §14).
pub fn cmd_stats(args: &Args) -> Result<()> {
    let stats = connect_client(args)?.stats()?;
    match args.get("format").unwrap_or("human") {
        "human" => print!("{stats}"),
        "prom" => print!("{}", prom::render(&stats)),
        other => bail!("unknown --format '{other}' (human|prom)"),
    }
    Ok(())
}

/// `parlamp trace summary FILE` — recompute the paper's Fig. 7 view from
/// an exported Chrome trace: per-rank breakdown, steal matrix, DTD wave
/// spreads. Takes positional operands, so [`super::run`] dispatches it
/// before the flag parser.
pub fn cmd_trace(rest: &[String]) -> Result<()> {
    match rest {
        [verb, file] if verb == "summary" => {
            let doc =
                std::fs::read_to_string(file).with_context(|| format!("read {file}"))?;
            print!("{}", summary::summarize(&doc)?);
            Ok(())
        }
        _ => bail!("usage: parlamp trace summary FILE"),
    }
}

/// `parlamp shutdown` — ask the daemon to drain its queue and exit.
pub fn cmd_shutdown(args: &Args) -> Result<()> {
    connect_client(args)?.shutdown()?;
    println!("daemon acknowledged shutdown (draining queue, dismissing fleet)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_cmd_runs() {
        let args = Args::parse(&["--quick".to_string()]).unwrap();
        cmd_scenarios(&args).unwrap();
    }

    #[test]
    fn gendata_then_lamp_roundtrip() {
        let dir = std::env::temp_dir().join(format!("parlamp_cli_{}", std::process::id()));
        let argv: Vec<String> = ["--scenario", "mcf7", "--quick", "--out", dir.to_str().unwrap()]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv).unwrap();
        cmd_gendata(&args).unwrap();
        let data = dir.join("mcf7.dat");
        let labels = dir.join("mcf7.labels");
        let base = vec![
            "--data".to_string(),
            data.to_str().unwrap().to_string(),
            "--labels".to_string(),
            labels.to_str().unwrap().to_string(),
        ];
        // serial reference path
        let args = Args::parse(&base).unwrap();
        cmd_lamp(&args).unwrap();
        // coordinated DES path through the same CLI entry point
        let mut argv = base.clone();
        argv.extend(["--engine", "sim", "--procs", "6"].iter().map(|s| s.to_string()));
        let args = Args::parse(&argv).unwrap();
        cmd_lamp(&args).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bench_writes_valid_report_and_check_gates() {
        let dir = std::env::temp_dir().join(format!("parlamp_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("BENCH_test.json");
        // Quick single-scenario run on the uninstrumented-spawn-free
        // engines (process needs the real binary; CI covers it).
        let argv: Vec<String> = [
            "--quick",
            "--engines",
            "serial,sim",
            "--procs",
            "3",
            "--out",
            out.to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        cmd_bench(&Args::parse(&argv).unwrap()).unwrap();
        let doc = std::fs::read_to_string(&out).unwrap();
        assert_eq!(crate::bench::report::validate(&doc).unwrap(), 2);
        // --check accepts the good file and rejects a corrupted one.
        let check = |p: &std::path::Path| {
            let argv = vec!["--check".to_string(), p.to_str().unwrap().to_string()];
            cmd_bench(&Args::parse(&argv).unwrap())
        };
        check(&out).unwrap();
        let bad = dir.join("BENCH_bad.json");
        std::fs::write(&bad, doc.replace("\"runs\"", "\"ruins\"")).unwrap();
        assert!(check(&bad).is_err());
        // --compare: a report against itself diffs clean, in both the
        // comma form and the --with form; a corrupt input fails.
        let both = format!("{0},{0}", out.to_str().unwrap());
        cmd_bench(&Args::parse(&["--compare".to_string(), both]).unwrap()).unwrap();
        let argv: Vec<String> = vec![
            "--compare".into(),
            out.to_str().unwrap().into(),
            "--with".into(),
            out.to_str().unwrap().into(),
        ];
        cmd_bench(&Args::parse(&argv).unwrap()).unwrap();
        let both_bad = format!("{},{}", out.to_str().unwrap(), bad.to_str().unwrap());
        assert!(cmd_bench(&Args::parse(&["--compare".to_string(), both_bad]).unwrap()).is_err());
        // unknown engine / scenario / data plane fail fast
        let argv: Vec<String> =
            ["--quick", "--engines", "warp"].iter().map(|s| s.to_string()).collect();
        assert!(cmd_bench(&Args::parse(&argv).unwrap()).is_err());
        let argv: Vec<String> = ["--quick", "--engines", "serial", "--data-plane", "warp"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(cmd_bench(&Args::parse(&argv).unwrap()).is_err());
        let argv: Vec<String> = ["--quick", "--scenarios", "nope", "--engines", "serial"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(cmd_bench(&Args::parse(&argv).unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lamp_rejects_unknown_engine_and_screen() {
        let dir = std::env::temp_dir().join(format!("parlamp_cli_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("t.dat"), "0 1\n1\n").unwrap();
        std::fs::write(dir.join("t.labels"), "1\n0\n").unwrap();
        let base = vec![
            "--data".to_string(),
            dir.join("t.dat").to_str().unwrap().to_string(),
            "--labels".to_string(),
            dir.join("t.labels").to_str().unwrap().to_string(),
        ];
        let mut argv = base.clone();
        argv.extend(["--engine", "warp"].iter().map(|s| s.to_string()));
        assert!(cmd_lamp(&Args::parse(&argv).unwrap()).is_err());
        let mut argv = base.clone();
        argv.extend(["--screen", "gpu"].iter().map(|s| s.to_string()));
        assert!(cmd_lamp(&Args::parse(&argv).unwrap()).is_err());
        // A typo'd --data-plane must error on every engine, even the
        // serial ones that never consume it.
        let mut argv = base.clone();
        argv.extend(["--data-plane", "warp"].iter().map(|s| s.to_string()));
        assert!(cmd_lamp(&Args::parse(&argv).unwrap()).is_err());
        // Same for --transport…
        let mut argv = base.clone();
        argv.extend(["--transport", "carrier-pigeon"].iter().map(|s| s.to_string()));
        assert!(cmd_lamp(&Args::parse(&argv).unwrap()).is_err());
        // …and --hosts is a process-engine launcher flag, nothing else's.
        let mut argv = base;
        argv.extend(["--hosts", "127.0.0.1:7001"].iter().map(|s| s.to_string()));
        assert!(cmd_lamp(&Args::parse(&argv).unwrap()).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn hosts_flag_parses_endpoints() {
        let argv: Vec<String> = ["--hosts", "127.0.0.1:7001,tcp:10.0.0.2:7002,unix:/tmp/w.sock"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let hosts = hosts_from_args(&Args::parse(&argv).unwrap()).unwrap().unwrap();
        assert_eq!(hosts.len(), 3);
        assert_eq!(hosts[0], Endpoint::tcp("127.0.0.1", 7001));
        assert_eq!(hosts[1], Endpoint::tcp("10.0.0.2", 7002));
        assert!(hosts[2].is_unix());
        // malformed entries and empty lists fail fast
        let argv: Vec<String> =
            ["--hosts", "localhost"].iter().map(|s| s.to_string()).collect();
        assert!(hosts_from_args(&Args::parse(&argv).unwrap()).is_err());
        let argv: Vec<String> = ["--hosts", ","].iter().map(|s| s.to_string()).collect();
        assert!(hosts_from_args(&Args::parse(&argv).unwrap()).is_err());
        // absent flag → None (local spawn mode)
        assert!(hosts_from_args(&Args::parse(&[]).unwrap()).unwrap().is_none());
    }

    #[test]
    fn endpoint_flag_accepts_socket_alias() {
        let argv: Vec<String> =
            ["--socket", "/tmp/d.sock"].iter().map(|s| s.to_string()).collect();
        let ep = endpoint_from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(ep, Endpoint::unix("/tmp/d.sock"));
        let argv: Vec<String> =
            ["--endpoint", "tcp:127.0.0.1:9"].iter().map(|s| s.to_string()).collect();
        let ep = endpoint_from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(ep, Endpoint::tcp("127.0.0.1", 9));
        assert!(endpoint_from_args(&Args::parse(&[]).unwrap()).is_err());
    }
}
