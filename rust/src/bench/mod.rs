//! Benchmark scenarios, calibration, and the perf-trajectory harness.
//!
//! [`scenarios`] defines the six problems of Table 1, scaled so a laptop
//! regenerates every table and figure in minutes (the ratios — items :
//! transactions, density regime, class balance — are preserved; see
//! DESIGN.md §3 for what "reproduced" means on the substituted testbed).
//!
//! [`report`] is the `BENCH_*.json` schema the `parlamp bench` subcommand
//! emits: one record per `(scenario, engine)` with wall-clock, expansion
//! work units, closed-set counts, and λ*, validated structurally in CI.
//! [`measure_engine`] produces those records.

pub mod report;
pub mod scenarios;

pub use report::{BenchRecord, BenchReport, SCHEMA_ID};
pub use scenarios::{all_scenarios, Scenario};

/// The engines [`measure_engine`] understands, in the order the bench
/// runs them by default — re-exported from the coordinator, which owns the
/// one engine-name dispatch point ([`crate::coordinator::parse_engine`]).
pub use crate::coordinator::ENGINES;

use anyhow::Result;

use crate::coordinator::{parse_engine, Coordinator, EngineSelect, ScreenMode, Transport};
use crate::db::Database;
use crate::par::DataPlane;
use crate::lamp::{
    lamp2::lamp2_serial, lamp_serial, phase1_serial, phase2_count, phase3_extract,
};
use crate::lcm::{mine_closed, Visit};
use crate::util::bench_harness::time_once;

/// Calibrate the DES cost model: run the serial miner for real, divide
/// wall-clock by total expansion work units — candidate-loop word ops
/// *plus* conditional-database reduction work, i.e.
/// [`crate::lcm::ExpandStats::units`], so `ns_per_unit` stays meaningful
/// on the reduced hot path. Returns (ns_per_unit, serial_seconds,
/// closed_sets).
pub fn calibrate(db: &Database, min_sup: u32) -> (f64, f64, u64) {
    let mut closed = 0u64;
    let (secs, stats) = time_once(|| {
        mine_closed(db, min_sup, |_n, ms| {
            closed += 1;
            (Visit::Continue, ms)
        })
    });
    let units = stats.expand.units().max(1);
    ((secs * 1e9) / units as f64, secs, closed)
}

/// A measured serial LAMP run (phases 1+2): the `t₁` baseline plus the
/// calibrated DES cost-model constant derived from the *same* workload.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Virtual nanoseconds per expansion work unit (word ops + reduction).
    pub ns_per_unit: f64,
    /// Serial wall-clock for phases 1+2 (the paper's measured `t`).
    pub t1_s: f64,
    /// Final minimum support λ*−1.
    pub min_sup: u32,
    /// Correction factor CS(min_sup).
    pub correction: u64,
}

/// Measure serial phases 1+2 and derive the DES calibration from them.
pub fn calibrate_lamp(db: &Database, alpha: f64) -> Calibration {
    let (secs, (p1, p2)) = time_once(|| {
        let p1 = phase1_serial(db, alpha);
        let p2 = phase2_count(db, p1.min_sup);
        (p1, p2)
    });
    let units = (p1.stats.expand.units() + p2.stats.expand.units()).max(1);
    Calibration {
        ns_per_unit: secs * 1e9 / units as f64,
        t1_s: secs,
        min_sup: p1.min_sup,
        correction: p2.correction_factor,
    }
}

/// Serial full-LAMP wall time plus the result — the `t₁` column.
pub fn serial_t1(db: &Database, alpha: f64) -> (f64, crate::lamp::LampResult) {
    let (secs, res) = time_once(|| lamp_serial(db, alpha));
    (secs, res)
}

/// One engine's end-to-end measurement, the per-engine slice of a
/// [`BenchRecord`] (the scenario/shape fields are added by the caller).
#[derive(Clone, Debug)]
pub struct EngineRun {
    pub wall_s: f64,
    /// Phases 1+2 makespan (virtual on the DES engine); 0 for serial.
    pub t_parallel_s: f64,
    pub work_units: u64,
    pub word_ops: u64,
    pub reduce_ops: u64,
    pub lambda_star: u32,
    pub min_sup: u32,
    pub correction_factor: u64,
    pub phase1_closed: u64,
    pub phase2_closed: u64,
    pub significant: usize,
    /// Process engine only: data-plane frames relayed through the hub /
    /// sent directly worker-to-worker, summed over both distributed
    /// phases. A mesh run records `hub_frames == 0` — the observable form
    /// of the hub-demotion win. 0 on every other engine.
    pub hub_frames: u64,
    pub direct_frames: u64,
    /// Fig. 7 CPU-time breakdown summed over processes and both
    /// distributed phases (DESIGN.md §8); all 0 on the serial engines,
    /// which have no per-rank instrumentation.
    pub preprocess_s: f64,
    pub main_s: f64,
    pub probe_s: f64,
    pub idle_s: f64,
    /// Steal-protocol totals over both distributed phases: REQUEST frames
    /// sent, GIVE frames answered, stack roots shipped. 0 on the serial
    /// engines.
    pub steal_sent: u64,
    pub steal_gives: u64,
    pub tasks_shipped: u64,
    /// Per-rank event timelines when tracing is on (DESIGN.md §14);
    /// empty otherwise and on the serial engines.
    pub traces: Vec<crate::obs::trace::RankTrace>,
}

/// Run the full three-phase LAMP procedure on `engine`
/// (`serial|lamp2|threads|sim|process`) and measure it. `data_plane` and
/// `transport` apply to the process engine only (`--data-plane hub|mesh`,
/// `--transport unix|tcp`). The phase-3 screen is pinned to native so
/// records compare like with like across machines with and without XLA
/// artifacts.
pub fn measure_engine(
    db: &Database,
    engine: &str,
    procs: usize,
    alpha: f64,
    seed: u64,
    data_plane: DataPlane,
    transport: Transport,
) -> Result<EngineRun> {
    match parse_engine(engine, procs, seed)? {
        EngineSelect::Serial => {
            let (secs, (p1, p2, sig)) = time_once(|| {
                let p1 = phase1_serial(db, alpha);
                let p2 = phase2_count(db, p1.min_sup);
                let sig = phase3_extract(db, p1.min_sup, p2.correction_factor, alpha);
                (p1, p2, sig)
            });
            let e = |s: &crate::lcm::MineStats| s.expand;
            let (x1, x2) = (e(&p1.stats), e(&p2.stats));
            Ok(EngineRun {
                wall_s: secs,
                t_parallel_s: 0.0,
                work_units: x1.units() + x2.units(),
                word_ops: x1.word_ops + x2.word_ops,
                reduce_ops: x1.reduce_ops + x2.reduce_ops,
                lambda_star: p1.lambda_final,
                min_sup: p1.min_sup,
                correction_factor: p2.correction_factor,
                phase1_closed: p1.stats.closed,
                phase2_closed: p2.closed,
                significant: sig.len(),
                hub_frames: 0,
                direct_frames: 0,
                preprocess_s: 0.0,
                main_s: 0.0,
                probe_s: 0.0,
                idle_s: 0.0,
                steal_sent: 0,
                steal_gives: 0,
                tasks_shipped: 0,
                traces: Vec::new(),
            })
        }
        EngineSelect::Lamp2 => {
            // The occurrence-deliver comparator is not word-op
            // instrumented (different cost structure); unit fields are 0.
            let (secs, res) = time_once(|| lamp2_serial(db, alpha));
            Ok(EngineRun {
                wall_s: secs,
                t_parallel_s: 0.0,
                work_units: 0,
                word_ops: 0,
                reduce_ops: 0,
                lambda_star: res.lambda_final,
                min_sup: res.min_sup,
                correction_factor: res.correction_factor,
                phase1_closed: res.phase1_closed,
                phase2_closed: res.phase2_closed,
                significant: res.significant.len(),
                hub_frames: 0,
                direct_frames: 0,
                preprocess_s: 0.0,
                main_s: 0.0,
                probe_s: 0.0,
                idle_s: 0.0,
                steal_sent: 0,
                steal_gives: 0,
                tasks_shipped: 0,
                traces: Vec::new(),
            })
        }
        EngineSelect::Backend(backend) => {
            let backend = backend.with_data_plane(data_plane).with_transport(transport);
            let coord = Coordinator::new(alpha).with_screen(ScreenMode::Native);
            let (secs, run) = time_once(|| coord.run(db, &backend));
            let run = run?;
            let comm = run.comm_total();
            let [preprocess_s, main_s, probe_s, idle_s] = run.breakdown_total().as_secs();
            Ok(EngineRun {
                wall_s: secs,
                t_parallel_s: run.t_parallel_s(),
                work_units: run.work_units_total(),
                word_ops: 0,
                reduce_ops: 0,
                lambda_star: run.result.lambda_final,
                min_sup: run.result.min_sup,
                correction_factor: run.result.correction_factor,
                phase1_closed: run.result.phase1_closed,
                phase2_closed: run.result.phase2_closed,
                significant: run.result.significant.len(),
                hub_frames: comm.hub_frames,
                direct_frames: comm.direct_frames,
                preprocess_s,
                main_s,
                probe_s,
                idle_s,
                steal_sent: comm.sent,
                steal_gives: comm.gives,
                tasks_shipped: comm.tasks_shipped,
                traces: run.traces(),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_gwas, GwasSpec};

    fn small_db() -> Database {
        let spec = GwasSpec { n_snps: 90, n_individuals: 70, n_pos: 18, ..GwasSpec::small(5) };
        generate_gwas(&spec).0
    }

    #[test]
    fn engines_agree_and_serial_is_instrumented() {
        let db = small_db();
        let dp = DataPlane::Mesh;
        let tr = Transport::Unix;
        let serial = measure_engine(&db, "serial", 1, 0.05, 1, dp, tr).unwrap();
        assert!(serial.work_units > 0);
        assert_eq!(serial.work_units, serial.word_ops + serial.reduce_ops);
        assert!(serial.reduce_ops > 0, "reduction work must be counted");
        assert_eq!((serial.hub_frames, serial.direct_frames), (0, 0));
        for engine in ["lamp2", "sim"] {
            let got = measure_engine(&db, engine, 3, 0.05, 1, dp, tr).unwrap();
            assert_eq!(got.lambda_star, serial.lambda_star, "{engine}");
            assert_eq!(got.correction_factor, serial.correction_factor, "{engine}");
            assert_eq!(got.significant, serial.significant, "{engine}");
        }
        assert!(measure_engine(&db, "warp", 1, 0.05, 1, dp, tr).is_err());
    }

    #[test]
    fn calibration_units_include_reduction() {
        // calibrate() must divide by the same unit total the DES charges:
        // ns_per_unit × units ≈ measured seconds (exactly, by definition).
        let db = small_db();
        let (ns_per_unit, secs, closed) = calibrate(&db, 2);
        assert!(closed > 0);
        assert!(ns_per_unit > 0.0);
        assert!(secs >= 0.0);
        let cal = calibrate_lamp(&db, 0.05);
        assert!(cal.ns_per_unit > 0.0);
        assert!(cal.correction >= 1);
    }
}
