//! Payloads of the `parlamp serve` job frames (DESIGN.md §9).
//!
//! The service socket speaks the same length-prefixed framing as the
//! process fabric ([`super`]); this module holds the job-level payload
//! types — what a client submits ([`JobSpec`]), how the daemon reports
//! progress ([`JobState`]), and what a finished job returns
//! ([`JobOutcome`]) — plus their codecs. Decoders follow the same
//! discipline as the fabric grammar: every count is validated against the
//! bytes actually remaining, so corrupt input errors instead of panicking
//! or allocating gigabytes.

use anyhow::{bail, ensure, Result};

use crate::coordinator::{CoordinatorRun, GlbParams, ScreenKind, ScreenMode};
use crate::db::{Database, Item};
use crate::fabric::HistDelta;
use crate::lamp::{LampResult, SignificantPattern};

use super::{get_db, get_hist, put_bool, put_db, put_f64, put_hist, put_str, put_u32, put_u64};
use super::{Dec, WIRE_VERSION};

/// Everything one mining request needs: the statistical level, the GLB
/// topology parameters, the phase-3 screen policy, the steal-randomness
/// seed, and the database itself. The fleet size is *not* here — it is a
/// property of the daemon (`parlamp serve --procs P`), not of a job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Family-wise error rate α.
    pub alpha: f64,
    /// Lifeline-GLB parameters (`l`, `w`, steal, preprocess, tree arity).
    pub glb: GlbParams,
    /// Phase-3 screen selection.
    pub screen: ScreenMode,
    /// Base RNG seed. Results are seed-invariant (only communication and
    /// timing statistics differ), which is why the seed is *excluded* from
    /// the result-cache key.
    pub seed: u64,
    /// Scheduling priority within one client's queue (higher dispatches
    /// first; equal priorities dispatch in submission order). Default 1.
    pub priority: u8,
    /// Relative deadline in milliseconds; 0 means none. A job still queued
    /// when its deadline passes is expired with [`JobState::Expired`]
    /// instead of being run late.
    pub deadline_ms: u64,
    /// Client identity for fair-share accounting and admission control.
    /// Empty means anonymous (the daemon buckets it as `"anon"`).
    pub client: String,
    /// The transaction database to mine.
    pub db: Database,
}

impl JobSpec {
    /// A job over `db` at level `alpha` with the paper-default GLB
    /// parameters, the native screen, and the default seed.
    pub fn new(db: Database, alpha: f64) -> JobSpec {
        JobSpec {
            alpha,
            glb: GlbParams::default(),
            screen: ScreenMode::Native,
            seed: 2015,
            priority: 1,
            deadline_ms: 0,
            client: String::new(),
            db,
        }
    }
}

/// Where a job is in its lifecycle (DESIGN.md §9 state machine).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the FIFO queue; `position` 0 is next to run.
    Queued { position: u32 },
    /// The scheduler is mining it on the warm fleet.
    Running,
    /// Finished; the outcome is available via `RESULT`.
    Done {
        /// `true` when the outcome was served from the result cache
        /// without the workers receiving any work frames.
        from_cache: bool,
    },
    /// The run failed; `reason` is the error chain.
    Failed { reason: String },
    /// Removed from the queue by `CANCEL` before it ran.
    Cancelled,
    /// The daemon has no record of this job id.
    NotFound,
    /// The job's deadline passed while it was still queued; it was never
    /// dispatched.
    Expired,
    /// Admission control rejected the submission (queue depth bound hit);
    /// `reason` says which bound. The job was never assigned an id.
    Busy { reason: String },
}

impl JobState {
    /// A terminal state will never change again.
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued { .. } | JobState::Running)
    }
}

impl std::fmt::Display for JobState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JobState::Queued { position } => write!(f, "queued (position {position})"),
            JobState::Running => write!(f, "running"),
            JobState::Done { from_cache: true } => write!(f, "done (cache hit)"),
            JobState::Done { from_cache: false } => write!(f, "done (mined)"),
            JobState::Failed { reason } => write!(f, "failed: {reason}"),
            JobState::Cancelled => write!(f, "cancelled"),
            JobState::NotFound => write!(f, "not found"),
            JobState::Expired => write!(f, "expired (deadline passed before dispatch)"),
            JobState::Busy { reason } => write!(f, "busy: {reason}"),
        }
    }
}

/// The result view a finished job ships back: the [`LampResult`] scalars,
/// the significant-pattern set, the phase makespans, and the phase-2
/// closed-pattern histogram (sparse), which is the cross-engine equivalence
/// witness the integration tests diff against the serial miner.
#[derive(Clone, Debug, PartialEq)]
pub struct JobOutcome {
    pub alpha: f64,
    pub lambda_final: u32,
    pub min_sup: u32,
    pub correction_factor: u64,
    pub phase1_closed: u64,
    pub phase2_closed: u64,
    /// Screen that produced `significant`.
    pub screen: ScreenKind,
    /// Served from the result cache (no mining for this submission).
    pub from_cache: bool,
    pub phase1_makespan_s: f64,
    pub phase2_makespan_s: f64,
    /// Sparse phase-2 histogram: (support, closed-set count), ascending
    /// support.
    pub hist2: HistDelta,
    /// Significant patterns, ascending P-value.
    pub significant: Vec<SignificantPattern>,
}

impl JobOutcome {
    /// Build the wire view of a finished coordinated run.
    pub fn from_run(run: &CoordinatorRun, from_cache: bool) -> JobOutcome {
        let hist2 = run.phase2.hist.sparse();
        JobOutcome {
            alpha: run.result.alpha,
            lambda_final: run.result.lambda_final,
            min_sup: run.result.min_sup,
            correction_factor: run.result.correction_factor,
            phase1_closed: run.result.phase1_closed,
            phase2_closed: run.result.phase2_closed,
            screen: run.screen,
            from_cache,
            phase1_makespan_s: run.phase1.makespan_s,
            phase2_makespan_s: run.phase2.makespan_s,
            hist2,
            significant: run.result.significant.clone(),
        }
    }

    /// Reconstruct the [`LampResult`] view (for `summary()` and the CLI's
    /// significant-pattern table).
    pub fn to_lamp_result(&self) -> LampResult {
        LampResult {
            alpha: self.alpha,
            lambda_final: self.lambda_final,
            min_sup: self.min_sup,
            correction_factor: self.correction_factor,
            adjusted_level: self.alpha / self.correction_factor as f64,
            significant: self.significant.clone(),
            phase1_closed: self.phase1_closed,
            phase2_closed: self.phase2_closed,
        }
    }
}

// ---- codecs ----------------------------------------------------------------

const SCREEN_MODE_AUTO: u8 = 0;
const SCREEN_MODE_NATIVE: u8 = 1;
const SCREEN_MODE_XLA: u8 = 2;

fn put_screen_mode(buf: &mut Vec<u8>, m: ScreenMode) {
    buf.push(match m {
        ScreenMode::Auto => SCREEN_MODE_AUTO,
        ScreenMode::Native => SCREEN_MODE_NATIVE,
        ScreenMode::Xla => SCREEN_MODE_XLA,
    });
}

fn get_screen_mode(d: &mut Dec) -> Result<ScreenMode> {
    Ok(match d.u8()? {
        SCREEN_MODE_AUTO => ScreenMode::Auto,
        SCREEN_MODE_NATIVE => ScreenMode::Native,
        SCREEN_MODE_XLA => ScreenMode::Xla,
        other => bail!("wire: unknown screen mode {other:#x}"),
    })
}

pub(super) fn put_job_spec(buf: &mut Vec<u8>, spec: &JobSpec) {
    super::put_u16(buf, WIRE_VERSION);
    put_f64(buf, spec.alpha);
    put_u32(buf, spec.glb.l as u32);
    put_u32(buf, spec.glb.w as u32);
    put_bool(buf, spec.glb.steal);
    put_bool(buf, spec.glb.preprocess);
    put_u32(buf, spec.glb.tree_arity as u32);
    put_screen_mode(buf, spec.screen);
    put_u64(buf, spec.seed);
    buf.push(spec.priority);
    put_u64(buf, spec.deadline_ms);
    put_str(buf, &spec.client);
    put_db(buf, &spec.db);
}

pub(super) fn get_job_spec(d: &mut Dec) -> Result<JobSpec> {
    let version = d.u16()?;
    ensure!(
        version == WIRE_VERSION,
        "wire: SUBMIT version {version} != supported {WIRE_VERSION}"
    );
    Ok(JobSpec {
        alpha: d.f64()?,
        glb: GlbParams {
            l: d.u32()? as usize,
            w: d.u32()? as usize,
            steal: d.bool()?,
            preprocess: d.bool()?,
            tree_arity: d.u32()? as usize,
        },
        screen: get_screen_mode(d)?,
        seed: d.u64()?,
        priority: d.u8()?,
        deadline_ms: d.u64()?,
        client: d.str()?,
        db: get_db(d)?,
    })
}

const STATE_QUEUED: u8 = 0;
const STATE_RUNNING: u8 = 1;
const STATE_DONE: u8 = 2;
const STATE_FAILED: u8 = 3;
const STATE_CANCELLED: u8 = 4;
const STATE_NOT_FOUND: u8 = 5;
const STATE_EXPIRED: u8 = 6;
const STATE_BUSY: u8 = 7;

pub(super) fn put_job_state(buf: &mut Vec<u8>, state: &JobState) {
    match state {
        JobState::Queued { position } => {
            buf.push(STATE_QUEUED);
            put_u32(buf, *position);
        }
        JobState::Running => buf.push(STATE_RUNNING),
        JobState::Done { from_cache } => {
            buf.push(STATE_DONE);
            put_bool(buf, *from_cache);
        }
        JobState::Failed { reason } => {
            buf.push(STATE_FAILED);
            put_str(buf, reason);
        }
        JobState::Cancelled => buf.push(STATE_CANCELLED),
        JobState::NotFound => buf.push(STATE_NOT_FOUND),
        JobState::Expired => buf.push(STATE_EXPIRED),
        JobState::Busy { reason } => {
            buf.push(STATE_BUSY);
            put_str(buf, reason);
        }
    }
}

pub(super) fn get_job_state(d: &mut Dec) -> Result<JobState> {
    Ok(match d.u8()? {
        STATE_QUEUED => JobState::Queued { position: d.u32()? },
        STATE_RUNNING => JobState::Running,
        STATE_DONE => JobState::Done { from_cache: d.bool()? },
        STATE_FAILED => JobState::Failed { reason: d.str()? },
        STATE_CANCELLED => JobState::Cancelled,
        STATE_NOT_FOUND => JobState::NotFound,
        STATE_EXPIRED => JobState::Expired,
        STATE_BUSY => JobState::Busy { reason: d.str()? },
        other => bail!("wire: unknown job state {other:#x}"),
    })
}

const SCREEN_KIND_NATIVE: u8 = 0;
const SCREEN_KIND_XLA: u8 = 1;

pub(super) fn put_job_outcome(buf: &mut Vec<u8>, o: &JobOutcome) {
    put_f64(buf, o.alpha);
    put_u32(buf, o.lambda_final);
    put_u32(buf, o.min_sup);
    put_u64(buf, o.correction_factor);
    put_u64(buf, o.phase1_closed);
    put_u64(buf, o.phase2_closed);
    buf.push(match o.screen {
        ScreenKind::Native => SCREEN_KIND_NATIVE,
        ScreenKind::Xla => SCREEN_KIND_XLA,
    });
    put_bool(buf, o.from_cache);
    put_f64(buf, o.phase1_makespan_s);
    put_f64(buf, o.phase2_makespan_s);
    put_hist(buf, &o.hist2);
    put_u32(buf, o.significant.len() as u32);
    for s in &o.significant {
        put_u32(buf, s.items.len() as u32);
        for &i in &s.items {
            put_u32(buf, i);
        }
        put_u32(buf, s.support);
        put_u32(buf, s.pos_support);
        put_f64(buf, s.p_value);
    }
}

pub(super) fn get_job_outcome(d: &mut Dec) -> Result<JobOutcome> {
    let alpha = d.f64()?;
    let lambda_final = d.u32()?;
    let min_sup = d.u32()?;
    let correction_factor = d.u64()?;
    let phase1_closed = d.u64()?;
    let phase2_closed = d.u64()?;
    let screen = match d.u8()? {
        SCREEN_KIND_NATIVE => ScreenKind::Native,
        SCREEN_KIND_XLA => ScreenKind::Xla,
        other => bail!("wire: unknown screen kind {other:#x}"),
    };
    let from_cache = d.bool()?;
    let phase1_makespan_s = d.f64()?;
    let phase2_makespan_s = d.f64()?;
    let hist2 = get_hist(d)?;
    // Each pattern occupies ≥ 20 bytes (item count + support + pos + p).
    let n_sig = d.count(20)?;
    let mut significant = Vec::with_capacity(n_sig);
    for _ in 0..n_sig {
        let n_items = d.count(4)?;
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            items.push(d.u32()? as Item);
        }
        significant.push(SignificantPattern {
            items,
            support: d.u32()?,
            pos_support: d.u32()?,
            p_value: d.f64()?,
        });
    }
    Ok(JobOutcome {
        alpha,
        lambda_final,
        min_sup,
        correction_factor,
        phase1_closed,
        phase2_closed,
        screen,
        from_cache,
        phase1_makespan_s,
        phase2_makespan_s,
        hist2,
        significant,
    })
}

/// Encode a [`JobOutcome`] as a standalone byte string — the persistent
/// result store's record body reuses the wire codec verbatim so the
/// on-disk format and the RESULT frame can never drift apart.
pub fn encode_job_outcome(o: &JobOutcome) -> Vec<u8> {
    let mut buf = Vec::new();
    put_job_outcome(&mut buf, o);
    buf
}

/// Decode a byte string produced by [`encode_job_outcome`], rejecting
/// trailing garbage. Corrupt input errors instead of panicking.
pub fn decode_job_outcome(bytes: &[u8]) -> Result<JobOutcome> {
    let mut d = Dec::new(bytes);
    let o = get_job_outcome(&mut d)?;
    d.finish()?;
    Ok(o)
}

// ---- STATS report ----------------------------------------------------------

/// Per-fleet utilization counters inside a [`ServiceStats`] report,
/// indexed by fleet id.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FleetStats {
    pub jobs_mined: u64,
    /// Wall-clock milliseconds this fleet spent mining.
    pub busy_ms: u64,
    /// Worker ranks respawned in place (PR-7 recovery) across all runs.
    pub respawns: u64,
    /// Whole-fleet rebuilds after a poisoned run.
    pub rebuilds: u64,
}

/// Per-client queue depths + lifetime submissions inside a
/// [`ServiceStats`] report.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ClientStats {
    pub client: String,
    pub queued: u64,
    pub active: u64,
    pub submitted: u64,
}

/// The STATS frame payload: a point-in-time view of the daemon's
/// scheduler, cache, store, and fleet-pool health (DESIGN.md §13).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub uptime_ms: u64,
    pub jobs_submitted: u64,
    pub jobs_mined: u64,
    pub jobs_failed: u64,
    pub jobs_rejected_busy: u64,
    pub jobs_expired: u64,
    pub jobs_cancelled: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_entries: u64,
    pub store_entries: u64,
    pub store_appends: u64,
    /// LRU misses answered from the persistent store.
    pub store_hits: u64,
    /// Terminal job records evicted from the bounded history.
    pub evicted_records: u64,
    pub fleets: Vec<FleetStats>,
    pub clients: Vec<ClientStats>,
    /// Log₂ histogram of submit→dispatch wait, bucket `i` = `[2^i, 2^(i+1))` ms.
    pub queue_wait_ms: Vec<u64>,
    /// Log₂ histogram of submit→terminal latency, same bucketing.
    pub latency_ms: Vec<u64>,
}

fn fmt_hist(f: &mut std::fmt::Formatter<'_>, label: &str, buckets: &[u64]) -> std::fmt::Result {
    write!(f, "  {label}:")?;
    if buckets.iter().all(|&c| c == 0) {
        return writeln!(f, " (no samples)");
    }
    for (i, &count) in buckets.iter().enumerate() {
        if count > 0 {
            let lo = if i == 0 { 0 } else { 1u64 << i };
            write!(f, " [{lo}ms,{}ms):{count}", 1u64 << (i + 1))?;
        }
    }
    writeln!(f)
}

impl std::fmt::Display for ServiceStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "uptime: {:.1}s", self.uptime_ms as f64 / 1e3)?;
        writeln!(
            f,
            "jobs: {} submitted / {} mined / {} failed / {} busy-rejected / \
             {} expired / {} cancelled",
            self.jobs_submitted,
            self.jobs_mined,
            self.jobs_failed,
            self.jobs_rejected_busy,
            self.jobs_expired,
            self.jobs_cancelled
        )?;
        writeln!(
            f,
            "cache: {} hits / {} misses / {} entries (memory), \
             {} entries / {} appends / {} hits (disk)",
            self.cache_hits,
            self.cache_misses,
            self.cache_entries,
            self.store_entries,
            self.store_appends,
            self.store_hits
        )?;
        writeln!(f, "history: {} terminal records evicted", self.evicted_records)?;
        for (i, fl) in self.fleets.iter().enumerate() {
            let util = if self.uptime_ms > 0 {
                100.0 * fl.busy_ms as f64 / self.uptime_ms as f64
            } else {
                0.0
            };
            writeln!(
                f,
                "fleet {i}: {} jobs, {:.1}% busy, {} respawns, {} rebuilds",
                fl.jobs_mined, util, fl.respawns, fl.rebuilds
            )?;
        }
        for c in &self.clients {
            writeln!(
                f,
                "client {}: {} queued / {} active / {} submitted",
                c.client, c.queued, c.active, c.submitted
            )?;
        }
        fmt_hist(f, "queue wait", &self.queue_wait_ms)?;
        fmt_hist(f, "job latency", &self.latency_ms)
    }
}

pub(super) fn put_service_stats(buf: &mut Vec<u8>, s: &ServiceStats) {
    put_u64(buf, s.uptime_ms);
    put_u64(buf, s.jobs_submitted);
    put_u64(buf, s.jobs_mined);
    put_u64(buf, s.jobs_failed);
    put_u64(buf, s.jobs_rejected_busy);
    put_u64(buf, s.jobs_expired);
    put_u64(buf, s.jobs_cancelled);
    put_u64(buf, s.cache_hits);
    put_u64(buf, s.cache_misses);
    put_u64(buf, s.cache_entries);
    put_u64(buf, s.store_entries);
    put_u64(buf, s.store_appends);
    put_u64(buf, s.store_hits);
    put_u64(buf, s.evicted_records);
    put_u32(buf, s.fleets.len() as u32);
    for fl in &s.fleets {
        put_u64(buf, fl.jobs_mined);
        put_u64(buf, fl.busy_ms);
        put_u64(buf, fl.respawns);
        put_u64(buf, fl.rebuilds);
    }
    put_u32(buf, s.clients.len() as u32);
    for c in &s.clients {
        put_str(buf, &c.client);
        put_u64(buf, c.queued);
        put_u64(buf, c.active);
        put_u64(buf, c.submitted);
    }
    put_u32(buf, s.queue_wait_ms.len() as u32);
    for &b in &s.queue_wait_ms {
        put_u64(buf, b);
    }
    put_u32(buf, s.latency_ms.len() as u32);
    for &b in &s.latency_ms {
        put_u64(buf, b);
    }
}

pub(super) fn get_service_stats(d: &mut Dec) -> Result<ServiceStats> {
    let uptime_ms = d.u64()?;
    let jobs_submitted = d.u64()?;
    let jobs_mined = d.u64()?;
    let jobs_failed = d.u64()?;
    let jobs_rejected_busy = d.u64()?;
    let jobs_expired = d.u64()?;
    let jobs_cancelled = d.u64()?;
    let cache_hits = d.u64()?;
    let cache_misses = d.u64()?;
    let cache_entries = d.u64()?;
    let store_entries = d.u64()?;
    let store_appends = d.u64()?;
    let store_hits = d.u64()?;
    let evicted_records = d.u64()?;
    let n_fleets = d.count(32)?;
    let mut fleets = Vec::with_capacity(n_fleets);
    for _ in 0..n_fleets {
        fleets.push(FleetStats {
            jobs_mined: d.u64()?,
            busy_ms: d.u64()?,
            respawns: d.u64()?,
            rebuilds: d.u64()?,
        });
    }
    // Each client entry is ≥ 28 bytes (name len + three u64 counters).
    let n_clients = d.count(28)?;
    let mut clients = Vec::with_capacity(n_clients);
    for _ in 0..n_clients {
        clients.push(ClientStats {
            client: d.str()?,
            queued: d.u64()?,
            active: d.u64()?,
            submitted: d.u64()?,
        });
    }
    let n_wait = d.count(8)?;
    let mut queue_wait_ms = Vec::with_capacity(n_wait);
    for _ in 0..n_wait {
        queue_wait_ms.push(d.u64()?);
    }
    let n_lat = d.count(8)?;
    let mut latency_ms = Vec::with_capacity(n_lat);
    for _ in 0..n_lat {
        latency_ms.push(d.u64()?);
    }
    Ok(ServiceStats {
        uptime_ms,
        jobs_submitted,
        jobs_mined,
        jobs_failed,
        jobs_rejected_busy,
        jobs_expired,
        jobs_cancelled,
        cache_hits,
        cache_misses,
        cache_entries,
        store_entries,
        store_appends,
        store_hits,
        evicted_records,
        fleets,
        clients,
        queue_wait_ms,
        latency_ms,
    })
}
