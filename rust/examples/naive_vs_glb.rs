//! Naive static partition vs lifeline GLB (paper §5.4, Table 2 left):
//! same results, very different balance. Both variants run through the
//! [`parlamp::coordinator`] — only the [`GlbParams`] differ — and the
//! per-process work distribution shows *why* the naive approach fails on
//! deep trees.
//!
//! ```bash
//! cargo run --release --example naive_vs_glb [P]
//! ```

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::coordinator::{Backend, Coordinator, GlbParams, ScreenMode};
use parlamp::lamp::lamp_serial;
use parlamp::util::table::Table;

fn main() {
    let p: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(12);
    let sc = all_scenarios(true).into_iter().find(|s| s.name == "hapmap-dom-20").unwrap();
    let db = sc.build();
    let serial = lamp_serial(&db, parlamp::DEFAULT_ALPHA);
    let cal = calibrate_lamp(&db, parlamp::DEFAULT_ALPHA);
    let t1 = cal.t1_s;
    println!(
        "hapmap-dom-20-like: {} items × {} trans, CS({})={}, serial t1 {t1:.3}s\n",
        db.n_items(),
        db.n_trans(),
        serial.min_sup,
        serial.correction_factor
    );

    // All balance columns describe phase 2 (the counting phase — the
    // regime Table 2 left reports); the speedup column is the full
    // phases-1+2 pipeline against the serial t1.
    let mut table = Table::new(&[
        "engine",
        "p2 time(s)",
        "speedup(1+2)",
        "p2 gives",
        "p2 idle share",
        "max/mean work",
    ]);
    let variants = [
        ("GLB (lifeline steal)", GlbParams::default()),
        ("naive (static partition)", GlbParams::naive()),
    ];
    for (label, glb) in variants {
        let coord = Coordinator::new(parlamp::DEFAULT_ALPHA)
            .with_glb(glb)
            .with_calibration(cal)
            .with_screen(ScreenMode::Native);
        let run = coord.run(&db, &Backend::sim(p)).expect("coordinated run");
        assert_eq!(
            run.result.correction_factor, serial.correction_factor,
            "results must match the serial reference"
        );
        // Balance metrics from the phase-2 merge (the counting phase, the
        // regime Table 2 reports).
        let out = &run.phase2;
        let total = parlamp::par::breakdown::sum(&out.breakdowns);
        let idle_share = total.idle_ns as f64 / total.total_ns().max(1) as f64;
        let mains: Vec<f64> = out.breakdowns.iter().map(|b| b.main_ns as f64).collect();
        let mean = mains.iter().sum::<f64>() / mains.len() as f64;
        let max = mains.iter().cloned().fold(0.0, f64::max);
        table.row(vec![
            label.to_string(),
            format!("{:.4}", out.makespan_s),
            format!("{:.1}x", t1 / run.t_parallel_s()),
            out.comm.gives.to_string(),
            format!("{:.0}%", idle_share * 100.0),
            format!("{:.1}", max / mean.max(1.0)),
        ]);
    }
    println!("{}", table.render());
    println!(
        "the naive engine's max/mean work imbalance is the paper's \"failed\n\
         completely\": one process inherits the deep subtree and everyone\n\
         else idles (§5.4)."
    );
}
