//! The `parlamp serve` daemon (DESIGN.md §9 and §13).
//!
//! One process owns a **pool** of warm [`ProcessFleet`]s for its whole
//! lifetime and answers job frames over a stream socket — Unix-domain by
//! default, TCP when `--endpoint tcp:host:port` says so (DESIGN.md §11):
//!
//! - a **listener thread** accepts client connections and spawns one
//!   handler thread per connection;
//! - handler threads translate frames into operations on the shared state
//!   (submit → admission control + fair queue, status/result/cancel/stats
//!   → job table) and block `RESULT` replies until the job is terminal;
//! - one **runner thread per fleet** pulls the next eligible job from the
//!   weighted-fair queue ([`super::queue`]) and mines it via
//!   [`crate::coordinator::Coordinator::run_on_fleet`] — so `--fleets N`
//!   mines N jobs concurrently, and a fleet poisoned by an unrecoverable
//!   failure is rebuilt by its own runner without draining the pool.
//!
//! Results are answered from three layers, cheapest first: the in-memory
//! LRU ([`super::cache`]), the disk-backed persistent store
//! ([`super::store`], when `--store` is given — loaded at startup so a
//! restart keeps the cache warm), and finally the fleets. The `STATS`
//! frame ([`crate::wire::service::ServiceStats`]) exposes per-fleet
//! utilization, per-client queue depths, cache/store counters, and
//! latency histograms.
//!
//! Shutdown (a `SHUTDOWN` frame or `SIGTERM`/`SIGINT`) is graceful: new
//! submissions are rejected, the queue drains, every fleet gets its
//! `BYE`, and the socket is unlinked before [`serve`] returns.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use anyhow::{Context as _, Result};

use crate::net::fault::NetFaultPlan;
use crate::net::{Endpoint, Listener, Stream};
use crate::obs::log::{self, Tags};
use crate::obs::trace::{self as obs_trace, EventKind as TraceEv, RankTrace, TraceEvent, TraceRing};
use crate::obs::{chrome, clock};
use crate::par::{AbortHandle, DataPlane, FleetError, PendingFleet, ProcessConfig};
use crate::util::fault::FaultPlan;
use crate::util::sig;
use crate::wire::service::{JobOutcome, JobSpec, JobState};
use crate::wire::{read_frame, write_frame, Frame};

use super::cache::{CacheKey, ResultCache};
use super::metrics::Metrics;
use super::pool::{spawn_pool, FleetRunner};
use super::queue::{FairQueue, QueueLimits};
use super::store::ResultStore;

/// Knobs of one daemon instance.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Where to listen (`unix:<path>` or `tcp:<host>:<port>`). A Unix
    /// socket is created at startup and unlinked at shutdown, and the
    /// daemon refuses to start if the path already exists; a TCP listener
    /// leaves nothing on disk.
    pub listen: Endpoint,
    /// Worker processes per fleet.
    pub procs: usize,
    /// Warm fleets in the pool (`--fleets`, ≥ 1). Each fleet gets its own
    /// runner thread; jobs from different clients mine concurrently.
    pub fleets: usize,
    /// Result-cache capacity (entries).
    pub cache_cap: usize,
    /// Persistent result store path (`--store`); `None` = memory only.
    /// Loaded at startup, so a restarted daemon answers previously-mined
    /// jobs from disk without running a single fleet phase.
    pub store: Option<PathBuf>,
    /// Admission-control bounds and the per-client fairness slot cap.
    pub limits: QueueLimits,
    /// Worker executable override (tests; `None` = this binary).
    pub worker_exe: Option<PathBuf>,
    /// Fleet spawn/handshake timeout.
    pub spawn_timeout: Duration,
    /// Data plane of the warm fleets (`--data-plane hub|mesh`, DESIGN.md
    /// §10). A daemon property like the fleet size: the mesh peer links
    /// are opened lazily and then kept warm across jobs, so a stream of
    /// steal-heavy jobs pays the connect cost once.
    pub data_plane: DataPlane,
    /// Where the fleet *hubs* listen (`--transport tcp` maps to
    /// `Some(tcp:127.0.0.1:0)` — port 0, so each fleet binds its own
    /// ephemeral port); `None` = a fresh per-fleet Unix socket.
    pub fleet_listen: Option<Endpoint>,
    /// Remote attach mode (`--hosts`): the daemon spawns no local workers
    /// and instead prints join commands for `len()` externally-launched
    /// ones (see [`crate::par::engine_process`]). Incompatible with
    /// `fleets > 1` — one set of operators attaches one fleet.
    pub remote_workers: Option<Vec<Endpoint>>,
    /// Deterministic fault injection (`--fault-inject`, DESIGN.md §12):
    /// kill the named worker at the planned point of the fleet's lifetime.
    /// Arms **fleet 0 only**, so the chaos suite knows exactly which fleet
    /// dies and can prove the others unaffected.
    pub fault: Option<FaultPlan>,
    /// Deterministic *network*-fault injection (`--net-fault`, DESIGN.md
    /// §15): stall/drop/corrupt/partition one rank's fabric stream at a
    /// scripted frame count. Arms **fleet 0 only**, like `fault`.
    pub net_fault: Option<NetFaultPlan>,
    /// Heartbeat-lease timeout override for the fleets' hubs
    /// (`--lease-timeout`); `None` keeps the 60 s default.
    pub lease_timeout: Option<Duration>,
    /// Per-job wall-clock bound (`--job-watchdog-secs`, DESIGN.md §15):
    /// a job mining longer than this has its fleet force-killed by the
    /// watchdog thread, fails with a typed reason, and the fleet is
    /// rebuilt before that runner's next job. `None` disables the bound.
    pub job_watchdog: Option<Duration>,
    /// `--trace FILE` (DESIGN.md §14): accumulate the daemon's own
    /// queue/pop/expire events plus every mined job's per-rank timelines
    /// and write one Chrome trace-event JSON at drain. Per-track events
    /// are bounded by the default ring capacity (overflow counted), so a
    /// long session degrades loudly instead of growing without bound.
    pub trace: Option<PathBuf>,
}

impl ServeConfig {
    pub fn new(listen: Endpoint, procs: usize) -> ServeConfig {
        ServeConfig {
            listen,
            procs,
            fleets: 1,
            cache_cap: 32,
            store: None,
            limits: QueueLimits::default(),
            worker_exe: None,
            spawn_timeout: Duration::from_secs(30),
            data_plane: DataPlane::Mesh,
            fleet_listen: None,
            remote_workers: None,
            fault: None,
            net_fault: None,
            lease_timeout: None,
            job_watchdog: Some(Duration::from_secs(1800)),
            trace: None,
        }
    }
}

/// A job's lifecycle record. The spec (and its database) is dropped the
/// moment a runner takes the job, so queued-but-not-yet-run jobs are the
/// only ones holding database memory. Non-terminal records carry the
/// submitting client (for slot release) and the submit instant on the
/// metrics clock (for the latency histograms).
enum Record {
    Queued { spec: Box<JobSpec>, key: CacheKey, client: String, submitted_ms: u64 },
    Running { client: String, submitted_ms: u64 },
    Done { outcome: JobOutcome },
    Failed { reason: String },
    Cancelled,
    Expired,
}

/// How many *terminal* job records (done/failed/cancelled/expired) the
/// daemon retains for STATUS/RESULT queries. Older ones are evicted
/// oldest-first and report `not found` afterwards — without a bound, a
/// long-running daemon would leak one record (outcome included) per
/// submission forever. Evictions are counted in STATS
/// (`evicted_records`) and announced once in the log.
const JOB_HISTORY_CAP: usize = 1024;

struct Inner {
    next_id: u64,
    queue: FairQueue,
    jobs: HashMap<u64, Record>,
    /// Terminal job ids, oldest first, for [`JOB_HISTORY_CAP`] eviction.
    finished: std::collections::VecDeque<u64>,
    cache: ResultCache,
    store: Option<ResultStore>,
    metrics: Metrics,
    /// Shutdown requested: reject new submissions, finish the queue, exit.
    draining: bool,
    /// All runners have exited (result waiters must not block forever).
    done: bool,
    /// Hub-side serve events (queue/pop/expire) when tracing is on
    /// (DESIGN.md §14) — a bounded ring, like the worker rings.
    trace: TraceRing,
    /// Per-track fleet timelines folded in from traced jobs, keyed by
    /// export tid (fleet·procs + rank; [`chrome::HUB_RANK`] for hubs).
    rank_traces: std::collections::BTreeMap<u32, RankTrace>,
}

impl Inner {
    /// Record a job's terminal state, feed the latency histogram, and
    /// evict the oldest terminal records beyond [`JOB_HISTORY_CAP`].
    /// Queued/running jobs are never evicted.
    fn finish(&mut self, id: u64, record: Record) {
        let now = self.metrics.now_ms();
        if let Some(
            Record::Queued { submitted_ms, .. } | Record::Running { submitted_ms, .. },
        ) = self.jobs.get(&id)
        {
            self.metrics.latency.record(now.saturating_sub(*submitted_ms));
        }
        self.jobs.insert(id, record);
        self.finished.push_back(id);
        while self.finished.len() > JOB_HISTORY_CAP {
            if let Some(old) = self.finished.pop_front() {
                self.jobs.remove(&old);
                if self.metrics.evicted_records == 0 {
                    log::warn(
                        "serve",
                        &Tags::job(old),
                        format_args!(
                            "job history cap ({JOB_HISTORY_CAP}) reached; evicting oldest \
                             terminal records (count in STATS)"
                        ),
                    );
                }
                self.metrics.evicted_records += 1;
            }
        }
    }

    /// Layered result lookup: LRU first, then the persistent store (a
    /// disk hit is promoted into the LRU).
    fn lookup(&mut self, key: &CacheKey) -> Option<Arc<JobOutcome>> {
        if let Some(outcome) = self.cache.get(key) {
            return Some(outcome);
        }
        let outcome = self.store.as_ref()?.get(key)?;
        self.metrics.store_hits += 1;
        self.cache.insert_outcome(*key, Arc::clone(&outcome));
        Some(outcome)
    }

    /// Fold one traced job's timelines into the daemon-lifetime trace.
    /// Fleet `fleet`'s rank r rides export track `fleet·procs + r` so
    /// concurrent fleets stay distinct; every run's hub events land on the
    /// one shared [`chrome::HUB_RANK`] track. Events beyond the default
    /// ring capacity per track are dropped and counted — never silent.
    fn absorb_traces(&mut self, fleet: usize, procs: usize, traces: Vec<RankTrace>) {
        for rt in traces {
            let tid = if rt.rank == chrome::HUB_RANK {
                chrome::HUB_RANK
            } else {
                rt.rank + (fleet * procs) as u32
            };
            let slot = self.rank_traces.entry(tid).or_insert_with(|| RankTrace {
                rank: tid,
                offset_ns: 0,
                uncertainty_ns: 0,
                dropped: 0,
                events: Vec::new(),
            });
            slot.uncertainty_ns = slot.uncertainty_ns.max(rt.uncertainty_ns);
            slot.dropped += rt.dropped;
            for e in &rt.events {
                if slot.events.len() >= obs_trace::DEFAULT_RING_CAP {
                    slot.dropped += 1;
                } else {
                    slot.events.push(TraceEvent { t_ns: rt.aligned_ns(e), kind: e.kind });
                }
            }
        }
    }

    /// Poll the signal latch into the draining flag.
    fn poll_signals(&mut self) {
        if sig::terminate_requested() && !self.draining {
            self.draining = true;
            println!("parlamp serve: signal received, draining queue");
        }
    }
}

struct Shared {
    inner: Mutex<Inner>,
    /// Signals queue arrivals (runners) and job completions (waiters).
    wake: Condvar,
}

/// One armed per-job watchdog: which job is mining on the fleet, when it
/// must be done by, and the handle that kills the fleet if it is not.
struct WatchEntry {
    job: u64,
    deadline: Instant,
    handle: AbortHandle,
}

/// The watchdog registry, keyed by fleet index. Runners insert before
/// mining and remove after; the monitor thread fires expired entries.
type Watchdogs = Arc<Mutex<HashMap<usize, WatchEntry>>>;

impl Shared {
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().expect("service state lock")
    }
}

/// Unlink the service socket when the daemon exits, however it exits.
/// Transport-aware: only a Unix endpoint leaves a filesystem name behind;
/// for TCP there is nothing to unlink, so the guard is a no-op and a
/// restart can never fail on a bogus stale-path check.
struct SocketGuard(Endpoint);

impl Drop for SocketGuard {
    fn drop(&mut self) {
        if let Some(path) = self.0.unix_path() {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Print one copy-pasteable `parlamp __worker` join command per rank —
/// shared by `serve` and the `lamp --hosts` launcher path.
pub fn print_join_commands(pending: &PendingFleet, hosts: &[Endpoint]) {
    let exe = std::env::current_exe()
        .map(|p| p.to_string_lossy().into_owned())
        .unwrap_or_else(|_| "parlamp".into());
    println!(
        "fleet hub listening at {} ({} remote worker(s) expected)",
        pending.endpoint(),
        hosts.len()
    );
    println!("start each worker on its host:");
    for (rank, peer) in hosts.iter().enumerate() {
        println!("JOIN[{rank}]: {}", pending.join_command(&exe, rank, Some(peer)));
    }
}

/// Run the daemon: spawn the fleet pool, load the persistent store,
/// listen on `cfg.listen`, schedule jobs until a `SHUTDOWN` frame or
/// `SIGTERM`/`SIGINT` drains the queue. Returns after every fleet was
/// dismissed and any Unix socket unlinked.
pub fn serve(cfg: &ServeConfig) -> Result<()> {
    // SIGTERM/SIGINT latch into an atomic flag the runners poll; the
    // worker processes ignore terminal SIGINT themselves (see util::sig),
    // so a Ctrl-C drain finishes the in-flight jobs instead of killing
    // the fleets under them.
    sig::install_terminate_latch();
    anyhow::ensure!(cfg.fleets >= 1, "serve needs at least one fleet");
    anyhow::ensure!(
        cfg.fleets == 1 || cfg.remote_workers.is_none(),
        "--fleets > 1 is incompatible with --hosts (remote attach assembles one fleet)"
    );
    let mut fleet_cfg = ProcessConfig {
        worker_exe: cfg.worker_exe.clone(),
        spawn_timeout: cfg.spawn_timeout,
        data_plane: cfg.data_plane,
        listen: cfg.fleet_listen.clone(),
        remote_workers: cfg.remote_workers.clone(),
        fault: cfg.fault,
        net_fault: cfg.net_fault,
        ..ProcessConfig::paper_defaults(cfg.procs, 2015)
    };
    if let Some(t) = cfg.lease_timeout {
        fleet_cfg.lease_timeout = t;
    }
    // Fleets first: a daemon that cannot mine should fail before it
    // starts accepting submissions.
    let runners = spawn_pool(&fleet_cfg, cfg.fleets)?;
    println!(
        "parlamp serve: {} fleet(s) of {} worker processes warm ({} data plane)",
        cfg.fleets,
        fleet_cfg.world_size(),
        cfg.data_plane.name()
    );

    // Persistent store: open, recover, and warm the LRU from the most
    // recent records so a restart serves repeats without mining.
    let mut cache = ResultCache::new(cfg.cache_cap);
    let store = match &cfg.store {
        None => None,
        Some(path) => {
            let store = ResultStore::open(path)?;
            let warm = store.recent(cfg.cache_cap);
            let loaded = warm.len();
            for (key, outcome) in warm {
                cache.insert_outcome(key, outcome);
            }
            println!(
                "parlamp serve: result store {} ({} record(s), {loaded} preloaded)",
                path.display(),
                store.len()
            );
            Some(store)
        }
    };

    if let Some(path) = cfg.listen.unix_path() {
        // Refuse a stale path loudly instead of silently stealing it; a
        // TCP bind gets the same protection from the OS (AddrInUse).
        if path.exists() {
            anyhow::bail!(
                "service socket {} already exists (stale socket from a dead daemon? \
                 remove it first)",
                path.display()
            );
        }
    }
    let listener = Listener::bind(&cfg.listen)
        .with_context(|| format!("bind service endpoint {}", cfg.listen))?;
    let _socket_guard = SocketGuard(cfg.listen.clone());
    let bound = listener.local_endpoint().context("resolve service endpoint")?;
    listener.set_nonblocking(true).context("set service listener non-blocking")?;
    println!("parlamp serve: listening on {bound}");

    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            next_id: 1,
            queue: FairQueue::new(cfg.limits),
            jobs: HashMap::new(),
            finished: std::collections::VecDeque::new(),
            cache,
            store,
            metrics: Metrics::new(cfg.fleets),
            draining: false,
            done: false,
            trace: TraceRing::with_default_cap(),
            rank_traces: std::collections::BTreeMap::new(),
        }),
        wake: Condvar::new(),
    });

    // Listener thread: accept until the runners are done.
    let accept_shared = Arc::clone(&shared);
    let listener_thread = std::thread::spawn(move || loop {
        if accept_shared.lock().done {
            return;
        }
        match listener.accept() {
            Ok(stream) => {
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let shared = Arc::clone(&accept_shared);
                std::thread::spawn(move || client_loop(stream, &shared));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            // Transient accept failures (ECONNABORTED from a client that
            // vanished mid-handshake, EMFILE under fd pressure) must not
            // kill the accept loop — a daemon that silently stops
            // answering is worse than a noisy retry.
            Err(e) => {
                log::warn("serve", &Tags::NONE, format_args!("accept error (retrying): {e}"));
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    });

    // Per-job watchdog (DESIGN.md §15): runners register their fleet's
    // abort handle + deadline here before mining; the monitor thread
    // force-kills any fleet whose entry outlives its deadline. The killed
    // run errors out, the runner fails the job and rebuilds the fleet —
    // the same poison-and-rebuild path a crashed fleet takes.
    let dogs: Watchdogs = Arc::new(Mutex::new(HashMap::new()));
    let monitor_stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let dogs = Arc::clone(&dogs);
        let stop = Arc::clone(&monitor_stop);
        std::thread::spawn(move || {
            while !stop.load(Ordering::SeqCst) {
                std::thread::sleep(Duration::from_millis(250));
                let mut dogs = dogs.lock().expect("watchdog registry");
                let now = Instant::now();
                dogs.retain(|fleet, entry| {
                    if now < entry.deadline {
                        return true;
                    }
                    log::warn(
                        "serve",
                        &Tags::fleet(*fleet).and_job(entry.job).and_cause("watchdog-abort"),
                        format_args!(
                            "job {} exceeded its watchdog deadline; force-killing fleet {}",
                            entry.job, fleet
                        ),
                    );
                    entry.handle.fire();
                    false
                });
            }
        })
    };

    // One runner thread per fleet; each pulls from the shared fair queue.
    let procs = fleet_cfg.world_size();
    let job_watchdog = cfg.job_watchdog;
    let runner_threads: Vec<_> = runners
        .into_iter()
        .map(|mut runner| {
            let shared = Arc::clone(&shared);
            let dogs = Arc::clone(&dogs);
            std::thread::spawn(move || -> Result<()> {
                runner_loop(&shared, &mut runner, procs, job_watchdog, &dogs);
                runner.shutdown().context("dismiss warm fleet")
            })
        })
        .collect();

    // Wait for the drain: every runner exits once draining is set and the
    // queue is empty.
    let mut shutdown_result: Result<()> = Ok(());
    for thread in runner_threads {
        let joined = thread.join().unwrap_or_else(|_| {
            Err(anyhow::anyhow!("fleet runner thread panicked"))
        });
        if shutdown_result.is_ok() {
            shutdown_result = joined;
        }
    }
    monitor_stop.store(true, Ordering::SeqCst);
    let _ = monitor.join();

    // Drained. Release waiters and stop the listener.
    {
        let mut inner = shared.lock();
        inner.done = true;
        let (hits, misses) = inner.cache.stats();
        println!(
            "parlamp serve: drained ({} jobs mined, cache {hits} hits / {misses} misses)",
            inner.metrics.jobs_mined
        );
    }
    shared.wake.notify_all();
    let _ = listener_thread.join();

    // Write the daemon-lifetime trace after everything else stopped, so
    // no runner appends to the timelines mid-export.
    if let Some(path) = &cfg.trace {
        let mut inner = shared.lock();
        let (events, dropped) = inner.trace.take();
        let hub = inner.rank_traces.entry(chrome::HUB_RANK).or_insert_with(|| RankTrace {
            rank: chrome::HUB_RANK,
            offset_ns: 0,
            uncertainty_ns: 0,
            dropped: 0,
            events: Vec::new(),
        });
        hub.events.extend(events);
        hub.dropped += dropped;
        hub.events.sort_by_key(|e| e.t_ns);
        let traces: Vec<RankTrace> = inner.rank_traces.values().cloned().collect();
        drop(inner);
        std::fs::write(path, chrome::export(&traces))
            .with_context(|| format!("write trace {}", path.display()))?;
        println!("parlamp serve: wrote trace {} ({} track(s))", path.display(), traces.len());
    }
    shutdown_result
}

/// One fleet's scheduling loop: expire deadlines, pull the next eligible
/// job, probe the caches, mine, publish. Exits once the daemon is
/// draining and the queue is empty. `procs` is the fleet world size, used
/// to give each fleet's ranks their own trace tracks; `watchdog` bounds
/// each job's mining wall-clock through the `dogs` registry (DESIGN.md
/// §15).
fn runner_loop(
    shared: &Arc<Shared>,
    runner: &mut FleetRunner,
    procs: usize,
    watchdog: Option<Duration>,
    dogs: &Watchdogs,
) {
    loop {
        // One locked section: poll signals, expire deadlines, try to pop.
        let popped = {
            let mut inner = shared.lock();
            inner.poll_signals();
            let now = inner.metrics.now_ms();
            let expired = inner.queue.expire(now);
            if !expired.is_empty() {
                // Expired jobs were pending, never dispatched — no slot to
                // release, just the terminal record and the counter.
                for id in expired {
                    inner.metrics.jobs_expired += 1;
                    if obs_trace::enabled() {
                        inner.trace.push(clock::now_ns(), TraceEv::ServeExpire { job: id });
                    }
                    inner.finish(id, Record::Expired);
                }
                shared.wake.notify_all();
            }
            match inner.queue.pop() {
                Some(id) => {
                    // Take the spec and mark the job running. A popped id
                    // is always `Queued` — CANCEL and expiry only touch
                    // jobs still in the queue.
                    let now = inner.metrics.now_ms();
                    match inner.jobs.remove(&id) {
                        Some(Record::Queued { spec, key, client, submitted_ms }) => {
                            inner.jobs.insert(
                                id,
                                Record::Running { client: client.clone(), submitted_ms },
                            );
                            inner
                                .metrics
                                .queue_wait
                                .record(now.saturating_sub(submitted_ms));
                            if obs_trace::enabled() {
                                inner.trace.push(clock::now_ns(), TraceEv::ServePop { job: id });
                            }
                            Some((id, spec, key, client))
                        }
                        stale => {
                            // Defensive: restore whatever was there and
                            // release the slot the pop consumed.
                            if let Some(r) = stale {
                                inner.jobs.insert(id, r);
                            }
                            None
                        }
                    }
                }
                None if inner.draining && inner.queue.is_empty() => break,
                None => {
                    let guard = shared
                        .wake
                        .wait_timeout(inner, Duration::from_millis(200))
                        .expect("service state lock");
                    drop(guard);
                    continue;
                }
            }
        };
        let Some((id, spec, key, client)) = popped else {
            continue;
        };

        // Schedule-time cache probe: an identical job may have finished
        // (on any fleet) while this one waited in the queue.
        let cached = {
            let mut inner = shared.lock();
            inner.lookup(&key).map(|o| o.as_ref().clone())
        };
        if let Some(outcome) = cached {
            let mut inner = shared.lock();
            inner.finish(id, Record::Done { outcome });
            inner.queue.complete(&client);
            drop(inner);
            shared.wake.notify_all();
            continue;
        }

        // Mine — the expensive part, outside the lock. Other runners keep
        // dispatching while this fleet works. The fleet is (re)built
        // *before* the watchdog arms so the registered handle covers the
        // pids that actually mine this job.
        let started = std::time::Instant::now();
        let mined = match runner.ensure_fleet() {
            Ok(()) => {
                if let (Some(limit), Some(handle)) = (watchdog, runner.abort_handle()) {
                    dogs.lock().expect("watchdog registry").insert(
                        runner.idx,
                        WatchEntry { job: id, deadline: Instant::now() + limit, handle },
                    );
                }
                let mined = runner.mine(&spec);
                dogs.lock().expect("watchdog registry").remove(&runner.idx);
                mined
            }
            Err(e) => Err(e),
        };
        let busy_ms = started.elapsed().as_millis() as u64;

        let mut inner = shared.lock();
        let fleet = &mut inner.metrics.fleets[runner.idx];
        fleet.busy_ms += busy_ms;
        fleet.respawns = runner.respawns();
        fleet.rebuilds = runner.rebuilds();
        match mined {
            Ok(run) => {
                inner.metrics.jobs_mined += 1;
                inner.metrics.fleets[runner.idx].jobs_mined += 1;
                if obs_trace::enabled() {
                    let traces = run.traces();
                    inner.absorb_traces(runner.idx, procs, traces);
                }
                let shared_outcome = Arc::new(JobOutcome::from_run(&run, true));
                if let Some(store) = &mut inner.store {
                    match store.append(key, &shared_outcome) {
                        Ok(()) => inner.metrics.store_appends += 1,
                        // A full disk must not fail the job — the result
                        // is in memory and on its way to the client.
                        Err(e) => log::warn(
                            "serve",
                            &Tags::fleet(runner.idx).and_job(id),
                            format_args!("store append failed: {e:#}"),
                        ),
                    }
                }
                inner.cache.insert_outcome(key, shared_outcome);
                inner.finish(id, Record::Done { outcome: JobOutcome::from_run(&run, false) });
            }
            Err(e) => {
                inner.metrics.jobs_failed += 1;
                // Tag the failure with its typed cause when the fleet
                // layer provided one (DESIGN.md §15) — log scrapes can
                // then tell a watchdog kill from exhausted recoveries.
                let mut tags = Tags::fleet(runner.idx).and_job(id);
                if let Some(fe) =
                    e.source().and_then(|s| s.downcast_ref::<FleetError>())
                {
                    tags = tags.and_cause(match fe {
                        FleetError::WatchdogAbort => "watchdog-abort",
                        FleetError::RecoveryExhausted { .. } => "recovery-exhausted",
                        FleetError::AssembleTimeout { .. } => "assemble-timeout",
                    });
                }
                log::warn("serve", &tags, format_args!("job {id} failed: {e:#}"));
                inner.finish(id, Record::Failed { reason: format!("{e:#}") });
            }
        }
        inner.queue.complete(&client);
        drop(inner);
        shared.wake.notify_all();
    }
}

/// One connected client: serve frames until EOF (or its `SHUTDOWN` ack).
fn client_loop(mut stream: Stream, shared: &Arc<Shared>) {
    loop {
        let frame = match read_frame(&mut stream) {
            Ok(Some(frame)) => frame,
            Ok(None) => return, // client gone
            // A malformed or version-mismatched frame gets one clear error
            // reply (the wire versioning promise) before the connection
            // closes — after a framing error the stream cannot be resynced.
            Err(e) => {
                log::warn("serve", &Tags::NONE, format_args!("bad client frame: {e:#}"));
                let _ = write_frame(
                    &mut stream,
                    &Frame::Status {
                        job_id: 0,
                        report: Some(JobState::Failed { reason: format!("bad frame: {e:#}") }),
                    },
                );
                return;
            }
        };
        let last = matches!(frame, Frame::Shutdown);
        let reply = handle(shared, frame);
        if write_frame(&mut stream, &reply).is_err() {
            return;
        }
        if last {
            return;
        }
    }
}

fn handle(shared: &Arc<Shared>, frame: Frame) -> Frame {
    match frame {
        Frame::Submit(spec) => submit(shared, spec),
        Frame::Status { job_id, .. } => {
            let inner = shared.lock();
            Frame::Status { job_id, report: Some(state_of(&inner, job_id)) }
        }
        Frame::JobResult { job_id, .. } => wait_result(shared, job_id),
        Frame::Cancel { job_id } => {
            let mut inner = shared.lock();
            // Only a still-pending job can be cancelled; a running or
            // terminal one just reports its current state. A cancelled
            // job held no fleet slot, so there is nothing to release.
            if inner.queue.cancel(job_id) {
                inner.metrics.jobs_cancelled += 1;
                inner.finish(job_id, Record::Cancelled);
            }
            Frame::Status { job_id, report: Some(state_of(&inner, job_id)) }
        }
        Frame::Stats { .. } => {
            let inner = shared.lock();
            let (hits, misses) = inner.cache.stats();
            let depths = inner.queue.depths();
            let report = inner.metrics.snapshot(
                (hits, misses, inner.cache.len()),
                inner.store.as_ref().map_or(0, |s| s.len()),
                &depths,
            );
            Frame::Stats { report: Some(Box::new(report)) }
        }
        Frame::Shutdown => {
            {
                let mut inner = shared.lock();
                if !inner.draining {
                    inner.draining = true;
                    println!("parlamp serve: SHUTDOWN received, draining queue");
                }
            }
            shared.wake.notify_all();
            Frame::Shutdown
        }
        other => Frame::Status {
            job_id: 0,
            report: Some(JobState::Failed {
                reason: format!("unexpected {} frame on the service socket", other.name()),
            }),
        },
    }
}

fn submit(shared: &Arc<Shared>, spec: Box<JobSpec>) -> Frame {
    let key = CacheKey::new(spec.db.digest(), spec.alpha, spec.glb, spec.screen);
    let client = if spec.client.is_empty() { "anon".to_string() } else { spec.client.clone() };
    let mut inner = shared.lock();
    if inner.draining {
        return Frame::Status {
            job_id: 0,
            report: Some(JobState::Failed {
                reason: "daemon is draining (shutdown in progress)".into(),
            }),
        };
    }
    inner.metrics.jobs_submitted += 1;
    *inner.metrics.submitted_by_client.entry(client.clone()).or_insert(0) += 1;
    // Submit-time cache/store probe: a repeat submission never reaches the
    // queue, let alone the workers — and after a restart the probe hits
    // the persistent store, so zero fleet phases run.
    if let Some(outcome) = inner.lookup(&key) {
        let id = inner.next_id;
        inner.next_id += 1;
        inner.finish(id, Record::Done { outcome: outcome.as_ref().clone() });
        return Frame::Accepted { job_id: id };
    }
    // Admission control: a typed busy reply instead of unbounded growth.
    let now = inner.metrics.now_ms();
    let id = inner.next_id;
    if let Err(busy) = inner.queue.push(&client, id, spec.priority, spec.deadline_ms, now) {
        inner.metrics.jobs_rejected_busy += 1;
        return Frame::Status {
            job_id: 0,
            report: Some(JobState::Busy { reason: busy.to_string() }),
        };
    }
    inner.next_id += 1;
    if obs_trace::enabled() {
        inner.trace.push(clock::now_ns(), TraceEv::ServeQueue { job: id });
    }
    inner
        .jobs
        .insert(id, Record::Queued { spec, key, client, submitted_ms: now });
    drop(inner);
    shared.wake.notify_all();
    Frame::Accepted { job_id: id }
}

fn state_of(inner: &Inner, id: u64) -> JobState {
    match inner.jobs.get(&id) {
        None => JobState::NotFound,
        Some(Record::Queued { .. }) => JobState::Queued {
            position: inner.queue.position(id).unwrap_or(0) as u32,
        },
        Some(Record::Running { .. }) => JobState::Running,
        Some(Record::Done { outcome }) => JobState::Done { from_cache: outcome.from_cache },
        Some(Record::Failed { reason }) => JobState::Failed { reason: reason.clone() },
        Some(Record::Cancelled) => JobState::Cancelled,
        Some(Record::Expired) => JobState::Expired,
    }
}

/// Block until `id` is terminal; reply `RESULT` for a finished job and a
/// `STATUS` report otherwise (failed, cancelled, expired, unknown).
fn wait_result(shared: &Arc<Shared>, id: u64) -> Frame {
    let mut inner = shared.lock();
    loop {
        // Decide on an owned reply first so the `jobs` borrow ends before
        // the guard is handed to the condvar.
        let reply: Option<Frame> = match inner.jobs.get(&id) {
            Some(Record::Done { outcome }) => {
                Some(Frame::JobResult { job_id: id, report: Some(Box::new(outcome.clone())) })
            }
            Some(Record::Queued { .. } | Record::Running { .. }) if !inner.done => None,
            Some(Record::Queued { .. } | Record::Running { .. }) => Some(Frame::Status {
                job_id: id,
                report: Some(JobState::Failed {
                    reason: "daemon exited before the job finished".into(),
                }),
            }),
            _ => Some(Frame::Status { job_id: id, report: Some(state_of(&inner, id)) }),
        };
        if let Some(frame) = reply {
            return frame;
        }
        let (guard, _) = shared
            .wake
            .wait_timeout(inner, Duration::from_millis(200))
            .expect("service state lock");
        inner = guard;
    }
}
