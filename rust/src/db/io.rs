//! Text I/O for transaction databases.
//!
//! Format follows the FIMI `.dat` convention the LCM tooling uses: one
//! transaction per line, whitespace-separated item ids. Labels are one
//! `0`/`1` per line (1 = positive), aligned with the transaction file.

use std::io::{BufRead, BufReader, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Item;

/// Read a FIMI-style transaction file. Returns `(n_items, transactions)`
/// where `n_items` is one past the largest item id seen.
pub fn read_transactions(path: &Path) -> Result<(usize, Vec<Vec<Item>>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut trans = Vec::new();
    let mut max_item: i64 = -1;
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            trans.push(Vec::new());
            continue;
        }
        let mut t = Vec::new();
        for tok in line.split_whitespace() {
            let item: Item = tok
                .parse()
                .with_context(|| format!("{}:{}: bad item '{tok}'", path.display(), lineno + 1))?;
            max_item = max_item.max(item as i64);
            t.push(item);
        }
        t.sort_unstable();
        t.dedup();
        trans.push(t);
    }
    Ok(((max_item + 1) as usize, trans))
}

/// Read a label file (one `0`/`1` per line).
pub fn read_labels(path: &Path) -> Result<Vec<bool>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut labels = Vec::new();
    for (lineno, line) in BufReader::new(f).lines().enumerate() {
        let line = line?;
        match line.trim() {
            "0" => labels.push(false),
            "1" => labels.push(true),
            "" => {}
            other => bail!("{}:{}: bad label '{other}'", path.display(), lineno + 1),
        }
    }
    Ok(labels)
}

/// Write transactions in FIMI format.
pub fn write_transactions(path: &Path, trans: &[Vec<Item>]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    for t in trans {
        let line: Vec<String> = t.iter().map(|i| i.to_string()).collect();
        writeln!(f, "{}", line.join(" "))?;
    }
    Ok(())
}

/// Write labels (one per line).
pub fn write_labels(path: &Path, labels: &[bool]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    for l in labels {
        writeln!(f, "{}", u8::from(*l))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("parlamp_io_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tpath = dir.join("t.dat");
        let lpath = dir.join("t.labels");
        let trans = vec![vec![0, 2, 5], vec![], vec![1, 2]];
        let labels = vec![true, false, true];
        write_transactions(&tpath, &trans).unwrap();
        write_labels(&lpath, &labels).unwrap();
        let (n_items, got) = read_transactions(&tpath).unwrap();
        assert_eq!(n_items, 6);
        assert_eq!(got, trans);
        assert_eq!(read_labels(&lpath).unwrap(), labels);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_label() {
        let dir = std::env::temp_dir().join(format!("parlamp_io_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let lpath = dir.join("bad.labels");
        std::fs::write(&lpath, "0\n2\n").unwrap();
        assert!(read_labels(&lpath).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dedups_and_sorts_items() {
        let dir = std::env::temp_dir().join(format!("parlamp_io_dup_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tpath = dir.join("d.dat");
        std::fs::write(&tpath, "3 1 3 2\n").unwrap();
        let (_, got) = read_transactions(&tpath).unwrap();
        assert_eq!(got, vec![vec![1, 2, 3]]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
