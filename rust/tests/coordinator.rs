//! Coordinator integration: the phase-boundary merges gathered at Mattern
//! DTD quiescence must reproduce the serial miner's histograms exactly, on
//! both fabric backends, with and without stealing; and the whole
//! coordinated pipeline must agree with `lamp_serial` end to end.

use parlamp::coordinator::{Backend, Coordinator, GlbParams, ScreenKind, ScreenMode};
use parlamp::datagen::{generate_gwas, GwasSpec};
use parlamp::db::Database;
use parlamp::lamp::{lamp_serial, SupportIncreaseRule};
use parlamp::lcm::{mine_closed, SupportHist, Visit};

fn small_db(seed: u64) -> Database {
    let spec = GwasSpec { n_snps: 140, n_individuals: 90, n_pos: 24, ..GwasSpec::small(seed) };
    generate_gwas(&spec).0
}

/// The serial LCM closed-set histogram at `min_sup` — the ground truth the
/// distributed phase-2 merge must equal.
fn serial_hist(db: &Database, min_sup: u32) -> SupportHist {
    let mut hist = SupportHist::new(db.n_trans());
    mine_closed(db, min_sup, |node, ms| {
        hist.record(node.support);
        (Visit::Continue, ms)
    });
    hist
}

fn assert_phase2_merge_matches_serial(db: &Database, backend: &Backend, glb: GlbParams) {
    let serial = lamp_serial(db, 0.05);
    let run = Coordinator::new(0.05)
        .with_glb(glb)
        .with_screen(ScreenMode::Native)
        .run(db, backend)
        .expect("coordinated run");
    assert_eq!(run.result.lambda_final, serial.lambda_final, "{backend:?} λ*");
    assert_eq!(run.result.correction_factor, serial.correction_factor, "{backend:?} k");

    // Phase 2 counts every closed set with support ≥ min_sup exactly once,
    // so the merged histogram must equal the serial one bin for bin.
    let want = serial_hist(db, run.result.min_sup);
    assert_eq!(
        run.phase2.hist.counts(),
        want.counts(),
        "{backend:?} steal={}: phase-2 merged histogram != serial LCM histogram",
        glb.steal
    );
    assert_eq!(run.phase2.hist.total(), serial.correction_factor);

    // Phase 1's merged histogram is exact at and above λ* (below it the
    // rising λ prunes), which is precisely what makes the recomputed λ* a
    // fixed point of the support-increase rule.
    let full = serial_hist(db, 1);
    for lambda in run.result.lambda_final..=db.n_trans() as u32 {
        assert_eq!(
            run.phase1.hist.cs_ge(lambda),
            full.cs_ge(lambda),
            "{backend:?} steal={}: phase-1 CS({lambda}) diverges from serial",
            glb.steal
        );
    }
    let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
    assert_eq!(
        rule.advance(1, |l| run.phase1.hist.cs_ge(l)),
        run.result.lambda_final,
        "{backend:?}: λ* must be recomputable from the merged phase-1 histogram"
    );

    if !glb.steal {
        let comm = run.comm_total();
        assert_eq!(comm.gives, 0, "{backend:?}: naive baseline must never ship tasks");
        assert_eq!(comm.tasks_shipped, 0);
    }
}

#[test]
fn sim_backend_merge_matches_serial() {
    let db = small_db(7);
    for p in [1usize, 4, 9] {
        assert_phase2_merge_matches_serial(&db, &Backend::sim(p), GlbParams::default());
    }
}

#[test]
fn thread_backend_merge_matches_serial() {
    let db = small_db(11);
    for p in [2usize, 4] {
        let backend = Backend::Threads { p, seed: 77 };
        assert_phase2_merge_matches_serial(&db, &backend, GlbParams::default());
    }
}

#[test]
fn naive_baseline_merge_matches_serial_on_both_backends() {
    let db = small_db(13);
    assert_phase2_merge_matches_serial(&db, &Backend::sim(6), GlbParams::naive());
    let backend = Backend::Threads { p: 3, seed: 5 };
    assert_phase2_merge_matches_serial(&db, &backend, GlbParams::naive());
}

#[test]
fn backends_agree_with_each_other() {
    let db = small_db(17);
    let coord = Coordinator::new(0.05).with_screen(ScreenMode::Native);
    let thr = coord.run(&db, &Backend::Threads { p: 3, seed: 1 }).expect("threads");
    let sim = coord.run(&db, &Backend::sim(5)).expect("sim");
    assert_eq!(thr.result.lambda_final, sim.result.lambda_final);
    assert_eq!(thr.result.correction_factor, sim.result.correction_factor);
    assert_eq!(thr.result.significant.len(), sim.result.significant.len());
    for (a, b) in thr.result.significant.iter().zip(&sim.result.significant) {
        assert_eq!(a.items, b.items);
        assert_eq!(a.support, b.support);
        assert!((a.p_value - b.p_value).abs() < 1e-12);
    }
}

#[test]
fn default_screen_degrades_gracefully_without_artifacts() {
    // In CI there are no AOT artifacts: the Auto screen must fall back to
    // native Fisher and still produce the serial significant set.
    let db = small_db(19);
    let serial = lamp_serial(&db, 0.05);
    let run = Coordinator::new(0.05).run(&db, &Backend::sim(4)).expect("auto run");
    if !parlamp::runtime::artifacts_available() {
        assert_eq!(run.screen, ScreenKind::Native);
    }
    assert_eq!(run.result.significant.len(), serial.significant.len());
    for (a, b) in run.result.significant.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items);
    }
}
