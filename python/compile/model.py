"""L2 — the significance-screen compute graph.

`screen_batch` composes the two L1 Pallas kernels into the batched
phase-3 screen the rust coordinator offloads through PJRT: packed
occurrence bitmaps in, (support, positive support, Fisher log-P, Tarone
log-f) out. Forward-only — this is a mining paper, there is no backward
pass to build (DESIGN.md §1).

jax config: f64 must be enabled before any jax import site uses it; the
import below is the single switch for the whole compile path.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels.fisher import fisher_tarone  # noqa: E402
from .kernels.popcount import support_counts  # noqa: E402


def screen_batch(occ_words, pos_words, n_total, n_pos, *, t_max):
    """The full screen: bitmaps → statistics.

    occ_words: (K, W) uint32 packed candidate occurrence bitmaps (padded
        rows must be all-zero: they produce x = 0 → log P = 0, screened out
        by the rust side).
    pos_words: (W,) uint32 positive-class mask.
    n_total, n_pos: (1,) float64 marginals (runtime scalars, so one
        artifact serves any dataset with n_pos + 1 <= t_max).
    Returns (x, n, logp, logf).
    """
    x, n = support_counts(occ_words, pos_words)
    logp, logf = fisher_tarone(x, n, n_total, n_pos, t_max=t_max)
    return x, n, logp, logf


def screen_example_args(k, w, t_max):
    """ShapeDtypeStructs for AOT lowering of `screen_batch`."""
    del t_max  # static; fixed by closure at lowering time
    return (
        jax.ShapeDtypeStruct((k, w), jnp.uint32),
        jax.ShapeDtypeStruct((w,), jnp.uint32),
        jax.ShapeDtypeStruct((1,), jnp.float64),
        jax.ShapeDtypeStruct((1,), jnp.float64),
    )
