//! MCF7-style transcriptome workload: few items, many transactions.
//!
//! The paper's sixth problem (Table 1: 397 items × 12,773 transactions,
//! density 2.94%) exercises the regime its bitmap miner is *not* tuned
//! for — the depth-1 preprocess dominates at P ≥ 600 because there are
//! fewer items than processes (§5.2), and the occurrence-deliver LAMP2
//! baseline wins on it single-core (§5.5). This generator reproduces that
//! shape: a small item vocabulary with a heavy-tailed frequency spectrum
//! over a large transaction set.

use crate::db::{Database, Item};
use crate::util::rng::Rng;

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct Mcf7Spec {
    pub n_items: usize,
    pub n_trans: usize,
    pub n_pos: usize,
    /// Target matrix density (paper: 0.0294).
    pub density: f64,
    /// Item-frequency skew: item `i` gets weight `(i+1)^-skew`.
    pub skew: f64,
    /// Planted positive-enriched pattern arities and penetrances.
    pub planted: Vec<(usize, f64)>,
    pub seed: u64,
}

impl Mcf7Spec {
    pub fn small(seed: u64) -> Self {
        Mcf7Spec {
            n_items: 60,
            n_trans: 800,
            n_pos: 70,
            density: 0.03,
            skew: 0.8,
            planted: vec![(2, 0.7)],
            seed,
        }
    }
}

/// Generate the labelled database plus planted pattern ids.
pub fn generate_mcf7_like(spec: &Mcf7Spec) -> (Database, Vec<Vec<Item>>) {
    let mut rng = Rng::new(spec.seed);
    let (m, n) = (spec.n_items, spec.n_trans);
    assert!(spec.n_pos <= n);

    // Zipf-ish per-item probabilities scaled to the target density.
    let weights: Vec<f64> = (0..m).map(|i| 1.0 / ((i + 1) as f64).powf(spec.skew)).collect();
    let wsum: f64 = weights.iter().sum();
    let scale = spec.density * m as f64 / wsum;
    let probs: Vec<f64> = weights.iter().map(|w| (w * scale).min(0.9)).collect();

    let mut labels = vec![false; n];
    for l in labels.iter_mut().take(spec.n_pos) {
        *l = true;
    }

    let mut trans: Vec<Vec<Item>> = (0..n)
        .map(|_| {
            (0..m as Item).filter(|&i| rng.bernoulli(probs[i as usize])).collect::<Vec<_>>()
        })
        .collect();

    // Plant enriched combinations among positives.
    let mut planted_items = Vec::new();
    for &(arity, penetrance) in &spec.planted {
        let mut items: Vec<Item> = Vec::new();
        while items.len() < arity.min(m) {
            let i = rng.index(m) as Item;
            if !items.contains(&i) {
                items.push(i);
            }
        }
        items.sort_unstable();
        for (t, lab) in labels.iter().enumerate() {
            if *lab && rng.bernoulli(penetrance) {
                for &i in &items {
                    if !trans[t].contains(&i) {
                        trans[t].push(i);
                    }
                }
            }
        }
        planted_items.push(items);
    }
    for t in trans.iter_mut() {
        t.sort_unstable();
    }

    (Database::from_transactions(m, &trans, &labels), planted_items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_density() {
        let spec = Mcf7Spec { planted: vec![], ..Mcf7Spec::small(3) };
        let (db, _) = generate_mcf7_like(&spec);
        assert_eq!(db.n_items(), 60);
        assert_eq!(db.n_trans(), 800);
        let d = db.density();
        assert!(
            (d - 0.03).abs() < 0.012,
            "density {d} should approximate the 0.03 target"
        );
    }

    #[test]
    fn frequency_spectrum_is_skewed() {
        let spec = Mcf7Spec { planted: vec![], ..Mcf7Spec::small(9) };
        let (db, _) = generate_mcf7_like(&spec);
        // first decile of items should be much more frequent than the last
        let head: u32 = (0..6).map(|i| db.item_support(i)).sum();
        let tail: u32 = (54..60).map(|i| db.item_support(i)).sum();
        assert!(head > tail, "head {head} vs tail {tail}");
    }

    #[test]
    fn deterministic() {
        let spec = Mcf7Spec::small(1);
        let (a, pa) = generate_mcf7_like(&spec);
        let (b, pb) = generate_mcf7_like(&spec);
        assert_eq!(a.density(), b.density());
        assert_eq!(pa, pb);
    }

    #[test]
    fn planted_items_valid() {
        let (db, planted) = generate_mcf7_like(&Mcf7Spec::small(17));
        for p in &planted {
            assert!(!p.is_empty());
            assert!(db.support(p) > 0, "planted pattern must occur");
        }
    }
}
