//! The daemon's warm fleet pool (DESIGN.md §13).
//!
//! `serve --fleets N --procs P` owns N independent [`ProcessFleet`]s of P
//! worker processes each. Jobs dispatch onto idle fleets concurrently —
//! one runner thread per fleet pulls work from the shared fair queue, so
//! two clients' jobs mine at the same time on different fleets and a long
//! job never blocks the whole daemon.
//!
//! Fleet loss is contained per runner: a fleet whose run errors (a worker
//! death the PR-7 in-place respawn could not absorb, a poisoned socket) is
//! dropped — kill-on-drop reaps its processes — and rebuilt lazily before
//! that runner's *next* job, without draining the queue or touching the
//! other fleets. The failed job reports `Failed`; nothing else notices.

use anyhow::{Context, Result};

use crate::coordinator::{Coordinator, CoordinatorRun};
use crate::par::{AbortHandle, ProcessConfig, ProcessFleet};
use crate::wire::service::JobSpec;

use super::print_join_commands;

/// Spawn (or remote-attach) one fleet. Same path the single-fleet daemon
/// always used: in remote attach mode the per-rank join commands print
/// *before* the blocking wait, so the operator can start the workers.
fn spawn_fleet(cfg: &ProcessConfig) -> Result<ProcessFleet> {
    let pending = ProcessFleet::bind(cfg).context("bind fleet hub")?;
    if let Some(hosts) = &cfg.remote_workers {
        print_join_commands(&pending, hosts);
    }
    pending.await_workers().context("assemble warm worker fleet")
}

/// One fleet plus its rebuild configuration and work counters — the unit
/// a runner thread owns exclusively (never shared, never locked).
pub struct FleetRunner {
    /// Index into the pool (the fleet id in STATS and logs).
    pub idx: usize,
    cfg: ProcessConfig,
    /// `None` after a poisoned run, until the next job rebuilds it.
    fleet: Option<ProcessFleet>,
    /// In-place rank respawns accumulated by fleets this runner already
    /// dropped (a live fleet's own count is added on top).
    respawns_base: u64,
    /// Whole-fleet rebuilds performed (poisoned → respawned).
    rebuilds: u64,
}

impl FleetRunner {
    /// Rebuild the fleet if a previous run poisoned it; a no-op while the
    /// fleet is alive. Split out of [`FleetRunner::mine`] so the serve
    /// watchdog can take the *fresh* fleet's [`AbortHandle`] before the
    /// job starts mining (DESIGN.md §15) — a handle snapshotted from the
    /// poisoned fleet would kill already-reaped pids.
    pub fn ensure_fleet(&mut self) -> Result<()> {
        if self.fleet.is_none() {
            // A rebuilt fleet never inherits a fault plan: the injected
            // fault already fired once, which is the whole point.
            self.fleet = Some(
                spawn_fleet(&self.cfg.without_fault())
                    .with_context(|| format!("rebuilding fleet {}", self.idx))?,
            );
            self.rebuilds += 1;
        }
        Ok(())
    }

    /// The live fleet's watchdog handle; `None` while poisoned.
    pub fn abort_handle(&self) -> Option<AbortHandle> {
        self.fleet.as_ref().map(ProcessFleet::abort_handle)
    }

    /// Mine one job on this runner's fleet, rebuilding the fleet first if
    /// the previous run poisoned it. On error the fleet is dropped
    /// (kill-on-drop) so the next call starts from clean processes.
    pub fn mine(&mut self, spec: &JobSpec) -> Result<CoordinatorRun> {
        self.ensure_fleet()?;
        let fleet = self.fleet.as_mut().expect("fleet just ensured");
        let coordinator = Coordinator::new(spec.alpha)
            .with_glb(spec.glb)
            .with_screen(spec.screen);
        let run = coordinator.run_on_fleet(&spec.db, fleet, spec.seed);
        if run.is_err() {
            // Poison: drop the fleet now (reaping its processes) rather
            // than handing the next job a wedged socket.
            self.respawns_base += self.fleet.as_ref().map_or(0, |f| f.respawns());
            self.fleet = None;
        }
        run.with_context(|| format!("mining on fleet {}", self.idx))
    }

    /// Worker ranks respawned *in place* by the fleet recovery path
    /// (DESIGN.md §12), cumulative across this runner's whole life —
    /// rebuilt fleets included.
    pub fn respawns(&self) -> u64 {
        self.respawns_base + self.fleet.as_ref().map_or(0, |f| f.respawns())
    }

    /// Whole-fleet rebuilds (distinct from in-place rank respawns).
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Dismiss the fleet cleanly (BYE + join), if it is alive.
    pub fn shutdown(mut self) -> Result<()> {
        match self.fleet.take() {
            Some(fleet) => fleet.shutdown(),
            None => Ok(()),
        }
    }
}

/// Spawn the pool: `n` fleets, each from its own copy of `cfg`. All
/// fleets spawn *before* the daemon accepts connections — a daemon that
/// cannot mine must fail its startup, not its first job.
///
/// An injected fault plan arms **fleet 0 only** (deterministic chaos: the
/// tests know exactly which fleet dies, and prove the others unaffected).
/// The returned runners are meant to move into per-fleet threads; nothing
/// in them is shared.
pub fn spawn_pool(cfg: &ProcessConfig, n: usize) -> Result<Vec<FleetRunner>> {
    let mut runners = Vec::with_capacity(n);
    for idx in 0..n {
        let fleet_cfg = if idx == 0 { cfg.clone() } else { cfg.without_fault() };
        let fleet = spawn_fleet(&fleet_cfg)
            .with_context(|| format!("spawning fleet {idx} of {n}"))?;
        runners.push(FleetRunner {
            idx,
            cfg: fleet_cfg,
            fleet: Some(fleet),
            respawns_base: 0,
            rebuilds: 0,
        });
    }
    Ok(runners)
}
