//! Cached log-factorial table.

/// `ln(k!)` for `k = 0..=max`, precomputed once per database size.
///
/// All hypergeometric quantities are evaluated in log space to stay finite
/// for the large binomials a 12k-transaction database produces.
#[derive(Clone, Debug)]
pub struct LogFact {
    table: Vec<f64>,
}

impl LogFact {
    /// Build a table valid for arguments up to `max` inclusive.
    pub fn new(max: u32) -> Self {
        let mut table = Vec::with_capacity(max as usize + 1);
        table.push(0.0); // ln 0! = 0
        let mut acc = 0.0f64;
        for k in 1..=max as u64 {
            acc += (k as f64).ln();
            table.push(acc);
        }
        LogFact { table }
    }

    /// `ln(k!)`.
    #[inline]
    pub fn lf(&self, k: u32) -> f64 {
        self.table[k as usize]
    }

    /// `ln C(n, k)`; requires `k ≤ n ≤ max`.
    #[inline]
    pub fn log_choose(&self, n: u32, k: u32) -> f64 {
        debug_assert!(k <= n);
        self.lf(n) - self.lf(k) - self.lf(n - k)
    }

    /// Largest argument the table supports.
    pub fn max(&self) -> u32 {
        (self.table.len() - 1) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_factorials_exact() {
        let t = LogFact::new(10);
        assert!((t.lf(0) - 0.0).abs() < 1e-12);
        assert!((t.lf(1) - 0.0).abs() < 1e-12);
        assert!((t.lf(5) - 120f64.ln()).abs() < 1e-10);
        assert!((t.lf(10) - 3628800f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn log_choose_matches_pascal() {
        let t = LogFact::new(30);
        for n in 0..=30u32 {
            let mut row = vec![1u128];
            for _ in 0..n {
                let mut next = vec![1u128];
                for w in row.windows(2) {
                    next.push(w[0] + w[1]);
                }
                next.push(1);
                row = next;
            }
            for (k, &c) in row.iter().enumerate() {
                let got = t.log_choose(n, k as u32);
                let want = (c as f64).ln();
                assert!((got - want).abs() < 1e-9, "C({n},{k})");
            }
        }
    }

    #[test]
    fn max_reports_capacity() {
        assert_eq!(LogFact::new(100).max(), 100);
    }
}
