//! Synthetic workload generators (the data substitution — DESIGN.md §2).
//!
//! The paper's genotype matrices (HapMap, Alzheimer GWAS) are
//! restricted-access; these generators reproduce the *shape statistics*
//! that drive the miner — item count, transaction count, density, class
//! balance, minor-allele-frequency spectrum, linkage-disequilibrium-style
//! item correlation, and planted significant combinations — so tree shape
//! and protocol behaviour match the paper's regimes.

pub mod gwas;
pub mod mcf7;

pub use gwas::{generate_gwas, GeneticModel, GwasSpec};
pub use mcf7::{generate_mcf7_like, Mcf7Spec};
