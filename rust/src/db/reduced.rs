//! Reduced (projected) conditional databases — the miner's hot path.
//!
//! The paper's §4.6 position is that dense GWAS matrices want plain bitmap
//! AND + popcount and no database reduction. That is true near the root,
//! but LCM's FIM-competition lineage wins deep in the tree by *projection*:
//! once a node `P` is fixed, only the transactions containing `P`, and the
//! items still frequent inside that denotation, can influence any
//! descendant. [`ConditionalDb`] is that projection, rebuilt per expansion
//! (nodes stay shippable as bare itemsets — paper §4.1 — so nothing here
//! crosses the wire):
//!
//! 1. **Row projection & remapping** — the transactions of `occ(P)` are
//!    renumbered to the dense range `0..sup(P)`.
//! 2. **Infrequent-item pruning** — only items `i > core(P)`, `i ∉ P`,
//!    with `sup(P ∪ i) ≥ min_sup` are kept. A pruned item can neither
//!    extend `P` nor contain any descendant's occurrence (containment
//!    would force its projected support above the threshold), so it
//!    vanishes from every PPC and closure check.
//! 3. **Identical-row merging** — rows with the same kept-item signature
//!    collapse into one weighted row; true supports are recovered from
//!    the [`row weights`](ConditionalDb::row_weights).
//! 4. **Adaptive encoding** — kept occurrences are stored as dense
//!    [`BitVec`]s over merged rows or as sorted sparse row-id lists,
//!    whichever the projection's density favors (the switch rule is
//!    documented in DESIGN.md §8 and exposed as [`ConditionalDb::is_dense`]).
//!
//! Kept items also carry a frequency order ([`ConditionalDb::candidates`],
//! [`ConditionalDb::ppc_closure`]): a candidate's containment pass only
//! ever touches items of projected support ≥ its own, so the pass length
//! shrinks with the candidate's frequency instead of scanning all items.
//!
//! `lcm::expand` consumes this type for every node, which is how the
//! serial miner, the thread engine, the discrete-event engine, and the
//! process engine all inherit the reduced hot path unchanged.

use std::collections::HashMap;

use crate::bits::{sparse_subset_of, words_for, BitVec};
use crate::db::{Database, Item};

/// Reusable intermediate buffers for [`ConditionalDb::project_where_with`].
///
/// A projection is built for *every* tree-node expansion; the expansion
/// scratch (`lcm::ExpandScratch`) owns one of these so the rank prefix,
/// the extracted row-list CSR, the inverted arena, and the grouping
/// vectors keep their capacity across millions of nodes instead of
/// reallocating each time. Only the projection's *outputs* (kept
/// columns, supports, weights), which the returned [`ConditionalDb`]
/// owns, and the transient row-grouping hash map are freshly allocated.
#[derive(Default)]
pub struct ProjectScratch {
    rank: Vec<u32>,
    /// Item-major CSR of the extracted row lists: kept item `k`'s rows
    /// live at `flat[flat_off[k]..flat_off[k + 1]]`.
    flat: Vec<u32>,
    flat_off: Vec<usize>,
    deg: Vec<u32>,
    off: Vec<usize>,
    cursor: Vec<usize>,
    arena: Vec<u32>,
    reps: Vec<u32>,
}

/// Occurrence storage for the kept items, chosen by projected density.
#[derive(Clone, Debug)]
enum Cols {
    /// One bitmap over merged rows per kept item.
    Dense(Vec<BitVec>),
    /// One strictly-ascending merged-row-id list per kept item.
    Sparse(Vec<Vec<u32>>),
}

/// The conditional database of one search node: the occurrence of every
/// surviving candidate item, projected onto `occ(P)`, with identical rows
/// merged into weighted rows.
///
/// # Examples
///
/// Conditioning the tiny database below on `P = {1}` keeps only the items
/// that are still frequent among the transactions containing item 1, and
/// merges transactions that became indistinguishable inside the
/// projection:
///
/// ```
/// use parlamp::db::{ConditionalDb, Database};
///
/// let trans = vec![vec![0, 1, 2], vec![0, 1], vec![1, 2], vec![0, 1], vec![3]];
/// let db = Database::from_transactions(4, &trans, &[true, false, false, false, false]);
///
/// let occ = db.occurrence(&[1]);
/// let cond = ConditionalDb::project(&db, &occ, &[1], -1, 2);
///
/// assert_eq!(cond.total_weight(), 4); // sup({1})
/// // Projected supports are exactly sup({1} ∪ {i}); item 3 (support 0
/// // inside the projection) is pruned.
/// assert_eq!(cond.kept_items(), &[(0, 3), (2, 2)]);
/// // Transactions {0,1} and {0,1} are identical inside the projection
/// // and merge into one row of weight 2.
/// assert_eq!(cond.rows(), 3);
/// assert_eq!(cond.row_weights().iter().sum::<u32>(), 4);
/// ```
#[derive(Clone, Debug)]
pub struct ConditionalDb {
    /// Kept items, ascending by original id: `(original id, sup(P ∪ i))`.
    items: Vec<(Item, u32)>,
    /// Kept indices sorted by descending projected support (ties broken by
    /// ascending original id) — the frequency order of the checks.
    by_desc: Vec<u32>,
    /// Merged row count.
    rows: usize,
    /// Multiplicity of each merged row; sums to `sup(P)`.
    weights: Vec<u32>,
    cols: Cols,
    scanned: u64,
    build_ops: u64,
}

impl ConditionalDb {
    /// Project `db` onto the node `(members, core)` whose occurrence
    /// bitmap is `occ`: scan the candidate range `core+1..n_items`, prune
    /// items with projected support < `min_sup`, merge identical rows,
    /// and pick the occurrence encoding.
    ///
    /// `members` must be the node's sorted itemset and `occ` its
    /// occurrence bitmap (`core = -1` for the root).
    pub fn project(
        db: &Database,
        occ: &BitVec,
        members: &[Item],
        core: i64,
        min_sup: u32,
    ) -> ConditionalDb {
        Self::project_where(db, occ, members, core, min_sup, |_| true)
    }

    /// [`ConditionalDb::project`] restricted to candidate-range items
    /// accepted by `scan`. Used by the depth-1 preprocess partition
    /// (paper §4.5): each rank only extracts its own `i mod P = r` slice,
    /// so the aggregate projection work over the fleet stays `O(m)`
    /// instead of `O(P·m)`. Items outside `scan` are absent from the
    /// projection entirely — callers that still need them for containment
    /// checks must fall back to full-width columns (as `lcm::expand`
    /// does).
    pub fn project_where(
        db: &Database,
        occ: &BitVec,
        members: &[Item],
        core: i64,
        min_sup: u32,
        scan: impl Fn(Item) -> bool,
    ) -> ConditionalDb {
        Self::project_where_with(db, occ, members, core, min_sup, scan, &mut Default::default())
    }

    /// [`ConditionalDb::project_where`] with caller-owned intermediate
    /// buffers — the hot-path entry point (`lcm::expand` threads its
    /// [`ProjectScratch`] through here once per node).
    pub fn project_where_with(
        db: &Database,
        occ: &BitVec,
        members: &[Item],
        core: i64,
        min_sup: u32,
        scan: impl Fn(Item) -> bool,
        scratch: &mut ProjectScratch,
    ) -> ConditionalDb {
        let ProjectScratch { rank, flat, flat_off, deg, off, cursor, arena, reps } = scratch;
        let min_sup = min_sup.max(1) as usize;
        let occ_w = occ.words();
        let mut build_ops = occ_w.len() as u64; // rank-prefix construction
        // rank[w] = number of set bits of `occ` strictly before word `w`,
        // turning a transaction id into its projected row id in O(1).
        rank.clear();
        let mut acc = 0u32;
        for w in occ_w {
            rank.push(acc);
            acc += w.count_ones();
        }
        let s = acc as usize; // sup(P): the projected row universe

        // Steps 1+2: extract each candidate-range item's projected row
        // list, pruning infrequent items immediately. The list length is
        // the *true* support sup(P ∪ i): rows are still one-per-
        // transaction here.
        let start = (core + 1).max(0) as usize;
        let n_items = db.n_items();
        let mut items: Vec<(Item, u32)> = Vec::new();
        let mut scanned = 0u64;
        let mut mi = members.partition_point(|&m| (m as usize) < start);
        flat.clear();
        flat_off.clear();
        flat_off.push(0);
        for i in start..n_items {
            if mi < members.len() && members[mi] as usize == i {
                mi += 1;
                continue;
            }
            if !scan(i as Item) {
                continue;
            }
            scanned += 1;
            let mark = flat.len();
            let col_w = db.col(i as Item).words();
            for (w, (&ow, &cw)) in occ_w.iter().zip(col_w).enumerate() {
                let mut x = ow & cw;
                while x != 0 {
                    let b = x.trailing_zeros();
                    flat.push(rank[w] + (ow & ((1u64 << b) - 1)).count_ones());
                    x &= x - 1;
                }
            }
            let len = flat.len() - mark;
            build_ops += occ_w.len() as u64 + len as u64 / 16;
            if len >= min_sup {
                items.push((i as Item, len as u32));
                flat_off.push(flat.len());
            } else {
                flat.truncate(mark); // infrequent: discard its rows in place
            }
        }
        let kept = items.len();

        // Step 3: merge identical rows. Invert the kept columns into a
        // row → kept-item CSR arena (each row's signature is ascending by
        // construction), then group rows by signature. Merged ids are
        // assigned in first-seen row order, so the layout is deterministic
        // regardless of the hasher.
        let total_ones: usize = flat.len();
        deg.clear();
        deg.resize(s, 0);
        for &r in flat.iter() {
            deg[r as usize] += 1;
        }
        off.clear();
        let mut sum = 0usize;
        for &d in deg.iter() {
            off.push(sum);
            sum += d as usize;
        }
        off.push(sum);
        cursor.clear();
        cursor.extend_from_slice(&off[..s]);
        arena.clear();
        arena.resize(total_ones, 0);
        for k in 0..kept {
            for &r in &flat[flat_off[k]..flat_off[k + 1]] {
                arena[cursor[r as usize]] = k as u32;
                cursor[r as usize] += 1;
            }
        }
        reps.clear();
        let mut weights: Vec<u32> = Vec::new();
        {
            let mut groups: HashMap<&[u32], u32> = HashMap::new();
            for r in 0..s {
                let sig = &arena[off[r]..off[r + 1]];
                let id = *groups.entry(sig).or_insert_with(|| {
                    reps.push(r as u32);
                    weights.push(0);
                    (reps.len() - 1) as u32
                });
                weights[id as usize] += 1;
            }
        }
        build_ops += s as u64 + total_ones as u64 / 8;

        // Step 4: re-encode the kept columns over merged rows, from each
        // representative row's signature (ascending ids come for free).
        let rows = reps.len();
        let merged_ones: usize =
            reps.iter().map(|&r| off[r as usize + 1] - off[r as usize]).sum();
        let dense = Self::choose_dense(rows, kept, merged_ones);
        let cols = if dense {
            let mut cols: Vec<BitVec> = (0..kept).map(|_| BitVec::zeros(rows)).collect();
            for (m, &r) in reps.iter().enumerate() {
                for &k in &arena[off[r as usize]..off[r as usize + 1]] {
                    cols[k as usize].set(m, true);
                }
            }
            build_ops += kept as u64 * words_for(rows) as u64 / 8 + merged_ones as u64 / 16;
            Cols::Dense(cols)
        } else {
            let mut cols: Vec<Vec<u32>> = vec![Vec::new(); kept];
            for (m, &r) in reps.iter().enumerate() {
                for &k in &arena[off[r as usize]..off[r as usize + 1]] {
                    cols[k as usize].push(m as u32);
                }
            }
            build_ops += merged_ones as u64 / 16;
            Cols::Sparse(cols)
        };

        let mut by_desc: Vec<u32> = (0..kept as u32).collect();
        by_desc.sort_unstable_by(|&a, &b| {
            let (ia, sa) = items[a as usize];
            let (ib, sb) = items[b as usize];
            sb.cmp(&sa).then(ia.cmp(&ib))
        });
        build_ops += kept as u64;

        ConditionalDb { items, by_desc, rows, weights, cols, scanned, build_ops }
    }

    /// Encoding switch rule (DESIGN.md §8): dense when the merged row
    /// space fits in ≤ 8 words anyway, or when kept columns average at
    /// least one set bit per 32 rows — one sparse `u32` entry costs half
    /// a dense `u64` word, so 2 entries per word is the break-even.
    fn choose_dense(rows: usize, kept: usize, ones: usize) -> bool {
        rows <= 512 || ones * 32 >= rows * kept.max(1)
    }

    /// Kept items ascending by original id, as `(original id, sup(P ∪ i))`.
    pub fn kept_items(&self) -> &[(Item, u32)] {
        &self.items
    }

    /// `(original id, projected support)` of kept item `k`.
    #[inline]
    pub fn item(&self, k: usize) -> (Item, u32) {
        self.items[k]
    }

    /// Kept indices in ascending projected-support order — a deterministic
    /// candidate iteration order for the expansion loop. Per-candidate
    /// cost does not depend on this order (each
    /// [`ConditionalDb::ppc_closure`] pass is independent); the frequency
    /// order that *does* cut work is the descending walk inside that pass.
    pub fn candidates(&self) -> impl Iterator<Item = usize> + '_ {
        self.by_desc.iter().rev().map(|&k| k as usize)
    }

    /// Number of merged (weighted) rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Multiplicity of each merged row; sums to the node's support.
    pub fn row_weights(&self) -> &[u32] {
        &self.weights
    }

    /// Sum of the row weights, i.e. `sup(P)`.
    pub fn total_weight(&self) -> u32 {
        self.weights.iter().sum()
    }

    /// `true` when the dense bitmap encoding was chosen.
    pub fn is_dense(&self) -> bool {
        matches!(self.cols, Cols::Dense(_))
    }

    /// Items scanned in the candidate range (kept + pruned).
    pub fn scanned(&self) -> u64 {
        self.scanned
    }

    /// Construction cost in word-op equivalents (DESIGN.md §8), charged
    /// to `ExpandStats::reduce_ops` by the expansion loop.
    pub fn build_ops(&self) -> u64 {
        self.build_ops
    }

    /// Does kept item `sub`'s occurrence lie inside kept item `sup`'s?
    /// Charges the check's cost model to `ops` (dense scans early-exit and
    /// are charged 1 word like the full-width scans they replace; sparse
    /// merge scans are charged by length).
    #[inline]
    fn contains(&self, sub: usize, sup: usize, ops: &mut u64) -> bool {
        match &self.cols {
            Cols::Dense(c) => {
                *ops += 1;
                c[sub].is_subset_of(&c[sup])
            }
            Cols::Sparse(c) => {
                let (a, b) = (&c[sub], &c[sup]);
                *ops += 1 + (a.len() + b.len()) as u64 / 16;
                sparse_subset_of(a, b)
            }
        }
    }

    /// One frequency-ordered PPC + closure pass for kept candidate `k`
    /// (paper §2.1 on the reduced representation): every kept item whose
    /// projected support is ≥ the candidate's is tested for containment of
    /// the candidate's occurrence. A container with a *smaller* original
    /// id is a prefix-preservation violation (`false` is returned, the
    /// candidate generates no child); containers with larger ids are the
    /// closure completion and are pushed onto `closure` as original ids.
    ///
    /// Items below the support cut cannot contain the candidate (weights
    /// are positive, so containment implies support ≥ the candidate's)
    /// and are never touched — this is what the frequency order buys.
    pub fn ppc_closure(&self, k: usize, closure: &mut Vec<Item>, ops: &mut u64) -> bool {
        let (orig, sup) = self.items[k];
        for &j in &self.by_desc {
            let j = j as usize;
            let (jorig, jsup) = self.items[j];
            if jsup < sup {
                break;
            }
            if j == k {
                continue;
            }
            if self.contains(k, j, ops) {
                if jorig < orig {
                    return false;
                }
                closure.push(jorig);
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, m: usize, n: usize, density: f64) -> Database {
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t % 3 == 0).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    /// Reference projected support computed the slow way.
    fn slow_sup(db: &Database, members: &[Item], i: Item) -> u32 {
        let mut set: Vec<Item> = members.to_vec();
        set.push(i);
        db.support(&set)
    }

    #[test]
    fn kept_supports_match_database() {
        forall("projected supports == db.support(P ∪ i)", 48, |rng| {
            let db = random_db(rng, 3 + rng.index(6), 4 + rng.index(20), 0.2 + rng.f64() * 0.5);
            // Condition on a random single frequent item (or the root).
            let members: Vec<Item> = if rng.bernoulli(0.5) {
                vec![rng.index(db.n_items()) as Item]
            } else {
                Vec::new()
            };
            let core: i64 = if members.is_empty() { -1 } else { members[0] as i64 };
            let occ = db.occurrence(&members);
            let min_sup = 1 + rng.below(2) as u32;
            let cond = ConditionalDb::project(&db, &occ, &members, core, min_sup);
            for &(i, sup) in cond.kept_items() {
                if sup != slow_sup(&db, &members, i) {
                    return Err(format!("item {i}: got {sup}"));
                }
                if sup < min_sup {
                    return Err(format!("item {i} kept below min_sup"));
                }
                if (i as i64) <= core {
                    return Err(format!("item {i} outside candidate range"));
                }
            }
            // Pruning is complete: every range item outside P with support
            // ≥ min_sup is kept.
            for i in (core + 1).max(0) as usize..db.n_items() {
                let i = i as Item;
                if members.contains(&i) {
                    continue;
                }
                let sup = slow_sup(&db, &members, i);
                let kept = cond.kept_items().iter().any(|&(j, _)| j == i);
                if (sup >= min_sup) != kept {
                    return Err(format!("item {i} sup={sup} kept={kept}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn weights_sum_to_support_and_merging_collapses_duplicates() {
        // Four copies of the same transaction plus one distinct one.
        let trans = vec![vec![0, 1], vec![0, 1], vec![0, 1], vec![0, 1], vec![1, 2]];
        let db = Database::from_transactions(3, &trans, &[true; 5]);
        let occ = db.occurrence(&[1]);
        let cond = ConditionalDb::project(&db, &occ, &[1], -1, 1);
        assert_eq!(cond.total_weight(), 5);
        assert_eq!(cond.rows(), 2, "identical projected rows must merge");
        let mut w = cond.row_weights().to_vec();
        w.sort_unstable();
        assert_eq!(w, vec![1, 4]);
    }

    #[test]
    fn encoding_follows_switch_rule() {
        let mut rng = Rng::new(42);
        // Small row space → dense regardless of density.
        let small = random_db(&mut rng, 6, 40, 0.1);
        let occ = BitVec::ones(small.n_trans());
        assert!(ConditionalDb::project(&small, &occ, &[], -1, 1).is_dense());
        // Tall sparse projection (rows > 512, ones per column ≪ rows/32)
        // → sparse id lists. Distinct singleton rows avoid merging.
        let n = 700usize;
        let m = 40usize;
        let trans: Vec<Vec<Item>> = (0..n).map(|t| vec![(t % m) as Item]).collect();
        let tall = Database::from_transactions(m, &trans, &vec![false; n]);
        let occ = BitVec::ones(n);
        let cond = ConditionalDb::project(&tall, &occ, &[], -1, 1);
        assert!(cond.rows() > 512, "rows={}", cond.rows());
        assert!(!cond.is_dense());
        assert_eq!(cond.kept_items().len(), m);
    }

    #[test]
    fn dense_and_sparse_agree_on_ppc_closure() {
        // The same logical projection, checked through both encodings:
        // replicate each base pattern with a distinct tag item so the row
        // space crosses the switch threshold while the subset structure of
        // the low items is unchanged.
        let m = 5usize;
        let base: Vec<Vec<Item>> = (0..10)
            .map(|t| (0..m as Item).filter(|&i| (7 * t + 3 * i as usize) % 5 < 2).collect())
            .collect();
        let mk = |copies: usize| {
            let trans: Vec<Vec<Item>> = base
                .iter()
                .flat_map(|t| {
                    (0..copies).map(move |c| {
                        let mut t = t.clone();
                        t.push((m + c) as Item);
                        t
                    })
                })
                .collect();
            let n = trans.len();
            Database::from_transactions(m + copies, &trans, &vec![false; n])
        };
        let small = mk(1);
        let big = mk(199); // 5 distinct patterns × 199 tags = 995 rows, sparse
        let occ_s = BitVec::ones(small.n_trans());
        let occ_b = BitVec::ones(big.n_trans());
        let cs = ConditionalDb::project(&small, &occ_s, &[], -1, 1);
        let cb = ConditionalDb::project(&big, &occ_b, &[], -1, 1);
        assert!(cs.is_dense());
        assert!(!cb.is_dense(), "rows={} must pick the sparse encoding", cb.rows());
        // PPC/closure outcomes on the shared low items must agree exactly.
        let mut ops = 0u64;
        for k in 0..m {
            let find =
                |c: &ConditionalDb| c.kept_items().iter().position(|&(i, _)| i == k as Item);
            let (Some(ks), Some(kb)) = (find(&cs), find(&cb)) else { continue };
            let (mut close_s, mut close_b) = (Vec::new(), Vec::new());
            let ok_s = cs.ppc_closure(ks, &mut close_s, &mut ops);
            let ok_b = cb.ppc_closure(kb, &mut close_b, &mut ops);
            close_s.retain(|&i| (i as usize) < m);
            close_b.retain(|&i| (i as usize) < m);
            close_s.sort_unstable();
            close_b.sort_unstable();
            assert_eq!(ok_s, ok_b, "item {k}");
            assert_eq!(close_s, close_b, "item {k}");
        }
        assert!(ops > 0);
    }

    #[test]
    fn empty_and_degenerate_projections() {
        let db = Database::from_transactions(2, &[vec![0], vec![1]], &[true, false]);
        // min_sup above every support → nothing kept.
        let occ = BitVec::ones(2);
        let cond = ConditionalDb::project(&db, &occ, &[], -1, 5);
        assert!(cond.kept_items().is_empty());
        assert_eq!(cond.scanned(), 2);
        assert_eq!(cond.candidates().count(), 0);
        // Empty occurrence → zero rows, nothing kept.
        let empty = BitVec::zeros(2);
        let cond = ConditionalDb::project(&db, &empty, &[], -1, 1);
        assert_eq!(cond.rows(), 0);
        assert!(cond.kept_items().is_empty());
        assert!(cond.build_ops() > 0);
    }

    #[test]
    fn candidate_order_is_ascending_support() {
        let mut rng = Rng::new(11);
        let db = random_db(&mut rng, 8, 30, 0.4);
        let occ = BitVec::ones(db.n_trans());
        let cond = ConditionalDb::project(&db, &occ, &[], -1, 1);
        let sups: Vec<u32> = cond.candidates().map(|k| cond.item(k).1).collect();
        for w in sups.windows(2) {
            assert!(w[0] <= w[1], "candidates must come least-frequent first");
        }
    }
}
