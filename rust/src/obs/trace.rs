//! Per-rank event tracer: a fixed-capacity ring behind a static flag.
//!
//! Tracing must be free when off: every hook site is
//! `if enabled() { … }` where [`enabled`] is one relaxed atomic load —
//! no allocation, no formatting, no I/O on the hot path. When on, events
//! are recorded into a bounded [`TraceRing`] (keep-first: once full,
//! further events increment [`TraceRing::dropped`] instead of evicting
//! history — the interesting part of a mining run is usually its start,
//! and a counted drop is honest where a silently rotated ring is not).
//!
//! Timestamps are nanoseconds on the *recording process's* monotonic
//! clock; [`crate::obs::clock`] aligns them into one fleet-wide timeline
//! after collection. Under the sim engine the "clock" is DES virtual
//! time, which makes event sequences exactly reproducible run-to-run.

use std::sync::atomic::{AtomicBool, Ordering};

/// Process-global trace switch. Off by default; flipped once at startup
/// (CLI `--trace`, or by `worker_main` from the received `PhaseSpec`).
static TRACE_ON: AtomicBool = AtomicBool::new(false);

/// Is tracing enabled? One relaxed load — the only cost paid when off.
#[inline]
pub fn enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Flip the global trace switch. Callers flip it once at startup, before
/// workers are built; flipping mid-run merely starts/stops recording.
pub fn set_enabled(on: bool) {
    TRACE_ON.store(on, Ordering::Relaxed);
}

/// Default per-rank ring capacity (events). At 64 Ki events × ~24 bytes
/// this bounds a rank's trace memory to ~1.5 MiB.
pub const DEFAULT_RING_CAP: usize = 64 * 1024;

/// What happened. All variants are fixed-size and `Copy`; the wire
/// encoding lives in `wire::trace` and must cover every variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A phase began on this rank (phase = 1/2/3, epoch = hub replay epoch).
    PhaseStart { phase: u8, epoch: u64 },
    /// The phase's merge was produced.
    PhaseEnd { phase: u8, epoch: u64 },
    /// A batch of search nodes was expanded between polls.
    ExpandBatch { units: u64 },
    /// This rank asked `dst` for work (`lifeline` = hypercube edge).
    StealRequest { dst: u32, lifeline: bool },
    /// `src` asked us and we had nothing to give.
    StealReject { src: u32, lifeline: bool },
    /// We shipped `tasks` stack roots to `dst`.
    StealGive { dst: u32, tasks: u32 },
    /// `src` shipped us `tasks` stack roots.
    StealRecv { src: u32, tasks: u32 },
    /// A DTD wave token arrived (t = wave id, up = WaveUp vs WaveDown).
    WaveArrive { t: u32, up: bool },
    /// A custody CHECKPOINT beacon was sent to the hub.
    Checkpoint { units: u64, roots: u32 },
    /// The hub respawned `rank` and fenced a replay under `epoch`.
    Respawn { rank: u32, epoch: u64 },
    /// Service: job queued.
    ServeQueue { job: u64 },
    /// Service: job popped by a fleet runner.
    ServePop { job: u64 },
    /// Service: job expired before running.
    ServeExpire { job: u64 },
    /// Hub: `rank` missed its heartbeat lease during `epoch` — hung,
    /// partitioned, or livelocked with its socket still open (v8,
    /// DESIGN.md §15). A `ForceKill` + `Respawn` pair follows.
    LeaseMiss { rank: u32, epoch: u64 },
    /// Hub: `rank` was force-killed after its lease expired; the PR-7
    /// respawn + epoch-fenced replay path takes over from here.
    ForceKill { rank: u32, epoch: u64 },
}

/// One timestamped event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Nanoseconds on the recorder's monotonic (or DES virtual) clock.
    pub t_ns: u64,
    pub kind: EventKind,
}

/// Fixed-capacity keep-first event buffer with a counted overflow.
#[derive(Debug, Clone)]
pub struct TraceRing {
    cap: usize,
    events: Vec<TraceEvent>,
    /// Events rejected because the ring was full. Reported, never silent.
    pub dropped: u64,
}

impl TraceRing {
    pub fn new(cap: usize) -> Self {
        // Don't pre-reserve `cap`: a quiet rank should not pin ~1.5 MiB.
        TraceRing { cap, events: Vec::new(), dropped: 0 }
    }

    pub fn with_default_cap() -> Self {
        Self::new(DEFAULT_RING_CAP)
    }

    /// Record one event, or count it as dropped if the ring is full.
    #[inline]
    pub fn push(&mut self, t_ns: u64, kind: EventKind) {
        if self.events.len() < self.cap {
            self.events.push(TraceEvent { t_ns, kind });
        } else {
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    /// Drain the ring into its parts `(events, dropped)` for flushing.
    pub fn take(&mut self) -> (Vec<TraceEvent>, u64) {
        let dropped = self.dropped;
        self.dropped = 0;
        (std::mem::take(&mut self.events), dropped)
    }
}

/// One rank's assembled timeline, clock-aligned into hub time.
///
/// `offset_ns` is *added* to each event's `t_ns` to place it on the hub
/// clock; in-process engines share one clock, so their offset is 0 with
/// zero uncertainty.
#[derive(Debug, Clone)]
pub struct RankTrace {
    pub rank: u32,
    /// Estimated hub-clock minus rank-clock, in ns (may be negative).
    pub offset_ns: i64,
    /// Half-width of the offset interval: ± bound on alignment error.
    pub uncertainty_ns: u64,
    /// Events dropped by the rank's ring (overflow), summed over phases.
    pub dropped: u64,
    pub events: Vec<TraceEvent>,
}

impl RankTrace {
    /// An event's timestamp translated onto the hub clock (saturating:
    /// a clock estimated slightly behind the hub epoch clamps to 0).
    pub fn aligned_ns(&self, e: &TraceEvent) -> u64 {
        let t = e.t_ns as i64 + self.offset_ns;
        t.max(0) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flag_defaults_off_and_toggles() {
        // Note: this test mutates process-global state; integration tests
        // that flip the flag live in tests/trace.rs (their own process).
        assert!(!enabled() || enabled()); // no assumption about other tests
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn ring_keeps_first_and_counts_overflow() {
        let mut r = TraceRing::new(3);
        for i in 0..5u64 {
            r.push(i, EventKind::ExpandBatch { units: i });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped, 2);
        // Keep-first: the survivors are the earliest events.
        assert_eq!(r.events()[0].t_ns, 0);
        assert_eq!(r.events()[2].t_ns, 2);
        let (ev, dropped) = r.take();
        assert_eq!(ev.len(), 3);
        assert_eq!(dropped, 2);
        assert!(r.is_empty());
        assert_eq!(r.dropped, 0);
    }

    #[test]
    fn aligned_ns_applies_signed_offset_and_clamps() {
        let rt = RankTrace {
            rank: 1,
            offset_ns: -100,
            uncertainty_ns: 5,
            dropped: 0,
            events: vec![],
        };
        let early = TraceEvent { t_ns: 40, kind: EventKind::ExpandBatch { units: 1 } };
        let late = TraceEvent { t_ns: 400, kind: EventKind::ExpandBatch { units: 1 } };
        assert_eq!(rt.aligned_ns(&early), 0); // clamped
        assert_eq!(rt.aligned_ns(&late), 300);
    }
}
