//! One-sided Fisher's exact test (paper §3.1).
//!
//! For an itemset `I` with total frequency `x = x(I)` and positive-class
//! frequency `n = n(I)` under marginals `(N, N_pos)`:
//!
//! ```text
//!            min{x, N_pos}   C(N_pos, n_i) · C(N − N_pos, x − n_i)
//! P(I)  =        Σ           ───────────────────────────────────────
//!            n_i = n(I)                   C(N, x)
//! ```
//!
//! i.e. the upper tail of the hypergeometric distribution at the observed
//! positive count. Evaluated in log space with a numerically stable
//! log-sum-exp over the (short) tail.

use super::{LogFact, Marginals};

/// Fisher exact-test evaluator bound to fixed marginals.
#[derive(Clone, Debug)]
pub struct FisherTable {
    m: Marginals,
    lf: LogFact,
}

impl FisherTable {
    pub fn new(m: Marginals) -> Self {
        let lf = LogFact::new(m.n);
        FisherTable { m, lf }
    }

    pub fn marginals(&self) -> Marginals {
        self.m
    }

    /// log-PMF of the hypergeometric: probability that exactly `k` of the
    /// `x` transactions containing the itemset are positive.
    #[inline]
    fn log_pmf(&self, x: u32, k: u32) -> f64 {
        let Marginals { n, n_pos } = self.m;
        debug_assert!(k <= x && k <= n_pos && x - k <= n - n_pos);
        self.lf.log_choose(n_pos, k) + self.lf.log_choose(n - n_pos, x - k)
            - self.lf.log_choose(n, x)
    }

    /// One-sided (enrichment in positives) P-value: `P[H ≥ n_obs]` for
    /// `H ~ Hypergeom(N, N_pos, x)`.
    ///
    /// Returns 1.0 when `n_obs` is at or below the distribution's lower
    /// support limit; 0-probability cells are handled by the summation
    /// bounds rather than `-inf` logs.
    pub fn p_value(&self, x: u32, n_obs: u32) -> f64 {
        self.log_p_value(x, n_obs).exp()
    }

    /// `ln P(I)`; preferred for comparisons against tiny thresholds.
    pub fn log_p_value(&self, x: u32, n_obs: u32) -> f64 {
        let Marginals { n, n_pos } = self.m;
        assert!(x <= n, "x={x} > N={n}");
        assert!(n_obs <= x, "n(I)={n_obs} > x(I)={x}");
        let hi = x.min(n_pos);
        // Lower support limit: x - k ≤ N - N_pos  ⇒  k ≥ x - (N - N_pos).
        let lo_support = x.saturating_sub(n - n_pos);
        let lo = n_obs.max(lo_support);
        if n_obs <= lo_support {
            return 0.0; // tail covers the whole support ⇒ P = 1
        }
        // log-sum-exp over k = lo ..= hi, anchored at the largest term.
        let mut max_lp = f64::NEG_INFINITY;
        let mut lps = Vec::with_capacity((hi - lo + 1) as usize);
        for k in lo..=hi {
            let lp = self.log_pmf(x, k);
            max_lp = max_lp.max(lp);
            lps.push(lp);
        }
        if lps.is_empty() || max_lp == f64::NEG_INFINITY {
            return f64::NEG_INFINITY; // empty tail ⇒ P = 0 (cannot happen for valid inputs)
        }
        let sum: f64 = lps.iter().map(|lp| (lp - max_lp).exp()).sum();
        // Clamp at ln 1: rounding can push the full-tail sum epsilon above 1.
        (max_lp + sum.ln()).min(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    /// Oracle values precomputed with scipy.stats.hypergeom.sf(n-1, N, Npos, x).
    const ORACLE: &[(u32, u32, u32, u32, f64)] = &[
        (10, 5, 4, 3, 0.2619047619047619),
        (100, 20, 10, 6, 0.003933076466791354),
        (697, 105, 8, 7, 1.036502823205562e-05),
        (364, 176, 30, 25, 4.303547201354027e-05),
        (50, 25, 50, 25, 1.0),
        (697, 105, 1, 1, 0.15064562410329987),
        (364, 176, 18, 18, 1.3008679821704796e-06),
    ];

    #[test]
    fn matches_scipy_oracle() {
        for &(n, npos, x, nobs, want) in ORACLE {
            let f = FisherTable::new(Marginals::new(n, npos));
            let got = f.p_value(x, nobs);
            assert!(
                (got - want).abs() / want.max(1e-300) < 1e-9,
                "N={n} Npos={npos} x={x} n={nobs}: got {got:e} want {want:e}"
            );
        }
    }

    #[test]
    fn full_tail_is_one() {
        let f = FisherTable::new(Marginals::new(30, 12));
        // n_obs at the lower support limit ⇒ tail covers everything ⇒ P = 1
        assert!((f.p_value(5, 0) - 1.0).abs() < 1e-12);
        // x > N - N_pos forces a positive lower limit
        assert!((f.p_value(25, 7) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pmf_sums_to_one() {
        let f = FisherTable::new(Marginals::new(40, 15));
        for x in [1u32, 5, 17, 40] {
            let lo = x.saturating_sub(40 - 15);
            let hi = x.min(15);
            let total: f64 = (lo..=hi).map(|k| f.log_pmf(x, k).exp()).sum();
            assert!((total - 1.0).abs() < 1e-10, "x={x} total={total}");
        }
    }

    #[test]
    fn monotone_decreasing_in_observed_count() {
        forall("P(x, n) decreasing in n", 64, |rng| {
            let n = 10 + rng.below(200) as u32;
            let npos = 1 + rng.below(n as u64 - 1) as u32;
            let f = FisherTable::new(Marginals::new(n, npos));
            let x = 1 + rng.below(n as u64) as u32;
            let mut prev = f64::INFINITY;
            for nobs in 0..=x.min(npos) {
                let p = f.p_value(x, nobs);
                if p > prev + 1e-12 {
                    return Err(format!("N={n} Npos={npos} x={x} n={nobs}: {p} > {prev}"));
                }
                prev = p;
            }
            Ok(())
        });
    }

    #[test]
    fn log_p_consistent_with_p() {
        let f = FisherTable::new(Marginals::new(120, 37));
        for (x, nobs) in [(10, 8), (50, 20), (3, 3)] {
            let lp = f.log_p_value(x, nobs);
            let p = f.p_value(x, nobs);
            assert!((lp.exp() - p).abs() < 1e-12);
            assert!(lp <= 1e-12, "log p must be ≤ 0, got {lp}");
        }
    }
}
