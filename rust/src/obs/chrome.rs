//! Chrome/Perfetto trace-event JSON export.
//!
//! Emits the classic `{"traceEvents": [...]}` document understood by
//! `chrome://tracing` and <https://ui.perfetto.dev>: one process (pid 0,
//! "parlamp fleet"), one thread track per rank, complete (`ph:"X"`) spans
//! for phases, instant (`ph:"i"`) events for everything punctual, and
//! flow arrows (`ph:"s"` → `ph:"f"`) linking each steal REQUEST to the
//! GIVE that answered it — the visual form of the paper's Fig. 5/6
//! work-distribution argument.
//!
//! Timestamps are the rank timelines aligned onto the hub clock
//! ([`RankTrace::aligned_ns`]), expressed in microseconds as the format
//! requires. The hub/service's own events ride a synthetic track,
//! [`HUB_RANK`]. Ring overflow is surfaced as a per-rank `dropped`
//! instant plus a top-level `otherData` note — never silently absent.

use crate::obs::trace::{EventKind, RankTrace, TraceEvent};
use std::collections::HashMap;

/// Synthetic `tid` for the hub / service timeline track.
pub const HUB_RANK: u32 = u32::MAX;

fn track_name(rank: u32) -> String {
    if rank == HUB_RANK {
        "hub".to_string()
    } else {
        format!("rank {rank}")
    }
}

fn ts_us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1000.0)
}

/// Export aligned rank timelines as a Chrome trace-event JSON document.
pub fn export(traces: &[RankTrace]) -> String {
    let mut ev: Vec<String> = Vec::new();

    // Track metadata: stable names for every tid.
    ev.push(
        r#"{"name":"process_name","ph":"M","pid":0,"tid":0,"args":{"name":"parlamp fleet"}}"#
            .to_string(),
    );
    for t in traces {
        ev.push(format!(
            r#"{{"name":"thread_name","ph":"M","pid":0,"tid":{},"args":{{"name":"{}"}}}}"#,
            t.rank,
            track_name(t.rank)
        ));
    }

    // Merge all events into one hub-clock order so steal flow matching
    // (request on the thief, give on the victim) sees them causally.
    let mut merged: Vec<(u64, u32, &TraceEvent)> = Vec::new();
    for t in traces {
        for e in &t.events {
            merged.push((t.aligned_ns(e), t.rank, e));
        }
    }
    merged.sort_by_key(|(ts, rank, _)| (*ts, *rank));
    let end_ns = merged.last().map(|(ts, _, _)| *ts).unwrap_or(0);

    // Open phase spans per rank, pending steal flows per (thief, victim).
    let mut open: HashMap<u32, Vec<(u8, u64, u64)>> = HashMap::new();
    let mut flows: HashMap<(u32, u32), Vec<u64>> = HashMap::new();
    let mut next_flow: u64 = 1;

    for (ts, rank, e) in &merged {
        let (ts, rank) = (*ts, *rank);
        match e.kind {
            EventKind::PhaseStart { phase, epoch } => {
                open.entry(rank).or_default().push((phase, epoch, ts));
            }
            EventKind::PhaseEnd { phase, epoch } => {
                let stack = open.entry(rank).or_default();
                if let Some(i) = stack.iter().rposition(|&(p, ep, _)| p == phase && ep == epoch)
                {
                    let (_, _, t0) = stack.remove(i);
                    ev.push(span(rank, phase, epoch, t0, ts));
                }
            }
            EventKind::ExpandBatch { units } => {
                ev.push(instant(rank, ts, "expand", "work", &format!(r#""units":{units}"#)));
            }
            EventKind::StealRequest { dst, lifeline } => {
                let id = next_flow;
                next_flow += 1;
                flows.entry((rank, dst)).or_default().push(id);
                ev.push(instant(
                    rank,
                    ts,
                    "steal.request",
                    "steal",
                    &format!(r#""dst":{dst},"lifeline":{lifeline}"#),
                ));
                ev.push(flow(rank, ts, "s", "", id));
            }
            EventKind::StealGive { dst, tasks } => {
                ev.push(instant(
                    rank,
                    ts,
                    "steal.give",
                    "steal",
                    &format!(r#""dst":{dst},"tasks":{tasks}"#),
                ));
                // The oldest outstanding request from `dst` to us is the
                // one this GIVE answers (per-pair channels are FIFO).
                if let Some(ids) = flows.get_mut(&(dst, rank)) {
                    if !ids.is_empty() {
                        let id = ids.remove(0);
                        ev.push(flow(rank, ts, "f", r#","bp":"e""#, id));
                    }
                }
            }
            EventKind::StealReject { src, lifeline } => {
                ev.push(instant(
                    rank,
                    ts,
                    "steal.reject",
                    "steal",
                    &format!(r#""src":{src},"lifeline":{lifeline}"#),
                ));
            }
            EventKind::StealRecv { src, tasks } => {
                ev.push(instant(
                    rank,
                    ts,
                    "steal.recv",
                    "steal",
                    &format!(r#""src":{src},"tasks":{tasks}"#),
                ));
            }
            EventKind::WaveArrive { t, up } => {
                ev.push(instant(
                    rank,
                    ts,
                    "dtd.wave",
                    "dtd",
                    &format!(r#""t":{t},"up":{up}"#),
                ));
            }
            EventKind::Checkpoint { units, roots } => {
                ev.push(instant(
                    rank,
                    ts,
                    "checkpoint",
                    "fault",
                    &format!(r#""units":{units},"roots":{roots}"#),
                ));
            }
            EventKind::Respawn { rank: dead, epoch } => {
                ev.push(instant(
                    rank,
                    ts,
                    "respawn",
                    "fault",
                    &format!(r#""rank":{dead},"epoch":{epoch}"#),
                ));
            }
            EventKind::ServeQueue { job } => {
                ev.push(instant(rank, ts, "serve.queue", "serve", &format!(r#""job":{job}"#)));
            }
            EventKind::ServePop { job } => {
                ev.push(instant(rank, ts, "serve.pop", "serve", &format!(r#""job":{job}"#)));
            }
            EventKind::ServeExpire { job } => {
                ev.push(instant(rank, ts, "serve.expire", "serve", &format!(r#""job":{job}"#)));
            }
            EventKind::LeaseMiss { rank: dead, epoch } => {
                ev.push(instant(
                    rank,
                    ts,
                    "lease.miss",
                    "fault",
                    &format!(r#""rank":{dead},"epoch":{epoch}"#),
                ));
            }
            EventKind::ForceKill { rank: dead, epoch } => {
                ev.push(instant(
                    rank,
                    ts,
                    "force.kill",
                    "fault",
                    &format!(r#""rank":{dead},"epoch":{epoch}"#),
                ));
            }
        }
    }

    // A phase whose end never arrived (ring overflow, dead rank) still
    // renders: close it at the trace horizon.
    let mut ranks: Vec<u32> = open.keys().copied().collect();
    ranks.sort_unstable();
    for rank in ranks {
        for &(phase, epoch, t0) in &open[&rank] {
            ev.push(span(rank, phase, epoch, t0, end_ns.max(t0)));
        }
    }

    // Surface overflow on the affected track.
    let mut total_dropped: u64 = 0;
    for t in traces {
        if t.dropped > 0 {
            total_dropped += t.dropped;
            ev.push(instant(
                t.rank,
                end_ns,
                "trace.dropped",
                "meta",
                &format!(r#""dropped":{}"#, t.dropped),
            ));
        }
    }

    let mut out = String::new();
    out.push_str("{\"traceEvents\":[\n");
    for (i, e) in ev.iter().enumerate() {
        out.push_str(e);
        if i + 1 < ev.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str(&format!(
        "],\"displayTimeUnit\":\"ms\",\"otherData\":\
         {{\"generator\":\"parlamp\",\"dropped_events\":{total_dropped}}}}}\n"
    ));
    out
}

/// A flow event on the steal track: `ph:"s"` at the request, `ph:"f"`
/// (with `bp:"e"` in `extra`) at the give that answers it.
fn flow(rank: u32, ts_ns: u64, ph: &str, extra: &str, id: u64) -> String {
    format!(
        concat!(
            r#"{{"name":"steal","cat":"steal","ph":"{ph}"{extra},"#,
            r#""id":{id},"ts":{ts},"pid":0,"tid":{rank}}}"#
        ),
        ph = ph,
        extra = extra,
        id = id,
        ts = ts_us(ts_ns),
        rank = rank,
    )
}

fn span(rank: u32, phase: u8, epoch: u64, t0_ns: u64, t1_ns: u64) -> String {
    let dur_ns = t1_ns.saturating_sub(t0_ns);
    format!(
        concat!(
            r#"{{"name":"phase{phase}","cat":"phase","ph":"X","ts":{ts},"dur":{dur},"#,
            r#""pid":0,"tid":{rank},"args":{{"epoch":{epoch}}}}}"#
        ),
        phase = phase,
        ts = ts_us(t0_ns),
        dur = ts_us(dur_ns),
        rank = rank,
        epoch = epoch,
    )
}

fn instant(rank: u32, ts_ns: u64, name: &str, cat: &str, args: &str) -> String {
    format!(
        concat!(
            r#"{{"name":"{name}","cat":"{cat}","ph":"i","s":"t","ts":{ts},"#,
            r#""pid":0,"tid":{rank},"args":{{{args}}}}}"#
        ),
        name = name,
        cat = cat,
        ts = ts_us(ts_ns),
        rank = rank,
        args = args,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::TraceEvent;

    fn rt(rank: u32, events: Vec<TraceEvent>) -> RankTrace {
        RankTrace { rank, offset_ns: 0, uncertainty_ns: 0, dropped: 0, events }
    }

    fn e(t_ns: u64, kind: EventKind) -> TraceEvent {
        TraceEvent { t_ns, kind }
    }

    #[test]
    fn export_is_valid_json_with_spans_and_flows() {
        let thief = rt(
            0,
            vec![
                e(0, EventKind::PhaseStart { phase: 1, epoch: 0 }),
                e(100, EventKind::StealRequest { dst: 1, lifeline: true }),
                e(900, EventKind::StealRecv { src: 1, tasks: 4 }),
                e(2_000, EventKind::PhaseEnd { phase: 1, epoch: 0 }),
            ],
        );
        let victim = rt(
            1,
            vec![
                e(0, EventKind::PhaseStart { phase: 1, epoch: 0 }),
                e(500, EventKind::StealGive { dst: 0, tasks: 4 }),
                e(2_000, EventKind::PhaseEnd { phase: 1, epoch: 0 }),
            ],
        );
        let json = export(&[thief, victim]);

        // Structurally valid (the bench harness ships a JSON parser).
        crate::bench::report::parse_json(&json).expect("exported trace must parse as JSON");

        // One phase span per rank, one matched flow pair.
        assert_eq!(json.matches(r#""ph":"X""#).count(), 2);
        assert_eq!(json.matches(r#""ph":"s""#).count(), 1);
        assert_eq!(json.matches(r#""ph":"f""#).count(), 1);
        assert!(json.contains(r#""name":"phase1""#));
        assert!(json.contains(r#""name":"rank 0""#));
        assert!(json.contains(r#""name":"rank 1""#));
    }

    #[test]
    fn unmatched_phase_start_closes_at_horizon() {
        let t = rt(
            0,
            vec![
                e(10, EventKind::PhaseStart { phase: 2, epoch: 3 }),
                e(50, EventKind::ExpandBatch { units: 9 }),
            ],
        );
        let json = export(&[t]);
        crate::bench::report::parse_json(&json).unwrap();
        assert_eq!(json.matches(r#""ph":"X""#).count(), 1);
        assert!(json.contains(r#""name":"phase2""#));
    }

    #[test]
    fn dropped_events_are_reported() {
        let mut t = rt(5, vec![e(1, EventKind::ExpandBatch { units: 1 })]);
        t.dropped = 7;
        let json = export(&[t]);
        crate::bench::report::parse_json(&json).unwrap();
        assert!(json.contains(r#""name":"trace.dropped""#));
        assert!(json.contains(r#""dropped_events":7"#));
    }

    #[test]
    fn hub_track_is_named() {
        let t = rt(HUB_RANK, vec![e(5, EventKind::ServeQueue { job: 1 })]);
        let json = export(&[t]);
        crate::bench::report::parse_json(&json).unwrap();
        assert!(json.contains(r#""name":"hub""#));
        assert!(json.contains(r#""name":"serve.queue""#));
    }

    #[test]
    fn lease_events_render_on_the_fault_category() {
        // A stalled rank's lease expiry shows up on the hub track as a
        // lease.miss / force.kill pair next to the respawn it causes.
        let t = rt(
            HUB_RANK,
            vec![
                e(10, EventKind::LeaseMiss { rank: 1, epoch: 4 }),
                e(20, EventKind::ForceKill { rank: 1, epoch: 4 }),
                e(30, EventKind::Respawn { rank: 1, epoch: 5 }),
            ],
        );
        let json = export(&[t]);
        crate::bench::report::parse_json(&json).unwrap();
        assert!(json.contains(r#""name":"lease.miss""#));
        assert!(json.contains(r#""name":"force.kill""#));
        assert_eq!(json.matches(r#""cat":"fault""#).count(), 3);
        assert!(json.contains(r#""rank":1,"epoch":4"#));
    }
}
