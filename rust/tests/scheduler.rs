//! Model-based test suite for the daemon's weighted-fair queue
//! (`service::queue`, DESIGN.md §13).
//!
//! A reference model reimplements the scheduler's contract with the most
//! naive data structures that can express it — scan-everything selection,
//! no incremental bookkeeping — and hundreds of randomized traces of
//! submit / cancel / complete / expire / dispatch operations drive the
//! real queue and the model in lockstep, asserting every observable
//! return value and gauge agrees at every step. On top of the
//! equivalence, the traces assert the scheduler's headline guarantees
//! directly:
//!
//! - **fairness / no starvation**: no client ever holds more than its
//!   slot cap of the pool, and under a greedy backlog a newly-arrived
//!   client is served within one scheduling round;
//! - **priority ordering**: within one client, a drain dispatches in
//!   (priority desc, submission seq asc) order — FIFO within a class;
//! - **deadline expiry**: a job whose deadline has passed is reported by
//!   `expire` and is never dispatched, while deadline-free jobs and jobs
//!   at exactly their deadline instant survive.
//!
//! Failures replay deterministically: the harness prints the case seed
//! (`PROPCHECK_SEED`), and the trace is a pure function of it.

use std::collections::BTreeMap;

use parlamp::service::{Busy, ClientDepth, FairQueue, QueueLimits};
use parlamp::util::propcheck::{forall, forall_sized};
use parlamp::util::rng::Rng;

/// Virtual-time charge per dispatch at weight 1 — must match the
/// scheduler's constant (the model is useless if it models a different
/// currency).
const SCALE: u64 = 1 << 20;

// ---- the reference model ---------------------------------------------------

#[derive(Clone, Debug)]
struct MEntry {
    id: u64,
    priority: u8,
    deadline_at: Option<u64>,
    seq: u64,
}

#[derive(Clone, Debug, Default)]
struct MClient {
    pending: Vec<MEntry>,
    active: usize,
    vtime: u64,
    weight: u32,
}

impl MClient {
    fn idle(&self) -> bool {
        self.pending.is_empty() && self.active == 0
    }
}

/// The naive scan-everything reference scheduler.
#[derive(Clone, Debug)]
struct Model {
    limits: QueueLimits,
    clients: BTreeMap<String, MClient>,
    seq: u64,
}

impl Model {
    fn new(limits: QueueLimits) -> Model {
        Model { limits, clients: BTreeMap::new(), seq: 0 }
    }

    fn set_weight(&mut self, client: &str, weight: u32) {
        self.clients.entry(client.to_string()).or_default().weight = weight.max(1);
    }

    fn len(&self) -> usize {
        self.clients.values().map(|c| c.pending.len()).sum()
    }

    fn active_total(&self) -> usize {
        self.clients.values().map(|c| c.active).sum()
    }

    fn push(
        &mut self,
        client: &str,
        id: u64,
        priority: u8,
        deadline_ms: u64,
        now_ms: u64,
    ) -> Result<(), Busy> {
        if self.len() >= self.limits.global_queued {
            return Err(Busy::Global { queued: self.len(), cap: self.limits.global_queued });
        }
        let queued = self.clients.get(client).map_or(0, |c| c.pending.len());
        if queued >= self.limits.per_client_queued {
            return Err(Busy::Client { queued, cap: self.limits.per_client_queued });
        }
        let floor = self
            .clients
            .iter()
            .filter(|(name, c)| name.as_str() != client && !c.idle())
            .map(|(_, c)| c.vtime)
            .min();
        let state = self.clients.entry(client.to_string()).or_default();
        if state.idle() {
            if let Some(floor) = floor {
                state.vtime = state.vtime.max(floor);
            }
        }
        state.pending.push(MEntry {
            id,
            priority,
            deadline_at: (deadline_ms > 0).then(|| now_ms.saturating_add(deadline_ms)),
            seq: self.seq,
        });
        self.seq += 1;
        Ok(())
    }

    fn expire(&mut self, now_ms: u64) -> Vec<u64> {
        let mut out = Vec::new();
        for c in self.clients.values_mut() {
            let (dead, live): (Vec<MEntry>, Vec<MEntry>) = c
                .pending
                .drain(..)
                .partition(|e| e.deadline_at.is_some_and(|at| now_ms > at));
            out.extend(dead.into_iter().map(|e| e.id));
            c.pending = live;
        }
        out.sort_unstable();
        out
    }

    fn pop(&mut self) -> Option<u64> {
        let winner = self
            .clients
            .iter()
            .filter(|(_, c)| {
                !c.pending.is_empty() && c.active < self.limits.per_client_active
            })
            .min_by_key(|(name, c)| (c.vtime, name.clone()))
            .map(|(name, _)| name.clone())?;
        let c = self.clients.get_mut(&winner).expect("winner exists");
        let mut best = 0;
        for i in 1..c.pending.len() {
            let (a, b) = (&c.pending[i], &c.pending[best]);
            if a.priority > b.priority || (a.priority == b.priority && a.seq < b.seq) {
                best = i;
            }
        }
        let entry = c.pending.remove(best);
        c.active += 1;
        c.vtime += SCALE / u64::from(c.weight.max(1));
        Some(entry.id)
    }

    fn complete(&mut self, client: &str) {
        if let Some(c) = self.clients.get_mut(client) {
            c.active = c.active.saturating_sub(1);
        }
    }

    fn cancel(&mut self, id: u64) -> bool {
        for c in self.clients.values_mut() {
            if let Some(i) = c.pending.iter().position(|e| e.id == id) {
                c.pending.remove(i);
                return true;
            }
        }
        false
    }

    fn position(&self, id: u64) -> Option<usize> {
        let target = self.clients.values().flat_map(|c| c.pending.iter()).find(|e| e.id == id)?;
        Some(
            self.clients
                .values()
                .flat_map(|c| c.pending.iter())
                .filter(|e| {
                    e.priority > target.priority
                        || (e.priority == target.priority && e.seq < target.seq)
                })
                .count(),
        )
    }

    fn depths(&self) -> Vec<ClientDepth> {
        self.clients
            .iter()
            .map(|(name, c)| ClientDepth {
                client: name.clone(),
                queued: c.pending.len(),
                active: c.active,
            })
            .collect()
    }

    /// The deadline a pending id carries (for never-dispatched-late checks).
    fn deadline_of(&self, id: u64) -> Option<u64> {
        self.clients
            .values()
            .flat_map(|c| c.pending.iter())
            .find(|e| e.id == id)
            .and_then(|e| e.deadline_at)
    }

    /// Which client owns a pending id.
    fn owner_of(&self, id: u64) -> Option<String> {
        self.clients
            .iter()
            .find(|(_, c)| c.pending.iter().any(|e| e.id == id))
            .map(|(name, _)| name.clone())
    }
}

// ---- lockstep driver -------------------------------------------------------

const CLIENT_NAMES: [&str; 3] = ["ada", "bob", "cyd"];

/// Compare every observable gauge of queue vs model.
fn check_gauges(q: &FairQueue, m: &Model, step: usize) -> Result<(), String> {
    if q.len() != m.len() {
        return Err(format!("step {step}: len {} vs model {}", q.len(), m.len()));
    }
    if q.is_empty() != (m.len() == 0) {
        return Err(format!("step {step}: is_empty disagrees"));
    }
    if q.active_total() != m.active_total() {
        return Err(format!(
            "step {step}: active_total {} vs model {}",
            q.active_total(),
            m.active_total()
        ));
    }
    let (qd, md) = (q.depths(), m.depths());
    if qd != md {
        return Err(format!("step {step}: depths {qd:?} vs model {md:?}"));
    }
    // Invariant: the slot cap holds for everyone, always.
    for d in &qd {
        if d.active > m.limits.per_client_active {
            return Err(format!(
                "step {step}: client {} holds {} slots, cap {}",
                d.client, d.active, m.limits.per_client_active
            ));
        }
    }
    Ok(())
}

/// One randomized trace: drive queue and model in lockstep, then drain to
/// empty mirroring the daemon's expire-before-pop discipline.
fn run_trace(rng: &mut Rng, steps: usize) -> Result<(), String> {
    let limits = QueueLimits {
        per_client_queued: rng.range(1, 4) as usize,
        global_queued: rng.range(2, 8) as usize,
        per_client_active: rng.range(1, 3) as usize,
    };
    let mut q = FairQueue::new(limits);
    let mut m = Model::new(limits);
    for name in CLIENT_NAMES {
        if rng.bernoulli(0.5) {
            let w = rng.range(1, 3) as u32;
            q.set_weight(name, w);
            m.set_weight(name, w);
        }
    }

    let mut now: u64 = 0;
    let mut next_id: u64 = 1;
    let mut live: Vec<u64> = Vec::new(); // queued ids (model-tracked)

    for step in 0..steps {
        now += rng.below(40);
        match rng.below(10) {
            // submit (weighted to keep the queue busy)
            0..=4 => {
                let client = rng.choose(&CLIENT_NAMES);
                let id = next_id;
                let priority = rng.below(4) as u8;
                let deadline_ms = if rng.bernoulli(0.3) { rng.range(1, 60) } else { 0 };
                let got = q.push(client, id, priority, deadline_ms, now);
                let want = m.push(client, id, priority, deadline_ms, now);
                if got != want {
                    return Err(format!("step {step}: push({client},{id}) {got:?} vs {want:?}"));
                }
                if got.is_ok() {
                    live.push(id);
                    next_id += 1;
                }
            }
            // dispatch, mirroring the daemon: expire first, then pop
            5..=6 => {
                let got_exp = q.expire(now);
                let want_exp = m.expire(now);
                if got_exp != want_exp {
                    return Err(format!("step {step}: expire {got_exp:?} vs {want_exp:?}"));
                }
                live.retain(|id| !got_exp.contains(id));
                // After expire(now), nothing pending may be past deadline.
                if let Some(id) = live.iter().find(|id| {
                    m.deadline_of(**id).is_some_and(|at| now > at)
                }) {
                    return Err(format!("step {step}: job {id} survived its deadline"));
                }
                let got = q.pop();
                let want = m.pop();
                if got != want {
                    return Err(format!("step {step}: pop {got:?} vs model {want:?}"));
                }
                if let Some(id) = got {
                    live.retain(|x| *x != id);
                }
            }
            // release a slot
            7 => {
                let client = rng.choose(&CLIENT_NAMES);
                q.complete(client);
                m.complete(client);
            }
            // cancel a live or bogus id
            8 => {
                let id = if !live.is_empty() && rng.bernoulli(0.8) {
                    live[rng.index(live.len())]
                } else {
                    next_id + 100 // unknown
                };
                let got = q.cancel(id);
                let want = m.cancel(id);
                if got != want {
                    return Err(format!("step {step}: cancel({id}) {got} vs model {want}"));
                }
                live.retain(|x| *x != id);
            }
            // position probe
            _ => {
                if !live.is_empty() {
                    let id = live[rng.index(live.len())];
                    let (got, want) = (q.position(id), m.position(id));
                    if got != want {
                        return Err(format!(
                            "step {step}: position({id}) {got:?} vs model {want:?}"
                        ));
                    }
                }
            }
        }
        check_gauges(&q, &m, step)?;
    }

    // Drain: the daemon's steady-state loop — expire, pop, complete —
    // until both agree the queue is empty. Must terminate: with all
    // slots free, any pending client is eligible.
    let mut guard = 0;
    loop {
        guard += 1;
        if guard > 10_000 {
            return Err("drain did not terminate".into());
        }
        now += 1;
        let (ge, we) = (q.expire(now), m.expire(now));
        if ge != we {
            return Err(format!("drain: expire {ge:?} vs model {we:?}"));
        }
        // Snapshot ownership before the pops remove the entry.
        let pre = m.clone();
        match (q.pop(), m.pop()) {
            (got, want) if got != want => {
                return Err(format!("drain: pop {got:?} vs model {want:?}"));
            }
            (Some(id), _) => {
                // Return the slot immediately, as the daemon does when the
                // job finishes.
                let owner = pre.owner_of(id).ok_or("popped id unknown to the model")?;
                q.complete(&owner);
                m.complete(&owner);
            }
            (None, _) => {
                if q.is_empty() && m.len() == 0 {
                    break;
                }
                // Pending but nobody eligible: free every slot and retry.
                for name in CLIENT_NAMES {
                    q.complete(name);
                    m.complete(name);
                }
            }
        }
        check_gauges(&q, &m, usize::MAX)?;
    }
    Ok(())
}

#[test]
fn randomized_traces_match_reference_model() {
    // ≥ 500 independent traces, ramping from short to long histories.
    forall_sized("fair queue matches reference model", 512, |rng, case| {
        let steps = 20 + (case as usize % 8) * 15; // 20..125 ops
        run_trace(rng, steps)
    });
}

// ---- targeted guarantees on top of the equivalence -------------------------

#[test]
fn no_starvation_while_another_client_is_saturated() {
    // A greedy client with a deep backlog never locks out a late arrival:
    // once `meek` submits, it is dispatched within one scheduling round
    // (its job is among the next 2 pops), for any slot cap.
    forall("greedy client cannot starve a newcomer", 64, |rng| {
        let cap = rng.range(1, 3) as usize;
        let mut q = FairQueue::new(QueueLimits {
            per_client_queued: 64,
            global_queued: 256,
            per_client_active: cap,
        });
        for id in 1..=20u64 {
            q.push("greedy", id, 1, 0, 0).map_err(|e| e.to_string())?;
        }
        // Let greedy run for a random while (completing as it goes, so it
        // is never capped and keeps the pool saturated).
        for _ in 0..rng.below(10) {
            if q.pop().is_some() {
                q.complete("greedy");
            }
        }
        q.push("meek", 999, 1, 0, 0).map_err(|e| e.to_string())?;
        for _ in 0..2 {
            match q.pop() {
                Some(999) => return Ok(()),
                Some(_) => q.complete("greedy"),
                None => return Err("pool stalled with work pending".into()),
            }
        }
        Err("meek's job was not dispatched within one round".into())
    });
}

#[test]
fn drain_order_is_priority_desc_then_fifo_within_class() {
    // Single client ⇒ fairness is irrelevant and the dispatch order must
    // be exactly (priority desc, submission order asc).
    forall("priority classes drain FIFO", 128, |rng| {
        let mut q = FairQueue::new(QueueLimits {
            per_client_queued: 64,
            global_queued: 256,
            per_client_active: 1,
        });
        let n = rng.range(2, 24);
        let mut jobs: Vec<(u64, u8)> = Vec::new(); // (id, priority) in submit order
        for id in 1..=n {
            let priority = rng.below(3) as u8;
            q.push("solo", id, priority, 0, 0).map_err(|e| e.to_string())?;
            jobs.push((id, priority));
        }
        let mut order = Vec::new();
        while let Some(id) = q.pop() {
            order.push(id);
            q.complete("solo");
        }
        let mut want = jobs.clone();
        // Stable sort keeps submission order within a priority class.
        want.sort_by_key(|(_, p)| std::cmp::Reverse(*p));
        let want: Vec<u64> = want.into_iter().map(|(id, _)| id).collect();
        if order != want {
            return Err(format!("dispatched {order:?}, want {want:?} from {jobs:?}"));
        }
        Ok(())
    });
}

#[test]
fn expired_jobs_are_reported_and_never_dispatched() {
    forall("deadlines partition the queue exactly", 128, |rng| {
        let mut q = FairQueue::new(QueueLimits {
            per_client_queued: 64,
            global_queued: 256,
            per_client_active: 8,
        });
        let submit_at = 1_000u64;
        let check_at = submit_at + rng.range(0, 120);
        let n = rng.range(1, 16);
        let mut doomed = Vec::new();
        let mut safe = Vec::new();
        for id in 1..=n {
            let deadline_ms = if rng.bernoulli(0.5) { rng.range(1, 100) } else { 0 };
            q.push("c", id, 1, deadline_ms, submit_at).map_err(|e| e.to_string())?;
            // Strict: the deadline instant itself is still servable.
            if deadline_ms > 0 && check_at > submit_at + deadline_ms {
                doomed.push(id);
            } else {
                safe.push(id);
            }
        }
        let expired = q.expire(check_at);
        if expired != doomed {
            return Err(format!("expire -> {expired:?}, want {doomed:?}"));
        }
        let mut served = Vec::new();
        while let Some(id) = q.pop() {
            served.push(id);
            q.complete("c"); // free the slot so the cap never stalls the drain
        }
        served.sort_unstable();
        if served != safe {
            return Err(format!("dispatched {served:?}, want exactly {safe:?}"));
        }
        Ok(())
    });
}

#[test]
fn weights_split_service_proportionally() {
    // Deterministic: weight 2 vs weight 1, both with deep backlogs and a
    // free-slot pool — over any window the heavy client gets 2 of every
    // 3 dispatches.
    let mut q = FairQueue::new(QueueLimits {
        per_client_queued: 64,
        global_queued: 256,
        per_client_active: 64,
    });
    q.set_weight("heavy", 2);
    q.set_weight("light", 1);
    for id in 1..=30u64 {
        q.push("heavy", id, 1, 0, 0).unwrap();
        q.push("light", 100 + id, 1, 0, 0).unwrap();
    }
    let first_12: Vec<u64> = (0..12).filter_map(|_| q.pop()).collect();
    let heavy = first_12.iter().filter(|id| **id <= 30).count();
    assert_eq!(heavy, 8, "weight 2:1 must yield a 2:1 dispatch split, got {first_12:?}");
}
