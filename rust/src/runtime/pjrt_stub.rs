//! Stub PJRT runtime, compiled when the `xla` cargo feature is disabled.
//!
//! Mirrors the public surface of the real `pjrt` module (same types, same
//! signatures) so every call site — `runtime::screen`, the CLI, the
//! coordinator, benches, tests — compiles identically with or without the
//! feature. [`XlaRuntime::load`] always fails with an explanatory error;
//! since loading is the only way to obtain an `XlaRuntime`, the remaining
//! methods are unreachable in practice but still return honest errors.

use std::path::Path;

use anyhow::{bail, Result};

use crate::bits::BitVec;
use crate::stats::Marginals;

use super::manifest::Manifest;

/// Stand-in for the compiled screen executable. Never constructible in
/// stub builds: [`XlaRuntime::load`] is the sole constructor and it always
/// returns an error.
pub struct XlaRuntime {
    manifest: Manifest,
}

/// Statistics for one screened candidate row (same layout as the real
/// runtime's output).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScreenOut {
    pub x: i32,
    pub n: i32,
    pub logp: f64,
    pub logf: f64,
}

const UNAVAILABLE: &str = "XLA/PJRT backend not compiled into this binary \
     (build with `--features xla` and a vendored `xla` crate); \
     the native Fisher screen is the supported offline path";

impl XlaRuntime {
    /// Validate the artifact directory, then report that no PJRT backend is
    /// available. Checking the manifest first keeps the two failure modes
    /// distinguishable: "artifacts missing/corrupt" vs "backend not built".
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let _ = Manifest::load(dir)?;
        bail!(UNAVAILABLE)
    }

    pub fn manifest(&self) -> Manifest {
        self.manifest
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// See the real runtime's `screen_batch`; always errors in stub builds.
    pub fn screen_batch(&self, _rows: &[&BitVec], _m: Marginals) -> Result<Vec<ScreenOut>> {
        bail!(UNAVAILABLE)
    }

    /// See the real runtime's `screen_batch_with_pos`; always errors in
    /// stub builds.
    pub fn screen_batch_with_pos(
        &self,
        _rows: &[&BitVec],
        _pos_mask: &BitVec,
        _m: Marginals,
    ) -> Result<Vec<ScreenOut>> {
        bail!(UNAVAILABLE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_distinguishes_missing_artifacts_from_missing_backend() {
        let dir = std::env::temp_dir().join(format!("parlamp_stub_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // No manifest at all: the error is about the artifacts.
        let e = XlaRuntime::load(&dir).unwrap_err();
        assert!(!format!("{e:#}").contains("not compiled"), "{e:#}");
        // Valid manifest but stub build: the error is about the backend.
        std::fs::write(dir.join("manifest.json"), r#"{"k": 8, "w": 2, "t_max": 16}"#).unwrap();
        let e = XlaRuntime::load(&dir).unwrap_err();
        assert!(format!("{e:#}").contains("not compiled"), "{e:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
