//! Client side of the `parlamp serve` protocol: connect, speak frames,
//! surface typed results. Used by the `parlamp submit|status|results|
//! cancel|stats|shutdown` subcommands and by the integration tests.

use anyhow::{bail, Context, Result};

use crate::net::{dial, Endpoint, RetryPolicy, Stream};
use crate::wire::service::{JobOutcome, JobSpec, JobState, ServiceStats};
use crate::wire::{read_frame, write_frame, Frame};

/// One connection to a running daemon. A connection can carry any number
/// of requests; each request is one frame out, one frame back.
pub struct Client {
    stream: Stream,
}

impl Client {
    /// Connect to the daemon listening at `ep` — Unix path or TCP
    /// host:port, through the one [`dial`] retry/timeout path (DESIGN.md
    /// §11).
    pub fn connect(ep: &Endpoint) -> Result<Client> {
        let stream = dial(ep, &RetryPolicy::default()).with_context(|| {
            format!("connect to parlamp daemon at {ep} (is `parlamp serve` running?)")
        })?;
        Ok(Client { stream })
    }

    fn call(&mut self, frame: &Frame) -> Result<Frame> {
        write_frame(&mut self.stream, frame)
            .with_context(|| format!("send {} to daemon", frame.name()))?;
        read_frame(&mut self.stream)?.context("daemon closed the connection without replying")
    }

    /// Submit a job; returns the assigned job id. A daemon at its
    /// admission bounds replies with a `STATUS` carrying
    /// [`JobState::Busy`]; that (and any other rejection, e.g. a deadline
    /// already impossible or a draining daemon) surfaces here as an error
    /// rendering the typed state.
    pub fn submit(&mut self, spec: JobSpec) -> Result<u64> {
        match self.call(&Frame::Submit(Box::new(spec)))? {
            Frame::Accepted { job_id } => Ok(job_id),
            Frame::Status { report: Some(state), .. } => {
                bail!("daemon rejected the submission: {state}")
            }
            other => bail!("expected ACCEPTED from daemon, got {}", other.name()),
        }
    }

    /// Query a job's lifecycle state.
    pub fn status(&mut self, job_id: u64) -> Result<JobState> {
        match self.call(&Frame::Status { job_id, report: None })? {
            Frame::Status { job_id: got, report: Some(state) } if got == job_id => Ok(state),
            other => bail!("expected STATUS report from daemon, got {}", other.name()),
        }
    }

    /// Fetch a job's outcome. The daemon blocks the reply until the job is
    /// terminal, so this call waits with it; a job that failed, was
    /// cancelled, or is unknown surfaces as an error carrying its state.
    pub fn results(&mut self, job_id: u64) -> Result<JobOutcome> {
        match self.call(&Frame::JobResult { job_id, report: None })? {
            Frame::JobResult { job_id: got, report: Some(outcome) } if got == job_id => {
                Ok(*outcome)
            }
            Frame::Status { report: Some(state), .. } => {
                bail!("job {job_id} has no results: {state}")
            }
            other => bail!("expected RESULT from daemon, got {}", other.name()),
        }
    }

    /// Remove a pending job from the queue; returns the job's state after
    /// the attempt (`Cancelled` iff it was still pending).
    pub fn cancel(&mut self, job_id: u64) -> Result<JobState> {
        match self.call(&Frame::Cancel { job_id })? {
            Frame::Status { job_id: got, report: Some(state) } if got == job_id => Ok(state),
            other => bail!("expected STATUS report from daemon, got {}", other.name()),
        }
    }

    /// Fetch the daemon's operational counters: per-fleet utilization,
    /// per-client queue depths, cache/store counters, latency histograms.
    pub fn stats(&mut self) -> Result<ServiceStats> {
        match self.call(&Frame::Stats { report: None })? {
            Frame::Stats { report: Some(stats) } => Ok(*stats),
            other => bail!("expected STATS report from daemon, got {}", other.name()),
        }
    }

    /// Ask the daemon to drain its queue and exit. Returns once the daemon
    /// acknowledged (it may still be draining; wait on process exit or
    /// socket removal for full teardown).
    pub fn shutdown(&mut self) -> Result<()> {
        match self.call(&Frame::Shutdown)? {
            Frame::Shutdown => Ok(()),
            other => bail!("expected SHUTDOWN ack from daemon, got {}", other.name()),
        }
    }
}
