//! Leveled, target-filtered, rank/fleet/job-tagged structured logging.
//!
//! Replaces the ad-hoc `eprintln!` diagnostics that had accreted across
//! the fleet supervisor, service daemon, and coordinator. Every record
//! carries a level, a target (the subsystem: `"fleet"`, `"serve"`,
//! `"store"`, …) and optional rank/fleet/job tags, rendered as one
//! stderr line:
//!
//! ```text
//! parlamp[WARN fleet rank=1] worker rank 1 lost (EOF); respawning rank 1
//! ```
//!
//! Filtering is configured once from `PARLAMP_LOG=level[,target=level]*`
//! (e.g. `PARLAMP_LOG=warn,serve=debug`); the default is `info`. Every
//! record — printed or filtered — is also appended to a small in-process
//! ring, and [`dump_recent`] replays the last records to stderr when a
//! process dies (panic hook, fault injection) or a worker is declared
//! `Gone`, so deaths leave a post-mortem instead of a bare exit code.
//!
//! Discipline: this module is for *cold-path* diagnostics — records are
//! formatted unconditionally (the ring wants them even when filtered).
//! Hot-path visibility belongs in [`crate::obs::trace`], which costs one
//! branch when off.

use std::fmt;
use std::sync::{Mutex, Once, OnceLock, TryLockError};

/// Severity, most severe first (`Error < Warn`, so a record prints when
/// `record_level <= configured_level`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

impl Level {
    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Optional context tags attached to a record.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Tags {
    pub rank: Option<u32>,
    pub fleet: Option<u32>,
    pub job: Option<u64>,
    /// Failure-cause classifier on respawn/failed-job records (v8): one
    /// of the fixed detection classes (`"lease-expiry"`, `"eof"`,
    /// `"corrupt-frame"`, `"watchdog-abort"`, …) so log scrapes can
    /// aggregate *why* ranks die, not just that they did.
    pub cause: Option<&'static str>,
}

impl Tags {
    pub const NONE: Tags = Tags { rank: None, fleet: None, job: None, cause: None };

    pub fn rank(rank: usize) -> Tags {
        Tags { rank: Some(rank as u32), ..Tags::NONE }
    }

    pub fn fleet(fleet: usize) -> Tags {
        Tags { fleet: Some(fleet as u32), ..Tags::NONE }
    }

    pub fn job(job: u64) -> Tags {
        Tags { job: Some(job), ..Tags::NONE }
    }

    pub fn and_rank(mut self, rank: usize) -> Tags {
        self.rank = Some(rank as u32);
        self
    }

    pub fn and_job(mut self, job: u64) -> Tags {
        self.job = Some(job);
        self
    }

    pub fn and_cause(mut self, cause: &'static str) -> Tags {
        self.cause = Some(cause);
        self
    }
}

impl fmt::Display for Tags {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let Some(r) = self.rank {
            write!(f, " rank={r}")?;
        }
        if let Some(fl) = self.fleet {
            write!(f, " fleet={fl}")?;
        }
        if let Some(j) = self.job {
            write!(f, " job={j}")?;
        }
        if let Some(c) = self.cause {
            write!(f, " cause={c}")?;
        }
        Ok(())
    }
}

/// Parsed `PARLAMP_LOG` filter: a default level plus per-target overrides.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Filter {
    default: Level,
    overrides: Vec<(String, Level)>,
}

impl Filter {
    pub fn max_level(&self, target: &str) -> Level {
        self.overrides
            .iter()
            .find(|(t, _)| t == target)
            .map(|(_, l)| *l)
            .unwrap_or(self.default)
    }
}

/// Parse a `level[,target=level]*` spec. Unknown level names and empty
/// parts are ignored; an empty spec yields the default (`info`).
pub fn parse_filter(spec: &str) -> Filter {
    let mut f = Filter { default: Level::Info, overrides: Vec::new() };
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        match part.split_once('=') {
            None => {
                if let Some(l) = Level::parse(part) {
                    f.default = l;
                }
            }
            Some((target, level)) => {
                if let Some(l) = Level::parse(level) {
                    f.overrides.push((target.trim().to_string(), l));
                }
            }
        }
    }
    f
}

fn filter() -> &'static Filter {
    static F: OnceLock<Filter> = OnceLock::new();
    F.get_or_init(|| parse_filter(&std::env::var("PARLAMP_LOG").unwrap_or_default()))
}

/// Would a record at `level` for `target` reach stderr?
pub fn enabled(level: Level, target: &str) -> bool {
    level <= filter().max_level(target)
}

fn format_line(level: Level, target: &str, tags: &Tags, msg: fmt::Arguments<'_>) -> String {
    format!("parlamp[{} {}{}] {}", level.tag(), target, tags, msg)
}

/// Record one diagnostic: always remembered in the post-mortem ring,
/// printed to stderr iff the filter admits it.
pub fn emit(level: Level, target: &str, tags: &Tags, msg: fmt::Arguments<'_>) {
    let line = format_line(level, target, tags, msg);
    remember(&line);
    if enabled(level, target) {
        eprintln!("{line}");
    }
}

pub fn error(target: &str, tags: &Tags, msg: fmt::Arguments<'_>) {
    emit(Level::Error, target, tags, msg);
}

pub fn warn(target: &str, tags: &Tags, msg: fmt::Arguments<'_>) {
    emit(Level::Warn, target, tags, msg);
}

pub fn info(target: &str, tags: &Tags, msg: fmt::Arguments<'_>) {
    emit(Level::Info, target, tags, msg);
}

pub fn debug(target: &str, tags: &Tags, msg: fmt::Arguments<'_>) {
    emit(Level::Debug, target, tags, msg);
}

/// How many records the post-mortem ring retains.
pub const RING_CAP: usize = 128;

struct Ring {
    buf: Vec<String>,
    next: usize,
}

fn ring() -> &'static Mutex<Ring> {
    static R: OnceLock<Mutex<Ring>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Ring { buf: Vec::new(), next: 0 }))
}

fn remember(line: &str) {
    let mut r = match ring().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if r.buf.len() < RING_CAP {
        r.buf.push(line.to_string());
    } else {
        let slot = r.next;
        r.buf[slot] = line.to_string();
    }
    r.next = (r.next + 1) % RING_CAP;
}

/// The retained records, oldest first.
pub fn recent() -> Vec<String> {
    let r = match ring().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    };
    if r.buf.len() < RING_CAP {
        r.buf.clone()
    } else {
        let mut out = Vec::with_capacity(RING_CAP);
        out.extend_from_slice(&r.buf[r.next..]);
        out.extend_from_slice(&r.buf[..r.next]);
        out
    }
}

/// Replay the retained records to stderr, e.g. from a panic hook or just
/// before a fault-injected exit. Uses `try_lock` so a panic raised while
/// the ring lock is held degrades to no dump rather than a deadlock.
pub fn dump_recent(why: &str) {
    let r = match ring().try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(poisoned)) => poisoned.into_inner(),
        Err(TryLockError::WouldBlock) => return,
    };
    if r.buf.is_empty() {
        return;
    }
    let n = r.buf.len();
    eprintln!("parlamp post-mortem ({why}): last {n} log records");
    let order: Vec<&String> = if n < RING_CAP {
        r.buf.iter().collect()
    } else {
        r.buf[r.next..].iter().chain(r.buf[..r.next].iter()).collect()
    };
    for line in order {
        eprintln!("  {line}");
    }
}

/// Chain a panic hook that dumps the log ring after the default report.
/// Idempotent; installed by the CLI entry point and by `worker_main` so
/// a dying worker's stderr carries its recent history.
pub fn install_panic_hook() {
    static ONCE: Once = Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            prev(info);
            dump_recent("panic");
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_order_most_severe_first() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
        assert!(Level::Debug < Level::Trace);
    }

    #[test]
    fn parse_filter_default_and_overrides() {
        let f = parse_filter("");
        assert_eq!(f.max_level("fleet"), Level::Info);

        let f = parse_filter("warn,serve=debug, store=error");
        assert_eq!(f.max_level("fleet"), Level::Warn);
        assert_eq!(f.max_level("serve"), Level::Debug);
        assert_eq!(f.max_level("store"), Level::Error);

        // Unknown levels / garbage parts are ignored, not fatal.
        let f = parse_filter("bogus,fleet=nope,debug");
        assert_eq!(f.max_level("fleet"), Level::Debug);
    }

    #[test]
    fn format_line_carries_level_target_and_tags() {
        let tags = Tags::fleet(2).and_rank(1).and_job(7);
        let line = format_line(Level::Warn, "fleet", &tags, format_args!("lost ({})", "EOF"));
        assert_eq!(line, "parlamp[WARN fleet rank=1 fleet=2 job=7] lost (EOF)");
        let bare = format_line(Level::Info, "serve", &Tags::NONE, format_args!("up"));
        assert_eq!(bare, "parlamp[INFO serve] up");
        let caused = Tags::rank(1).and_cause("lease-expiry");
        let line = format_line(Level::Warn, "fleet", &caused, format_args!("respawning"));
        assert_eq!(line, "parlamp[WARN fleet rank=1 cause=lease-expiry] respawning");
    }

    #[test]
    fn ring_retains_most_recent_in_order() {
        // The ring is process-global and shared with other tests' emits;
        // saturate it with known lines, then check the tail.
        for i in 0..(RING_CAP + 10) {
            remember(&format!("line-{i}"));
        }
        let recent = recent();
        assert_eq!(recent.len(), RING_CAP);
        assert_eq!(recent.last().unwrap(), &format!("line-{}", RING_CAP + 9));
        assert_eq!(recent.first().unwrap(), "line-10");
    }
}
