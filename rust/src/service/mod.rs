//! The serving layer (DESIGN.md §9 and §13): `parlamp` as a long-running
//! mining service instead of a one-shot batch run.
//!
//! Every earlier entry point pays the full startup bill per request —
//! spawn a worker fleet, handshake, ship the database, mine, tear down.
//! The paper's own deployment story is the opposite: a *persistent* set of
//! cores fed work continuously (§4), and the task-parallel literature
//! (PAPERS.md) identifies repeated runtime re-initialization as a dominant
//! cost when mining requests arrive as a stream. This module is where that
//! lives:
//!
//! - [`server::serve`] — the daemon: binds a stream socket (`unix:` or
//!   `tcp:`, DESIGN.md §11), spawns a **pool** of warm worker fleets
//!   ([`pool`], `--fleets N`) once and keeps them warm, schedules queued
//!   jobs onto idle fleets concurrently, and drains gracefully on
//!   `SHUTDOWN` or `SIGTERM`;
//! - [`pool::FleetRunner`] — one fleet plus its rebuild logic: a fleet
//!   poisoned by an unrecoverable failure is rebuilt through the fleet
//!   recovery path (DESIGN.md §12) without draining the pool;
//! - [`queue::FairQueue`] — the weighted-fair queue with per-client
//!   accounting: admission control (typed [`queue::Busy`] rejections),
//!   fairness slot caps, priorities, and deadlines;
//! - [`cache::ResultCache`] — a bounded in-memory LRU keyed by
//!   `(database digest, α, GlbParams, screen mode)`; a repeat submission
//!   is answered without the workers receiving a single frame;
//! - [`store::ResultStore`] — the disk-backed persistent result store
//!   behind the LRU (`--store`): an append-only checksummed record log
//!   that keeps the cache warm across daemon restarts;
//! - [`metrics::Metrics`] — the counters behind the `STATS` frame
//!   (per-fleet utilization, per-client depths, latency histograms);
//! - [`client::Client`] — the typed client the `parlamp
//!   submit|status|results|cancel|stats|shutdown` subcommands drive.
//!
//! The wire grammar of the job frames lives in [`crate::wire::service`];
//! the daemon and its clients share [`crate::wire`]'s framing, bounds
//! checking, and versioning.

pub mod cache;
pub mod client;
pub mod metrics;
pub mod pool;
pub mod queue;
pub mod server;
pub mod store;

pub use cache::{CacheKey, ResultCache};
pub use client::Client;
pub use queue::{Busy, ClientDepth, FairQueue, QueueLimits};
pub use server::{print_join_commands, serve, ServeConfig};
pub use store::ResultStore;
