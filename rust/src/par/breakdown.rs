//! Per-process time breakdown (paper Fig. 7).
//!
//! The paper splits total CPU time into four categories:
//! - **preprocess** — everything up to the depth-1 barrier release (§4.5;
//!   for MCF7 at P ≥ 600 this includes the waiting that dominates Fig. 7),
//! - **main** — node expansion work,
//! - **probe** — message send/receive handling plus stack split/merge,
//! - **idle** — waiting for steal replies or for global termination.

/// Nanosecond totals per category for one process.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Breakdown {
    pub preprocess_ns: u64,
    pub main_ns: u64,
    pub probe_ns: u64,
    pub idle_ns: u64,
}

impl Breakdown {
    pub fn total_ns(&self) -> u64 {
        self.preprocess_ns + self.main_ns + self.probe_ns + self.idle_ns
    }

    pub fn add(&mut self, o: &Breakdown) {
        self.preprocess_ns += o.preprocess_ns;
        self.main_ns += o.main_ns;
        self.probe_ns += o.probe_ns;
        self.idle_ns += o.idle_ns;
    }

    /// Fill `idle` so the total spans `span_ns` (a process's unaccounted
    /// time inside the run span is, by definition, waiting).
    pub fn close_over_span(&mut self, span_ns: u64) {
        let busy = self.preprocess_ns + self.main_ns + self.probe_ns;
        self.idle_ns = span_ns.saturating_sub(busy);
    }

    pub fn as_secs(&self) -> [f64; 4] {
        [
            self.preprocess_ns as f64 * 1e-9,
            self.main_ns as f64 * 1e-9,
            self.probe_ns as f64 * 1e-9,
            self.idle_ns as f64 * 1e-9,
        ]
    }
}

/// Sum a slice of breakdowns (the stacked bars of Fig. 7 are totals over
/// all processes).
pub fn sum(breakdowns: &[Breakdown]) -> Breakdown {
    let mut acc = Breakdown::default();
    for b in breakdowns {
        acc.add(b);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn close_over_span_assigns_remainder_to_idle() {
        let mut b = Breakdown { preprocess_ns: 10, main_ns: 50, probe_ns: 15, idle_ns: 0 };
        b.close_over_span(100);
        assert_eq!(b.idle_ns, 25);
        assert_eq!(b.total_ns(), 100);
        // span shorter than busy time saturates at zero idle
        b.close_over_span(10);
        assert_eq!(b.idle_ns, 0);
    }

    #[test]
    fn sum_accumulates() {
        let a = Breakdown { preprocess_ns: 1, main_ns: 2, probe_ns: 3, idle_ns: 4 };
        let s = sum(&[a, a, a]);
        assert_eq!(s, Breakdown { preprocess_ns: 3, main_ns: 6, probe_ns: 9, idle_ns: 12 });
    }
}
