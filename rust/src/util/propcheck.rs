//! Minimal property-based testing harness.
//!
//! The offline registry has no `proptest`, so this module provides the
//! subset the crate's invariant tests need: a seeded case generator, a
//! configurable number of cases, and failure reporting that prints the seed
//! so a failing case can be replayed deterministically.
//!
//! ```
//! use parlamp::util::propcheck::forall;
//! forall("addition commutes", 256, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     if a + b != b + a {
//!         return Err(format!("a={a} b={b}"));
//!     }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Base seed; combined with the case index so each case is independent but
/// reproducible. Override with env var `PROPCHECK_SEED` to replay.
fn base_seed() -> u64 {
    std::env::var("PROPCHECK_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5)
}

/// Number-of-cases override (`PROPCHECK_CASES`), for quick local runs or
/// deeper CI sweeps.
fn case_count(default_cases: u64) -> u64 {
    std::env::var("PROPCHECK_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default_cases)
}

/// Run `prop` against `cases` independently seeded RNGs; panic with the
/// case seed and the property's message on the first failure.
pub fn forall<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let base = base_seed();
    for case in 0..case_count(cases) {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay with PROPCHECK_SEED={base} — case seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Like [`forall`] but hands the case index to the property as well, which
/// is convenient for size-ramped generation (small cases first).
pub fn forall_sized<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng, u64) -> Result<(), String>,
{
    let base = base_seed();
    let total = case_count(cases);
    for case in 0..total {
        let seed = base ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay with PROPCHECK_SEED={base} — case seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall("count", 17, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 17);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        forall("fails", 4, |rng| {
            if rng.below(2) < 2 {
                Err("always".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn sized_ramps_cases() {
        let mut seen = Vec::new();
        forall_sized("sizes", 5, |_, case| {
            seen.push(case);
            Ok(())
        });
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }
}
