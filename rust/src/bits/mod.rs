//! Packed bitmap algebra.
//!
//! The paper (§4.6) targets dense databases with relatively few
//! transactions, counting supports with the population-count instruction
//! over packed occurrence bitmaps. [`BitVec`] is that representation: one
//! bit per transaction, `u64` words, with the AND / ANDNOT / popcount
//! kernels the LCM expansion loop is built from. Since PR 3 the expansion
//! runs those kernels over *reduced* row spaces (`db::ConditionalDb`,
//! DESIGN.md §8); [`sparse_subset_of`] is the id-list counterpart used
//! when a projection is too sparse for packed words to pay off.

mod bitvec;

pub use bitvec::BitVec;

/// Number of `u64` words needed for `nbits` bits.
#[inline]
pub const fn words_for(nbits: usize) -> usize {
    nbits.div_ceil(64)
}

/// Popcount of the intersection of two word slices — the innermost support
/// counting kernel. Slices must be the same length.
///
/// Kept as a free function so benches can target it directly; unrolled by
/// fours which measurably helps on the dense workloads the paper targets
/// (see EXPERIMENTS.md §Perf).
#[inline]
pub fn and_popcount(a: &[u64], b: &[u64]) -> u32 {
    // Hard assert: with the zipped loops below a length mismatch would
    // silently truncate (wrong supports), not panic like indexing did.
    assert_eq!(a.len(), b.len());
    let mut acc0: u32 = 0;
    let mut acc1: u32 = 0;
    let mut acc2: u32 = 0;
    let mut acc3: u32 = 0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        acc0 += (x[0] & y[0]).count_ones();
        acc1 += (x[1] & y[1]).count_ones();
        acc2 += (x[2] & y[2]).count_ones();
        acc3 += (x[3] & y[3]).count_ones();
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        acc0 += (x & y).count_ones();
    }
    acc0 + acc1 + acc2 + acc3
}

/// `true` iff `a & b == a` (i.e. `a ⊆ b`), early-exiting on the first
/// violating chunk. Used by the closure computation.
///
/// Unrolled by fours like [`and_popcount`]: the four per-word violation
/// masks are OR-folded into one branch per chunk, so the common
/// (subset-holds) path runs branch-light while a violation still exits
/// within its chunk. Property-tested against the per-word definition.
#[inline]
pub fn subset_of(a: &[u64], b: &[u64]) -> bool {
    assert_eq!(a.len(), b.len());
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (x, y) in (&mut ca).zip(&mut cb) {
        let violation =
            (x[0] & !y[0]) | (x[1] & !y[1]) | (x[2] & !y[2]) | (x[3] & !y[3]);
        if violation != 0 {
            return false;
        }
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        if x & !y != 0 {
            return false;
        }
    }
    true
}

/// `true` iff the strictly-ascending id list `a` is a subset of the
/// strictly-ascending id list `b` — the sparse-encoding counterpart of
/// [`subset_of`], used by the reduced conditional database
/// ([`crate::db::ConditionalDb`], DESIGN.md §8) when a projection is too
/// sparse for packed words to pay off. Merge scan, early-exiting as soon
/// as an element of `a` cannot be matched.
#[inline]
pub fn sparse_subset_of(a: &[u32], b: &[u32]) -> bool {
    if a.len() > b.len() {
        return false;
    }
    let mut bi = 0usize;
    for &x in a {
        while bi < b.len() && b[bi] < x {
            bi += 1;
        }
        if bi == b.len() || b[bi] != x {
            return false;
        }
        bi += 1;
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_words(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn words_for_boundaries() {
        assert_eq!(words_for(0), 0);
        assert_eq!(words_for(1), 1);
        assert_eq!(words_for(64), 1);
        assert_eq!(words_for(65), 2);
        assert_eq!(words_for(697), 11); // HapMap transaction count
    }

    #[test]
    fn and_popcount_matches_naive() {
        forall("and_popcount == naive", 128, |rng| {
            let n = rng.index(21); // several chunks + every remainder path
            let a = random_words(rng, n);
            let b = random_words(rng, n);
            let naive: u32 = a.iter().zip(&b).map(|(x, y)| (x & y).count_ones()).sum();
            if and_popcount(&a, &b) != naive {
                return Err(format!("n={n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_subset_of_matches_set_definition() {
        forall("sparse_subset_of == set ⊆", 128, |rng| {
            let universe = 1 + rng.index(200);
            let b: Vec<u32> =
                (0..universe as u32).filter(|_| rng.bernoulli(0.3)).collect();
            // a ⊆ b half the time, independent random otherwise
            let a: Vec<u32> = if rng.bernoulli(0.5) {
                b.iter().copied().filter(|_| rng.bernoulli(0.6)).collect()
            } else {
                (0..universe as u32).filter(|_| rng.bernoulli(0.2)).collect()
            };
            let naive = a.iter().all(|x| b.binary_search(x).is_ok());
            if sparse_subset_of(&a, &b) != naive {
                return Err(format!("a={a:?} b={b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn sparse_subset_edges() {
        assert!(sparse_subset_of(&[], &[]));
        assert!(sparse_subset_of(&[], &[1, 2]));
        assert!(!sparse_subset_of(&[1], &[]));
        assert!(sparse_subset_of(&[1, 5], &[0, 1, 4, 5]));
        assert!(!sparse_subset_of(&[1, 6], &[0, 1, 4, 5]));
    }

    #[test]
    fn subset_of_matches_definition() {
        forall("subset_of == definition", 128, |rng| {
            // Sizes up to 20 words cover several unrolled chunks plus
            // every remainder length.
            let n = 1 + rng.index(20);
            let b = random_words(rng, n);
            // generate a ⊆ b half the time, random otherwise
            let a: Vec<u64> = if rng.bernoulli(0.5) {
                b.iter().map(|w| w & rng.next_u64()).collect()
            } else {
                random_words(rng, n)
            };
            let naive = a.iter().zip(&b).all(|(x, y)| x & y == *x);
            if subset_of(&a, &b) != naive {
                return Err(format!("a={a:?} b={b:?}"));
            }
            Ok(())
        });
    }
}
