//! Search-tree node representation.

use crate::bits::BitVec;
use crate::db::{Database, Item};

/// Core index of the root node (no generating item).
pub const NO_CORE: i64 = -1;

/// One node of the LCM tree: a closed itemset plus the bookkeeping the PPC
/// extension needs.
///
/// The occurrence bitmap is a *cache*: it is dropped when a node is shipped
/// to another process (the paper notes the itemset data itself identifies
/// the node, §4.1) and lazily recomputed on first expansion.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SearchNode {
    /// Sorted member items of the closed itemset.
    pub items: Vec<Item>,
    /// The generating item (PPC core); `NO_CORE` for the root.
    pub core: i64,
    /// Support `x(I)`.
    pub support: u32,
    /// Cached occurrence bitmap (`None` after a steal ships the node).
    pub occ: Option<BitVec>,
}

impl SearchNode {
    /// The root node: the closure of the empty itemset (all items present
    /// in *every* transaction), support `N`.
    pub fn root(db: &Database) -> Self {
        let occ = BitVec::ones(db.n_trans());
        let sup = db.n_trans() as u32;
        let items: Vec<Item> =
            (0..db.n_items() as Item).filter(|&i| db.item_support(i) == sup).collect();
        SearchNode { items, core: NO_CORE, support: sup, occ: Some(occ) }
    }

    /// Occurrence bitmap, recomputing from the item list if the cache was
    /// dropped in transit.
    pub fn occurrence(&mut self, db: &Database) -> &BitVec {
        if self.occ.is_none() {
            self.occ = Some(db.occurrence(&self.items));
        }
        self.occ.as_ref().unwrap()
    }

    /// Strip the bitmap cache for wire transfer; returns the approximate
    /// number of bytes the serialized node occupies (itemset + header), the
    /// quantity the fabric's bandwidth model charges.
    pub fn strip_for_wire(&mut self) -> usize {
        self.occ = None;
        self.items.len() * std::mem::size_of::<Item>() + 16
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db() -> Database {
        // item 1 occurs in every transaction -> root closure = {1}
        let trans = vec![vec![0, 1], vec![1], vec![1, 2]];
        Database::from_transactions(3, &trans, &[true, false, false])
    }

    #[test]
    fn root_is_closure_of_empty() {
        let r = SearchNode::root(&db());
        assert_eq!(r.items, vec![1]);
        assert_eq!(r.support, 3);
        assert_eq!(r.core, NO_CORE);
    }

    #[test]
    fn occurrence_recomputed_after_strip() {
        let d = db();
        let mut n = SearchNode::root(&d);
        let before = n.occurrence(&d).clone();
        let bytes = n.strip_for_wire();
        assert!(bytes >= 16);
        assert!(n.occ.is_none());
        assert_eq!(*n.occurrence(&d), before);
    }
}
