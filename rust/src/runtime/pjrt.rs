//! PJRT client wrapper: HLO-text artifact → compiled executable.
//!
//! Mirrors /opt/xla-example/load_hlo: text (not serialized proto) is the
//! interchange format because jax ≥ 0.5 emits 64-bit instruction ids the
//! bundled xla_extension 0.5.1 rejects; the text parser reassigns ids.

use std::path::Path;

use anyhow::{Context, Result};

use crate::bits::BitVec;
use crate::stats::Marginals;

use super::manifest::Manifest;

/// One loaded screen executable plus its frozen shapes.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    screen: xla::PjRtLoadedExecutable,
    manifest: Manifest,
}

/// Statistics for one screened candidate row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScreenOut {
    pub x: i32,
    pub n: i32,
    pub logp: f64,
    pub logf: f64,
}

impl XlaRuntime {
    /// Create a CPU PJRT client and compile the screen artifact from
    /// `dir` (usually [`super::artifacts_dir`]).
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let path = dir.join("screen.hlo.txt");
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let screen = client.compile(&comp).context("compile screen artifact")?;
        Ok(XlaRuntime { client, screen, manifest })
    }

    pub fn manifest(&self) -> Manifest {
        self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the screen on up to `k` packed bitmaps.
    ///
    /// `rows.len() ≤ k`; rows are padded with all-zero bitmaps (x = 0 ⇒
    /// log P = 0, filtered by callers). Transactions beyond the bitmap
    /// length are zero bits by the [`BitVec`] invariant.
    pub fn screen_batch(&self, rows: &[&BitVec], m: Marginals) -> Result<Vec<ScreenOut>> {
        let Manifest { k, w, t_max } = self.manifest;
        anyhow::ensure!(rows.len() <= k, "batch {} exceeds artifact capacity {k}", rows.len());
        anyhow::ensure!(
            (m.n_pos as usize) < t_max,
            "N_pos={} exceeds artifact tail capacity t_max={t_max}",
            m.n_pos
        );
        if let Some(r) = rows.first() {
            anyhow::ensure!(
                r.len() <= w * 32,
                "bitmap of {} transactions exceeds artifact width {} bits",
                r.len(),
                w * 32
            );
        }

        let mut occ_flat: Vec<u32> = Vec::with_capacity(k * w);
        for r in rows {
            occ_flat.extend(r.to_u32_words(w));
        }
        occ_flat.resize(k * w, 0);
        let pos_words = vec![0u32; w]; // caller overrides via screen_batch_with_pos
        self.execute(&occ_flat, &pos_words, m, rows.len())
    }

    /// Full screen: candidate bitmaps + the positive-class mask.
    pub fn screen_batch_with_pos(
        &self,
        rows: &[&BitVec],
        pos_mask: &BitVec,
        m: Marginals,
    ) -> Result<Vec<ScreenOut>> {
        let Manifest { k, w, t_max } = self.manifest;
        anyhow::ensure!(rows.len() <= k, "batch {} exceeds artifact capacity {k}", rows.len());
        anyhow::ensure!(
            (m.n_pos as usize) < t_max,
            "N_pos={} exceeds artifact tail capacity t_max={t_max}",
            m.n_pos
        );
        anyhow::ensure!(
            pos_mask.len() <= w * 32,
            "positive mask of {} transactions exceeds artifact width {} bits",
            pos_mask.len(),
            w * 32
        );
        let mut occ_flat: Vec<u32> = Vec::with_capacity(k * w);
        for r in rows {
            anyhow::ensure!(r.len() == pos_mask.len(), "bitmap length mismatch");
            occ_flat.extend(r.to_u32_words(w));
        }
        occ_flat.resize(k * w, 0);
        let pos_words = pos_mask.to_u32_words(w);
        self.execute(&occ_flat, &pos_words, m, rows.len())
    }

    fn execute(
        &self,
        occ_flat: &[u32],
        pos_words: &[u32],
        m: Marginals,
        take: usize,
    ) -> Result<Vec<ScreenOut>> {
        let Manifest { k, w, .. } = self.manifest;
        let occ = xla::Literal::vec1(occ_flat).reshape(&[k as i64, w as i64])?;
        let pos = xla::Literal::vec1(pos_words);
        let n_total = xla::Literal::vec1(&[m.n as f64]);
        let n_pos = xla::Literal::vec1(&[m.n_pos as f64]);
        let result = self.screen.execute::<xla::Literal>(&[occ, pos, n_total, n_pos])?[0][0]
            .to_literal_sync()?;
        let (x, n, logp, logf) = result.to_tuple4()?;
        let x = x.to_vec::<i32>()?;
        let n = n.to_vec::<i32>()?;
        let logp = logp.to_vec::<f64>()?;
        let logf = logf.to_vec::<f64>()?;
        Ok((0..take)
            .map(|i| ScreenOut { x: x[i], n: n[i], logp: logp[i], logf: logf[i] })
            .collect())
    }
}
