//! End-to-end tracing tests (DESIGN.md §14).
//!
//! Every test in this binary arms the global trace flag, so they can run
//! in parallel — the flag is one-way here (nothing turns it off), exactly
//! like a traced CLI run. Determinism matters most: the DES engine runs
//! under virtual time, so two identical runs must produce *identical*
//! per-rank event sequences — the property that makes a trace of a
//! simulated 1,200-process fleet trustworthy evidence rather than noise.

use parlamp::bench::report::parse_json;
use parlamp::db::{Database, Item};
use parlamp::obs::trace::{set_enabled, EventKind, RankTrace};
use parlamp::obs::{chrome, summary};
use parlamp::par::{run_sim, run_threads, RunMode, SimConfig};
use parlamp::util::rng::Rng;

fn random_db(seed: u64, m: usize, n: usize, density: f64) -> Database {
    let mut rng = Rng::new(seed);
    let trans: Vec<Vec<Item>> = (0..n)
        .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
        .collect();
    let labels: Vec<bool> = (0..n).map(|t| t < n / 3).collect();
    Database::from_transactions(m, &trans, &labels)
}

/// Each rank's timeline opens with its phase span, closes it somewhere
/// (late arrivals — rejects, DTD waves — may trail the PhaseEnd), and is
/// time-ordered throughout.
fn assert_well_formed(rt: &RankTrace, phase: u8) {
    assert!(!rt.events.is_empty(), "rank {}: empty timeline", rt.rank);
    assert_eq!(rt.dropped, 0, "rank {}: ring overflowed", rt.rank);
    assert!(
        matches!(rt.events[0].kind, EventKind::PhaseStart { phase: p, .. } if p == phase),
        "rank {}: first event is {:?}",
        rt.rank,
        rt.events[0].kind
    );
    assert!(
        rt.events
            .iter()
            .any(|e| matches!(e.kind, EventKind::PhaseEnd { phase: p, .. } if p == phase)),
        "rank {}: phase {phase} never ended",
        rt.rank
    );
    for w in rt.events.windows(2) {
        assert!(w[0].t_ns <= w[1].t_ns, "rank {}: time went backwards", rt.rank);
    }
}

#[test]
fn sim_traces_are_deterministic_and_well_formed() {
    set_enabled(true);
    let db = random_db(11, 12, 30, 0.4);
    let cfg = SimConfig::paper_defaults(6);
    let a = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);
    let b = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);

    assert_eq!(a.traces.len(), 6, "one timeline per simulated rank");
    for rt in &a.traces {
        assert_well_formed(rt, 1);
        assert_eq!((rt.offset_ns, rt.uncertainty_ns), (0, 0), "in-process: one clock");
    }
    // Two identical virtual-time runs → bit-identical event sequences.
    assert_eq!(a.traces.len(), b.traces.len());
    for (x, y) in a.traces.iter().zip(&b.traces) {
        assert_eq!(x.rank, y.rank);
        assert_eq!(x.events, y.events, "rank {} diverged between replays", x.rank);
    }
}

#[test]
fn thread_engine_traces_cover_phase2() {
    set_enabled(true);
    let db = random_db(21, 10, 26, 0.5);
    let run = run_threads(&db, RunMode::Count { min_sup: 2 }, 3, true, 7);
    assert_eq!(run.traces.len(), 3);
    for rt in &run.traces {
        assert_well_formed(rt, 2);
    }
}

#[test]
fn chrome_export_of_a_sim_run_is_loadable_and_summarizable() {
    set_enabled(true);
    let db = random_db(31, 12, 30, 0.4);
    let cfg = SimConfig::paper_defaults(4);
    let run = run_sim(&db, RunMode::Phase1 { alpha: 0.05 }, &cfg);
    let json = chrome::export(&run.traces);

    parse_json(&json).expect("exported trace must be valid JSON");
    // One phase span per rank, a named track per rank.
    assert_eq!(json.matches(r#""ph":"X""#).count(), 4, "{json}");
    for r in 0..4 {
        assert!(json.contains(&format!(r#""name":"rank {r}""#)), "missing track {r}");
    }
    // Flow starts are emitted per steal REQUEST, finishes per answered
    // GIVE; rejected or termination-time requests legitimately go
    // unanswered, so finish count is bounded by start count.
    let s = json.matches(r#""ph":"s""#).count();
    let f = json.matches(r#""ph":"f""#).count();
    assert!(f <= s, "more flow finishes ({f}) than starts ({s})");

    let report = summary::summarize(&json).expect("summary must accept its own exporter");
    assert!(report.contains("per-rank breakdown"), "{report}");
    assert!(report.contains("rank 0"), "{report}");
}

#[test]
fn trace_rides_along_without_perturbing_results() {
    set_enabled(true);
    let db = random_db(41, 12, 28, 0.45);
    let cfg = SimConfig::paper_defaults(5);
    let traced = run_sim(&db, RunMode::Count { min_sup: 2 }, &cfg);
    // The reference counts come from the engine's own unit suite, which
    // runs untraced; here it is enough that tracing does not change the
    // virtual makespan or the mined counts between two traced runs and
    // that the event totals match the comm counters.
    let again = run_sim(&db, RunMode::Count { min_sup: 2 }, &cfg);
    assert_eq!(traced.closed_total, again.closed_total);
    assert_eq!(traced.makespan_s, again.makespan_s);
    let gives: usize = traced
        .traces
        .iter()
        .flat_map(|t| &t.events)
        .filter(|e| matches!(e.kind, EventKind::StealGive { .. }))
        .count();
    assert_eq!(gives as u64, traced.comm.gives, "one GIVE event per counted give");
}
