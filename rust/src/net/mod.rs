//! Host-neutral stream transport (DESIGN.md §11).
//!
//! Everything in the process fabric and the serving layer that used to
//! hold a raw Unix-socket path now holds an [`Endpoint`] — a typed
//! address that is either `unix:<path>` or `tcp:<host>:<port>` — and
//! every listener/stream pair is a [`Listener`]/[`Stream`] wrapper that
//! works identically over both transports. This module sits *below*
//! [`crate::wire`]: it never encodes or decodes frames itself (callers
//! hand [`dial_with_preamble`] pre-encoded bytes), so the layering stays
//! acyclic while the wire layer can still carry endpoints as strings.

pub mod fault;
pub mod transport;

pub use fault::{NetFaultKind, NetFaultPlan, NET_FAULT_ENV};
pub use transport::{
    dial, dial_with_preamble, fresh_token, Endpoint, Listener, RetryPolicy, Stream,
};
