fn main() {
    parlamp::cli::main();
}
