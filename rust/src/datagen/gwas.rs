//! GWAS-style genotype matrix generator (paper §5.1).
//!
//! Pipeline mirrors the paper's preparation:
//! 1. draw per-SNP minor allele frequencies from a spectrum,
//! 2. generate diploid genotypes (0/1/2 minor-allele counts) with
//!    LD-style correlation between adjacent SNPs (block copying),
//! 3. binarize under the **dominant** (≥1 copy) or **recessive**
//!    (2 copies) model — dominant yields the denser matrices,
//! 4. drop items outside the MAF window (the paper's "upper 10"/"upper 20"
//!    thresholds keep only SNPs with MAF below 10%/20%),
//! 5. assign `n_pos` positive labels and plant significant item
//!    combinations enriched in the positive class.

use crate::db::{Database, Item};
use crate::util::rng::Rng;

/// Binarization model for diploid genotypes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneticModel {
    /// Mutation present iff ≥ 1 minor allele (denser items).
    Dominant,
    /// Mutation present iff homozygous minor (sparser items).
    Recessive,
}

/// Generator parameters.
#[derive(Clone, Debug)]
pub struct GwasSpec {
    /// SNPs drawn before MAF filtering.
    pub n_snps: usize,
    /// Individuals (transactions).
    pub n_individuals: usize,
    /// Positive-class individuals.
    pub n_pos: usize,
    pub model: GeneticModel,
    /// Keep items with MAF ≤ this bound (0.10 / 0.20 in the paper).
    pub maf_upper: f64,
    /// Probability an SNP copies its left neighbour (LD blocks; produces
    /// the non-trivial closures real genotype data has).
    pub ld_copy_prob: f64,
    /// Fraction of SNPs drawn near the MAF cap (a common-variant mode on
    /// top of the rare-skewed spectrum); drives the density / tree-depth
    /// regime: the paper's dense problems (Alz dom 10) have most kept
    /// items close to the threshold.
    pub common_frac: f64,
    /// Planted significant patterns: (arity, positive-class penetrance).
    pub planted: Vec<(usize, f64)>,
    pub seed: u64,
}

impl GwasSpec {
    /// A small default spec handy for tests and the quickstart example.
    pub fn small(seed: u64) -> Self {
        GwasSpec {
            n_snps: 300,
            n_individuals: 120,
            n_pos: 30,
            model: GeneticModel::Dominant,
            maf_upper: 0.2,
            ld_copy_prob: 0.3,
            common_frac: 0.2,
            planted: vec![(3, 0.8)],
            seed,
        }
    }
}

/// Generate a labelled binary database plus the planted pattern item ids
/// (post-filtering; a planted item dropped by the MAF filter is omitted).
pub fn generate_gwas(spec: &GwasSpec) -> (Database, Vec<Vec<Item>>) {
    let mut rng = Rng::new(spec.seed);
    let n = spec.n_individuals;
    let m = spec.n_snps;
    assert!(spec.n_pos <= n);

    // 1–2. genotypes with LD blocks.
    let mut geno: Vec<Vec<u8>> = Vec::with_capacity(m); // [snp][individual]
    let mut mafs: Vec<f64> = Vec::with_capacity(m);
    for s in 0..m {
        if s > 0 && rng.bernoulli(spec.ld_copy_prob) {
            // Copy the previous SNP with small mutation noise: an LD proxy.
            let prev = geno[s - 1].clone();
            let mut col = prev;
            for g in col.iter_mut() {
                if rng.bernoulli(0.05) {
                    *g = rng.below(3) as u8;
                }
            }
            mafs.push(mafs[s - 1]);
            geno.push(col);
        } else {
            // Mixture spectrum: a common-variant mode hugging the MAF cap
            // plus a rare-skewed tail on [0.01, 0.5].
            let q = if rng.bernoulli(spec.common_frac) {
                spec.maf_upper * (0.55 + 0.45 * rng.f64())
            } else {
                0.01 + 0.49 * rng.f64().powi(2)
            };
            mafs.push(q);
            let col = (0..n)
                .map(|_| u8::from(rng.bernoulli(q)) + u8::from(rng.bernoulli(q)))
                .collect();
            geno.push(col);
        }
    }

    // Labels first (planting needs them).
    let mut labels = vec![false; n];
    for l in labels.iter_mut().take(spec.n_pos) {
        *l = true;
    }

    // 3. binarize.
    let mut cols: Vec<Vec<bool>> = geno
        .iter()
        .map(|col| {
            col.iter()
                .map(|&g| match spec.model {
                    GeneticModel::Dominant => g >= 1,
                    GeneticModel::Recessive => g >= 2,
                })
                .collect()
        })
        .collect();

    // 5a. plant patterns *before* filtering so their items keep realistic
    // frequencies: choose `arity` random SNPs and switch them on together
    // for a `penetrance` fraction of positives (plus background carriers).
    let mut planted_snps: Vec<Vec<usize>> = Vec::new();
    // keep_max is computed below from maf_upper; candidates for planting
    // must stay under it *after* the positive-class boost, or the MAF
    // filter would silently drop the signal.
    let keep_max_f = 2.0 * spec.maf_upper * n as f64;
    for &(arity, penetrance) in &spec.planted {
        let mut snps = Vec::with_capacity(arity);
        let mut tries = 0;
        while snps.len() < arity {
            let s = rng.index(m);
            tries += 1;
            let boosted = 2.0 * mafs[s] * n as f64 + penetrance * spec.n_pos as f64;
            let rare_enough = boosted <= 0.9 * keep_max_f || tries > 20 * m;
            if rare_enough && !snps.contains(&s) {
                snps.push(s);
            }
        }
        for (t, lab) in labels.iter().enumerate() {
            if *lab && rng.bernoulli(penetrance) {
                for &s in &snps {
                    cols[s][t] = true;
                }
            }
        }
        planted_snps.push(snps);
    }

    // 4. MAF-window filter on realized item frequency: keep items whose
    // carrier frequency is within (0, maf_upper·(model factor)].
    // Dominant carriers ≈ 2q, recessive ≈ q²; filtering on the *realized*
    // frequency matches what matters to the miner.
    let keep_max = match spec.model {
        GeneticModel::Dominant => (2.0 * spec.maf_upper * n as f64) as u32,
        GeneticModel::Recessive => {
            // recessive matrices are sparse; admit everything below the
            // dominant-equivalent carrier bound
            (2.0 * spec.maf_upper * n as f64) as u32
        }
    };
    let mut keep_map: Vec<Option<Item>> = vec![None; m];
    let mut trans: Vec<Vec<Item>> = vec![Vec::new(); n];
    let mut next: Item = 0;
    for (s, col) in cols.iter().enumerate() {
        let sup = col.iter().filter(|&&b| b).count() as u32;
        if sup == 0 || sup > keep_max.max(1) {
            continue;
        }
        keep_map[s] = Some(next);
        for (t, &b) in col.iter().enumerate() {
            if b {
                trans[t].push(next);
            }
        }
        next += 1;
    }

    let planted_items: Vec<Vec<Item>> = planted_snps
        .iter()
        .map(|snps| {
            let mut v: Vec<Item> = snps.iter().filter_map(|&s| keep_map[s]).collect();
            v.sort_unstable();
            v
        })
        .collect();

    (Database::from_transactions(next as usize, &trans, &labels), planted_items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_spec() {
        let spec = GwasSpec::small(42);
        let (db, planted) = generate_gwas(&spec);
        assert_eq!(db.n_trans(), 120);
        assert!(db.n_items() > 50, "MAF filter should keep most rare items");
        assert!(db.n_items() <= 300);
        assert_eq!(db.marginals().n_pos, 30);
        assert_eq!(planted.len(), 1);
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = GwasSpec::small(7);
        let (a, _) = generate_gwas(&spec);
        let (b, _) = generate_gwas(&spec);
        assert_eq!(a.n_items(), b.n_items());
        assert_eq!(a.density(), b.density());
        let (c, _) = generate_gwas(&GwasSpec::small(8));
        // different seed gives a different matrix (overwhelmingly likely)
        assert!(a.density() != c.density() || a.n_items() != c.n_items());
    }

    #[test]
    fn dominant_denser_than_recessive() {
        let mut spec = GwasSpec::small(11);
        spec.planted.clear();
        let (dom, _) = generate_gwas(&spec);
        spec.model = GeneticModel::Recessive;
        let (rec, _) = generate_gwas(&spec);
        assert!(
            dom.density() > rec.density(),
            "dominant {} must exceed recessive {}",
            dom.density(),
            rec.density()
        );
    }

    #[test]
    fn planted_pattern_enriched_in_positives() {
        let mut spec = GwasSpec::small(123);
        spec.planted = vec![(3, 0.9)];
        let (db, planted) = generate_gwas(&spec);
        let p = &planted[0];
        if p.len() < 2 {
            return; // pattern filtered away (rare); other seeds cover this
        }
        let occ = db.occurrence(p);
        let npos = db.pos_support(&occ);
        let x = occ.count();
        // strong enrichment: most carriers are positive
        assert!(x > 0);
        assert!(
            npos as f64 >= 0.6 * x as f64,
            "planted pattern should be positive-enriched: n={npos} x={x}"
        );
    }

    #[test]
    fn maf_filter_bounds_item_frequency() {
        let spec = GwasSpec { planted: vec![], ..GwasSpec::small(5) };
        let (db, _) = generate_gwas(&spec);
        let bound = (2.0 * spec.maf_upper * spec.n_individuals as f64) as u32;
        for i in 0..db.n_items() as Item {
            assert!(db.item_support(i) <= bound.max(1), "item {i} too frequent");
        }
    }
}
