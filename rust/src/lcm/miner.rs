//! Serial stack-based closed-itemset miner (paper Fig. 3, `DFS_Loop`).
//!
//! The same Pop → ProcessNode → Push loop the distributed workers run,
//! minus the communication. The visitor can adjust the minimum support
//! between nodes, which is how the LAMP phase-1 support-increase algorithm
//! plugs in.

use crate::db::Database;

use super::expand::{expand, ExpandScratch, ExpandStats};
use super::node::SearchNode;

/// Visitor verdict for a closed itemset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Visit {
    /// Keep searching (children of this node will be expanded).
    Continue,
    /// Do not expand this node's children (but keep the rest of the tree).
    PruneChildren,
    /// Abort the whole search.
    Stop,
}

/// Aggregate statistics of one mining run.
#[derive(Clone, Copy, Debug, Default)]
pub struct MineStats {
    /// Closed itemsets reported to the visitor.
    pub closed: u64,
    /// Nodes popped from the stack (≥ `closed` when λ rises mid-run).
    pub popped: u64,
    /// Nodes skipped at pop time because λ rose past their support.
    pub pruned_at_pop: u64,
    /// Expansion work counters.
    pub expand: ExpandStats,
    /// High-water mark of the node stack.
    pub max_stack: usize,
}

/// Histogram of closed-itemset counts by support, the quantity the LAMP
/// support-increase rule consumes: `cs_ge(λ)` = #closed sets with support
/// ≥ λ.
#[derive(Clone, Debug)]
pub struct SupportHist {
    counts: Vec<u64>,
}

impl SupportHist {
    pub fn new(n_trans: usize) -> Self {
        SupportHist { counts: vec![0; n_trans + 1] }
    }

    #[inline]
    pub fn record(&mut self, support: u32) {
        self.counts[support as usize] += 1;
    }

    /// Number of recorded closed sets with support ≥ `lambda`.
    pub fn cs_ge(&self, lambda: u32) -> u64 {
        self.counts[(lambda as usize).min(self.counts.len())..].iter().sum()
    }

    /// Record `n` closed sets at once (used when applying a sparse
    /// wire-format delta, where per-support counts can be large).
    #[inline]
    pub fn add_count(&mut self, support: u32, n: u64) {
        self.counts[support as usize] += n;
    }

    /// Merge another histogram (used by the distributed gather).
    pub fn merge(&mut self, other: &SupportHist) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Raw counts, index = support.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Sparse form `(support, count)` with zero entries dropped, ascending
    /// support — the wire representation used by the phase-boundary merge
    /// and the service result payloads.
    pub fn sparse(&self) -> Vec<(u32, u64)> {
        self.counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(s, &c)| (s as u32, c))
            .collect()
    }

    /// Total closed sets recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }
}

/// Mine all closed itemsets with support ≥ the visitor-controlled minimum
/// support, depth-first.
///
/// The visitor is called once per closed itemset with
/// `(node, current_min_sup) -> (Visit, new_min_sup)`; returning a higher
/// `new_min_sup` immediately prunes the remaining search below it (the
/// support-increase mechanism). The root (closure of ∅) is visited only if
/// non-empty.
pub fn mine_closed<F>(db: &Database, initial_min_sup: u32, mut visit: F) -> MineStats
where
    F: FnMut(&SearchNode, u32) -> (Visit, u32),
{
    let mut stats = MineStats::default();
    let mut min_sup = initial_min_sup.max(1);
    let mut stack: Vec<SearchNode> = Vec::new();
    let mut scratch = ExpandScratch::default();

    let root = SearchNode::root(db);
    if !root.items.is_empty() && root.support >= min_sup {
        let (v, ms) = visit(&root, min_sup);
        stats.closed += 1;
        min_sup = ms.max(min_sup);
        match v {
            Visit::Stop => return stats,
            Visit::PruneChildren => return stats,
            Visit::Continue => {}
        }
    }
    stack.push(root);

    // Visit each closed set when it is *popped* (traversal time), exactly
    // as the paper's Fig 2 walk-through: a node generated while λ was low
    // but reached after λ rose past its support is skipped, not counted.
    while let Some(mut node) = stack.pop() {
        stats.popped += 1;
        if node.core >= 0 {
            if node.support < min_sup {
                stats.pruned_at_pop += 1;
                continue;
            }
            let (v, ms) = visit(&node, min_sup);
            stats.closed += 1;
            min_sup = ms.max(min_sup);
            match v {
                Visit::Stop => return stats,
                Visit::PruneChildren => continue,
                Visit::Continue => {}
            }
        }
        stats.expand.add(&expand(db, &mut node, min_sup, &mut scratch, &mut stack));
        stats.max_stack = stats.max_stack.max(stack.len());
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lcm::brute::brute_force_closed;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, max_items: usize, max_trans: usize) -> Database {
        let m = 2 + rng.index(max_items - 1);
        let n = 2 + rng.index(max_trans - 1);
        let density = 0.2 + rng.f64() * 0.5;
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|_| rng.bernoulli(0.4)).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    fn collect(db: &Database, min_sup: u32) -> Vec<(Vec<Item>, u32)> {
        let mut got = Vec::new();
        mine_closed(db, min_sup, |node, ms| {
            got.push((node.items.clone(), node.support));
            (Visit::Continue, ms)
        });
        got.sort();
        got
    }

    #[test]
    fn matches_brute_force_on_random_dbs() {
        forall("LCM == brute force", 60, |rng| {
            let db = random_db(rng, 9, 14);
            let min_sup = 1 + rng.below(3) as u32;
            let want = brute_force_closed(&db, min_sup);
            let got = collect(&db, min_sup);
            if got != want {
                return Err(format!(
                    "m={} n={} min_sup={min_sup}\n got {got:?}\nwant {want:?}",
                    db.n_items(),
                    db.n_trans()
                ));
            }
            Ok(())
        });
    }

    #[test]
    fn no_duplicates_ever() {
        forall("each closed set visited once", 40, |rng| {
            let db = random_db(rng, 10, 16);
            let got = collect(&db, 1);
            let mut dedup = got.clone();
            dedup.dedup();
            if dedup.len() != got.len() {
                return Err("duplicate closed sets".into());
            }
            Ok(())
        });
    }

    #[test]
    fn stop_aborts_search() {
        let mut rng = Rng::new(3);
        let db = random_db(&mut rng, 10, 16);
        let mut count = 0;
        mine_closed(&db, 1, |_, ms| {
            count += 1;
            (if count >= 3 { Visit::Stop } else { Visit::Continue }, ms)
        });
        assert_eq!(count, 3);
    }

    #[test]
    fn raising_min_sup_mid_run_only_prunes() {
        forall("dynamic λ result ⊆ static λ=1 result, ⊇ static λ=hi result", 30, |rng| {
            let db = random_db(rng, 9, 14);
            let hi = 3u32;
            let all = collect(&db, 1);
            let strict = collect(&db, hi);
            // raise λ to `hi` after the 5th closed set
            let mut seen = 0;
            let mut dynamic = Vec::new();
            mine_closed(&db, 1, |node, ms| {
                seen += 1;
                dynamic.push((node.items.clone(), node.support));
                (Visit::Continue, if seen >= 5 { ms.max(hi) } else { ms })
            });
            dynamic.sort();
            for e in &dynamic {
                if !all.contains(e) {
                    return Err(format!("dynamic produced non-closed {e:?}"));
                }
            }
            for e in &strict {
                if !dynamic.contains(e) {
                    return Err(format!("dynamic missed high-support set {e:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn support_hist_cs_ge() {
        let mut h = SupportHist::new(10);
        h.record(3);
        h.record(3);
        h.record(7);
        assert_eq!(h.cs_ge(1), 3);
        assert_eq!(h.cs_ge(4), 1);
        assert_eq!(h.cs_ge(8), 0);
        assert_eq!(h.total(), 3);
        let mut h2 = SupportHist::new(10);
        h2.record(7);
        h.merge(&h2);
        assert_eq!(h.cs_ge(7), 2);
    }

    #[test]
    fn dfs_order_matches_recursive_definition() {
        // With reverse-order pushes the visit order must equal recursive
        // DFS: parent's children in ascending core order, each subtree
        // fully before the next sibling.
        let db = Database::from_transactions(
            3,
            &[vec![0, 1, 2], vec![0, 1], vec![0], vec![1, 2]],
            &[true, false, false, true],
        );
        let mut order = Vec::new();
        mine_closed(&db, 1, |n, ms| {
            order.push(n.items.clone());
            (Visit::Continue, ms)
        });
        // Visits happen at generation; cores ascend within one expansion.
        // Sanity: first visited child of the root has the smallest core.
        assert!(!order.is_empty());
        let mut sorted = order.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), order.len());
    }
}
