//! Transaction databases in the paper's vertical bitmap layout.
//!
//! An item's column is its *occurrence bitmap* over transactions; support
//! counting is bitwise AND + popcount (paper §4.6). [`Database`] owns the
//! per-item bitmaps plus the positive-class mask used by the significance
//! statistics. The miner's hot path does not scan these full-width
//! columns per candidate, though: each expansion first projects the
//! node's [`ConditionalDb`] (item pruning, weighted row merging, adaptive
//! dense/sparse encoding — DESIGN.md §8) and checks against that.

mod io;
mod reduced;

pub use io::{read_labels, read_transactions, write_labels, write_transactions};
pub use reduced::{ConditionalDb, ProjectScratch};

use crate::bits::BitVec;
use crate::stats::Marginals;

/// Identifier of an item (column index after any preprocessing).
pub type Item = u32;

/// A binary transaction database with class labels, stored vertically.
///
/// # Examples
///
/// Supports, occurrences, and class statistics all come from the vertical
/// bitmap layout:
///
/// ```
/// use parlamp::db::Database;
///
/// // Three transactions over four items; the first two are positives.
/// let db = Database::from_transactions(
///     4,
///     &[vec![0, 1], vec![0, 1, 2], vec![1, 3]],
///     &[true, true, false],
/// );
/// assert_eq!((db.n_items(), db.n_trans()), (4, 3));
/// assert_eq!(db.support(&[0, 1]), 2);
/// assert_eq!(db.pos_support(&db.occurrence(&[0, 1])), 2);
/// assert!((db.density() - 7.0 / 12.0).abs() < 1e-12);
/// ```
///
/// The miner never scans these full-width columns per candidate: each
/// expansion projects the node's conditional database first (see
/// [`ConditionalDb`] and DESIGN.md §8).
#[derive(Clone, Debug)]
pub struct Database {
    n_trans: usize,
    /// `cols[i]` = occurrence bitmap of item `i` over transactions.
    cols: Vec<BitVec>,
    /// Bit `t` set iff transaction `t` is labelled positive.
    pos_mask: BitVec,
}

impl Database {
    /// Build from horizontal transactions (`trans[t]` = sorted-or-not item
    /// list of transaction `t`) and a positive-class indicator per
    /// transaction. `n_items` fixes the column count (items ≥ `n_items` are
    /// rejected).
    pub fn from_transactions(n_items: usize, trans: &[Vec<Item>], positive: &[bool]) -> Self {
        assert_eq!(trans.len(), positive.len(), "labels must match transactions");
        let n_trans = trans.len();
        let mut cols = vec![BitVec::zeros(n_trans); n_items];
        for (t, items) in trans.iter().enumerate() {
            for &i in items {
                assert!((i as usize) < n_items, "item {i} out of range {n_items}");
                cols[i as usize].set(t, true);
            }
        }
        let pos = positive.iter().enumerate().filter(|(_, p)| **p).map(|(t, _)| t);
        let pos_mask = BitVec::from_indices(n_trans, pos);
        Database { n_trans, cols, pos_mask }
    }

    /// Number of transactions `N`.
    pub fn n_trans(&self) -> usize {
        self.n_trans
    }

    /// Number of items (columns).
    pub fn n_items(&self) -> usize {
        self.cols.len()
    }

    /// Occurrence bitmap of item `i`.
    #[inline]
    pub fn col(&self, i: Item) -> &BitVec {
        &self.cols[i as usize]
    }

    /// Positive-class mask.
    pub fn pos_mask(&self) -> &BitVec {
        &self.pos_mask
    }

    /// Support of a single item.
    #[inline]
    pub fn item_support(&self, i: Item) -> u32 {
        self.cols[i as usize].count()
    }

    /// Occurrence bitmap of an itemset (AND over member columns); the
    /// all-ones vector for the empty set.
    pub fn occurrence(&self, items: &[Item]) -> BitVec {
        let mut occ = BitVec::ones(self.n_trans);
        for &i in items {
            occ = occ.and(self.col(i));
        }
        occ
    }

    /// Support of an itemset.
    pub fn support(&self, items: &[Item]) -> u32 {
        self.occurrence(items).count()
    }

    /// Positive-class support `n(I)` for an occurrence bitmap.
    #[inline]
    pub fn pos_support(&self, occ: &BitVec) -> u32 {
        occ.and_count(&self.pos_mask)
    }

    /// Statistical marginals `(N, N_pos)`.
    pub fn marginals(&self) -> Marginals {
        Marginals::new(self.n_trans as u32, self.pos_mask.count())
    }

    /// Fraction of set bits in the item-transaction matrix (the paper's
    /// "density" column in Table 1).
    pub fn density(&self) -> f64 {
        if self.n_items() == 0 || self.n_trans == 0 {
            return 0.0;
        }
        let ones: u64 = self.cols.iter().map(|c| c.count() as u64).sum();
        ones as f64 / (self.n_items() as f64 * self.n_trans as f64)
    }

    /// Stable 64-bit FNV-1a content digest over the canonical encoding of
    /// this database — the service layer's cache key and the warm process
    /// fleet's "is this the database the workers already hold?" check.
    ///
    /// The hashed byte stream is exactly the [`crate::wire`] database
    /// encoding (DESIGN.md §7): `n_items:u32 n_trans:u32 n_pos:u32
    /// pos_idx:u32[] (occ_count:u32 occ_idx:u32[])^n_items`, all
    /// little-endian, occurrence indices ascending. Two databases digest
    /// equal iff they have identical columns, dimensions, and labels, so
    /// the digest is invariant under a no-op round-trip through the text
    /// I/O ([`write_transactions`] / [`read_transactions`]), provided no
    /// trailing all-zero column is dropped by the reader's `max item + 1`
    /// inference.
    ///
    /// # Examples
    ///
    /// ```
    /// use parlamp::db::Database;
    ///
    /// let a = Database::from_transactions(2, &[vec![0], vec![0, 1]], &[true, false]);
    /// let b = Database::from_transactions(2, &[vec![0], vec![0, 1]], &[true, false]);
    /// let c = Database::from_transactions(2, &[vec![0], vec![0, 1]], &[true, true]);
    /// assert_eq!(a.digest(), b.digest());
    /// assert_ne!(a.digest(), c.digest(), "labels are part of the content");
    /// ```
    pub fn digest(&self) -> u64 {
        const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = FNV_OFFSET;
        let mut eat_u32 = |v: u32| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(FNV_PRIME);
            }
        };
        eat_u32(self.n_items() as u32);
        eat_u32(self.n_trans as u32);
        eat_u32(self.pos_mask.count());
        for t in self.pos_mask.iter_ones() {
            eat_u32(t as u32);
        }
        for col in &self.cols {
            eat_u32(col.count());
            for t in col.iter_ones() {
                eat_u32(t as u32);
            }
        }
        h
    }

    /// Drop items whose support is outside `[min_sup, max_sup]`, returning
    /// the remapped database and the mapping `new item -> old item`.
    ///
    /// This is the MAF-style frequency filter applied when preparing the
    /// GWAS inputs (paper §5.1): overly frequent or ultra-rare variants are
    /// excluded before mining.
    pub fn filter_items(&self, min_sup: u32, max_sup: u32) -> (Database, Vec<Item>) {
        let mut keep = Vec::new();
        for i in 0..self.n_items() as Item {
            let s = self.item_support(i);
            if s >= min_sup && s <= max_sup {
                keep.push(i);
            }
        }
        let cols = keep.iter().map(|&i| self.cols[i as usize].clone()).collect();
        (
            Database { n_trans: self.n_trans, cols, pos_mask: self.pos_mask.clone() },
            keep,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 5 transactions, 4 items; transactions 0,1 positive.
    fn tiny() -> Database {
        let trans = vec![
            vec![0, 1, 2],
            vec![0, 1],
            vec![1, 2, 3],
            vec![0, 3],
            vec![1],
        ];
        let labels = vec![true, true, false, false, false];
        Database::from_transactions(4, &trans, &labels)
    }

    #[test]
    fn shape_and_supports() {
        let db = tiny();
        assert_eq!(db.n_trans(), 5);
        assert_eq!(db.n_items(), 4);
        assert_eq!(db.item_support(0), 3);
        assert_eq!(db.item_support(1), 4);
        assert_eq!(db.item_support(3), 2);
        assert_eq!(db.support(&[0, 1]), 2);
        assert_eq!(db.support(&[]), 5); // empty set occurs everywhere
        assert_eq!(db.support(&[0, 1, 2, 3]), 0);
    }

    #[test]
    fn positive_support_and_marginals() {
        let db = tiny();
        let m = db.marginals();
        assert_eq!((m.n, m.n_pos), (5, 2));
        let occ = db.occurrence(&[0, 1]);
        assert_eq!(db.pos_support(&occ), 2); // both transactions 0,1
        let occ3 = db.occurrence(&[3]);
        assert_eq!(db.pos_support(&occ3), 0);
    }

    #[test]
    fn density_counts_all_ones() {
        let db = tiny();
        // 3+4+2+2 = 11 ones over 4*5 cells
        assert!((db.density() - 11.0 / 20.0).abs() < 1e-12);
    }

    #[test]
    fn filter_items_remaps() {
        let db = tiny();
        let (f, map) = db.filter_items(3, 3);
        assert_eq!(map, vec![0]); // only item 0 has support exactly 3
        assert_eq!(f.n_items(), 1);
        assert_eq!(f.item_support(0), 3);
        assert_eq!(f.n_trans(), 5);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rejects_out_of_range_items() {
        Database::from_transactions(2, &[vec![5]], &[true]);
    }

    /// Pinned FNV-1a vectors: the digest is a wire-visible cache key, so
    /// its value for known inputs must never drift across refactors.
    #[test]
    fn digest_matches_pinned_vectors() {
        // Empty database: canonical bytes are 12 zero bytes
        // (n_items=0, n_trans=0, n_pos=0).
        let empty = Database::from_transactions(0, &[], &[]);
        assert_eq!(empty.digest(), 0x5467_b0da_1d10_6495);
        // 2 items × 3 transactions, trans = [[0], [0,1], []],
        // labels = [+,−,+]: bytes are n_items=2, n_trans=3, n_pos=2,
        // pos [0,2], item 0 count 2 idx [0,1], item 1 count 1 idx [1].
        let tiny = Database::from_transactions(
            2,
            &[vec![0], vec![0, 1], vec![]],
            &[true, false, true],
        );
        assert_eq!(tiny.digest(), 0x70ae_1262_178d_0b57);
    }

    #[test]
    fn digest_separates_content_and_ignores_input_order() {
        let a = Database::from_transactions(3, &[vec![0, 2], vec![1]], &[true, false]);
        // Same content, items listed in a different horizontal order.
        let b = Database::from_transactions(3, &[vec![2, 0], vec![1]], &[true, false]);
        assert_eq!(a.digest(), b.digest());
        // One extra occurrence, one flipped label, one extra (empty) column:
        // all must change the digest.
        let c = Database::from_transactions(3, &[vec![0, 2], vec![1, 2]], &[true, false]);
        let d = Database::from_transactions(3, &[vec![0, 2], vec![1]], &[true, true]);
        let e = Database::from_transactions(4, &[vec![0, 2], vec![1]], &[true, false]);
        assert_ne!(a.digest(), c.digest());
        assert_ne!(a.digest(), d.digest());
        assert_ne!(a.digest(), e.digest());
    }

    #[test]
    fn digest_invariant_under_io_roundtrip() {
        let trans = vec![vec![0, 3], vec![1, 2], vec![0, 1, 2, 3], vec![2]];
        let labels = vec![true, false, true, false];
        // Item 3 (the highest id) occurs, so the reader's `max + 1`
        // inference reconstructs the same column count.
        let db = Database::from_transactions(4, &trans, &labels);
        let dir = std::env::temp_dir().join(format!("parlamp_digest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let tpath = dir.join("d.dat");
        let lpath = dir.join("d.labels");
        write_transactions(&tpath, &trans).unwrap();
        write_labels(&lpath, &labels).unwrap();
        let (n_items, got_trans) = read_transactions(&tpath).unwrap();
        let got_labels = read_labels(&lpath).unwrap();
        let back = Database::from_transactions(n_items, &got_trans, &got_labels);
        assert_eq!(back.digest(), db.digest());
        std::fs::remove_dir_all(&dir).ok();
    }
}
