//! End-to-end driver (the EXPERIMENTS.md validation run): a full GWAS
//! significant-pattern study exercising every layer of the stack —
//!
//! 1. synthetic GWAS cohort generation (dominant model, MAF filter,
//!    planted multi-SNP association),
//! 2. serial LAMP (reference),
//! 3. the distributed miner on the DES fabric at P = 96 (phases 1–2) with
//!    the λ/DTD protocol, calibrated against the measured serial run,
//! 4. phase 3 through the AOT-compiled XLA/PJRT screen when artifacts are
//!    present (native fallback otherwise),
//! 5. cross-validation of all three paths + paper §5.6-style reporting.
//!
//! ```bash
//! make artifacts && cargo run --release --example gwas_study
//! ```

use parlamp::bench::calibrate_lamp;
use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::lamp::lamp_serial;
use parlamp::par::{breakdown, lamp_parallel_sim, SimConfig};
use parlamp::runtime::{artifacts_available, artifacts_dir, phase3_extract_xla, ScreenEngine, XlaRuntime};
use parlamp::util::bench_harness::time_once;

fn main() {
    // 1. cohort
    let spec = GwasSpec {
        n_snps: 450,
        n_individuals: 192,
        n_pos: 29,
        model: GeneticModel::Dominant,
        maf_upper: 0.20,
        ld_copy_prob: 0.35,
        common_frac: 0.2,
        planted: vec![(4, 0.85)],
        seed: 0xE2E,
    };
    let (db, planted) = generate_gwas(&spec);
    println!("== cohort ==");
    println!(
        "{} SNP items × {} individuals, density {:.2}%, N_pos={}",
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0,
        db.marginals().n_pos
    );
    println!("planted: {:?}", planted[0]);

    // 2. serial reference
    let (t1, serial) = time_once(|| lamp_serial(&db, 0.05));
    println!("\n== serial LAMP ==\nt1={t1:.3}s  {}", serial.summary());

    // 3. distributed run (DES, P = 96)
    let cal = calibrate_lamp(&db, 0.05);
    let p = 96;
    let cfg = SimConfig { p, ..SimConfig::calibrated(p, &cal) };
    let (par_res, p1, p2) = lamp_parallel_sim(&db, 0.05, &cfg);
    let t_par = p1.makespan_s + p2.makespan_s;
    println!("\n== distributed (DES, P={p}) ==");
    // Speedup baseline: the same computation serially (phases 1+2).
    println!(
        "phase1={:.4}s phase2={:.4}s speedup={:.1}x efficiency={:.0}%  (serial phases 1+2: {:.3}s)",
        p1.makespan_s,
        p2.makespan_s,
        cal.t1_s / t_par,
        100.0 * cal.t1_s / t_par / p as f64,
        cal.t1_s
    );
    println!(
        "steals: {} gives, {} tasks shipped, {} messages, {} bytes",
        p1.comm.gives + p2.comm.gives,
        p1.comm.tasks_shipped + p2.comm.tasks_shipped,
        p1.comm.sent + p2.comm.sent,
        p1.comm.bytes_sent + p2.comm.bytes_sent
    );
    let b = breakdown::sum(&p1.breakdowns);
    let [pre, main, probe, idle] = b.as_secs();
    println!("phase1 CPU breakdown: preprocess={pre:.3}s main={main:.3}s probe={probe:.3}s idle={idle:.3}s");
    assert_eq!(par_res.lambda_final, serial.lambda_final, "parallel must match serial");
    assert_eq!(par_res.correction_factor, serial.correction_factor);

    // 4. phase 3 through XLA/PJRT
    println!("\n== phase 3 ==");
    let significant = if artifacts_available() {
        let rt = XlaRuntime::load(&artifacts_dir()).expect("load artifacts");
        println!("screen: XLA artifact on {} (AOT from JAX/Pallas)", rt.platform());
        let engine = ScreenEngine::new(rt);
        let (t3, sig) = time_once(|| {
            phase3_extract_xla(&engine, &db, serial.min_sup, serial.correction_factor, 0.05)
                .expect("xla phase 3")
        });
        println!("xla phase-3 time: {t3:.3}s");
        sig
    } else {
        println!("screen: native (artifacts missing — run `make artifacts` for the XLA path)");
        serial.significant.clone()
    };

    // 5. cross-validate + report
    assert_eq!(significant.len(), serial.significant.len(), "screens must agree");
    println!(
        "\n== findings (paper §5.6 style) ==\n{} significant patterns, max arity {}",
        significant.len(),
        significant.iter().map(|s| s.items.len()).max().unwrap_or(0)
    );
    for (i, s) in significant.iter().take(8).enumerate() {
        println!(
            "  {:>2}. {:?} x={} n={} P={:.3e}",
            i + 1,
            s.items,
            s.support,
            s.pos_support,
            s.p_value
        );
    }
    let found = significant.iter().any(|s| planted[0].iter().all(|i| s.items.contains(i)));
    println!("\nplanted association recovered: {found}");
    assert!(found, "the planted association must be recovered");
    println!("\nOK — all layers agree (serial = distributed; native = XLA screen).");
}
