//! Cross-engine equivalence for the multi-process fabric: the process
//! engine must compute *exactly* what the serial miner computes on the
//! quickstart scenario — same λ*, same closed-pattern histogram, same
//! correction factor, same significant set — with every protocol message
//! crossing the DESIGN.md §7 wire boundary between real OS processes.
//!
//! Worker processes re-execute the `parlamp` binary (Cargo builds it for
//! integration tests and exposes the path as `CARGO_BIN_EXE_parlamp`).

use std::path::PathBuf;
use std::process::Command;
use std::time::Duration;

use parlamp::coordinator::{Coordinator, ScreenMode};
use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::lamp::{lamp_serial, SupportIncreaseRule};
use parlamp::lcm::{mine_closed, SupportHist, Visit};
use parlamp::net::Endpoint;
use parlamp::par::{run_process_with, DataPlane, ProcessConfig, ProcessFleet, RunMode};

fn parlamp_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_parlamp"))
}

/// The test binary is not `parlamp`, so every in-library run must name the
/// worker executable explicitly. (The `PARLAMP_WORKER_EXE` environment
/// override exists for the same purpose, but `std::env::set_var` races
/// with concurrent test threads spawning processes, so tests avoid it.)
fn process_cfg(p: usize, seed: u64) -> ProcessConfig {
    ProcessConfig {
        worker_exe: Some(parlamp_bin()),
        spawn_timeout: Duration::from_secs(60),
        ..ProcessConfig::paper_defaults(p, seed)
    }
}

/// The quickstart scenario: the same cohort the `quickstart` example and
/// the CI smoke job mine (200 SNPs × 150 individuals, one planted 3-SNP
/// association).
fn quickstart_db() -> parlamp::db::Database {
    let spec = GwasSpec {
        n_snps: 200,
        n_individuals: 150,
        n_pos: 40,
        model: GeneticModel::Dominant,
        maf_upper: 0.2,
        ld_copy_prob: 0.25,
        common_frac: 0.2,
        planted: vec![(3, 0.9)],
        seed: 31,
    };
    generate_gwas(&spec).0
}

/// Serial closed-pattern histogram at `min_sup` — the oracle the process
/// engine's phase-boundary merge must reproduce exactly.
fn serial_hist(db: &parlamp::db::Database, min_sup: u32) -> SupportHist {
    let mut hist = SupportHist::new(db.n_trans());
    mine_closed(db, min_sup, |node, ms| {
        hist.record(node.support);
        (Visit::Continue, ms)
    });
    hist
}

/// Acceptance: the process engine computes the same λ* and the same closed-
/// pattern histogram as the serial reference on the quickstart scenario.
#[test]
fn process_engine_matches_serial_on_quickstart_scenario() {
    let db = quickstart_db();
    let serial = lamp_serial(&db, 0.05);
    let rule = SupportIncreaseRule::new(db.marginals(), 0.05);

    // Phase 1 (λ search) across 3 worker processes.
    let mut p1 = run_process_with(&db, RunMode::Phase1 { alpha: 0.05 }, &process_cfg(3, 42))
        .expect("process phase 1");
    p1.finalize_phase1(&rule);
    assert_eq!(p1.lambda_final, serial.lambda_final, "λ* mismatch");
    assert_eq!(p1.min_sup, serial.min_sup);

    // The phase-1 merge is exact at and above λ* (DESIGN.md §4); it must
    // equal the serial miner's histogram support by support.
    let oracle = serial_hist(&db, serial.lambda_final);
    for support in serial.lambda_final..=db.n_trans() as u32 {
        assert_eq!(
            p1.hist.counts()[support as usize],
            oracle.counts()[support as usize],
            "phase-1 histogram differs at support {support}"
        );
    }

    // Phase 2 (count at min_sup) must reproduce the correction factor and
    // the full closed-pattern histogram.
    let p2 = run_process_with(
        &db,
        RunMode::Count { min_sup: serial.min_sup },
        &process_cfg(3, 43),
    )
    .expect("process phase 2");
    assert_eq!(p2.closed_total, serial.correction_factor, "correction factor mismatch");
    assert_eq!(
        p2.hist.counts(),
        serial_hist(&db, serial.min_sup).counts(),
        "phase-2 closed-pattern histogram mismatch"
    );
    // Real distributed run: traffic crossed the wire.
    assert!(p2.comm.sent > 0, "no messages crossed the process fabric");
    assert!(p2.makespan_s > 0.0);
}

/// Acceptance for the peer-to-peer data plane (DESIGN.md §10): the mesh
/// and hub planes produce bit-identical mining results on the quickstart
/// scenario — same λ*, same closed-pattern histograms, same significant
/// set — and the mesh run's merged `CommStats` shows *zero* data-plane
/// frames relayed by the hub.
#[test]
fn mesh_and_hub_data_planes_agree_and_mesh_bypasses_hub() {
    let db = quickstart_db();
    let run_with = |plane: DataPlane| {
        let cfg = ProcessConfig { data_plane: plane, ..process_cfg(3, 42) };
        let mut fleet = ProcessFleet::spawn(&cfg).expect("spawn fleet");
        assert_eq!(fleet.data_plane(), plane);
        let coord = Coordinator::new(0.05).with_screen(ScreenMode::Native);
        let run = coord.run_on_fleet(&db, &mut fleet, 42).expect("coordinated run");
        fleet.shutdown().expect("fleet shutdown");
        run
    };
    let mesh = run_with(DataPlane::Mesh);
    let hub = run_with(DataPlane::Hub);

    // Bit-identical results across the two planes.
    assert_eq!(mesh.result.lambda_final, hub.result.lambda_final, "λ* differs");
    assert_eq!(mesh.result.min_sup, hub.result.min_sup);
    assert_eq!(mesh.result.correction_factor, hub.result.correction_factor);
    assert_eq!(
        mesh.phase1.hist.counts(),
        hub.phase1.hist.counts(),
        "phase-1 closed-pattern histogram differs between planes"
    );
    assert_eq!(
        mesh.phase2.hist.counts(),
        hub.phase2.hist.counts(),
        "phase-2 closed-pattern histogram differs between planes"
    );
    assert_eq!(
        mesh.result.significant.len(),
        hub.result.significant.len(),
        "significant set size differs"
    );
    for (a, b) in mesh.result.significant.iter().zip(&hub.result.significant) {
        assert_eq!(a.items, b.items, "significant set differs");
    }
    // ... and against the serial reference.
    let serial = lamp_serial(&db, 0.05);
    assert_eq!(mesh.result.lambda_final, serial.lambda_final);
    assert_eq!(mesh.result.correction_factor, serial.correction_factor);
    assert_eq!(mesh.result.significant.len(), serial.significant.len());

    // The headline property: under mesh the hub forwards zero data-plane
    // frames — everything went worker-to-worker — while the hub plane
    // relays everything and sends nothing directly.
    let (mc, hc) = (mesh.comm_total(), hub.comm_total());
    assert_eq!(mc.hub_frames, 0, "mesh run relayed {} frames through the hub", mc.hub_frames);
    assert!(mc.direct_frames > 0, "mesh run sent no direct frames at all");
    assert_eq!(hc.direct_frames, 0, "hub run must not open direct connections");
    assert!(hc.hub_frames > 0, "hub run relayed nothing — counters broken");
}

/// Acceptance for the pluggable transport (DESIGN.md §11): running the
/// whole fabric — hub control plane *and* mesh data plane — over loopback
/// TCP instead of Unix sockets changes nothing about the mining result.
/// Both data planes must match the serial reference and each other
/// bit-for-bit (λ*, both closed-pattern histograms, significant set), and
/// the mesh run must still bypass the hub entirely.
#[test]
fn tcp_transport_matches_serial_on_both_data_planes() {
    let db = quickstart_db();
    let serial = lamp_serial(&db, 0.05);
    let run_with = |plane: DataPlane| {
        let cfg = ProcessConfig {
            data_plane: plane,
            listen: Some(Endpoint::tcp("127.0.0.1", 0)),
            ..process_cfg(3, 42)
        };
        let mut fleet = ProcessFleet::spawn(&cfg).expect("spawn TCP fleet");
        let coord = Coordinator::new(0.05).with_screen(ScreenMode::Native);
        let run = coord.run_on_fleet(&db, &mut fleet, 42).expect("coordinated TCP run");
        fleet.shutdown().expect("fleet shutdown");
        run
    };
    let mesh = run_with(DataPlane::Mesh);
    let hub = run_with(DataPlane::Hub);

    for (plane, run) in [("mesh", &mesh), ("hub", &hub)] {
        assert_eq!(run.result.lambda_final, serial.lambda_final, "λ* differs over tcp/{plane}");
        assert_eq!(run.result.min_sup, serial.min_sup);
        assert_eq!(run.result.correction_factor, serial.correction_factor);
        assert_eq!(
            run.phase2.hist.counts(),
            serial_hist(&db, serial.min_sup).counts(),
            "phase-2 histogram differs over tcp/{plane}"
        );
        assert_eq!(run.result.significant.len(), serial.significant.len());
        for (a, b) in run.result.significant.iter().zip(&serial.significant) {
            assert_eq!(a.items, b.items, "significant set differs over tcp/{plane}");
        }
    }
    // The same zero-hub-relay invariant must hold on TCP as on Unix.
    assert_eq!(mesh.phase1.hist.counts(), hub.phase1.hist.counts());
    let (mc, hc) = (mesh.comm_total(), hub.comm_total());
    assert_eq!(mc.hub_frames, 0, "tcp mesh run relayed {} frames via the hub", mc.hub_frames);
    assert!(mc.direct_frames > 0, "tcp mesh run sent no direct frames");
    assert_eq!(hc.direct_frames, 0);
    assert!(hc.hub_frames > 0);
}

/// The `--hosts` launcher path end to end, in-process: bind the hub on
/// loopback TCP in remote-attach mode, start each "remote" worker
/// ourselves with exactly the argv the printed `JOIN[rank]` command would
/// carry, and check the coordinated run still matches the serial miner.
#[test]
fn remote_attached_tcp_workers_match_serial() {
    let db = quickstart_db();
    let serial = lamp_serial(&db, 0.05);
    let hosts = vec![Endpoint::tcp("127.0.0.1", 0), Endpoint::tcp("127.0.0.1", 0)];
    let cfg = ProcessConfig {
        listen: Some(Endpoint::tcp("127.0.0.1", 0)),
        remote_workers: Some(hosts),
        ..process_cfg(0, 42) // procs ignored: world size = remote_workers.len()
    };
    let pending = ProcessFleet::bind(&cfg).expect("bind hub");
    assert!(matches!(pending.endpoint(), Endpoint::Tcp(_, port) if *port != 0));
    // What `--hosts` mode prints for humans; here we exec it ourselves.
    let mut children: Vec<std::process::Child> = (0..2)
        .map(|rank: usize| {
            Command::new(parlamp_bin())
                .arg("__worker")
                .arg("--connect")
                .arg(pending.endpoint().to_string())
                .arg("--token")
                .arg(pending.token())
                .arg("--worker-rank")
                .arg(rank.to_string())
                .spawn()
                .expect("spawn remote worker")
        })
        .collect();
    let mut fleet = pending.await_workers().expect("await remote workers");
    let coord = Coordinator::new(0.05).with_screen(ScreenMode::Native);
    let run = coord.run_on_fleet(&db, &mut fleet, 42).expect("coordinated remote run");
    fleet.shutdown().expect("fleet shutdown");
    for child in &mut children {
        child.wait().ok();
    }
    assert_eq!(run.result.lambda_final, serial.lambda_final, "λ* differs (remote attach)");
    assert_eq!(run.result.correction_factor, serial.correction_factor);
    assert_eq!(run.result.significant.len(), serial.significant.len());
    let comm = run.comm_total();
    assert_eq!(comm.hub_frames, 0, "remote mesh fleet must not relay through the hub");
}

/// The naive baseline (stealing disabled, §5.4) over the process fabric:
/// identical counts, and no task is ever shipped.
#[test]
fn process_naive_mode_counts_match_and_never_ship() {
    let spec = GwasSpec { n_snps: 90, n_individuals: 64, n_pos: 16, ..GwasSpec::small(21) };
    let (db, _) = generate_gwas(&spec);
    let serial = lamp_serial(&db, 0.05);
    let cfg = ProcessConfig { steal: false, ..process_cfg(3, 7) };
    let p2 = run_process_with(&db, RunMode::Count { min_sup: serial.min_sup }, &cfg)
        .expect("naive process count phase");
    assert_eq!(p2.closed_total, serial.correction_factor);
    assert_eq!(p2.hist.counts(), serial_hist(&db, serial.min_sup).counts());
    assert_eq!(p2.comm.gives, 0, "naive mode must never ship tasks");
}

/// Extract the six `λ*=… min_sup=… k=… δ=… significant=… max_arity=…`
/// summary tokens from a CLI stdout blob, engine-independent.
fn summary_tokens(stdout: &str) -> Vec<String> {
    let at = stdout.find("λ*=").expect("no summary in output");
    stdout[at..].split_whitespace().take(6).map(str::to_string).collect()
}

/// CLI-level acceptance: `parlamp lamp --engine process` prints the same
/// result summary as `--engine serial` on the same dataset files.
#[test]
fn cli_engine_process_matches_serial() {
    let spec = GwasSpec { n_snps: 100, n_individuals: 70, n_pos: 18, ..GwasSpec::small(5) };
    let (db, _) = generate_gwas(&spec);
    let dir = std::env::temp_dir().join(format!("parlamp-proc-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let data = dir.join("g.dat");
    let labels = dir.join("g.labels");
    // reconstruct horizontal form for the FIMI writer
    let mut trans: Vec<Vec<parlamp::db::Item>> = vec![Vec::new(); db.n_trans()];
    for i in 0..db.n_items() as parlamp::db::Item {
        for t in db.col(i).iter_ones() {
            trans[t].push(i);
        }
    }
    let lab: Vec<bool> = (0..db.n_trans()).map(|t| db.pos_mask().get(t)).collect();
    parlamp::db::write_transactions(&data, &trans).unwrap();
    parlamp::db::write_labels(&labels, &lab).unwrap();

    let run_cli = |engine: &str, extra: &[&str]| -> String {
        let mut cmd = Command::new(parlamp_bin());
        cmd.arg("lamp")
            .arg("--data")
            .arg(&data)
            .arg("--labels")
            .arg(&labels)
            .arg("--engine")
            .arg(engine)
            .args(extra);
        let out = cmd.output().expect("run parlamp CLI");
        assert!(
            out.status.success(),
            "engine {engine} failed: {}\n{}",
            out.status,
            String::from_utf8_lossy(&out.stderr)
        );
        String::from_utf8(out.stdout).expect("utf8 stdout")
    };

    let serial_out = run_cli("serial", &[]);
    // `-n` is the documented shorthand for `--procs`; the default data
    // plane is mesh, and `--data-plane hub` selects the relay baseline —
    // the quickstart equivalence must hold under both.
    let mesh_out = run_cli("process", &["-n", "2"]);
    let hub_out = run_cli("process", &["-n", "2", "--data-plane", "hub"]);
    for (plane, out) in [("mesh", &mesh_out), ("hub", &hub_out)] {
        assert_eq!(
            summary_tokens(&serial_out),
            summary_tokens(out),
            "serial vs process ({plane}) CLI summaries differ\n--- serial ---\n\
             {serial_out}\n--- process ({plane}) ---\n{out}"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}
