//! PPC extension — the `ProcessNode` of the paper's Fig. 5.
//!
//! Expanding a closed itemset `P` with core `e` generates, for every item
//! `i > e` with `i ∉ P` and `sup(P ∪ i) ≥ min_sup`, the closure
//! `Q = clo(P ∪ i)`; the extension is *prefix-preserving* iff
//! `Q ∩ [0, i) = P ∩ [0, i)`. Each frequent closed itemset other than the
//! root is produced by exactly one `(P, i)` pair, so no duplicate detection
//! is needed — the property that makes the search a tree and therefore
//! amenable to stack-based distribution.

use crate::bits::BitVec;
use crate::db::{Database, Item};

use super::node::SearchNode;

/// Reusable scratch buffers so the hot loop performs no allocations.
#[derive(Default)]
pub struct ExpandScratch {
    child_occ: Option<BitVec>,
}

/// Work accounting for one expansion, used both for perf reporting and as
/// the discrete-event simulator's virtual-time cost model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ExpandStats {
    /// Number of candidate items scanned.
    pub candidates: u64,
    /// Number of frequent candidates that reached the closure check.
    pub closure_checks: u64,
    /// Children emitted.
    pub children: u64,
    /// Approximate `u64`-word operations performed (the DES cost unit).
    pub word_ops: u64,
}

impl ExpandStats {
    pub fn add(&mut self, o: &ExpandStats) {
        self.candidates += o.candidates;
        self.closure_checks += o.closure_checks;
        self.children += o.children;
        self.word_ops += o.word_ops;
    }
}

/// Expand `node`, pushing each PPC child onto `out` in **reverse item
/// order** so that popping from a stack visits children in ascending order
/// (depth-first order identical to the recursive formulation — paper §4.1).
///
/// `min_sup` is the current frequency threshold (the LAMP `λ`); children
/// below it are not generated.
pub fn expand(
    db: &Database,
    node: &mut SearchNode,
    min_sup: u32,
    scratch: &mut ExpandScratch,
    out: &mut Vec<SearchNode>,
) -> ExpandStats {
    expand_filtered(db, node, min_sup, scratch, out, |_| true)
}

/// [`expand`] restricted to generating items accepted by `keep`.
///
/// Used by the depth-1 preprocess partition (paper §4.5): process `r` of
/// `P` expands the root only for items `i` with `i mod P = r`, which seeds
/// every stack without any communication.
pub fn expand_filtered(
    db: &Database,
    node: &mut SearchNode,
    min_sup: u32,
    scratch: &mut ExpandScratch,
    out: &mut Vec<SearchNode>,
    keep: impl Fn(Item) -> bool,
) -> ExpandStats {
    let mut stats = ExpandStats::default();
    let n_items = db.n_items() as Item;
    let words = crate::bits::words_for(db.n_trans()) as u64;
    let first = out.len();

    // Ensure the occurrence bitmap exists (may have been stripped in
    // transit); charge its reconstruction cost.
    if node.occ.is_none() {
        stats.word_ops += words * node.items.len() as u64;
    }
    let occ = node.occurrence(db).clone();

    let start: Item = (node.core + 1) as Item; // NO_CORE = -1 -> 0
    // Membership mask of P for O(1) "i ∈ P" checks. P is sorted and small.
    let in_p = |i: Item| node.items.binary_search(&i).is_ok();

    let child_occ = scratch.child_occ.get_or_insert_with(|| BitVec::zeros(db.n_trans()));

    for i in start..n_items {
        if in_p(i) || !keep(i) {
            continue;
        }
        stats.candidates += 1;
        stats.word_ops += words;
        let sup = occ.and_count(db.col(i));
        if sup < min_sup || sup == 0 {
            continue;
        }
        stats.closure_checks += 1;
        occ.and_assign_into(db.col(i), child_occ);
        stats.word_ops += words;

        // PPC check: no item j < i outside P may contain child_occ.
        let mut prefix_ok = true;
        for j in 0..i {
            if in_p(j) {
                continue;
            }
            stats.word_ops += 1; // early-exit scans are ~1 word on average
            if child_occ.is_subset_of(db.col(j)) {
                prefix_ok = false;
                break;
            }
        }
        if !prefix_ok {
            continue;
        }

        // Closure completion: items j > i with child_occ ⊆ col(j).
        let mut items = Vec::with_capacity(node.items.len() + 2);
        items.extend_from_slice(&node.items);
        items.push(i);
        for j in i + 1..n_items {
            if in_p(j) {
                continue;
            }
            stats.word_ops += 1;
            if child_occ.is_subset_of(db.col(j)) {
                items.push(j);
            }
        }
        items.sort_unstable();

        out.push(SearchNode {
            items,
            core: i as i64,
            support: sup,
            occ: Some(child_occ.clone()),
        });
        stats.children += 1;
    }

    // Reverse the children pushed by this call so stack pops see ascending
    // core order (true DFS order).
    out[first..].reverse();
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lcm::node::NO_CORE;

    fn db() -> Database {
        // The classic 4-item example; transactions chosen so several
        // closures are non-trivial.
        let trans = vec![
            vec![0, 1, 2],
            vec![0, 1],
            vec![1, 2, 3],
            vec![0, 3],
            vec![1, 2],
        ];
        Database::from_transactions(4, &trans, &[true, true, false, false, false])
    }

    #[test]
    fn children_have_correct_support_and_closure() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut out = Vec::new();
        let mut scratch = ExpandScratch::default();
        let st = expand(&d, &mut root, 1, &mut scratch, &mut out);
        assert_eq!(st.children as usize, out.len());
        for c in &out {
            // support matches db
            assert_eq!(d.support(&c.items), c.support, "items {:?}", c.items);
            // closed: no item outside adds nothing
            let occ = d.occurrence(&c.items);
            for j in 0..d.n_items() as Item {
                if !c.items.contains(&j) {
                    assert!(
                        !occ.is_subset_of(d.col(j)),
                        "items {:?} not closed wrt {j}",
                        c.items
                    );
                }
            }
            assert!(c.core > NO_CORE);
        }
    }

    #[test]
    fn min_sup_prunes() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut scratch = ExpandScratch::default();
        let mut all = Vec::new();
        expand(&d, &mut root.clone(), 1, &mut scratch, &mut all);
        let mut frequent = Vec::new();
        expand(&d, &mut root, 3, &mut scratch, &mut frequent);
        assert!(frequent.len() < all.len());
        for c in &frequent {
            assert!(c.support >= 3);
        }
    }

    #[test]
    fn children_pushed_in_reverse_core_order() {
        let d = db();
        let mut root = SearchNode::root(&d);
        let mut out = Vec::new();
        expand(&d, &mut root, 1, &mut ExpandScratch::default(), &mut out);
        for w in out.windows(2) {
            assert!(w[0].core > w[1].core, "stack order must be reverse");
        }
    }

    #[test]
    fn stats_accumulate() {
        let mut a = ExpandStats { candidates: 1, closure_checks: 2, children: 3, word_ops: 4 };
        let b = a;
        a.add(&b);
        assert_eq!(a, ExpandStats { candidates: 2, closure_checks: 4, children: 6, word_ops: 8 });
    }
}
