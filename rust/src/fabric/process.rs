//! Process-backed fabric: one OS process per rank, Unix-domain sockets as
//! the interconnect (DESIGN.md §7).
//!
//! The first fabric backend with real address-space separation: unlike
//! [`super::thread`] and [`super::sim`], nothing can be passed by value, so
//! every protocol message crosses the [`crate::wire`] serialization
//! boundary. Topology is hub-and-spoke: the parent process runs a [`Hub`]
//! that accepts one connection per worker rank and routes `RELAY` frames
//! between them, which keeps the design at `P` sockets instead of the
//! `P(P−1)/2` a full mesh would need (file-descriptor passing between
//! children is not required).
//!
//! Lifecycle of one phase:
//!
//! 1. the engine ([`crate::par::engine_process`]) binds a hub and spawns
//!    `P` worker processes pointing at its socket;
//! 2. each worker connects and sends `HELLO { rank }`; the hub answers with
//!    `CONFIG` (the full [`RunSpec`], database included);
//! 3. once all `P` ranks are registered the hub broadcasts `START` — the
//!    startup barrier that guarantees no steal traffic targets an
//!    unregistered rank;
//! 4. workers run the ordinary [`crate::par::Worker`] loop against a
//!    [`ProcessMailbox`]; every [`Mailbox::send`] becomes a `RELAY` frame
//!    the hub forwards;
//! 5. on `Finish` each worker sends its `MERGE` (the phase-boundary
//!    histogram/breakdown/counter payload) and blocks until `BYE`;
//! 6. the hub collects `P` merges, broadcasts `BYE`, and the workers exit.
//!
//! Failure semantics: a worker that dies mid-run surfaces as a
//! [`HubEvent::Gone`] (socket EOF or error) and the engine aborts the run;
//! a forward to an already-exited worker is silently dropped, mirroring the
//! finished-peer no-op of the thread fabric (MPI-finalize semantics).

use std::collections::VecDeque;
use std::io::Write;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::wire::{
    encode_config, read_frame, write_frame, Frame, RunSpec, WorkerMerge, MAX_FRAME_LEN,
};

use super::{Mailbox, Msg};

/// How long either side waits for the other during the HELLO/CONFIG/START
/// handshake before declaring the peer dead.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

// ---- worker (child) side ---------------------------------------------------

/// Link status of a worker's hub connection.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Link {
    Open,
    /// Orderly `BYE` received.
    Bye,
    /// Socket error or unexpected EOF; the run cannot complete.
    Lost(String),
}

enum ChildEvent {
    Deliver { src: usize, msg: Msg },
    Bye,
    Lost(String),
}

/// The worker-process endpoint of the fabric: the [`Mailbox`] the ordinary
/// [`crate::par::Worker`] state machine drives, plus the merge/shutdown
/// handshake. Obtain one with [`connect`].
pub struct ProcessMailbox {
    rank: usize,
    size: usize,
    writer: UnixStream,
    rx: Receiver<ChildEvent>,
    /// Messages pulled in by a blocking wait (or buffered during the
    /// handshake) but not yet consumed by the worker's probe loop.
    pending: VecDeque<(usize, Msg)>,
    link: Link,
    _reader: JoinHandle<()>,
}

/// Connect to the hub at `path` as `rank`: send `HELLO`, receive `CONFIG`,
/// wait for the `START` barrier (buffering any early `RELAY` traffic), then
/// hand the socket to a background reader thread.
///
/// Returns the run specification and the ready-to-poll mailbox.
pub fn connect(path: &Path, rank: usize) -> Result<(RunSpec, ProcessMailbox)> {
    let mut stream = UnixStream::connect(path)
        .with_context(|| format!("connect to fabric hub at {}", path.display()))?;
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    write_frame(&mut stream, &Frame::Hello { rank: rank as u32 }).context("send HELLO")?;

    let frame = read_frame(&mut stream)?.context("hub closed before CONFIG")?;
    let spec = match frame {
        Frame::Config(spec) => spec,
        other => bail!("expected CONFIG from hub, got {}", other.name()),
    };
    ensure!(
        (rank as u32) < spec.p,
        "rank {rank} out of range for world size {}",
        spec.p
    );

    // Await the START barrier. Workers that started earlier may already be
    // sending us steal traffic; buffer it in arrival order.
    let mut pending = VecDeque::new();
    loop {
        let frame = read_frame(&mut stream)?.context("hub closed before START")?;
        match frame {
            Frame::Start => break,
            Frame::Relay { peer, msg } => pending.push_back((peer as usize, msg)),
            other => bail!("expected START from hub, got {}", other.name()),
        }
    }
    stream.set_read_timeout(None)?;

    let reader_stream = stream.try_clone().context("clone fabric socket")?;
    let (tx, rx) = channel();
    let reader = std::thread::spawn(move || reader_loop(reader_stream, tx));
    let mb = ProcessMailbox {
        rank,
        size: spec.p as usize,
        writer: stream,
        rx,
        pending,
        link: Link::Open,
        _reader: reader,
    };
    Ok((*spec, mb))
}

fn reader_loop(mut stream: UnixStream, tx: Sender<ChildEvent>) {
    loop {
        match read_frame(&mut stream) {
            Ok(Some(Frame::Relay { peer, msg })) => {
                if tx.send(ChildEvent::Deliver { src: peer as usize, msg }).is_err() {
                    return; // mailbox dropped
                }
            }
            Ok(Some(Frame::Bye)) => {
                let _ = tx.send(ChildEvent::Bye);
                return;
            }
            Ok(Some(other)) => {
                let _ = tx.send(ChildEvent::Lost(format!(
                    "unexpected {} frame from hub",
                    other.name()
                )));
                return;
            }
            Ok(None) => {
                let _ = tx.send(ChildEvent::Lost("hub closed the connection".into()));
                return;
            }
            Err(e) => {
                let _ = tx.send(ChildEvent::Lost(format!("{e:#}")));
                return;
            }
        }
    }
}

impl ProcessMailbox {
    fn absorb(&mut self, ev: ChildEvent) -> Option<(usize, Msg)> {
        match ev {
            ChildEvent::Deliver { src, msg } => Some((src, msg)),
            ChildEvent::Bye => {
                self.link = Link::Bye;
                None
            }
            ChildEvent::Lost(e) => {
                if self.link == Link::Open {
                    self.link = Link::Lost(e);
                }
                None
            }
        }
    }

    /// The error that severed the hub link, if any. The worker loop checks
    /// this each quantum and aborts the run — without a hub there is no
    /// termination detection, so spinning would hang forever.
    pub fn lost(&self) -> Option<&str> {
        match &self.link {
            Link::Lost(e) => Some(e),
            _ => None,
        }
    }

    /// Block until a message arrives (buffered for the next `try_recv`) or
    /// the timeout elapses — used by idle workers so they wake on incoming
    /// GIVEs without spinning. Returns whether a message arrived.
    pub fn wait_for_msg(&mut self, d: Duration) -> bool {
        if !self.pending.is_empty() {
            return true;
        }
        match self.rx.recv_timeout(d) {
            Ok(ev) => match self.absorb(ev) {
                Some(m) => {
                    self.pending.push_back(m);
                    true
                }
                None => false,
            },
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => false,
        }
    }

    /// Send the phase-boundary merge after the worker saw `Finish`.
    pub fn send_merge(&mut self, merge: &WorkerMerge) -> Result<()> {
        write_frame(&mut self.writer, &Frame::Merge(Box::new(merge.clone())))
            .context("send MERGE to hub")
    }

    /// Block until the hub acknowledges the merge with `BYE` (late steal
    /// traffic still in flight is drained and dropped).
    pub fn wait_bye(&mut self, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        loop {
            match &self.link {
                Link::Bye => return Ok(()),
                Link::Lost(e) => bail!("hub link lost while awaiting BYE: {e}"),
                Link::Open => {}
            }
            let now = Instant::now();
            ensure!(now < deadline, "timed out waiting for BYE from hub");
            match self.rx.recv_timeout(deadline - now) {
                Ok(ev) => {
                    let _ = self.absorb(ev); // drop late deliveries
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    bail!("fabric reader thread exited while awaiting BYE")
                }
            }
        }
    }
}

impl Mailbox for ProcessMailbox {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        if self.link != Link::Open {
            return; // shutdown race: mirror the dropped-peer no-op
        }
        let frame = Frame::Relay { peer: dst as u32, msg };
        if let Err(e) = write_frame(&mut self.writer, &frame) {
            self.link = Link::Lost(format!("send to hub failed: {e}"));
        }
    }

    fn try_recv(&mut self) -> Option<(usize, Msg)> {
        if let Some(m) = self.pending.pop_front() {
            return Some(m);
        }
        while let Ok(ev) = self.rx.try_recv() {
            if let Some(m) = self.absorb(ev) {
                return Some(m);
            }
            if self.link != Link::Open {
                return None;
            }
        }
        None
    }
}

// ---- hub (parent) side -----------------------------------------------------

/// What the hub reports to the engine while a phase runs.
#[derive(Debug)]
pub enum HubEvent {
    /// A worker delivered its phase-boundary merge.
    Merge(WorkerMerge),
    /// A worker's connection ended — orderly EOF after its merge and the
    /// `BYE`, or a crash/protocol violation mid-run. The engine treats it as
    /// fatal only for ranks that have not merged yet.
    Gone { rank: usize, detail: String },
}

/// Per-rank write halves, shared between the hub and its route threads.
type Writers = Arc<Vec<Mutex<Option<UnixStream>>>>;

/// Parent-side fabric endpoint: accepts worker connections, runs one route
/// thread per worker, and surfaces merges. Owned and driven by
/// [`crate::par::engine_process::run_process_with`].
pub struct Hub {
    listener: UnixListener,
    /// Pre-encoded `CONFIG` frame (identical for every worker).
    config_bytes: Arc<Vec<u8>>,
    p: usize,
    writers: Writers,
    events_tx: Sender<HubEvent>,
    events_rx: Receiver<HubEvent>,
    routers: Vec<JoinHandle<()>>,
    connected: usize,
    started: bool,
}

impl Hub {
    /// Bind the hub socket and freeze the run specification that every
    /// connecting worker will receive.
    pub fn bind(path: &Path, spec: &RunSpec) -> Result<Hub> {
        let listener = UnixListener::bind(path)
            .with_context(|| format!("bind fabric hub socket {}", path.display()))?;
        listener.set_nonblocking(true).context("set hub listener non-blocking")?;
        let p = spec.p as usize;
        ensure!(p >= 1, "world size must be ≥ 1");
        let config_bytes = encode_config(spec);
        ensure!(
            config_bytes.len() - 4 <= MAX_FRAME_LEN as usize,
            "CONFIG frame ({} bytes) exceeds the {MAX_FRAME_LEN}-byte frame cap; \
             the database is too large for the process fabric's wire format",
            config_bytes.len() - 4
        );
        let (events_tx, events_rx) = channel();
        Ok(Hub {
            listener,
            config_bytes: Arc::new(config_bytes),
            p,
            writers: Arc::new((0..p).map(|_| Mutex::new(None)).collect()),
            events_tx,
            events_rx,
            routers: Vec::with_capacity(p),
            connected: 0,
            started: false,
        })
    }

    /// Ranks that have completed the HELLO/CONFIG handshake so far.
    pub fn connected(&self) -> usize {
        self.connected
    }

    /// Accept and handshake at most one pending worker connection. Returns
    /// whether one was accepted. Non-blocking: the engine interleaves this
    /// with liveness checks on the spawned processes.
    pub fn try_accept(&mut self) -> Result<bool> {
        let (mut stream, _) = match self.listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) => return Err(e).context("accept worker connection"),
        };
        stream.set_nonblocking(false).context("set worker socket blocking")?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let frame = read_frame(&mut stream)?.context("worker closed during handshake")?;
        let rank = match frame {
            Frame::Hello { rank } => rank as usize,
            other => bail!("expected HELLO from worker, got {}", other.name()),
        };
        ensure!(rank < self.p, "HELLO rank {rank} out of range for world size {}", self.p);
        stream.write_all(&self.config_bytes).context("send CONFIG")?;
        stream.set_read_timeout(None)?;
        let reader = stream.try_clone().context("clone worker socket")?;
        {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            ensure!(slot.is_none(), "duplicate HELLO for rank {rank}");
            *slot = Some(stream);
        }
        let writers = Arc::clone(&self.writers);
        let tx = self.events_tx.clone();
        let p = self.p;
        self.routers.push(std::thread::spawn(move || route_loop(rank, reader, writers, tx, p)));
        self.connected += 1;
        Ok(true)
    }

    /// Release the startup barrier: broadcast `START` once every rank is
    /// registered. Workers begin the phase on receipt.
    pub fn start_all(&mut self) -> Result<()> {
        ensure!(
            self.connected == self.p,
            "cannot start: {}/{} workers connected",
            self.connected,
            self.p
        );
        ensure!(!self.started, "phase already started");
        for rank in 0..self.p {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            let w = slot.as_mut().expect("connected worker has a writer");
            write_frame(w, &Frame::Start)
                .with_context(|| format!("send START to rank {rank}"))?;
        }
        self.started = true;
        Ok(())
    }

    /// Wait up to `timeout` for the next hub event. `Ok(None)` = timeout.
    pub fn recv_event(&self, timeout: Duration) -> Result<Option<HubEvent>> {
        match self.events_rx.recv_timeout(timeout) {
            Ok(ev) => Ok(Some(ev)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // All route threads gone without the engine collecting P merges.
            Err(RecvTimeoutError::Disconnected) => bail!("all fabric route threads exited"),
        }
    }

    /// Broadcast `BYE`. Send errors are ignored: a worker that already
    /// exited has nothing left to acknowledge.
    pub fn broadcast_bye(&mut self) {
        for slot in self.writers.iter() {
            if let Some(w) = slot.lock().expect("writer lock").as_mut() {
                let _ = write_frame(w, &Frame::Bye);
            }
        }
    }

    /// Join the route threads (they exit at worker-socket EOF). Call after
    /// [`Hub::broadcast_bye`] and after the worker processes were reaped —
    /// never while workers may still be running.
    pub fn join(&mut self) {
        for h in self.routers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-worker route thread: forward `RELAY` frames to their destination
/// rank (stamping the source), surface `MERGE` and disconnection.
fn route_loop(
    rank: usize,
    mut reader: UnixStream,
    writers: Writers,
    tx: Sender<HubEvent>,
    p: usize,
) {
    let gone = |detail: String| {
        let _ = tx.send(HubEvent::Gone { rank, detail });
    };
    loop {
        match read_frame(&mut reader) {
            Ok(Some(Frame::Relay { peer, msg })) => {
                let dst = peer as usize;
                if dst >= p {
                    gone(format!("relayed to out-of-range rank {dst}"));
                    return;
                }
                let frame = Frame::Relay { peer: rank as u32, msg };
                let mut slot = writers[dst].lock().expect("writer lock");
                if let Some(w) = slot.as_mut() {
                    // A failed forward means the destination already exited;
                    // drop it like the thread fabric drops sends to a
                    // finished peer.
                    let _ = write_frame(w, &frame);
                }
            }
            Ok(Some(Frame::Merge(m))) => {
                if m.rank as usize != rank {
                    gone(format!("MERGE claims rank {} on rank {rank}'s connection", m.rank));
                    return;
                }
                if tx.send(HubEvent::Merge(*m)).is_err() {
                    return; // engine gone
                }
                // Keep draining until EOF so late RELAYs are still routed.
            }
            Ok(Some(other)) => {
                gone(format!("unexpected {} frame", other.name()));
                return;
            }
            Ok(None) => {
                gone("EOF".into());
                return;
            }
            Err(e) => {
                gone(format!("{e:#}"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Database;
    use crate::fabric::BasicKind;
    use crate::par::worker::RunMode;

    fn tiny_spec(p: u32) -> RunSpec {
        let trans = vec![vec![0, 1], vec![1]];
        let db = Database::from_transactions(2, &trans, &[true, false]);
        RunSpec {
            p,
            seed: 1,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: false,
            probe_budget_units: 1000,
            dtd_interval_ns: 1000,
            mode: RunMode::Count { min_sup: 1 },
            db,
        }
    }

    fn test_sock(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("parlamp-fabtest-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("hub.sock")
    }

    fn merge_for(rank: u32) -> WorkerMerge {
        WorkerMerge {
            rank,
            hist: vec![(1, 2)],
            closed_count: 2,
            work_units: 10,
            breakdown: Default::default(),
            comm: Default::default(),
            makespan_ns: 5,
        }
    }

    /// Two in-process "workers" on real sockets: handshake, START barrier,
    /// routed messages both ways, merge collection, BYE.
    #[test]
    fn hub_routes_between_two_workers() {
        let sock = test_sock("route");
        let mut hub = Hub::bind(&sock, &tiny_spec(2)).unwrap();

        let spawn_worker = |rank: usize, sock: std::path::PathBuf| {
            std::thread::spawn(move || -> Result<()> {
                let (spec, mut mb) = connect(&sock, rank)?;
                assert_eq!(spec.p, 2);
                assert_eq!(mb.rank(), rank);
                assert_eq!(mb.size(), 2);
                let peer = 1 - rank;
                mb.send(peer, Msg::WaveDown { t: rank as u64, lambda: 7 });
                // await the peer's message
                let deadline = Instant::now() + Duration::from_secs(10);
                let got = loop {
                    if let Some(got) = mb.try_recv() {
                        break got;
                    }
                    assert!(Instant::now() < deadline, "no message from peer");
                    mb.wait_for_msg(Duration::from_millis(10));
                };
                assert_eq!(got.0, peer, "source must be stamped by the hub");
                assert!(matches!(got.1, Msg::WaveDown { lambda: 7, .. }));
                mb.send_merge(&merge_for(rank as u32))?;
                mb.wait_bye(Duration::from_secs(10))?;
                Ok(())
            })
        };
        let w0 = spawn_worker(0, sock.clone());
        let w1 = spawn_worker(1, sock.clone());

        let deadline = Instant::now() + Duration::from_secs(10);
        while hub.connected() < 2 {
            if !hub.try_accept().unwrap() {
                assert!(Instant::now() < deadline, "workers never connected");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        hub.start_all().unwrap();

        let mut merged = [false; 2];
        while !(merged[0] && merged[1]) {
            match hub.recv_event(Duration::from_secs(10)).unwrap() {
                Some(HubEvent::Merge(m)) => merged[m.rank as usize] = true,
                Some(HubEvent::Gone { rank, detail }) => {
                    panic!("rank {rank} gone before merge: {detail}")
                }
                None => panic!("timed out waiting for merges"),
            }
        }
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        w1.join().unwrap().unwrap();
        hub.join();
    }

    /// GIVE payloads (serialized SearchNodes) survive the hub round trip.
    #[test]
    fn give_tasks_roundtrip_through_hub() {
        let sock = test_sock("give");
        let mut hub = Hub::bind(&sock, &tiny_spec(2)).unwrap();
        let tasks = vec![crate::fabric::WireTask { items: vec![3, 9], core: 9, support: 4 }];
        let sent = tasks.clone();
        let w0 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<()> {
                let (_, mut mb) = connect(&sock, 0)?;
                mb.send(1, Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks } });
                mb.send_merge(&merge_for(0))?;
                mb.wait_bye(Duration::from_secs(10))
            }
        });
        let w1 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<(usize, Msg)> {
                let (_, mut mb) = connect(&sock, 1)?;
                let deadline = Instant::now() + Duration::from_secs(10);
                let got = loop {
                    if let Some(got) = mb.try_recv() {
                        break got;
                    }
                    ensure!(Instant::now() < deadline, "no GIVE arrived");
                    mb.wait_for_msg(Duration::from_millis(10));
                };
                mb.send_merge(&merge_for(1))?;
                mb.wait_bye(Duration::from_secs(10))?;
                Ok(got)
            }
        });
        let deadline = Instant::now() + Duration::from_secs(10);
        while hub.connected() < 2 {
            if !hub.try_accept().unwrap() {
                assert!(Instant::now() < deadline);
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        hub.start_all().unwrap();
        let mut got = 0;
        while got < 2 {
            if let Some(HubEvent::Merge(_)) =
                hub.recv_event(Duration::from_secs(10)).unwrap()
            {
                got += 1;
            }
        }
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        let (src, msg) = w1.join().unwrap().unwrap();
        assert_eq!(src, 0);
        match msg {
            Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks } } => {
                assert_eq!(tasks, sent);
            }
            other => panic!("expected GIVE, got {other:?}"),
        }
        hub.join();
    }

    /// Drive `try_accept` until it yields a definite accept/reject outcome.
    fn accept_outcome(hub: &mut Hub) -> Result<bool> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match hub.try_accept() {
                Ok(false) => {
                    assert!(Instant::now() < deadline, "no pending connection");
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => return other,
            }
        }
    }

    #[test]
    fn hub_rejects_out_of_range_and_duplicate_ranks() {
        let sock = test_sock("badrank");
        let mut hub = Hub::bind(&sock, &tiny_spec(2)).unwrap();
        // out-of-range rank
        let mut s = UnixStream::connect(&sock).unwrap();
        write_frame(&mut s, &Frame::Hello { rank: 9 }).unwrap();
        let err = accept_outcome(&mut hub).expect_err("rank 9 must be rejected");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // duplicate rank: first registration succeeds, second errors
        let mut a = UnixStream::connect(&sock).unwrap();
        write_frame(&mut a, &Frame::Hello { rank: 0 }).unwrap();
        assert!(accept_outcome(&mut hub).unwrap());
        let mut b = UnixStream::connect(&sock).unwrap();
        write_frame(&mut b, &Frame::Hello { rank: 0 }).unwrap();
        let err = accept_outcome(&mut hub).expect_err("duplicate rank must be rejected");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
        assert_eq!(hub.connected(), 1);
    }
}
