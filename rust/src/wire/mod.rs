//! The wire protocol of the process fabric (DESIGN.md §7).
//!
//! Both in-process fabric backends ([`crate::fabric::thread`],
//! [`crate::fabric::sim`]) pass [`Msg`] values through shared memory, which
//! lets every payload stay an ordinary Rust value. The process backend
//! ([`crate::fabric::process`]) cannot: each rank is a separate OS process,
//! so every message the paper's §4 protocol describes — steal
//! request/response with serialized search nodes, DTD wave tokens, the
//! preprocess barrier, the phase-boundary merge — must cross an explicit
//! serialization boundary. This module is that boundary: a small, versioned,
//! length-prefixed binary format with no external dependencies.
//!
//! ## Framing
//!
//! Every frame on a fabric socket is
//!
//! ```text
//! frame   := len:u32  tag:u8  payload
//! ```
//!
//! where `len` counts the tag byte plus the payload, all integers are
//! little-endian, and `len` is capped at [`MAX_FRAME_LEN`] so a corrupt
//! length prefix fails fast instead of allocating gigabytes. The fabric
//! frame types and the message grammar are documented in DESIGN.md §7; the
//! job frames the `parlamp serve` daemon speaks with its clients
//! (`SUBMIT`/`ACCEPTED`/`STATUS`/`RESULT`/`CANCEL`/`SHUTDOWN`/`STATS`,
//! payloads in [`service`]) in DESIGN.md §9 and §13. The encoders/decoders here are the
//! normative implementation for both.
//!
//! ## Versioning
//!
//! [`HELLO`](Frame::Hello), [`CONFIG`](Frame::Config),
//! [`RECONFIG`](Frame::Reconfig), [`PEERHELLO`](Frame::PeerHello), and
//! [`SUBMIT`](Frame::Submit) all carry [`WIRE_VERSION`]. The receiving
//! side rejects a peer whose version differs, so a stale binary on one
//! side of the socket produces one clear error instead of a garbled
//! protocol exchange.

pub mod service;
pub mod trace;

use anyhow::{bail, ensure, Context, Result};
use std::io::{Read, Write};

use crate::db::{Database, Item};
use crate::fabric::{BasicKind, CommStats, HistDelta, Msg, WireTask};
use crate::net::Endpoint;
use crate::par::breakdown::Breakdown;
use crate::par::worker::RunMode;

use service::{JobOutcome, JobSpec, JobState, ServiceStats};

/// First four bytes of every `HELLO` payload ("ParLamp Message Wire").
pub const WIRE_MAGIC: [u8; 4] = *b"PLMW";

/// Protocol version; bump on any change to the frame or message grammar.
/// v2: split `CONFIG` into reusable [`PhaseSpec`] + database, added
/// `RECONFIG` (warm-fleet phase without re-shipping the database) and the
/// `parlamp serve` job frames.
/// v3: the peer-to-peer mesh data plane (DESIGN.md §10) — `HELLO` reports
/// the worker's own data-plane socket path, `CONFIG`/`RECONFIG` carry the
/// peer socket map, and `PEERHELLO`/`PEERMSG` open and carry the direct
/// worker-to-worker connections (epoch-stamped for phase fencing). `MERGE`
/// gains the hub-relayed / direct frame counters.
/// v4: the pluggable stream transport (DESIGN.md §11) — every peer
/// address is a typed [`crate::net::Endpoint`] (`unix:<path>` |
/// `tcp:<host>:<port>`) instead of a raw socket path, and `HELLO` /
/// `PEERHELLO` carry the per-fleet shared-secret token so stray TCP
/// connections are rejected at the handshake.
/// v5: fault-tolerant fleets (DESIGN.md §12) — `START` carries the
/// hub-assigned phase epoch (a respawned rank must join the fleet's
/// numbering, and a replayed phase must get a *fresh* epoch so stale
/// frames from the aborted attempt are fenced out), `MERGE` echoes the
/// epoch it concludes (the owner discards merges from an aborted epoch),
/// and the new worker → hub `CHECKPOINT` frame periodically reports the
/// rank's unfinished stack roots so the hub's custody table can say what
/// a dead rank was holding.
/// v6: multi-fleet serve (DESIGN.md §13) — `SUBMIT` gains the scheduling
/// fields (priority, relative deadline, client identity for fair-share
/// accounting), `STATUS` can report the new `Expired` / `Busy` job states,
/// and the new `STATS` frame queries the daemon's scheduler/cache/store
/// counters ([`ServiceStats`]).
/// v7: end-to-end tracing (DESIGN.md §14) — [`PhaseSpec`] carries the
/// `trace` flag so every worker arms its event ring for exactly the
/// phases the owner wants traced, and the new worker → hub `TRACE` frame
/// ([`trace::TraceChunk`]) flushes the rank's timestamped event ring
/// after `MERGE`, carrying the worker-clock START-receipt and flush
/// stamps the hub's clock-offset estimator pairs with its own.
/// v8: heartbeat liveness (DESIGN.md §15) — the new hub → worker `PING`
/// and worker → hub `PONG` frames (both empty) drive the hub's per-rank
/// lease table, so a rank that is hung or partitioned (its socket open,
/// no EOF ever arriving) is detected by a missed lease instead of
/// stalling the fleet forever. `PONG` is answered by the worker's *main*
/// thread, so it attests whole-worker liveness, not just the reader
/// thread's.
pub const WIRE_VERSION: u16 = 8;

/// Upper bound on `len` (tag + payload) of a single frame: 256 MiB.
pub const MAX_FRAME_LEN: u32 = 256 << 20;

/// Sanity cap on decoded database dimensions (items and transactions).
/// Far above any Table-1-scale problem, far below header values whose
/// decode-side allocations could hurt (a corrupt `n_trans` would otherwise
/// drive gigabyte allocations before any per-element bounds check runs —
/// transactions, unlike every other variable-length list in the format,
/// can legitimately occupy zero payload bytes, so they cannot be validated
/// against the remaining byte count alone).
pub const MAX_DB_DIM: u32 = 1 << 24;

const TAG_HELLO: u8 = 0x01;
const TAG_CONFIG: u8 = 0x02;
const TAG_RELAY: u8 = 0x03;
const TAG_MERGE: u8 = 0x04;
const TAG_BYE: u8 = 0x05;
const TAG_START: u8 = 0x06;
const TAG_RECONFIG: u8 = 0x07;
// Mesh data plane (worker ↔ worker direct connections, DESIGN.md §10).
const TAG_PEERHELLO: u8 = 0x08;
const TAG_PEERMSG: u8 = 0x09;
// Fault tolerance (custody checkpoints, DESIGN.md §12).
const TAG_CHECKPOINT: u8 = 0x0A;
// Observability (post-MERGE trace-ring flush, DESIGN.md §14).
const TAG_TRACE: u8 = 0x0B;
// Heartbeat liveness (hub → worker PING, worker → hub PONG, DESIGN.md §15).
const TAG_PING: u8 = 0x0C;
const TAG_PONG: u8 = 0x0D;
// Job frames (the `parlamp serve` client protocol, DESIGN.md §9) live in
// a disjoint tag range so fabric and service streams can never be confused.
const TAG_SUBMIT: u8 = 0x10;
const TAG_ACCEPTED: u8 = 0x11;
const TAG_STATUS: u8 = 0x12;
const TAG_RESULT: u8 = 0x13;
const TAG_CANCEL: u8 = 0x14;
const TAG_SHUTDOWN: u8 = 0x15;
const TAG_STATS: u8 = 0x16;

/// Per-phase worker parameterization: the exact [`crate::par::WorkerConfig`]
/// surface minus rank (which the worker already knows) and minus the
/// database (which ships once per dataset in `CONFIG` and is *reused* by
/// `RECONFIG`, so a warm fleet pays the serialization cost only when the
/// data actually changes).
#[derive(Clone, Debug)]
pub struct PhaseSpec {
    /// World size.
    pub p: u32,
    /// Base RNG seed (each worker folds in its rank).
    pub seed: u64,
    /// Random steal attempts `w`.
    pub w: u32,
    /// Lifeline hypercube edge length `l`.
    pub l: u32,
    /// Mattern DTD spanning-tree arity.
    pub tree_arity: u32,
    /// `false` = naive static-partition baseline.
    pub steal: bool,
    /// Depth-1 preprocess partition (already `p > 1`-gated by the hub).
    pub preprocess: bool,
    /// v7: arm the worker's per-rank event ring for this phase and flush
    /// it to the hub as a `TRACE` frame after `MERGE`.
    pub trace: bool,
    /// Expansion cost units between probes.
    pub probe_budget_units: u64,
    /// DTD wave cadence in nanoseconds.
    pub dtd_interval_ns: u64,
    /// Phase being run.
    pub mode: RunMode,
}

/// The `CONFIG` frame payload: a [`PhaseSpec`] plus the database itself,
/// shipped vertically (per-item occurrence index lists + the positive-class
/// mask), so a worker process needs no filesystem access to participate.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub phase: PhaseSpec,
    pub db: Database,
}

/// One worker's phase-boundary contribution, shipped in the `MERGE` frame:
/// everything the in-process engines read off a local [`crate::par::Worker`]
/// after DTD quiescence when they merge a phase.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerMerge {
    pub rank: u32,
    /// The phase epoch this merge concludes (v5). The fleet owner drops
    /// merges whose epoch is not the one it is collecting — after a
    /// mid-phase worker loss the aborted epoch's stragglers must not be
    /// mistaken for contributions to the replayed one.
    pub epoch: u64,
    /// Sparse closed-set histogram (support, count).
    pub hist: HistDelta,
    pub closed_count: u64,
    /// Total expansion work units — word-op equivalents including the
    /// conditional-database reduction work (`ExpandStats::units`).
    pub work_units: u64,
    pub breakdown: Breakdown,
    pub comm: CommStats,
    /// The worker's own wall-clock span from `CONFIG` receipt to `Finish`.
    pub makespan_ns: u64,
}

/// Everything that crosses a process-fabric or service socket.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Worker → hub, first frame after connect: magic, version, own rank,
    /// the fleet's shared-secret token (checked by the hub before the
    /// rank joins; a stray or stale connection is rejected here), and the
    /// endpoint of the worker's own data-plane listener (used when the
    /// hub selects the mesh data plane, DESIGN.md §10-§11).
    Hello { rank: u32, token: String, peer: Endpoint },
    /// Hub → worker: the phase specification plus the database. Sent once
    /// per dataset; subsequent phases over the same data use `Reconfig`.
    /// `peers` is the peer endpoint map (one endpoint per rank) when this
    /// phase runs on the mesh data plane; empty = hub-relayed data plane.
    Config { spec: Box<RunSpec>, peers: Vec<Endpoint> },
    /// Hub → worker: a new phase over the database shipped by the most
    /// recent `Config` — the warm-fleet fast path (no database bytes).
    /// `peers` as in `Config`.
    Reconfig { phase: Box<PhaseSpec>, peers: Vec<Endpoint> },
    /// Worker → worker, first frame on a direct mesh connection: magic,
    /// version, the *sender's* rank, and the fleet token (checked by the
    /// receiving worker before the link carries any data-plane traffic).
    PeerHello { rank: u32, token: String },
    /// Worker → worker direct data-plane message: the sender's rank (must
    /// match the connection's `PeerHello`), the sender's phase index
    /// (epoch), and the protocol message. The epoch fences phases — mesh
    /// sockets carry no CONFIG/START ordering, so the receiver drops
    /// frames from finished phases and buffers frames from a phase it has
    /// not started yet (DESIGN.md §10); `Relay` carries the same fence on
    /// the hub plane.
    PeerMsg { src: u32, epoch: u64, msg: Msg },
    /// Hub → worker once *every* rank has completed the handshake: begin
    /// the phase. Separating `START` from `CONFIG` gives the run an MPI-like
    /// startup barrier, so no worker can send steal traffic toward a rank
    /// that has not yet registered with the hub. `epoch` (v5) is the
    /// hub-assigned phase index: a respawned worker inherits the fleet's
    /// numbering from it instead of counting its own phases, and a replayed
    /// phase gets a fresh epoch so mesh frames and merges from the aborted
    /// attempt are fenced out (DESIGN.md §12).
    Start { epoch: u64 },
    /// Worker → hub, periodically during a phase: the rank's current
    /// unfinished [`WireTask`] stack roots (bottom of the DFS stack =
    /// largest subtrees), its epoch, and its work-unit clock. Feeds the
    /// hub's custody table so a `Gone` rank's loss is diagnosable — what it
    /// held, how far it got — without any reply traffic (DESIGN.md §12).
    Checkpoint { rank: u32, epoch: u64, work_units: u64, roots: Vec<WireTask> },
    /// Routed protocol message. Worker → hub: `peer` is the *destination*
    /// rank. Hub → worker: `peer` is the *source* rank. `epoch` (v5) is
    /// the sender's phase epoch, carried through the relay unchanged: hub
    /// socket FIFO alone fenced phases when phases could only end with
    /// every merge collected, but a mid-phase abort (DESIGN.md §12) can
    /// leave a survivor's stale relay racing the hub's own RECONFIG, so
    /// hub-plane deliveries are epoch-fenced exactly like `PeerMsg`.
    Relay { peer: u32, epoch: u64, msg: Msg },
    /// Worker → hub after `Finish`: the phase-boundary merge payload.
    Merge(Box<WorkerMerge>),
    /// Worker → hub after `Merge`, only when the phase was traced (v7):
    /// the rank's flushed event ring plus the worker-clock stamps
    /// (START receipt, flush time) the hub pairs with its own clock for
    /// offset estimation (DESIGN.md §14). Best-effort: a lost TRACE
    /// costs a timeline, never a result.
    Trace(Box<trace::TraceChunk>),
    /// Hub → worker heartbeat probe (v8, empty payload): "prove the whole
    /// worker is alive". Answered with `Pong` from the worker's *main*
    /// thread, so a rank whose reader still drains frames but whose main
    /// thread is hung or partitioned still misses its lease (DESIGN.md
    /// §15). Pure control traffic — never counted as a data-plane frame.
    Ping,
    /// Worker → hub heartbeat answer (v8, empty payload). Refreshes the
    /// rank's lease in the hub table; absorbed by the route thread, never
    /// forwarded.
    Pong,
    /// Hub → worker: no further phases; exit cleanly.
    Bye,
    /// Client → daemon: submit a mining job (parameters + database).
    Submit(Box<JobSpec>),
    /// Daemon → client, in response to `Submit`: the assigned job id.
    Accepted { job_id: u64 },
    /// Job-state exchange. Client → daemon with `report: None` is a query;
    /// the daemon answers with `report: Some(state)`.
    Status { job_id: u64, report: Option<JobState> },
    /// Result exchange. Client → daemon with `report: None` requests the
    /// outcome (the daemon blocks the reply until the job is terminal);
    /// daemon → client carries it.
    JobResult { job_id: u64, report: Option<Box<JobOutcome>> },
    /// Client → daemon: remove a *pending* job from the queue. Answered
    /// with `Status` reporting the job's resulting state.
    Cancel { job_id: u64 },
    /// Client → daemon: drain the queue, dismiss the fleet, exit. Echoed
    /// back as the acknowledgment.
    Shutdown,
    /// Daemon statistics exchange (v6). Client → daemon with
    /// `report: None` is a query; the daemon answers with the current
    /// [`ServiceStats`] snapshot.
    Stats { report: Option<Box<ServiceStats>> },
}

impl Frame {
    /// Short frame-type name for diagnostics (the `Debug` form of `Config`
    /// or `Submit` would print the entire database).
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "HELLO",
            Frame::Config { .. } => "CONFIG",
            Frame::Reconfig { .. } => "RECONFIG",
            Frame::PeerHello { .. } => "PEERHELLO",
            Frame::PeerMsg { .. } => "PEERMSG",
            Frame::Start { .. } => "START",
            Frame::Checkpoint { .. } => "CHECKPOINT",
            Frame::Relay { .. } => "RELAY",
            Frame::Merge(_) => "MERGE",
            Frame::Trace(_) => "TRACE",
            Frame::Ping => "PING",
            Frame::Pong => "PONG",
            Frame::Bye => "BYE",
            Frame::Submit(_) => "SUBMIT",
            Frame::Accepted { .. } => "ACCEPTED",
            Frame::Status { .. } => "STATUS",
            Frame::JobResult { .. } => "RESULT",
            Frame::Cancel { .. } => "CANCEL",
            Frame::Shutdown => "SHUTDOWN",
            Frame::Stats { .. } => "STATS",
        }
    }
}

// ---- primitive put/get -----------------------------------------------------

fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

fn put_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_i64(buf: &mut Vec<u8>, v: i64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    buf.extend_from_slice(&v.to_bits().to_le_bytes());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(u8::from(v));
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

/// Cursor over a received payload. Every accessor bounds-checks, so a
/// truncated or corrupt frame decodes to an error, never a panic.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Dec { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "wire: truncated payload (need {n} bytes at offset {}, have {})",
            self.pos,
            self.buf.len() - self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("wire: bad bool byte {b:#x}"),
        }
    }

    fn str(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| anyhow::anyhow!("wire: bad utf-8: {e}"))
    }

    /// Validate a count prefix against the bytes actually remaining, so a
    /// corrupt count cannot drive a huge allocation.
    fn count(&mut self, min_elem_bytes: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        ensure!(
            n.saturating_mul(min_elem_bytes) <= self.buf.len() - self.pos,
            "wire: count {n} exceeds remaining payload"
        );
        Ok(n)
    }

    fn finish(&self) -> Result<()> {
        ensure!(
            self.pos == self.buf.len(),
            "wire: {} trailing bytes after payload",
            self.buf.len() - self.pos
        );
        Ok(())
    }
}

// ---- message grammar -------------------------------------------------------

const MSG_REQUEST: u8 = 0;
const MSG_REJECT: u8 = 1;
const MSG_GIVE: u8 = 2;
const MSG_WAVE_DOWN: u8 = 3;
const MSG_WAVE_UP: u8 = 4;
const MSG_PRE_UP: u8 = 5;
const MSG_PRE_DOWN: u8 = 6;
const MSG_FINISH: u8 = 7;

fn put_hist(buf: &mut Vec<u8>, hist: &HistDelta) {
    put_u32(buf, hist.len() as u32);
    for &(s, c) in hist {
        put_u32(buf, s);
        put_u64(buf, c);
    }
}

fn get_hist(d: &mut Dec) -> Result<HistDelta> {
    let n = d.count(12)?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let s = d.u32()?;
        let c = d.u64()?;
        out.push((s, c));
    }
    Ok(out)
}

fn put_task(buf: &mut Vec<u8>, t: &WireTask) {
    put_u32(buf, t.items.len() as u32);
    for &i in &t.items {
        put_u32(buf, i);
    }
    put_i64(buf, t.core);
    put_u32(buf, t.support);
}

fn get_task(d: &mut Dec) -> Result<WireTask> {
    let n = d.count(4)?;
    let mut items = Vec::with_capacity(n);
    for _ in 0..n {
        items.push(d.u32()? as Item);
    }
    let core = d.i64()?;
    let support = d.u32()?;
    Ok(WireTask { items, core, support })
}

/// Serialize one protocol message (the body of a `RELAY` frame).
pub fn put_msg(buf: &mut Vec<u8>, msg: &Msg) {
    match msg {
        Msg::Basic { stamp, kind } => match kind {
            BasicKind::Request { lifeline } => {
                put_u8(buf, MSG_REQUEST);
                put_u64(buf, *stamp);
                put_bool(buf, *lifeline);
            }
            BasicKind::Reject { lifeline } => {
                put_u8(buf, MSG_REJECT);
                put_u64(buf, *stamp);
                put_bool(buf, *lifeline);
            }
            BasicKind::Give { tasks } => {
                put_u8(buf, MSG_GIVE);
                put_u64(buf, *stamp);
                put_u32(buf, tasks.len() as u32);
                for t in tasks {
                    put_task(buf, t);
                }
            }
        },
        Msg::WaveDown { t, lambda } => {
            put_u8(buf, MSG_WAVE_DOWN);
            put_u64(buf, *t);
            put_u32(buf, *lambda);
        }
        Msg::WaveUp { t, count, invalid, all_idle, hist } => {
            put_u8(buf, MSG_WAVE_UP);
            put_u64(buf, *t);
            put_i64(buf, *count);
            put_bool(buf, *invalid);
            put_bool(buf, *all_idle);
            put_hist(buf, hist);
        }
        Msg::PreUp { hist } => {
            put_u8(buf, MSG_PRE_UP);
            put_hist(buf, hist);
        }
        Msg::PreDown { lambda } => {
            put_u8(buf, MSG_PRE_DOWN);
            put_u32(buf, *lambda);
        }
        Msg::Finish => put_u8(buf, MSG_FINISH),
    }
}

fn get_msg(d: &mut Dec) -> Result<Msg> {
    let kind = d.u8()?;
    Ok(match kind {
        MSG_REQUEST => Msg::Basic {
            stamp: d.u64()?,
            kind: BasicKind::Request { lifeline: d.bool()? },
        },
        MSG_REJECT => Msg::Basic {
            stamp: d.u64()?,
            kind: BasicKind::Reject { lifeline: d.bool()? },
        },
        MSG_GIVE => {
            let stamp = d.u64()?;
            let n = d.count(16)?;
            let mut tasks = Vec::with_capacity(n);
            for _ in 0..n {
                tasks.push(get_task(d)?);
            }
            Msg::Basic { stamp, kind: BasicKind::Give { tasks } }
        }
        MSG_WAVE_DOWN => Msg::WaveDown { t: d.u64()?, lambda: d.u32()? },
        MSG_WAVE_UP => Msg::WaveUp {
            t: d.u64()?,
            count: d.i64()?,
            invalid: d.bool()?,
            all_idle: d.bool()?,
            hist: get_hist(d)?,
        },
        MSG_PRE_UP => Msg::PreUp { hist: get_hist(d)? },
        MSG_PRE_DOWN => Msg::PreDown { lambda: d.u32()? },
        MSG_FINISH => Msg::Finish,
        other => bail!("wire: unknown message kind {other:#x}"),
    })
}

// ---- database --------------------------------------------------------------

/// Serialize the database vertically: the positive-class mask plus one
/// occurrence index list per item. Dense bitmaps would also work, but index
/// lists match the generator densities (a few percent) and keep the format
/// independent of the in-memory word layout.
fn put_db(buf: &mut Vec<u8>, db: &Database) {
    put_u32(buf, db.n_items() as u32);
    put_u32(buf, db.n_trans() as u32);
    let pos: Vec<usize> = db.pos_mask().iter_ones().collect();
    put_u32(buf, pos.len() as u32);
    for t in pos {
        put_u32(buf, t as u32);
    }
    for i in 0..db.n_items() as Item {
        let col = db.col(i);
        put_u32(buf, col.count());
        for t in col.iter_ones() {
            put_u32(buf, t as u32);
        }
    }
}

fn get_db(d: &mut Dec) -> Result<Database> {
    let n_items = d.u32()?;
    let n_trans = d.u32()?;
    ensure!(n_items <= MAX_DB_DIM, "wire: database item count {n_items} exceeds {MAX_DB_DIM}");
    ensure!(
        n_trans <= MAX_DB_DIM,
        "wire: database transaction count {n_trans} exceeds {MAX_DB_DIM}"
    );
    // Each item contributes at least its 4-byte occurrence-count prefix, so
    // the item count is additionally bounded by the payload that remains.
    ensure!(
        (n_items as usize).saturating_mul(4) <= d.buf.len() - d.pos,
        "wire: database item count {n_items} exceeds remaining payload"
    );
    let n_items = n_items as usize;
    let n_trans = n_trans as usize;
    let n_pos = d.count(4)?;
    let mut positive = vec![false; n_trans];
    for _ in 0..n_pos {
        let t = d.u32()? as usize;
        ensure!(t < n_trans, "wire: positive index {t} out of range {n_trans}");
        positive[t] = true;
    }
    let mut trans: Vec<Vec<Item>> = vec![Vec::new(); n_trans];
    for i in 0..n_items as Item {
        let k = d.count(4)?;
        for _ in 0..k {
            let t = d.u32()? as usize;
            ensure!(t < n_trans, "wire: occurrence index {t} out of range {n_trans}");
            trans[t].push(i);
        }
    }
    Ok(Database::from_transactions(n_items, &trans, &positive))
}

// ---- run spec / merge ------------------------------------------------------

const MODE_PHASE1: u8 = 0;
const MODE_COUNT: u8 = 1;

fn put_mode(buf: &mut Vec<u8>, mode: &RunMode) {
    match mode {
        RunMode::Phase1 { alpha } => {
            put_u8(buf, MODE_PHASE1);
            put_f64(buf, *alpha);
        }
        RunMode::Count { min_sup } => {
            put_u8(buf, MODE_COUNT);
            put_u32(buf, *min_sup);
        }
    }
}

fn get_mode(d: &mut Dec) -> Result<RunMode> {
    match d.u8()? {
        MODE_PHASE1 => Ok(RunMode::Phase1 { alpha: d.f64()? }),
        MODE_COUNT => Ok(RunMode::Count { min_sup: d.u32()? }),
        other => bail!("wire: unknown run mode {other:#x}"),
    }
}

/// Shared by `CONFIG`, `RECONFIG`: version prefix + the phase fields.
fn put_phase(buf: &mut Vec<u8>, phase: &PhaseSpec) {
    put_u16(buf, WIRE_VERSION);
    put_u32(buf, phase.p);
    put_u64(buf, phase.seed);
    put_u32(buf, phase.w);
    put_u32(buf, phase.l);
    put_u32(buf, phase.tree_arity);
    put_bool(buf, phase.steal);
    put_bool(buf, phase.preprocess);
    put_bool(buf, phase.trace);
    put_u64(buf, phase.probe_budget_units);
    put_u64(buf, phase.dtd_interval_ns);
    put_mode(buf, &phase.mode);
}

fn get_phase(d: &mut Dec) -> Result<PhaseSpec> {
    let version = d.u16()?;
    ensure!(
        version == WIRE_VERSION,
        "wire: CONFIG version {version} != supported {WIRE_VERSION}"
    );
    Ok(PhaseSpec {
        p: d.u32()?,
        seed: d.u64()?,
        w: d.u32()?,
        l: d.u32()?,
        tree_arity: d.u32()?,
        steal: d.bool()?,
        preprocess: d.bool()?,
        trace: d.bool()?,
        probe_budget_units: d.u64()?,
        dtd_interval_ns: d.u64()?,
        mode: get_mode(d)?,
    })
}

/// The peer endpoint map carried by `CONFIG`/`RECONFIG`: one endpoint per
/// rank in rank order, or empty for the hub-relayed data plane. Endpoints
/// cross the wire in their display form (`unix:<path>` |
/// `tcp:<host>:<port>`), which parses back exactly.
fn put_peers(buf: &mut Vec<u8>, peers: &[Endpoint]) {
    put_u32(buf, peers.len() as u32);
    for p in peers {
        put_str(buf, &p.to_string());
    }
}

fn get_peers(d: &mut Dec) -> Result<Vec<Endpoint>> {
    // Each entry carries at least its 4-byte length prefix, so the count
    // is validated against the remaining payload before any allocation.
    let n = d.count(4)?;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let s = d.str()?;
        out.push(
            s.parse::<Endpoint>()
                .with_context(|| format!("wire: bad peer endpoint for rank {i}"))?,
        );
    }
    Ok(out)
}

/// `CONFIG` payload: phase, peer map, then the database — the small
/// header fields first, the bulk payload last.
fn put_spec(buf: &mut Vec<u8>, spec: &RunSpec, peers: &[Endpoint]) {
    put_phase(buf, &spec.phase);
    put_peers(buf, peers);
    put_db(buf, &spec.db);
}

fn put_merge(buf: &mut Vec<u8>, m: &WorkerMerge) {
    put_u32(buf, m.rank);
    put_u64(buf, m.epoch);
    put_hist(buf, &m.hist);
    put_u64(buf, m.closed_count);
    put_u64(buf, m.work_units);
    put_u64(buf, m.breakdown.preprocess_ns);
    put_u64(buf, m.breakdown.main_ns);
    put_u64(buf, m.breakdown.probe_ns);
    put_u64(buf, m.breakdown.idle_ns);
    put_u64(buf, m.comm.sent);
    put_u64(buf, m.comm.received);
    put_u64(buf, m.comm.steal_requests);
    put_u64(buf, m.comm.rejects);
    put_u64(buf, m.comm.gives);
    put_u64(buf, m.comm.tasks_shipped);
    put_u64(buf, m.comm.bytes_sent);
    put_u64(buf, m.comm.hub_frames);
    put_u64(buf, m.comm.direct_frames);
    put_u64(buf, m.makespan_ns);
}

fn get_merge(d: &mut Dec) -> Result<WorkerMerge> {
    Ok(WorkerMerge {
        rank: d.u32()?,
        epoch: d.u64()?,
        hist: get_hist(d)?,
        closed_count: d.u64()?,
        work_units: d.u64()?,
        breakdown: Breakdown {
            preprocess_ns: d.u64()?,
            main_ns: d.u64()?,
            probe_ns: d.u64()?,
            idle_ns: d.u64()?,
        },
        comm: CommStats {
            sent: d.u64()?,
            received: d.u64()?,
            steal_requests: d.u64()?,
            rejects: d.u64()?,
            gives: d.u64()?,
            tasks_shipped: d.u64()?,
            bytes_sent: d.u64()?,
            hub_frames: d.u64()?,
            direct_frames: d.u64()?,
        },
        makespan_ns: d.u64()?,
    })
}

// ---- frame encode / decode -------------------------------------------------

impl Frame {
    /// Encode into a complete frame (length prefix included).
    pub fn encode(&self) -> Vec<u8> {
        let mut body = Vec::new();
        match self {
            Frame::Hello { rank, token, peer } => {
                put_u8(&mut body, TAG_HELLO);
                body.extend_from_slice(&WIRE_MAGIC);
                put_u16(&mut body, WIRE_VERSION);
                put_u32(&mut body, *rank);
                put_str(&mut body, token);
                put_str(&mut body, &peer.to_string());
            }
            Frame::Config { spec, peers } => {
                put_u8(&mut body, TAG_CONFIG);
                put_spec(&mut body, spec, peers);
            }
            Frame::Reconfig { phase, peers } => {
                put_u8(&mut body, TAG_RECONFIG);
                put_phase(&mut body, phase);
                put_peers(&mut body, peers);
            }
            Frame::PeerHello { rank, token } => {
                put_u8(&mut body, TAG_PEERHELLO);
                body.extend_from_slice(&WIRE_MAGIC);
                put_u16(&mut body, WIRE_VERSION);
                put_u32(&mut body, *rank);
                put_str(&mut body, token);
            }
            Frame::PeerMsg { src, epoch, msg } => {
                put_u8(&mut body, TAG_PEERMSG);
                put_u32(&mut body, *src);
                put_u64(&mut body, *epoch);
                put_msg(&mut body, msg);
            }
            Frame::Start { epoch } => {
                put_u8(&mut body, TAG_START);
                put_u64(&mut body, *epoch);
            }
            Frame::Checkpoint { rank, epoch, work_units, roots } => {
                put_u8(&mut body, TAG_CHECKPOINT);
                put_u32(&mut body, *rank);
                put_u64(&mut body, *epoch);
                put_u64(&mut body, *work_units);
                put_u32(&mut body, roots.len() as u32);
                for t in roots {
                    put_task(&mut body, t);
                }
            }
            Frame::Relay { peer, epoch, msg } => {
                put_u8(&mut body, TAG_RELAY);
                put_u32(&mut body, *peer);
                put_u64(&mut body, *epoch);
                put_msg(&mut body, msg);
            }
            Frame::Merge(m) => {
                put_u8(&mut body, TAG_MERGE);
                put_merge(&mut body, m);
            }
            Frame::Trace(chunk) => {
                put_u8(&mut body, TAG_TRACE);
                trace::put_trace_chunk(&mut body, chunk);
            }
            Frame::Ping => put_u8(&mut body, TAG_PING),
            Frame::Pong => put_u8(&mut body, TAG_PONG),
            Frame::Bye => put_u8(&mut body, TAG_BYE),
            Frame::Submit(spec) => {
                put_u8(&mut body, TAG_SUBMIT);
                service::put_job_spec(&mut body, spec);
            }
            Frame::Accepted { job_id } => {
                put_u8(&mut body, TAG_ACCEPTED);
                put_u64(&mut body, *job_id);
            }
            Frame::Status { job_id, report } => {
                put_u8(&mut body, TAG_STATUS);
                put_u64(&mut body, *job_id);
                match report {
                    None => put_u8(&mut body, 0),
                    Some(state) => {
                        put_u8(&mut body, 1);
                        service::put_job_state(&mut body, state);
                    }
                }
            }
            Frame::JobResult { job_id, report } => {
                put_u8(&mut body, TAG_RESULT);
                put_u64(&mut body, *job_id);
                match report {
                    None => put_u8(&mut body, 0),
                    Some(outcome) => {
                        put_u8(&mut body, 1);
                        service::put_job_outcome(&mut body, outcome);
                    }
                }
            }
            Frame::Cancel { job_id } => {
                put_u8(&mut body, TAG_CANCEL);
                put_u64(&mut body, *job_id);
            }
            Frame::Shutdown => put_u8(&mut body, TAG_SHUTDOWN),
            Frame::Stats { report } => {
                put_u8(&mut body, TAG_STATS);
                match report {
                    None => put_u8(&mut body, 0),
                    Some(stats) => {
                        put_u8(&mut body, 1);
                        service::put_service_stats(&mut body, stats);
                    }
                }
            }
        }
        debug_assert!(body.len() <= MAX_FRAME_LEN as usize);
        let mut out = Vec::with_capacity(4 + body.len());
        out.extend_from_slice(&(body.len() as u32).to_le_bytes());
        out.extend_from_slice(&body);
        out
    }

    /// Decode from a frame body (tag + payload, length prefix already
    /// stripped).
    pub fn decode(body: &[u8]) -> Result<Frame> {
        let mut d = Dec::new(body);
        let tag = d.u8()?;
        let frame = match tag {
            TAG_HELLO => {
                let magic = d.take(4)?;
                ensure!(magic == WIRE_MAGIC, "wire: bad HELLO magic {magic:02x?}");
                let version = d.u16()?;
                ensure!(
                    version == WIRE_VERSION,
                    "wire: HELLO version {version} != supported {WIRE_VERSION}"
                );
                let rank = d.u32()?;
                let token = d.str()?;
                let peer = d
                    .str()?
                    .parse::<Endpoint>()
                    .context("wire: bad HELLO peer endpoint")?;
                Frame::Hello { rank, token, peer }
            }
            TAG_CONFIG => {
                let phase = get_phase(&mut d)?;
                let peers = get_peers(&mut d)?;
                let db = get_db(&mut d)?;
                Frame::Config { spec: Box::new(RunSpec { phase, db }), peers }
            }
            TAG_RECONFIG => {
                let phase = Box::new(get_phase(&mut d)?);
                let peers = get_peers(&mut d)?;
                Frame::Reconfig { phase, peers }
            }
            TAG_PEERHELLO => {
                let magic = d.take(4)?;
                ensure!(magic == WIRE_MAGIC, "wire: bad PEERHELLO magic {magic:02x?}");
                let version = d.u16()?;
                ensure!(
                    version == WIRE_VERSION,
                    "wire: PEERHELLO version {version} != supported {WIRE_VERSION}"
                );
                Frame::PeerHello { rank: d.u32()?, token: d.str()? }
            }
            TAG_PEERMSG => Frame::PeerMsg {
                src: d.u32()?,
                epoch: d.u64()?,
                msg: get_msg(&mut d)?,
            },
            TAG_START => Frame::Start { epoch: d.u64()? },
            TAG_CHECKPOINT => {
                let rank = d.u32()?;
                let epoch = d.u64()?;
                let work_units = d.u64()?;
                // Each root carries at least its item count (4), core (8),
                // and support (4), so the count is validated against the
                // remaining payload before any allocation.
                let n = d.count(16)?;
                let mut roots = Vec::with_capacity(n);
                for _ in 0..n {
                    roots.push(get_task(&mut d)?);
                }
                Frame::Checkpoint { rank, epoch, work_units, roots }
            }
            TAG_RELAY => Frame::Relay { peer: d.u32()?, epoch: d.u64()?, msg: get_msg(&mut d)? },
            TAG_MERGE => Frame::Merge(Box::new(get_merge(&mut d)?)),
            TAG_TRACE => Frame::Trace(Box::new(trace::get_trace_chunk(&mut d)?)),
            TAG_PING => Frame::Ping,
            TAG_PONG => Frame::Pong,
            TAG_BYE => Frame::Bye,
            TAG_SUBMIT => Frame::Submit(Box::new(service::get_job_spec(&mut d)?)),
            TAG_ACCEPTED => Frame::Accepted { job_id: d.u64()? },
            TAG_STATUS => {
                let job_id = d.u64()?;
                let report = match d.u8()? {
                    0 => None,
                    1 => Some(service::get_job_state(&mut d)?),
                    b => bail!("wire: bad STATUS presence byte {b:#x}"),
                };
                Frame::Status { job_id, report }
            }
            TAG_RESULT => {
                let job_id = d.u64()?;
                let report = match d.u8()? {
                    0 => None,
                    1 => Some(Box::new(service::get_job_outcome(&mut d)?)),
                    b => bail!("wire: bad RESULT presence byte {b:#x}"),
                };
                Frame::JobResult { job_id, report }
            }
            TAG_CANCEL => Frame::Cancel { job_id: d.u64()? },
            TAG_SHUTDOWN => Frame::Shutdown,
            TAG_STATS => {
                let report = match d.u8()? {
                    0 => None,
                    1 => Some(Box::new(service::get_service_stats(&mut d)?)),
                    b => bail!("wire: bad STATS presence byte {b:#x}"),
                };
                Frame::Stats { report }
            }
            other => bail!("wire: unknown frame tag {other:#x}"),
        };
        d.finish()?;
        Ok(frame)
    }
}

/// Pre-encode the `CONFIG` frame from a borrowed spec (the hub sends the
/// identical bytes to every worker; this avoids cloning the database just
/// to feed an owned [`Frame`]). `peers` is the mesh peer endpoint map, or
/// empty for the hub-relayed data plane.
pub fn encode_config(spec: &RunSpec, peers: &[Endpoint]) -> Vec<u8> {
    let mut body = vec![TAG_CONFIG];
    put_spec(&mut body, spec, peers);
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Write one frame to a stream (a single `write_all`; Unix-socket writes of
/// a frame this size are atomic enough that no explicit flush protocol is
/// needed). Refuses frames over [`MAX_FRAME_LEN`] — the receiver would
/// reject them anyway, and past `u32::MAX` the length prefix would wrap and
/// desynchronize the stream.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    let bytes = frame.encode();
    if bytes.len() - 4 > MAX_FRAME_LEN as usize {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame length {} exceeds {MAX_FRAME_LEN}", bytes.len() - 4),
        ));
    }
    w.write_all(&bytes)
}

/// Read one frame, blocking. Returns `Ok(None)` on a clean EOF *at a frame
/// boundary* (the peer closed its socket between frames); any mid-frame EOF
/// or malformed content is an error.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Frame>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                bail!("wire: EOF inside frame length prefix");
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e).context("wire: read length prefix"),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    ensure!(len >= 1, "wire: zero-length frame");
    ensure!(len <= MAX_FRAME_LEN, "wire: frame length {len} exceeds {MAX_FRAME_LEN}");
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body).context("wire: read frame body")?;
    Frame::decode(&body).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = f.encode();
        let mut cursor = &bytes[..];
        let got = read_frame(&mut cursor).expect("decode").expect("not EOF");
        assert!(cursor.is_empty(), "decoder must consume the whole frame");
        got
    }

    fn roundtrip_msg(m: &Msg) -> Msg {
        match roundtrip(&Frame::Relay { peer: 3, epoch: 9, msg: m.clone() }) {
            Frame::Relay { peer, epoch, msg } => {
                assert_eq!((peer, epoch), (3, 9));
                msg
            }
            other => panic!("wrong frame: {other:?}"),
        }
    }

    #[test]
    fn every_msg_variant_roundtrips() {
        let msgs = vec![
            Msg::Basic { stamp: 7, kind: BasicKind::Request { lifeline: true } },
            Msg::Basic { stamp: 8, kind: BasicKind::Reject { lifeline: false } },
            Msg::Basic {
                stamp: u64::MAX,
                kind: BasicKind::Give {
                    tasks: vec![
                        WireTask { items: vec![0, 5, 9], core: 5, support: 12 },
                        WireTask { items: vec![], core: -1, support: 0 },
                    ],
                },
            },
            Msg::WaveDown { t: 3, lambda: 42 },
            Msg::WaveUp {
                t: 3,
                count: -17,
                invalid: true,
                all_idle: false,
                hist: vec![(2, 10), (9, 1)],
            },
            Msg::PreUp { hist: vec![(1, 1_000_000)] },
            Msg::PreDown { lambda: 6 },
            Msg::Finish,
        ];
        for m in &msgs {
            assert_eq!(&roundtrip_msg(m), m, "{m:?}");
        }
    }

    #[test]
    fn random_messages_roundtrip() {
        forall("wire msg roundtrip", 64, |rng| {
            let m = random_msg(rng);
            let got = roundtrip_msg(&m);
            if got != m {
                return Err(format!("{m:?} -> {got:?}"));
            }
            Ok(())
        });
    }

    fn random_msg(rng: &mut Rng) -> Msg {
        match rng.index(6) {
            0 => Msg::Basic {
                stamp: rng.next_u64(),
                kind: BasicKind::Request { lifeline: rng.bernoulli(0.5) },
            },
            1 => Msg::Basic {
                stamp: rng.next_u64(),
                kind: BasicKind::Reject { lifeline: rng.bernoulli(0.5) },
            },
            2 => {
                let tasks = (0..rng.index(5))
                    .map(|_| WireTask {
                        items: (0..rng.index(20)).map(|_| rng.below(1 << 20) as Item).collect(),
                        core: rng.below(100) as i64 - 1,
                        support: rng.below(1 << 16) as u32,
                    })
                    .collect();
                Msg::Basic { stamp: rng.next_u64(), kind: BasicKind::Give { tasks } }
            }
            3 => Msg::WaveDown { t: rng.next_u64(), lambda: rng.below(1 << 20) as u32 },
            4 => Msg::WaveUp {
                t: rng.next_u64(),
                count: rng.below(1 << 30) as i64 - (1 << 29),
                invalid: rng.bernoulli(0.5),
                all_idle: rng.bernoulli(0.5),
                hist: (0..rng.index(8)).map(|_| (rng.below(100) as u32, rng.next_u64())).collect(),
            },
            _ => Msg::PreUp {
                hist: (0..rng.index(8)).map(|_| (rng.below(100) as u32, rng.next_u64())).collect(),
            },
        }
    }

    #[test]
    fn hello_start_and_bye_roundtrip() {
        // Both transports survive the HELLO roundtrip with the token.
        for peer in
            [Endpoint::unix("/tmp/hub.sock.r11"), Endpoint::tcp("198.51.100.7", 9131)]
        {
            let sent = Frame::Hello { rank: 11, token: "deadbeef01020304".into(), peer };
            match (roundtrip(&sent), sent) {
                (
                    Frame::Hello { rank, token, peer },
                    Frame::Hello { rank: r0, token: t0, peer: p0 },
                ) => {
                    assert_eq!(rank, r0);
                    assert_eq!(token, t0);
                    assert_eq!(peer, p0);
                }
                (other, _) => panic!("{other:?}"),
            }
        }
        assert!(matches!(roundtrip(&Frame::Start { epoch: 42 }), Frame::Start { epoch: 42 }));
        assert!(matches!(roundtrip(&Frame::Bye), Frame::Bye));
        assert_eq!(Frame::Bye.name(), "BYE");
        assert_eq!(Frame::Start { epoch: 0 }.name(), "START");
    }

    #[test]
    fn ping_and_pong_roundtrip() {
        // The v8 heartbeat frames are empty-payload singletons: 5 bytes on
        // the wire (length prefix + tag), nothing else.
        assert!(matches!(roundtrip(&Frame::Ping), Frame::Ping));
        assert!(matches!(roundtrip(&Frame::Pong), Frame::Pong));
        assert_eq!(Frame::Ping.name(), "PING");
        assert_eq!(Frame::Pong.name(), "PONG");
        assert_eq!(Frame::Ping.encode().len(), 5);
        assert_eq!(Frame::Pong.encode().len(), 5);
        // Trailing bytes after the tag are rejected like every other frame.
        let mut long = Frame::Pong.encode()[4..].to_vec();
        long.push(0);
        assert!(Frame::decode(&long).is_err(), "trailing byte must fail");
    }

    #[test]
    fn peer_frames_roundtrip() {
        match roundtrip(&Frame::PeerHello { rank: 7, token: "0f0f0f0f0f0f0f0f".into() }) {
            Frame::PeerHello { rank, token } => {
                assert_eq!(rank, 7);
                assert_eq!(token, "0f0f0f0f0f0f0f0f");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Frame::PeerHello { rank: 0, token: String::new() }.name(), "PEERHELLO");
        let msg = Msg::Basic {
            stamp: 9,
            kind: BasicKind::Give {
                tasks: vec![WireTask { items: vec![1, 2, 3], core: 3, support: 6 }],
            },
        };
        match roundtrip(&Frame::PeerMsg { src: 5, epoch: 12, msg: msg.clone() }) {
            Frame::PeerMsg { src, epoch, msg: got } => {
                assert_eq!((src, epoch), (5, 12));
                assert_eq!(got, msg);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(Frame::PeerMsg { src: 0, epoch: 0, msg: Msg::Finish }.name(), "PEERMSG");
    }

    fn phase_spec(p: u32) -> PhaseSpec {
        PhaseSpec {
            p,
            seed: 3,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: true,
            trace: false,
            probe_budget_units: 10,
            dtd_interval_ns: 20,
            mode: RunMode::Count { min_sup: 2 },
        }
    }

    #[test]
    fn encode_config_matches_owned_frame_encode() {
        let db = Database::from_transactions(2, &[vec![0], vec![1]], &[true, false]);
        let spec = RunSpec { phase: phase_spec(2), db };
        let peers = vec![Endpoint::unix("/a.sock.r0"), Endpoint::tcp("10.0.0.2", 7001)];
        let borrowed = encode_config(&spec, &peers);
        let owned = Frame::Config { spec: Box::new(spec), peers }.encode();
        assert_eq!(borrowed, owned);
    }

    #[test]
    fn config_roundtrips_database_and_mode() {
        let trans = vec![vec![0, 2], vec![1], vec![0, 1, 2], vec![]];
        let labels = vec![true, false, true, false];
        let db = Database::from_transactions(3, &trans, &labels);
        let spec = RunSpec {
            phase: PhaseSpec {
                p: 4,
                seed: 99,
                preprocess: false,
                probe_budget_units: 1234,
                dtd_interval_ns: 5678,
                mode: RunMode::Phase1 { alpha: 0.05 },
                ..phase_spec(4)
            },
            db: db.clone(),
        };
        let peer_map = vec![
            Endpoint::unix("/x.r0"),
            Endpoint::tcp("127.0.0.1", 9000),
            Endpoint::tcp("node-2", 9001),
            Endpoint::unix("/x.r3"),
        ];
        let frame = Frame::Config { spec: Box::new(spec), peers: peer_map.clone() };
        let (got, got_peers) = match roundtrip(&frame) {
            Frame::Config { spec, peers } => (*spec, peers),
            other => panic!("{other:?}"),
        };
        assert_eq!(got_peers, peer_map, "peer endpoint map must survive the roundtrip");
        assert_eq!(got.phase.p, 4);
        assert_eq!(got.phase.seed, 99);
        assert!(matches!(got.phase.mode, RunMode::Phase1 { alpha } if alpha == 0.05));
        assert_eq!(got.db.n_items(), db.n_items());
        assert_eq!(got.db.n_trans(), db.n_trans());
        for i in 0..db.n_items() as Item {
            assert_eq!(got.db.col(i), db.col(i), "column {i}");
        }
        assert_eq!(got.db.pos_mask(), db.pos_mask());

        let count = RunSpec {
            phase: PhaseSpec { mode: RunMode::Count { min_sup: 9 }, ..got.phase },
            db: got.db,
        };
        let back = match roundtrip(&Frame::Config { spec: Box::new(count), peers: vec![] }) {
            Frame::Config { spec, peers } => {
                assert!(peers.is_empty(), "hub-plane CONFIG carries no peer map");
                *spec
            }
            other => panic!("{other:?}"),
        };
        assert!(matches!(back.phase.mode, RunMode::Count { min_sup: 9 }));
    }

    #[test]
    fn reconfig_roundtrips_without_database_bytes() {
        let phase = PhaseSpec { seed: 77, mode: RunMode::Phase1 { alpha: 0.01 }, ..phase_spec(6) };
        let frame = Frame::Reconfig { phase: Box::new(phase), peers: vec![] };
        let bytes = frame.encode();
        // version(2) + p(4) seed(8) w(4) l(4) arity(4) steal(1) pre(1)
        // trace(1) budget(8) dtd(8) + mode(1+8) = 54, + empty peer map
        // (4) = 58 payload bytes + tag + len.
        assert_eq!(bytes.len(), 4 + 1 + 58);
        let got = match roundtrip(&frame) {
            Frame::Reconfig { phase, peers } => {
                assert!(peers.is_empty());
                *phase
            }
            other => panic!("{other:?}"),
        };
        assert_eq!(got.p, 6);
        assert_eq!(got.seed, 77);
        assert!(matches!(got.mode, RunMode::Phase1 { alpha } if alpha == 0.01));
        let named = Frame::Reconfig { phase: Box::new(got), peers: vec![] };
        assert_eq!(named.name(), "RECONFIG");
    }

    #[test]
    fn merge_roundtrips() {
        let m = WorkerMerge {
            rank: 2,
            epoch: 9,
            hist: vec![(3, 5), (10, 1)],
            closed_count: 6,
            work_units: 777,
            breakdown: Breakdown { preprocess_ns: 1, main_ns: 2, probe_ns: 3, idle_ns: 4 },
            comm: CommStats {
                sent: 9,
                received: 8,
                steal_requests: 7,
                rejects: 6,
                gives: 5,
                tasks_shipped: 4,
                bytes_sent: 3,
                hub_frames: 2,
                direct_frames: 11,
            },
            makespan_ns: 123_456,
        };
        let got = match roundtrip(&Frame::Merge(Box::new(m.clone()))) {
            Frame::Merge(g) => *g,
            other => panic!("{other:?}"),
        };
        assert_eq!(got, m);
    }

    fn sample_checkpoint(n_roots: usize) -> Frame {
        Frame::Checkpoint {
            rank: 2,
            epoch: 7,
            work_units: 123_456,
            roots: (0..n_roots)
                .map(|i| WireTask {
                    items: (0..i as Item).collect(),
                    core: i as i64 - 1,
                    support: 10 + i as u32,
                })
                .collect(),
        }
    }

    /// The v5 frames (CHECKPOINT custody reports, the epoch-carrying START)
    /// roundtrip exactly, including the empty-stack checkpoint an idle
    /// worker sends.
    #[test]
    fn checkpoint_and_epoch_start_roundtrip() {
        for n in [0usize, 1, 5] {
            let sent = sample_checkpoint(n);
            let (Frame::Checkpoint { rank, epoch, work_units, roots },
                 Frame::Checkpoint { rank: r0, epoch: e0, work_units: w0, roots: t0 }) =
                (roundtrip(&sent), sent)
            else {
                panic!("checkpoint did not roundtrip as a checkpoint");
            };
            assert_eq!(rank, r0);
            assert_eq!(epoch, e0);
            assert_eq!(work_units, w0);
            assert_eq!(roots, t0);
        }
        assert_eq!(sample_checkpoint(0).name(), "CHECKPOINT");
        match roundtrip(&Frame::Start { epoch: u64::MAX }) {
            Frame::Start { epoch } => assert_eq!(epoch, u64::MAX),
            other => panic!("{other:?}"),
        }
        // Random checkpoints through the same generator discipline as the
        // message property test.
        crate::util::propcheck::forall("random checkpoints roundtrip", 64, |rng| {
            let frame = Frame::Checkpoint {
                rank: rng.below(64) as u32,
                epoch: rng.next_u64(),
                work_units: rng.next_u64(),
                roots: (0..rng.index(6))
                    .map(|_| WireTask {
                        items: (0..rng.index(5)).map(|_| rng.below(100) as Item).collect(),
                        core: rng.below(100) as i64 - 1,
                        support: rng.below(1000) as u32 + 1,
                    })
                    .collect(),
            };
            let bytes = frame.encode();
            let Frame::Checkpoint { roots: r0, rank, epoch, work_units } = frame else {
                unreachable!()
            };
            match Frame::decode(&bytes[4..]) {
                Ok(Frame::Checkpoint { roots, rank: r, epoch: e, work_units: w })
                    if roots == r0 && r == rank && e == epoch && w == work_units =>
                {
                    Ok(())
                }
                other => Err(format!("checkpoint roundtrip mismatch: {other:?}")),
            }
        });
    }

    /// The v5 frames survive the same corruption battery as every earlier
    /// frame generation: per-byte truncation, trailing garbage, and
    /// oversized count prefixes error — never panic, never allocate wildly.
    #[test]
    fn corrupt_v5_frames_error_instead_of_panicking() {
        let relay = Frame::Relay {
            peer: 2,
            epoch: 7,
            msg: Msg::Basic { stamp: 9, kind: BasicKind::Request { lifeline: true } },
        };
        for frame in [sample_checkpoint(3), Frame::Start { epoch: 3 }, relay] {
            let bytes = frame.encode();
            for cut in 1..bytes.len() - 4 {
                assert!(
                    Frame::decode(&bytes[4..4 + cut]).is_err(),
                    "{}: truncation at {cut} must fail",
                    frame.name()
                );
            }
            assert!(Frame::decode(&bytes[4..]).is_ok(), "{}", frame.name());
            let mut long = bytes[4..].to_vec();
            long.push(0);
            assert!(Frame::decode(&long).is_err(), "{}", frame.name());
        }
        // An absurd root count in a CHECKPOINT must not allocate.
        let mut body = vec![TAG_CHECKPOINT];
        put_u32(&mut body, 0); // rank
        put_u64(&mut body, 0); // epoch
        put_u64(&mut body, 0); // work units
        put_u32(&mut body, u32::MAX); // root count with no task bytes
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // Same for an absurd per-task item count inside a valid root count.
        let mut body = vec![TAG_CHECKPOINT];
        put_u32(&mut body, 0);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        put_u32(&mut body, 1); // one root…
        put_u32(&mut body, u32::MAX); // …claiming u32::MAX items
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // A MERGE truncated inside the new epoch field fails cleanly (the
        // epoch sits between rank and the histogram).
        let m = WorkerMerge {
            rank: 1,
            epoch: 5,
            hist: vec![(2, 2)],
            closed_count: 2,
            work_units: 10,
            breakdown: Breakdown::default(),
            comm: CommStats::default(),
            makespan_ns: 1,
        };
        let bytes = Frame::Merge(Box::new(m)).encode();
        assert!(Frame::decode(&bytes[4..4 + 8]).is_err()); // tag+rank+3 epoch bytes
    }

    /// A TRACE chunk covering every event kind (v7; lease kinds v8).
    fn sample_trace_chunk() -> Frame {
        use crate::obs::trace::{EventKind, TraceEvent};
        let kinds = [
            EventKind::PhaseStart { phase: 1, epoch: 4 },
            EventKind::PhaseEnd { phase: 1, epoch: 4 },
            EventKind::ExpandBatch { units: 4096 },
            EventKind::StealRequest { dst: 3, lifeline: true },
            EventKind::StealReject { src: 3, lifeline: false },
            EventKind::StealGive { dst: 1, tasks: 7 },
            EventKind::StealRecv { src: 2, tasks: 7 },
            EventKind::WaveArrive { t: 9, up: true },
            EventKind::Checkpoint { units: 1_000_000, roots: 12 },
            EventKind::Respawn { rank: 5, epoch: 6 },
            EventKind::ServeQueue { job: 42 },
            EventKind::ServePop { job: 42 },
            EventKind::ServeExpire { job: 43 },
            EventKind::LeaseMiss { rank: 5, epoch: 6 },
            EventKind::ForceKill { rank: 5, epoch: 6 },
        ];
        let events = kinds
            .iter()
            .enumerate()
            .map(|(i, &kind)| TraceEvent { t_ns: i as u64 * 1_000, kind })
            .collect();
        Frame::Trace(Box::new(trace::TraceChunk {
            rank: 2,
            epoch: 4,
            start_recv_ns: 111,
            flush_ns: 99_999,
            dropped: 3,
            events,
        }))
    }

    #[test]
    fn trace_chunk_roundtrips_every_event_kind() {
        let frame = sample_trace_chunk();
        assert_eq!(frame.name(), "TRACE");
        let orig = match &frame {
            Frame::Trace(c) => (**c).clone(),
            _ => unreachable!(),
        };
        match roundtrip(&frame) {
            Frame::Trace(c) => assert_eq!(*c, orig),
            other => panic!("{other:?}"),
        }
        // An empty chunk (quiet rank, or ring drained by a prior phase)
        // is legal and roundtrips.
        let empty = Frame::Trace(Box::new(trace::TraceChunk {
            rank: 0,
            epoch: 0,
            start_recv_ns: 0,
            flush_ns: 0,
            dropped: 0,
            events: vec![],
        }));
        match roundtrip(&empty) {
            Frame::Trace(c) => {
                assert!(c.events.is_empty());
                assert_eq!(c.dropped, 0);
            }
            other => panic!("{other:?}"),
        }
    }

    /// The v7 TRACE frame survives the same corruption battery as every
    /// other frame: per-byte truncation, trailing garbage, oversized
    /// count prefixes, and unknown event kinds error — never panic.
    #[test]
    fn corrupt_v7_trace_frames_error_instead_of_panicking() {
        let frame = sample_trace_chunk();
        let bytes = frame.encode();
        for cut in 1..bytes.len() - 4 {
            assert!(
                Frame::decode(&bytes[4..4 + cut]).is_err(),
                "TRACE: truncation at {cut} must fail"
            );
        }
        assert!(Frame::decode(&bytes[4..]).is_ok());
        let mut long = bytes[4..].to_vec();
        long.push(0);
        assert!(Frame::decode(&long).is_err(), "trailing byte must fail");
        // An absurd event count with no event bytes must not allocate.
        let mut body = vec![TAG_TRACE];
        put_u32(&mut body, 0); // rank
        put_u64(&mut body, 0); // epoch
        put_u64(&mut body, 0); // start_recv_ns
        put_u64(&mut body, 0); // flush_ns
        put_u64(&mut body, 0); // dropped
        put_u32(&mut body, u32::MAX); // event count with no bytes behind it
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // An unknown event kind byte is a decode error, not a skip.
        let mut body = vec![TAG_TRACE];
        put_u32(&mut body, 0);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        put_u32(&mut body, 1); // one event…
        put_u64(&mut body, 5); // …with a timestamp…
        put_u8(&mut body, 0xEE); // …and a kind from the future
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("unknown trace event kind"), "{err:#}");
    }

    #[test]
    fn corrupt_input_errors_instead_of_panicking() {
        // truncated body
        let mut bytes = Frame::Bye.encode();
        bytes[0] = 10; // claim a longer frame than is present
        let mut cursor = &bytes[..];
        assert!(read_frame(&mut cursor).is_err());
        // unknown tag
        assert!(Frame::decode(&[0x77]).is_err());
        // bad magic
        let mut hello =
            Frame::Hello { rank: 0, token: "t".into(), peer: Endpoint::unix("/p") }.encode();
        hello[5] = b'X'; // first magic byte (after len prefix + tag)
        assert!(Frame::decode(&hello[4..]).is_err());
        // oversized length prefix
        let huge = (MAX_FRAME_LEN + 1).to_le_bytes();
        let mut cursor: &[u8] = &huge;
        assert!(read_frame(&mut cursor).is_err());
        // absurd count prefix inside a RELAY(GIVE) must not allocate
        let mut body = vec![TAG_RELAY];
        put_u32(&mut body, 0); // peer
        put_u64(&mut body, 0); // epoch (v5)
        put_u8(&mut body, MSG_GIVE);
        put_u64(&mut body, 0); // stamp
        put_u32(&mut body, u32::MAX); // task count with no task bytes
        assert!(Frame::decode(&body).is_err());
    }

    #[test]
    fn absurd_database_dimensions_error_instead_of_allocating() {
        // A CONFIG whose db header claims u32::MAX transactions/items must
        // fail the dimension checks, not allocate gigabytes.
        let db = Database::from_transactions(1, &[vec![0]], &[true]);
        let spec = RunSpec { phase: phase_spec(1), db };
        let frame = Frame::Config { spec: Box::new(spec), peers: vec![] }.encode();
        // db starts right after: len(4) tag(1) version(2) p(4) seed(8) w(4)
        // l(4) arity(4) steal(1) pre(1) trace(1) budget(8) dtd(8)
        // mode(1+4) = 55, plus the empty peer map's count (4) = 59.
        let db_off = 59;
        for dim_off in [0usize, 4] {
            let mut bad = frame.clone();
            bad[db_off + dim_off..db_off + dim_off + 4]
                .copy_from_slice(&u32::MAX.to_le_bytes());
            let err = Frame::decode(&bad[4..]).unwrap_err();
            assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        }
    }

    /// The mesh frames survive the same corruption battery as the fabric
    /// frames: per-byte truncation, bad magic/version, and oversized count
    /// prefixes must error — never panic, never allocate wildly.
    #[test]
    fn corrupt_peer_frames_error_instead_of_panicking() {
        let db = Database::from_transactions(1, &[vec![0]], &[true]);
        let token = || "00ff00ff00ff00ff".to_string();
        let frames = vec![
            Frame::Hello {
                rank: 3,
                token: token(),
                peer: Endpoint::unix("/tmp/hub.sock.r3"),
            },
            Frame::Hello { rank: 4, token: token(), peer: Endpoint::tcp("10.1.2.3", 4455) },
            Frame::PeerHello { rank: 3, token: token() },
            Frame::PeerMsg {
                src: 1,
                epoch: 4,
                msg: Msg::WaveUp {
                    t: 2,
                    count: -1,
                    invalid: false,
                    all_idle: true,
                    hist: vec![(3, 4)],
                },
            },
            Frame::Config {
                spec: Box::new(RunSpec { phase: phase_spec(2), db }),
                peers: vec![Endpoint::unix("/x.r0"), Endpoint::tcp("127.0.0.1", 9001)],
            },
            Frame::Reconfig {
                phase: Box::new(phase_spec(2)),
                peers: vec![Endpoint::tcp("h0", 1), Endpoint::tcp("h1", 2)],
            },
        ];
        for frame in &frames {
            let bytes = frame.encode();
            for cut in 1..bytes.len() - 4 {
                assert!(
                    Frame::decode(&bytes[4..4 + cut]).is_err(),
                    "{}: truncation at {cut} must fail",
                    frame.name()
                );
            }
            assert!(Frame::decode(&bytes[4..]).is_ok(), "{}", frame.name());
            // Trailing garbage after a well-formed payload is rejected.
            let mut long = bytes[4..].to_vec();
            long.push(0);
            assert!(Frame::decode(&long).is_err(), "{}", frame.name());
        }
        // Bad PEERHELLO magic and a version skew produce clear errors.
        let mut ph = Frame::PeerHello { rank: 0, token: token() }.encode();
        ph[5] = b'X';
        assert!(Frame::decode(&ph[4..]).is_err());
        let mut ph = Frame::PeerHello { rank: 0, token: token() }.encode();
        ph[9] = 0xFF; // version low byte
        let err = Frame::decode(&ph[4..]).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // A version-skewed HELLO (a stale binary on one side) errors the
        // same way — the version check runs before rank/token/endpoint.
        let hello =
            || Frame::Hello { rank: 0, token: token(), peer: Endpoint::tcp("h", 1) }.encode();
        let mut h = hello();
        h[9] = 0xFF; // version low byte (len 4 + tag 1 + magic 4)
        let err = Frame::decode(&h[4..]).unwrap_err();
        assert!(format!("{err:#}").contains("version"), "{err:#}");
        // A HELLO whose peer string is not a valid endpoint is rejected
        // with a clear parse error, not accepted as a bogus address.
        let mut body = vec![TAG_HELLO];
        body.extend_from_slice(&WIRE_MAGIC);
        put_u16(&mut body, WIRE_VERSION);
        put_u32(&mut body, 0);
        put_str(&mut body, "tok");
        put_str(&mut body, "tcp:host:notaport");
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("HELLO peer endpoint"), "{err:#}");
        // Same for a CONFIG/RECONFIG peer-map entry.
        let mut body = vec![TAG_RECONFIG];
        put_phase(&mut body, &phase_spec(2));
        put_u32(&mut body, 1);
        put_str(&mut body, "tcp::123"); // empty host
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("peer endpoint for rank 0"), "{err:#}");
        // An absurd peer-map count in a RECONFIG must not allocate.
        let mut body = vec![TAG_RECONFIG];
        put_phase(&mut body, &phase_spec(2));
        put_u32(&mut body, u32::MAX); // peer count with no string bytes
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // A non-UTF-8 peer path errors instead of panicking.
        let mut body = vec![TAG_RECONFIG];
        put_phase(&mut body, &phase_spec(2));
        put_u32(&mut body, 1);
        put_u32(&mut body, 2);
        body.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Frame::decode(&body).is_err());
    }

    #[test]
    fn clean_eof_between_frames_is_none() {
        let empty: &[u8] = &[];
        let mut cursor = empty;
        assert!(read_frame(&mut cursor).unwrap().is_none());
        // EOF inside the prefix is an error
        let partial: &[u8] = &[1, 0];
        let mut cursor = partial;
        assert!(read_frame(&mut cursor).is_err());
    }

    // ---- service (job) frames ----------------------------------------------

    use super::service::{JobOutcome, JobSpec, JobState};
    use crate::coordinator::{GlbParams, ScreenKind, ScreenMode};
    use crate::lamp::SignificantPattern;

    fn sample_outcome() -> JobOutcome {
        JobOutcome {
            alpha: 0.05,
            lambda_final: 7,
            min_sup: 6,
            correction_factor: 123,
            phase1_closed: 44,
            phase2_closed: 123,
            screen: ScreenKind::Native,
            from_cache: true,
            phase1_makespan_s: 0.25,
            phase2_makespan_s: 0.125,
            hist2: vec![(6, 100), (9, 23)],
            significant: vec![
                SignificantPattern { items: vec![3, 5], support: 9, pos_support: 8, p_value: 1e-6 },
                SignificantPattern { items: vec![11], support: 7, pos_support: 7, p_value: 3e-4 },
            ],
        }
    }

    #[test]
    fn submit_roundtrips_spec_and_database() {
        let db = Database::from_transactions(2, &[vec![0, 1], vec![1]], &[true, false]);
        let spec = JobSpec {
            alpha: 0.01,
            glb: GlbParams { w: 2, steal: false, ..GlbParams::default() },
            screen: ScreenMode::Native,
            seed: 31,
            priority: 3,
            deadline_ms: 1500,
            client: "tenant-a".into(),
            db: db.clone(),
        };
        let got = match roundtrip(&Frame::Submit(Box::new(spec))) {
            Frame::Submit(s) => *s,
            other => panic!("{other:?}"),
        };
        assert_eq!(got.alpha, 0.01);
        assert_eq!(got.glb, GlbParams { w: 2, steal: false, ..GlbParams::default() });
        assert_eq!(got.screen, ScreenMode::Native);
        assert_eq!(got.seed, 31);
        assert_eq!(got.priority, 3);
        assert_eq!(got.deadline_ms, 1500);
        assert_eq!(got.client, "tenant-a");
        assert_eq!(got.db.digest(), db.digest());
        assert_eq!(Frame::Submit(Box::new(got)).name(), "SUBMIT");
    }

    #[test]
    fn every_job_state_roundtrips_through_status() {
        let states = vec![
            JobState::Queued { position: 4 },
            JobState::Running,
            JobState::Done { from_cache: true },
            JobState::Done { from_cache: false },
            JobState::Failed { reason: "worker rank 1 exited mid-run".into() },
            JobState::Cancelled,
            JobState::NotFound,
            JobState::Expired,
            JobState::Busy { reason: "daemon queue full (256/256 jobs queued)".into() },
        ];
        for state in states {
            let frame = Frame::Status { job_id: 9, report: Some(state.clone()) };
            match roundtrip(&frame) {
                Frame::Status { job_id, report } => {
                    assert_eq!(job_id, 9);
                    assert_eq!(report, Some(state));
                }
                other => panic!("{other:?}"),
            }
        }
        // The query form (no report) roundtrips too.
        match roundtrip(&Frame::Status { job_id: 3, report: None }) {
            Frame::Status { job_id: 3, report: None } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn result_accepted_cancel_shutdown_roundtrip() {
        let outcome = sample_outcome();
        let frame = Frame::JobResult { job_id: 12, report: Some(Box::new(outcome.clone())) };
        match roundtrip(&frame) {
            Frame::JobResult { job_id, report } => {
                assert_eq!(job_id, 12);
                assert_eq!(*report.expect("payload"), outcome);
            }
            other => panic!("{other:?}"),
        }
        assert!(matches!(
            roundtrip(&Frame::JobResult { job_id: 5, report: None }),
            Frame::JobResult { job_id: 5, report: None }
        ));
        assert!(matches!(
            roundtrip(&Frame::Accepted { job_id: 88 }),
            Frame::Accepted { job_id: 88 }
        ));
        assert!(matches!(
            roundtrip(&Frame::Cancel { job_id: 17 }),
            Frame::Cancel { job_id: 17 }
        ));
        assert!(matches!(roundtrip(&Frame::Shutdown), Frame::Shutdown));
        assert_eq!(Frame::Shutdown.name(), "SHUTDOWN");
    }

    #[test]
    fn stats_roundtrips_query_and_report() {
        use super::service::{ClientStats, FleetStats, ServiceStats};
        assert!(matches!(
            roundtrip(&Frame::Stats { report: None }),
            Frame::Stats { report: None }
        ));
        let mut latency_ms = vec![0u64; 20];
        latency_ms[4] = 5;
        let stats = ServiceStats {
            uptime_ms: 12345,
            jobs_submitted: 9,
            jobs_mined: 5,
            jobs_failed: 1,
            jobs_rejected_busy: 2,
            jobs_expired: 1,
            jobs_cancelled: 0,
            cache_hits: 3,
            cache_misses: 6,
            cache_entries: 4,
            store_entries: 7,
            store_appends: 5,
            store_hits: 2,
            evicted_records: 11,
            fleets: vec![
                FleetStats { jobs_mined: 3, busy_ms: 900, respawns: 1, rebuilds: 0 },
                FleetStats { jobs_mined: 2, busy_ms: 450, respawns: 0, rebuilds: 1 },
            ],
            clients: vec![ClientStats {
                client: "anon".into(),
                queued: 1,
                active: 1,
                submitted: 9,
            }],
            queue_wait_ms: vec![0; 20],
            latency_ms,
        };
        match roundtrip(&Frame::Stats { report: Some(Box::new(stats.clone())) }) {
            Frame::Stats { report } => assert_eq!(*report.expect("payload"), stats),
            other => panic!("{other:?}"),
        }
        assert_eq!(Frame::Stats { report: None }.name(), "STATS");
        // The human rendering names the load-bearing numbers.
        let text = stats.to_string();
        assert!(text.contains("9 submitted"), "{text}");
        assert!(text.contains("11 terminal records evicted"), "{text}");
        assert!(text.contains("fleet 1: 2 jobs"), "{text}");
    }

    /// Every service frame survives the same corruption battery as the
    /// fabric frames: truncated payloads, bad tags/discriminants, and
    /// oversized counts must error — never panic, never allocate wildly.
    #[test]
    fn corrupt_service_frames_error_instead_of_panicking() {
        let db = Database::from_transactions(1, &[vec![0]], &[true]);
        let frames = vec![
            Frame::Submit(Box::new(JobSpec::new(db, 0.05))),
            Frame::Accepted { job_id: 1 },
            Frame::Status { job_id: 2, report: Some(JobState::Failed { reason: "x".into() }) },
            Frame::JobResult { job_id: 3, report: Some(Box::new(sample_outcome())) },
            Frame::Cancel { job_id: 4 },
            Frame::Stats {
                report: Some(Box::new(super::service::ServiceStats {
                    fleets: vec![Default::default()],
                    clients: vec![super::service::ClientStats {
                        client: "c".into(),
                        ..Default::default()
                    }],
                    queue_wait_ms: vec![0; 20],
                    latency_ms: vec![0; 20],
                    ..Default::default()
                })),
            },
        ];
        for frame in &frames {
            let bytes = frame.encode();
            // Truncate the body at every prefix length: must error, not
            // panic (the final full-length slice must decode fine).
            for cut in 1..bytes.len() - 4 {
                assert!(
                    Frame::decode(&bytes[4..4 + cut]).is_err(),
                    "{}: truncation at {cut} must fail",
                    frame.name()
                );
            }
            assert!(Frame::decode(&bytes[4..]).is_ok(), "{}", frame.name());
        }
        // Bad presence byte on STATUS / RESULT / STATS.
        for tag in [TAG_STATUS, TAG_RESULT] {
            let mut body = vec![tag];
            put_u64(&mut body, 1);
            put_u8(&mut body, 7); // neither 0 nor 1
            assert!(Frame::decode(&body).is_err());
        }
        let body = vec![TAG_STATS, 7];
        assert!(Frame::decode(&body).is_err());
        // Unknown job-state discriminant.
        let mut body = vec![TAG_STATUS];
        put_u64(&mut body, 1);
        put_u8(&mut body, 1);
        put_u8(&mut body, 0x66);
        assert!(Frame::decode(&body).is_err());
        // Oversized significant-pattern count in a RESULT must not allocate.
        let mut body = vec![TAG_RESULT];
        put_u64(&mut body, 1); // job id
        put_u8(&mut body, 1); // present
        let mut o = sample_outcome();
        o.significant.clear();
        super::service::put_job_outcome(&mut body, &o);
        let n = body.len();
        body[n - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode(&body).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // Oversized item count inside a SUBMIT database column.
        let db = Database::from_transactions(1, &[vec![0]], &[true]);
        let bytes = Frame::Submit(Box::new(JobSpec::new(db, 0.05))).encode();
        // db starts after len(4) tag(1) version(2) alpha(8) l(4) w(4)
        // steal(1) pre(1) arity(4) screen(1) seed(8) priority(1)
        // deadline(8) client(4 + 0, empty) = 51; n_items is first.
        let mut bad = bytes.clone();
        bad[51..55].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = Frame::decode(&bad[4..]).unwrap_err();
        assert!(format!("{err:#}").contains("exceeds"), "{err:#}");
        // Trailing garbage after a well-formed payload is rejected.
        let mut long = bytes[4..].to_vec();
        long.push(0);
        assert!(Frame::decode(&long).is_err());
    }
}
