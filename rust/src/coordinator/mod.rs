//! The L3 coordination layer (paper §4): one orchestration path for the
//! complete three-phase LAMP procedure over any fabric backend.
//!
//! The lower layers each solve one problem — [`crate::lcm`] expands tree
//! nodes, [`crate::par`] runs the Fig. 5 worker under an engine,
//! [`crate::glb`] shapes the lifeline topology, [`crate::dtd`] detects
//! quiescence — but the seed left the *composition* of a full run scattered
//! across the CLI, the examples, and ad-hoc helpers. [`Coordinator`] owns
//! that composition:
//!
//! 1. **Phase 1** (λ search): workers are configured from [`GlbParams`]
//!    (the lifeline hypercube edge length `l`, random steal attempts `w`,
//!    DTD tree arity) and launched on the chosen [`Backend`]. The engine
//!    returns only after Mattern DTD declares quiescence, at which point
//!    the per-worker `SupportHist` / `Breakdown` / `CommStats` have been
//!    merged into one [`ParRunResult`] — the *phase boundary*. The final λ
//!    is recomputed from the merged (exact) histogram, so it equals the
//!    serial result even though the in-flight λ may have lagged
//!    (DESIGN.md §4).
//! 2. **Phase 2** (correction factor): a counting run at
//!    `min_sup = λ* − 1`, same backend, same merge discipline.
//! 3. **Phase 3** (extraction): dispatched through the XLA/PJRT screen
//!    when AOT artifacts are present and loadable
//!    ([`ScreenMode::Auto`]), with a graceful fallback to the native
//!    [`crate::stats::fisher`] path — the paper measures this phase at
//!    ~10 ms, so the serial fallback never dominates.
//!
//! The CLI (`parlamp lamp --engine threads|sim|process`, `parlamp sim`) and
//! the `quickstart` / `naive_vs_glb` / `scaling_study` / `gwas_study`
//! examples all run through this one path.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::bench::Calibration;
use crate::db::Database;
use crate::fabric::sim::NetModel;
use crate::fabric::CommStats;
use crate::glb::Lifelines;
use crate::lamp::{phase3_extract, LampResult, SignificantPattern, SupportIncreaseRule};
use crate::net::fault::NetFaultPlan;
use crate::net::Endpoint;
use crate::obs::chrome::HUB_RANK;
use crate::obs::clock;
use crate::obs::log;
use crate::obs::trace::{self as obs_trace, EventKind as TraceEv, RankTrace, TraceEvent};
use crate::par::{
    breakdown, run_sim, run_threads_with, DataPlane, ParRunResult, ProcessConfig, ProcessFleet,
    RunMode, SimConfig, ThreadConfig,
};
use crate::runtime::{
    artifacts_available, artifacts_dir, phase3_extract_xla, ScreenEngine, XlaRuntime,
};
use crate::util::fault::FaultPlan;

/// Every engine name the CLI and the bench harness accept, in the order
/// the bench runs them by default. [`parse_engine`] is the one dispatch
/// point; its error message derives from this list.
pub const ENGINES: &[&str] = &["serial", "lamp2", "threads", "sim", "process"];

/// What an engine name resolves to: one of the two serial pipelines, or a
/// coordinated distributed [`Backend`].
#[derive(Clone, Copy, Debug)]
pub enum EngineSelect {
    /// The serial reference pipeline (`lamp_serial`).
    Serial,
    /// The occurrence-deliver serial comparator (`lamp2_serial`).
    Lamp2,
    /// A distributed run through the [`Coordinator`].
    Backend(Backend),
}

/// Resolve an engine name (`serial|lamp2|threads|sim|process`) to its
/// dispatch target — the single engine-name parser shared by `parlamp
/// lamp`, `parlamp bench`, and the service daemon, so a typo gets the same
/// one-line error everywhere.
pub fn parse_engine(name: &str, p: usize, seed: u64) -> Result<EngineSelect> {
    Ok(match name {
        "serial" => EngineSelect::Serial,
        "lamp2" => EngineSelect::Lamp2,
        "threads" => EngineSelect::Backend(Backend::Threads { p, seed }),
        "sim" => EngineSelect::Backend(Backend::Sim { p, net: NetModel::default(), seed }),
        "process" => EngineSelect::Backend(Backend::process(p).with_seed(seed)),
        other => bail!("unknown engine '{other}' ({})", ENGINES.join("|")),
    })
}

/// Which stream transport the process backend's sockets use
/// (`--transport unix|tcp`, DESIGN.md §11). `Unix` is the single-host
/// default; `Tcp` binds the hub (and every worker's mesh listener) on
/// loopback/ephemeral TCP ports instead — the same wire bytes, a
/// different interconnect.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transport {
    Unix,
    Tcp,
}

impl Transport {
    /// The flag spelling, as recorded in bench reports.
    pub fn name(self) -> &'static str {
        match self {
            Transport::Unix => "unix",
            Transport::Tcp => "tcp",
        }
    }
}

impl std::str::FromStr for Transport {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Transport> {
        match s {
            "unix" => Ok(Transport::Unix),
            "tcp" => Ok(Transport::Tcp),
            other => bail!("unknown transport '{other}' (unix|tcp)"),
        }
    }
}

/// Lifeline-GLB topology parameters (paper §4.2), the knobs the
/// coordinator translates into per-worker configuration for every engine.
/// `Hash` because these parameters are part of the service result-cache
/// key (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GlbParams {
    /// Hypercube edge length `l` (paper fixes 2: binary hypercube).
    pub l: usize,
    /// Random steal attempts `w` before falling back to lifelines
    /// (paper fixes 1).
    pub w: usize,
    /// `false` = the §5.4 naive static-partition baseline: depth-1
    /// distribution plus the λ broadcast, no stealing.
    pub steal: bool,
    /// Depth-1 preprocess partition (§4.5).
    pub preprocess: bool,
    /// Mattern DTD spanning-tree arity (paper: ternary).
    pub tree_arity: usize,
}

impl Default for GlbParams {
    /// The paper's fixed operating point: `l = 2`, `w = 1`, ternary DTD
    /// tree, stealing and preprocess on.
    fn default() -> Self {
        GlbParams { l: 2, w: 1, steal: true, preprocess: true, tree_arity: 3 }
    }
}

impl GlbParams {
    /// The naive baseline of Table 2: identical protocol with stealing
    /// disabled.
    pub fn naive() -> Self {
        GlbParams { steal: false, ..Self::default() }
    }

    /// The lifeline neighborhood this parameterization induces for `rank`
    /// in a world of `p` processes — exactly what each worker is wired
    /// with.
    pub fn lifelines(&self, rank: usize, p: usize) -> Lifelines {
        Lifelines::new(rank, p, self.l)
    }
}

/// Which fabric executes phases 1–2.
#[derive(Clone, Copy, Debug)]
pub enum Backend {
    /// One OS thread per process over the channel fabric; real wall-clock
    /// time (the paper's single-node runs, §5.3).
    Threads { p: usize, seed: u64 },
    /// Discrete-event simulation; virtual time under `net`'s latency and
    /// bandwidth model (the TSUBAME substitution, DESIGN.md §2).
    Sim { p: usize, net: NetModel, seed: u64 },
    /// One OS process per rank over the stream-socket fabric; real
    /// wall-clock time and real address-space separation — every message
    /// crosses the [`crate::wire`] protocol (DESIGN.md §7). `plane`
    /// selects the data plane: direct worker-to-worker mesh sockets (the
    /// default) or the centralized hub relay (DESIGN.md §10); `transport`
    /// selects Unix-domain sockets (the default) or loopback TCP
    /// (DESIGN.md §11). Requires a spawnable `parlamp` binary (see
    /// [`crate::par::engine_process`]).
    Process { p: usize, seed: u64, plane: DataPlane, transport: Transport },
}

impl Backend {
    /// Thread backend with the default seed.
    pub fn threads(p: usize) -> Backend {
        Backend::Threads { p, seed: 2015 }
    }

    /// Sim backend with the default (InfiniBand-class) network and seed.
    pub fn sim(p: usize) -> Backend {
        Backend::Sim { p, net: NetModel::default(), seed: 2015 }
    }

    /// Multi-process backend with the default seed, data plane (mesh),
    /// and transport (unix).
    pub fn process(p: usize) -> Backend {
        Backend::Process { p, seed: 2015, plane: DataPlane::Mesh, transport: Transport::Unix }
    }

    /// This backend with its seed set. A no-op for nothing — every
    /// backend carries a seed.
    pub fn with_seed(self, seed: u64) -> Backend {
        match self {
            Backend::Threads { p, .. } => Backend::Threads { p, seed },
            Backend::Sim { p, net, .. } => Backend::Sim { p, net, seed },
            Backend::Process { p, plane, transport, .. } => {
                Backend::Process { p, seed, plane, transport }
            }
        }
    }

    /// This backend with its data plane set (`--data-plane hub|mesh`).
    /// A no-op for backends other than [`Backend::Process`] — the
    /// in-process fabrics have no hub to bypass.
    pub fn with_data_plane(self, plane: DataPlane) -> Backend {
        match self {
            Backend::Process { p, seed, transport, .. } => {
                Backend::Process { p, seed, plane, transport }
            }
            other => other,
        }
    }

    /// This backend with its stream transport set (`--transport unix|tcp`).
    /// A no-op for backends other than [`Backend::Process`] — the
    /// in-process fabrics have no sockets at all.
    pub fn with_transport(self, transport: Transport) -> Backend {
        match self {
            Backend::Process { p, seed, plane, .. } => {
                Backend::Process { p, seed, plane, transport }
            }
            other => other,
        }
    }

    /// World size.
    pub fn p(&self) -> usize {
        match self {
            Backend::Threads { p, .. } | Backend::Sim { p, .. } | Backend::Process { p, .. } => {
                *p
            }
        }
    }

    fn seed(&self) -> u64 {
        match self {
            Backend::Threads { seed, .. }
            | Backend::Sim { seed, .. }
            | Backend::Process { seed, .. } => *seed,
        }
    }
}

/// Phase-3 screen selection. `Hash` because the screen policy is part of
/// the service result-cache key (DESIGN.md §9).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ScreenMode {
    /// Use the XLA/PJRT artifact when present and loadable, otherwise the
    /// native Fisher path. The default.
    Auto,
    /// Always the native `stats::fisher` path.
    Native,
    /// Require the XLA/PJRT artifact; error when it cannot be used.
    Xla,
}

/// Which screen actually ran.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScreenKind {
    Native,
    Xla,
}

/// Everything one coordinated run produces: the LAMP result plus the
/// merged per-phase artifacts gathered at the DTD phase boundaries.
#[derive(Clone, Debug)]
pub struct CoordinatorRun {
    pub result: LampResult,
    /// Screen that produced `result.significant`.
    pub screen: ScreenKind,
    /// Phase-1 merge: exact histogram (at and above λ*), breakdowns,
    /// communication counters, makespan.
    pub phase1: ParRunResult,
    /// Phase-2 merge: the full histogram at `min_sup`, whose total is the
    /// correction factor.
    pub phase2: ParRunResult,
    /// Hub-track trace events (phase spans as the coordinator saw them,
    /// respawn records from the fleet) on the coordinator's clock. Empty
    /// unless tracing is on (DESIGN.md §14).
    pub hub_events: Vec<TraceEvent>,
}

impl CoordinatorRun {
    /// Phases 1+2 makespan — the quantity the paper's speedups compare
    /// against the serial `t₁`.
    pub fn t_parallel_s(&self) -> f64 {
        self.phase1.makespan_s + self.phase2.makespan_s
    }

    /// Total expansion work units (word-op equivalents including the
    /// conditional-database reduction work, DESIGN.md §8) summed over both
    /// distributed phases — the quantity `parlamp bench` records for
    /// cross-run comparison.
    pub fn work_units_total(&self) -> u64 {
        self.phase1.work_units + self.phase2.work_units
    }

    /// Communication counters summed over both distributed phases.
    pub fn comm_total(&self) -> CommStats {
        let mut c = self.phase1.comm;
        c.add(&self.phase2.comm);
        c
    }

    /// Fig. 7-style CPU-time breakdown summed over processes and phases.
    pub fn breakdown_total(&self) -> breakdown::Breakdown {
        let mut b = breakdown::sum(&self.phase1.breakdowns);
        b.add(&breakdown::sum(&self.phase2.breakdowns));
        b
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} | phase1 {:.4}s phase2 {:.4}s screen={:?}",
            self.result.summary(),
            self.phase1.makespan_s,
            self.phase2.makespan_s,
            self.screen
        )
    }

    /// The run's full timeline: both phases' per-rank traces merged (one
    /// track per rank, events pre-aligned onto the hub clock) plus the
    /// hub track ([`HUB_RANK`]) carrying the coordinator's phase 1/2/3
    /// spans and any respawn records. Empty when the run was untraced.
    pub fn traces(&self) -> Vec<RankTrace> {
        let mut by_rank: std::collections::BTreeMap<u32, RankTrace> =
            std::collections::BTreeMap::new();
        for rt in self.phase1.traces.iter().chain(&self.phase2.traces) {
            let merged = by_rank.entry(rt.rank).or_insert_with(|| RankTrace {
                rank: rt.rank,
                offset_ns: 0,
                uncertainty_ns: 0,
                dropped: 0,
                events: Vec::new(),
            });
            merged.uncertainty_ns = merged.uncertainty_ns.max(rt.uncertainty_ns);
            merged.dropped += rt.dropped;
            // Apply each phase's own offset estimate here, so the merged
            // track needs none (its events are already in hub time).
            merged.events.extend(
                rt.events.iter().map(|e| TraceEvent { t_ns: rt.aligned_ns(e), kind: e.kind }),
            );
        }
        let mut out: Vec<RankTrace> = by_rank.into_values().collect();
        if !self.hub_events.is_empty() {
            out.push(RankTrace {
                rank: HUB_RANK,
                offset_ns: 0,
                uncertainty_ns: 0,
                dropped: 0,
                events: self.hub_events.clone(),
            });
        }
        out
    }
}

/// Owns the three-phase LAMP orchestration. Construct with [`Coordinator::new`],
/// adjust with the builder methods, then [`run`](Coordinator::run) against a
/// database and a [`Backend`].
///
/// # Examples
///
/// Run the full three-phase procedure on the discrete-event backend and
/// cross-check it against the serial reference:
///
/// ```
/// use parlamp::coordinator::{Backend, Coordinator, ScreenMode};
/// use parlamp::datagen::{generate_gwas, GwasSpec};
/// use parlamp::lamp::lamp_serial;
///
/// let spec = GwasSpec { n_snps: 80, n_individuals: 60, n_pos: 15, ..GwasSpec::small(11) };
/// let (db, _planted) = generate_gwas(&spec);
///
/// let run = Coordinator::new(0.05)
///     .with_screen(ScreenMode::Native)
///     .run(&db, &Backend::sim(4))
///     .expect("coordinated run");
///
/// let serial = lamp_serial(&db, 0.05);
/// assert_eq!(run.result.lambda_final, serial.lambda_final);
/// assert_eq!(run.result.correction_factor, serial.correction_factor);
/// ```
#[derive(Clone, Debug)]
pub struct Coordinator {
    alpha: f64,
    glb: GlbParams,
    screen: ScreenMode,
    /// When present, the DES cost model and probe/wave cadences are derived
    /// from a measured serial run (`bench::calibrate_lamp`); otherwise the
    /// paper-default knobs apply.
    calibration: Option<Calibration>,
    /// Deterministic fault injection for the process backend
    /// (`--fault-inject`, DESIGN.md §12). Only [`Backend::Process`] runs
    /// consult it — the in-process fabrics have no workers to kill.
    fault: Option<FaultPlan>,
    /// Deterministic *network*-fault injection for the process backend
    /// (`--net-fault`, DESIGN.md §15): stall/drop/corrupt/partition one
    /// rank's fabric traffic at a scripted frame count.
    net_fault: Option<NetFaultPlan>,
    /// Heartbeat-lease timeout override for the process backend
    /// (`--lease-timeout`, DESIGN.md §15); `None` keeps the paper-default
    /// 60 s.
    lease_timeout: Option<Duration>,
    /// When present, overrides the paper-default probe budget (expansion
    /// cost units between mailbox polls) on every backend
    /// (`--probe-budget`, DESIGN.md §14).
    probe_budget: Option<u64>,
}

impl Coordinator {
    /// A coordinator at family-wise error rate `alpha` with the paper's
    /// GLB parameters and the `Auto` screen.
    pub fn new(alpha: f64) -> Coordinator {
        Coordinator {
            alpha,
            glb: GlbParams::default(),
            screen: ScreenMode::Auto,
            calibration: None,
            fault: None,
            net_fault: None,
            lease_timeout: None,
            probe_budget: None,
        }
    }

    pub fn with_glb(mut self, glb: GlbParams) -> Coordinator {
        self.glb = glb;
        self
    }

    pub fn with_screen(mut self, screen: ScreenMode) -> Coordinator {
        self.screen = screen;
        self
    }

    pub fn with_calibration(mut self, cal: Calibration) -> Coordinator {
        self.calibration = Some(cal);
        self
    }

    /// Arm a planned worker death for process-backend runs (chaos testing;
    /// see [`FaultPlan`]).
    pub fn with_fault_plan(mut self, plan: FaultPlan) -> Coordinator {
        self.fault = Some(plan);
        self
    }

    /// Arm a planned network fault for process-backend runs (chaos
    /// testing; see [`NetFaultPlan`]).
    pub fn with_net_fault_plan(mut self, plan: NetFaultPlan) -> Coordinator {
        self.net_fault = Some(plan);
        self
    }

    /// Override the heartbeat-lease timeout for process-backend runs. A
    /// rank that sends the hub nothing — no data frame, no `PONG` — for
    /// this long mid-phase is force-killed and respawned (DESIGN.md §15).
    pub fn with_lease_timeout(mut self, timeout: Duration) -> Coordinator {
        self.lease_timeout = Some(timeout);
        self
    }

    /// Override the probe budget — expansion cost units a worker mines
    /// between mailbox polls — for phases 1–2 on any backend. A workload
    /// that fits inside one paper-default quantum (4 M units) leaves no
    /// mid-phase poll at which a busy victim could answer a steal
    /// request; a smaller budget makes the steal protocol observable on
    /// short runs (`--trace`, DESIGN.md §14) at the price of more polling.
    pub fn with_probe_budget(mut self, units: u64) -> Coordinator {
        self.probe_budget = Some(units.max(1));
        self
    }

    pub fn alpha(&self) -> f64 {
        self.alpha
    }

    pub fn glb(&self) -> GlbParams {
        self.glb
    }

    /// Run the complete three-phase procedure. Phases 1–2 execute on
    /// `backend`; phase 3 runs through the configured screen.
    ///
    /// The process backend spawns a [`ProcessFleet`] that serves *both*
    /// distributed phases (phase 2 reuses phase 1's shipped database via
    /// `RECONFIG`) and is torn down afterwards; callers answering many
    /// requests should hold their own fleet and use
    /// [`Coordinator::run_on_fleet`] instead.
    pub fn run(&self, db: &Database, backend: &Backend) -> Result<CoordinatorRun> {
        match backend {
            Backend::Process { p, seed, plane, transport } => {
                let listen = match transport {
                    Transport::Unix => None,
                    Transport::Tcp => Some(Endpoint::tcp("127.0.0.1", 0)),
                };
                let cfg = ProcessConfig {
                    data_plane: *plane,
                    listen,
                    ..self.process_config(*p, *seed)
                };
                let mut fleet = ProcessFleet::spawn(&cfg)?;
                let run = self.run_on_fleet(db, &mut fleet, *seed)?;
                fleet.shutdown()?;
                Ok(run)
            }
            Backend::Threads { p, .. } => {
                let seed = backend.seed();
                self.run_phases(db, |mode, idx| {
                    Ok(run_threads_with(
                        db,
                        mode,
                        &self.thread_config(*p, seed.wrapping_add(idx)),
                    ))
                })
            }
            Backend::Sim { p, net, .. } => {
                let seed = backend.seed();
                self.run_phases(db, |mode, idx| {
                    Ok(run_sim(db, mode, &self.sim_config(*p, *net, seed.wrapping_add(idx))))
                })
            }
        }
    }

    /// Run the three-phase procedure across an already-warm worker fleet —
    /// the entry point the `parlamp serve` daemon uses so the fleet
    /// outlives any single job. On error the fleet is poisoned and must be
    /// dropped (see [`ProcessFleet`]).
    pub fn run_on_fleet(
        &self,
        db: &Database,
        fleet: &mut ProcessFleet,
        seed: u64,
    ) -> Result<CoordinatorRun> {
        let cfg = self.process_config(fleet.p(), seed);
        let mut run = self.run_phases(db, |mode, idx| {
            fleet
                .run_phase(db, mode, &cfg, seed.wrapping_add(idx))
                .context("process-fabric phase")
        })?;
        // Fold the fleet's hub-side records (respawns, replay fences) into
        // the hub track; both sets are stamped on this process's clock.
        let (fleet_events, _dropped) = fleet.take_hub_trace();
        if !fleet_events.is_empty() {
            run.hub_events.extend(fleet_events);
            run.hub_events.sort_by_key(|e| e.t_ns);
        }
        Ok(run)
    }

    /// The three-phase skeleton, generic over how a distributed phase is
    /// executed. `phase(mode, phase_idx)` blocks until the phase's
    /// DTD-quiescent merge; `phase_idx` decorrelates the two phases' steal
    /// randomness, mirroring `lamp_parallel_threads`.
    fn run_phases<F>(&self, db: &Database, mut phase: F) -> Result<CoordinatorRun>
    where
        F: FnMut(RunMode, u64) -> Result<ParRunResult>,
    {
        let rule = SupportIncreaseRule::new(db.marginals(), self.alpha);
        // Hub-track spans: the coordinator brackets each phase on its own
        // clock, which puts phase 3 — never seen by any worker — on the
        // timeline too. One closure so the off case stays one branch.
        let mut hub_events: Vec<TraceEvent> = Vec::new();
        let mut stamp = |events: &mut Vec<TraceEvent>, kind: TraceEv| {
            if obs_trace::enabled() {
                events.push(TraceEvent { t_ns: clock::now_ns(), kind });
            }
        };

        // Phase 1: λ search with the piggybacked support-increase protocol.
        // The engine returns after DTD quiescence with the workers'
        // histograms merged; the exact λ* is then recomputed from that
        // merged histogram (the root's in-flight λ may lag — DESIGN.md §4).
        stamp(&mut hub_events, TraceEv::PhaseStart { phase: 1, epoch: 0 });
        let mut p1 = phase(RunMode::Phase1 { alpha: self.alpha }, 0)?;
        stamp(&mut hub_events, TraceEv::PhaseEnd { phase: 1, epoch: 0 });
        p1.finalize_phase1(&rule);
        debug_assert_eq!(
            rule.advance(p1.lambda_final, |l| p1.hist.cs_ge(l)),
            p1.lambda_final,
            "λ* must be a fixed point of the merged histogram"
        );

        // Phase 2: correction factor k = CS(λ* − 1) by re-mining at the
        // final minimum support.
        stamp(&mut hub_events, TraceEv::PhaseStart { phase: 2, epoch: 0 });
        let p2 = phase(RunMode::Count { min_sup: p1.min_sup }, 1)?;
        stamp(&mut hub_events, TraceEv::PhaseEnd { phase: 2, epoch: 0 });
        let k = p2.closed_total.max(1);

        // Phase 3: significance screen at the adjusted level α / k.
        stamp(&mut hub_events, TraceEv::PhaseStart { phase: 3, epoch: 0 });
        let (significant, screen) = self.screen(db, p1.min_sup, k)?;
        stamp(&mut hub_events, TraceEv::PhaseEnd { phase: 3, epoch: 0 });

        let result = LampResult {
            alpha: self.alpha,
            lambda_final: p1.lambda_final,
            min_sup: p1.min_sup,
            correction_factor: k,
            adjusted_level: self.alpha / k as f64,
            significant,
            phase1_closed: p1.closed_total,
            phase2_closed: p2.closed_total,
        };
        Ok(CoordinatorRun { result, screen, phase1: p1, phase2: p2, hub_events })
    }

    /// `GlbParams` (+ paper-default cadences) → process-engine knobs.
    fn process_config(&self, p: usize, seed: u64) -> ProcessConfig {
        let mut cfg = ProcessConfig {
            w: self.glb.w,
            l: self.glb.l,
            tree_arity: self.glb.tree_arity,
            steal: self.glb.steal,
            preprocess: self.glb.preprocess,
            fault: self.fault,
            net_fault: self.net_fault,
            ..ProcessConfig::paper_defaults(p, seed)
        };
        if let Some(t) = self.lease_timeout {
            cfg.lease_timeout = t;
        }
        if let Some(units) = self.probe_budget {
            cfg.probe_budget_units = units;
        }
        cfg
    }

    /// `GlbParams` (+ paper-default cadences) → thread-engine knobs.
    fn thread_config(&self, p: usize, seed: u64) -> ThreadConfig {
        let mut cfg = ThreadConfig {
            w: self.glb.w,
            l: self.glb.l,
            tree_arity: self.glb.tree_arity,
            steal: self.glb.steal,
            preprocess: self.glb.preprocess,
            ..ThreadConfig::paper_defaults(p, seed)
        };
        if let Some(units) = self.probe_budget {
            cfg.probe_budget_units = units;
        }
        cfg
    }

    /// `GlbParams` (+ calibration when present) → DES knobs.
    fn sim_config(&self, p: usize, net: NetModel, seed: u64) -> SimConfig {
        let base = match &self.calibration {
            Some(cal) => SimConfig::calibrated(p, cal),
            None => SimConfig::paper_defaults(p),
        };
        let mut cfg = SimConfig {
            p,
            net,
            seed,
            w: self.glb.w,
            l: self.glb.l,
            tree_arity: self.glb.tree_arity,
            steal: self.glb.steal,
            preprocess: self.glb.preprocess,
            ..base
        };
        if let Some(units) = self.probe_budget {
            cfg.probe_budget_units = units;
        }
        cfg
    }

    /// Phase-3 dispatch: PJRT screen or native Fisher, per [`ScreenMode`].
    /// Public so serial pipelines (CLI `--engine serial|lamp2`) share the
    /// exact same screen-selection policy as coordinated runs.
    pub fn screen(
        &self,
        db: &Database,
        min_sup: u32,
        correction_factor: u64,
    ) -> Result<(Vec<SignificantPattern>, ScreenKind)> {
        match self.screen {
            ScreenMode::Native => {
                let sig = phase3_extract(db, min_sup, correction_factor, self.alpha);
                Ok((sig, ScreenKind::Native))
            }
            ScreenMode::Xla => {
                let sig = self.xla_screen(db, min_sup, correction_factor)?;
                Ok((sig, ScreenKind::Xla))
            }
            ScreenMode::Auto => {
                // Fall back to native when artifacts are absent, the PJRT
                // backend is not compiled in (stub build), or the frozen
                // artifact shapes cannot hold this database — but say why,
                // so an operator can tell why the fast path never runs.
                if artifacts_available() {
                    match self.xla_screen(db, min_sup, correction_factor) {
                        Ok(sig) => return Ok((sig, ScreenKind::Xla)),
                        Err(e) => {
                            log::warn(
                                "coord",
                                &log::Tags::NONE,
                                format_args!("XLA screen unusable, using native: {e:#}"),
                            );
                        }
                    }
                }
                let sig = phase3_extract(db, min_sup, correction_factor, self.alpha);
                Ok((sig, ScreenKind::Native))
            }
        }
    }

    /// The XLA/PJRT screen path: load artifacts, compile, batch-score.
    /// Shared by the `Xla` (required) and `Auto` (best-effort) modes.
    fn xla_screen(
        &self,
        db: &Database,
        min_sup: u32,
        correction_factor: u64,
    ) -> Result<Vec<SignificantPattern>> {
        let rt = XlaRuntime::load(&artifacts_dir())
            .context("load XLA artifacts (run `make artifacts`)")?;
        let engine = ScreenEngine::new(rt);
        phase3_extract_xla(&engine, db, min_sup, correction_factor, self.alpha)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_gwas, GwasSpec};
    use crate::lamp::lamp_serial;

    fn small_db() -> crate::db::Database {
        let spec = GwasSpec { n_snps: 120, n_individuals: 80, n_pos: 20, ..GwasSpec::small(99) };
        generate_gwas(&spec).0
    }

    #[test]
    fn sim_run_matches_serial_end_to_end() {
        let db = small_db();
        let serial = lamp_serial(&db, 0.05);
        let run = Coordinator::new(0.05)
            .with_screen(ScreenMode::Native)
            .run(&db, &Backend::sim(6))
            .expect("coordinated run");
        assert_eq!(run.result.lambda_final, serial.lambda_final);
        assert_eq!(run.result.correction_factor, serial.correction_factor);
        assert_eq!(run.result.significant.len(), serial.significant.len());
        for (a, b) in run.result.significant.iter().zip(&serial.significant) {
            assert_eq!(a.items, b.items);
        }
        assert!(run.t_parallel_s() > 0.0);
    }

    #[test]
    fn glb_params_flow_into_worker_topology() {
        // w = 0 must eliminate random steal attempts: every request is a
        // lifeline request, so rejects only carry the lifeline flag.
        let db = small_db();
        let glb = GlbParams { w: 0, ..GlbParams::default() };
        assert_eq!(glb.lifelines(0, 8).z(), 3); // binary hypercube of 8
        let run = Coordinator::new(0.05)
            .with_glb(glb)
            .with_screen(ScreenMode::Native)
            .run(&db, &Backend::sim(8))
            .expect("run");
        let serial = lamp_serial(&db, 0.05);
        assert_eq!(run.result.correction_factor, serial.correction_factor);
    }

    #[test]
    fn xla_screen_mode_errors_without_artifacts() {
        // CI has no artifacts; requiring the XLA screen must fail loudly
        // while Auto (the default) silently degrades to native.
        if artifacts_available() {
            return; // environment with artifacts: covered by runtime_xla
        }
        let db = small_db();
        let err = Coordinator::new(0.05)
            .with_screen(ScreenMode::Xla)
            .run(&db, &Backend::sim(2))
            .unwrap_err();
        assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
        let run = Coordinator::new(0.05).run(&db, &Backend::sim(2)).expect("auto run");
        assert_eq!(run.screen, ScreenKind::Native);
    }

    #[test]
    fn backend_builders_compose() {
        let b = Backend::process(4)
            .with_seed(7)
            .with_data_plane(DataPlane::Hub)
            .with_transport(Transport::Tcp);
        match b {
            Backend::Process { p, seed, plane, transport } => {
                assert_eq!(p, 4);
                assert_eq!(seed, 7);
                assert!(matches!(plane, DataPlane::Hub));
                assert_eq!(transport, Transport::Tcp);
            }
            other => panic!("unexpected backend {other:?}"),
        }
        assert_eq!("tcp".parse::<Transport>().unwrap(), Transport::Tcp);
        assert_eq!("unix".parse::<Transport>().unwrap(), Transport::Unix);
        let err = "ib".parse::<Transport>().unwrap_err();
        assert!(err.to_string().contains("unix|tcp"), "{err}");
    }

    #[test]
    fn summary_mentions_phase_times() {
        let db = small_db();
        let run = Coordinator::new(0.05)
            .with_screen(ScreenMode::Native)
            .run(&db, &Backend::sim(3))
            .expect("run");
        let s = run.summary();
        assert!(s.contains("phase1"), "{s}");
        assert!(s.contains("screen=Native"), "{s}");
        let total = run.breakdown_total();
        assert!(total.total_ns() > 0);
        assert!(run.work_units_total() > 0, "merged work units must be non-zero");
    }
}
