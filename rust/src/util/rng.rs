//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded via SplitMix64 — fast, high quality, and fully
//! reproducible across platforms, which the discrete-event simulator and the
//! property-test harness both rely on. No external crates (the offline
//! registry has no `rand`).

/// A `xoshiro256**` PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream, e.g. one per simulated process.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be nonzero.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform `usize` in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for bound in [1u64, 2, 3, 10, 1 << 40] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        let mut acc = 0.0;
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            acc += x;
        }
        // mean of 1000 uniforms should be near 0.5
        assert!((acc / 1000.0 - 0.5).abs() < 0.05);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forked_streams_differ() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
