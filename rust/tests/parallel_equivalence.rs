//! Serial / thread-parallel / DES-parallel equivalence: the paper's
//! parallelization must not change *what* is computed, only how fast.
//! (λ*, CS(λ*−1), and the significant set are asserted identical.)

use parlamp::datagen::{generate_gwas, generate_mcf7_like, GwasSpec, Mcf7Spec};
use parlamp::db::Database;
use parlamp::fabric::sim::NetModel;
use parlamp::lamp::lamp_serial;
use parlamp::par::{lamp_parallel_sim, lamp_parallel_threads, SimConfig};

fn assert_equivalent(db: &Database, alpha: f64, p: usize, label: &str) {
    let serial = lamp_serial(db, alpha);
    let cfg = SimConfig { p, ..SimConfig::paper_defaults(p) };
    let (sim, _, _) = lamp_parallel_sim(db, alpha, &cfg);
    assert_eq!(sim.lambda_final, serial.lambda_final, "{label}: λ (sim p={p})");
    assert_eq!(
        sim.correction_factor, serial.correction_factor,
        "{label}: k (sim p={p})"
    );
    assert_eq!(
        sim.significant.len(),
        serial.significant.len(),
        "{label}: |significant| (sim p={p})"
    );
    for (a, b) in sim.significant.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items, "{label} (sim p={p})");
    }
}

#[test]
fn sim_engine_equivalent_across_worlds() {
    let (db, _) = generate_gwas(&GwasSpec::small(2015));
    for p in [1usize, 2, 7, 16, 61] {
        assert_equivalent(&db, 0.05, p, "gwas-small");
    }
}

#[test]
fn sim_engine_equivalent_large_world() {
    // More processes than items: exercises empty preprocess partitions.
    let spec = GwasSpec { n_snps: 60, n_individuals: 64, n_pos: 16, ..GwasSpec::small(8) };
    let (db, _) = generate_gwas(&spec);
    assert_equivalent(&db, 0.05, 128, "more-procs-than-items");
}

#[test]
fn sim_engine_equivalent_mcf7_like() {
    let (db, _) = generate_mcf7_like(&Mcf7Spec::small(3));
    assert_equivalent(&db, 0.05, 24, "mcf7-like");
}

#[test]
fn thread_engine_equivalent() {
    let (db, _) = generate_gwas(&GwasSpec::small(44));
    let serial = lamp_serial(&db, 0.05);
    for p in [2usize, 6] {
        let (thr, _, _) = lamp_parallel_threads(&db, 0.05, p, true, 7);
        assert_eq!(thr.lambda_final, serial.lambda_final, "thread p={p}");
        assert_eq!(thr.correction_factor, serial.correction_factor, "thread p={p}");
        assert_eq!(thr.significant.len(), serial.significant.len(), "thread p={p}");
    }
}

#[test]
fn slow_network_changes_time_not_results() {
    let (db, _) = generate_gwas(&GwasSpec::small(55));
    let fast = SimConfig { p: 12, ..SimConfig::paper_defaults(12) };
    let slow = SimConfig { p: 12, net: NetModel::ethernet(), ..SimConfig::paper_defaults(12) };
    let (rf, p1f, _) = lamp_parallel_sim(&db, 0.05, &fast);
    let (rs, p1s, _) = lamp_parallel_sim(&db, 0.05, &slow);
    // Results must be identical regardless of the network (paper §5.2's
    // network-delay discussion: latency only costs time).
    assert_eq!(rf.lambda_final, rs.lambda_final);
    assert_eq!(rf.correction_factor, rs.correction_factor);
    assert_eq!(rf.significant.len(), rs.significant.len());
    // Timing: on a tiny tree the makespan is quantized by the DTD wave
    // cadence, so "slower net ⇒ strictly slower" does not hold pointwise;
    // a 250× latency increase must not *improve* time by more than one
    // wave interval, though.
    assert!(
        p1s.makespan_s >= p1f.makespan_s - 2e-3,
        "slow net {} implausibly beat fast net {}",
        p1s.makespan_s,
        p1f.makespan_s
    );
}

#[test]
fn steal_traffic_exists_and_conserves_work() {
    // Unbalanced tree (LD blocks + planted deep pattern) and a fine probe
    // budget so victims answer requests while still working.
    let spec = GwasSpec {
        n_snps: 300,
        n_individuals: 140,
        n_pos: 35,
        ld_copy_prob: 0.5,
        planted: vec![(4, 0.9)],
        ..GwasSpec::small(66)
    };
    let (db, _) = generate_gwas(&spec);
    let serial = lamp_serial(&db, 0.05);
    let cfg = SimConfig {
        p: 16,
        probe_budget_units: 100_000,
        ..SimConfig::paper_defaults(16)
    };
    let (res, p1, p2) = lamp_parallel_sim(&db, 0.05, &cfg);
    assert_eq!(res.correction_factor, serial.correction_factor);
    // With 16 procs on a non-trivial tree the protocol must actually move
    // work around…
    assert!(p1.comm.gives > 0 || p2.comm.gives > 0, "no task was ever shipped");
    // …and every phase-2 closed set is counted exactly once.
    assert_eq!(p2.closed_total, serial.correction_factor);
}
