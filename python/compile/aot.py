"""AOT lowering: JAX/Pallas → HLO **text** artifacts for the rust runtime.

Run once at build time (`make artifacts`); Python is never on the request
path. HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids that the xla_extension
0.5.1 under the rust `xla` crate rejects; the text parser reassigns ids
(see /opt/xla-example/README.md).

Artifacts:
  artifacts/screen.hlo.txt   — the full significance screen (L2 + both L1
                               Pallas kernels fused into one module)
  artifacts/support.hlo.txt  — popcount support counting alone
  artifacts/manifest.json    — frozen shapes the rust loader validates
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple for rust's
    `to_tupleN` unwrap)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_screen(k, w, t_max):
    def fn(occ, pos, n_total, n_pos):
        return model.screen_batch(occ, pos, n_total, n_pos, t_max=t_max)

    return jax.jit(fn).lower(*model.screen_example_args(k, w, t_max))


def lower_support(k, w):
    from .kernels.popcount import support_counts

    import jax.numpy as jnp

    def fn(occ, pos):
        return support_counts(occ, pos)

    return jax.jit(fn).lower(
        jax.ShapeDtypeStruct((k, w), jnp.uint32),
        jax.ShapeDtypeStruct((w,), jnp.uint32),
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--k", type=int, default=1024, help="batch capacity (candidates)")
    ap.add_argument("--w", type=int, default=64, help="u32 words per bitmap (64 = 2048 transactions)")
    ap.add_argument("--t-max", type=int, default=512, help="max Fisher tail length (must be > N_pos)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)

    screen = to_hlo_text(lower_screen(args.k, args.w, args.t_max))
    screen_path = os.path.join(args.out_dir, "screen.hlo.txt")
    with open(screen_path, "w") as f:
        f.write(screen)
    print(f"wrote {screen_path} ({len(screen)} chars)")

    support = to_hlo_text(lower_support(args.k, args.w))
    support_path = os.path.join(args.out_dir, "support.hlo.txt")
    with open(support_path, "w") as f:
        f.write(support)
    print(f"wrote {support_path} ({len(support)} chars)")

    manifest = {
        "k": args.k,
        "w": args.w,
        "t_max": args.t_max,
        "entries": {
            "screen": {
                "file": "screen.hlo.txt",
                "inputs": [
                    {"name": "occ_words", "shape": [args.k, args.w], "dtype": "u32"},
                    {"name": "pos_words", "shape": [args.w], "dtype": "u32"},
                    {"name": "n_total", "shape": [1], "dtype": "f64"},
                    {"name": "n_pos", "shape": [1], "dtype": "f64"},
                ],
                "outputs": ["x:i32", "n:i32", "logp:f64", "logf:f64"],
            },
            "support": {
                "file": "support.hlo.txt",
                "inputs": [
                    {"name": "occ_words", "shape": [args.k, args.w], "dtype": "u32"},
                    {"name": "pos_words", "shape": [args.w], "dtype": "u32"},
                ],
                "outputs": ["x:i32", "n:i32"],
            },
        },
    }
    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
