//! Fig. 6: time and speedup vs process count, all six problems,
//! P ∈ {1, 12, 24, 48, 96, 192, 300, 600, 1200} (paper §5.2).
//!
//! Run: `cargo bench --bench fig6 [-- --quick]`

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::par::{lamp_parallel_sim, SimConfig};
use parlamp::util::bench_harness::{quick_mode, BenchSet};
use parlamp::util::fmt_secs;

const PROCS: &[usize] = &[1, 12, 24, 48, 96, 192, 300, 600, 1200];

fn main() {
    let quick = quick_mode();
    let alpha = parlamp::DEFAULT_ALPHA;
    let procs: Vec<usize> =
        if quick { vec![1, 12, 96, 1200] } else { PROCS.to_vec() };
    for sc in all_scenarios(quick) {
        let db = sc.build();
        let cal = calibrate_lamp(&db, alpha);
        let t1 = cal.t1_s; // phases 1+2, the computation the sims run
        let mut set = BenchSet::new(
            &format!(
                "Fig 6 — {} ({}, t1={})",
                sc.name,
                if sc.large { "LARGE" } else { "small" },
                fmt_secs(t1)
            ),
            &["P", "time", "speedup", "efficiency", "gives", "msgs"],
        );
        for &p in &procs {
            let cfg = SimConfig { p, ..SimConfig::calibrated(p, &cal) };
            let (_res, p1, p2) = lamp_parallel_sim(&db, alpha, &cfg);
            let t = p1.makespan_s + p2.makespan_s;
            let speedup = t1 / t.max(1e-12);
            set.row(vec![
                p.to_string(),
                fmt_secs(t),
                format!("{speedup:.1}x"),
                format!("{:.0}%", 100.0 * speedup / p as f64),
                (p1.comm.gives + p2.comm.gives).to_string(),
                (p1.comm.sent + p2.comm.sent).to_string(),
            ]);
        }
        set.finish();
    }
}
