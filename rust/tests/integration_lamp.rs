//! End-to-end LAMP integration over the synthetic GWAS / MCF7 generators:
//! the full three-phase pipeline, statistical sanity (FWER behaviour under
//! the null), and planted-pattern recovery.

use parlamp::datagen::{generate_gwas, generate_mcf7_like, GeneticModel, GwasSpec, Mcf7Spec};
use parlamp::lamp::{lamp2::lamp2_serial, lamp_serial};
use parlamp::stats::FisherTable;
use parlamp::util::rng::Rng;

#[test]
fn planted_gwas_pattern_is_discovered() {
    let spec = GwasSpec {
        n_snps: 200,
        n_individuals: 150,
        n_pos: 40,
        model: GeneticModel::Dominant,
        maf_upper: 0.2,
        ld_copy_prob: 0.25,
        common_frac: 0.2,
        planted: vec![(3, 0.9)],
        seed: 31,
    };
    let (db, planted) = generate_gwas(&spec);
    let res = lamp_serial(&db, 0.05);
    assert!(res.min_sup >= 1);
    assert!(res.correction_factor >= 1);
    assert!(
        !res.significant.is_empty(),
        "a strongly planted pattern must reach significance: {}",
        res.summary()
    );
    // the planted items (or a closed superset) must appear
    let p = &planted[0];
    assert!(
        res.significant.iter().any(|s| p.iter().all(|i| s.items.contains(i))),
        "planted {:?} missing from {:?}",
        p,
        res.significant.iter().map(|s| &s.items).collect::<Vec<_>>()
    );
}

#[test]
fn null_data_rarely_rejects() {
    // With no planted signal and random labels, LAMP at α = 0.05 should
    // essentially never report anything (FWER control); we allow a single
    // seed to fire across 8 runs.
    let mut fires = 0;
    for seed in 0..8u64 {
        let spec = GwasSpec {
            n_snps: 120,
            n_individuals: 80,
            n_pos: 20,
            model: GeneticModel::Dominant,
            maf_upper: 0.25,
            ld_copy_prob: 0.2,
            common_frac: 0.2,
            planted: vec![],
            seed: 1000 + seed,
        };
        let (db, _) = generate_gwas(&spec);
        let res = lamp_serial(&db, 0.05);
        if !res.significant.is_empty() {
            fires += 1;
        }
    }
    assert!(fires <= 1, "null data fired {fires}/8 times — FWER control broken?");
}

#[test]
fn reported_p_values_are_exact_and_below_delta() {
    let (db, _) = generate_gwas(&GwasSpec::small(77));
    let res = lamp_serial(&db, 0.05);
    let fisher = FisherTable::new(db.marginals());
    for s in &res.significant {
        assert!(s.p_value <= res.adjusted_level * (1.0 + 1e-12));
        assert_eq!(db.support(&s.items), s.support);
        let occ = db.occurrence(&s.items);
        assert_eq!(db.pos_support(&occ), s.pos_support);
        let want = fisher.p_value(s.support, s.pos_support);
        assert!((s.p_value - want).abs() < 1e-12);
        assert!(s.support >= res.min_sup, "significant pattern below min_sup");
    }
}

#[test]
fn mcf7_like_pipeline_runs_and_agrees_with_lamp2() {
    let spec = Mcf7Spec::small(5);
    let (db, _) = generate_mcf7_like(&spec);
    let a = lamp_serial(&db, 0.05);
    let b = lamp2_serial(&db, 0.05);
    assert_eq!(a.lambda_final, b.lambda_final);
    assert_eq!(a.correction_factor, b.correction_factor);
    assert_eq!(a.significant.len(), b.significant.len());
}

#[test]
fn alpha_monotonicity_of_discoveries() {
    let spec = GwasSpec { planted: vec![(2, 0.9), (3, 0.8)], ..GwasSpec::small(13) };
    let (db, _) = generate_gwas(&spec);
    let strict = lamp_serial(&db, 0.01);
    let loose = lamp_serial(&db, 0.10);
    // A stricter family-wise level cannot *increase* the minimum support's
    // leniency: λ* is non-decreasing in 1/α.
    assert!(strict.lambda_final >= loose.lambda_final);
}

#[test]
fn lambda_reported_matches_table1_semantics() {
    // Table 1's λ column is the *minimum support* (λ* − 1); make sure the
    // plumbing agrees with phase 2's mining threshold.
    let mut rng = Rng::new(4);
    for _ in 0..5 {
        let (db, _) = generate_gwas(&GwasSpec::small(rng.next_u64()));
        let res = lamp_serial(&db, 0.05);
        assert_eq!(res.min_sup, res.lambda_final.saturating_sub(1).max(1));
        for s in &res.significant {
            assert!(s.support >= res.min_sup);
        }
    }
}
