//! Payload of the worker → hub `TRACE` frame (v7, DESIGN.md §14).
//!
//! When a phase runs with tracing armed ([`crate::wire::PhaseSpec::trace`]),
//! each worker drains its event ring ([`crate::obs::trace::TraceRing`])
//! right after `MERGE` and ships it as one [`TraceChunk`]. The chunk also
//! carries the two worker-clock stamps the hub needs for clock alignment
//! — when the worker *read* `START` and when it *wrote* this frame — which
//! the hub pairs with its own send/receive stamps to form one NTP-style
//! handshake sample per phase ([`crate::obs::clock::estimate_offset`]).
//!
//! Events encode as `t_ns:u64 kind:u8 args…`; the event count is
//! validated against the bytes actually remaining (9 bytes minimum per
//! event) so corrupt input errors instead of allocating gigabytes.

use anyhow::{bail, Result};

use crate::obs::trace::{EventKind, TraceEvent};

use super::{put_bool, put_u32, put_u64, put_u8, Dec};

/// One rank's flushed event ring plus its clock-handshake stamps.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceChunk {
    /// The rank whose timeline this is.
    pub rank: u32,
    /// Respawn epoch the events were recorded under.
    pub epoch: u64,
    /// Worker-clock time at which this phase's `START` frame was read
    /// (pairs with the hub's stamp of the matching write).
    pub start_recv_ns: u64,
    /// Worker-clock time at which this frame was written (pairs with the
    /// hub's stamp of the read).
    pub flush_ns: u64,
    /// Events lost to ring overflow — counted, never silent.
    pub dropped: u64,
    /// The ring contents, in recording order (worker-clock timestamps).
    pub events: Vec<TraceEvent>,
}

// Event kind discriminants. New kinds append; existing values are wire
// format and never change.
const EK_PHASE_START: u8 = 0;
const EK_PHASE_END: u8 = 1;
const EK_EXPAND_BATCH: u8 = 2;
const EK_STEAL_REQUEST: u8 = 3;
const EK_STEAL_REJECT: u8 = 4;
const EK_STEAL_GIVE: u8 = 5;
const EK_STEAL_RECV: u8 = 6;
const EK_WAVE_ARRIVE: u8 = 7;
const EK_CHECKPOINT: u8 = 8;
const EK_RESPAWN: u8 = 9;
const EK_SERVE_QUEUE: u8 = 10;
const EK_SERVE_POP: u8 = 11;
const EK_SERVE_EXPIRE: u8 = 12;
// v8 heartbeat-lease kinds (DESIGN.md §15).
const EK_LEASE_MISS: u8 = 13;
const EK_FORCE_KILL: u8 = 14;

fn put_event(buf: &mut Vec<u8>, e: &TraceEvent) {
    put_u64(buf, e.t_ns);
    match e.kind {
        EventKind::PhaseStart { phase, epoch } => {
            put_u8(buf, EK_PHASE_START);
            put_u8(buf, phase);
            put_u64(buf, epoch);
        }
        EventKind::PhaseEnd { phase, epoch } => {
            put_u8(buf, EK_PHASE_END);
            put_u8(buf, phase);
            put_u64(buf, epoch);
        }
        EventKind::ExpandBatch { units } => {
            put_u8(buf, EK_EXPAND_BATCH);
            put_u64(buf, units);
        }
        EventKind::StealRequest { dst, lifeline } => {
            put_u8(buf, EK_STEAL_REQUEST);
            put_u32(buf, dst);
            put_bool(buf, lifeline);
        }
        EventKind::StealReject { src, lifeline } => {
            put_u8(buf, EK_STEAL_REJECT);
            put_u32(buf, src);
            put_bool(buf, lifeline);
        }
        EventKind::StealGive { dst, tasks } => {
            put_u8(buf, EK_STEAL_GIVE);
            put_u32(buf, dst);
            put_u32(buf, tasks);
        }
        EventKind::StealRecv { src, tasks } => {
            put_u8(buf, EK_STEAL_RECV);
            put_u32(buf, src);
            put_u32(buf, tasks);
        }
        EventKind::WaveArrive { t, up } => {
            put_u8(buf, EK_WAVE_ARRIVE);
            put_u32(buf, t);
            put_bool(buf, up);
        }
        EventKind::Checkpoint { units, roots } => {
            put_u8(buf, EK_CHECKPOINT);
            put_u64(buf, units);
            put_u32(buf, roots);
        }
        EventKind::Respawn { rank, epoch } => {
            put_u8(buf, EK_RESPAWN);
            put_u32(buf, rank);
            put_u64(buf, epoch);
        }
        EventKind::ServeQueue { job } => {
            put_u8(buf, EK_SERVE_QUEUE);
            put_u64(buf, job);
        }
        EventKind::ServePop { job } => {
            put_u8(buf, EK_SERVE_POP);
            put_u64(buf, job);
        }
        EventKind::ServeExpire { job } => {
            put_u8(buf, EK_SERVE_EXPIRE);
            put_u64(buf, job);
        }
        EventKind::LeaseMiss { rank, epoch } => {
            put_u8(buf, EK_LEASE_MISS);
            put_u32(buf, rank);
            put_u64(buf, epoch);
        }
        EventKind::ForceKill { rank, epoch } => {
            put_u8(buf, EK_FORCE_KILL);
            put_u32(buf, rank);
            put_u64(buf, epoch);
        }
    }
}

fn get_event(d: &mut Dec) -> Result<TraceEvent> {
    let t_ns = d.u64()?;
    let kind = match d.u8()? {
        EK_PHASE_START => EventKind::PhaseStart { phase: d.u8()?, epoch: d.u64()? },
        EK_PHASE_END => EventKind::PhaseEnd { phase: d.u8()?, epoch: d.u64()? },
        EK_EXPAND_BATCH => EventKind::ExpandBatch { units: d.u64()? },
        EK_STEAL_REQUEST => EventKind::StealRequest { dst: d.u32()?, lifeline: d.bool()? },
        EK_STEAL_REJECT => EventKind::StealReject { src: d.u32()?, lifeline: d.bool()? },
        EK_STEAL_GIVE => EventKind::StealGive { dst: d.u32()?, tasks: d.u32()? },
        EK_STEAL_RECV => EventKind::StealRecv { src: d.u32()?, tasks: d.u32()? },
        EK_WAVE_ARRIVE => EventKind::WaveArrive { t: d.u32()?, up: d.bool()? },
        EK_CHECKPOINT => EventKind::Checkpoint { units: d.u64()?, roots: d.u32()? },
        EK_RESPAWN => EventKind::Respawn { rank: d.u32()?, epoch: d.u64()? },
        EK_SERVE_QUEUE => EventKind::ServeQueue { job: d.u64()? },
        EK_SERVE_POP => EventKind::ServePop { job: d.u64()? },
        EK_SERVE_EXPIRE => EventKind::ServeExpire { job: d.u64()? },
        EK_LEASE_MISS => EventKind::LeaseMiss { rank: d.u32()?, epoch: d.u64()? },
        EK_FORCE_KILL => EventKind::ForceKill { rank: d.u32()?, epoch: d.u64()? },
        k => bail!("wire: unknown trace event kind {k}"),
    };
    Ok(TraceEvent { t_ns, kind })
}

pub(super) fn put_trace_chunk(buf: &mut Vec<u8>, c: &TraceChunk) {
    put_u32(buf, c.rank);
    put_u64(buf, c.epoch);
    put_u64(buf, c.start_recv_ns);
    put_u64(buf, c.flush_ns);
    put_u64(buf, c.dropped);
    put_u32(buf, c.events.len() as u32);
    for e in &c.events {
        put_event(buf, e);
    }
}

pub(super) fn get_trace_chunk(d: &mut Dec) -> Result<TraceChunk> {
    let rank = d.u32()?;
    let epoch = d.u64()?;
    let start_recv_ns = d.u64()?;
    let flush_ns = d.u64()?;
    let dropped = d.u64()?;
    // Each event is at least t_ns(8) + kind(1) bytes.
    let n = d.count(9)?;
    let mut events = Vec::with_capacity(n);
    for _ in 0..n {
        events.push(get_event(d)?);
    }
    Ok(TraceChunk { rank, epoch, start_recv_ns, flush_ns, dropped, events })
}
