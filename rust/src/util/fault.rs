//! Deterministic fault injection for the process fleet (DESIGN.md §12).
//!
//! A [`FaultPlan`] names one worker rank and the moment it must die: during
//! phase epoch `phase`, once the rank's local expansion clock passes
//! `after` work units — or, if the epoch completes first, at the rank's
//! next idle poll after the epoch has passed (which is how the chaos suite
//! kills a worker *between* distributed phases, e.g. while the owner runs
//! the serial phase-3 screen). The plan travels as one CLI/env token,
//!
//! ```text
//! rank=R,phase=P,after=N
//! ```
//!
//! parsed by [`FaultPlan::parse`] and re-emitted verbatim by `Display`, so
//! the same spelling works for `--fault-inject` on `lamp` and `serve`, for
//! the `PARLAMP_FAULT_INJECT` environment variable, and for the argv the
//! fleet owner forwards to each spawned `__worker`. The injected death is
//! `process::exit(FAULT_EXIT_CODE)` — a real worker loss from the fleet's
//! point of view (socket EOF → `Gone`), not a simulated one.
//!
//! Respawned replacement workers are always launched *without* the plan
//! (see `Fleet::respawn`): the fault fires exactly once, which is what the
//! chaos CI gates' "exactly one respawn" greps pin down.

use anyhow::{bail, Context, Result};

/// Exit code of a worker killed by fault injection. Distinctive so a chaos
/// test or an operator reading `serve` logs can tell an injected death
/// from a real crash.
pub const FAULT_EXIT_CODE: i32 = 86;

/// Environment variable consulted by `__worker` when no `--fault-inject`
/// argument is present (same `rank=R,phase=P,after=N` grammar).
pub const FAULT_ENV: &str = "PARLAMP_FAULT_INJECT";

/// One planned worker death: kill `rank` during phase epoch `phase` once
/// its work-unit clock reaches `after` (or at the first idle poll after
/// the epoch has passed, if the phase finishes under budget).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultPlan {
    /// Worker rank to kill.
    pub rank: usize,
    /// Fleet phase epoch (0-based, hub-assigned; monotonic across jobs,
    /// replays, and warm-fleet lifetimes) during which the fault arms.
    pub phase: u64,
    /// Local work units into that epoch after which the fault fires.
    pub after: u64,
}

impl FaultPlan {
    /// Parse the `rank=R,phase=P,after=N` spelling (fields in any order,
    /// all three required).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let (mut rank, mut phase, mut after) = (None, None, None);
        for field in s.split(',').filter(|f| !f.is_empty()) {
            let (key, value) = field
                .split_once('=')
                .with_context(|| format!("fault plan field '{field}' is not key=value"))?;
            match key.trim() {
                "rank" => {
                    rank = Some(value.trim().parse::<usize>().with_context(|| {
                        format!("fault plan rank '{value}' is not an unsigned integer")
                    })?);
                }
                "phase" => {
                    phase = Some(value.trim().parse::<u64>().with_context(|| {
                        format!("fault plan phase '{value}' is not an unsigned integer")
                    })?);
                }
                "after" => {
                    after = Some(value.trim().parse::<u64>().with_context(|| {
                        format!("fault plan after '{value}' is not an unsigned integer")
                    })?);
                }
                other => bail!("unknown fault plan field '{other}' (rank|phase|after)"),
            }
        }
        Ok(FaultPlan {
            rank: rank.context("fault plan is missing rank= (rank=R,phase=P,after=N)")?,
            phase: phase.context("fault plan is missing phase= (rank=R,phase=P,after=N)")?,
            after: after.context("fault plan is missing after= (rank=R,phase=P,after=N)")?,
        })
    }

    /// The plan fires mid-phase: `rank` is inside epoch `phase` and has
    /// done at least `after` work units.
    pub fn fires_in_phase(&self, rank: usize, epoch: u64, work_units: u64) -> bool {
        rank == self.rank && epoch == self.phase && work_units >= self.after
    }

    /// The plan fires at an idle poll: epoch `phase` has already completed
    /// (`phases_started` counts past it) without the in-phase trigger
    /// having been reached — death at the first opportunity afterwards.
    pub fn fires_after_phase(&self, rank: usize, phases_started: u64) -> bool {
        rank == self.rank && phases_started > self.phase
    }
}

impl std::fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "rank={},phase={},after={}", self.rank, self.phase, self.after)
    }
}

impl std::str::FromStr for FaultPlan {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<FaultPlan> {
        FaultPlan::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        let plan = FaultPlan { rank: 2, phase: 1, after: 4096 };
        assert_eq!(FaultPlan::parse(&plan.to_string()).unwrap(), plan);
        // Any field order parses; whitespace around fields is tolerated.
        assert_eq!(
            FaultPlan::parse("after=4096, rank=2 ,phase=1").unwrap(),
            plan
        );
        assert_eq!("rank=0,phase=0,after=0".parse::<FaultPlan>().unwrap().after, 0);
    }

    #[test]
    fn parse_rejects_malformed_plans() {
        for bad in [
            "",
            "rank=1",                       // missing phase/after
            "rank=1,phase=0",               // missing after
            "rank=x,phase=0,after=1",       // non-numeric
            "rank=1,phase=0,after=1,bogus=2", // unknown field
            "rank,phase=0,after=1",         // not key=value
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' must be rejected");
        }
    }

    #[test]
    fn trigger_semantics() {
        let plan = FaultPlan { rank: 1, phase: 2, after: 100 };
        // Mid-phase: only the named rank, only its epoch, only past budget.
        assert!(plan.fires_in_phase(1, 2, 100));
        assert!(plan.fires_in_phase(1, 2, 5000));
        assert!(!plan.fires_in_phase(1, 2, 99));
        assert!(!plan.fires_in_phase(0, 2, 5000));
        assert!(!plan.fires_in_phase(1, 3, 5000));
        // Post-phase: fires once the epoch counter moved past the armed
        // phase (a worker that survived under budget dies while idle).
        assert!(!plan.fires_after_phase(1, 2));
        assert!(plan.fires_after_phase(1, 3));
        assert!(!plan.fires_after_phase(0, 3));
    }
}
