//! The message fabric — an MPI-like substrate (paper §4.6 uses MVAPICH).
//!
//! The paper's protocol needs exactly the MPI surface of `MPI_Send` +
//! `MPI_Iprobe`/`MPI_Recv`: asynchronous point-to-point messages and a
//! non-blocking receive poll. [`Mailbox`] is that surface. Three backends
//! implement it:
//!
//! - [`thread::ThreadMailbox`] — one OS thread per process, channel-backed;
//!   exercises the real protocol code with true concurrency.
//! - [`sim`] — a deterministic discrete-event network used by
//!   `par::engine_sim` to model up to 1,200 processes with a calibrated
//!   latency/bandwidth model (the TSUBAME substitution; see DESIGN.md §2).
//! - [`process`] — one OS process per rank over Unix-domain sockets, every
//!   message crossing the [`crate::wire`] serialization boundary; the only
//!   backend with real address-space separation (DESIGN.md §7).
//!
//! Message taxonomy follows Mattern's terminology (paper §4.3): *basic*
//! messages (steal protocol traffic) are counted and time-stamped for
//! termination detection; *control* messages (DTD waves, preprocess
//! barrier, finish) are not.

pub mod process;
pub mod sim;
pub mod thread;

use crate::db::Item;

/// A search-tree task in wire form: the occurrence bitmap is stripped (the
/// itemset identifies the node — paper §4.1) and recomputed by the thief.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct WireTask {
    pub items: Vec<Item>,
    pub core: i64,
    pub support: u32,
}

impl WireTask {
    /// Approximate serialized size, used by the bandwidth model.
    pub fn wire_bytes(&self) -> usize {
        16 + self.items.len() * std::mem::size_of::<Item>()
    }
}

/// Steal-protocol payloads — Mattern *basic* messages.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BasicKind {
    /// Work-steal request; `lifeline` marks a lifeline (hypercube-edge)
    /// request that the victim records for deferred distribution.
    Request { lifeline: bool },
    /// Victim had no work. Echoes the request's `lifeline` flag so the
    /// thief can tell a (terminal) random rejection from a lifeline
    /// rejection — after the latter the victim has *recorded* the lifeline
    /// and will GIVE when it next has surplus work (paper §4.2,
    /// `Distribute`).
    Reject { lifeline: bool },
    /// Work transfer: half of the victim's stack.
    Give { tasks: Vec<WireTask> },
}

/// Sparse per-support closed-set counts, the λ-gather payload (paper §4.4).
pub type HistDelta = Vec<(u32, u64)>;

/// All messages exchanged by processes.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// A counted, clock-stamped basic message (steal traffic).
    Basic { stamp: u64, kind: BasicKind },
    /// DTD wave descending the ternary spanning tree; carries the current
    /// global λ (piggyback, paper §4.4).
    WaveDown { t: u64, lambda: u32 },
    /// DTD wave ascending: aggregated message-counter deficit, cut
    /// invalidation flag, idleness, and the closed-set histogram delta.
    WaveUp { t: u64, count: i64, invalid: bool, all_idle: bool, hist: HistDelta },
    /// Preprocess barrier: depth-1 histogram ascending the tree (§4.5).
    PreUp { hist: HistDelta },
    /// Preprocess barrier release with the initial λ.
    PreDown { lambda: u32 },
    /// Global termination (broadcast by the root once DTD fires).
    Finish,
}

impl Msg {
    /// Approximate wire size in bytes for the bandwidth model.
    pub fn wire_bytes(&self) -> usize {
        match self {
            Msg::Basic { kind, .. } => {
                16 + match kind {
                    BasicKind::Request { .. } => 1,
                    BasicKind::Reject { .. } => 1,
                    BasicKind::Give { tasks } => {
                        tasks.iter().map(WireTask::wire_bytes).sum::<usize>()
                    }
                }
            }
            Msg::WaveDown { .. } => 24,
            Msg::WaveUp { hist, .. } | Msg::PreUp { hist } => 40 + hist.len() * 12,
            Msg::PreDown { .. } => 12,
            Msg::Finish => 8,
        }
    }

    /// Is this a Mattern *basic* (counted) message?
    pub fn is_basic(&self) -> bool {
        matches!(self, Msg::Basic { .. })
    }
}

/// The MPI-like surface a worker drives its communication through.
pub trait Mailbox {
    /// Own rank.
    fn rank(&self) -> usize;
    /// World size.
    fn size(&self) -> usize;
    /// Asynchronous send (never blocks).
    fn send(&mut self, dst: usize, msg: Msg);
    /// Non-blocking receive of any pending message (`MPI_Iprobe` + recv).
    fn try_recv(&mut self) -> Option<(usize, Msg)>;
}

/// Per-process communication counters (reported in EXPERIMENTS.md and used
/// by the overhead breakdown).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CommStats {
    pub sent: u64,
    pub received: u64,
    pub steal_requests: u64,
    pub rejects: u64,
    pub gives: u64,
    pub tasks_shipped: u64,
    pub bytes_sent: u64,
    /// Process fabric only: data-plane frames this rank pushed through the
    /// parent hub's relay (the hub data plane; 0 under the mesh plane and
    /// on the in-process fabrics). Together with [`direct_frames`] this
    /// makes the hub-vs-mesh win observable: a mesh run must report 0 here
    /// (DESIGN.md §10).
    ///
    /// [`direct_frames`]: CommStats::direct_frames
    pub hub_frames: u64,
    /// Process fabric only: data-plane frames sent worker-to-worker over a
    /// direct mesh connection, with zero hub hops.
    pub direct_frames: u64,
}

impl CommStats {
    pub fn add(&mut self, o: &CommStats) {
        self.sent += o.sent;
        self.received += o.received;
        self.steal_requests += o.steal_requests;
        self.rejects += o.rejects;
        self.gives += o.gives;
        self.tasks_shipped += o.tasks_shipped;
        self.bytes_sent += o.bytes_sent;
        self.hub_frames += o.hub_frames;
        self.direct_frames += o.direct_frames;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_bytes_scale_with_payload() {
        let small = Msg::Basic { stamp: 0, kind: BasicKind::Reject { lifeline: false } };
        let big = Msg::Basic {
            stamp: 0,
            kind: BasicKind::Give {
                tasks: vec![WireTask { items: vec![1; 100], core: 5, support: 3 }],
            },
        };
        assert!(big.wire_bytes() > small.wire_bytes() + 300);
    }

    #[test]
    fn basic_classification() {
        assert!(Msg::Basic { stamp: 1, kind: BasicKind::Reject { lifeline: false } }.is_basic());
        assert!(!Msg::Finish.is_basic());
        assert!(!Msg::WaveDown { t: 0, lambda: 1 }.is_basic());
    }
}
