//! Thread engine: one OS thread per process, real wall-clock time.
//!
//! This is the configuration the paper runs on a single compute node
//! (§5.3, the `t₁₂` column of Table 1): MPI communication degenerates to a
//! memory copy. The container this reproduction runs in has a single
//! physical core, so wall-clock *speedup* is measured with the DES engine;
//! this engine demonstrates protocol correctness under true concurrency
//! and OS-scheduling nondeterminism.

use std::time::{Duration, Instant};

use crate::db::Database;
use crate::obs::trace::EventKind as TraceEv;

use super::engine_sim::collect;
use super::worker::{Poll, RunMode, Worker, WorkerConfig};
use super::ParRunResult;

/// Knobs for one thread-engine phase: the same GLB/DTD surface as
/// [`super::engine_sim::SimConfig`] minus the network model (the channel
/// fabric is "a memory copy", §5.3) and minus `ns_per_unit` (real
/// wall-clock replaces the virtual cost model).
#[derive(Clone, Debug)]
pub struct ThreadConfig {
    pub p: usize,
    /// Random steal attempts `w` (paper: 1).
    pub w: usize,
    /// Hypercube edge length `l` (paper: 2).
    pub l: usize,
    /// DTD spanning-tree arity (paper: 3).
    pub tree_arity: usize,
    /// `false` = naive baseline (no stealing).
    pub steal: bool,
    /// Depth-1 preprocess partition (§4.5).
    pub preprocess: bool,
    /// Work budget between probes, in expansion cost units (§4.6).
    pub probe_budget_units: u64,
    pub dtd_interval_ns: u64,
    pub seed: u64,
}

impl ThreadConfig {
    pub fn paper_defaults(p: usize, seed: u64) -> Self {
        ThreadConfig {
            p,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: true,
            probe_budget_units: 4_000_000,
            dtd_interval_ns: 1_000_000,
            seed,
        }
    }
}

/// Run one phase on `p` OS threads with the paper-default knobs.
/// `steal = false` gives the naive baseline.
pub fn run_threads(db: &Database, mode: RunMode, p: usize, steal: bool, seed: u64) -> ParRunResult {
    run_threads_with(db, mode, &ThreadConfig { steal, ..ThreadConfig::paper_defaults(p, seed) })
}

/// Run one phase on OS threads with explicit GLB/DTD knobs (the
/// coordinator's entry point). Blocking waits cap at 200 µs so DTD waves
/// keep flowing.
pub fn run_threads_with(db: &Database, mode: RunMode, cfg: &ThreadConfig) -> ParRunResult {
    let p = cfg.p;
    assert!(p >= 1);
    let boxes = crate::fabric::thread::thread_fabric(p);
    let t0 = Instant::now();
    let workers: Vec<Worker> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (rank, mut mb) in boxes.into_iter().enumerate() {
            let wc = WorkerConfig {
                rank,
                p,
                w: cfg.w,
                l: cfg.l,
                tree_arity: cfg.tree_arity,
                steal: cfg.steal,
                preprocess: cfg.preprocess && p > 1,
                mode,
                probe_budget_units: cfg.probe_budget_units,
                dtd_interval_ns: cfg.dtd_interval_ns,
                ns_per_unit: None, // real time
                seed: cfg.seed,
            };
            let mut worker = Worker::new(db, wc);
            handles.push(scope.spawn(move || {
                worker.trace_event(TraceEv::PhaseStart { phase: mode.phase_no(), epoch: 0 });
                let t0 = Instant::now();
                loop {
                    let now_ns = t0.elapsed().as_nanos() as u64;
                    match worker.poll(&mut mb, now_ns) {
                        Poll::Busy { .. } => {}
                        Poll::Idle { wake_at } => {
                            let cap = Duration::from_micros(200);
                            let d = match wake_at {
                                Some(t) => {
                                    Duration::from_nanos(t.saturating_sub(now_ns)).min(cap)
                                }
                                None => cap,
                            };
                            if !d.is_zero() {
                                mb.wait_for_msg(d);
                            }
                        }
                        Poll::Finished => break,
                    }
                }
                worker.trace_event(TraceEv::PhaseEnd { phase: mode.phase_no(), epoch: 0 });
                worker
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    let makespan_ns = t0.elapsed().as_nanos() as u64;
    collect(db, workers, makespan_ns, mode)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lamp::{lamp_serial, SupportIncreaseRule};
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng, m: usize, n: usize, density: f64) -> Database {
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(density)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t < n / 3).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    #[test]
    fn threads_phase1_matches_serial() {
        let mut rng = Rng::new(21);
        for p in [1usize, 2, 4] {
            let db = random_db(&mut rng, 12, 30, 0.4);
            let serial = lamp_serial(&db, 0.05);
            let rule = SupportIncreaseRule::new(db.marginals(), 0.05);
            let mut got = run_threads(&db, RunMode::Phase1 { alpha: 0.05 }, p, true, 42);
            got.finalize_phase1(&rule);
            assert_eq!(got.lambda_final, serial.lambda_final, "p={p}");
            let p2 = run_threads(&db, RunMode::Count { min_sup: got.min_sup }, p, true, 43);
            assert_eq!(p2.closed_total, serial.correction_factor, "p={p}");
        }
    }

    #[test]
    fn threads_naive_matches_serial_counts() {
        let mut rng = Rng::new(31);
        let db = random_db(&mut rng, 10, 26, 0.5);
        let serial = lamp_serial(&db, 0.05);
        let p2 = run_threads(&db, RunMode::Count { min_sup: serial.min_sup }, 3, false, 7);
        assert_eq!(p2.closed_total, serial.correction_factor);
        assert_eq!(p2.comm.gives, 0);
    }
}
