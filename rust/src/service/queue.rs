//! The daemon's FIFO job queue.
//!
//! Deliberately minimal: job *records* (spec, state, outcome) live in the
//! server's job table; the queue holds only the ids of jobs awaiting the
//! scheduler, in submission order. `CANCEL` removes exactly the targeted
//! pending id and nothing else — the property test below pins both the
//! FIFO discipline and that surgical removal.

use std::collections::VecDeque;

/// FIFO queue of pending job ids.
#[derive(Debug, Default)]
pub struct JobQueue {
    q: VecDeque<u64>,
}

impl JobQueue {
    pub fn new() -> JobQueue {
        JobQueue::default()
    }

    /// Append a job at the tail.
    pub fn push(&mut self, id: u64) {
        self.q.push_back(id);
    }

    /// Take the next job to run (submission order).
    pub fn pop(&mut self) -> Option<u64> {
        self.q.pop_front()
    }

    /// Remove a pending job. Returns whether it was present; every other
    /// entry keeps its relative order.
    pub fn cancel(&mut self, id: u64) -> bool {
        match self.q.iter().position(|&x| x == id) {
            Some(i) => {
                let _ = self.q.remove(i);
                true
            }
            None => false,
        }
    }

    /// 0-based distance from the head (0 = next to run).
    pub fn position(&self, id: u64) -> Option<usize> {
        self.q.iter().position(|&x| x == id)
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn fifo_and_position() {
        let mut q = JobQueue::new();
        assert!(q.is_empty());
        q.push(10);
        q.push(11);
        q.push(12);
        assert_eq!(q.len(), 3);
        assert_eq!(q.position(11), Some(1));
        assert_eq!(q.pop(), Some(10));
        assert_eq!(q.position(11), Some(0));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), Some(12));
        assert_eq!(q.pop(), None);
    }

    /// Random interleavings of push/cancel/pop against a model `Vec`:
    /// FIFO order is preserved, and cancel removes exactly the targeted
    /// pending job (present → removed and true; absent → false and
    /// untouched).
    #[test]
    fn queue_matches_model_under_random_ops() {
        forall("job queue vs model", 128, |rng| {
            let mut q = JobQueue::new();
            let mut model: Vec<u64> = Vec::new();
            let mut next_id = 0u64;
            for _ in 0..rng.index(64) {
                match rng.index(4) {
                    // push (weighted: half the ops)
                    0 | 1 => {
                        q.push(next_id);
                        model.push(next_id);
                        next_id += 1;
                    }
                    // pop
                    2 => {
                        let want = if model.is_empty() { None } else { Some(model.remove(0)) };
                        if q.pop() != want {
                            return Err(format!("pop mismatch, want {want:?}"));
                        }
                    }
                    // cancel a random id — sometimes pending, sometimes
                    // already popped or never issued
                    _ => {
                        let id = rng.below(next_id.max(1) + 2);
                        let want = model.iter().position(|&x| x == id);
                        if let Some(i) = want {
                            model.remove(i);
                        }
                        if q.cancel(id) != want.is_some() {
                            return Err(format!("cancel({id}) presence mismatch"));
                        }
                    }
                }
                if q.len() != model.len() {
                    return Err(format!("len {} != model {}", q.len(), model.len()));
                }
                for (i, &id) in model.iter().enumerate() {
                    if q.position(id) != Some(i) {
                        return Err(format!("order drift at {i} (id {id})"));
                    }
                }
            }
            // Drain: remaining pops must replay the model exactly.
            for &id in &model {
                if q.pop() != Some(id) {
                    return Err(format!("drain mismatch at id {id}"));
                }
            }
            if q.pop().is_some() {
                return Err("queue not empty after drain".into());
            }
            Ok(())
        });
    }
}
