//! The pluggable stream transport: typed endpoints, listener/stream
//! wrappers over Unix-domain and TCP sockets, and the single dial path
//! (connect timeout + bounded retry/backoff) every client-side
//! connection in the crate goes through.
//!
//! Endpoint grammar (DESIGN.md §11):
//!
//! ```text
//! endpoint := "unix:" path
//!           | "tcp:" host ":" port        (port := u16; host may not be
//!                                          empty; the LAST colon splits
//!                                          host from port)
//!           | path                        (no scheme — legacy `--socket`
//!                                          form, taken as a unix path)
//! ```
//!
//! Parsing and display round-trip exactly: `ep.to_string().parse()`
//! yields `ep` back for every endpoint (the bare-path legacy form
//! normalizes to `unix:<path>` on display).

use std::fmt;
use std::io::{self, Read, Write};
use std::net::{IpAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::str::FromStr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::{bail, Context, Result};

// ---------------------------------------------------------------------------
// Endpoint
// ---------------------------------------------------------------------------

/// A typed transport address: a Unix-domain socket path or a TCP
/// `host:port` pair. The crate-wide replacement for the raw socket-path
/// `String`s that used to thread through wire framing, fabric setup,
/// peer maps, and the service layer.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Endpoint {
    /// `unix:<path>` — a filesystem socket (single host).
    Unix(PathBuf),
    /// `tcp:<host>:<port>` — a network socket (any host). Port 0 asks
    /// the OS for an ephemeral port; [`Listener::local_endpoint`]
    /// reports the resolved one.
    Tcp(String, u16),
}

impl Endpoint {
    /// A unix-domain endpoint at `path`.
    pub fn unix(path: impl Into<PathBuf>) -> Endpoint {
        Endpoint::Unix(path.into())
    }

    /// A TCP endpoint at `host:port`.
    pub fn tcp(host: impl Into<String>, port: u16) -> Endpoint {
        Endpoint::Tcp(host.into(), port)
    }

    pub fn is_unix(&self) -> bool {
        matches!(self, Endpoint::Unix(_))
    }

    pub fn is_tcp(&self) -> bool {
        matches!(self, Endpoint::Tcp(..))
    }

    /// The filesystem path, if this is a unix endpoint. Cleanup code
    /// (`SockDir`, the serve-socket guard) keys off this: TCP endpoints
    /// have nothing to unlink.
    pub fn unix_path(&self) -> Option<&Path> {
        match self {
            Endpoint::Unix(p) => Some(p),
            Endpoint::Tcp(..) => None,
        }
    }

    /// Short transport name, for log lines and error contexts.
    pub fn transport_name(&self) -> &'static str {
        match self {
            Endpoint::Unix(_) => "unix",
            Endpoint::Tcp(..) => "tcp",
        }
    }
}

impl fmt::Display for Endpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Endpoint::Unix(p) => write!(f, "unix:{}", p.display()),
            Endpoint::Tcp(host, port) => write!(f, "tcp:{host}:{port}"),
        }
    }
}

impl FromStr for Endpoint {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Endpoint> {
        if s.is_empty() {
            bail!("empty endpoint (expected unix:<path> or tcp:<host>:<port>)");
        }
        if let Some(path) = s.strip_prefix("unix:") {
            if path.is_empty() {
                bail!("endpoint '{s}': unix endpoint needs a non-empty path");
            }
            return Ok(Endpoint::Unix(PathBuf::from(path)));
        }
        if let Some(rest) = s.strip_prefix("tcp:") {
            let Some((host, port)) = rest.rsplit_once(':') else {
                bail!("endpoint '{s}': tcp endpoint needs <host>:<port>");
            };
            if host.is_empty() {
                bail!("endpoint '{s}': tcp endpoint has an empty host");
            }
            let port: u16 = port
                .parse()
                .with_context(|| format!("endpoint '{s}': bad port '{port}' (want 0..=65535)"))?;
            return Ok(Endpoint::Tcp(host.to_string(), port));
        }
        // No scheme: the legacy `--socket PATH` form. Any other string is
        // a valid unix path, so typos like `tpc:h:1` parse as paths — the
        // connect error that follows names the path, which is diagnosable.
        Ok(Endpoint::Unix(PathBuf::from(s)))
    }
}

// ---------------------------------------------------------------------------
// Listener / Stream
// ---------------------------------------------------------------------------

/// A bound, accepting socket over either transport.
#[derive(Debug)]
pub enum Listener {
    Unix(UnixListener),
    Tcp(TcpListener),
}

impl Listener {
    /// Bind a listener at `ep`. For TCP, port 0 binds an ephemeral port;
    /// read the real address back with [`Listener::local_endpoint`].
    pub fn bind(ep: &Endpoint) -> Result<Listener> {
        match ep {
            Endpoint::Unix(path) => {
                let l = UnixListener::bind(path)
                    .with_context(|| format!("bind unix listener at {}", path.display()))?;
                Ok(Listener::Unix(l))
            }
            Endpoint::Tcp(host, port) => {
                let l = TcpListener::bind((host.as_str(), *port))
                    .with_context(|| format!("bind tcp listener at {host}:{port}"))?;
                Ok(Listener::Tcp(l))
            }
        }
    }

    /// The endpoint this listener is actually bound at. For TCP this
    /// resolves a requested port 0 to the ephemeral port the OS picked —
    /// the address peers must dial.
    pub fn local_endpoint(&self) -> Result<Endpoint> {
        match self {
            Listener::Unix(l) => {
                let addr = l.local_addr().context("unix listener local_addr")?;
                let path = addr
                    .as_pathname()
                    .context("unix listener is unnamed (no filesystem path)")?;
                Ok(Endpoint::Unix(path.to_path_buf()))
            }
            Listener::Tcp(l) => {
                let addr = l.local_addr().context("tcp listener local_addr")?;
                Ok(Endpoint::Tcp(addr.ip().to_string(), addr.port()))
            }
        }
    }

    pub fn set_nonblocking(&self, nonblocking: bool) -> io::Result<()> {
        match self {
            Listener::Unix(l) => l.set_nonblocking(nonblocking),
            Listener::Tcp(l) => l.set_nonblocking(nonblocking),
        }
    }

    /// Accept one connection. TCP streams get `TCP_NODELAY` so the
    /// fabric's small control frames aren't Nagle-delayed.
    pub fn accept(&self) -> io::Result<Stream> {
        match self {
            Listener::Unix(l) => {
                let (s, _) = l.accept()?;
                Ok(Stream::Unix(s))
            }
            Listener::Tcp(l) => {
                let (s, _) = l.accept()?;
                let _ = s.set_nodelay(true);
                Ok(Stream::Tcp(s))
            }
        }
    }
}

/// A connected stream over either transport. Implements `Read + Write`,
/// so [`crate::wire::read_frame`] / [`crate::wire::write_frame`] work on
/// it directly — a dialed `Stream` *is* the framed connection.
#[derive(Debug)]
pub enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl Stream {
    pub fn try_clone(&self) -> io::Result<Stream> {
        match self {
            Stream::Unix(s) => s.try_clone().map(Stream::Unix),
            Stream::Tcp(s) => s.try_clone().map(Stream::Tcp),
        }
    }

    pub fn set_nonblocking(&self, nonblocking: bool) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_nonblocking(nonblocking),
            Stream::Tcp(s) => s.set_nonblocking(nonblocking),
        }
    }

    pub fn set_read_timeout(&self, dur: Option<Duration>) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.set_read_timeout(dur),
            Stream::Tcp(s) => s.set_read_timeout(dur),
        }
    }

    pub fn shutdown(&self, how: std::net::Shutdown) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.shutdown(how),
            Stream::Tcp(s) => s.shutdown(how),
        }
    }

    /// The local IP of a TCP stream (`None` for unix). A worker that
    /// dialed a remote hub uses this to learn which of its interfaces
    /// routes to the coordinator, and binds its mesh listener there.
    pub fn local_tcp_ip(&self) -> Option<IpAddr> {
        match self {
            Stream::Unix(_) => None,
            Stream::Tcp(s) => s.local_addr().ok().map(|a| a.ip()),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

// ---------------------------------------------------------------------------
// Dial (the one connect/retry/backoff path)
// ---------------------------------------------------------------------------

/// Connect timeout + bounded retry/backoff for [`dial`]. The backoff
/// doubles per failed attempt, capped at one second.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Total connect attempts (≥ 1).
    pub attempts: u32,
    /// Per-attempt connect timeout (TCP only; unix connects are local
    /// and either succeed or fail immediately).
    pub connect_timeout: Duration,
    /// Pause after the first failed attempt; doubles each retry.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            attempts: 5,
            connect_timeout: Duration::from_secs(5),
            backoff: Duration::from_millis(20),
        }
    }
}

impl RetryPolicy {
    /// A single attempt, no backoff — for callers (the mesh `send_direct`
    /// path) that run their own retry loop around the dial.
    pub fn once() -> RetryPolicy {
        RetryPolicy { attempts: 1, ..RetryPolicy::default() }
    }
}

fn connect_once(ep: &Endpoint, timeout: Duration) -> Result<Stream> {
    match ep {
        Endpoint::Unix(path) => {
            let s = UnixStream::connect(path)
                .with_context(|| format!("connect unix socket {}", path.display()))?;
            Ok(Stream::Unix(s))
        }
        Endpoint::Tcp(host, port) => {
            let addrs: Vec<_> = (host.as_str(), *port)
                .to_socket_addrs()
                .with_context(|| format!("resolve {host}:{port}"))?
                .collect();
            let mut last: Option<io::Error> = None;
            for addr in &addrs {
                match TcpStream::connect_timeout(addr, timeout) {
                    Ok(s) => {
                        let _ = s.set_nodelay(true);
                        return Ok(Stream::Tcp(s));
                    }
                    Err(e) => last = Some(e),
                }
            }
            match last {
                Some(e) => Err(e).with_context(|| format!("connect tcp {host}:{port}")),
                None => bail!("{host}:{port} resolved to no addresses"),
            }
        }
    }
}

/// Dial `ep` under `policy`: up to `attempts` connects, each with the
/// policy's timeout, sleeping a doubling backoff between failures. The
/// returned [`Stream`] is ready for `read_frame`/`write_frame` — this is
/// the *only* connect path in the crate (service client, worker hub
/// dial, and mesh peer dial all come through here).
pub fn dial(ep: &Endpoint, policy: &RetryPolicy) -> Result<Stream> {
    let attempts = policy.attempts.max(1);
    let mut pause = policy.backoff;
    let mut last_err = None;
    for attempt in 0..attempts {
        if attempt > 0 {
            std::thread::sleep(pause);
            pause = (pause * 2).min(Duration::from_secs(1));
        }
        match connect_once(ep, policy.connect_timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap())
        .with_context(|| format!("dial {ep} failed after {attempts} attempt(s)"))
}

/// [`dial`], then write `preamble` (a pre-encoded wire frame — HELLO or
/// PEERHELLO bytes) before handing the stream back. Keeping the frame
/// encoding on the caller's side keeps `net` below `wire` in the layer
/// map while still collapsing every connect+handshake preamble into one
/// helper.
pub fn dial_with_preamble(ep: &Endpoint, policy: &RetryPolicy, preamble: &[u8]) -> Result<Stream> {
    let mut stream = dial(ep, policy)?;
    stream
        .write_all(preamble)
        .and_then(|()| stream.flush())
        .with_context(|| format!("send handshake preamble to {ep}"))?;
    Ok(stream)
}

// ---------------------------------------------------------------------------
// Fleet auth token
// ---------------------------------------------------------------------------

/// A fresh per-fleet shared-secret token, carried in every HELLO and
/// PEERHELLO (wire v4) and checked before a connection joins the fabric.
/// It is an anti-accident guard — unique per fleet so a stray or stale
/// connection (another fleet on the same port, a port scanner, a
/// crossed-wire test) is rejected at the handshake — **not** a
/// cryptographic credential; run real multi-host fleets on a trusted
/// network.
pub fn fresh_token() -> String {
    static COUNTER: AtomicU64 = AtomicU64::new(0);
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let pid = std::process::id() as u64;
    let seq = COUNTER.fetch_add(1, Ordering::Relaxed);
    // splitmix64 finalizer over the three entropy sources; the counter
    // guarantees distinct tokens even within one clock tick.
    let mut x = nanos ^ (pid << 32) ^ seq.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    format!("{x:016x}")
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn ep(s: &str) -> Endpoint {
        s.parse().unwrap()
    }

    #[test]
    fn parse_and_display_fixed_cases() {
        assert_eq!(ep("unix:/tmp/x.sock"), Endpoint::unix("/tmp/x.sock"));
        assert_eq!(ep("tcp:127.0.0.1:7401"), Endpoint::tcp("127.0.0.1", 7401));
        assert_eq!(ep("tcp:node-03.cluster:0"), Endpoint::tcp("node-03.cluster", 0));
        // Legacy bare path (the old `--socket PATH` form).
        assert_eq!(ep("/run/parlamp.sock"), Endpoint::unix("/run/parlamp.sock"));
        assert_eq!(ep("rel/path.sock"), Endpoint::unix("rel/path.sock"));
        // Display normalizes to the schemed form and round-trips.
        assert_eq!(ep("/tmp/a").to_string(), "unix:/tmp/a");
        assert_eq!(ep("tcp:h:80").to_string(), "tcp:h:80");
        // The LAST colon splits host from port, so colon-bearing hosts
        // (unbracketed IPv6) survive.
        assert_eq!(ep("tcp:::1:9000"), Endpoint::tcp("::1", 9000));
    }

    #[test]
    fn parse_errors_are_clear() {
        for (input, needle) in [
            ("", "empty endpoint"),
            ("unix:", "non-empty path"),
            ("tcp:justhost", "<host>:<port>"),
            ("tcp::9000", "empty host"),
            ("tcp:h:70000", "bad port"),
            ("tcp:h:-1", "bad port"),
            ("tcp:h:x", "bad port"),
        ] {
            let err = input.parse::<Endpoint>().unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains(needle), "error for '{input}' missing '{needle}': {msg}");
        }
    }

    /// Satellite: `Endpoint` parse/display round-trip as a property over
    /// generated hosts, ports, and paths (including colons in paths).
    #[test]
    fn endpoint_display_parse_roundtrip_property() {
        fn rand_host(rng: &mut Rng) -> String {
            const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789.-";
            let len = 1 + rng.below(16) as usize;
            (0..len).map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize] as char).collect()
        }
        fn rand_path(rng: &mut Rng) -> String {
            // Paths may contain colons and dots but (for the round-trip to
            // hold through PathBuf) no NUL and nothing empty.
            const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz0123456789._-:/";
            let len = 1 + rng.below(24) as usize;
            let body: String = (0..len)
                .map(|_| ALPHABET[rng.below(ALPHABET.len() as u64) as usize] as char)
                .collect();
            format!("/{body}")
        }
        forall("endpoint display/parse round-trip", 512, |rng| {
            let original = if rng.bernoulli(0.5) {
                Endpoint::tcp(rand_host(rng), (rng.next_u64() & 0xFFFF) as u16)
            } else {
                Endpoint::unix(rand_path(rng))
            };
            let shown = original.to_string();
            let back: Endpoint =
                shown.parse().map_err(|e| format!("'{shown}' failed to re-parse: {e}"))?;
            if back != original {
                return Err(format!("{original:?} -> '{shown}' -> {back:?}"));
            }
            Ok(())
        });
    }

    fn tmp_sock(tag: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let n = N.fetch_add(1, Ordering::Relaxed);
        std::env::temp_dir().join(format!(
            "parlamp-net-{}-{tag}-{n}.sock",
            std::process::id()
        ))
    }

    fn echo_roundtrip(listen_at: &Endpoint) {
        let listener = Listener::bind(listen_at).expect("bind");
        let local = listener.local_endpoint().expect("local endpoint");
        if let Endpoint::Tcp(_, port) = &local {
            assert_ne!(*port, 0, "port 0 must resolve to a real ephemeral port");
        }
        let server = std::thread::spawn(move || {
            let mut s = listener.accept().expect("accept");
            let mut buf = [0u8; 5];
            s.read_exact(&mut buf).expect("server read");
            s.write_all(&buf).expect("server write");
            buf
        });
        let mut c = dial(&local, &RetryPolicy::default()).expect("dial");
        c.write_all(b"hello").expect("client write");
        let mut back = [0u8; 5];
        c.read_exact(&mut back).expect("client read");
        assert_eq!(&back, b"hello");
        assert_eq!(server.join().unwrap(), *b"hello");
    }

    #[test]
    fn unix_listener_stream_roundtrip() {
        let path = tmp_sock("echo");
        echo_roundtrip(&Endpoint::unix(&path));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn tcp_listener_stream_roundtrip_and_port_resolution() {
        echo_roundtrip(&Endpoint::tcp("127.0.0.1", 0));
    }

    #[test]
    fn dial_with_preamble_delivers_bytes_first() {
        let listener = Listener::bind(&Endpoint::tcp("127.0.0.1", 0)).unwrap();
        let local = listener.local_endpoint().unwrap();
        let server = std::thread::spawn(move || {
            let mut s = listener.accept().unwrap();
            let mut pre = [0u8; 4];
            s.read_exact(&mut pre).unwrap();
            pre
        });
        let stream =
            dial_with_preamble(&local, &RetryPolicy::once(), b"PLMW").expect("dial+preamble");
        assert!(stream.local_tcp_ip().is_some(), "tcp stream must report a local ip");
        assert_eq!(server.join().unwrap(), *b"PLMW");
    }

    #[test]
    fn dial_dead_endpoint_reports_attempts() {
        let gone = Endpoint::unix(tmp_sock("gone"));
        let policy = RetryPolicy {
            attempts: 3,
            connect_timeout: Duration::from_millis(200),
            backoff: Duration::from_millis(1),
        };
        let err = dial(&gone, &policy).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("3 attempt(s)"), "missing attempt count: {msg}");
        assert!(msg.contains("connect unix socket"), "missing cause: {msg}");
    }

    #[test]
    fn fresh_tokens_are_distinct_hex() {
        let a = fresh_token();
        let b = fresh_token();
        assert_ne!(a, b, "two tokens from one process must differ");
        for t in [&a, &b] {
            assert_eq!(t.len(), 16);
            assert!(t.chars().all(|c| c.is_ascii_hexdigit()));
        }
    }

    #[test]
    fn unix_streams_have_no_tcp_ip() {
        let path = tmp_sock("noip");
        let listener = Listener::bind(&Endpoint::unix(&path)).unwrap();
        let local = listener.local_endpoint().unwrap();
        assert_eq!(local, Endpoint::unix(&path), "unix local_endpoint echoes the bind path");
        let _srv = std::thread::spawn(move || listener.accept());
        let stream = dial(&local, &RetryPolicy::once()).unwrap();
        assert!(stream.local_tcp_ip().is_none());
        std::fs::remove_file(&path).ok();
    }
}
