//! Phase 2 — closed-set count at the optimal minimum support.
//!
//! A plain frequent closed-itemset mining run at `min_sup = λ* − 1`; its
//! count is the Tarone–Bonferroni correction factor `k`.

use crate::db::Database;
use crate::lcm::{mine_closed, MineStats, Visit};

/// Outcome of phase 2.
#[derive(Clone, Debug)]
pub struct Phase2Result {
    /// `k = CS(min_sup)`: the number of closed itemsets with support ≥
    /// `min_sup`, used as the multiple-testing correction factor.
    pub correction_factor: u64,
    /// Same number (kept separately for reporting symmetry with phase 1).
    pub closed: u64,
    pub stats: MineStats,
}

/// Count closed itemsets with support ≥ `min_sup`.
pub fn phase2_count(db: &Database, min_sup: u32) -> Phase2Result {
    let mut count: u64 = 0;
    let stats = mine_closed(db, min_sup.max(1), |_node, ms| {
        count += 1;
        (Visit::Continue, ms)
    });
    Phase2Result { correction_factor: count.max(1), closed: count, stats }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Item;
    use crate::lcm::brute_force_closed;
    use crate::util::propcheck::forall;
    use crate::util::rng::Rng;

    fn random_db(rng: &mut Rng) -> Database {
        let m = 3 + rng.index(6);
        let n = 4 + rng.index(14);
        let trans: Vec<Vec<Item>> = (0..n)
            .map(|_| (0..m as Item).filter(|_| rng.bernoulli(0.5)).collect())
            .collect();
        let labels: Vec<bool> = (0..n).map(|t| t % 2 == 0).collect();
        Database::from_transactions(m, &trans, &labels)
    }

    #[test]
    fn count_matches_brute_force() {
        forall("phase2 count == brute force", 40, |rng| {
            let db = random_db(rng);
            let min_sup = 1 + rng.below(4) as u32;
            let want = brute_force_closed(&db, min_sup).len() as u64;
            let got = phase2_count(&db, min_sup).closed;
            if got != want {
                return Err(format!("min_sup={min_sup}: got {got} want {want}"));
            }
            Ok(())
        });
    }

    #[test]
    fn correction_factor_never_zero() {
        // Even a degenerate database yields k ≥ 1 so α/k stays finite.
        let db = Database::from_transactions(1, &[vec![]], &[false]);
        assert_eq!(phase2_count(&db, 5).correction_factor, 1);
    }
}
