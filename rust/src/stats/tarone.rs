//! Tarone's minimum-achievable-P bound (paper §3.2).
//!
//! Given marginals `(N, N_pos)` and an itemset frequency `x`, the smallest
//! P-value any itemset of frequency `x` can attain (all `x` occurrences in
//! the positive class) is
//!
//! ```text
//! f(x) = C(N_pos, x) / C(N, x)        (x ≤ N_pos; else the analogous
//!                                       all-in-one-class bound, see below)
//! ```
//!
//! `f` is monotone non-increasing in `x`, which is exactly what makes the
//! LAMP support-increase search sound: raising the minimum support `λ` only
//! discards itemsets whose best-achievable P already exceeds the adjusted
//! significance level.

use super::{LogFact, Marginals};

/// Evaluator for `f(x)` bound to fixed marginals.
#[derive(Clone, Debug)]
pub struct TaroneBound {
    m: Marginals,
    lf: LogFact,
}

impl TaroneBound {
    pub fn new(m: Marginals) -> Self {
        TaroneBound { m, lf: LogFact::new(m.n) }
    }

    /// `ln f(x)`. For `x > N_pos` the literal binomial ratio is zero; the
    /// true minimum achievable P is then the probability that *all*
    /// positives fall inside the itemset's support, `C(N−N_pos, x−N_pos) /
    /// C(N, x)`, which is what phase-1 needs to stay conservative. For
    /// `x = 0` the bound is 1 (`ln f = 0`).
    pub fn log_f(&self, x: u32) -> f64 {
        let Marginals { n, n_pos } = self.m;
        assert!(x <= n, "x={x} > N={n}");
        if x == 0 {
            return 0.0;
        }
        if x <= n_pos {
            self.lf.log_choose(n_pos, x) - self.lf.log_choose(n, x)
        } else {
            self.lf.log_choose(n - n_pos, x - n_pos) - self.lf.log_choose(n, x)
        }
    }

    /// `f(x)` in linear space.
    pub fn f(&self, x: u32) -> f64 {
        self.log_f(x).exp()
    }

    pub fn marginals(&self) -> Marginals {
        self.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::fisher::FisherTable;
    use crate::util::propcheck::forall;

    /// Oracle values: f(x) = C(Npos,x)/C(N,x), precomputed exactly.
    const ORACLE: &[(u32, u32, u32, f64)] = &[
        (10, 5, 4, 0.023809523809523808),
        (100, 20, 10, 1.0673177187555404e-08),
        (697, 105, 8, 2.1013089920178958e-07),
        (364, 176, 30, 8.452749188777162e-11),
        (697, 105, 1, 0.15064562410329985),
        (364, 176, 18, 1.3008679821704798e-06),
    ];

    #[test]
    fn matches_exact_binomial_ratio() {
        for &(n, npos, x, want) in ORACLE {
            let t = TaroneBound::new(Marginals::new(n, npos));
            let got = t.f(x);
            assert!(
                (got - want).abs() / want < 1e-9,
                "N={n} Npos={npos} x={x}: got {got:e} want {want:e}"
            );
        }
    }

    #[test]
    fn boundary_values() {
        let t = TaroneBound::new(Marginals::new(20, 8));
        assert!((t.f(0) - 1.0).abs() < 1e-12);
        // x = N: every transaction contains I, both classes fully inside ⇒ 1
        assert!((t.f(20) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_nonincreasing_up_to_npos() {
        forall("f(x) nonincreasing on 0..=Npos", 64, |rng| {
            let n = 5 + rng.below(300) as u32;
            let npos = 1 + rng.below(n as u64) as u32;
            let t = TaroneBound::new(Marginals::new(n, npos));
            let mut prev = f64::INFINITY;
            for x in 0..=npos {
                let fx = t.f(x);
                if fx > prev * (1.0 + 1e-12) {
                    return Err(format!("N={n} Npos={npos} x={x}: {fx} > {prev}"));
                }
                prev = fx;
            }
            Ok(())
        });
    }

    #[test]
    fn lower_bounds_every_achievable_p() {
        // f(x) must lower-bound the Fisher P for every feasible n(I).
        forall("f(x) ≤ P(x, n) ∀ feasible n", 48, |rng| {
            let n = 10 + rng.below(120) as u32;
            let npos = 1 + rng.below(n as u64 - 1) as u32;
            let t = TaroneBound::new(Marginals::new(n, npos));
            let fi = FisherTable::new(Marginals::new(n, npos));
            let x = 1 + rng.below(n as u64) as u32;
            let lo = x.saturating_sub(n - npos);
            for nobs in lo..=x.min(npos) {
                let p = fi.p_value(x, nobs);
                let fx = t.f(x);
                if fx > p * (1.0 + 1e-9) + 1e-300 {
                    return Err(format!("N={n} Npos={npos} x={x} n={nobs}: f={fx:e} > P={p:e}"));
                }
            }
            Ok(())
        });
    }
}
