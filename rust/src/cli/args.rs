//! Flag parsing: `--key value` and boolean `--flag` pairs.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed `--key value` / `--flag` arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Flags that take no value.
const BOOL_FLAGS: &[&str] = &["naive", "ethernet", "quick", "no-preprocess", "verbose"];

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            // `-n` is shorthand for `--procs` (rank count).
            let key = if a == "-n" {
                "procs"
            } else if let Some(key) = a.strip_prefix("--") {
                key
            } else {
                bail!("unexpected positional argument '{a}'");
            };
            if BOOL_FLAGS.contains(&key) {
                out.flags.push(key.to_string());
                i += 1;
            } else {
                let v = argv.get(i + 1).with_context(|| format!("--{key} needs a value"))?;
                out.kv.insert(key.to_string(), v.clone());
                i += 2;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(String::as_str)
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).with_context(|| format!("missing required --{key}"))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad float '{v}'")),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad integer '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key}: bad integer '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::parse(&argv(&["--alpha", "0.01", "--naive", "--procs", "96"])).unwrap();
        assert_eq!(a.get("alpha"), Some("0.01"));
        assert!(a.flag("naive"));
        assert!(!a.flag("ethernet"));
        assert_eq!(a.get_usize("procs", 1).unwrap(), 96);
        assert_eq!(a.get_f64("alpha", 0.05).unwrap(), 0.01);
        assert_eq!(a.get_f64("beta", 0.5).unwrap(), 0.5);
    }

    #[test]
    fn rejects_positional_and_dangling() {
        assert!(Args::parse(&argv(&["positional"])).is_err());
        assert!(Args::parse(&argv(&["--alpha"])).is_err());
        assert!(Args::parse(&argv(&["-x", "1"])).is_err());
    }

    #[test]
    fn dash_n_is_procs() {
        let a = Args::parse(&argv(&["-n", "8"])).unwrap();
        assert_eq!(a.get_usize("procs", 1).unwrap(), 8);
        assert!(Args::parse(&argv(&["-n"])).is_err());
    }

    #[test]
    fn require_reports_missing() {
        let a = Args::parse(&argv(&[])).unwrap();
        assert!(a.require("data").is_err());
    }
}
