//! # parlamp
//!
//! Reproduction of *"Redesigning pattern mining algorithms for
//! supercomputers"* (Yoshizoe, Terada & Tsuda, 2015): a distributed-memory
//! parallel closed-itemset miner (LCM) generalized to significant pattern
//! mining (LAMP), built on lifeline-based global load balancing, Mattern
//! distributed termination detection, and a piggybacked support-increase
//! protocol — plus an XLA/PJRT-offloaded batched significance screen
//! (Fisher exact test + Tarone bound) AOT-compiled from JAX/Pallas.
//!
//! Layer map (see `DESIGN.md`):
//! - [`bits`], [`db`], [`stats`] — substrates: packed bitmaps, transaction
//!   databases, exact-test statistics.
//! - [`lcm`], [`lamp`] — the serial miner and the LAMP three-phase
//!   procedure (incl. the `lamp2` occurrence-deliver baseline).
//! - [`fabric`], [`glb`], [`dtd`], [`par`] — the distributed runtime: an
//!   MPI-like message fabric (thread, discrete-event, and multi-process
//!   backends), lifeline work stealing, termination detection, and the
//!   parallel DFS worker.
//! - [`net`] — the pluggable stream transport: typed `Endpoint`
//!   addresses (`unix:<path>` | `tcp:<host>:<port>`), listener/stream
//!   wrappers, and the single dial/retry path (DESIGN.md §11).
//! - [`wire`] — the versioned length-prefixed binary protocol the process
//!   fabric speaks across address spaces (DESIGN.md §7).
//! - [`coordinator`] — the L3 orchestration layer: owns the three-phase
//!   LAMP procedure across any fabric backend (configures workers from
//!   the GLB parameters, merges histograms/breakdowns/counters at the DTD
//!   phase boundaries) and dispatches the phase-3 screen.
//! - [`service`] — the serving layer: the `parlamp serve` daemon (warm
//!   worker fleet, FIFO job queue, bounded result cache) and its typed
//!   client (DESIGN.md §9).
//! - [`obs`] — observability: per-rank event tracing with fleet-wide
//!   clock-aligned timelines (Chrome/Perfetto export, terminal summary),
//!   structured logging, and Prometheus stats exposition (DESIGN.md §14).
//! - [`runtime`] — PJRT loader for the AOT artifacts built under
//!   `python/compile` (`make artifacts`); a stub without the `xla` feature.
//! - [`datagen`] — synthetic GWAS / transcriptome workload generators.
//! - [`bench`], [`cli`], [`util`] — harnesses and drivers.

pub mod bench;
pub mod bits;
pub mod cli;
pub mod coordinator;
pub mod datagen;
pub mod db;
pub mod dtd;
pub mod fabric;
pub mod glb;
pub mod lamp;
pub mod lcm;
pub mod net;
pub mod obs;
pub mod par;
pub mod runtime;
pub mod service;
pub mod stats;
pub mod util;
pub mod wire;

/// Default family-wise error rate used throughout the paper's experiments.
pub const DEFAULT_ALPHA: f64 = 0.05;
