"""Pallas kernels vs pure-jnp reference — the core L1 correctness signal.

hypothesis sweeps shapes and bit patterns; scipy provides an independent
statistical oracle for the Fisher/Tarone kernel.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from scipy.stats import hypergeom  # noqa: E402

from compile.kernels import ref  # noqa: E402
from compile.kernels.fisher import fisher_tarone  # noqa: E402
from compile.kernels.popcount import support_counts  # noqa: E402


# ---------------------------------------------------------------- popcount


def test_popcount_exhaustive_small():
    v = np.array([0, 1, 2, 3, 0xFFFFFFFF, 0x80000000, 0x55555555], dtype=np.uint32)
    got = np.asarray(ref.popcount_u32(jnp.asarray(v)))
    want = np.array([bin(x).count("1") for x in v], dtype=np.int32)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(
    k_blocks=st.integers(1, 3),
    w=st.integers(1, 17),
    seed=st.integers(0, 2**31 - 1),
)
def test_support_kernel_matches_ref(k_blocks, w, seed):
    rng = np.random.default_rng(seed)
    k = 256 * k_blocks
    occ = rng.integers(0, 2**32, size=(k, w), dtype=np.uint32)
    pos = rng.integers(0, 2**32, size=(w,), dtype=np.uint32)
    x, n = support_counts(jnp.asarray(occ), jnp.asarray(pos))
    xr, nr = ref.support_counts_ref(jnp.asarray(occ), jnp.asarray(pos))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(xr))
    np.testing.assert_array_equal(np.asarray(n), np.asarray(nr))
    # independent numpy oracle
    want_x = np.array([sum(bin(wd).count("1") for wd in row) for row in occ])
    np.testing.assert_array_equal(np.asarray(x), want_x)


def test_support_kernel_rejects_unpadded():
    with pytest.raises(AssertionError):
        support_counts(jnp.zeros((100, 4), jnp.uint32), jnp.zeros((4,), jnp.uint32))


# ------------------------------------------------------------------ fisher


def _scipy_logp(x, n, N, Np):
    # one-sided (greater): P[H >= n], H ~ Hypergeom(N, Np, x)
    p = hypergeom.sf(n - 1, N, Np, x)
    return np.log(np.clip(p, 1e-320, 1.0))


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_total=st.integers(10, 900),
)
def test_fisher_kernel_matches_scipy(seed, n_total):
    rng = np.random.default_rng(seed)
    n_pos = int(rng.integers(1, n_total))
    k = 256
    x = rng.integers(0, n_total + 1, size=k).astype(np.int32)
    lo = np.maximum(0, x - (n_total - n_pos))
    hi = np.minimum(x, n_pos)
    n = (lo + rng.random(k) * (hi - lo + 1)).astype(np.int32)
    n = np.minimum(n, hi).astype(np.int32)
    t_max = n_pos + 1
    logp, logf = fisher_tarone(
        jnp.asarray(x),
        jnp.asarray(n),
        jnp.asarray([float(n_total)]),
        jnp.asarray([float(n_pos)]),
        t_max=t_max,
    )
    logp = np.asarray(logp)
    logf = np.asarray(logf)
    want = np.array([_scipy_logp(xi, ni, n_total, n_pos) for xi, ni in zip(x, n)])
    np.testing.assert_allclose(logp, want, rtol=1e-8, atol=1e-8)
    # Tarone bound must lower-bound the P-value and hit it at n == hi.
    assert np.all(logf <= logp + 1e-9)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fisher_kernel_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n_total, n_pos = 300, 77
    k = 512
    x = rng.integers(0, n_total + 1, size=k).astype(np.int32)
    n = np.minimum(x, rng.integers(0, n_pos + 1, size=k)).astype(np.int32)
    t_max = n_pos + 1
    logp, logf = fisher_tarone(
        jnp.asarray(x), jnp.asarray(n),
        jnp.asarray([300.0]), jnp.asarray([77.0]), t_max=t_max,
    )
    rp = ref.fisher_logp_ref(jnp.asarray(x), jnp.asarray(n), 300.0, 77.0, t_max)
    rf = ref.tarone_logf_ref(jnp.asarray(x), 300.0, 77.0)
    np.testing.assert_allclose(np.asarray(logp), np.asarray(rp), rtol=1e-10, atol=1e-10)
    np.testing.assert_allclose(np.asarray(logf), np.asarray(rf), rtol=1e-10, atol=1e-10)


def test_fisher_edge_cases():
    # x = 0 → P = 1; n at the lower support limit → P = 1; n = hi → P = f(x)
    logp, logf = fisher_tarone(
        jnp.asarray([0, 25, 8], jnp.int32),
        jnp.asarray([0, 7, 8], jnp.int32),
        jnp.asarray([30.0]),
        jnp.asarray([12.0]),
        t_max=13,
        block_k=1,
    )
    logp = np.asarray(logp)
    logf = np.asarray(logf)
    assert logp[0] == 0.0
    # x=25, N−Np=18 → lo=7: full tail ⇒ P=1
    np.testing.assert_allclose(logp[1], 0.0, atol=1e-12)
    # n == hi == min(x, Np) = 8 ⇒ single term = f(x)
    np.testing.assert_allclose(logp[2], logf[2], rtol=1e-10)
