//! Benchmark scenarios and calibration.
//!
//! [`scenarios`] defines the six problems of Table 1, scaled so a laptop
//! regenerates every table and figure in minutes (the ratios — items :
//! transactions, density regime, class balance — are preserved; see
//! DESIGN.md §3 for what "reproduced" means on the substituted testbed).

pub mod scenarios;

pub use scenarios::{all_scenarios, Scenario};

use crate::db::Database;
use crate::lamp::{lamp_serial, phase1_serial, phase2_count};
use crate::lcm::{mine_closed, Visit};
use crate::util::bench_harness::time_once;

/// Calibrate the DES cost model: run the serial miner for real, divide
/// wall-clock by total expansion work units. Returns (ns_per_unit,
/// serial_seconds, closed_sets).
pub fn calibrate(db: &Database, min_sup: u32) -> (f64, f64, u64) {
    let mut closed = 0u64;
    let (secs, stats) = time_once(|| {
        mine_closed(db, min_sup, |_n, ms| {
            closed += 1;
            (Visit::Continue, ms)
        })
    });
    let units = stats.expand.word_ops.max(1);
    ((secs * 1e9) / units as f64, secs, closed)
}

/// A measured serial LAMP run (phases 1+2): the `t₁` baseline plus the
/// calibrated DES cost-model constant derived from the *same* workload.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Virtual nanoseconds per expansion work unit.
    pub ns_per_unit: f64,
    /// Serial wall-clock for phases 1+2 (the paper's measured `t`).
    pub t1_s: f64,
    /// Final minimum support λ*−1.
    pub min_sup: u32,
    /// Correction factor CS(min_sup).
    pub correction: u64,
}

/// Measure serial phases 1+2 and derive the DES calibration from them.
pub fn calibrate_lamp(db: &Database, alpha: f64) -> Calibration {
    let (secs, (p1, p2)) = time_once(|| {
        let p1 = phase1_serial(db, alpha);
        let p2 = phase2_count(db, p1.min_sup);
        (p1, p2)
    });
    let units = (p1.stats.expand.word_ops + p2.stats.expand.word_ops).max(1);
    Calibration {
        ns_per_unit: secs * 1e9 / units as f64,
        t1_s: secs,
        min_sup: p1.min_sup,
        correction: p2.correction_factor,
    }
}

/// Serial full-LAMP wall time plus the result — the `t₁` column.
pub fn serial_t1(db: &Database, alpha: f64) -> (f64, crate::lamp::LampResult) {
    let (secs, res) = time_once(|| lamp_serial(db, alpha));
    (secs, res)
}
