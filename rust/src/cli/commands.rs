//! Subcommand implementations.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::bench::all_scenarios;
use crate::db::{read_labels, read_transactions, Database};
use crate::fabric::sim::NetModel;
use crate::lamp::{lamp2::lamp2_serial, lamp_serial};
use crate::lcm::{mine_closed, Visit};
use crate::par::{lamp_parallel_sim, SimConfig};
use crate::runtime::{artifacts_dir, phase3_extract_xla, ScreenEngine, XlaRuntime};
use crate::util::table::Table;

use super::args::Args;

fn load_db(args: &Args) -> Result<Database> {
    let data = args.require("data")?;
    let labels_path = args.require("labels")?;
    let (n_items, trans) = read_transactions(Path::new(data))?;
    let labels = read_labels(Path::new(labels_path))?;
    anyhow::ensure!(
        labels.len() == trans.len(),
        "{} labels vs {} transactions",
        labels.len(),
        trans.len()
    );
    Ok(Database::from_transactions(n_items, &trans, &labels))
}

fn scenario_db(args: &Args) -> Result<(String, Database)> {
    let name = args.require("scenario")?;
    let quick = args.flag("quick");
    let sc = all_scenarios(quick)
        .into_iter()
        .find(|s| s.name == name)
        .with_context(|| format!("unknown scenario '{name}' (see `parlamp scenarios`)"))?;
    Ok((name.to_string(), sc.build()))
}

/// `parlamp lamp` — full three-phase LAMP on a dataset from disk.
pub fn cmd_lamp(args: &Args) -> Result<()> {
    let db = load_db(args)?;
    let alpha = args.get_f64("alpha", crate::DEFAULT_ALPHA)?;
    let engine = args.get("engine").unwrap_or("serial");
    let res = match engine {
        "serial" => lamp_serial(&db, alpha),
        "lamp2" => lamp2_serial(&db, alpha),
        other => bail!("unknown --engine '{other}' (serial|lamp2)"),
    };
    println!(
        "N={} items={} density={:.4}% N_pos={}",
        db.n_trans(),
        db.n_items(),
        db.density() * 100.0,
        db.marginals().n_pos
    );
    println!("{}", res.summary());

    let significant = match args.get("screen").unwrap_or("native") {
        "native" => res.significant.clone(),
        "xla" => {
            let rt = XlaRuntime::load(&artifacts_dir())
                .context("load XLA artifacts (run `make artifacts`)")?;
            let eng = ScreenEngine::new(rt);
            phase3_extract_xla(&eng, &db, res.min_sup, res.correction_factor, alpha)?
        }
        other => bail!("unknown --screen '{other}' (native|xla)"),
    };
    let mut t = Table::new(&["rank", "items", "x", "n", "p-value"]);
    for (i, s) in significant.iter().take(20).enumerate() {
        t.row(vec![
            (i + 1).to_string(),
            format!("{:?}", s.items),
            s.support.to_string(),
            s.pos_support.to_string(),
            format!("{:.3e}", s.p_value),
        ]);
    }
    println!("{}", t.render());
    if significant.len() > 20 {
        println!("… and {} more", significant.len() - 20);
    }
    Ok(())
}

/// `parlamp mine` — plain frequent closed itemset mining.
pub fn cmd_mine(args: &Args) -> Result<()> {
    let data = args.require("data")?;
    let (n_items, trans) = read_transactions(Path::new(data))?;
    let labels = vec![false; trans.len()];
    let db = Database::from_transactions(n_items, &trans, &labels);
    let min_sup = args.get_usize("min-sup", 1)? as u32;
    let mut count = 0u64;
    let verbose = args.flag("verbose");
    let stats = mine_closed(&db, min_sup, |node, ms| {
        count += 1;
        if verbose {
            println!("{:?} (sup {})", node.items, node.support);
        }
        (Visit::Continue, ms)
    });
    println!(
        "closed itemsets: {count} (expanded {} candidates, {} word-ops)",
        stats.expand.candidates, stats.expand.word_ops
    );
    Ok(())
}

/// `parlamp sim` — one DES run with full reporting.
pub fn cmd_sim(args: &Args) -> Result<()> {
    let (name, db) = scenario_db(args)?;
    let p = args.get_usize("procs", 12)?;
    let alpha = args.get_f64("alpha", crate::DEFAULT_ALPHA)?;
    // The speedup baseline is the *same computation* serially: LAMP
    // phases 1+2 with support-increase pruning (not a full enumeration).
    let cal = crate::bench::calibrate_lamp(&db, alpha);
    let t1 = cal.t1_s;
    let cfg = SimConfig {
        p,
        net: if args.flag("ethernet") { NetModel::ethernet() } else { NetModel::default() },
        steal: !args.flag("naive"),
        preprocess: !args.flag("no-preprocess"),
        seed: args.get_u64("seed", 2015)?,
        ..SimConfig::calibrated(p, &cal)
    };
    let (res, p1, p2) = lamp_parallel_sim(&db, alpha, &cfg);
    println!("scenario {name}: {}", res.summary());
    println!(
        "serial t1={:.3}s | P={p} phase1={:.4}s phase2={:.4}s speedup₁={:.1}×",
        t1,
        p1.makespan_s,
        p2.makespan_s,
        t1 / (p1.makespan_s + p2.makespan_s).max(1e-12)
    );
    println!(
        "comm: sent={} gives={} tasks={} rejects={} bytes={}",
        p1.comm.sent + p2.comm.sent,
        p1.comm.gives + p2.comm.gives,
        p1.comm.tasks_shipped + p2.comm.tasks_shipped,
        p1.comm.rejects + p2.comm.rejects,
        p1.comm.bytes_sent + p2.comm.bytes_sent,
    );
    let b = crate::par::breakdown::sum(&p1.breakdowns);
    let [pre, main, probe, idle] = b.as_secs();
    println!("phase1 cpu-time: preprocess={pre:.4}s main={main:.4}s probe={probe:.4}s idle={idle:.4}s");
    Ok(())
}

/// `parlamp gendata` — write a scenario to FIMI files.
pub fn cmd_gendata(args: &Args) -> Result<()> {
    let (name, db) = scenario_db(args)?;
    let out = PathBuf::from(args.require("out")?);
    std::fs::create_dir_all(&out)?;
    // reconstruct horizontal form
    let mut trans: Vec<Vec<crate::db::Item>> = vec![Vec::new(); db.n_trans()];
    for i in 0..db.n_items() as crate::db::Item {
        for t in db.col(i).iter_ones() {
            trans[t].push(i);
        }
    }
    let labels: Vec<bool> = (0..db.n_trans()).map(|t| db.pos_mask().get(t)).collect();
    crate::db::write_transactions(&out.join(format!("{name}.dat")), &trans)?;
    crate::db::write_labels(&out.join(format!("{name}.labels")), &labels)?;
    println!(
        "wrote {}/{name}.dat ({} items × {} transactions, density {:.3}%)",
        out.display(),
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0
    );
    Ok(())
}

/// `parlamp scenarios` — list the Table-1 mirror problems.
pub fn cmd_scenarios(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let mut t = Table::new(&["name", "items", "trans", "density", "N_pos", "class"]);
    for s in all_scenarios(quick) {
        let db = s.build();
        t.row(vec![
            s.name.to_string(),
            db.n_items().to_string(),
            db.n_trans().to_string(),
            format!("{:.2}%", db.density() * 100.0),
            db.marginals().n_pos.to_string(),
            if s.large { "LARGE".into() } else { "small".into() },
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenarios_cmd_runs() {
        let args = Args::parse(&["--quick".to_string()]).unwrap();
        cmd_scenarios(&args).unwrap();
    }

    #[test]
    fn gendata_then_lamp_roundtrip() {
        let dir = std::env::temp_dir().join(format!("parlamp_cli_{}", std::process::id()));
        let argv: Vec<String> = ["--scenario", "mcf7", "--quick", "--out", dir.to_str().unwrap()]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let args = Args::parse(&argv).unwrap();
        cmd_gendata(&args).unwrap();
        let argv: Vec<String> = [
            "--data",
            dir.join("mcf7.dat").to_str().unwrap(),
            "--labels",
            dir.join("mcf7.labels").to_str().unwrap(),
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let args = Args::parse(&argv).unwrap();
        cmd_lamp(&args).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }
}
