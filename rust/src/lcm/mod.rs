//! Linear-time Closed itemset Miner (LCM) over bitmap databases.
//!
//! Implements the prefix-preserving closure (PPC) extension of Uno et al.
//! (paper §2.1): the search space is a tree whose nodes are exactly the
//! closed itemsets, so depth-first traversal enumerates each closed set
//! once with no duplicate checks. The single tree-node expansion
//! ([`expand`]) is shared verbatim by the serial miner ([`mine_closed`]),
//! the LAMP phases, and the distributed workers (`par::worker`), which is
//! what guarantees serial/parallel result equivalence. Expansion runs on
//! a per-node reduced conditional database (`db::ConditionalDb`,
//! DESIGN.md §8); `rust/tests/reduced_equivalence.rs` pins it to the
//! brute-force oracle ([`brute_force_closed`]).

mod brute;
mod expand;
mod miner;
mod node;

pub use brute::brute_force_closed;
pub use expand::{expand, expand_filtered, ExpandScratch, ExpandStats};
pub use miner::{mine_closed, MineStats, SupportHist, Visit};
pub use node::{SearchNode, NO_CORE};
