//! Minimal Unix signal plumbing (no `libc` dependency — the two symbols
//! used are part of every Unix libc ABI and are declared directly).
//!
//! Two users:
//! - the `parlamp serve` daemon latches SIGTERM/SIGINT into an atomic flag
//!   (the one async-signal-safe thing a handler may do) and drains
//!   gracefully (DESIGN.md §9);
//! - `parlamp __worker` processes *ignore* SIGINT: a terminal Ctrl-C
//!   delivers SIGINT to the whole foreground process group, and workers
//!   that die mid-phase would turn a graceful daemon drain into a failed
//!   job. Workers are supervised — they exit on the fabric socket's EOF
//!   (or `BYE`), so ignoring the terminal's signal never leaks them.

use std::sync::atomic::{AtomicBool, Ordering};

pub const SIGINT: i32 = 2;
pub const SIGTERM: i32 = 15;

/// `SIG_IGN` as the kernel ABI encodes it.
const SIG_IGN: usize = 1;

/// Latched by [`install_terminate_latch`]'s handler.
static TERMINATE: AtomicBool = AtomicBool::new(false);

type Handler = extern "C" fn(i32);

extern "C" {
    /// POSIX `signal(2)`. The handler slot is pointer-sized; passing it as
    /// `usize` lets the same declaration carry both real handlers and the
    /// `SIG_IGN` sentinel.
    fn signal(signum: i32, handler: usize) -> usize;
}

extern "C" fn latch(_signum: i32) {
    TERMINATE.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT into the terminate latch; poll with
/// [`terminate_requested`].
pub fn install_terminate_latch() {
    let h: Handler = latch;
    unsafe {
        signal(SIGTERM, h as *const () as usize);
        signal(SIGINT, h as *const () as usize);
    }
}

/// Whether a latched SIGTERM/SIGINT has been received.
pub fn terminate_requested() -> bool {
    TERMINATE.load(Ordering::SeqCst)
}

/// Ignore SIGINT for this process (worker processes under a supervisor).
pub fn ignore_interrupts() {
    unsafe {
        signal(SIGINT, SIG_IGN);
    }
}
