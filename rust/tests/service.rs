//! End-to-end acceptance for `parlamp serve` (DESIGN.md §9): a real
//! daemon process with a warm 2-rank worker fleet, driven over its
//! Unix-domain socket — and, for the §11 transport abstraction, over a
//! loopback TCP endpoint — by concurrent clients.
//!
//! Proves the ISSUE-4 acceptance criteria:
//! - two concurrent clients get results identical to the serial engine
//!   (λ*, closed-pattern histogram, correction factor, significant set);
//! - a repeat submission is answered from the result cache (`from_cache`
//!   in the STATUS/RESULT payloads) without re-mining;
//! - `SHUTDOWN` and SIGTERM both drain, dismiss the fleet, unlink the
//!   socket, and exit 0.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use parlamp::datagen::{generate_gwas, GeneticModel, GwasSpec};
use parlamp::lamp::lamp_serial;
use parlamp::lcm::{mine_closed, SupportHist, Visit};
use parlamp::net::Endpoint;
use parlamp::service::Client;
use parlamp::wire::service::{JobOutcome, JobSpec, JobState};

fn parlamp_bin() -> PathBuf {
    PathBuf::from(env!("CARGO_BIN_EXE_parlamp"))
}

fn test_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parlamp-svc-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A small cohort with one planted association — large enough that the
/// three phases do real work, small enough for CI.
fn cohort() -> parlamp::db::Database {
    let spec = GwasSpec {
        n_snps: 120,
        n_individuals: 90,
        n_pos: 24,
        model: GeneticModel::Dominant,
        maf_upper: 0.2,
        ld_copy_prob: 0.25,
        common_frac: 0.2,
        planted: vec![(3, 0.9)],
        seed: 47,
    };
    generate_gwas(&spec).0
}

fn serial_sparse_hist(db: &parlamp::db::Database, min_sup: u32) -> Vec<(u32, u64)> {
    let mut hist = SupportHist::new(db.n_trans());
    mine_closed(db, min_sup, |node, ms| {
        hist.record(node.support);
        (Visit::Continue, ms)
    });
    hist.sparse()
}

struct Daemon {
    child: Child,
    socket: PathBuf,
}

impl Daemon {
    fn start(tag: &str, procs: usize) -> Daemon {
        Daemon::start_with(tag, procs, &[])
    }

    fn start_with(tag: &str, procs: usize, extra: &[&str]) -> Daemon {
        let socket = test_dir(tag).join("parlamp.sock");
        let child = Command::new(parlamp_bin())
            .arg("serve")
            .arg("--socket")
            .arg(&socket)
            .arg("--procs")
            .arg(procs.to_string())
            .arg("--cache")
            .arg("8")
            .args(extra)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .spawn()
            .expect("spawn parlamp serve");
        let daemon = Daemon { child, socket };
        // Readiness = the socket exists (the daemon binds it only after
        // the fleet is warm).
        let deadline = Instant::now() + Duration::from_secs(60);
        while !daemon.socket.exists() {
            assert!(Instant::now() < deadline, "daemon never bound its socket");
            std::thread::sleep(Duration::from_millis(10));
        }
        daemon
    }

    fn endpoint(&self) -> Endpoint {
        Endpoint::unix(&self.socket)
    }

    fn client(&self) -> Client {
        Client::connect(&self.endpoint()).expect("connect to daemon")
    }

    /// Wait for the daemon to exit on its own; panics after 60 s.
    fn wait_exit(mut self) -> std::process::ExitStatus {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            if let Some(status) = self.child.try_wait().expect("poll daemon") {
                return status;
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                panic!("daemon did not exit in time");
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn assert_matches_serial(
    outcome: &JobOutcome,
    serial: &parlamp::lamp::LampResult,
    hist: &[(u32, u64)],
) {
    assert_eq!(outcome.lambda_final, serial.lambda_final, "λ* mismatch");
    assert_eq!(outcome.min_sup, serial.min_sup);
    assert_eq!(outcome.correction_factor, serial.correction_factor);
    assert_eq!(outcome.phase2_closed, serial.phase2_closed);
    assert_eq!(outcome.hist2, hist, "phase-2 closed-pattern histogram mismatch");
    assert_eq!(outcome.significant.len(), serial.significant.len());
    for (a, b) in outcome.significant.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items);
        assert_eq!(a.support, b.support);
        assert_eq!(a.pos_support, b.pos_support);
        assert!((a.p_value - b.p_value).abs() < 1e-12, "{} vs {}", a.p_value, b.p_value);
    }
}

/// Acceptance: two concurrent clients, serial-identical results, cache
/// hits on repeat submission, graceful SHUTDOWN.
#[test]
fn daemon_serves_concurrent_clients_and_caches_repeats() {
    let db = cohort();
    let serial = lamp_serial(&db, 0.05);
    let hist = serial_sparse_hist(&db, serial.min_sup);
    let daemon = Daemon::start("main", 2);

    // Two clients submit the same problem concurrently (different seeds —
    // the cache key ignores them, results are seed-invariant) and both
    // block on RESULT.
    let submit = |seed: u64| {
        let db = db.clone();
        let ep = daemon.endpoint();
        std::thread::spawn(move || -> (u64, JobOutcome) {
            let mut client = Client::connect(&ep).expect("connect");
            let spec = JobSpec { seed, ..JobSpec::new(db, 0.05) };
            let id = client.submit(spec).expect("submit");
            let outcome = client.results(id).expect("results");
            (id, outcome)
        })
    };
    let a = submit(7);
    let b = submit(8);
    let (id_a, out_a) = a.join().unwrap();
    let (id_b, out_b) = b.join().unwrap();
    assert_ne!(id_a, id_b, "every submission gets its own job id");
    assert_matches_serial(&out_a, &serial, &hist);
    assert_matches_serial(&out_b, &serial, &hist);
    // The scheduler runs one job at a time, so exactly one of the two was
    // mined; the other was answered from the cache (at submit or schedule
    // time) without the workers seeing new work.
    assert_eq!(
        [out_a.from_cache, out_b.from_cache].iter().filter(|&&c| c).count(),
        1,
        "exactly one of two identical concurrent jobs must be mined"
    );

    // A repeat submission after both finished is a pure submit-time cache
    // hit: terminal immediately, no queue, no workers.
    let mut client = daemon.client();
    let id3 = client.submit(JobSpec::new(db.clone(), 0.05)).expect("resubmit");
    match client.status(id3).expect("status") {
        JobState::Done { from_cache } => assert!(from_cache, "repeat must be a cache hit"),
        other => panic!("repeat submission not terminal at once: {other}"),
    }
    let out3 = client.results(id3).expect("cached results");
    assert!(out3.from_cache);
    assert_matches_serial(&out3, &serial, &hist);

    // A different α is a different cache key: accepted, and *not* served
    // from cache (we only check its acceptance + status here to keep the
    // test fast — it mines for real).
    let id4 = client.submit(JobSpec::new(db.clone(), 0.01)).expect("different alpha");
    let out4 = client.results(id4).expect("results at α=0.01");
    assert!(!out4.from_cache, "different α must not hit the α=0.05 entry");

    // Unknown ids are reported, not errors at the protocol level.
    assert_eq!(client.status(999_999).expect("status"), JobState::NotFound);
    assert_eq!(client.cancel(999_999).expect("cancel"), JobState::NotFound);

    // Graceful shutdown: ack, exit 0, socket unlinked.
    client.shutdown().expect("shutdown ack");
    let socket = daemon.socket.clone();
    let status = daemon.wait_exit();
    assert!(status.success(), "daemon exit: {status}");
    assert!(!socket.exists(), "socket must be unlinked on shutdown");
}

/// Acceptance for the §11 transport abstraction: the daemon serves the
/// exact same results over a loopback TCP endpoint. The ephemeral port is
/// recovered from the `listening on tcp:…` banner, the client dials it,
/// and one mined job must match the serial reference bit for bit.
#[test]
fn daemon_serves_over_tcp() {
    let db = cohort();
    let serial = lamp_serial(&db, 0.05);
    let hist = serial_sparse_hist(&db, serial.min_sup);
    let mut child = Command::new(parlamp_bin())
        .args(["serve", "--endpoint", "tcp:127.0.0.1:0", "--procs", "2", "--cache", "4"])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn parlamp serve (tcp)");
    struct KillOnDrop(Option<Child>);
    impl Drop for KillOnDrop {
        fn drop(&mut self) {
            if let Some(mut c) = self.0.take() {
                let _ = c.kill();
                let _ = c.wait();
            }
        }
    }
    // Readiness: the daemon prints `parlamp serve: listening on
    // tcp:127.0.0.1:<port>` once the fleet is warm — that line carries the
    // resolved ephemeral port. Keep draining stdout afterwards so the
    // daemon's later prints never block or hit a closed pipe.
    let stdout = child.stdout.take().expect("piped stdout");
    let mut guard = KillOnDrop(Some(child));
    let ep = {
        use std::io::BufRead;
        let mut reader = std::io::BufReader::new(stdout);
        let mut found = None;
        let mut line = String::new();
        while reader.read_line(&mut line).expect("daemon stdout") > 0 {
            if let Some(rest) = line.trim_end().strip_prefix("parlamp serve: listening on ") {
                found = Some(rest.parse::<Endpoint>().expect("endpoint in banner"));
                break;
            }
            line.clear();
        }
        std::thread::spawn(move || {
            let mut sink = String::new();
            while reader.read_line(&mut sink).map(|n| n > 0).unwrap_or(false) {
                sink.clear();
            }
        });
        found.expect("daemon exited without a listening banner")
    };
    assert!(matches!(ep, Endpoint::Tcp(_, port) if port != 0), "unresolved port in {ep}");

    let mut client = Client::connect(&ep).expect("connect over TCP");
    let id = client.submit(JobSpec::new(db, 0.05)).expect("submit over TCP");
    let outcome = client.results(id).expect("results over TCP");
    assert!(!outcome.from_cache);
    assert_matches_serial(&outcome, &serial, &hist);

    // Graceful shutdown over TCP: ack, exit 0 (nothing on disk to unlink).
    client.shutdown().expect("shutdown ack");
    let mut child = guard.0.take().expect("daemon still owned");
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Some(status) = child.try_wait().expect("poll daemon") {
            assert!(status.success(), "daemon exit: {status}");
            break;
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            panic!("tcp daemon did not exit in time");
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Acceptance (DESIGN.md §13): the persistent result store keeps the
/// cache warm across daemon restarts. Daemon 1 mines a job and appends it
/// to `--store`; daemon 2, started fresh on the same store file, answers
/// the identical submission as a terminal cache hit at submit time — with
/// zero fleet phases run, proven by STATS reporting zero mined jobs.
#[test]
fn persistent_store_survives_daemon_restart() {
    let db = cohort();
    let serial = lamp_serial(&db, 0.05);
    let hist = serial_sparse_hist(&db, serial.min_sup);
    let store = test_dir("store").join("results.plst");
    let store_arg = store.to_str().expect("utf-8 temp path").to_string();

    // Daemon 1: mine the job once; the result is appended to the store.
    {
        let daemon = Daemon::start_with("store1", 2, &["--store", &store_arg]);
        let mut client = daemon.client();
        let id = client.submit(JobSpec::new(db.clone(), 0.05)).expect("submit");
        let outcome = client.results(id).expect("results");
        assert!(!outcome.from_cache, "first run must mine");
        assert_matches_serial(&outcome, &serial, &hist);
        let stats = client.stats().expect("stats");
        assert_eq!(stats.jobs_mined, 1);
        assert_eq!(stats.store_appends, 1, "the mined result must be persisted");
        assert_eq!(stats.store_entries, 1);
        client.shutdown().expect("shutdown ack");
        assert!(daemon.wait_exit().success());
    }
    assert!(store.exists(), "store file must outlive the daemon");

    // Daemon 2: fresh process, same store. The identical submission is
    // terminal at submit time — no queue, no fleet phase, served from the
    // preloaded disk record.
    {
        let daemon = Daemon::start_with("store2", 2, &["--store", &store_arg]);
        let mut client = daemon.client();
        let id = client.submit(JobSpec::new(db.clone(), 0.05)).expect("resubmit");
        match client.status(id).expect("status") {
            JobState::Done { from_cache } => {
                assert!(from_cache, "restart must serve the job from the store");
            }
            other => panic!("restarted daemon did not answer at submit time: {other}"),
        }
        let outcome = client.results(id).expect("cached results");
        assert!(outcome.from_cache);
        assert_matches_serial(&outcome, &serial, &hist);
        let stats = client.stats().expect("stats");
        assert_eq!(stats.jobs_mined, 0, "zero fleet phases may run for a store hit");
        assert_eq!(stats.store_entries, 1);
        client.shutdown().expect("shutdown ack");
        assert!(daemon.wait_exit().success());
    }
}

/// Acceptance: SIGTERM drains the queue (the in-flight job finishes) and
/// the daemon exits 0 with the socket unlinked.
#[test]
fn sigterm_drains_and_unlinks_socket() {
    let db = cohort();
    let daemon = Daemon::start("sigterm", 2);
    let mut client = daemon.client();
    let id = client.submit(JobSpec::new(db, 0.05)).expect("submit");
    assert!(id >= 1);

    let kill = Command::new("kill")
        .arg("-TERM")
        .arg(daemon.child.id().to_string())
        .status()
        .expect("send SIGTERM");
    assert!(kill.success());

    let socket = daemon.socket.clone();
    let status = daemon.wait_exit();
    assert!(status.success(), "daemon must drain and exit 0 on SIGTERM, got {status}");
    assert!(!socket.exists(), "socket must be unlinked after SIGTERM drain");
}
