//! XLA runtime integration: the AOT screen artifact vs the native rust
//! statistics. Requires `make artifacts` (tests skip with a notice when
//! the artifacts are absent, so plain `cargo test` stays green).

use parlamp::bits::BitVec;
use parlamp::datagen::{generate_gwas, GwasSpec};
use parlamp::lamp::lamp_serial;
use parlamp::runtime::{
    artifacts_available, artifacts_dir, phase3_extract_xla, ScreenEngine, XlaRuntime,
};
use parlamp::stats::{tarone::TaroneBound, FisherTable, Marginals};
use parlamp::util::rng::Rng;

fn engine_or_skip() -> Option<ScreenEngine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(ScreenEngine::new(XlaRuntime::load(&artifacts_dir()).expect("load artifacts")))
}

#[test]
fn screen_matches_native_fisher_on_random_bitmaps() {
    let Some(engine) = engine_or_skip() else { return };
    let m = Marginals::new(500, 120);
    let fisher = FisherTable::new(m);
    let tarone = TaroneBound::new(m);
    let mut rng = Rng::new(2015);
    let n = 500usize;
    let pos = BitVec::from_indices(n, 0..120);
    let rows: Vec<BitVec> = (0..700)
        .map(|_| {
            let density = 0.02 + rng.f64() * 0.4;
            BitVec::from_indices(n, (0..n).filter(|_| rng.bernoulli(density)))
        })
        .collect();
    let got = engine.score(&rows, &pos, m).expect("screen");
    assert_eq!(got.len(), rows.len());
    for (row, out) in rows.iter().zip(&got) {
        let x = row.count();
        let nobs = row.and_count(&pos);
        assert_eq!(out.x as u32, x);
        assert_eq!(out.n as u32, nobs);
        let want_logp = fisher.log_p_value(x, nobs);
        let want_logf = tarone.log_f(x);
        assert!(
            (out.logp - want_logp).abs() < 1e-8 * want_logp.abs().max(1.0),
            "logp mismatch: xla {} native {} (x={x} n={nobs})",
            out.logp,
            want_logp
        );
        assert!(
            (out.logf - want_logf).abs() < 1e-8 * want_logf.abs().max(1.0),
            "logf mismatch: xla {} native {} (x={x})",
            out.logf,
            want_logf
        );
    }
}

#[test]
fn xla_phase3_equals_native_phase3() {
    let Some(engine) = engine_or_skip() else { return };
    let spec = GwasSpec {
        n_snps: 120,
        n_individuals: 100,
        n_pos: 25,
        planted: vec![(3, 0.85)],
        ..GwasSpec::small(99)
    };
    let (db, _) = generate_gwas(&spec);
    let serial = lamp_serial(&db, 0.05);
    let xla = phase3_extract_xla(&engine, &db, serial.min_sup, serial.correction_factor, 0.05)
        .expect("xla phase 3");
    assert_eq!(
        xla.len(),
        serial.significant.len(),
        "pattern count: xla {} native {}",
        xla.len(),
        serial.significant.len()
    );
    for (a, b) in xla.iter().zip(&serial.significant) {
        assert_eq!(a.items, b.items);
        assert_eq!(a.support, b.support);
        assert_eq!(a.pos_support, b.pos_support);
        assert!((a.p_value - b.p_value).abs() <= 1e-9 * b.p_value.max(1e-300));
    }
}

#[test]
fn screen_rejects_oversized_marginals() {
    let Some(engine) = engine_or_skip() else { return };
    let t_max = engine.runtime().manifest().t_max;
    let n = (t_max + 10).min(engine.runtime().manifest().max_transactions());
    let m = Marginals::new(n as u32, t_max as u32); // n_pos == t_max: too big
    let pos = BitVec::ones(n);
    let rows = vec![BitVec::ones(n)];
    assert!(engine.score(&rows, &pos, m).is_err());
}
