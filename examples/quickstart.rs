//! Quickstart: generate a small GWAS-like dataset, run the full
//! three-phase LAMP procedure, and print the statistically significant
//! mutation combinations.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use parlamp::datagen::{generate_gwas, GwasSpec};
use parlamp::lamp::lamp_serial;

fn main() {
    // A 300-SNP, 120-individual cohort with one planted 3-SNP association.
    let spec = GwasSpec::small(2015);
    let (db, planted) = generate_gwas(&spec);
    println!(
        "dataset: {} items × {} transactions, density {:.2}%, {} positives",
        db.n_items(),
        db.n_trans(),
        db.density() * 100.0,
        db.marginals().n_pos
    );
    println!("planted association: {:?}\n", planted[0]);

    let res = lamp_serial(&db, 0.05);
    println!("LAMP: {}", res.summary());
    println!("\nsignificant patterns (FWER ≤ {}):", res.alpha);
    for (i, s) in res.significant.iter().take(10).enumerate() {
        println!(
            "  {:>2}. {:?}  support={} positives={} P={:.3e}",
            i + 1,
            s.items,
            s.support,
            s.pos_support,
            s.p_value
        );
    }
    if res.significant.is_empty() {
        println!("  (none — try a stronger planted signal)");
    }
}
