//! The `BENCH_*.json` perf-trajectory schema: writer + validator.
//!
//! `parlamp bench` emits one schema-stable JSON document per run so every
//! future PR can compare against the recorded trajectory (wall-clock,
//! expansion work units, closed-set counts, λ*). The schema is versioned
//! through the [`SCHEMA_ID`] string; additive fields bump the suffix.
//! CI gates on [`validate`] (structure and types), never on timings —
//! machine noise must not fail a build, a shape change must.
//!
//! No `serde` exists in the offline registry, so the writer builds the
//! document by hand and [`validate`] runs a minimal recursive-descent JSON
//! parser — also used by the round-trip tests.

use anyhow::{bail, ensure, Context, Result};

/// Schema identifier stamped into every report. v2 added the process
/// engine's data-plane fields: `data_plane` ("mesh"/"hub"; "none" for the
/// other engines) and the `hub_frames`/`direct_frames` relay counters.
/// v3 adds `transport` ("unix"/"tcp"; "none" for the other engines) — the
/// stream transport the process fabric ran over (DESIGN.md §11).
/// v4 adds the Fig. 7 CPU-time breakdown (`preprocess_s`/`main_s`/
/// `probe_s`/`idle_s`, summed over ranks and both distributed phases) and
/// the steal-protocol totals (`steal_sent`/`steal_gives`/`tasks_shipped`)
/// — all 0 on the serial engines (DESIGN.md §14).
pub const SCHEMA_ID: &str = "parlamp-bench/4";

/// One `(scenario, engine)` measurement.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub scenario: String,
    pub engine: String,
    /// Process engine: "mesh" or "hub" (DESIGN.md §10); "none" elsewhere.
    pub data_plane: String,
    /// Process engine: "unix" or "tcp" (DESIGN.md §11); "none" elsewhere.
    pub transport: String,
    /// World size (1 for the serial engines).
    pub procs: usize,
    pub n_items: usize,
    pub n_trans: usize,
    pub density: f64,
    /// Real wall-clock of the end-to-end run, seconds.
    pub wall_s: f64,
    /// Phases 1+2 makespan for distributed engines (virtual seconds on the
    /// DES engine); 0 for the serial engines.
    pub t_parallel_s: f64,
    /// Total expansion work units (`ExpandStats::units`); 0 when the
    /// engine is not instrumented (lamp2).
    pub work_units: u64,
    /// Serial bitmap engine only: the candidate-loop / reduction split of
    /// `work_units`. 0 elsewhere.
    pub word_ops: u64,
    pub reduce_ops: u64,
    pub lambda_star: u32,
    pub min_sup: u32,
    pub correction_factor: u64,
    pub phase1_closed: u64,
    pub phase2_closed: u64,
    pub significant: usize,
    /// Process engine: data-plane frames relayed by the hub (summed over
    /// both distributed phases). A mesh run records 0 here. 0 elsewhere.
    pub hub_frames: u64,
    /// Process engine: data-plane frames sent worker-to-worker directly.
    pub direct_frames: u64,
    /// Fig. 7 CPU-time breakdown, summed over ranks and both distributed
    /// phases; 0 on the serial engines (no per-rank instrumentation).
    pub preprocess_s: f64,
    pub main_s: f64,
    pub probe_s: f64,
    pub idle_s: f64,
    /// Steal-protocol totals over both distributed phases: REQUEST frames
    /// sent, GIVE frames answered, stack roots shipped. 0 elsewhere.
    pub steal_sent: u64,
    pub steal_gives: u64,
    pub tasks_shipped: u64,
}

/// A full report: header + one record per `(scenario, engine)`.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub label: String,
    pub quick: bool,
    pub alpha: f64,
    pub seed: u64,
    pub runs: Vec<BenchRecord>,
}

impl BenchReport {
    pub fn new(label: &str, quick: bool, alpha: f64, seed: u64) -> BenchReport {
        BenchReport { label: label.to_string(), quick, alpha, seed, runs: Vec::new() }
    }

    pub fn push(&mut self, r: BenchRecord) {
        self.runs.push(r);
    }

    pub fn len(&self) -> usize {
        self.runs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Render the document. Key order is part of the stable schema.
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(512 + self.runs.len() * 400);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": {},\n", json_str(SCHEMA_ID)));
        s.push_str(&format!("  \"label\": {},\n", json_str(&self.label)));
        s.push_str(&format!("  \"quick\": {},\n", self.quick));
        s.push_str(&format!("  \"alpha\": {},\n", json_num(self.alpha)));
        s.push_str(&format!("  \"seed\": {},\n", self.seed));
        s.push_str("  \"runs\": [\n");
        for (i, r) in self.runs.iter().enumerate() {
            s.push_str("    {");
            s.push_str(&format!("\"scenario\": {}, ", json_str(&r.scenario)));
            s.push_str(&format!("\"engine\": {}, ", json_str(&r.engine)));
            s.push_str(&format!("\"data_plane\": {}, ", json_str(&r.data_plane)));
            s.push_str(&format!("\"transport\": {}, ", json_str(&r.transport)));
            s.push_str(&format!("\"procs\": {}, ", r.procs));
            s.push_str(&format!("\"n_items\": {}, ", r.n_items));
            s.push_str(&format!("\"n_trans\": {}, ", r.n_trans));
            s.push_str(&format!("\"density\": {}, ", json_num(r.density)));
            s.push_str(&format!("\"wall_s\": {}, ", json_num(r.wall_s)));
            s.push_str(&format!("\"t_parallel_s\": {}, ", json_num(r.t_parallel_s)));
            s.push_str(&format!("\"work_units\": {}, ", r.work_units));
            s.push_str(&format!("\"word_ops\": {}, ", r.word_ops));
            s.push_str(&format!("\"reduce_ops\": {}, ", r.reduce_ops));
            s.push_str(&format!("\"lambda_star\": {}, ", r.lambda_star));
            s.push_str(&format!("\"min_sup\": {}, ", r.min_sup));
            s.push_str(&format!("\"correction_factor\": {}, ", r.correction_factor));
            s.push_str(&format!("\"phase1_closed\": {}, ", r.phase1_closed));
            s.push_str(&format!("\"phase2_closed\": {}, ", r.phase2_closed));
            s.push_str(&format!("\"significant\": {}, ", r.significant));
            s.push_str(&format!("\"hub_frames\": {}, ", r.hub_frames));
            s.push_str(&format!("\"direct_frames\": {}, ", r.direct_frames));
            s.push_str(&format!("\"preprocess_s\": {}, ", json_num(r.preprocess_s)));
            s.push_str(&format!("\"main_s\": {}, ", json_num(r.main_s)));
            s.push_str(&format!("\"probe_s\": {}, ", json_num(r.probe_s)));
            s.push_str(&format!("\"idle_s\": {}, ", json_num(r.idle_s)));
            s.push_str(&format!("\"steal_sent\": {}, ", r.steal_sent));
            s.push_str(&format!("\"steal_gives\": {}, ", r.steal_gives));
            s.push_str(&format!("\"tasks_shipped\": {}}}", r.tasks_shipped));
            s.push_str(if i + 1 < self.runs.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_num(x: f64) -> String {
    // `{:?}` prints the shortest round-trip form, which is valid JSON for
    // finite values. A NaN/∞ measurement is corrupt: emit `null` so the
    // schema validator (and the writer's self-check before the file is
    // written) rejects the document loudly instead of recording a
    // plausible-looking zero.
    if x.is_finite() {
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}

// ---- minimal JSON value model + parser (validation / tests only) -------

/// Parsed JSON value. Only what validation needs; numbers are `f64`.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a JSON document (strict enough for the bench schema; rejects
/// trailing garbage).
pub fn parse_json(s: &str) -> Result<Json> {
    let b = s.as_bytes();
    let mut pos = 0usize;
    let v = parse_value(b, &mut pos)?;
    skip_ws(b, &mut pos);
    ensure!(pos == b.len(), "trailing garbage at byte {pos}");
    Ok(v)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    ensure!(*pos < b.len(), "unexpected end of input");
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => bail!("unexpected byte {:?} at {}", c as char, *pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json> {
    ensure!(b[*pos..].starts_with(lit.as_bytes()), "bad literal at {}", *pos);
    *pos += lit.len();
    Ok(v)
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let txt = std::str::from_utf8(&b[start..*pos])?;
    let x: f64 = txt.parse().with_context(|| format!("bad number '{txt}' at {start}"))?;
    ensure!(x.is_finite(), "non-finite number at {start}");
    Ok(Json::Num(x))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    ensure!(b[*pos] == b'"', "expected string at {}", *pos);
    *pos += 1;
    let mut out = String::new();
    loop {
        ensure!(*pos < b.len(), "unterminated string");
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                ensure!(*pos < b.len(), "unterminated escape");
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        ensure!(*pos + 4 < b.len(), "truncated \\u escape");
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let code = u32::from_str_radix(hex, 16)
                            .with_context(|| format!("bad \\u{hex}"))?;
                        // Surrogate pairs don't occur in the schema's
                        // ASCII field names; reject rather than mangle.
                        let c = char::from_u32(code)
                            .with_context(|| format!("\\u{hex} is not a scalar value"))?;
                        out.push(c);
                        *pos += 4;
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                }
                *pos += 1;
            }
            _ => {
                // copy one UTF-8 scalar
                let rest = std::str::from_utf8(&b[*pos..]).context("invalid UTF-8")?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(out));
    }
    loop {
        out.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        ensure!(*pos < b.len(), "unterminated array");
        match b[*pos] {
            b',' => *pos += 1,
            b']' => {
                *pos += 1;
                return Ok(Json::Arr(out));
            }
            c => bail!("expected ',' or ']' at {}, got '{}'", *pos, c as char),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut out = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(out));
    }
    loop {
        skip_ws(b, pos);
        let k = parse_string(b, pos)?;
        skip_ws(b, pos);
        ensure!(*pos < b.len() && b[*pos] == b':', "expected ':' at {}", *pos);
        *pos += 1;
        let v = parse_value(b, pos)?;
        out.push((k, v));
        skip_ws(b, pos);
        ensure!(*pos < b.len(), "unterminated object");
        match b[*pos] {
            b',' => *pos += 1,
            b'}' => {
                *pos += 1;
                return Ok(Json::Obj(out));
            }
            c => bail!("expected ',' or '}}' at {}, got '{}'", *pos, c as char),
        }
    }
}

// ---- schema validation -------------------------------------------------

const RUN_STR_FIELDS: &[&str] = &["scenario", "engine", "data_plane", "transport"];
const RUN_NUM_FIELDS: &[&str] = &[
    "procs",
    "n_items",
    "n_trans",
    "density",
    "wall_s",
    "t_parallel_s",
    "work_units",
    "word_ops",
    "reduce_ops",
    "lambda_star",
    "min_sup",
    "correction_factor",
    "phase1_closed",
    "phase2_closed",
    "significant",
    "hub_frames",
    "direct_frames",
    "preprocess_s",
    "main_s",
    "probe_s",
    "idle_s",
    "steal_sent",
    "steal_gives",
    "tasks_shipped",
];

/// Validate a rendered report against the `parlamp-bench/4` schema:
/// header fields present and typed, at least one run, every run carrying
/// every field with the right type and non-negative measurements. Returns
/// the number of runs. This is the CI gate — timings are deliberately not
/// judged.
pub fn validate(doc: &str) -> Result<usize> {
    let v = parse_json(doc).context("parse")?;
    let schema = v
        .get("schema")
        .and_then(Json::as_str)
        .context("missing or non-string 'schema'")?;
    ensure!(schema == SCHEMA_ID, "schema '{schema}' != '{SCHEMA_ID}'");
    v.get("label").and_then(Json::as_str).context("missing 'label'")?;
    ensure!(
        matches!(v.get("quick"), Some(Json::Bool(_))),
        "missing or non-bool 'quick'"
    );
    v.get("alpha").and_then(Json::as_f64).context("missing 'alpha'")?;
    v.get("seed").and_then(Json::as_f64).context("missing 'seed'")?;
    let runs = v.get("runs").and_then(Json::as_arr).context("missing 'runs' array")?;
    ensure!(!runs.is_empty(), "'runs' must not be empty");
    for (i, r) in runs.iter().enumerate() {
        for f in RUN_STR_FIELDS {
            let s = r
                .get(f)
                .and_then(Json::as_str)
                .with_context(|| format!("run {i}: missing string '{f}'"))?;
            ensure!(!s.is_empty(), "run {i}: empty '{f}'");
        }
        for f in RUN_NUM_FIELDS {
            let x = r
                .get(f)
                .and_then(Json::as_f64)
                .with_context(|| format!("run {i}: missing number '{f}'"))?;
            ensure!(x >= 0.0, "run {i}: negative '{f}'");
        }
    }
    Ok(runs.len())
}

// ---- two-report comparison (`parlamp bench --compare`) -----------------

/// One joined row of a [`compare`] report.
struct CompareRow {
    scenario: String,
    engine: String,
    planes: (String, String),
    transports: (String, String),
    wall: (f64, f64),
    units: (f64, f64),
    /// Fig. 7 breakdown seconds (main expansion loop, idle wait) — v4
    /// fields, so the deltas localize a slowdown to work vs. starvation.
    main: (f64, f64),
    idle: (f64, f64),
    /// Result fields that must match between runs of the same scenario;
    /// non-empty = a correctness regression, flagged in the report.
    mismatches: Vec<&'static str>,
}

fn pct_delta(a: f64, b: f64) -> String {
    if a <= 0.0 {
        return "-".into();
    }
    format!("{:+.1}%", (b - a) / a * 100.0)
}

/// Diff two validated bench reports, joined per `(scenario, engine)`:
/// wall-clock and work-unit deltas, the data planes, and loud flags when
/// result fields (λ*, correction factor, significant count) differ — the
/// one-command regression check for hub-vs-mesh and for future PRs.
/// Returns the rendered report.
pub fn compare(doc_a: &str, doc_b: &str) -> Result<String> {
    validate(doc_a).context("validate first report")?;
    validate(doc_b).context("validate second report")?;
    let a = parse_json(doc_a)?;
    let b = parse_json(doc_b)?;
    let label = |v: &Json| v.get("label").and_then(Json::as_str).unwrap_or("?").to_string();
    let (label_a, label_b) = (label(&a), label(&b));
    let runs = |v: &Json| -> Vec<Json> { v.get("runs").and_then(Json::as_arr).unwrap().to_vec() };
    let key = |r: &Json| -> (String, String) {
        (
            r.get("scenario").and_then(Json::as_str).unwrap().to_string(),
            r.get("engine").and_then(Json::as_str).unwrap().to_string(),
        )
    };
    let num = |r: &Json, f: &str| r.get(f).and_then(Json::as_f64).unwrap();
    let strf = |r: &Json, f: &str| r.get(f).and_then(Json::as_str).unwrap().to_string();

    let runs_a = runs(&a);
    let runs_b = runs(&b);
    let mut rows: Vec<CompareRow> = Vec::new();
    let mut only_a: Vec<(String, String)> = Vec::new();
    let mut only_b: Vec<(String, String)> = runs_b.iter().map(key).collect();
    for ra in &runs_a {
        let k = key(ra);
        let Some(rb) = runs_b.iter().find(|&r| key(r) == k) else {
            only_a.push(k);
            continue;
        };
        only_b.retain(|x| *x != k);
        let mut mismatches = Vec::new();
        for f in ["lambda_star", "min_sup", "correction_factor", "significant"] {
            if num(ra, f) != num(rb, f) {
                mismatches.push(match f {
                    "lambda_star" => "λ*",
                    "min_sup" => "min_sup",
                    "correction_factor" => "k",
                    _ => "significant",
                });
            }
        }
        rows.push(CompareRow {
            scenario: k.0,
            engine: k.1,
            planes: (strf(ra, "data_plane"), strf(rb, "data_plane")),
            transports: (strf(ra, "transport"), strf(rb, "transport")),
            wall: (num(ra, "wall_s"), num(rb, "wall_s")),
            units: (num(ra, "work_units"), num(rb, "work_units")),
            main: (num(ra, "main_s"), num(rb, "main_s")),
            idle: (num(ra, "idle_s"), num(rb, "idle_s")),
            mismatches,
        });
    }
    ensure!(
        !rows.is_empty(),
        "the reports share no (scenario, engine) pair — nothing to compare"
    );

    let mut t = crate::util::table::Table::new(&[
        "scenario", "engine", "plane", "transport", "wall A", "wall B", "Δwall", "units A",
        "units B", "Δunits", "Δmain", "Δidle", "result",
    ]);
    let joined = |pair: &(String, String)| {
        if pair.0 == pair.1 {
            pair.0.clone()
        } else {
            format!("{}→{}", pair.0, pair.1)
        }
    };
    let mut regressions = 0usize;
    for r in &rows {
        let plane = joined(&r.planes);
        let transport = joined(&r.transports);
        let result = if r.mismatches.is_empty() {
            "=".to_string()
        } else {
            regressions += 1;
            format!("MISMATCH: {}", r.mismatches.join(","))
        };
        t.row(vec![
            r.scenario.clone(),
            r.engine.clone(),
            plane,
            transport,
            crate::util::fmt_secs(r.wall.0),
            crate::util::fmt_secs(r.wall.1),
            pct_delta(r.wall.0, r.wall.1),
            (r.units.0 as u64).to_string(),
            (r.units.1 as u64).to_string(),
            pct_delta(r.units.0, r.units.1),
            pct_delta(r.main.0, r.main.1),
            pct_delta(r.idle.0, r.idle.1),
            result,
        ]);
    }
    let mut out = format!("A = {label_a}, B = {label_b}\n{}", t.render());
    for (s, e) in &only_a {
        out.push_str(&format!("\nonly in A: ({s}, {e})"));
    }
    for (s, e) in &only_b {
        out.push_str(&format!("\nonly in B: ({s}, {e})"));
    }
    out.push('\n');
    if regressions > 0 {
        bail!(
            "{regressions} (scenario, engine) pair(s) disagree on result fields:\n{out}"
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(engine: &str) -> BenchRecord {
        BenchRecord {
            scenario: "mcf7".into(),
            engine: engine.into(),
            data_plane: if engine == "process" { "mesh".into() } else { "none".into() },
            transport: if engine == "process" { "unix".into() } else { "none".into() },
            procs: 4,
            n_items: 250,
            n_trans: 2000,
            density: 0.0294,
            wall_s: 0.125,
            t_parallel_s: 0.0,
            work_units: 123_456,
            word_ops: 100_000,
            reduce_ops: 23_456,
            lambda_star: 7,
            min_sup: 6,
            correction_factor: 88,
            phase1_closed: 1234,
            phase2_closed: 88,
            significant: 3,
            hub_frames: 0,
            direct_frames: if engine == "process" { 42 } else { 0 },
            preprocess_s: 0.001,
            main_s: 0.1,
            probe_s: 0.002,
            idle_s: 0.02,
            steal_sent: if engine == "process" { 12 } else { 0 },
            steal_gives: if engine == "process" { 9 } else { 0 },
            tasks_shipped: if engine == "process" { 42 } else { 0 },
        }
    }

    #[test]
    fn report_roundtrips_and_validates() {
        let mut rep = BenchReport::new("pr3", true, 0.05, 2015);
        rep.push(record("serial"));
        rep.push(record("sim"));
        let doc = rep.to_json();
        assert_eq!(validate(&doc).unwrap(), 2);
        let v = parse_json(&doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str().unwrap(), SCHEMA_ID);
        assert_eq!(v.get("runs").unwrap().as_arr().unwrap().len(), 2);
        let r0 = &v.get("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(r0.get("engine").unwrap().as_str().unwrap(), "serial");
        assert_eq!(r0.get("work_units").unwrap().as_f64().unwrap(), 123_456.0);
        assert_eq!(r0.get("density").unwrap().as_f64().unwrap(), 0.0294);
    }

    #[test]
    fn validator_rejects_shape_violations() {
        let mut rep = BenchReport::new("pr3", false, 0.05, 1);
        // empty runs
        assert!(validate(&rep.to_json()).is_err());
        rep.push(record("serial"));
        let good = rep.to_json();
        assert!(validate(&good).is_ok());
        // wrong schema id
        let bad = good.replace(SCHEMA_ID, "parlamp-bench/0");
        assert!(validate(&bad).is_err());
        // a missing field
        let bad = good.replace("\"lambda_star\"", "\"lambda_sta\"");
        assert!(validate(&bad).is_err());
        // truncated document
        assert!(validate(&good[..good.len() / 2]).is_err());
        // type confusion
        let bad = good.replace("\"procs\": 4", "\"procs\": \"four\"");
        assert!(validate(&bad).is_err());
    }

    #[test]
    fn non_finite_measurements_fail_validation_not_silently_zeroed() {
        let mut rep = BenchReport::new("pr3", false, 0.05, 1);
        let mut r = record("serial");
        r.wall_s = f64::NAN;
        rep.push(r);
        let doc = rep.to_json();
        assert!(doc.contains("\"wall_s\": null"), "{doc}");
        assert!(validate(&doc).is_err(), "corrupt measurement must not validate");
    }

    #[test]
    fn compare_joins_on_scenario_and_engine() {
        let mut a = BenchReport::new("hub", true, 0.05, 1);
        let mut b = BenchReport::new("mesh", true, 0.05, 1);
        let mut ra = record("process");
        ra.data_plane = "hub".into();
        ra.wall_s = 0.2;
        ra.hub_frames = 900;
        ra.direct_frames = 0;
        a.push(ra);
        a.push(record("serial"));
        let mut rb = record("process");
        rb.wall_s = 0.1;
        rb.transport = "tcp".into();
        b.push(rb);
        b.push(record("sim")); // unmatched on both sides
        let out = compare(&a.to_json(), &b.to_json()).unwrap();
        assert!(out.contains("A = hub, B = mesh"), "{out}");
        assert!(out.contains("hub→mesh"), "{out}");
        assert!(out.contains("unix→tcp"), "{out}");
        assert!(out.contains("-50.0%"), "wall delta missing:\n{out}");
        assert!(out.contains("only in A: (mcf7, serial)"), "{out}");
        assert!(out.contains("only in B: (mcf7, sim)"), "{out}");
    }

    #[test]
    fn compare_flags_result_mismatches_and_rejects_disjoint_reports() {
        let mut a = BenchReport::new("old", true, 0.05, 1);
        a.push(record("serial"));
        let mut b = BenchReport::new("new", true, 0.05, 1);
        let mut r = record("serial");
        r.lambda_star = 8; // a correctness regression, not noise
        b.push(r);
        let err = compare(&a.to_json(), &b.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("MISMATCH: λ*"), "{err:#}");
        // Identical results compare clean even when timings differ.
        let mut c = BenchReport::new("new", true, 0.05, 1);
        let mut r = record("serial");
        r.wall_s = 99.0;
        c.push(r);
        assert!(compare(&a.to_json(), &c.to_json()).is_ok());
        // No shared (scenario, engine) pair is an error, not an empty diff.
        let mut d = BenchReport::new("other", true, 0.05, 1);
        d.push(record("sim"));
        let err = compare(&a.to_json(), &d.to_json()).unwrap_err();
        assert!(format!("{err:#}").contains("nothing to compare"), "{err:#}");
        // Invalid input is rejected before any diffing.
        assert!(compare("{}", &a.to_json()).is_err());
    }

    #[test]
    fn parser_handles_json_basics() {
        let v = parse_json(r#"{"a": [1, 2.5, -3e-2], "b": "x\"y\n", "c": null, "d": true}"#)
            .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_f64().unwrap(), -0.03);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "x\"y\n");
        assert_eq!(v.get("c"), Some(&Json::Null));
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        assert!(parse_json("{\"a\": }").is_err());
        assert!(parse_json("[1, 2").is_err());
        assert!(parse_json("NaN").is_err());
    }

    #[test]
    fn strings_escape_cleanly() {
        assert_eq!(json_str("a\"b\\c\nd"), r#""a\"b\\c\nd""#);
        let doc = format!("{{\"k\": {}}}", json_str("weird \u{1} value"));
        let v = parse_json(&doc).unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "weird \u{1} value");
    }
}
