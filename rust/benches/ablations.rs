//! Ablations over the design knobs the paper fixes from preliminary
//! experiments (§4.2: `l = 2`, `w = 1`; §4.6: ~1 ms probe cadence; §4.5:
//! depth-1 preprocess): sweep each on the large HapMap-like problem.
//!
//! Run: `cargo bench --bench ablations [-- --quick]`

use parlamp::bench::{all_scenarios, calibrate_lamp};
use parlamp::par::{run_sim, RunMode, SimConfig};
use parlamp::util::bench_harness::{quick_mode, BenchSet};
use parlamp::util::fmt_secs;

fn main() {
    let quick = quick_mode();
    let alpha = parlamp::DEFAULT_ALPHA;
    let sc = all_scenarios(quick)
        .into_iter()
        .find(|s| s.name == "hapmap-dom-20")
        .expect("scenario");
    let db = sc.build();
    let cal = calibrate_lamp(&db, alpha);
    let p = if quick { 48 } else { 192 };
    let base = SimConfig { p, ..SimConfig::calibrated(p, &cal) };

    let mut run = |label: String, cfg: &SimConfig, set: &mut BenchSet| {
        let out = run_sim(&db, RunMode::Phase1 { alpha }, cfg);
        set.row(vec![
            label,
            fmt_secs(out.makespan_s),
            out.comm.gives.to_string(),
            out.comm.rejects.to_string(),
            out.comm.sent.to_string(),
        ]);
    };

    let mut set = BenchSet::new(
        &format!("Ablation — random steal attempts w (P={p}, hapmap-dom-20)"),
        &["w", "time", "gives", "rejects", "msgs"],
    );
    for w in [0usize, 1, 2, 4] {
        run(w.to_string(), &SimConfig { w, ..base.clone() }, &mut set);
    }
    set.finish();

    let mut set = BenchSet::new(
        &format!("Ablation — lifeline hypercube edge length l (P={p})"),
        &["l", "time", "gives", "rejects", "msgs"],
    );
    for l in [2usize, 3, 4] {
        run(l.to_string(), &SimConfig { l, ..base.clone() }, &mut set);
    }
    set.finish();

    let mut set = BenchSet::new(
        &format!("Ablation — probe budget (≈probe interval; paper tunes to 1 ms) (P={p})"),
        &["budget(units)", "time", "gives", "rejects", "msgs"],
    );
    for budget in [250_000u64, 1_000_000, 4_000_000, 16_000_000] {
        run(
            budget.to_string(),
            &SimConfig { probe_budget_units: budget, ..base.clone() },
            &mut set,
        );
    }
    set.finish();

    let mut set = BenchSet::new(
        &format!("Ablation — depth-1 preprocess partition (§4.5) (P={p})"),
        &["preprocess", "time", "gives", "rejects", "msgs"],
    );
    for pre in [true, false] {
        run(pre.to_string(), &SimConfig { preprocess: pre, ..base.clone() }, &mut set);
    }
    set.finish();

    let mut set = BenchSet::new(
        &format!("Ablation — DTD spanning-tree arity (paper: ternary) (P={p})"),
        &["arity", "time", "gives", "rejects", "msgs"],
    );
    for arity in [1usize, 2, 3, 8] {
        run(arity.to_string(), &SimConfig { tree_arity: arity, ..base.clone() }, &mut set);
    }
    set.finish();
}
