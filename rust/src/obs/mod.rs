//! Observability: tracing, structured logging, and metrics export.
//!
//! The paper's central claim — that GLB over a hypercube-with-random-edges
//! "distributes communication evenly" (Figs. 5–7) — is a claim about *when*
//! things happen, not just how much. Totals (`Breakdown`, `CommStats`, the
//! serve `STATS` frame) can detect a regression; only a timeline can explain
//! one. This module provides that timeline plus the logging and metrics
//! plumbing around it (DESIGN.md §14):
//!
//! - [`trace`]: a per-rank fixed-capacity event ring ([`trace::TraceRing`])
//!   behind a process-global static flag. When tracing is off the hot path
//!   pays one relaxed atomic load and a branch — no allocation, no I/O.
//!   Overflow is counted, never silent.
//! - [`clock`]: per-process monotonic clocks ([`clock::now_ns`]) and the
//!   interval-based offset estimator ([`clock::estimate_offset`]) the hub
//!   uses to align worker timelines from HELLO/START handshake timestamps.
//! - [`chrome`]: Chrome/Perfetto trace-event JSON export — one track per
//!   rank, phase spans, instant events, and flow arrows linking each steal
//!   REQUEST to the GIVE that answered it.
//! - [`summary`]: `parlamp trace summary` — per-rank Fig.7 breakdown table,
//!   who-stole-from-whom matrix, DTD wave latencies, recomputed from an
//!   exported trace file.
//! - [`log`]: leveled, target-filtered, rank/fleet/job-tagged structured
//!   logging (`PARLAMP_LOG=level[,target=level]`) with a last-N record ring
//!   dumped on panic so worker deaths leave a post-mortem.
//! - [`prom`]: Prometheus text exposition of [`crate::wire::service::ServiceStats`]
//!   for `parlamp stats --format prom`.

pub mod chrome;
pub mod clock;
pub mod log;
pub mod prom;
pub mod summary;
pub mod trace;
