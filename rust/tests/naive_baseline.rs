//! The naive static-partition baseline (paper §5.4): identical results,
//! predictably worse balance on deep trees — "the naive approach of
//! separating the search space failed completely."

use parlamp::datagen::{generate_gwas, GwasSpec};
use parlamp::lamp::lamp_serial;
use parlamp::par::{breakdown, run_sim, RunMode, SimConfig};

#[test]
fn naive_results_match_glb_and_serial() {
    let (db, _) = generate_gwas(&GwasSpec::small(10));
    let serial = lamp_serial(&db, 0.05);
    for p in [4usize, 12] {
        let glb = SimConfig { p, ..SimConfig::paper_defaults(p) };
        let naive = SimConfig { p, steal: false, ..SimConfig::paper_defaults(p) };
        let a = run_sim(&db, RunMode::Count { min_sup: serial.min_sup }, &glb);
        let b = run_sim(&db, RunMode::Count { min_sup: serial.min_sup }, &naive);
        assert_eq!(a.closed_total, serial.correction_factor, "glb p={p}");
        assert_eq!(b.closed_total, serial.correction_factor, "naive p={p}");
        assert_eq!(b.comm.gives, 0, "naive must not steal");
    }
}

#[test]
fn naive_is_never_faster_and_idles_more() {
    // On an unbalanced tree GLB should beat the static partition, and the
    // naive processes should spend visibly more of the span idle.
    let spec = GwasSpec {
        n_snps: 260,
        n_individuals: 140,
        n_pos: 35,
        ld_copy_prob: 0.45, // correlated blocks → unbalanced subtrees
        planted: vec![(3, 0.8)],
        ..GwasSpec::small(555)
    };
    let (db, _) = generate_gwas(&spec);
    let p = 12;
    // Fine probe/wave cadence so granularity quantization doesn't mask the
    // balance difference on a test-sized tree; min_sup = 2 keeps the tree
    // deep and unbalanced (the regime where the paper's naive run fails).
    let min_sup = 2;
    let base = SimConfig {
        p,
        probe_budget_units: 50_000,
        dtd_interval_ns: 100_000,
        ..SimConfig::paper_defaults(p)
    };
    let glb = base.clone();
    let naive = SimConfig { steal: false, ..base };
    let a = run_sim(&db, RunMode::Count { min_sup }, &glb);
    let b = run_sim(&db, RunMode::Count { min_sup }, &naive);
    assert_eq!(a.closed_total, b.closed_total);
    assert!(
        b.makespan_s >= a.makespan_s * 0.95,
        "naive ({:.6}s) unexpectedly beat GLB ({:.6}s)",
        b.makespan_s,
        a.makespan_s
    );
    let idle_glb = breakdown::sum(&a.breakdowns).idle_ns as f64;
    let idle_naive = breakdown::sum(&b.breakdowns).idle_ns as f64;
    assert!(
        idle_naive >= idle_glb,
        "naive idle {idle_naive} < glb idle {idle_glb} — stealing should reduce idling"
    );
}
