//! Process-backed fabric: one OS process per rank, Unix-domain sockets as
//! the interconnect (DESIGN.md §7).
//!
//! The first fabric backend with real address-space separation: unlike
//! [`super::thread`] and [`super::sim`], nothing can be passed by value, so
//! every protocol message crosses the [`crate::wire`] serialization
//! boundary. Topology is hub-and-spoke: the parent process runs a [`Hub`]
//! that accepts one connection per worker rank and routes `RELAY` frames
//! between them, which keeps the design at `P` sockets instead of the
//! `P(P−1)/2` a full mesh would need (file-descriptor passing between
//! children is not required).
//!
//! The fleet is **warm**: a worker's connection outlives any single phase,
//! so one spawned fleet can serve many phases — and many jobs, which is
//! what `parlamp serve` (DESIGN.md §9) is built on. Lifecycle:
//!
//! 1. the engine ([`crate::par::engine_process`]) binds a hub and spawns
//!    `P` worker processes pointing at its socket; each worker connects and
//!    sends `HELLO { rank }`;
//! 2. per phase, the hub broadcasts `CONFIG` (the [`PhaseSpec`] *plus* the
//!    database) — or `RECONFIG` (the [`PhaseSpec`] alone) when the workers
//!    already hold the right database — and then `START`, the barrier that
//!    guarantees no steal traffic targets a rank that is not in the phase;
//! 3. workers run the ordinary [`crate::par::Worker`] loop against a
//!    [`ProcessMailbox`]; every [`Mailbox::send`] becomes a `RELAY` frame
//!    the hub forwards;
//! 4. on `Finish` each worker sends its `MERGE` (the phase-boundary
//!    histogram/breakdown/counter payload) and returns to
//!    [`ProcessMailbox::await_phase`];
//! 5. the hub collects `P` merges and either opens the next phase (step 2)
//!    or broadcasts `BYE`, upon which the workers exit cleanly.
//!
//! Between phases no fencing is needed: a worker sends nothing after its
//! `MERGE` until its next `START`, so once the hub holds all `P` merges,
//! every late relay of the finished phase has already been forwarded —
//! anything a worker receives *before* its next `CONFIG`/`RECONFIG` is
//! stale and dropped, anything after belongs to the new phase and is
//! buffered until `START`.
//!
//! Failure semantics: a worker that dies mid-run surfaces as a
//! [`HubEvent::Gone`] (socket EOF or error) and the engine aborts the run;
//! a forward to an already-exited worker is silently dropped, mirroring the
//! finished-peer no-op of the thread fabric (MPI-finalize semantics).

use std::collections::VecDeque;
use std::io::Write;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::db::Database;
use crate::wire::{
    encode_config, read_frame, write_frame, Frame, PhaseSpec, RunSpec, WorkerMerge,
    MAX_FRAME_LEN,
};

use super::{Mailbox, Msg};

/// How long the hub waits for a connecting worker's `HELLO` before
/// declaring the peer dead.
pub const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(60);

// ---- worker (child) side ---------------------------------------------------

/// Link status of a worker's hub connection.
#[derive(Clone, Debug, PartialEq, Eq)]
enum Link {
    Open,
    /// Socket error, unexpected EOF, or protocol violation; the run cannot
    /// complete.
    Lost(String),
}

enum ChildEvent {
    Deliver { src: usize, msg: Msg },
    Config(Box<RunSpec>),
    Reconfig(Box<PhaseSpec>),
    Start,
    Bye,
    Lost(String),
}

/// What [`ProcessMailbox::await_phase`] hands the worker: the phase
/// parameters, plus the database when the hub (re-)shipped one (`CONFIG`).
/// `db: None` means "mine the database you already hold" (`RECONFIG`).
pub struct PhaseStart {
    pub phase: PhaseSpec,
    pub db: Option<Database>,
}

/// The worker-process endpoint of the fabric: the [`Mailbox`] the ordinary
/// [`crate::par::Worker`] state machine drives, plus the phase/merge
/// handshake. Obtain one with [`connect`]; drive phases with
/// [`ProcessMailbox::await_phase`].
pub struct ProcessMailbox {
    rank: usize,
    /// World size of the current phase (set by `await_phase`).
    size: usize,
    writer: UnixStream,
    rx: Receiver<ChildEvent>,
    /// Messages pulled in by a blocking wait (or buffered between `CONFIG`
    /// and `START`) but not yet consumed by the worker's probe loop.
    pending: VecDeque<(usize, Msg)>,
    link: Link,
    _reader: JoinHandle<()>,
}

/// Connect to the hub at `path` as `rank`: send `HELLO` and hand the
/// socket to a background reader thread. The worker then blocks in
/// [`ProcessMailbox::await_phase`] until the hub opens a phase — there is
/// deliberately no read timeout here, because a warm worker legitimately
/// idles between jobs for as long as the daemon stays up; a dead hub
/// surfaces as EOF.
pub fn connect(path: &Path, rank: usize) -> Result<ProcessMailbox> {
    let mut stream = UnixStream::connect(path)
        .with_context(|| format!("connect to fabric hub at {}", path.display()))?;
    write_frame(&mut stream, &Frame::Hello { rank: rank as u32 }).context("send HELLO")?;
    let reader_stream = stream.try_clone().context("clone fabric socket")?;
    let (tx, rx) = channel();
    let reader = std::thread::spawn(move || reader_loop(reader_stream, tx));
    Ok(ProcessMailbox {
        rank,
        size: 0,
        writer: stream,
        rx,
        pending: VecDeque::new(),
        link: Link::Open,
        _reader: reader,
    })
}

fn reader_loop(mut stream: UnixStream, tx: Sender<ChildEvent>) {
    loop {
        let ev = match read_frame(&mut stream) {
            Ok(Some(Frame::Relay { peer, msg })) => ChildEvent::Deliver { src: peer as usize, msg },
            Ok(Some(Frame::Config(spec))) => ChildEvent::Config(spec),
            Ok(Some(Frame::Reconfig(phase))) => ChildEvent::Reconfig(phase),
            Ok(Some(Frame::Start)) => ChildEvent::Start,
            Ok(Some(Frame::Bye)) => {
                let _ = tx.send(ChildEvent::Bye);
                return;
            }
            Ok(Some(other)) => {
                let _ = tx.send(ChildEvent::Lost(format!(
                    "unexpected {} frame from hub",
                    other.name()
                )));
                return;
            }
            Ok(None) => {
                let _ = tx.send(ChildEvent::Lost("hub closed the connection".into()));
                return;
            }
            Err(e) => {
                let _ = tx.send(ChildEvent::Lost(format!("{e:#}")));
                return;
            }
        };
        if tx.send(ev).is_err() {
            return; // mailbox dropped
        }
    }
}

impl ProcessMailbox {
    /// Block until the hub opens the next phase (`CONFIG`/`RECONFIG`
    /// followed by `START`) or dismisses the fleet (`BYE` → `None`).
    ///
    /// Stale deliveries from the finished phase — late relays the hub
    /// forwarded before it had collected every merge — arrive strictly
    /// before the phase frame and are dropped; deliveries between the
    /// phase frame and `START` belong to the new phase (a peer that
    /// started earlier may already be stealing) and are buffered.
    pub fn await_phase(&mut self) -> Result<Option<PhaseStart>> {
        if let Link::Lost(e) = &self.link {
            bail!("fabric link lost: {e}");
        }
        self.pending.clear();
        // 1. The phase frame (dropping stale traffic).
        let start = loop {
            match self.recv_event()? {
                ChildEvent::Config(spec) => {
                    let RunSpec { phase, db } = *spec;
                    break PhaseStart { phase, db: Some(db) };
                }
                ChildEvent::Reconfig(phase) => break PhaseStart { phase: *phase, db: None },
                ChildEvent::Deliver { .. } => continue, // stale: previous phase
                ChildEvent::Bye => return Ok(None),
                ChildEvent::Start => bail!("START from hub before CONFIG"),
                ChildEvent::Lost(e) => {
                    self.link = Link::Lost(e.clone());
                    bail!("fabric link lost awaiting phase: {e}");
                }
            }
        };
        ensure!(
            (self.rank as u32) < start.phase.p,
            "rank {} out of range for world size {}",
            self.rank,
            start.phase.p
        );
        self.size = start.phase.p as usize;
        // 2. The START barrier (buffering early next-phase traffic).
        loop {
            match self.recv_event()? {
                ChildEvent::Start => break,
                ChildEvent::Deliver { src, msg } => self.pending.push_back((src, msg)),
                ChildEvent::Bye => bail!("BYE from hub between CONFIG and START"),
                ChildEvent::Config(_) | ChildEvent::Reconfig(_) => {
                    bail!("duplicate CONFIG from hub before START")
                }
                ChildEvent::Lost(e) => {
                    self.link = Link::Lost(e.clone());
                    bail!("fabric link lost awaiting START: {e}");
                }
            }
        }
        Ok(Some(start))
    }

    fn recv_event(&mut self) -> Result<ChildEvent> {
        self.rx.recv().map_err(|_| anyhow::anyhow!("fabric reader thread exited"))
    }

    /// Absorb an event mid-phase, when only deliveries are legitimate.
    fn absorb(&mut self, ev: ChildEvent) -> Option<(usize, Msg)> {
        match ev {
            ChildEvent::Deliver { src, msg } => Some((src, msg)),
            ChildEvent::Config(_) | ChildEvent::Reconfig(_) | ChildEvent::Start
            | ChildEvent::Bye => {
                if self.link == Link::Open {
                    self.link = Link::Lost("phase frame from hub mid-phase".into());
                }
                None
            }
            ChildEvent::Lost(e) => {
                if self.link == Link::Open {
                    self.link = Link::Lost(e);
                }
                None
            }
        }
    }

    /// The error that severed the hub link, if any. The worker loop checks
    /// this each quantum and aborts the run — without a hub there is no
    /// termination detection, so spinning would hang forever.
    pub fn lost(&self) -> Option<&str> {
        match &self.link {
            Link::Lost(e) => Some(e),
            Link::Open => None,
        }
    }

    /// Block until a message arrives (buffered for the next `try_recv`) or
    /// the timeout elapses — used by idle workers so they wake on incoming
    /// GIVEs without spinning. Returns whether a message arrived.
    pub fn wait_for_msg(&mut self, d: Duration) -> bool {
        if !self.pending.is_empty() {
            return true;
        }
        match self.rx.recv_timeout(d) {
            Ok(ev) => match self.absorb(ev) {
                Some(m) => {
                    self.pending.push_back(m);
                    true
                }
                None => false,
            },
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => false,
        }
    }

    /// Send the phase-boundary merge after the worker saw `Finish`. The
    /// worker must send nothing else until its next phase starts — the
    /// between-phase protocol relies on `MERGE` being the last frame of a
    /// phase (see the module docs).
    pub fn send_merge(&mut self, merge: &WorkerMerge) -> Result<()> {
        write_frame(&mut self.writer, &Frame::Merge(Box::new(merge.clone())))
            .context("send MERGE to hub")
    }
}

impl Mailbox for ProcessMailbox {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.size
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        if self.link != Link::Open {
            return; // shutdown race: mirror the dropped-peer no-op
        }
        let frame = Frame::Relay { peer: dst as u32, msg };
        if let Err(e) = write_frame(&mut self.writer, &frame) {
            self.link = Link::Lost(format!("send to hub failed: {e}"));
        }
    }

    fn try_recv(&mut self) -> Option<(usize, Msg)> {
        if let Some(m) = self.pending.pop_front() {
            return Some(m);
        }
        while let Ok(ev) = self.rx.try_recv() {
            if let Some(m) = self.absorb(ev) {
                return Some(m);
            }
            if self.link != Link::Open {
                return None;
            }
        }
        None
    }
}

// ---- hub (parent) side -----------------------------------------------------

/// What the hub reports to the engine while a phase runs.
#[derive(Debug)]
pub enum HubEvent {
    /// A worker delivered its phase-boundary merge.
    Merge(WorkerMerge),
    /// A worker's connection ended — orderly EOF after the `BYE`, or a
    /// crash/protocol violation. Any `Gone` surfacing while a phase's
    /// merges are being collected fails that phase (a warm fleet with a
    /// missing rank cannot serve further phases either — the owner drops
    /// and respawns it); orderly post-`BYE` EOFs arrive only after the
    /// engine has stopped listening.
    Gone { rank: usize, detail: String },
}

/// Per-rank write halves, shared between the hub and its route threads.
type Writers = Arc<Vec<Mutex<Option<UnixStream>>>>;

/// Parent-side fabric endpoint: accepts worker connections, runs one route
/// thread per worker, opens phases, and surfaces merges. Owned and driven
/// by [`crate::par::engine_process::ProcessFleet`].
pub struct Hub {
    listener: UnixListener,
    p: usize,
    writers: Writers,
    events_tx: Sender<HubEvent>,
    events_rx: Receiver<HubEvent>,
    routers: Vec<JoinHandle<()>>,
    connected: usize,
}

impl Hub {
    /// Bind the hub socket for a world of `p` ranks.
    pub fn bind(path: &Path, p: usize) -> Result<Hub> {
        ensure!(p >= 1, "world size must be ≥ 1");
        let listener = UnixListener::bind(path)
            .with_context(|| format!("bind fabric hub socket {}", path.display()))?;
        listener.set_nonblocking(true).context("set hub listener non-blocking")?;
        let (events_tx, events_rx) = channel();
        Ok(Hub {
            listener,
            p,
            writers: Arc::new((0..p).map(|_| Mutex::new(None)).collect()),
            events_tx,
            events_rx,
            routers: Vec::with_capacity(p),
            connected: 0,
        })
    }

    /// Ranks that have completed the `HELLO` handshake so far.
    pub fn connected(&self) -> usize {
        self.connected
    }

    /// Accept and handshake at most one pending worker connection. Returns
    /// whether one was accepted. Non-blocking: the engine interleaves this
    /// with liveness checks on the spawned processes.
    pub fn try_accept(&mut self) -> Result<bool> {
        let (mut stream, _) = match self.listener.accept() {
            Ok(conn) => conn,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(false),
            Err(e) => return Err(e).context("accept worker connection"),
        };
        stream.set_nonblocking(false).context("set worker socket blocking")?;
        stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
        let frame = read_frame(&mut stream)?.context("worker closed during handshake")?;
        let rank = match frame {
            Frame::Hello { rank } => rank as usize,
            other => bail!("expected HELLO from worker, got {}", other.name()),
        };
        ensure!(rank < self.p, "HELLO rank {rank} out of range for world size {}", self.p);
        stream.set_read_timeout(None)?;
        let reader = stream.try_clone().context("clone worker socket")?;
        {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            ensure!(slot.is_none(), "duplicate HELLO for rank {rank}");
            *slot = Some(stream);
        }
        let writers = Arc::clone(&self.writers);
        let tx = self.events_tx.clone();
        let p = self.p;
        self.routers.push(std::thread::spawn(move || route_loop(rank, reader, writers, tx, p)));
        self.connected += 1;
        Ok(true)
    }

    /// Write pre-encoded frame bytes to every registered rank.
    fn broadcast_bytes(&mut self, bytes: &[u8], what: &str) -> Result<()> {
        ensure!(
            self.connected == self.p,
            "cannot {what}: {}/{} workers connected",
            self.connected,
            self.p
        );
        for rank in 0..self.p {
            let mut slot = self.writers[rank].lock().expect("writer lock");
            let w = slot
                .as_mut()
                .with_context(|| format!("rank {rank} disconnected before {what}"))?;
            w.write_all(bytes).with_context(|| format!("{what} to rank {rank}"))?;
        }
        Ok(())
    }

    /// Open a phase by shipping the full run specification — phase
    /// parameters *plus* database — to every rank. Use
    /// [`Hub::broadcast_reconfig`] instead when the workers already hold
    /// the database (the warm-fleet fast path).
    pub fn broadcast_config(&mut self, spec: &RunSpec) -> Result<()> {
        let bytes = encode_config(spec);
        ensure!(
            bytes.len() - 4 <= MAX_FRAME_LEN as usize,
            "CONFIG frame ({} bytes) exceeds the {MAX_FRAME_LEN}-byte frame cap; \
             the database is too large for the process fabric's wire format",
            bytes.len() - 4
        );
        self.broadcast_bytes(&bytes, "send CONFIG")
    }

    /// Open a phase over the database the workers already hold: ships the
    /// phase parameters only (a ~60-byte frame instead of the serialized
    /// database).
    pub fn broadcast_reconfig(&mut self, phase: &PhaseSpec) -> Result<()> {
        let bytes = Frame::Reconfig(Box::new(phase.clone())).encode();
        self.broadcast_bytes(&bytes, "send RECONFIG")
    }

    /// Release the phase barrier: broadcast `START`. Workers begin the
    /// phase on receipt. Call only after [`Hub::broadcast_config`] /
    /// [`Hub::broadcast_reconfig`] for this phase.
    pub fn start_all(&mut self) -> Result<()> {
        let bytes = Frame::Start.encode();
        self.broadcast_bytes(&bytes, "send START")
    }

    /// Wait up to `timeout` for the next hub event. `Ok(None)` = timeout.
    pub fn recv_event(&self, timeout: Duration) -> Result<Option<HubEvent>> {
        match self.events_rx.recv_timeout(timeout) {
            Ok(ev) => Ok(Some(ev)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // All route threads gone without the engine collecting P merges.
            Err(RecvTimeoutError::Disconnected) => bail!("all fabric route threads exited"),
        }
    }

    /// Broadcast `BYE`: no further phases; the fleet exits. Send errors are
    /// ignored: a worker that already exited has nothing left to
    /// acknowledge.
    pub fn broadcast_bye(&mut self) {
        let bytes = Frame::Bye.encode();
        for slot in self.writers.iter() {
            if let Some(w) = slot.lock().expect("writer lock").as_mut() {
                let _ = w.write_all(&bytes);
            }
        }
    }

    /// Join the route threads (they exit at worker-socket EOF). Call after
    /// [`Hub::broadcast_bye`] and after the worker processes were reaped —
    /// never while workers may still be running.
    pub fn join(&mut self) {
        for h in self.routers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Per-worker route thread: forward `RELAY` frames to their destination
/// rank (stamping the source), surface `MERGE` and disconnection. Lives for
/// the whole fleet lifetime, spanning phases.
fn route_loop(
    rank: usize,
    mut reader: UnixStream,
    writers: Writers,
    tx: Sender<HubEvent>,
    p: usize,
) {
    let gone = |detail: String| {
        let _ = tx.send(HubEvent::Gone { rank, detail });
    };
    loop {
        match read_frame(&mut reader) {
            Ok(Some(Frame::Relay { peer, msg })) => {
                let dst = peer as usize;
                if dst >= p {
                    gone(format!("relayed to out-of-range rank {dst}"));
                    return;
                }
                let frame = Frame::Relay { peer: rank as u32, msg };
                let mut slot = writers[dst].lock().expect("writer lock");
                if let Some(w) = slot.as_mut() {
                    // A failed forward means the destination already exited;
                    // drop it like the thread fabric drops sends to a
                    // finished peer.
                    let _ = write_frame(w, &frame);
                }
            }
            Ok(Some(Frame::Merge(m))) => {
                if m.rank as usize != rank {
                    gone(format!("MERGE claims rank {} on rank {rank}'s connection", m.rank));
                    return;
                }
                if tx.send(HubEvent::Merge(*m)).is_err() {
                    return; // engine gone
                }
                // Keep reading: the next phase's relays and merge arrive on
                // this same connection.
            }
            Ok(Some(other)) => {
                gone(format!("unexpected {} frame", other.name()));
                return;
            }
            Ok(None) => {
                gone("EOF".into());
                return;
            }
            Err(e) => {
                gone(format!("{e:#}"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::Database;
    use crate::fabric::BasicKind;
    use crate::par::worker::RunMode;

    fn tiny_phase(p: u32, seed: u64) -> PhaseSpec {
        PhaseSpec {
            p,
            seed,
            w: 1,
            l: 2,
            tree_arity: 3,
            steal: true,
            preprocess: false,
            probe_budget_units: 1000,
            dtd_interval_ns: 1000,
            mode: RunMode::Count { min_sup: 1 },
        }
    }

    fn tiny_spec(p: u32) -> RunSpec {
        let trans = vec![vec![0, 1], vec![1]];
        let db = Database::from_transactions(2, &trans, &[true, false]);
        RunSpec { phase: tiny_phase(p, 1), db }
    }

    fn test_sock(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("parlamp-fabtest-{}-{tag}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("hub.sock")
    }

    fn merge_for(rank: u32) -> WorkerMerge {
        WorkerMerge {
            rank,
            hist: vec![(1, 2)],
            closed_count: 2,
            work_units: 10,
            breakdown: Default::default(),
            comm: Default::default(),
            makespan_ns: 5,
        }
    }

    /// Drive `try_accept` until all `want` workers have registered.
    fn accept_all(hub: &mut Hub, want: usize) {
        let deadline = Instant::now() + Duration::from_secs(10);
        while hub.connected() < want {
            if !hub.try_accept().unwrap() {
                assert!(Instant::now() < deadline, "workers never connected");
                std::thread::sleep(Duration::from_millis(1));
            }
        }
    }

    fn collect_merges(hub: &Hub, want: usize) {
        let mut got = 0;
        while got < want {
            match hub.recv_event(Duration::from_secs(10)).unwrap() {
                Some(HubEvent::Merge(_)) => got += 1,
                Some(HubEvent::Gone { rank, detail }) => {
                    panic!("rank {rank} gone before merge: {detail}")
                }
                None => panic!("timed out waiting for merges"),
            }
        }
    }

    /// Two in-process "workers" on real sockets, across TWO phases on the
    /// same warm connections: phase 1 opens with `CONFIG` (database
    /// shipped), phase 2 with `RECONFIG` (database reused). Messages are
    /// routed both ways in each phase; `BYE` ends the loop.
    #[test]
    fn warm_hub_runs_two_phases_reusing_the_database() {
        let sock = test_sock("route");
        let mut hub = Hub::bind(&sock, 2).unwrap();

        let spawn_worker = |rank: usize, sock: std::path::PathBuf| {
            std::thread::spawn(move || -> Result<()> {
                let mut mb = connect(&sock, rank)?;
                let mut phases = 0u32;
                while let Some(start) = mb.await_phase()? {
                    assert_eq!(start.phase.p, 2);
                    assert_eq!(mb.rank(), rank);
                    assert_eq!(mb.size(), 2);
                    match phases {
                        0 => assert!(start.db.is_some(), "first phase must ship the db"),
                        _ => assert!(start.db.is_none(), "reconfig must not re-ship the db"),
                    }
                    assert_eq!(start.phase.seed, u64::from(phases) + 1);
                    let peer = 1 - rank;
                    mb.send(peer, Msg::WaveDown { t: rank as u64, lambda: 7 + phases });
                    // await the peer's message
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        assert!(Instant::now() < deadline, "no message from peer");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    assert_eq!(got.0, peer, "source must be stamped by the hub");
                    assert!(
                        matches!(got.1, Msg::WaveDown { lambda, .. } if lambda == 7 + phases)
                    );
                    mb.send_merge(&merge_for(rank as u32))?;
                    phases += 1;
                }
                assert_eq!(phases, 2, "worker must have served both phases");
                Ok(())
            })
        };
        let w0 = spawn_worker(0, sock.clone());
        let w1 = spawn_worker(1, sock.clone());

        accept_all(&mut hub, 2);
        // Phase 1: full CONFIG.
        hub.broadcast_config(&tiny_spec(2)).unwrap();
        hub.start_all().unwrap();
        collect_merges(&hub, 2);
        // Phase 2: RECONFIG over the resident database.
        hub.broadcast_reconfig(&tiny_phase(2, 2)).unwrap();
        hub.start_all().unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        w1.join().unwrap().unwrap();
        hub.join();
    }

    /// GIVE payloads (serialized SearchNodes) survive the hub round trip.
    #[test]
    fn give_tasks_roundtrip_through_hub() {
        let sock = test_sock("give");
        let mut hub = Hub::bind(&sock, 2).unwrap();
        let tasks = vec![crate::fabric::WireTask { items: vec![3, 9], core: 9, support: 4 }];
        let sent = tasks.clone();
        let w0 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<()> {
                let mut mb = connect(&sock, 0)?;
                while let Some(_start) = mb.await_phase()? {
                    mb.send(
                        1,
                        Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks: tasks.clone() } },
                    );
                    mb.send_merge(&merge_for(0))?;
                }
                Ok(())
            }
        });
        let w1 = std::thread::spawn({
            let sock = sock.clone();
            move || -> Result<(usize, Msg)> {
                let mut mb = connect(&sock, 1)?;
                let mut got_msg = None;
                while let Some(_start) = mb.await_phase()? {
                    let deadline = Instant::now() + Duration::from_secs(10);
                    let got = loop {
                        if let Some(got) = mb.try_recv() {
                            break got;
                        }
                        ensure!(Instant::now() < deadline, "no GIVE arrived");
                        mb.wait_for_msg(Duration::from_millis(10));
                    };
                    got_msg = Some(got);
                    mb.send_merge(&merge_for(1))?;
                }
                got_msg.context("no phase ran")
            }
        });
        accept_all(&mut hub, 2);
        hub.broadcast_config(&tiny_spec(2)).unwrap();
        hub.start_all().unwrap();
        collect_merges(&hub, 2);
        hub.broadcast_bye();
        w0.join().unwrap().unwrap();
        let (src, msg) = w1.join().unwrap().unwrap();
        assert_eq!(src, 0);
        match msg {
            Msg::Basic { stamp: 3, kind: BasicKind::Give { tasks } } => {
                assert_eq!(tasks, sent);
            }
            other => panic!("expected GIVE, got {other:?}"),
        }
        hub.join();
    }

    /// Drive `try_accept` until it yields a definite accept/reject outcome.
    fn accept_outcome(hub: &mut Hub) -> Result<bool> {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match hub.try_accept() {
                Ok(false) => {
                    assert!(Instant::now() < deadline, "no pending connection");
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => return other,
            }
        }
    }

    #[test]
    fn hub_rejects_out_of_range_and_duplicate_ranks() {
        let sock = test_sock("badrank");
        let mut hub = Hub::bind(&sock, 2).unwrap();
        // out-of-range rank
        let mut s = UnixStream::connect(&sock).unwrap();
        write_frame(&mut s, &Frame::Hello { rank: 9 }).unwrap();
        let err = accept_outcome(&mut hub).expect_err("rank 9 must be rejected");
        assert!(format!("{err:#}").contains("out of range"), "{err:#}");
        // duplicate rank: first registration succeeds, second errors
        let mut a = UnixStream::connect(&sock).unwrap();
        write_frame(&mut a, &Frame::Hello { rank: 0 }).unwrap();
        assert!(accept_outcome(&mut hub).unwrap());
        let mut b = UnixStream::connect(&sock).unwrap();
        write_frame(&mut b, &Frame::Hello { rank: 0 }).unwrap();
        let err = accept_outcome(&mut hub).expect_err("duplicate rank must be rejected");
        assert!(format!("{err:#}").contains("duplicate"), "{err:#}");
        assert_eq!(hub.connected(), 1);
        // a phase broadcast with a missing rank fails loudly
        let err = hub.broadcast_config(&tiny_spec(2)).expect_err("incomplete fleet");
        assert!(format!("{err:#}").contains("1/2"), "{err:#}");
    }
}
