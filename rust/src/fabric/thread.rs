//! Thread-backed fabric: one OS thread per process, mpsc channels as the
//! interconnect. Communication is "replaced with a memory copy" exactly as
//! the paper describes for its single-node MPI runs (§5.3).

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::{Mailbox, Msg};

/// One process's endpoint of the thread fabric.
pub struct ThreadMailbox {
    rank: usize,
    peers: Vec<Sender<(usize, Msg)>>,
    inbox: Receiver<(usize, Msg)>,
    /// Messages pulled in by a blocking wait but not yet consumed by the
    /// worker's probe loop.
    pending: VecDeque<(usize, Msg)>,
}

impl ThreadMailbox {
    /// Block until a message arrives (buffered for the next `try_recv`) or
    /// the timeout elapses — used by idle workers so they wake on incoming
    /// GIVEs without spinning. Returns whether a message arrived.
    pub fn wait_for_msg(&mut self, d: Duration) -> bool {
        if !self.pending.is_empty() {
            return true;
        }
        match self.inbox.recv_timeout(d) {
            Ok(m) => {
                self.pending.push_back(m);
                true
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => false,
        }
    }
}

impl Mailbox for ThreadMailbox {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.peers.len()
    }

    fn send(&mut self, dst: usize, msg: Msg) {
        // A send to a finished (dropped) peer is a no-op, mirroring MPI
        // finalize semantics during shutdown.
        let _ = self.peers[dst].send((self.rank, msg));
    }

    fn try_recv(&mut self) -> Option<(usize, Msg)> {
        if let Some(m) = self.pending.pop_front() {
            return Some(m);
        }
        self.inbox.try_recv().ok()
    }
}

/// Build a fully-connected fabric of `p` endpoints.
pub fn thread_fabric(p: usize) -> Vec<ThreadMailbox> {
    let mut senders = Vec::with_capacity(p);
    let mut receivers = Vec::with_capacity(p);
    for _ in 0..p {
        let (tx, rx) = channel();
        senders.push(tx);
        receivers.push(rx);
    }
    receivers
        .into_iter()
        .enumerate()
        .map(|(rank, inbox)| ThreadMailbox {
            rank,
            peers: senders.clone(),
            inbox,
            pending: VecDeque::new(),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::BasicKind;

    #[test]
    fn point_to_point_delivery() {
        let mut boxes = thread_fabric(3);
        let mut b2 = boxes.pop().unwrap();
        let b1 = boxes.pop().unwrap();
        let mut b0 = boxes.pop().unwrap();
        assert_eq!(b0.rank(), 0);
        assert_eq!(b1.rank(), 1);
        assert_eq!(b0.size(), 3);
        b0.send(2, Msg::Finish);
        b0.send(2, Msg::Basic { stamp: 7, kind: BasicKind::Reject { lifeline: false } });
        let (src, m) = b2.try_recv().unwrap();
        assert_eq!((src, m), (0, Msg::Finish));
        let (src, m) = b2.try_recv().unwrap();
        assert_eq!(src, 0);
        assert!(m.is_basic());
        assert!(b2.try_recv().is_none());
    }

    #[test]
    fn cross_thread_delivery() {
        let mut boxes = thread_fabric(2);
        let mut b1 = boxes.pop().unwrap();
        let mut b0 = boxes.pop().unwrap();
        let h = std::thread::spawn(move || {
            let arrived = b1.wait_for_msg(Duration::from_secs(5));
            (arrived, b1.try_recv())
        });
        b0.send(1, Msg::PreDown { lambda: 3 });
        let (arrived, got) = h.join().unwrap();
        assert!(arrived);
        assert_eq!(got, Some((0, Msg::PreDown { lambda: 3 })));
    }
}
