//! Mattern's time algorithm over the spanning tree (paper §4.3).
//!
//! Each process keeps a wave clock, a cumulative *basic*-message deficit
//! (`sends − receives`), and the maximum time-stamp among basic messages
//! received since its last wave visit. A wave `t` sweeps down the tree
//! (visiting = taking the process's cut) and aggregates up. The root
//! declares termination iff the wave reports
//!
//! 1. total deficit zero (no messages in flight across the cut),
//! 2. no process received a message stamped ≥ `t` before its visit (the
//!    cut is consistent — no "future" message crossed into the "past"),
//! 3. every process voted idle at its visit.
//!
//! The same waves carry the closed-set histogram up and λ down (§4.4).
//!
//! Clocks here are `u64`; Mattern's bounded-counter refinement (needed for
//! fixed-width clocks on long-lived systems) is unnecessary at one wave
//! per millisecond per run, but the stamp comparison logic is written so
//! wrapping arithmetic could be substituted without structural change.

use crate::fabric::{HistDelta, Msg};

use super::tree::SpanningTree;

/// Result of wave progress at the root.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WaveOutcome {
    /// Nothing to report (wave still in flight, or not the root).
    Pending,
    /// A wave completed at the root with the aggregated observations.
    Complete { count: i64, invalid: bool, all_idle: bool, hist: HistDelta },
}

/// Per-process DTD state machine. Owns no I/O: methods append outgoing
/// messages to `out` and the caller's fabric delivers them.
#[derive(Clone, Debug)]
pub struct DtdNode {
    tree: SpanningTree,
    /// Wave clock: the highest wave this process has been visited by.
    clock: u64,
    /// Cumulative basic-message deficit.
    count: i64,
    /// Max stamp among basic messages received since the last visit.
    max_recv_stamp: Option<u64>,
    /// Wave currently aggregating (only valid while `pending > 0`).
    wave_t: u64,
    /// Children yet to report for `wave_t`.
    pending: usize,
    agg_count: i64,
    agg_invalid: bool,
    agg_idle: bool,
    agg_hist: HistDelta,
}

impl DtdNode {
    pub fn new(tree: SpanningTree) -> Self {
        DtdNode {
            tree,
            clock: 0,
            count: 0,
            max_recv_stamp: None,
            wave_t: 0,
            pending: 0,
            agg_count: 0,
            agg_invalid: false,
            agg_idle: true,
            agg_hist: Vec::new(),
        }
    }

    pub fn tree(&self) -> &SpanningTree {
        &self.tree
    }

    pub fn clock(&self) -> u64 {
        self.clock
    }

    pub fn deficit(&self) -> i64 {
        self.count
    }

    /// Record an outgoing basic message; returns the stamp to attach.
    #[inline]
    pub fn on_basic_sent(&mut self) -> u64 {
        self.count += 1;
        self.clock
    }

    /// Record an incoming basic message carrying `stamp`.
    #[inline]
    pub fn on_basic_recv(&mut self, stamp: u64) {
        self.count -= 1;
        self.max_recv_stamp = Some(self.max_recv_stamp.map_or(stamp, |m| m.max(stamp)));
    }

    /// Take this process's cut for wave `t`: snapshot (deficit, invalid,
    /// idle, hist) and reset the stamp tracker.
    fn visit(&mut self, t: u64, idle: bool, hist: HistDelta) {
        debug_assert!(t > self.clock || (t == self.clock && self.tree.is_root() && t == 0));
        // A message stamped ≥ t received before this visit crossed the cut
        // backwards: the wave is invalid.
        let invalid = self.max_recv_stamp.is_some_and(|s| s >= t);
        self.max_recv_stamp = None;
        self.clock = t;
        self.wave_t = t;
        self.pending = self.tree.children().len();
        self.agg_count = self.count;
        self.agg_invalid = invalid;
        self.agg_idle = idle;
        self.agg_hist = hist;
    }

    /// Send the aggregated report to the parent (participants only).
    fn report_up(&mut self, out: &mut Vec<(usize, Msg)>) {
        let parent = self.tree.parent().expect("root never reports up");
        out.push((
            parent,
            Msg::WaveUp {
                t: self.wave_t,
                count: self.agg_count,
                invalid: self.agg_invalid,
                all_idle: self.agg_idle,
                hist: std::mem::take(&mut self.agg_hist),
            },
        ));
    }

    /// Root: start wave `clock + 1`, broadcasting λ down the tree.
    /// `idle`/`hist` are the root's own cut. Returns `Complete` immediately
    /// when the tree is a single node.
    pub fn initiate_wave(
        &mut self,
        lambda: u32,
        idle: bool,
        hist: HistDelta,
        out: &mut Vec<(usize, Msg)>,
    ) -> WaveOutcome {
        assert!(self.tree.is_root(), "only rank 0 initiates waves");
        assert_eq!(self.pending, 0, "previous wave still aggregating");
        let t = self.clock + 1;
        self.visit(t, idle, hist);
        for c in self.tree.children() {
            out.push((c, Msg::WaveDown { t, lambda }));
        }
        if self.pending == 0 {
            return self.complete();
        }
        WaveOutcome::Pending
    }

    /// Participant: a wave arrived from the parent. `idle`/`hist` are this
    /// process's cut; the caller passes the λ along in its own forwarding
    /// (we re-emit `WaveDown` for children here).
    pub fn on_wave_down(
        &mut self,
        t: u64,
        lambda: u32,
        idle: bool,
        hist: HistDelta,
        out: &mut Vec<(usize, Msg)>,
    ) {
        assert!(!self.tree.is_root(), "root receives no WaveDown");
        assert!(t == self.clock + 1, "waves must be sequential: t={t} clock={}", self.clock);
        self.visit(t, idle, hist);
        for c in self.tree.children() {
            out.push((c, Msg::WaveDown { t, lambda }));
        }
        if self.pending == 0 {
            self.report_up(out);
        }
    }

    /// A child's aggregated report arrived.
    pub fn on_wave_up(
        &mut self,
        t: u64,
        count: i64,
        invalid: bool,
        all_idle: bool,
        hist: HistDelta,
        out: &mut Vec<(usize, Msg)>,
    ) -> WaveOutcome {
        assert_eq!(t, self.wave_t, "stale wave report");
        assert!(self.pending > 0);
        self.pending -= 1;
        self.agg_count += count;
        self.agg_invalid |= invalid;
        self.agg_idle &= all_idle;
        merge_hist(&mut self.agg_hist, &hist);
        if self.pending == 0 {
            if self.tree.is_root() {
                return self.complete();
            }
            self.report_up(out);
        }
        WaveOutcome::Pending
    }

    fn complete(&mut self) -> WaveOutcome {
        WaveOutcome::Complete {
            count: self.agg_count,
            invalid: self.agg_invalid,
            all_idle: self.agg_idle,
            hist: std::mem::take(&mut self.agg_hist),
        }
    }
}

/// Merge sparse histogram deltas (sorted-by-support not required).
pub fn merge_hist(into: &mut HistDelta, from: &[(u32, u64)]) {
    for &(s, c) in from {
        if let Some(e) = into.iter_mut().find(|(s2, _)| *s2 == s) {
            e.1 += c;
        } else {
            into.push((s, c));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive a full wave over `size` processes by hand, with the given
    /// per-process (deficit, idle) and no in-flight stamps.
    fn run_wave(size: usize, deficits: &[i64], idles: &[bool]) -> WaveOutcome {
        let mut nodes: Vec<DtdNode> = (0..size)
            .map(|r| DtdNode::new(SpanningTree::ternary(r, size)))
            .collect();
        for (n, &d) in nodes.iter_mut().zip(deficits) {
            // fabricate the deficit via sends/recvs
            if d >= 0 {
                for _ in 0..d {
                    n.on_basic_sent();
                }
            } else {
                for _ in 0..-d {
                    n.on_basic_recv(0);
                }
            }
            n.max_recv_stamp = None; // ignore fabricated stamps here
        }
        let mut msgs: Vec<(usize, usize, Msg)> = Vec::new(); // (src, dst, msg)
        let mut out = Vec::new();
        let outcome = nodes[0].initiate_wave(1, idles[0], vec![(5, 1)], &mut out);
        if outcome != WaveOutcome::Pending {
            return outcome;
        }
        for (dst, m) in out.drain(..) {
            msgs.push((0, dst, m));
        }
        // deliver until quiescent
        while let Some((src, dst, m)) = msgs.pop() {
            let mut out = Vec::new();
            let outcome = match m {
                Msg::WaveDown { t, lambda } => {
                    nodes[dst].on_wave_down(t, lambda, idles[dst], vec![(5, 1)], &mut out);
                    WaveOutcome::Pending
                }
                Msg::WaveUp { t, count, invalid, all_idle, hist } => {
                    nodes[dst].on_wave_up(t, count, invalid, all_idle, hist, &mut out)
                }
                _ => unreachable!(),
            };
            if let WaveOutcome::Complete { .. } = outcome {
                return outcome;
            }
            for (d2, m2) in out {
                msgs.push((dst, d2, m2));
            }
            let _ = src;
        }
        panic!("wave never completed");
    }

    #[test]
    fn zero_deficit_all_idle_completes_clean() {
        for size in [1usize, 2, 3, 7, 13, 40] {
            let out = run_wave(size, &vec![0; size], &vec![true; size]);
            match out {
                WaveOutcome::Complete { count, invalid, all_idle, hist } => {
                    assert_eq!(count, 0);
                    assert!(!invalid);
                    assert!(all_idle);
                    // every process contributed (5,1)
                    assert_eq!(hist, vec![(5, size as u64)]);
                }
                _ => panic!("expected completion"),
            }
        }
    }

    #[test]
    fn nonzero_deficit_detected() {
        let mut deficits = vec![0i64; 9];
        deficits[4] = 2;
        deficits[7] = -1;
        let out = run_wave(9, &deficits, &[true; 9]);
        match out {
            WaveOutcome::Complete { count, .. } => assert_eq!(count, 1),
            _ => panic!(),
        }
    }

    #[test]
    fn busy_process_blocks_idle_vote() {
        let mut idles = vec![true; 5];
        idles[3] = false;
        let out = run_wave(5, &[0; 5], &idles);
        match out {
            WaveOutcome::Complete { all_idle, .. } => assert!(!all_idle),
            _ => panic!(),
        }
    }

    #[test]
    fn future_stamped_message_invalidates_cut() {
        // Process 1 receives a message stamped at wave 1 *before* being
        // visited by wave 1 → the wave must be invalid.
        let mut n0 = DtdNode::new(SpanningTree::ternary(0, 2));
        let mut n1 = DtdNode::new(SpanningTree::ternary(1, 2));
        // n0 is visited first (root initiates), then sends a basic message
        // stamped with its new clock (1); n1 receives it pre-visit.
        let mut out = Vec::new();
        let oc = n0.initiate_wave(1, true, vec![], &mut out);
        assert_eq!(oc, WaveOutcome::Pending);
        let stamp = n0.on_basic_sent();
        assert_eq!(stamp, 1);
        n1.on_basic_recv(stamp);
        // now wave reaches n1
        let (_, down) = out.pop().unwrap();
        let (t, lambda) = match down {
            Msg::WaveDown { t, lambda } => (t, lambda),
            _ => panic!(),
        };
        let mut up = Vec::new();
        n1.on_wave_down(t, lambda, true, vec![], &mut up);
        let (_, upmsg) = up.pop().unwrap();
        match upmsg {
            Msg::WaveUp { count, invalid, .. } => {
                // deficits: n0 +1, n1 −1 sum to 0 — only the invalid flag
                // saves us from a false termination.
                assert!(invalid, "future-stamped message must invalidate");
                let oc = n0.on_wave_up(t, count, invalid, true, vec![], &mut Vec::new());
                match oc {
                    WaveOutcome::Complete { count, invalid, .. } => {
                        // n0's send happened *after* its wave-1 cut, so the
                        // wave sees deficit −1 (recv counted, send not) and
                        // the invalid flag — either alone prevents a false
                        // termination.
                        assert_eq!(count, -1);
                        assert!(invalid);
                    }
                    _ => panic!(),
                }
            }
            _ => panic!(),
        }
    }

    #[test]
    fn merge_hist_accumulates() {
        let mut a = vec![(3u32, 2u64), (5, 1)];
        merge_hist(&mut a, &[(5, 4), (9, 9)]);
        a.sort();
        assert_eq!(a, vec![(3, 2), (5, 5), (9, 9)]);
    }

    #[test]
    fn sequential_waves_raise_clock() {
        let mut n = DtdNode::new(SpanningTree::ternary(0, 1));
        for t in 1..=5u64 {
            let oc = n.initiate_wave(1, true, vec![], &mut Vec::new());
            assert!(matches!(oc, WaveOutcome::Complete { .. }));
            assert_eq!(n.clock(), t);
        }
    }
}
