//! Lifeline-based global load balancing topology (paper §4.2).
//!
//! GLB (Saraswat et al., PPoPP'11) organizes processes as a hypercube with
//! edge length `l` plus `w` random steal attempts. The paper fixes `l = 2`
//! (binary hypercube, the highest possible dimension) and `w = 1` from
//! preliminary experiments; both remain configurable here for the ablation
//! benches. With `l = 2`, the lifeline neighbors of rank `r` are
//! `r XOR 2^j` for `j < z`, `z = ⌈log₂ P⌉`, skipping ids ≥ P.

use crate::util::rng::Rng;

/// The lifeline graph for one process.
///
/// # Examples
///
/// With `l = 2` the lifelines of rank `r` are `r XOR 2^j`:
///
/// ```
/// use parlamp::glb::Lifelines;
///
/// let ll = Lifelines::new(5, 16, 2);
/// assert_eq!(ll.neighbors(), &[4, 7, 1, 13]); // 5^1, 5^2, 5^4, 5^8
/// assert_eq!(ll.z(), 4);
/// assert_eq!(ll.index_of(7), Some(1));
/// assert_eq!(ll.index_of(6), None);
/// ```
///
/// For world sizes that are not a power of `l`, each dimension wraps to the
/// first id that actually exists, so every rank keeps an outgoing lifeline
/// in every dimension that distinguishes ranks — the directed lifeline
/// graph stays strongly connected (the paper's deadlock-freedom
/// prerequisite; see the property suite):
///
/// ```
/// use parlamp::glb::Lifelines;
///
/// // rank 4 of 5 at l = 3: both naive digit increments (to ids 5 and 7)
/// // fall outside the world and wrap to 3 and 1 instead.
/// assert_eq!(Lifelines::new(4, 5, 3).neighbors(), &[3, 1]);
/// ```
#[derive(Clone, Debug)]
pub struct Lifelines {
    rank: usize,
    size: usize,
    /// Lifeline neighbor ranks, `LL(j)` for `j < z` (deduplicated, < P).
    neighbors: Vec<usize>,
}

impl Lifelines {
    /// Construct the lifeline neighborhood of `rank` in a world of `size`
    /// processes for hypercube edge length `l` (the paper uses `l = 2`).
    ///
    /// For general `l`, ranks are written in base `l` with `z` digits
    /// (`l^z ≥ size`), and the `j`-th lifeline increments digit `j` mod `l`
    /// — the structure of Saraswat et al. For `l = 2` this reduces to the
    /// XOR form. When the incremented id falls outside the world (`≥ size`,
    /// possible when `size` is not a power of `l`), the digit keeps
    /// cycling until it lands on an existing rank: each dimension then
    /// forms a directed cycle over the ranks that exist, which keeps the
    /// directed lifeline graph strongly connected — the deadlock-freedom
    /// prerequisite of the paper's §4.2 (every starving process must be
    /// reachable from every working one through lifeline edges).
    pub fn new(rank: usize, size: usize, l: usize) -> Self {
        assert!(l >= 2, "hypercube edge length must be ≥ 2");
        assert!(rank < size);
        let mut z = 0usize;
        let mut cap = 1usize;
        while cap < size {
            cap *= l;
            z += 1;
        }
        let mut neighbors = Vec::with_capacity(z);
        for j in 0..z {
            // rank with base-l digit j incremented (cyclically, skipping
            // ids that fall outside the world) — the first valid id wins.
            let base = l.pow(j as u32);
            let digit = rank / base % l;
            for step in 1..l {
                // next != digit for every step in 1..l, so candidate != rank.
                let next = (digit + step) % l;
                let candidate = rank - digit * base + next * base;
                if candidate < size {
                    if !neighbors.contains(&candidate) {
                        neighbors.push(candidate);
                    }
                    break;
                }
            }
        }
        Lifelines { rank, size, neighbors }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Lifeline neighbors `LL(0..z)`.
    pub fn neighbors(&self) -> &[usize] {
        &self.neighbors
    }

    /// Dimension `z` (number of lifelines actually present).
    pub fn z(&self) -> usize {
        self.neighbors.len()
    }

    /// Index of `src` in the neighbor list, if it is one of our lifelines.
    pub fn index_of(&self, src: usize) -> Option<usize> {
        self.neighbors.iter().position(|&n| n == src)
    }

    /// A uniformly random steal victim ≠ self (the `w` random steals), or
    /// `None` in a single-process world, where no victim exists. Returning
    /// `None` (instead of asserting) matters in release builds: the old
    /// `debug_assert!` compiled away and the rejection loop spun forever
    /// when `size == 1`.
    pub fn random_victim(&self, rng: &mut Rng) -> Option<usize> {
        if self.size <= 1 {
            return None;
        }
        loop {
            let v = rng.index(self.size);
            if v != self.rank {
                return Some(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn l2_reduces_to_xor() {
        for size in [2usize, 3, 8, 13, 16, 100] {
            for rank in 0..size {
                let ll = Lifelines::new(rank, size, 2);
                let mut want: Vec<usize> = Vec::new();
                let z = (usize::BITS - (size - 1).leading_zeros()) as usize;
                for j in 0..z {
                    let n = rank ^ (1 << j);
                    if n < size && !want.contains(&n) {
                        want.push(n);
                    }
                }
                assert_eq!(ll.neighbors(), &want[..], "rank {rank} size {size}");
            }
        }
    }

    #[test]
    fn lifelines_are_symmetric_for_l2_powers_of_two() {
        // In a full binary hypercube the lifeline relation is symmetric.
        let size = 16;
        for rank in 0..size {
            let ll = Lifelines::new(rank, size, 2);
            for &n in ll.neighbors() {
                let back = Lifelines::new(n, size, 2);
                assert!(back.neighbors().contains(&rank));
            }
        }
    }

    #[test]
    fn graph_is_connected() {
        forall("lifeline graph connects all ranks", 24, |rng| {
            let size = 2 + rng.index(200);
            let l = 2 + rng.index(3); // l ∈ {2,3,4}
            // BFS from 0 over lifeline edges, traversed in both directions
            // (work flows victim→thief along an edge either may initiate).
            let adj: Vec<Vec<usize>> =
                (0..size).map(|r| Lifelines::new(r, size, l).neighbors().to_vec()).collect();
            let mut seen = vec![false; size];
            let mut queue = std::collections::VecDeque::from([0usize]);
            seen[0] = true;
            while let Some(u) = queue.pop_front() {
                for &v in &adj[u] {
                    if !seen[v] {
                        seen[v] = true;
                        queue.push_back(v);
                    }
                }
                for (v, a) in adj.iter().enumerate() {
                    if !seen[v] && a.contains(&u) {
                        seen[v] = true;
                        queue.push_back(v);
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err(format!("size={size} l={l}: unreachable ranks"));
            }
            Ok(())
        });
    }

    #[test]
    fn random_victim_never_self() {
        let ll = Lifelines::new(3, 7, 2);
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let v = ll.random_victim(&mut rng).expect("victims exist for size 7");
            assert!(v < 7 && v != 3);
        }
    }

    #[test]
    fn random_victim_is_none_in_a_singleton_world() {
        // Must return (None), not spin: the guard used to be a
        // debug_assert!, which release builds compile away.
        let ll = Lifelines::new(0, 1, 2);
        let mut rng = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(ll.random_victim(&mut rng), None);
        }
    }

    #[test]
    fn dimension_logarithmic() {
        let ll = Lifelines::new(0, 1200, 2);
        assert_eq!(ll.z(), 11); // 2^11 = 2048 ≥ 1200
        assert!(ll.neighbors().iter().all(|&n| n < 1200));
    }
}
